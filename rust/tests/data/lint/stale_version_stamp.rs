//! `stale-version-stamp` fixture: mutating a `ClusterState`
//! allocation field outside the version-stamping allowlist fires at
//! the field; allowlisted methods, reads, and the annotated twin
//! stay clean.

pub struct ClusterState {
    ready_count: usize,
    node_version: u64,
}

impl ClusterState {
    pub fn set_ready(&mut self, up: bool) {
        self.ready_count += if up { 1 } else { 0 };
        self.node_version += 1;
    }

    pub fn rebalance(&mut self) {
        self.ready_count = 0;
    }

    pub fn ready(&self) -> usize {
        self.ready_count
    }

    pub fn restore(&mut self, version: u64) {
        // greenpod-lint: allow(stale-version-stamp) reason="fixture twin: snapshot restore re-stamps explicitly"
        self.node_version = version;
    }
}
