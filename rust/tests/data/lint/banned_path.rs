//! `banned-path` fixture (identifier half): references to the retired
//! monolith schedulers fire; the annotated twin stays clean.

pub fn legacy() {
    let g = GreenPodScheduler::new(42);
    let d = DefaultK8sScheduler::new(42);
    run(g, d);
}

pub fn twin() {
    // greenpod-lint: allow(banned-path) reason="fixture twin: historical reference kept for a doc example"
    let g = GreenPodScheduler::new(42);
    drop(g);
}
