//! `unguarded-div` fixture: kernel divisions by `.len()` or a
//! capacity-shaped name with no zero guard in the enclosing fn fire
//! at the division operator; the guarded, asserted, and clamped
//! twins stay clean.

pub fn mean_wait(waits: &[f64]) -> f64 {
    waits.iter().sum::<f64>() / waits.len() as f64
}

pub fn shard_of(pod: u64, shard_count: u64) -> u64 {
    pod % shard_count
}

pub fn guarded_mean(waits: &[f64]) -> f64 {
    if waits.is_empty() {
        return 0.0;
    }
    waits.iter().sum::<f64>() / waits.len() as f64
}

pub fn asserted_shard(pod: u64, shard_count: u64) -> u64 {
    debug_assert!(shard_count > 0, "zero shards");
    pod % shard_count
}

pub fn clamped_rate(total: f64, node_count: f64) -> f64 {
    total / node_count.max(1.0)
}

pub fn sampled(total: f64, sample_count: f64) -> f64 {
    // greenpod-lint: allow(unguarded-div) reason="fixture twin: caller pins a non-empty sample set"
    total / sample_count
}
