//! `silent-clamp` fixture: `.max(…)`/`.clamp(…)` on time-like
//! values with no adjacent assert fire at the method name; the
//! running max, the asserted clamp, the non-time clamp, and the
//! annotated twin stay clean.

pub fn settle(arrival_s: f64, now: f64) -> f64 {
    arrival_s.max(now)
}

pub fn window(deadline: f64, horizon: f64) -> f64 {
    deadline.clamp(0.0, horizon)
}

pub fn widest(spans: &[f64]) -> f64 {
    let mut makespan = 0.0f64;
    for &s in spans {
        makespan = makespan.max(s);
    }
    makespan
}

pub fn guarded(start_s: f64, end_s: f64) -> f64 {
    debug_assert!(start_s <= end_s, "window order");
    end_s.max(start_s)
}

pub fn cores(requested: f64, available: f64) -> f64 {
    requested.min(available).max(1.0)
}

pub fn twin(at_s: f64, now: f64) -> f64 {
    // greenpod-lint: allow(silent-clamp) reason="fixture twin: late actions fire now by contract"
    at_s.max(now)
}
