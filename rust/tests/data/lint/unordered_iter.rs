//! `unordered-iter` fixture: the seeded violation below must fire at
//! exactly one span; the annotated twin stays clean.

use std::collections::HashMap;

// greenpod-lint: allow(unordered-iter) reason="fixture twin: the annotation must suppress this hash-set use"
use std::collections::HashSet;
