//! `unbounded-growth` fixture: a collection field grown inside a
//! kernel loop with no draining method anywhere in the type's impls
//! fires at the grower call; drained fields, straight-line pushes,
//! and the annotated twin stay clean.

use std::collections::VecDeque;

pub struct EventLog {
    entries: Vec<u64>,
    recent: VecDeque<u64>,
    audit: Vec<u64>,
}

impl EventLog {
    pub fn ingest(&mut self, batch: &[u64]) {
        for &e in batch {
            self.entries.push(e);
            self.recent.push_back(e);
        }
    }

    pub fn seed(&mut self, e: u64) {
        self.audit.push(e);
    }

    pub fn trim(&mut self) {
        while self.recent.len() > 64 {
            self.recent.pop_front();
        }
    }

    pub fn archive(&mut self, batch: &[u64]) {
        for &e in batch {
            // greenpod-lint: allow(unbounded-growth) reason="fixture twin: retention is the external compactor's job"
            self.audit.push(e);
        }
    }
}
