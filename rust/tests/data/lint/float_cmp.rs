//! `float-cmp-unwrap` fixture: ad-hoc orderings fire; the annotated
//! twin stays clean.

pub fn sort_scores(v: &mut [f64]) {
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
}

pub fn max_score(v: &[f64]) -> Option<&f64> {
    v.iter().max_by(|a, b| a.total_cmp(b))
}

pub fn twin(v: &mut [f64]) {
    // greenpod-lint: allow(float-cmp-unwrap) reason="fixture twin: suppressed ad-hoc float ordering"
    v.sort_by(|a, b| a.total_cmp(b));
}
