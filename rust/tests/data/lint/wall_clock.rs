//! `wall-clock-in-kernel` fixture: `Instant::now()` in kernel scope
//! fires; the plain import and the annotated twin stay clean.

use std::time::Instant;

pub fn stamp() -> std::time::Duration {
    let t0 = Instant::now();
    t0.elapsed()
}

pub fn stamp_allowed() -> std::time::Duration {
    // greenpod-lint: allow(wall-clock-in-kernel) reason="fixture twin: bench-style timing that never reaches results"
    let t0 = Instant::now();
    t0.elapsed()
}
