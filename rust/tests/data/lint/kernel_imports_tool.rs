//! `kernel-imports-tool` fixture: kernel files importing tool
//! modules fire at the tool segment (once per offending leaf);
//! kernel-to-kernel imports, deterministic util leaves, and the
//! annotated twin stay clean.

use crate::api::{ApiEvent, PodSubmission};
use crate::cluster::Pod;
use crate::runtime::PjrtTopsisEngine;
use crate::util::pretty::human_bytes;
use crate::util::stats::total_order;

// greenpod-lint: allow(kernel-imports-tool) reason="fixture twin: audited tool-module import"
use crate::experiments::grid;
