//! `lossy-id-cast` fixture: all three shapes fire; legitimate math
//! and the annotated twin stay clean.

pub fn shapes(pod_id: u64, count: u64, v: &Json) -> (f64, Json, u64) {
    let a = pod_id as f64;
    let b = Json::Num(count as f64);
    let c = v.as_f64().unwrap() as u64;
    (a, b, c)
}

pub fn clean_math(cpu_millis: u64) -> f64 {
    cpu_millis as f64 / 8.0
}

pub fn twin(node_id: u64) -> f64 {
    // greenpod-lint: allow(lossy-id-cast) reason="fixture twin: deliberate precision loss, proven harmless"
    node_id as f64
}
