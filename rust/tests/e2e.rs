//! End-to-end integration tests: whole-stack runs (no PJRT required —
//! those live in pjrt_integration.rs) plus failure injection.

use std::rc::Rc;

use greenpod::cluster::{ClusterState, Pod};
use greenpod::config::{
    ClusterConfig, CompetitionLevel, Config, SchedulerKind,
    WeightingScheme,
};
use greenpod::experiments::{
    run_ablation, run_alloc_analysis, run_cell, run_once, run_table6,
    run_table7, ExperimentContext,
};
use greenpod::framework::{
    BuildOptions, FrameworkScheduler, McdaScorePlugin, NodeResourcesFit,
    ProfileRegistry, SchedulerProfile,
};
use greenpod::scheduler::{AdaptiveWeighting, Estimator, Scheduler};
use greenpod::workload::{WorkloadClass, WorkloadExecutor};

/// Registry-built pair of framework profiles (the only scheduler
/// implementations since the monolith retirement).
fn scheds(
    config: &Config,
    scheme: WeightingScheme,
    seed: u64,
) -> (FrameworkScheduler, FrameworkScheduler) {
    let registry = ProfileRegistry::new(config);
    let opts = BuildOptions::new(config, scheme).with_seed(seed);
    (
        registry.build("greenpod", &opts).expect("built-in"),
        registry.build("default-k8s", &opts).expect("built-in"),
    )
}

fn fast_ctx(reps: u32) -> ExperimentContext {
    let mut cfg = Config::paper_default();
    cfg.experiment.replications = reps;
    ExperimentContext::new(cfg)
}

/// The headline reproduction: Table VI's qualitative shape (see
/// DESIGN.md §5 reproduction criterion).
#[test]
fn table6_full_factorial_shape() {
    let t6 = run_table6(&fast_ctx(3));

    for level in CompetitionLevel::ALL {
        let e = t6.cell(level, WeightingScheme::EnergyCentric);
        let p = t6.cell(level, WeightingScheme::PerformanceCentric);
        assert!(
            e.optimization_pct() > p.optimization_pct(),
            "{level:?}: energy {:.1}% !> perf {:.1}%",
            e.optimization_pct(),
            p.optimization_pct()
        );
        assert!(
            e.optimization_pct() > 15.0,
            "{level:?}: energy-centric only {:.1}%",
            e.optimization_pct()
        );
        assert_eq!(e.unschedulable, 0);
    }
    assert!(t6.average_optimization_pct > 5.0);
    // Fig. 2 renders from the same data.
    let fig = greenpod::experiments::render_fig2(&t6);
    assert!(fig.contains("Energy-centric"));
}

/// Table VII feeds off Table VI's measured average.
#[test]
fn table7_from_measured_optimization() {
    let t7 = run_table7(
        &Config::paper_default().energy,
        19.38, // the paper's published average
    );
    assert!((t7.single.annual_mwh - 10.70).abs() < 0.05);
    assert_eq!(t7.ten.clusters, 10);
}

/// §V.D: energy-centric placement concentrates on Category A.
#[test]
fn alloc_analysis_prefers_efficient_nodes() {
    let a = run_alloc_analysis(&fast_ctx(2), CompetitionLevel::Low);
    let energy = &a.topsis_alloc[&WeightingScheme::EnergyCentric];
    let on_a = *energy.get(&greenpod::cluster::NodeCategory::A).unwrap_or(&0);
    assert!(on_a > 0, "energy-centric never used Category A: {energy:?}");
}

/// Ablation harness runs all four MCDA methods.
#[test]
fn ablation_all_methods() {
    let ab = run_ablation(&fast_ctx(1), CompetitionLevel::Low);
    assert_eq!(ab.rows.len(), 4);
}

/// Failure injection: a NotReady node is never used; recovery restores it.
#[test]
fn node_failure_and_recovery() {
    let config = Config::paper_default();
    let mut state = ClusterState::from_config(&config.cluster);
    let (mut sched, _) = scheds(&config, WeightingScheme::EnergyCentric, 7);

    // Kill all A nodes (the energy-centric favorites).
    state.set_ready(0, false, 0.0);
    state.set_ready(1, false, 0.0);
    state.set_ready(2, false, 0.0);
    for i in 0..4 {
        let pod = Pod::new(i, WorkloadClass::Medium,
                           SchedulerKind::Topsis, 0.0, 2);
        let d = sched.schedule(&state, &pod);
        let n = d.node.expect("other nodes still fit");
        assert!(n > 2, "placed on NotReady node {n}");
        state.bind(&pod, n, 0.0).unwrap();
    }

    // Recover: the next pod can use A again.
    state.set_ready(0, true, 1.0);
    let pod = Pod::new(99, WorkloadClass::Medium,
                       SchedulerKind::Topsis, 0.0, 2);
    let d = sched.schedule(&state, &pod);
    assert_eq!(d.node, Some(0), "recovered A node should win on energy");
}

/// Failure injection: PJRT backend with a broken registry degrades to
/// the pure-Rust scorer and counts fallbacks.
#[test]
fn pjrt_fallback_on_missing_artifacts() {
    use greenpod::runtime::ArtifactRegistry;

    // A registry over an empty temp dir: manifest parse fails at open,
    // so simulate the later failure mode instead — a manifest whose
    // artifact files are missing.
    let dir = std::env::temp_dir().join(format!(
        "greenpod-test-{}",
        std::process::id()
    ));
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(
        dir.join("manifest.json"),
        r#"{"criteria_slots": 8, "epoch_steps": 8, "entries": {
            "topsis_score_n64": {
                "kind": "topsis", "nodes": 64, "criteria": 8,
                "path": "missing.hlo.txt",
                "inputs": [], "outputs": []
            }
        }}"#,
    )
    .unwrap();
    let reg = Rc::new(ArtifactRegistry::open(&dir).unwrap());

    let config = Config::paper_default();
    let state = ClusterState::from_config(&config.cluster);
    let registry = ProfileRegistry::new(&config);
    let opts = BuildOptions::new(&config, WeightingScheme::EnergyCentric)
        .with_pjrt(Some(reg));
    let mut sched = registry.build("greenpod", &opts).unwrap();

    let pod =
        Pod::new(0, WorkloadClass::Medium, SchedulerKind::Topsis, 0.0, 2);
    let d = sched.schedule(&state, &pod);
    assert!(d.node.is_some(), "fallback must still place the pod");
    assert_eq!(sched.pjrt_fallbacks(), 1);
    std::fs::remove_dir_all(&dir).ok();
}

/// Adaptive weighting integrates with the scheduler end to end.
#[test]
fn adaptive_scheduler_places_pods() {
    let config = Config::paper_default();
    let executor = WorkloadExecutor::analytic();
    // Adaptive weighting is a plugin-level knob, so this profile is
    // hand-assembled rather than registry-built.
    let profile = SchedulerProfile::new("greenpod-adaptive")
        .filter(Box::new(NodeResourcesFit))
        .score(
            Box::new(
                McdaScorePlugin::new(
                    Estimator::with_defaults(config.energy.clone()),
                    WeightingScheme::EnergyCentric,
                )
                .with_adaptive(AdaptiveWeighting::default()),
            ),
            1.0,
        );
    let mut topsis = FrameworkScheduler::new(profile, 7);
    let (_, mut default) =
        scheds(&config, WeightingScheme::EnergyCentric, 7);
    let engine = greenpod::simulation::SimulationEngine::new(
        &config,
        greenpod::simulation::SimulationParams::with_beta_and_seed(0.35, 7),
        &executor,
    );
    let pods = greenpod::workload::generate_pods(
        CompetitionLevel::High,
        &config.experiment,
        7,
    )
    .pods;
    let r = engine.run(pods, &mut topsis, &mut default);
    assert_eq!(r.records.len(), 22);
    assert!(r.unschedulable.is_empty());
}

/// Scaled cluster: the stack works beyond the paper's 6 nodes.
#[test]
fn scaled_cluster_cell() {
    let mut cfg = Config::paper_default();
    cfg.cluster = ClusterConfig::scaled(4); // 24 nodes
    cfg.experiment.replications = 1;
    let ctx = ExperimentContext::new(cfg);
    let cell = run_cell(&ctx, CompetitionLevel::High,
                        WeightingScheme::EnergyCentric);
    assert!(cell.topsis_kj > 0.0);
    assert_eq!(cell.unschedulable, 0);
}

/// Scheduling latency metric is captured and small (paper: "slight
/// scheduling latency" — ms scale at most).
#[test]
fn scheduling_latency_sane() {
    let ctx = fast_ctx(2);
    let executor = WorkloadExecutor::analytic();
    let r = run_once(&ctx, CompetitionLevel::Medium,
                     WeightingScheme::EnergyCentric, 1, &executor);
    let topsis_ms = r.mean_sched_ms(SchedulerKind::Topsis);
    let default_ms = r.mean_sched_ms(SchedulerKind::DefaultK8s);
    assert!(topsis_ms > 0.0);
    assert!(topsis_ms < 10.0, "TOPSIS scheduling {topsis_ms} ms");
    assert!(default_ms < 10.0);
}
