//! Integration tests for `greenpod lint` (L2): every rule — token
//! layer and item layer — fires on its seeded-violation fixture at
//! exactly the expected spans while the annotated twin in the same
//! file stays clean, the full pass over `rust/src/`, `rust/tests/`,
//! and `examples/` reports zero findings (the same gate CI runs via
//! `greenpod lint --deny`), the allow grammar survives its edge
//! cases (stacked own-line annotations, CRLF sources, escaped-quote
//! reasons), and the file-existence half of `banned-path` flags a
//! resurrected monolith scheduler file.

use std::fs;
use std::path::Path;

use greenpod::lint::{lint_roots, lint_source, lint_tree};

fn fixture(name: &str) -> String {
    let p = Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests/data/lint")
        .join(name);
    fs::read_to_string(&p)
        .unwrap_or_else(|e| panic!("reading {}: {e}", p.display()))
}

/// 1-based column of `needle` on 1-based `line` of `src`.
fn col_of(src: &str, line: usize, needle: &str) -> usize {
    let text = src
        .lines()
        .nth(line - 1)
        .unwrap_or_else(|| panic!("fixture has no line {line}"));
    text.find(needle).map(|i| i + 1).unwrap_or_else(|| {
        panic!("`{needle}` not on line {line}: {text}")
    })
}

/// Lint `name` under a kernel-scoped label and assert it produces
/// exactly `expected` findings of `rule`, each pinned to the span of
/// the named token. The fixture's annotated twin contributing zero
/// findings (including no `unused-allow`) falls out of the exact
/// length check.
fn check_fixture(name: &str, rule: &str, expected: &[(usize, &str)]) {
    let src = fixture(name);
    let label = format!("rust/src/fixtures/{name}");
    let out = lint_source(&label, &src);
    let rendered: Vec<String> =
        out.iter().map(|f| f.render()).collect();
    assert_eq!(
        out.len(),
        expected.len(),
        "{name}: expected {} finding(s), got {rendered:?}",
        expected.len()
    );
    for (f, (line, token)) in out.iter().zip(expected) {
        assert_eq!(f.rule, rule, "{name}: {}", f.render());
        assert_eq!(f.path, label, "{name}: {}", f.render());
        assert_eq!(f.line, *line, "{name}: {}", f.render());
        assert_eq!(
            f.col,
            col_of(&src, *line, token),
            "{name}: {}",
            f.render()
        );
    }
}

#[test]
fn unordered_iter_fixture_fires_at_its_span() {
    check_fixture(
        "unordered_iter.rs",
        "unordered-iter",
        &[(4, "HashMap")],
    );
}

#[test]
fn wall_clock_fixture_fires_at_its_span() {
    check_fixture(
        "wall_clock.rs",
        "wall-clock-in-kernel",
        &[(7, "Instant")],
    );
}

#[test]
fn lossy_id_cast_fixture_fires_all_three_shapes() {
    check_fixture(
        "lossy_id_cast.rs",
        "lossy-id-cast",
        &[(5, "as f64"), (6, "as f64"), (7, "as u64")],
    );
}

#[test]
fn float_cmp_fixture_fires_at_both_call_sites() {
    check_fixture(
        "float_cmp.rs",
        "float-cmp-unwrap",
        &[(5, "partial_cmp"), (9, "total_cmp")],
    );
}

#[test]
fn banned_path_fixture_fires_on_both_idents() {
    check_fixture(
        "banned_path.rs",
        "banned-path",
        &[(5, "GreenPodScheduler"), (6, "DefaultK8sScheduler")],
    );
}

#[test]
fn kernel_imports_tool_fixture_fires_per_offending_leaf() {
    // The grouped `crate::api::{…}` use expands to two leaves, both
    // anchored at the shared `api` segment; the deterministic util
    // leaf (`util::stats`) and the kernel-to-kernel import are quiet.
    check_fixture(
        "kernel_imports_tool.rs",
        "kernel-imports-tool",
        &[(6, "api"), (6, "api"), (8, "runtime"), (9, "util")],
    );
}

#[test]
fn unguarded_div_fixture_fires_at_the_operators() {
    check_fixture(
        "unguarded_div.rs",
        "unguarded-div",
        &[(7, "/"), (11, "%")],
    );
}

#[test]
fn unbounded_growth_fixture_fires_at_the_grower() {
    // Only the undrained `entries` push fires: `recent` has a
    // `pop_front` drain in `trim`, and the straight-line `audit`
    // push sits outside any loop.
    check_fixture(
        "unbounded_growth.rs",
        "unbounded-growth",
        &[(17, "push")],
    );
}

#[test]
fn silent_clamp_fixture_fires_at_the_method_names() {
    check_fixture(
        "silent_clamp.rs",
        "silent-clamp",
        &[(7, "max"), (11, "clamp")],
    );
}

#[test]
fn stale_version_stamp_fixture_fires_at_the_field() {
    check_fixture(
        "stale_version_stamp.rs",
        "stale-version-stamp",
        &[(18, "ready_count")],
    );
}

#[test]
fn kernel_only_rules_stay_quiet_in_tool_scope() {
    // The same seeded violations under a tool-module label: the
    // kernel-only rules must not fire, so the only findings left are
    // the twins' now-unused allows.
    for name in [
        "unordered_iter.rs",
        "wall_clock.rs",
        "kernel_imports_tool.rs",
        "unguarded_div.rs",
        "unbounded_growth.rs",
        "silent_clamp.rs",
    ] {
        let src = fixture(name);
        let out = lint_source(&format!("rust/src/util/{name}"), &src);
        assert_eq!(out.len(), 1, "{name}: {out:?}");
        assert_eq!(out[0].rule, "unused-allow", "{name}: {out:?}");
    }
}

#[test]
fn stale_version_stamp_fires_in_tool_scope_too() {
    // The version-stamp contract holds everywhere `ClusterState` is
    // mutated — tests and tools included — so the tool-scoped run
    // keeps the same finding (and its twin's allow stays used).
    let src = fixture("stale_version_stamp.rs");
    let out =
        lint_source("rust/src/util/stale_version_stamp.rs", &src);
    assert_eq!(out.len(), 1, "{out:?}");
    assert_eq!(out[0].rule, "stale-version-stamp", "{out:?}");
    assert_eq!(out[0].line, 18, "{out:?}");
}

#[test]
fn stacked_own_line_allows_cover_the_same_line() {
    // Two consecutive own-line annotations both attach to the next
    // code line, suppressing that line's two different-rule findings
    // with zero unused-allow residue.
    let src = "fn f(v: &mut [f64], id: u64) -> f64 {\n\
        // greenpod-lint: allow(lossy-id-cast) reason=\"edge case: display-only cast\"\n\
        // greenpod-lint: allow(float-cmp-unwrap) reason=\"edge case: ad-hoc order under test\"\n\
        let y = id as f64; v.sort_by(|a, b| a.partial_cmp(b).unwrap());\n\
        y\n}\n";
    let out = lint_source("rust/src/simulation/stacked.rs", src);
    assert!(out.is_empty(), "{out:?}");
    // Dropping the annotations restores both findings — the stacked
    // pass above really was suppression, not silence.
    let bare: String = src
        .lines()
        .filter(|l| !l.trim_start().starts_with("// greenpod-lint:"))
        .map(|l| format!("{l}\n"))
        .collect();
    let out = lint_source("rust/src/simulation/stacked.rs", &bare);
    let rules: Vec<&str> = out.iter().map(|f| f.rule).collect();
    assert_eq!(rules, ["lossy-id-cast", "float-cmp-unwrap"], "{out:?}");
}

#[test]
fn escaped_quotes_inside_allow_reasons_parse() {
    let src = "use std::collections::HashMap; \
        // greenpod-lint: allow(unordered-iter) reason=\"pins \\\"exact\\\" iteration twin\"\n";
    let out = lint_source("rust/src/simulation/escaped.rs", src);
    assert!(out.is_empty(), "{out:?}");
}

#[test]
fn crlf_sources_lint_like_lf_sources() {
    // CRLF line endings must not shift spans or break trailing
    // annotations (the comment body carries a `\r` the parser trims).
    let bare = "use std::collections::HashMap;\r\n\
                fn f() { let t = Instant::now(); }\r\n";
    let out = lint_source("rust/src/simulation/crlf.rs", bare);
    let spans: Vec<(&str, usize)> =
        out.iter().map(|f| (f.rule, f.line)).collect();
    assert_eq!(
        spans,
        [("unordered-iter", 1), ("wall-clock-in-kernel", 2)],
        "{out:?}"
    );
    let allowed = "use std::collections::HashMap; \
        // greenpod-lint: allow(unordered-iter) reason=\"crlf twin\"\r\n\
        // greenpod-lint: allow(wall-clock-in-kernel) reason=\"crlf twin\"\r\n\
        fn f() { let t = Instant::now(); }\r\n";
    let out = lint_source("rust/src/simulation/crlf.rs", allowed);
    assert!(out.is_empty(), "{out:?}");
}

#[test]
fn unused_and_malformed_allows_name_the_offending_rule() {
    // `unused-allow` carries the rule the annotation tried to
    // suppress…
    let src = "// greenpod-lint: allow(banned-path) reason=\"nothing here\"\n\
               fn f() {}\n";
    let out = lint_source("rust/src/simulation/unused.rs", src);
    assert_eq!(out.len(), 1, "{out:?}");
    assert_eq!(out[0].rule, "unused-allow");
    assert_eq!(out[0].allow_rule.as_deref(), Some("banned-path"));
    // …and so does `malformed-allow` when the rule name parsed but
    // the reason is missing (the underlying finding still fires).
    let src = "use std::collections::HashMap; \
               // greenpod-lint: allow(unordered-iter)\n";
    let out = lint_source("rust/src/simulation/malformed.rs", src);
    let rules: Vec<&str> = out.iter().map(|f| f.rule).collect();
    assert_eq!(rules, ["unordered-iter", "malformed-allow"], "{out:?}");
    let mal = out.iter().find(|f| f.rule == "malformed-allow").unwrap();
    assert_eq!(mal.allow_rule.as_deref(), Some("unordered-iter"));
}

#[test]
fn lint_repo_is_clean() {
    let manifest = Path::new(env!("CARGO_MANIFEST_DIR"));
    let roots = [
        manifest.join("src"),
        manifest.join("tests"),
        manifest.join("../examples"),
    ];
    let report =
        lint_roots(&roots).expect("lint walk over src/tests/examples");
    assert!(
        report.files_scanned > 50,
        "only {} files scanned — wrong roots?",
        report.files_scanned
    );
    assert!(
        report.clean(),
        "the swept tree must lint clean (CI runs `greenpod lint \
         --deny`):\n{}",
        report
            .findings
            .iter()
            .map(|f| f.render())
            .collect::<Vec<_>>()
            .join("\n")
    );
    // The legacy single-root entry point still walks `src` alone.
    let src_only = lint_tree(&manifest.join("src"))
        .expect("lint walk over rust/src");
    assert!(src_only.clean() && src_only.files_scanned > 40);
}

#[test]
fn banned_file_reappearance_is_flagged() {
    let dir = std::env::temp_dir()
        .join(format!("greenpod-lint-banned-{}", std::process::id()));
    let sched = dir.join("scheduler");
    fs::create_dir_all(&sched).expect("temp tree");
    fs::write(sched.join("greenpod.rs"), "// resurrected\n").unwrap();
    fs::write(dir.join("lib.rs"), "pub mod scheduler;\n").unwrap();
    let report = lint_tree(&dir).expect("lint walk over temp tree");
    fs::remove_dir_all(&dir).ok();
    assert_eq!(report.files_scanned, 2);
    assert_eq!(report.findings.len(), 1, "{:?}", report.findings);
    let f = &report.findings[0];
    assert_eq!(f.rule, "banned-path");
    assert!(
        f.path.ends_with("scheduler/greenpod.rs"),
        "{}",
        f.render()
    );
    assert_eq!((f.line, f.col), (1, 1));
}
