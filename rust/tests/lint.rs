//! Integration tests for `greenpod lint` (L2): every rule fires on
//! its seeded-violation fixture at exactly the expected spans while
//! the annotated twin in the same file stays clean, the full pass
//! over `rust/src/` reports zero findings (the same gate CI runs via
//! `greenpod lint --deny`), and the file-existence half of
//! `banned-path` flags a resurrected monolith scheduler file.

use std::fs;
use std::path::Path;

use greenpod::lint::{lint_source, lint_tree};

fn fixture(name: &str) -> String {
    let p = Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests/data/lint")
        .join(name);
    fs::read_to_string(&p)
        .unwrap_or_else(|e| panic!("reading {}: {e}", p.display()))
}

/// 1-based column of `needle` on 1-based `line` of `src`.
fn col_of(src: &str, line: usize, needle: &str) -> usize {
    let text = src
        .lines()
        .nth(line - 1)
        .unwrap_or_else(|| panic!("fixture has no line {line}"));
    text.find(needle).map(|i| i + 1).unwrap_or_else(|| {
        panic!("`{needle}` not on line {line}: {text}")
    })
}

/// Lint `name` under a kernel-scoped label and assert it produces
/// exactly `expected` findings of `rule`, each pinned to the span of
/// the named token. The fixture's annotated twin contributing zero
/// findings (including no `unused-allow`) falls out of the exact
/// length check.
fn check_fixture(name: &str, rule: &str, expected: &[(usize, &str)]) {
    let src = fixture(name);
    let label = format!("rust/src/fixtures/{name}");
    let out = lint_source(&label, &src);
    let rendered: Vec<String> =
        out.iter().map(|f| f.render()).collect();
    assert_eq!(
        out.len(),
        expected.len(),
        "{name}: expected {} finding(s), got {rendered:?}",
        expected.len()
    );
    for (f, (line, token)) in out.iter().zip(expected) {
        assert_eq!(f.rule, rule, "{name}: {}", f.render());
        assert_eq!(f.path, label, "{name}: {}", f.render());
        assert_eq!(f.line, *line, "{name}: {}", f.render());
        assert_eq!(
            f.col,
            col_of(&src, *line, token),
            "{name}: {}",
            f.render()
        );
    }
}

#[test]
fn unordered_iter_fixture_fires_at_its_span() {
    check_fixture(
        "unordered_iter.rs",
        "unordered-iter",
        &[(4, "HashMap")],
    );
}

#[test]
fn wall_clock_fixture_fires_at_its_span() {
    check_fixture(
        "wall_clock.rs",
        "wall-clock-in-kernel",
        &[(7, "Instant")],
    );
}

#[test]
fn lossy_id_cast_fixture_fires_all_three_shapes() {
    check_fixture(
        "lossy_id_cast.rs",
        "lossy-id-cast",
        &[(5, "as f64"), (6, "as f64"), (7, "as u64")],
    );
}

#[test]
fn float_cmp_fixture_fires_at_both_call_sites() {
    check_fixture(
        "float_cmp.rs",
        "float-cmp-unwrap",
        &[(5, "partial_cmp"), (9, "total_cmp")],
    );
}

#[test]
fn banned_path_fixture_fires_on_both_idents() {
    check_fixture(
        "banned_path.rs",
        "banned-path",
        &[(5, "GreenPodScheduler"), (6, "DefaultK8sScheduler")],
    );
}

#[test]
fn kernel_only_rules_stay_quiet_in_tool_scope() {
    // The same seeded violations under a tool-module label: the
    // kernel-only rules must not fire, so the only findings left are
    // the twins' now-unused allows.
    for name in ["unordered_iter.rs", "wall_clock.rs"] {
        let src = fixture(name);
        let out = lint_source(&format!("rust/src/util/{name}"), &src);
        assert_eq!(out.len(), 1, "{name}: {out:?}");
        assert_eq!(out[0].rule, "unused-allow", "{name}: {out:?}");
    }
}

#[test]
fn lint_repo_is_clean() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
    let report = lint_tree(&root).expect("lint walk over rust/src");
    assert!(
        report.files_scanned > 40,
        "only {} files scanned — wrong root?",
        report.files_scanned
    );
    assert!(
        report.clean(),
        "rust/src must lint clean (CI runs `greenpod lint --deny`):\n{}",
        report
            .findings
            .iter()
            .map(|f| f.render())
            .collect::<Vec<_>>()
            .join("\n")
    );
}

#[test]
fn banned_file_reappearance_is_flagged() {
    let dir = std::env::temp_dir()
        .join(format!("greenpod-lint-banned-{}", std::process::id()));
    let sched = dir.join("scheduler");
    fs::create_dir_all(&sched).expect("temp tree");
    fs::write(sched.join("greenpod.rs"), "// resurrected\n").unwrap();
    fs::write(dir.join("lib.rs"), "pub mod scheduler;\n").unwrap();
    let report = lint_tree(&dir).expect("lint walk over temp tree");
    fs::remove_dir_all(&dir).ok();
    assert_eq!(report.files_scanned, 2);
    assert_eq!(report.findings.len(), 1, "{:?}", report.findings);
    let f = &report.findings[0];
    assert_eq!(f.rule, "banned-path");
    assert!(
        f.path.ends_with("scheduler/greenpod.rs"),
        "{}",
        f.render()
    );
    assert_eq!((f.line, f.col), (1, 1));
}
