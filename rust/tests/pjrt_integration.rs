//! Cross-layer integration tests: the Rust runtime against the real AOT
//! artifacts (requires `make artifacts`).
//!
//! These verify the numerical contract between the three layers:
//! * golden.json replay — python-computed outputs must match what Rust
//!   gets from the PJRT executables, bit-close;
//! * PJRT TOPSIS ≡ pure-Rust TOPSIS on random decision problems;
//! * every manifest artifact loads, compiles and executes.

use std::rc::Rc;

use greenpod::mcda::{self, Criterion, DecisionProblem};
use greenpod::runtime::{ArtifactRegistry, LinRegRunner, PjrtTopsisEngine};
use greenpod::util::json::Json;
use greenpod::util::rng::Rng;
use greenpod::workload::WorkloadClass;

/// Open the artifact registry. Returns `None` (skipping the test with
/// a note) only for genuine environment limitations — artifacts not
/// built (`make artifacts`) or the binary linking the in-tree PJRT
/// stub, which cannot execute — so tier-1 stays green offline. With a
/// real XLA runtime linked, load/compile failures are NOT skipped:
/// they must fail the tests.
fn registry() -> Option<Rc<ArtifactRegistry>> {
    let reg = match ArtifactRegistry::open_default() {
        Ok(r) => Rc::new(r),
        Err(e) => {
            eprintln!("skipping PJRT test (no artifacts: {e})");
            return None;
        }
    };
    if reg.client().platform_name() == "cpu-stub" {
        eprintln!("skipping PJRT test (in-tree PJRT stub linked)");
        return None;
    }
    Some(reg)
}

#[test]
fn every_manifest_artifact_compiles() {
    let Some(reg) = registry() else { return };
    let names: Vec<String> =
        reg.manifest().entries.keys().cloned().collect();
    assert_eq!(names.len(), 11, "expected 11 artifacts, got {names:?}");
    for name in &names {
        reg.load(name).unwrap_or_else(|e| panic!("{name}: {e}"));
    }
    assert_eq!(reg.cached_count(), names.len());
}

#[test]
fn topsis_tier_selection() {
    let Some(reg) = registry() else { return };
    assert_eq!(reg.topsis_tier(3).unwrap().1, 4);
    assert_eq!(reg.topsis_tier(4).unwrap().1, 4);
    assert_eq!(reg.topsis_tier(5).unwrap().1, 8);
    assert_eq!(reg.topsis_tier(64).unwrap().1, 64);
    assert!(reg.topsis_tier(65).is_err());
}

#[test]
fn golden_topsis_replay() {
    let Some(reg) = registry() else { return };
    let golden = Json::parse(
        &std::fs::read_to_string(reg.dir().join("golden.json")).unwrap(),
    )
    .unwrap();
    let g = golden.get("topsis_n4").unwrap();
    let matrix: Vec<f64> = g
        .get("matrix").unwrap().as_arr().unwrap()
        .iter().map(|v| v.as_f64().unwrap()).collect();
    let weights: Vec<f64> = g
        .get("weights").unwrap().as_arr().unwrap()
        .iter().map(|v| v.as_f64().unwrap()).collect();
    let benefit: Vec<f64> = g
        .get("benefit").unwrap().as_arr().unwrap()
        .iter().map(|v| v.as_f64().unwrap()).collect();
    let expect: Vec<f64> = g
        .get("closeness").unwrap().as_arr().unwrap()
        .iter().map(|v| v.as_f64().unwrap()).collect();

    // Reconstruct the 4x8 problem (padding columns included, weight 0).
    let criteria: Vec<Criterion> = (0..8)
        .map(|i| {
            if benefit[i] > 0.5 {
                Criterion::benefit(weights[i])
            } else {
                Criterion::cost(weights[i])
            }
        })
        .collect();
    let p = DecisionProblem::new(matrix, 4, criteria);

    // PJRT path matches python golden output.
    let mut engine = PjrtTopsisEngine::new(reg.clone());
    let got = engine.closeness(&p).unwrap();
    for (g, e) in got.iter().zip(&expect) {
        assert!((g - e).abs() < 1e-5, "pjrt {got:?} vs golden {expect:?}");
    }

    // Pure-Rust path matches too (cross-implementation equivalence).
    let rust = mcda::topsis_closeness(&p);
    for (r, e) in rust.iter().zip(&expect) {
        assert!((r - e).abs() < 1e-5, "rust {rust:?} vs golden {expect:?}");
    }
}

#[test]
fn golden_linreg_replay() {
    // The python-recorded epoch losses for seed 42 must be strictly
    // decreasing, and our Rust-side run of the same artifact (different
    // dataset stream, same distribution) must behave the same way.
    let Some(reg) = registry() else { return };
    let golden = Json::parse(
        &std::fs::read_to_string(reg.dir().join("golden.json")).unwrap(),
    )
    .unwrap();
    let g = golden.get("linreg_light_seed42").unwrap();
    let losses: Vec<f64> = g
        .get("epoch_losses").unwrap().as_arr().unwrap()
        .iter().map(|v| v.as_f64().unwrap()).collect();
    assert!(losses.windows(2).all(|w| w[1] < w[0]), "python losses {losses:?}");

    let runner = LinRegRunner::new(&reg);
    let res = runner.run(WorkloadClass::Light, 1, 42, 1.0).unwrap();
    assert_eq!(res.losses.len(), reg.manifest().epoch_steps);
    assert!(
        res.losses.windows(2).all(|w| w[1] < w[0]),
        "rust losses {:?}",
        res.losses
    );
    // Loss magnitude comparable to python's run (same distribution,
    // same lr): final loss within an order of magnitude.
    let py_final = *losses.last().unwrap();
    let rs_final = *res.losses.last().unwrap() as f64;
    assert!(
        rs_final < py_final * 10.0 + 0.1,
        "rust final {rs_final} vs python {py_final}"
    );
}

#[test]
fn pjrt_equals_rust_topsis_on_random_problems() {
    let Some(reg) = registry() else { return };
    let mut engine = PjrtTopsisEngine::new(reg);
    let mut rng = Rng::seed_from_u64(99);
    for case in 0..25 {
        let n = 2 + rng.below(30);
        let c = 2 + rng.below(4); // up to 5 criteria (artifact slots = 8)
        let matrix: Vec<f64> =
            (0..n * c).map(|_| rng.range_f64(0.05, 10.0)).collect();
        let criteria: Vec<Criterion> = (0..c)
            .map(|_| {
                let w = rng.range_f64(0.05, 1.0);
                if rng.chance(0.5) {
                    Criterion::benefit(w)
                } else {
                    Criterion::cost(w)
                }
            })
            .collect();
        let p = DecisionProblem::new(matrix, n, criteria);
        let pjrt = engine.closeness(&p).unwrap();
        let rust = mcda::topsis_closeness(&p);
        assert_eq!(pjrt.len(), rust.len());
        for (a, b) in pjrt.iter().zip(&rust) {
            assert!(
                (a - b).abs() < 5e-4,
                "case {case} (n={n}, c={c}): pjrt {a} vs rust {b}"
            );
        }
    }
}

#[test]
fn all_workload_classes_train_and_converge() {
    let Some(reg) = registry() else { return };
    let runner = LinRegRunner::new(&reg);
    for class in WorkloadClass::ALL {
        let res = runner.run(class, 2, 7, 0.5).unwrap();
        let first = res.losses[0];
        let last = *res.losses.last().unwrap();
        assert!(
            last < first,
            "{class:?}: loss {first} -> {last} did not decrease"
        );
        let (_, d) = class.step_shape();
        assert_eq!(res.weights.len(), d);
        assert_eq!(res.epoch_secs.len(), 2);
    }
}

#[test]
fn epoch_timing_calibration_positive() {
    let Some(reg) = registry() else { return };
    let runner = LinRegRunner::new(&reg);
    let secs = runner.calibrate(WorkloadClass::Light, 3).unwrap();
    assert!(secs > 0.0 && secs < 60.0, "implausible epoch time {secs}");
}
