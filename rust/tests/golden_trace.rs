//! Deterministic golden-trace regression: replay the committed arrival
//! trace (`tests/data/golden_trace.jsonl`) through the discrete-event
//! engine and assert placements, queue waits, attempt counts and
//! energy against the checked-in expectations
//! (`tests/data/golden_trace.expected.json`).
//!
//! The expectations are produced by an *independent oracle* — a Python
//! mirror of the engine's arithmetic
//! (`python/tools/make_golden_trace.py`) — so this test pins both the
//! engine's determinism and its numerical semantics. Placements and
//! attempt counts must match exactly; times and joules to 1e-9
//! relative (the two implementations share IEEE-754 doubles but may
//! round intermediate sums differently).

use std::collections::HashMap;

use greenpod::config::{Config, SchedulerKind, WeightingScheme};
use greenpod::scheduler::{DefaultK8sScheduler, Estimator, GreenPodScheduler};
use greenpod::simulation::{RunResult, SimulationEngine, SimulationParams};
use greenpod::util::json::Json;
use greenpod::workload::{ArrivalTrace, WorkloadExecutor};

fn data_path(name: &str) -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests/data")
        .join(name)
}

/// Replay the committed trace with the golden configuration: paper
/// defaults, all pods TOPSIS-owned, energy-centric profile, seed 42.
fn replay() -> RunResult {
    let cfg = Config::paper_default();
    let executor = WorkloadExecutor::analytic();
    let text = std::fs::read_to_string(data_path("golden_trace.jsonl"))
        .expect("committed golden trace");
    let trace = ArrivalTrace::from_jsonl(&text).expect("parse golden trace");
    let pods = trace.to_pods(SchedulerKind::Topsis);
    let engine = SimulationEngine::new(
        &cfg,
        SimulationParams::with_beta_and_seed(
            cfg.experiment.contention_beta,
            42,
        ),
        &executor,
    );
    let mut topsis = GreenPodScheduler::new(
        Estimator::new(
            cfg.energy.clone(),
            executor.light_epoch_secs(),
            cfg.experiment.contention_beta,
        ),
        WeightingScheme::EnergyCentric,
    );
    let mut default = DefaultK8sScheduler::new(42);
    engine.run(pods, &mut topsis, &mut default)
}

fn assert_close(what: &str, got: f64, want: f64) {
    let tol = 1e-9 * want.abs().max(1.0);
    assert!(
        (got - want).abs() <= tol,
        "{what}: got {got}, golden {want} (tol {tol})"
    );
}

#[test]
fn golden_trace_matches_checked_in_expectations() {
    let result = replay();
    assert!(
        result.unschedulable.is_empty(),
        "golden trace must fully complete: {:?}",
        result.unschedulable
    );

    let expected = Json::parse(
        &std::fs::read_to_string(data_path("golden_trace.expected.json"))
            .expect("committed golden expectations"),
    )
    .expect("parse golden expectations");

    let by_pod: HashMap<u64, &greenpod::simulation::PodRecord> =
        result.records.iter().map(|r| (r.pod, r)).collect();

    let pods = expected
        .get("pods")
        .and_then(Json::as_arr)
        .expect("`pods` array");
    assert_eq!(by_pod.len(), pods.len(), "pod count drifted");

    for e in pods {
        let id = e.get("pod").and_then(Json::as_u64).expect("pod id");
        let rec = by_pod
            .get(&id)
            .unwrap_or_else(|| panic!("pod {id} missing from replay"));
        let want_node = e.get("node").and_then(Json::as_usize).unwrap();
        assert_eq!(
            rec.node, want_node,
            "pod {id}: placed on node {} but golden says {want_node}",
            rec.node
        );
        assert_eq!(
            rec.class.label_lower(),
            e.req_str("class").unwrap(),
            "pod {id} class drifted"
        );
        let want_attempts =
            e.get("attempts").and_then(Json::as_u64).unwrap() as u32;
        assert_eq!(rec.attempts, want_attempts, "pod {id} attempts");
        assert_close(
            &format!("pod {id} arrival_s"),
            rec.arrival_s,
            e.req_f64("arrival_s").unwrap(),
        );
        assert_close(
            &format!("pod {id} start_s"),
            rec.start_s,
            e.req_f64("start_s").unwrap(),
        );
        assert_close(
            &format!("pod {id} finish_s"),
            rec.finish_s,
            e.req_f64("finish_s").unwrap(),
        );
        assert_close(
            &format!("pod {id} wait_s"),
            rec.wait_s,
            e.req_f64("wait_s").unwrap(),
        );
        assert_close(
            &format!("pod {id} joules"),
            rec.joules,
            e.req_f64("joules").unwrap(),
        );
    }

    assert_close(
        "makespan_s",
        result.makespan_s,
        expected.req_f64("makespan_s").unwrap(),
    );
    assert_close(
        "total_kj",
        result.meter.total_kj(SchedulerKind::Topsis),
        expected.req_f64("total_kj").unwrap(),
    );

    // The golden scenario must actually exercise queueing: some pods
    // wait and retry.
    let queued = result.records.iter().filter(|r| r.wait_s > 0.0).count();
    assert!(queued > 0, "golden trace exercises no queueing");
    assert!(result.records.iter().any(|r| r.attempts > 1));
}

#[test]
fn golden_trace_replay_is_deterministic() {
    let a = replay();
    let b = replay();
    assert_eq!(a.records.len(), b.records.len());
    for (x, y) in a.records.iter().zip(&b.records) {
        assert_eq!(x.pod, y.pod);
        assert_eq!(x.node, y.node);
        assert_eq!(x.start_s, y.start_s);
        assert_eq!(x.finish_s, y.finish_s);
        assert_eq!(x.wait_s, y.wait_s);
        assert_eq!(x.joules, y.joules);
        assert_eq!(x.attempts, y.attempts);
    }
    assert_eq!(a.makespan_s, b.makespan_s);
}
