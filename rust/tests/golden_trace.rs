//! Deterministic golden-trace regression: replay the committed arrival
//! trace (`tests/data/golden_trace.jsonl`) through the discrete-event
//! engine and assert placements, queue waits, attempt counts and
//! energy against the checked-in expectations
//! (`tests/data/golden_trace.expected.json`), then replay the same
//! trace under the queue-driven threshold autoscaler against
//! `tests/data/golden_trace_autoscaled.expected.json` (scaling
//! actions, autoscaled placements, idle energy, node counts).
//!
//! The expectations are produced by an *independent oracle* — a Python
//! mirror of the engine's arithmetic
//! (`python/tools/make_golden_trace.py`) — so these tests pin both the
//! engine's determinism and its numerical semantics. Placements,
//! attempt counts and scaling actions must match exactly; times and
//! joules to 1e-9 relative (the two implementations share IEEE-754
//! doubles but may round intermediate sums differently).

use std::collections::HashMap;

use greenpod::autoscaler::{
    AutoscalerPolicy, CarbonWindowConfig, ThresholdConfig,
};
use greenpod::config::{Config, SchedulerKind, WeightingScheme};
use greenpod::energy::{grams_co2_per_joule, CarbonSignal};
use greenpod::experiments::phase_shifted_diurnal;
use greenpod::federation::{
    CarbonGreedy, FederationEngine, FederationParams, FederationResult,
    RegionSchedulers, RegionSpec,
};
use greenpod::framework::{BuildOptions, FrameworkScheduler, ProfileRegistry};
use greenpod::simulation::{RunResult, SimulationEngine, SimulationParams};
use greenpod::util::json::Json;
use greenpod::workload::{ArrivalTrace, WorkloadExecutor};

fn data_path(name: &str) -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests/data")
        .join(name)
}

/// The autoscaled fixture's policy — mirrored by `GOLDEN_POLICY` in
/// `python/tools/make_golden_trace.py`.
fn golden_policy(cfg: &Config) -> ThresholdConfig {
    ThresholdConfig {
        scale_out_pending: 2,
        scale_out_wait_p95_s: f64::INFINITY,
        provision_delay_s: 5.0,
        cooldown_s: 2.0,
        idle_scale_in_s: 10.0,
        min_nodes: 7,
        max_nodes: 10,
        template: ThresholdConfig::edge_template(&cfg.cluster),
        carbon: None,
    }
}

/// The carbon fixture's signal — mirrored by `GOLDEN_CARBON_SIGNAL` in
/// `python/tools/make_golden_trace.py`: one 120 s diurnal cycle around
/// the eGRID scalar (clean at 0 and 120 s, dirtiest at 60 s).
fn golden_carbon_signal(cfg: &Config) -> CarbonSignal {
    CarbonSignal::diurnal(grams_co2_per_joule(&cfg.energy), 0.5, 120.0, 12)
        .expect("valid diurnal parameters")
}

/// Replay the committed trace with the golden configuration: paper
/// defaults, all pods TOPSIS-owned, energy-centric profile, seed 42 —
/// optionally under a threshold policy and a carbon-intensity signal.
fn replay_with(
    policy: Option<ThresholdConfig>,
    carbon: Option<CarbonSignal>,
) -> RunResult {
    let cfg = Config::paper_default();
    let executor = WorkloadExecutor::analytic();
    let text = std::fs::read_to_string(data_path("golden_trace.jsonl"))
        .expect("committed golden trace");
    let trace = ArrivalTrace::from_jsonl(&text).expect("parse golden trace");
    let pods = trace.to_pods(SchedulerKind::Topsis);
    let mut params = SimulationParams::with_beta_and_seed(
        cfg.experiment.contention_beta,
        42,
    );
    if let Some(policy) = policy {
        params = params.with_autoscaler(AutoscalerPolicy::Threshold(policy));
    }
    if let Some(carbon) = carbon {
        params = params.with_carbon(carbon);
    }
    let engine = SimulationEngine::new(&cfg, params, &executor);
    let (mut topsis, mut default) = golden_schedulers(&cfg, &executor);
    engine.run(pods, &mut topsis, &mut default)
}

/// The golden scheduler pair: the framework `greenpod` / `default-k8s`
/// profiles (pinned bit-identical to the retired monoliths before
/// their removal), energy-centric, seed 42, estimator calibrated from
/// the executor — exactly what the Python oracle mirrors.
fn golden_schedulers(
    cfg: &Config,
    executor: &WorkloadExecutor,
) -> (FrameworkScheduler, FrameworkScheduler) {
    let registry = ProfileRegistry::new(cfg);
    let opts = BuildOptions::new(cfg, WeightingScheme::EnergyCentric)
        .with_seed(42)
        .with_executor(executor);
    (
        registry.build("greenpod", &opts).expect("built-in"),
        registry.build("default-k8s", &opts).expect("built-in"),
    )
}

fn replay() -> RunResult {
    replay_with(None, None)
}

fn assert_close(what: &str, got: f64, want: f64) {
    let tol = 1e-9 * want.abs().max(1.0);
    assert!(
        (got - want).abs() <= tol,
        "{what}: got {got}, golden {want} (tol {tol})"
    );
}

/// Assert the per-pod records, makespan and TOPSIS energy total of
/// `result` against one expected-JSON fixture.
fn assert_matches_fixture(result: &RunResult, expected: &Json) {
    let by_pod: HashMap<u64, &greenpod::simulation::PodRecord> =
        result.records.iter().map(|r| (r.pod, r)).collect();

    let pods = expected
        .get("pods")
        .and_then(Json::as_arr)
        .expect("`pods` array");
    assert_eq!(by_pod.len(), pods.len(), "pod count drifted");

    for e in pods {
        let id = e.get("pod").and_then(Json::as_u64).expect("pod id");
        let rec = by_pod
            .get(&id)
            .unwrap_or_else(|| panic!("pod {id} missing from replay"));
        let want_node = e.get("node").and_then(Json::as_usize).unwrap();
        assert_eq!(
            rec.node, want_node,
            "pod {id}: placed on node {} but golden says {want_node}",
            rec.node
        );
        assert_eq!(
            rec.class.label_lower(),
            e.req_str("class").unwrap(),
            "pod {id} class drifted"
        );
        let want_attempts =
            e.get("attempts").and_then(Json::as_u64).unwrap() as u32;
        assert_eq!(rec.attempts, want_attempts, "pod {id} attempts");
        assert_close(
            &format!("pod {id} arrival_s"),
            rec.arrival_s,
            e.req_f64("arrival_s").unwrap(),
        );
        assert_close(
            &format!("pod {id} start_s"),
            rec.start_s,
            e.req_f64("start_s").unwrap(),
        );
        assert_close(
            &format!("pod {id} finish_s"),
            rec.finish_s,
            e.req_f64("finish_s").unwrap(),
        );
        assert_close(
            &format!("pod {id} wait_s"),
            rec.wait_s,
            e.req_f64("wait_s").unwrap(),
        );
        assert_close(
            &format!("pod {id} joules"),
            rec.joules,
            e.req_f64("joules").unwrap(),
        );
    }

    assert_close(
        "makespan_s",
        result.makespan_s,
        expected.req_f64("makespan_s").unwrap(),
    );
    assert_close(
        "total_kj",
        result.meter.total_kj(SchedulerKind::Topsis),
        expected.req_f64("total_kj").unwrap(),
    );
}

fn load_fixture(name: &str) -> Json {
    Json::parse(
        &std::fs::read_to_string(data_path(name))
            .expect("committed golden expectations"),
    )
    .expect("parse golden expectations")
}

#[test]
fn golden_trace_matches_checked_in_expectations() {
    let result = replay();
    assert!(
        result.unschedulable.is_empty(),
        "golden trace must fully complete: {:?}",
        result.unschedulable
    );

    let expected = load_fixture("golden_trace.expected.json");
    assert_matches_fixture(&result, &expected);

    // The golden scenario must actually exercise queueing: some pods
    // wait and retry.
    let queued = result.records.iter().filter(|r| r.wait_s > 0.0).count();
    assert!(queued > 0, "golden trace exercises no queueing");
    assert!(result.records.iter().any(|r| r.attempts > 1));
    // No autoscaler: no scaling actions, flat node timeline.
    assert!(result.scaling.is_empty());
    assert!(result
        .node_timeline
        .iter()
        .all(|s| s.ready_nodes == 7 && s.total_nodes == 7));
}

/// Assert one fixture's scaling actions: exact kinds, nodes and order;
/// times to 1e-9.
fn assert_scaling_matches(result: &RunResult, expected: &Json) {
    let want_scaling = expected
        .get("scaling")
        .and_then(Json::as_arr)
        .expect("`scaling` array");
    assert_eq!(
        result.scaling.len(),
        want_scaling.len(),
        "scaling action count drifted: {:?}",
        result.scaling
    );
    for (i, (got, want)) in
        result.scaling.iter().zip(want_scaling).enumerate()
    {
        assert_eq!(got.kind, want.req_str("kind").unwrap(), "action {i}");
        assert_eq!(
            got.node,
            want.get("node").and_then(Json::as_usize).unwrap(),
            "action {i} node"
        );
        assert_close(
            &format!("action {i} at_s"),
            got.at_s,
            want.req_f64("at_s").unwrap(),
        );
        assert_close(
            &format!("action {i} effective_at_s"),
            got.effective_at_s,
            want.req_f64("effective_at_s").unwrap(),
        );
    }
}

#[test]
fn autoscaled_golden_trace_matches_checked_in_expectations() {
    let cfg = Config::paper_default();
    let result = replay_with(Some(golden_policy(&cfg)), None);
    assert!(
        result.unschedulable.is_empty(),
        "autoscaled golden trace must fully complete: {:?}",
        result.unschedulable
    );

    let expected = load_fixture("golden_trace_autoscaled.expected.json");
    assert_matches_fixture(&result, &expected);
    assert_scaling_matches(&result, &expected);

    // Idle-energy attribution and the node-count envelope.
    assert_close(
        "idle_kj",
        result.idle_kj(),
        expected.req_f64("idle_kj").unwrap(),
    );
    assert_eq!(
        result.peak_ready_nodes(),
        expected
            .get("peak_ready_nodes")
            .and_then(Json::as_usize)
            .unwrap()
    );
    let last = result.node_timeline.last().expect("timeline sampled");
    assert_eq!(
        last.ready_nodes,
        expected
            .get("final_ready_nodes")
            .and_then(Json::as_usize)
            .unwrap()
    );
    assert_eq!(
        last.total_nodes,
        expected
            .get("final_total_nodes")
            .and_then(Json::as_usize)
            .unwrap()
    );

    // The scenario exercises the full lifecycle: provisioned capacity
    // was actually used, and the cluster returned to base size.
    assert!(result.records.iter().any(|r| r.node >= 7));
    assert!(result.scaling.iter().any(|s| s.kind == "scale-out"));
    assert!(result.scaling.iter().any(|s| s.kind == "scale-in"));
}

#[test]
fn carbon_golden_trace_matches_checked_in_expectations() {
    // Same trace and threshold policy as the autoscaled fixture, under
    // a diurnal intensity signal with carbon scale-down windows (p50
    // dirty threshold, 0.25 idle tightening, 6 s deferral bound).
    let cfg = Config::paper_default();
    let signal = golden_carbon_signal(&cfg);
    let policy = golden_policy(&cfg).with_carbon_window(
        CarbonWindowConfig::at_percentile(signal.clone(), 0.5, 0.25, 6.0)
            .expect("valid window parameters"),
    );
    let result = replay_with(Some(policy), Some(signal.clone()));
    assert!(
        result.unschedulable.is_empty(),
        "carbon golden trace must fully complete: {:?}",
        result.unschedulable
    );

    let expected = load_fixture("golden_trace_carbon.expected.json");
    assert_matches_fixture(&result, &expected);
    assert_scaling_matches(&result, &expected);

    // The CO₂ ledger: per-pod grams and the run totals against the
    // oracle's signal-integrated arithmetic.
    let grams_by_pod: HashMap<u64, f64> = result
        .meter
        .records()
        .iter()
        .map(|r| (r.pod, r.grams))
        .collect();
    for e in expected.get("pods").and_then(Json::as_arr).unwrap() {
        let id = e.get("pod").and_then(Json::as_u64).expect("pod id");
        assert_close(
            &format!("pod {id} grams"),
            grams_by_pod[&id],
            e.req_f64("grams").unwrap(),
        );
    }
    assert_close(
        "total_co2_g",
        result.meter.total_co2_g(SchedulerKind::Topsis),
        expected.req_f64("total_co2_g").unwrap(),
    );
    assert_close(
        "idle_co2_g",
        result.meter.idle_co2_g(),
        expected.req_f64("idle_co2_g").unwrap(),
    );
    assert_close(
        "idle_kj",
        result.idle_kj(),
        expected.req_f64("idle_kj").unwrap(),
    );

    // The window actually engaged: the dirty-phase idle tightening
    // scales node 7 in earlier than the carbon-blind autoscaled replay
    // (49.5 s vs 57 s), which is exactly the idle-CO₂ saving.
    let blind = replay_with(Some(golden_policy(&cfg)), Some(signal));
    let at = |r: &RunResult| {
        r.scaling
            .iter()
            .find(|s| s.kind == "scale-in")
            .expect("scale-in")
            .at_s
    };
    assert!(
        at(&result) < at(&blind),
        "windowed scale-in {} !< blind {}",
        at(&result),
        at(&blind)
    );
    assert!(result.meter.idle_co2_g() < blind.meter.idle_co2_g());
    // Placements are untouched by the window in this scenario: the
    // saving is pure idle-floor carbon.
    assert_eq!(result.records.len(), blind.records.len());
    for (a, b) in result.records.iter().zip(&blind.records) {
        assert_eq!(a.pod, b.pod);
        assert_eq!(a.node, b.node);
        assert_eq!(a.joules, b.joules);
    }
}

/// The federation fixture's regions — mirrored by
/// `golden_federation_regions` in `python/tools/make_golden_trace.py`:
/// "east" under the golden diurnal signal (phase 0), "west" shifted by
/// half a period (dirty when east is clean), no autoscaler.
fn golden_federation_specs(cfg: &Config) -> Vec<RegionSpec> {
    let base = grams_co2_per_joule(&cfg.energy);
    vec![
        RegionSpec::new("east", cfg.clone())
            .with_carbon(golden_carbon_signal(cfg)),
        RegionSpec::new("west", cfg.clone())
            .with_carbon(phase_shifted_diurnal(base, 0.5, 120.0, 12, 0.5)),
    ]
}

/// The golden scheduler pair of one federation region — the same
/// build as `replay_with`'s single-cluster schedulers.
fn golden_region_schedulers(
    cfg: &Config,
    executor: &WorkloadExecutor,
) -> RegionSchedulers {
    let (topsis, default) = golden_schedulers(cfg, executor);
    RegionSchedulers {
        topsis: Box::new(topsis),
        default: Box::new(default),
    }
}

/// Replay the committed trace through the 2-region federation with
/// carbon-greedy dispatch.
fn replay_federation() -> FederationResult {
    let cfg = Config::paper_default();
    let executor = WorkloadExecutor::analytic();
    let text = std::fs::read_to_string(data_path("golden_trace.jsonl"))
        .expect("committed golden trace");
    let trace = ArrivalTrace::from_jsonl(&text).expect("parse golden trace");
    let pods = trace.to_pods(SchedulerKind::Topsis);
    let specs = golden_federation_specs(&cfg);
    let engine = FederationEngine::new(
        &specs,
        FederationParams::with_beta_and_seed(
            cfg.experiment.contention_beta,
            42,
        ),
        &executor,
    );
    let mut scheds: Vec<RegionSchedulers> = specs
        .iter()
        .map(|_| golden_region_schedulers(&cfg, &executor))
        .collect();
    let mut dispatcher = CarbonGreedy::new();
    engine.run(pods, &mut dispatcher, &mut scheds)
}

#[test]
fn federation_golden_trace_matches_checked_in_expectations() {
    let result = replay_federation();
    assert_eq!(result.unschedulable(), 0);

    let expected = load_fixture("golden_trace_federation.expected.json");

    // Per-pod: region assignment, placement, times, joules and grams.
    let mut by_pod: HashMap<
        u64,
        (&str, &greenpod::simulation::PodRecord, f64),
    > = HashMap::new();
    for reg in &result.regions {
        let grams: HashMap<u64, f64> = reg
            .run
            .meter
            .records()
            .iter()
            .map(|r| (r.pod, r.grams))
            .collect();
        for rec in &reg.run.records {
            by_pod.insert(rec.pod, (&reg.name, rec, grams[&rec.pod]));
        }
    }
    let pods = expected
        .get("pods")
        .and_then(Json::as_arr)
        .expect("`pods` array");
    assert_eq!(by_pod.len(), pods.len(), "pod count drifted");
    for e in pods {
        let id = e.get("pod").and_then(Json::as_u64).expect("pod id");
        let &(region, rec, grams) = by_pod
            .get(&id)
            .unwrap_or_else(|| panic!("pod {id} missing from replay"));
        assert_eq!(
            region,
            e.req_str("region").unwrap(),
            "pod {id} routed to the wrong region"
        );
        assert_eq!(
            rec.node,
            e.get("node").and_then(Json::as_usize).unwrap(),
            "pod {id} node"
        );
        assert_eq!(
            rec.attempts,
            e.get("attempts").and_then(Json::as_u64).unwrap() as u32,
            "pod {id} attempts"
        );
        assert_close(
            &format!("pod {id} start_s"),
            rec.start_s,
            e.req_f64("start_s").unwrap(),
        );
        assert_close(
            &format!("pod {id} finish_s"),
            rec.finish_s,
            e.req_f64("finish_s").unwrap(),
        );
        assert_close(
            &format!("pod {id} wait_s"),
            rec.wait_s,
            e.req_f64("wait_s").unwrap(),
        );
        assert_close(
            &format!("pod {id} joules"),
            rec.joules,
            e.req_f64("joules").unwrap(),
        );
        assert_close(
            &format!("pod {id} grams"),
            grams,
            e.req_f64("grams").unwrap(),
        );
    }
    assert_close(
        "makespan_s",
        result.makespan_s(),
        expected.req_f64("makespan_s").unwrap(),
    );

    // Per-region roll-ups: energy and the signal-integrated ledgers.
    let regions = expected
        .get("regions")
        .and_then(Json::as_arr)
        .expect("`regions` array");
    assert_eq!(result.regions.len(), regions.len());
    for (got, want) in result.regions.iter().zip(regions) {
        let name = want.req_str("name").unwrap();
        assert_eq!(got.name, name);
        assert_eq!(
            got.run.records.len(),
            want.get("pods").and_then(Json::as_usize).unwrap(),
            "region {name} pod count"
        );
        assert_close(
            &format!("region {name} makespan_s"),
            got.run.makespan_s,
            want.req_f64("makespan_s").unwrap(),
        );
        assert_close(
            &format!("region {name} total_kj"),
            got.run.meter.total_kj(SchedulerKind::Topsis),
            want.req_f64("total_kj").unwrap(),
        );
        assert_close(
            &format!("region {name} idle_kj"),
            got.run.idle_kj(),
            want.req_f64("idle_kj").unwrap(),
        );
        assert_close(
            &format!("region {name} total_co2_g"),
            got.run.meter.total_co2_g(SchedulerKind::Topsis),
            want.req_f64("total_co2_g").unwrap(),
        );
        assert_close(
            &format!("region {name} idle_co2_g"),
            got.run.meter.idle_co2_g(),
            want.req_f64("idle_co2_g").unwrap(),
        );
    }

    // The scenario actually exercises the federation: both regions ran
    // work (carbon-greedy spills to west when east fills), and every
    // assignment points at the region that completed the pod.
    for reg in &result.regions {
        assert!(!reg.run.records.is_empty(), "{} ran nothing", reg.name);
    }
    assert_eq!(
        result.assignments.len(),
        result.completed(),
        "every admitted pod dispatched exactly once"
    );
}

#[test]
fn single_region_federation_is_bit_identical_to_plain_engine() {
    // Post-collapse delegation differential: `SimulationEngine::run`
    // is now a thin wrapper that builds a 1-region federation, so this
    // pins the *wrapper's* SimulationParams→RegionSpec mapping against
    // a hand-assembled federation of the same scenario — one region
    // under the golden carbon signal *and* the golden threshold
    // policy, bit-for-bit: records, events, scaling, timeline, energy
    // and grams.
    let cfg = Config::paper_default();
    let executor = WorkloadExecutor::analytic();
    let signal = golden_carbon_signal(&cfg);
    let plain =
        replay_with(Some(golden_policy(&cfg)), Some(signal.clone()));

    let text = std::fs::read_to_string(data_path("golden_trace.jsonl"))
        .expect("committed golden trace");
    let trace = ArrivalTrace::from_jsonl(&text).expect("parse golden trace");
    let pods = trace.to_pods(SchedulerKind::Topsis);
    let specs = vec![RegionSpec::new("solo", cfg.clone())
        .with_carbon(signal)
        .with_autoscaler(AutoscalerPolicy::Threshold(golden_policy(&cfg)))];
    let engine = FederationEngine::new(
        &specs,
        FederationParams::with_beta_and_seed(
            cfg.experiment.contention_beta,
            42,
        ),
        &executor,
    );
    let mut scheds = vec![golden_region_schedulers(&cfg, &executor)];
    let mut dispatcher = CarbonGreedy::new();
    let fed = engine.run(pods, &mut dispatcher, &mut scheds);

    assert_eq!(fed.regions.len(), 1);
    let run = &fed.regions[0].run;
    assert_eq!(plain.records.len(), run.records.len());
    for (x, y) in plain.records.iter().zip(&run.records) {
        assert_eq!(x.pod, y.pod);
        assert_eq!(x.node, y.node);
        assert_eq!(x.start_s.to_bits(), y.start_s.to_bits());
        assert_eq!(x.finish_s.to_bits(), y.finish_s.to_bits());
        assert_eq!(x.joules.to_bits(), y.joules.to_bits());
        assert_eq!(x.attempts, y.attempts);
    }
    assert_eq!(plain.events, run.events);
    assert_eq!(plain.scaling, run.scaling);
    assert_eq!(plain.node_timeline, run.node_timeline);
    assert_eq!(plain.makespan_s.to_bits(), run.makespan_s.to_bits());
    assert_eq!(
        plain.meter.total_co2_g(SchedulerKind::Topsis).to_bits(),
        run.meter.total_co2_g(SchedulerKind::Topsis).to_bits()
    );
    assert_eq!(
        plain.meter.idle_co2_g().to_bits(),
        run.meter.idle_co2_g().to_bits()
    );
    assert_eq!(plain.idle_kj().to_bits(), run.idle_kj().to_bits());
}

#[test]
fn golden_trace_replay_is_deterministic() {
    let a = replay();
    let b = replay();
    assert_eq!(a.records.len(), b.records.len());
    for (x, y) in a.records.iter().zip(&b.records) {
        assert_eq!(x.pod, y.pod);
        assert_eq!(x.node, y.node);
        assert_eq!(x.start_s, y.start_s);
        assert_eq!(x.finish_s, y.finish_s);
        assert_eq!(x.wait_s, y.wait_s);
        assert_eq!(x.joules, y.joules);
        assert_eq!(x.attempts, y.attempts);
    }
    assert_eq!(a.makespan_s, b.makespan_s);
}

/// The trace-replay CI fixture (`tests/data/trace_10k_slice.jsonl`) is
/// a seeded 1-in-100-per-class slice of the ~1.05M-pod synthetic trace
/// `greenpod trace replay --full` streams, generated by the Python RNG
/// mirror (`python/tools/make_trace_fixture.py`). Regenerating the
/// same slice in-process and comparing byte-for-byte pins three
/// things at once: the SynthTrace/DownSampler RNG streams, the
/// Json compact writer, and the mirror itself — none can drift
/// without this failing.
#[test]
fn trace_fixture_in_sync_with_generators() {
    use greenpod::trace::{DownSampler, SynthTrace, WorkloadTrace};
    use greenpod::workload::TraceSpec;

    let config = Config::paper_default();
    // The fixture's trace seed is the default experiment seed, so the
    // slice is literally a sample of the `--full` run.
    assert_eq!(config.experiment.seed, 20250710);
    let mut synth = DownSampler::new(
        SynthTrace::poisson(
            TraceSpec::surf_lisa(100.0, 10_500.0),
            config.experiment.seed,
        ),
        100,
        7,
    );

    let path = data_path("trace_10k_slice.jsonl");
    let text = std::fs::read_to_string(&path).expect("fixture present");
    let mut fixture_lines =
        text.lines().filter(|l| !l.starts_with('#')).enumerate();
    let mut n = 0usize;
    while let Some(e) = synth.next_entry().expect("synth cannot fail") {
        let (i, line) = fixture_lines.next().unwrap_or_else(|| {
            panic!("fixture ends at entry {n}; generator has more")
        });
        assert_eq!(
            line,
            e.to_json().to_string(),
            "fixture line {} diverges from the generators — regenerate \
             with python3 python/tools/make_trace_fixture.py",
            i + 1
        );
        n += 1;
    }
    assert_eq!(
        fixture_lines.next(),
        None,
        "fixture has more lines than the generators produce"
    );
    assert_eq!(n, 10_509, "fixture entry count");
}

/// Replay the sliced fixture end to end through the streaming reader
/// and the federation engine on the default (paper Table I) cluster —
/// which is exactly `ClusterConfig::scaled(80).downsampled(100)`, the
/// capacity-side companion of the fixture's 1-in-100 pod slice.
#[test]
fn trace_fixture_replays_on_default_cluster() {
    use greenpod::config::ClusterConfig;
    use greenpod::experiments::{run_trace_replay, ExperimentContext};
    use greenpod::trace::{ChunkedTraceReader, TraceOwnership};

    let config = Config::paper_default();
    assert_eq!(
        ClusterConfig::scaled(80).downsampled(100),
        config.cluster,
        "fixture/capacity pairing drifted: scaled(80)/100 != default"
    );

    let path = data_path("trace_10k_slice.jsonl");
    let mut reader =
        ChunkedTraceReader::open(path.to_str().expect("utf-8 path"), 4096)
            .expect("fixture opens");
    let ctx = ExperimentContext::new(config);
    let s = run_trace_replay(
        &ctx,
        &mut reader,
        TraceOwnership::RoundRobin,
        Vec::new(),
    )
    .expect("fixture replays");
    assert_eq!(s.pods, 10_509);
    assert_eq!(s.completed + s.unschedulable, s.pods);
    assert!(s.completed > 0, "nothing completed");
    // The chunked reader never buffered more than one chunk.
    assert!(
        s.peak_buffered <= 4096,
        "peak buffered {} exceeds the chunk",
        s.peak_buffered
    );
    assert!(s.peak_live_pods < s.pods, "streaming held the whole trace");
    assert!(s.total_kj.is_finite() && s.total_kj > 0.0);
    assert!(s.makespan_s >= 10_400.0, "trace spans ~10.5k seconds");
}
