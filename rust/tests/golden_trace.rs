//! Deterministic golden-trace regression: replay the committed arrival
//! trace (`tests/data/golden_trace.jsonl`) through the discrete-event
//! engine and assert placements, queue waits, attempt counts and
//! energy against the checked-in expectations
//! (`tests/data/golden_trace.expected.json`), then replay the same
//! trace under the queue-driven threshold autoscaler against
//! `tests/data/golden_trace_autoscaled.expected.json` (scaling
//! actions, autoscaled placements, idle energy, node counts).
//!
//! The expectations are produced by an *independent oracle* — a Python
//! mirror of the engine's arithmetic
//! (`python/tools/make_golden_trace.py`) — so these tests pin both the
//! engine's determinism and its numerical semantics. Placements,
//! attempt counts and scaling actions must match exactly; times and
//! joules to 1e-9 relative (the two implementations share IEEE-754
//! doubles but may round intermediate sums differently).

use std::collections::HashMap;

use greenpod::autoscaler::{AutoscalerPolicy, ThresholdConfig};
use greenpod::config::{Config, SchedulerKind, WeightingScheme};
use greenpod::scheduler::{DefaultK8sScheduler, Estimator, GreenPodScheduler};
use greenpod::simulation::{RunResult, SimulationEngine, SimulationParams};
use greenpod::util::json::Json;
use greenpod::workload::{ArrivalTrace, WorkloadExecutor};

fn data_path(name: &str) -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests/data")
        .join(name)
}

/// The autoscaled fixture's policy — mirrored by `GOLDEN_POLICY` in
/// `python/tools/make_golden_trace.py`.
fn golden_policy(cfg: &Config) -> ThresholdConfig {
    ThresholdConfig {
        scale_out_pending: 2,
        scale_out_wait_p95_s: f64::INFINITY,
        provision_delay_s: 5.0,
        cooldown_s: 2.0,
        idle_scale_in_s: 10.0,
        min_nodes: 7,
        max_nodes: 10,
        template: ThresholdConfig::edge_template(&cfg.cluster),
    }
}

/// Replay the committed trace with the golden configuration: paper
/// defaults, all pods TOPSIS-owned, energy-centric profile, seed 42 —
/// optionally under the autoscaled fixture's threshold policy.
fn replay_with(autoscaled: bool) -> RunResult {
    let cfg = Config::paper_default();
    let executor = WorkloadExecutor::analytic();
    let text = std::fs::read_to_string(data_path("golden_trace.jsonl"))
        .expect("committed golden trace");
    let trace = ArrivalTrace::from_jsonl(&text).expect("parse golden trace");
    let pods = trace.to_pods(SchedulerKind::Topsis);
    let mut params = SimulationParams::with_beta_and_seed(
        cfg.experiment.contention_beta,
        42,
    );
    if autoscaled {
        params = params
            .with_autoscaler(AutoscalerPolicy::Threshold(golden_policy(&cfg)));
    }
    let engine = SimulationEngine::new(&cfg, params, &executor);
    let mut topsis = GreenPodScheduler::new(
        Estimator::new(
            cfg.energy.clone(),
            executor.light_epoch_secs(),
            cfg.experiment.contention_beta,
        ),
        WeightingScheme::EnergyCentric,
    );
    let mut default = DefaultK8sScheduler::new(42);
    engine.run(pods, &mut topsis, &mut default)
}

fn replay() -> RunResult {
    replay_with(false)
}

fn assert_close(what: &str, got: f64, want: f64) {
    let tol = 1e-9 * want.abs().max(1.0);
    assert!(
        (got - want).abs() <= tol,
        "{what}: got {got}, golden {want} (tol {tol})"
    );
}

/// Assert the per-pod records, makespan and TOPSIS energy total of
/// `result` against one expected-JSON fixture.
fn assert_matches_fixture(result: &RunResult, expected: &Json) {
    let by_pod: HashMap<u64, &greenpod::simulation::PodRecord> =
        result.records.iter().map(|r| (r.pod, r)).collect();

    let pods = expected
        .get("pods")
        .and_then(Json::as_arr)
        .expect("`pods` array");
    assert_eq!(by_pod.len(), pods.len(), "pod count drifted");

    for e in pods {
        let id = e.get("pod").and_then(Json::as_u64).expect("pod id");
        let rec = by_pod
            .get(&id)
            .unwrap_or_else(|| panic!("pod {id} missing from replay"));
        let want_node = e.get("node").and_then(Json::as_usize).unwrap();
        assert_eq!(
            rec.node, want_node,
            "pod {id}: placed on node {} but golden says {want_node}",
            rec.node
        );
        assert_eq!(
            rec.class.label_lower(),
            e.req_str("class").unwrap(),
            "pod {id} class drifted"
        );
        let want_attempts =
            e.get("attempts").and_then(Json::as_u64).unwrap() as u32;
        assert_eq!(rec.attempts, want_attempts, "pod {id} attempts");
        assert_close(
            &format!("pod {id} arrival_s"),
            rec.arrival_s,
            e.req_f64("arrival_s").unwrap(),
        );
        assert_close(
            &format!("pod {id} start_s"),
            rec.start_s,
            e.req_f64("start_s").unwrap(),
        );
        assert_close(
            &format!("pod {id} finish_s"),
            rec.finish_s,
            e.req_f64("finish_s").unwrap(),
        );
        assert_close(
            &format!("pod {id} wait_s"),
            rec.wait_s,
            e.req_f64("wait_s").unwrap(),
        );
        assert_close(
            &format!("pod {id} joules"),
            rec.joules,
            e.req_f64("joules").unwrap(),
        );
    }

    assert_close(
        "makespan_s",
        result.makespan_s,
        expected.req_f64("makespan_s").unwrap(),
    );
    assert_close(
        "total_kj",
        result.meter.total_kj(SchedulerKind::Topsis),
        expected.req_f64("total_kj").unwrap(),
    );
}

fn load_fixture(name: &str) -> Json {
    Json::parse(
        &std::fs::read_to_string(data_path(name))
            .expect("committed golden expectations"),
    )
    .expect("parse golden expectations")
}

#[test]
fn golden_trace_matches_checked_in_expectations() {
    let result = replay();
    assert!(
        result.unschedulable.is_empty(),
        "golden trace must fully complete: {:?}",
        result.unschedulable
    );

    let expected = load_fixture("golden_trace.expected.json");
    assert_matches_fixture(&result, &expected);

    // The golden scenario must actually exercise queueing: some pods
    // wait and retry.
    let queued = result.records.iter().filter(|r| r.wait_s > 0.0).count();
    assert!(queued > 0, "golden trace exercises no queueing");
    assert!(result.records.iter().any(|r| r.attempts > 1));
    // No autoscaler: no scaling actions, flat node timeline.
    assert!(result.scaling.is_empty());
    assert!(result
        .node_timeline
        .iter()
        .all(|s| s.ready_nodes == 7 && s.total_nodes == 7));
}

#[test]
fn autoscaled_golden_trace_matches_checked_in_expectations() {
    let result = replay_with(true);
    assert!(
        result.unschedulable.is_empty(),
        "autoscaled golden trace must fully complete: {:?}",
        result.unschedulable
    );

    let expected = load_fixture("golden_trace_autoscaled.expected.json");
    assert_matches_fixture(&result, &expected);

    // Scaling actions: exact kinds, nodes and order; times to 1e-9.
    let want_scaling = expected
        .get("scaling")
        .and_then(Json::as_arr)
        .expect("`scaling` array");
    assert_eq!(
        result.scaling.len(),
        want_scaling.len(),
        "scaling action count drifted: {:?}",
        result.scaling
    );
    for (i, (got, want)) in
        result.scaling.iter().zip(want_scaling).enumerate()
    {
        assert_eq!(got.kind, want.req_str("kind").unwrap(), "action {i}");
        assert_eq!(
            got.node,
            want.get("node").and_then(Json::as_usize).unwrap(),
            "action {i} node"
        );
        assert_close(
            &format!("action {i} at_s"),
            got.at_s,
            want.req_f64("at_s").unwrap(),
        );
        assert_close(
            &format!("action {i} effective_at_s"),
            got.effective_at_s,
            want.req_f64("effective_at_s").unwrap(),
        );
    }

    // Idle-energy attribution and the node-count envelope.
    assert_close(
        "idle_kj",
        result.idle_kj(),
        expected.req_f64("idle_kj").unwrap(),
    );
    assert_eq!(
        result.peak_ready_nodes(),
        expected
            .get("peak_ready_nodes")
            .and_then(Json::as_usize)
            .unwrap()
    );
    let last = result.node_timeline.last().expect("timeline sampled");
    assert_eq!(
        last.ready_nodes,
        expected
            .get("final_ready_nodes")
            .and_then(Json::as_usize)
            .unwrap()
    );
    assert_eq!(
        last.total_nodes,
        expected
            .get("final_total_nodes")
            .and_then(Json::as_usize)
            .unwrap()
    );

    // The scenario exercises the full lifecycle: provisioned capacity
    // was actually used, and the cluster returned to base size.
    assert!(result.records.iter().any(|r| r.node >= 7));
    assert!(result.scaling.iter().any(|s| s.kind == "scale-out"));
    assert!(result.scaling.iter().any(|s| s.kind == "scale-in"));
}

#[test]
fn golden_trace_replay_is_deterministic() {
    let a = replay();
    let b = replay();
    assert_eq!(a.records.len(), b.records.len());
    for (x, y) in a.records.iter().zip(&b.records) {
        assert_eq!(x.pod, y.pod);
        assert_eq!(x.node, y.node);
        assert_eq!(x.start_s, y.start_s);
        assert_eq!(x.finish_s, y.finish_s);
        assert_eq!(x.wait_s, y.wait_s);
        assert_eq!(x.joules, y.joules);
        assert_eq!(x.attempts, y.attempts);
    }
    assert_eq!(a.makespan_s, b.makespan_s);
}
