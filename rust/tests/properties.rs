//! Randomized property tests (seeded, deterministic) over the L3
//! invariants — the in-tree stand-in for proptest (DESIGN.md §1b).
//!
//! Each property runs a few hundred random cases from a fixed seed;
//! shrinkage is traded for printing the failing case's seed so it can
//! be replayed.

use greenpod::cluster::{ClusterState, Pod};
use greenpod::config::{
    ClusterConfig, CompetitionLevel, Config, ExperimentConfig,
    SchedulerKind, WeightingScheme,
};
use greenpod::mcda::{
    self, Criterion, DecisionProblem, Direction, McdaMethod,
};
use greenpod::scheduler::{
    DefaultK8sScheduler, Estimator, GreenPodScheduler, Scheduler,
};
use greenpod::util::rng::Rng;
use greenpod::workload::{generate_pods, WorkloadClass};

fn random_problem(rng: &mut Rng) -> DecisionProblem {
    let n = 1 + rng.below(40);
    let c = 1 + rng.below(7);
    let matrix: Vec<f64> =
        (0..n * c).map(|_| rng.range_f64(0.01, 100.0)).collect();
    let criteria: Vec<Criterion> = (0..c)
        .map(|_| {
            let w = rng.range_f64(0.01, 2.0);
            if rng.chance(0.5) {
                Criterion::benefit(w)
            } else {
                Criterion::cost(w)
            }
        })
        .collect();
    DecisionProblem::new(matrix, n, criteria)
}

#[test]
fn prop_topsis_closeness_in_unit_interval() {
    let mut rng = Rng::seed_from_u64(1);
    for case in 0..300 {
        let p = random_problem(&mut rng);
        for (i, s) in mcda::topsis_closeness(&p).iter().enumerate() {
            assert!(
                (-1e-9..=1.0 + 1e-9).contains(s),
                "case {case}: row {i} score {s}"
            );
            assert!(s.is_finite());
        }
    }
}

#[test]
fn prop_dominated_alternative_never_first() {
    // Build a problem, then append a row strictly dominated by row 0;
    // the dominated row must never outrank its dominator.
    let mut rng = Rng::seed_from_u64(2);
    for case in 0..200 {
        let mut p = random_problem(&mut rng);
        let c = p.c();
        let mut dominated = Vec::with_capacity(c);
        for col in 0..c {
            let v = p.at(0, col);
            let delta = rng.range_f64(0.1, 1.0);
            dominated.push(match p.criteria[col].direction {
                Direction::Benefit => (v - delta).max(0.001),
                Direction::Cost => v + delta,
            });
        }
        p.matrix.extend_from_slice(&dominated);
        p.n += 1;
        let scores = mcda::topsis_closeness(&p);
        assert!(
            scores[0] >= scores[p.n - 1] - 1e-9,
            "case {case}: dominated row scored {} > dominator {}",
            scores[p.n - 1],
            scores[0]
        );
    }
}

#[test]
fn prop_all_mcda_methods_rank_dominator_over_dominated() {
    let mut rng = Rng::seed_from_u64(3);
    for case in 0..100 {
        let mut p = random_problem(&mut rng);
        let c = p.c();
        let mut dominated = Vec::with_capacity(c);
        for col in 0..c {
            let v = p.at(0, col);
            dominated.push(match p.criteria[col].direction {
                Direction::Benefit => v * 0.5,
                Direction::Cost => v * 2.0 + 0.1,
            });
        }
        p.matrix.extend_from_slice(&dominated);
        p.n += 1;
        for method in McdaMethod::ALL {
            let scores = method.scores(&p);
            assert!(
                scores[0] >= scores[p.n - 1] - 1e-9,
                "case {case} {method:?}: dominated outranked dominator"
            );
        }
    }
}

#[test]
fn prop_topsis_scale_invariance() {
    // Multiplying any column by a positive constant leaves closeness
    // unchanged (vector normalization).
    let mut rng = Rng::seed_from_u64(4);
    for case in 0..200 {
        let p = random_problem(&mut rng);
        let col = rng.below(p.c());
        let k = rng.range_f64(0.1, 50.0);
        let mut scaled = p.clone();
        for row in 0..p.n {
            scaled.matrix[row * p.c() + col] *= k;
        }
        let a = mcda::topsis_closeness(&p);
        let b = mcda::topsis_closeness(&scaled);
        for (x, y) in a.iter().zip(&b) {
            assert!(
                (x - y).abs() < 1e-6,
                "case {case}: column {col} scale {k} changed {x} -> {y}"
            );
        }
    }
}

#[test]
fn prop_cluster_never_overcommits() {
    // Random bind/release sequences keep every node within capacity and
    // release restores the exact previous free amounts.
    let mut rng = Rng::seed_from_u64(5);
    for _case in 0..100 {
        let mut state =
            ClusterState::from_config(&ClusterConfig::paper_default());
        let mut live: Vec<Pod> = Vec::new();
        let mut id = 0u64;
        for _step in 0..200 {
            if rng.chance(0.6) || live.is_empty() {
                let class = match rng.below(3) {
                    0 => WorkloadClass::Light,
                    1 => WorkloadClass::Medium,
                    _ => WorkloadClass::Complex,
                };
                let pod =
                    Pod::new(id, class, SchedulerKind::Topsis, 0.0, 1);
                id += 1;
                let node = rng.below(state.nodes().len());
                let fits = state.fits(node, pod.requests);
                let res = state.bind(&pod, node, 0.0);
                assert_eq!(res.is_ok(), fits);
                if res.is_ok() {
                    live.push(pod);
                }
            } else {
                let idx = rng.below(live.len());
                let pod = live.swap_remove(idx);
                state.release(pod.id, 0.0).unwrap();
            }
            for n in 0..state.nodes().len() {
                assert!(state.free_cpu(n) <= state.node(n).cpu_millis);
                assert!(state.free_memory(n) <= state.node(n).memory_mib);
                let u = state.cpu_utilization(n);
                assert!((0.0..=1.0).contains(&u));
            }
        }
        // Release everything: cluster returns to pristine.
        for pod in live {
            state.release(pod.id, 0.0).unwrap();
        }
        for n in 0..state.nodes().len() {
            assert_eq!(state.free_cpu(n), state.node(n).cpu_millis);
            assert_eq!(state.free_memory(n), state.node(n).memory_mib);
            assert_eq!(state.pods_on(n), 0);
        }
    }
}

#[test]
fn prop_schedulers_always_pick_feasible_nodes() {
    let mut rng = Rng::seed_from_u64(6);
    let energy = greenpod::config::EnergyModelConfig::default();
    for case in 0..60 {
        let mut state =
            ClusterState::from_config(&ClusterConfig::paper_default());
        let mut topsis = GreenPodScheduler::new(
            Estimator::with_defaults(energy.clone()),
            match rng.below(4) {
                0 => WeightingScheme::General,
                1 => WeightingScheme::EnergyCentric,
                2 => WeightingScheme::PerformanceCentric,
                _ => WeightingScheme::ResourceEfficient,
            },
        );
        let mut default = DefaultK8sScheduler::new(case as u64);
        let mut id = 0u64;
        for _ in 0..40 {
            let class = match rng.below(3) {
                0 => WorkloadClass::Light,
                1 => WorkloadClass::Medium,
                _ => WorkloadClass::Complex,
            };
            let kind = if rng.chance(0.5) {
                SchedulerKind::Topsis
            } else {
                SchedulerKind::DefaultK8s
            };
            let pod = Pod::new(id, class, kind, 0.0, 1);
            id += 1;
            let d = match kind {
                SchedulerKind::Topsis => topsis.schedule(&state, &pod),
                SchedulerKind::DefaultK8s => default.schedule(&state, &pod),
            };
            match d.node {
                Some(n) => {
                    // The chosen node must satisfy the filter — bind
                    // must succeed.
                    state.bind(&pod, n, 0.0).unwrap();
                }
                None => {
                    // Unschedulable must mean NO node fits.
                    assert!(
                        state.feasible_nodes(pod.requests).is_empty(),
                        "case {case}: scheduler gave up though nodes fit"
                    );
                }
            }
        }
    }
}

#[test]
fn prop_generator_counts_and_determinism() {
    let mut rng = Rng::seed_from_u64(7);
    let cfg = ExperimentConfig::default();
    for _ in 0..50 {
        let seed = rng.next_u64();
        for level in CompetitionLevel::ALL {
            let a = generate_pods(level, &cfg, seed);
            let b = generate_pods(level, &cfg, seed);
            assert_eq!(a.pods.len(), level.total_pods());
            for (x, y) in a.pods.iter().zip(&b.pods) {
                assert_eq!(x.id, y.id);
                assert_eq!(x.class, y.class);
                assert_eq!(x.scheduler, y.scheduler);
                assert_eq!(x.arrival_s, y.arrival_s);
            }
            // Half/half ownership per Table V.
            let t = a.owned_by(SchedulerKind::Topsis).len();
            let d = a.owned_by(SchedulerKind::DefaultK8s).len();
            assert_eq!(t, d);
        }
    }
}

#[test]
fn prop_simulation_conservation() {
    // Across random seeds: every generated pod either completes with
    // positive energy and start >= arrival, or is reported
    // unschedulable; energy sums are finite and positive.
    let mut rng = Rng::seed_from_u64(8);
    let config = Config::paper_default();
    let executor = greenpod::workload::WorkloadExecutor::analytic();
    for _case in 0..30 {
        let seed = rng.next_u64();
        let level = match rng.below(3) {
            0 => CompetitionLevel::Low,
            1 => CompetitionLevel::Medium,
            _ => CompetitionLevel::High,
        };
        let ctx = greenpod::experiments::ExperimentContext::new(
            config.clone(),
        );
        let result = greenpod::experiments::run_once(
            &ctx,
            level,
            WeightingScheme::EnergyCentric,
            seed,
            &executor,
        );
        assert_eq!(
            result.records.len() + result.unschedulable.len(),
            level.total_pods()
        );
        for r in &result.records {
            assert!(r.joules > 0.0 && r.joules.is_finite());
            assert!(r.start_s >= r.arrival_s - 1e-9);
            assert!(r.finish_s > r.start_s);
            assert!(r.wait_s >= 0.0);
        }
        assert!(result.makespan_s.is_finite());
    }
}

#[test]
fn prop_weights_simplex_under_adaptation() {
    use greenpod::scheduler::AdaptiveWeighting;
    let mut rng = Rng::seed_from_u64(9);
    for _ in 0..100 {
        let a = AdaptiveWeighting {
            lo: rng.range_f64(0.0, 0.9),
            hi: rng.range_f64(0.0, 1.0),
            target: WeightingScheme::ResourceEfficient,
        };
        let mut state =
            ClusterState::from_config(&ClusterConfig::paper_default());
        // Random load.
        let mut id = 0;
        for _ in 0..rng.below(10) {
            let pod = Pod::new(id, WorkloadClass::Medium,
                               SchedulerKind::Topsis, 0.0, 1);
            id += 1;
            let node = rng.below(state.nodes().len());
            let _ = state.bind(&pod, node, 0.0);
        }
        for base in WeightingScheme::ALL {
            let w = a.weights(&state, base);
            let sum: f64 = w.iter().sum();
            assert!((sum - 1.0).abs() < 1e-9, "{w:?}");
            assert!(w.iter().all(|&x| x >= 0.0));
        }
    }
}
