//! Randomized property tests (seeded, deterministic) over the L3
//! invariants — the in-tree stand-in for proptest (DESIGN.md §1b).
//!
//! Each property runs a few hundred random cases from a fixed seed;
//! shrinkage is traded for printing the failing case's seed so it can
//! be replayed.

use greenpod::autoscaler::{AutoscalerPolicy, ThresholdConfig};
use greenpod::cluster::{ClusterState, Pod};
use greenpod::config::{
    ClusterConfig, CompetitionLevel, Config, DispatchKind,
    ExperimentConfig, SchedulerKind, WeightingScheme,
};
use greenpod::federation::{
    build_dispatcher, FederationEngine, FederationParams,
    FederationResult, RegionSchedulers, RegionSpec,
};
use greenpod::metrics::Summary;
use greenpod::energy::{
    grams_co2_per_joule, CarbonSignal, EnergyMeter, SignalShape,
};
use greenpod::mcda::{
    self, Criterion, DecisionProblem, Direction, McdaMethod,
};
use greenpod::framework::{
    BuildOptions, FrameworkScheduler, ProfileRegistry,
};
use greenpod::scheduler::Scheduler;
use greenpod::simulation::{
    NodeChange, RunResult, SimulationEngine, SimulationParams,
};
use greenpod::experiments::{run_trace_replay, ExperimentContext};
use greenpod::trace::{
    ChunkedTraceReader, DownSampler, InMemoryTrace, StreamArrivals,
    SynthTrace, TraceFormat, TraceOwnership, WorkloadTrace,
};
use greenpod::util::rng::Rng;
use greenpod::util::stats::total_order;
use greenpod::workload::{
    generate_pods, generate_pods_with, ArrivalProcess, ArrivalTrace,
    TraceEntry, TraceSpec, WorkloadClass, WorkloadExecutor,
};

/// Case-count knob: `GREENPOD_PROP_CASES` scales every property's
/// case count for hardening runs (e.g. `GREENPOD_PROP_CASES=2000
/// cargo test --release -q`); unset/garbage keeps the in-tree default.
fn prop_cases(default_cases: usize) -> usize {
    std::env::var("GREENPOD_PROP_CASES")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .filter(|&n| n > 0)
        .unwrap_or(default_cases)
}

/// Registry-built framework pair (`greenpod`, `default-k8s`) — the
/// only scheduler implementations since the monolith retirement.
fn framework_pair(
    scheme: WeightingScheme,
    seed: u64,
) -> (FrameworkScheduler, FrameworkScheduler) {
    let cfg = Config::paper_default();
    let registry = ProfileRegistry::new(&cfg);
    let opts = BuildOptions::new(&cfg, scheme).with_seed(seed);
    (
        registry.build("greenpod", &opts).expect("built-in"),
        registry.build("default-k8s", &opts).expect("built-in"),
    )
}

fn random_problem(rng: &mut Rng) -> DecisionProblem {
    let n = 1 + rng.below(40);
    let c = 1 + rng.below(7);
    let matrix: Vec<f64> =
        (0..n * c).map(|_| rng.range_f64(0.01, 100.0)).collect();
    let criteria: Vec<Criterion> = (0..c)
        .map(|_| {
            let w = rng.range_f64(0.01, 2.0);
            if rng.chance(0.5) {
                Criterion::benefit(w)
            } else {
                Criterion::cost(w)
            }
        })
        .collect();
    DecisionProblem::new(matrix, n, criteria)
}

#[test]
fn prop_topsis_closeness_in_unit_interval() {
    let mut rng = Rng::seed_from_u64(1);
    for case in 0..prop_cases(300) {
        let p = random_problem(&mut rng);
        for (i, s) in mcda::topsis_closeness(&p).iter().enumerate() {
            assert!(
                (-1e-9..=1.0 + 1e-9).contains(s),
                "case {case}: row {i} score {s}"
            );
            assert!(s.is_finite());
        }
    }
}

#[test]
fn prop_dominated_alternative_never_first() {
    // Build a problem, then append a row strictly dominated by row 0;
    // the dominated row must never outrank its dominator.
    let mut rng = Rng::seed_from_u64(2);
    for case in 0..prop_cases(200) {
        let mut p = random_problem(&mut rng);
        let c = p.c();
        let mut dominated = Vec::with_capacity(c);
        for col in 0..c {
            let v = p.at(0, col);
            let delta = rng.range_f64(0.1, 1.0);
            dominated.push(match p.criteria[col].direction {
                Direction::Benefit => (v - delta).max(0.001),
                Direction::Cost => v + delta,
            });
        }
        p.matrix.extend_from_slice(&dominated);
        p.n += 1;
        let scores = mcda::topsis_closeness(&p);
        assert!(
            scores[0] >= scores[p.n - 1] - 1e-9,
            "case {case}: dominated row scored {} > dominator {}",
            scores[p.n - 1],
            scores[0]
        );
    }
}

#[test]
fn prop_all_mcda_methods_rank_dominator_over_dominated() {
    let mut rng = Rng::seed_from_u64(3);
    for case in 0..prop_cases(100) {
        let mut p = random_problem(&mut rng);
        let c = p.c();
        let mut dominated = Vec::with_capacity(c);
        for col in 0..c {
            let v = p.at(0, col);
            dominated.push(match p.criteria[col].direction {
                Direction::Benefit => v * 0.5,
                Direction::Cost => v * 2.0 + 0.1,
            });
        }
        p.matrix.extend_from_slice(&dominated);
        p.n += 1;
        for method in McdaMethod::ALL {
            let scores = method.scores(&p);
            assert!(
                scores[0] >= scores[p.n - 1] - 1e-9,
                "case {case} {method:?}: dominated outranked dominator"
            );
        }
    }
}

#[test]
fn prop_topsis_scale_invariance() {
    // Multiplying any column by a positive constant leaves closeness
    // unchanged (vector normalization).
    let mut rng = Rng::seed_from_u64(4);
    for case in 0..prop_cases(200) {
        let p = random_problem(&mut rng);
        let col = rng.below(p.c());
        let k = rng.range_f64(0.1, 50.0);
        let mut scaled = p.clone();
        for row in 0..p.n {
            scaled.matrix[row * p.c() + col] *= k;
        }
        let a = mcda::topsis_closeness(&p);
        let b = mcda::topsis_closeness(&scaled);
        for (x, y) in a.iter().zip(&b) {
            assert!(
                (x - y).abs() < 1e-6,
                "case {case}: column {col} scale {k} changed {x} -> {y}"
            );
        }
    }
}

#[test]
fn prop_cluster_never_overcommits() {
    // Random bind/release sequences keep every node within capacity and
    // release restores the exact previous free amounts.
    let mut rng = Rng::seed_from_u64(5);
    for _case in 0..prop_cases(100) {
        let mut state =
            ClusterState::from_config(&ClusterConfig::paper_default());
        let mut live: Vec<Pod> = Vec::new();
        let mut id = 0u64;
        for _step in 0..200 {
            if rng.chance(0.6) || live.is_empty() {
                let class = match rng.below(3) {
                    0 => WorkloadClass::Light,
                    1 => WorkloadClass::Medium,
                    _ => WorkloadClass::Complex,
                };
                let pod =
                    Pod::new(id, class, SchedulerKind::Topsis, 0.0, 1);
                id += 1;
                let node = rng.below(state.nodes().len());
                let fits = state.fits(node, pod.requests);
                let res = state.bind(&pod, node, 0.0);
                assert_eq!(res.is_ok(), fits);
                if res.is_ok() {
                    live.push(pod);
                }
            } else {
                let idx = rng.below(live.len());
                let pod = live.swap_remove(idx);
                state.release(pod.id, 0.0).unwrap();
            }
            for n in 0..state.nodes().len() {
                assert!(state.free_cpu(n) <= state.node(n).cpu_millis);
                assert!(state.free_memory(n) <= state.node(n).memory_mib);
                let u = state.cpu_utilization(n);
                assert!((0.0..=1.0).contains(&u));
            }
        }
        // Release everything: cluster returns to pristine.
        for pod in live {
            state.release(pod.id, 0.0).unwrap();
        }
        for n in 0..state.nodes().len() {
            assert_eq!(state.free_cpu(n), state.node(n).cpu_millis);
            assert_eq!(state.free_memory(n), state.node(n).memory_mib);
            assert_eq!(state.pods_on(n), 0);
        }
    }
}

#[test]
fn prop_schedulers_always_pick_feasible_nodes() {
    let mut rng = Rng::seed_from_u64(6);
    for case in 0..prop_cases(60) {
        let mut state =
            ClusterState::from_config(&ClusterConfig::paper_default());
        let scheme = match rng.below(4) {
            0 => WeightingScheme::General,
            1 => WeightingScheme::EnergyCentric,
            2 => WeightingScheme::PerformanceCentric,
            _ => WeightingScheme::ResourceEfficient,
        };
        let (mut topsis, mut default) = framework_pair(scheme, case as u64);
        let mut id = 0u64;
        for _ in 0..40 {
            let class = match rng.below(3) {
                0 => WorkloadClass::Light,
                1 => WorkloadClass::Medium,
                _ => WorkloadClass::Complex,
            };
            let kind = if rng.chance(0.5) {
                SchedulerKind::Topsis
            } else {
                SchedulerKind::DefaultK8s
            };
            let pod = Pod::new(id, class, kind, 0.0, 1);
            id += 1;
            let d = match kind {
                SchedulerKind::Topsis => topsis.schedule(&state, &pod),
                SchedulerKind::DefaultK8s => default.schedule(&state, &pod),
            };
            match d.node {
                Some(n) => {
                    // The chosen node must satisfy the filter — bind
                    // must succeed.
                    state.bind(&pod, n, 0.0).unwrap();
                }
                None => {
                    // Unschedulable must mean NO node fits.
                    assert!(
                        state.feasible_nodes(pod.requests).is_empty(),
                        "case {case}: scheduler gave up though nodes fit"
                    );
                }
            }
        }
    }
}

#[test]
fn prop_generator_counts_and_determinism() {
    let mut rng = Rng::seed_from_u64(7);
    let cfg = ExperimentConfig::default();
    for _ in 0..prop_cases(50) {
        let seed = rng.next_u64();
        for level in CompetitionLevel::ALL {
            let a = generate_pods(level, &cfg, seed);
            let b = generate_pods(level, &cfg, seed);
            assert_eq!(a.pods.len(), level.total_pods());
            for (x, y) in a.pods.iter().zip(&b.pods) {
                assert_eq!(x.id, y.id);
                assert_eq!(x.class, y.class);
                assert_eq!(x.scheduler, y.scheduler);
                assert_eq!(x.arrival_s, y.arrival_s);
            }
            // Half/half ownership per Table V.
            let t = a.owned_by(SchedulerKind::Topsis).len();
            let d = a.owned_by(SchedulerKind::DefaultK8s).len();
            assert_eq!(t, d);
        }
    }
}

#[test]
fn prop_simulation_conservation() {
    // Across random seeds: every generated pod either completes with
    // positive energy and start >= arrival, or is reported
    // unschedulable; energy sums are finite and positive.
    let mut rng = Rng::seed_from_u64(8);
    let config = Config::paper_default();
    let executor = greenpod::workload::WorkloadExecutor::analytic();
    for _case in 0..prop_cases(30) {
        let seed = rng.next_u64();
        let level = match rng.below(3) {
            0 => CompetitionLevel::Low,
            1 => CompetitionLevel::Medium,
            _ => CompetitionLevel::High,
        };
        let ctx = greenpod::experiments::ExperimentContext::new(
            config.clone(),
        );
        let result = greenpod::experiments::run_once(
            &ctx,
            level,
            WeightingScheme::EnergyCentric,
            seed,
            &executor,
        );
        assert_eq!(
            result.records.len() + result.unschedulable.len(),
            level.total_pods()
        );
        for r in &result.records {
            assert!(r.joules > 0.0 && r.joules.is_finite());
            assert!(r.start_s >= r.arrival_s - 1e-9);
            assert!(r.finish_s > r.start_s);
            assert!(r.wait_s >= 0.0);
        }
        assert!(result.makespan_s.is_finite());
    }
}

#[test]
fn prop_weights_simplex_under_adaptation() {
    use greenpod::scheduler::AdaptiveWeighting;
    let mut rng = Rng::seed_from_u64(9);
    for _ in 0..prop_cases(100) {
        let a = AdaptiveWeighting {
            lo: rng.range_f64(0.0, 0.9),
            hi: rng.range_f64(0.0, 1.0),
            target: WeightingScheme::ResourceEfficient,
        };
        let mut state =
            ClusterState::from_config(&ClusterConfig::paper_default());
        // Random load.
        let mut id = 0;
        for _ in 0..rng.below(10) {
            let pod = Pod::new(id, WorkloadClass::Medium,
                               SchedulerKind::Topsis, 0.0, 1);
            id += 1;
            let node = rng.below(state.nodes().len());
            let _ = state.bind(&pod, node, 0.0);
        }
        for base in WeightingScheme::ALL {
            let w = a.weights(&state, base);
            let sum: f64 = w.iter().sum();
            assert!((sum - 1.0).abs() < 1e-9, "{w:?}");
            assert!(w.iter().all(|&x| x >= 0.0));
        }
    }
}

// ---------------------------------------------------------------------
// Event-kernel properties (the discrete-event engine's contract).

/// Run one seeded deployment through the event engine under a random
/// arrival process.
fn run_event_case(
    config: &Config,
    executor: &WorkloadExecutor,
    level: CompetitionLevel,
    process: ArrivalProcess,
    seed: u64,
) -> RunResult {
    let pods =
        generate_pods_with(level, &config.experiment, seed, process).pods;
    let engine = SimulationEngine::new(
        config,
        SimulationParams::with_beta_and_seed(
            config.experiment.contention_beta,
            seed,
        ),
        executor,
    );
    let (mut topsis, mut default) =
        framework_pair(WeightingScheme::EnergyCentric, seed);
    engine.run(pods, &mut topsis, &mut default)
}

fn random_process(rng: &mut Rng) -> ArrivalProcess {
    match rng.below(3) {
        0 => ArrivalProcess::Jittered {
            mean_gap_s: rng.range_f64(0.0, 2.0),
        },
        1 => ArrivalProcess::Poisson {
            rate_per_s: rng.range_f64(0.2, 5.0),
        },
        _ => ArrivalProcess::Bursty {
            burst_size: 1 + rng.below(6),
            burst_gap_s: rng.range_f64(0.5, 30.0),
            intra_gap_s: rng.range_f64(0.0, 0.2),
        },
    }
}

#[test]
fn prop_event_times_monotone() {
    // The kernel's clock contract: the event log is non-decreasing in
    // time for every arrival process and seed.
    let mut rng = Rng::seed_from_u64(10);
    let config = Config::paper_default();
    let executor = WorkloadExecutor::analytic();
    for case in 0..prop_cases(25) {
        let level = match rng.below(3) {
            0 => CompetitionLevel::Low,
            1 => CompetitionLevel::Medium,
            _ => CompetitionLevel::High,
        };
        let process = random_process(&mut rng);
        let seed = rng.next_u64();
        let r = run_event_case(&config, &executor, level, process, seed);
        assert!(!r.events.is_empty());
        for w in r.events.windows(2) {
            assert!(
                w[1].at_s >= w[0].at_s,
                "case {case} ({process:?}, seed {seed}): \
                 event time regressed {} -> {}",
                w[0].at_s,
                w[1].at_s
            );
        }
    }
}

#[test]
fn prop_no_pod_lost_between_arrival_and_completion() {
    // Conservation across the kernel: every generated pod is either
    // completed exactly once or reported unschedulable, under every
    // arrival process.
    let mut rng = Rng::seed_from_u64(11);
    let config = Config::paper_default();
    let executor = WorkloadExecutor::analytic();
    for case in 0..prop_cases(25) {
        let level = match rng.below(3) {
            0 => CompetitionLevel::Low,
            1 => CompetitionLevel::Medium,
            _ => CompetitionLevel::High,
        };
        let process = random_process(&mut rng);
        let seed = rng.next_u64();
        let r = run_event_case(&config, &executor, level, process, seed);
        assert_eq!(
            r.records.len() + r.unschedulable.len(),
            level.total_pods(),
            "case {case} ({process:?}, seed {seed}): pods lost"
        );
        let mut ids: Vec<u64> = r
            .records
            .iter()
            .map(|x| x.pod)
            .chain(r.unschedulable.iter().copied())
            .collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(
            ids.len(),
            level.total_pods(),
            "case {case}: duplicate pod outcome"
        );
        let arrivals =
            r.events.iter().filter(|e| e.kind == "pod-arrival").count();
        let completions =
            r.events.iter().filter(|e| e.kind == "pod-completed").count();
        assert_eq!(arrivals, level.total_pods());
        assert_eq!(completions, r.records.len());
        for rec in &r.records {
            assert!(rec.wait_s >= 0.0);
            assert!(rec.attempts >= 1);
            assert!(rec.start_s >= rec.arrival_s - 1e-9);
            assert!(rec.finish_s > rec.start_s);
            assert!(rec.joules.is_finite() && rec.joules > 0.0);
        }
    }
}

// ---------------------------------------------------------------------
// Autoscaler properties (the threshold policy's contract with the
// kernel — DESIGN.md §"Autoscaler").

/// Run one seeded deployment through the event engine under an optional
/// autoscaling policy (and optional churn schedule).
fn run_autoscaled_case(
    config: &Config,
    executor: &WorkloadExecutor,
    pods: Vec<Pod>,
    seed: u64,
    node_events: Vec<NodeChange>,
    policy: Option<AutoscalerPolicy>,
) -> RunResult {
    let params = SimulationParams {
        contention_beta: config.experiment.contention_beta,
        seed,
        node_events,
        autoscaler: policy,
        ..SimulationParams::default()
    };
    let engine = SimulationEngine::new(config, params, executor);
    let (mut topsis, mut default) =
        framework_pair(WeightingScheme::EnergyCentric, seed);
    engine.run(pods, &mut topsis, &mut default)
}

fn random_threshold_policy(
    rng: &mut Rng,
    cluster: &ClusterConfig,
) -> ThresholdConfig {
    let base = cluster.total_nodes();
    ThresholdConfig {
        scale_out_pending: 1 + rng.below(4),
        scale_out_wait_p95_s: if rng.chance(0.5) {
            rng.range_f64(2.0, 30.0)
        } else {
            f64::INFINITY
        },
        provision_delay_s: rng.range_f64(0.5, 10.0),
        cooldown_s: rng.range_f64(0.0, 10.0),
        idle_scale_in_s: if rng.chance(0.7) {
            rng.range_f64(5.0, 30.0)
        } else {
            f64::INFINITY
        },
        min_nodes: base,
        max_nodes: base + 1 + rng.below(5),
        template: if rng.chance(0.5) {
            ThresholdConfig::edge_template(cluster)
        } else {
            ThresholdConfig::cloud_template(cluster)
        },
        carbon: None,
    }
}

#[test]
fn prop_autoscaler_node_count_stays_in_bounds() {
    // Under random workloads and random threshold policies the Ready
    // node count never leaves [min_nodes, max_nodes], conservation
    // holds, and every scaling action is well-formed.
    let mut rng = Rng::seed_from_u64(13);
    let config = Config::paper_default();
    let executor = WorkloadExecutor::analytic();
    let base = config.cluster.total_nodes();
    for case in 0..prop_cases(20) {
        let level = match rng.below(3) {
            0 => CompetitionLevel::Low,
            1 => CompetitionLevel::Medium,
            _ => CompetitionLevel::High,
        };
        let process = random_process(&mut rng);
        let policy = random_threshold_policy(&mut rng, &config.cluster);
        let (min_n, max_n) = (policy.min_nodes, policy.max_nodes);
        let seed = rng.next_u64();
        let pods =
            generate_pods_with(level, &config.experiment, seed, process).pods;
        let r = run_autoscaled_case(
            &config,
            &executor,
            pods,
            seed,
            Vec::new(),
            Some(AutoscalerPolicy::Threshold(policy)),
        );
        assert_eq!(
            r.records.len() + r.unschedulable.len(),
            level.total_pods(),
            "case {case} (seed {seed}): pods lost"
        );
        assert!(!r.node_timeline.is_empty());
        for s in &r.node_timeline {
            assert!(
                (min_n..=max_n).contains(&s.ready_nodes),
                "case {case} (seed {seed}): ready {} outside [{min_n}, \
                 {max_n}] at {}",
                s.ready_nodes,
                s.at_s
            );
            assert!(s.total_nodes >= base);
            assert!(s.ready_nodes <= s.total_nodes);
        }
        for a in &r.scaling {
            assert!(a.node >= base, "case {case}: scaled a base node");
            assert!(a.effective_at_s >= a.at_s);
            assert!(matches!(a.kind, "scale-out" | "scale-in" | "activate"));
        }
        // Every scale-in targets a node that was provisioned first,
        // and every reactivation targets a previously scaled-in node.
        let outs: Vec<usize> = r
            .scaling
            .iter()
            .filter(|a| a.kind == "scale-out")
            .map(|a| a.node)
            .collect();
        for a in r
            .scaling
            .iter()
            .filter(|a| a.kind == "scale-in" || a.kind == "activate")
        {
            assert!(outs.contains(&a.node), "case {case}: {a:?}");
        }
    }
}

#[test]
fn prop_autoscaler_disabled_is_bit_identical() {
    // A policy whose every trigger is disabled must be bit-identical —
    // records, event log, makespan — to running with no autoscaler at
    // all: plugging the subsystem in perturbs nothing.
    let mut rng = Rng::seed_from_u64(14);
    let config = Config::paper_default();
    let executor = WorkloadExecutor::analytic();
    for case in 0..prop_cases(15) {
        let level = match rng.below(3) {
            0 => CompetitionLevel::Low,
            1 => CompetitionLevel::Medium,
            _ => CompetitionLevel::High,
        };
        let process = random_process(&mut rng);
        let seed = rng.next_u64();
        let pods =
            generate_pods_with(level, &config.experiment, seed, process).pods;
        let plain = run_autoscaled_case(
            &config,
            &executor,
            pods.clone(),
            seed,
            Vec::new(),
            None,
        );
        let noop = run_autoscaled_case(
            &config,
            &executor,
            pods,
            seed,
            Vec::new(),
            Some(AutoscalerPolicy::Threshold(ThresholdConfig::disabled(
                &config.cluster,
            ))),
        );
        assert_eq!(plain.records.len(), noop.records.len(), "case {case}");
        for (x, y) in plain.records.iter().zip(&noop.records) {
            assert_eq!(x.pod, y.pod, "case {case} (seed {seed})");
            assert_eq!(x.node, y.node);
            assert_eq!(x.start_s, y.start_s);
            assert_eq!(x.finish_s, y.finish_s);
            assert_eq!(x.wait_s, y.wait_s);
            assert_eq!(x.attempts, y.attempts);
            assert_eq!(x.joules, y.joules);
        }
        assert_eq!(plain.events, noop.events, "case {case}");
        assert_eq!(plain.makespan_s, noop.makespan_s);
        assert_eq!(plain.unschedulable, noop.unschedulable);
        assert!(noop.scaling.is_empty());
        assert_eq!(plain.node_timeline, noop.node_timeline);
    }
}

#[test]
fn prop_autoscaler_scale_out_threshold_monotone() {
    // Two monotonicity guarantees when raising the depth threshold
    // under the same workload and seed (cross-validated against the
    // Python engine mirror, python/tools/make_golden_trace.py):
    //
    // 1. the first scale-out never happens *earlier* — runs are
    //    identical until the first action, and a depth that reaches a
    //    higher threshold has reached every lower one;
    // 2. with provisioning slower than the run (added nodes never
    //    join, so scaling cannot feed back into placement), the final
    //    node count — base + total provisions — never increases.
    //
    // Unrestricted final-count monotonicity is *not* a law of the
    // closed loop: an early scale-out at a low threshold can absorb
    // backlog that would otherwise re-trigger scaling later, so a
    // higher threshold occasionally ends up provisioning more.
    let mut rng = Rng::seed_from_u64(15);
    let config = Config::paper_default();
    let executor = WorkloadExecutor::analytic();
    let base = config.cluster.total_nodes();
    let spec = TraceSpec {
        rate_per_s: 0.3,
        duration_s: 120.0,
        p_light: 0.2,
        p_medium: 0.2,
        p_complex: 0.6,
        epochs: [2, 2, 1],
    };
    let depths = [1usize, 2, 3, 5, 8];
    for case in 0..prop_cases(10) {
        let seed = rng.next_u64();
        let trace = ArrivalTrace::bursty(&spec, 12, seed);
        let run = |depth: usize, provision_delay_s: f64| {
            let policy = ThresholdConfig {
                scale_out_pending: depth,
                scale_out_wait_p95_s: f64::INFINITY,
                provision_delay_s,
                cooldown_s: 2.0,
                idle_scale_in_s: f64::INFINITY,
                min_nodes: base,
                max_nodes: base + 4,
                template: ThresholdConfig::edge_template(&config.cluster),
                carbon: None,
            };
            run_autoscaled_case(
                &config,
                &executor,
                trace.to_pods(SchedulerKind::Topsis),
                0,
                Vec::new(),
                Some(AutoscalerPolicy::Threshold(policy)),
            )
        };

        // 1. First-scale-out time is non-decreasing in the threshold.
        let mut last_first = 0.0_f64;
        for depth in depths {
            let r = run(depth, 5.0);
            assert!(r.unschedulable.is_empty(), "case {case} seed {seed}");
            let first = r
                .scaling
                .iter()
                .find(|a| a.kind == "scale-out")
                .map_or(f64::INFINITY, |a| a.at_s);
            assert!(
                first >= last_first,
                "case {case} (seed {seed}): depth {depth} scaled out at \
                 {first} — earlier than a lower threshold ({last_first})"
            );
            last_first = first;
        }

        // 2. Open-loop provisions (delay outlasts the run) are
        //    non-increasing in the threshold.
        let mut last_total = usize::MAX;
        for depth in depths {
            let r = run(depth, 1e6);
            let total = base + r.scaling_count("scale-out");
            assert_eq!(r.scaling_count("scale-in"), 0);
            assert!(
                total <= last_total,
                "case {case} (seed {seed}): depth {depth} provisioned \
                 {total} nodes > {last_total} at a lower threshold"
            );
            last_total = total;
        }
    }
}

#[test]
fn prop_churn_schedule_equals_autoscaler_replay() {
    // The differential contract: a churn schedule injected through
    // `SimulationParams::node_events` and the same schedule replayed
    // through the autoscaler's event-emission path share the kernel,
    // so placements, times, energy and outcomes are identical.
    let mut rng = Rng::seed_from_u64(16);
    let config = Config::paper_default();
    let executor = WorkloadExecutor::analytic();
    let n_nodes = config.cluster.total_nodes();
    for case in 0..prop_cases(15) {
        let level = match rng.below(3) {
            0 => CompetitionLevel::Low,
            1 => CompetitionLevel::Medium,
            _ => CompetitionLevel::High,
        };
        let process = random_process(&mut rng);
        let seed = rng.next_u64();
        // Random churn: pair each failure with a later rejoin so the
        // cluster always recovers (every pod eventually completes in
        // both runs — and must do so identically).
        let mut schedule = Vec::new();
        for _ in 0..1 + rng.below(4) {
            let node = rng.below(n_nodes);
            let down_at = rng.range_f64(0.0, 30.0);
            let up_at = down_at + rng.range_f64(1.0, 30.0);
            schedule.push(NodeChange { at_s: down_at, node, up: false });
            schedule.push(NodeChange { at_s: up_at, node, up: true });
        }
        let pods =
            generate_pods_with(level, &config.experiment, seed, process).pods;
        let injected = run_autoscaled_case(
            &config,
            &executor,
            pods.clone(),
            seed,
            schedule.clone(),
            None,
        );
        let replayed = run_autoscaled_case(
            &config,
            &executor,
            pods,
            seed,
            Vec::new(),
            Some(AutoscalerPolicy::Scheduled(schedule)),
        );
        assert_eq!(
            injected.records.len(),
            replayed.records.len(),
            "case {case} (seed {seed})"
        );
        for (x, y) in injected.records.iter().zip(&replayed.records) {
            assert_eq!(x.pod, y.pod, "case {case} (seed {seed})");
            assert_eq!(x.node, y.node, "case {case} (seed {seed})");
            assert_eq!(x.start_s, y.start_s);
            assert_eq!(x.finish_s, y.finish_s);
            assert_eq!(x.wait_s, y.wait_s);
            assert_eq!(x.attempts, y.attempts);
            assert_eq!(x.joules, y.joules);
        }
        assert_eq!(injected.unschedulable, replayed.unschedulable);
        assert_eq!(injected.makespan_s, replayed.makespan_s);
        // Idle-energy attribution sees the same Ready intervals.
        assert_eq!(injected.meter.idle_kj(), replayed.meter.idle_kj());
    }
}

#[test]
fn prop_batch_mode_equals_event_mode_at_t0() {
    // With every arrival at t = 0 the event kernel must reproduce the
    // synchronous batch pass exactly: same placements, same start and
    // finish times, same waits; energy matches to integration rounding.
    let mut rng = Rng::seed_from_u64(12);
    let config = Config::paper_default();
    let executor = WorkloadExecutor::analytic();
    for case in 0..prop_cases(20) {
        let level = match rng.below(3) {
            0 => CompetitionLevel::Low,
            1 => CompetitionLevel::Medium,
            _ => CompetitionLevel::High,
        };
        let seed = rng.next_u64();
        let mut pods =
            generate_pods(level, &config.experiment, seed).pods;
        for p in &mut pods {
            p.arrival_s = 0.0;
        }
        let engine = SimulationEngine::new(
            &config,
            SimulationParams::with_beta_and_seed(
                config.experiment.contention_beta,
                seed,
            ),
            &executor,
        );
        let (mut t1, mut d1) =
            framework_pair(WeightingScheme::EnergyCentric, seed);
        let (mut t2, mut d2) =
            framework_pair(WeightingScheme::EnergyCentric, seed);
        let ev = engine.run(pods.clone(), &mut t1, &mut d1);
        let ba = engine.run_batch(pods, &mut t2, &mut d2);
        assert_eq!(
            ev.records.len(),
            ba.records.len(),
            "case {case} (seed {seed})"
        );
        assert_eq!(ev.unschedulable, ba.unschedulable);
        for (x, y) in ev.records.iter().zip(&ba.records) {
            assert_eq!(x.pod, y.pod, "case {case} (seed {seed})");
            assert_eq!(x.node, y.node, "case {case} (seed {seed})");
            assert_eq!(x.start_s, y.start_s);
            assert_eq!(x.finish_s, y.finish_s);
            assert_eq!(x.wait_s, y.wait_s);
            assert_eq!(x.attempts, y.attempts);
            assert!(
                (x.joules - y.joules).abs() <= 1e-9 * x.joules.max(1.0),
                "case {case}: joules {} vs {}",
                x.joules,
                y.joules
            );
        }
        assert_eq!(ev.makespan_s, ba.makespan_s);
    }
}

// --------------------------------------------------------------------
// Carbon-signal properties (DESIGN.md §"Carbon signal").

/// A random step/linear intensity series: 1–10 samples, strictly
/// increasing timestamps, non-negative finite intensities.
fn random_signal(rng: &mut Rng) -> CarbonSignal {
    let n = 1 + rng.below(10);
    let mut t = rng.range_f64(0.0, 10.0);
    let mut points = Vec::with_capacity(n);
    for _ in 0..n {
        points.push((t, rng.range_f64(0.0, 5.0)));
        t += rng.range_f64(0.1, 20.0);
    }
    if rng.chance(0.5) {
        CarbonSignal::step(points).expect("valid series")
    } else {
        CarbonSignal::linear(points).expect("valid series")
    }
}

#[test]
fn prop_carbon_signal_clamps_and_interpolates_within_bounds() {
    let mut rng = Rng::seed_from_u64(34);
    for case in 0..prop_cases(200) {
        let s = random_signal(&mut rng);
        let (t0, v0) = s.points()[0];
        let &(tn, vn) = s.points().last().unwrap();
        // Endpoint clamping is exact.
        assert_eq!(s.at(t0 - rng.range_f64(0.0, 50.0)).to_bits(),
                   v0.to_bits(), "case {case}");
        assert_eq!(s.at(tn + rng.range_f64(0.0, 50.0)).to_bits(),
                   vn.to_bits(), "case {case}");
        // Interior lookups stay within the bracketing samples' bounds
        // (step: exactly the left sample; linear: between both).
        for _ in 0..20 {
            let t = rng.range_f64(t0, tn.max(t0 + 1e-9));
            let v = s.at(t);
            assert!(v.is_finite() && v >= 0.0, "case {case}: at({t}) = {v}");
            let Some(i) = (0..s.points().len() - 1)
                .find(|&i| t >= s.points()[i].0 && t < s.points()[i + 1].0)
            else {
                continue;
            };
            let (_, va) = s.points()[i];
            let (_, vb) = s.points()[i + 1];
            match s.shape() {
                SignalShape::Step => {
                    assert_eq!(v.to_bits(), va.to_bits(), "case {case}")
                }
                SignalShape::Linear => assert!(
                    v >= va.min(vb) - 1e-12 && v <= va.max(vb) + 1e-12,
                    "case {case}: at({t}) = {v} outside [{va}, {vb}]"
                ),
            }
        }
        // percentile endpoints are the sample extremes.
        let lo = s
            .points()
            .iter()
            .map(|&(_, v)| v)
            .fold(f64::INFINITY, f64::min);
        let hi = s
            .points()
            .iter()
            .map(|&(_, v)| v)
            .fold(f64::NEG_INFINITY, f64::max);
        assert_eq!(s.percentile(0.0), lo);
        assert_eq!(s.percentile(1.0), hi);
    }
}

#[test]
fn prop_carbon_integral_nonnegative_and_additive() {
    let mut rng = Rng::seed_from_u64(35);
    for case in 0..prop_cases(200) {
        let s = random_signal(&mut rng);
        let mut ts = [
            rng.range_f64(-20.0, 80.0),
            rng.range_f64(-20.0, 80.0),
            rng.range_f64(-20.0, 80.0),
        ];
        ts.sort_by(total_order);
        let [a, b, c] = ts;
        let whole = s.integral(a, c);
        let split = s.integral(a, b) + s.integral(b, c);
        assert!(whole >= 0.0, "case {case}: negative integral {whole}");
        assert!(
            (whole - split).abs() <= 1e-9 * whole.abs().max(1e-12),
            "case {case}: [{a}, {c}] = {whole} but split sum {split}"
        );
        // Reversed bounds integrate to zero.
        assert_eq!(s.integral(c, a), 0.0);
    }
}

#[test]
fn prop_carbon_ledger_nonnegative_and_additive_across_splits() {
    // The meter's grams ledger agrees across any interval splitting:
    // one whole-interval advance vs many random event boundaries.
    let mut rng = Rng::seed_from_u64(36);
    let config = Config::paper_default();
    let state = ClusterState::from_config(&config.cluster);
    let node = state.node(0).clone();
    for case in 0..prop_cases(100) {
        let signal = random_signal(&mut rng);
        let start = rng.range_f64(0.0, 30.0);
        let dur = rng.range_f64(1.0, 60.0);
        let mut splits: Vec<f64> = (0..rng.below(6))
            .map(|_| start + rng.range_f64(0.0, dur))
            .collect();
        splits.sort_by(total_order);
        let run = |splits: &[f64]| -> (f64, f64) {
            let mut m = EnergyMeter::new().with_carbon(signal.clone());
            m.start(
                &config.energy,
                1,
                greenpod::workload::WorkloadClass::Light,
                SchedulerKind::Topsis,
                &node,
                0.25,
                start,
            );
            for &t in splits {
                m.advance(t);
            }
            let joules = m.finish(1, start + dur);
            (joules, m.records()[0].grams)
        };
        let (wj, wg) = run(&[]);
        let (sj, sg) = run(&splits);
        assert!(wg >= 0.0 && sg >= 0.0, "case {case}: negative grams");
        assert!(
            (wj - sj).abs() <= 1e-9 * wj.abs().max(1e-12),
            "case {case}: joules {wj} vs split {sj}"
        );
        assert!(
            (wg - sg).abs() <= 1e-9 * wg.abs().max(1e-12),
            "case {case}: grams {wg} vs split {sg}"
        );
    }
}

#[test]
fn prop_constant_carbon_signal_is_bit_identical_to_scalar_path() {
    // The differential the carbon subsystem is pinned by (like the
    // PR 3 monolith differentials): under a constant signal, the
    // carbon-aware profile and the grams ledger reproduce the legacy
    // scalar grams_co2_per_joule path exactly — record-for-record
    // engine runs, grams = joules × g bit-for-bit. A two-sample series
    // with equal values exercises the *integral* path and must agree
    // with the scalar to rounding.
    let mut rng = Rng::seed_from_u64(37);
    let config = Config::paper_default();
    let executor = WorkloadExecutor::analytic();
    let g = grams_co2_per_joule(&config.energy);
    for case in 0..prop_cases(10) {
        let level = random_level(&mut rng);
        let seed = rng.next_u64();
        let pods = generate_pods(level, &config.experiment, seed).pods;
        let registry = ProfileRegistry::new(&config);
        let opts = BuildOptions::new(&config, WeightingScheme::EnergyCentric)
            .with_seed(seed)
            .with_executor(&executor);
        let run = |carbon: Option<CarbonSignal>| -> RunResult {
            let mut params = SimulationParams::with_beta_and_seed(
                config.experiment.contention_beta,
                seed,
            );
            if let Some(c) = carbon {
                params = params.with_carbon(c);
            }
            let engine = SimulationEngine::new(&config, params, &executor);
            let mut topsis = registry.build("carbon-aware", &opts).unwrap();
            let mut default = registry.build("default-k8s", &opts).unwrap();
            engine.run(pods.clone(), &mut topsis, &mut default)
        };
        // None defaults to the config's constant; an explicit constant
        // and a flat two-sample series must not perturb anything.
        let scalar = run(None);
        let constant = run(Some(CarbonSignal::constant(g)));
        let flat_series = run(Some(
            CarbonSignal::step(vec![(0.0, g), (1e6, g)]).unwrap(),
        ));
        for (tag, other) in
            [("constant", &constant), ("flat-series", &flat_series)]
        {
            assert_eq!(
                scalar.records.len(),
                other.records.len(),
                "case {case} ({tag}, seed {seed})"
            );
            for (x, y) in scalar.records.iter().zip(&other.records) {
                assert_eq!(x.pod, y.pod, "case {case} ({tag})");
                assert_eq!(x.node, y.node, "case {case} ({tag})");
                assert_eq!(x.start_s, y.start_s);
                assert_eq!(x.finish_s, y.finish_s);
                assert_eq!(x.joules, y.joules);
                assert_eq!(x.attempts, y.attempts);
            }
            assert_eq!(scalar.events, other.events, "case {case} ({tag})");
            assert_eq!(scalar.makespan_s, other.makespan_s);
        }
        // The grams ledger: single-sample signals are the scalar path
        // bit-for-bit; the flat series integrates to it within
        // rounding.
        for r in scalar.meter.records().iter().chain(constant.meter.records())
        {
            assert_eq!(
                r.grams.to_bits(),
                (r.joules * g).to_bits(),
                "case {case}: pod {} grams drifted off the scalar path",
                r.pod
            );
        }
        for r in flat_series.meter.records() {
            let want = r.joules * g;
            assert!(
                (r.grams - want).abs() <= 1e-9 * want.abs().max(1e-12),
                "case {case}: pod {} integral {} vs scalar {want}",
                r.pod,
                r.grams
            );
        }
        for n in 0..config.cluster.total_nodes() {
            assert_eq!(
                scalar.meter.node_idle_co2_g(n).to_bits(),
                (scalar.meter.node_idle_joules(n) * g).to_bits(),
                "case {case}: node {n} idle grams"
            );
        }
    }
}

// --------------------------------------------------------------------
// Framework differential: the profile-composed schedulers must be
// bit-identical to the pre-refactor monoliths — same chosen node, same
// per-candidate scores — over random cluster states and pods. This is
// the contract that makes the registry port a pure refactor.

fn random_scheme(rng: &mut Rng) -> WeightingScheme {
    WeightingScheme::ALL[rng.below(WeightingScheme::ALL.len())]
}

fn random_level(rng: &mut Rng) -> CompetitionLevel {
    CompetitionLevel::ALL[rng.below(CompetitionLevel::ALL.len())]
}

/// Drive two schedulers over the same evolving cluster: schedule each
/// pod with both, assert identical decisions bitwise, bind the chosen
/// node, and occasionally flip node readiness.
fn assert_bit_identical_decisions(
    first: &mut dyn Scheduler,
    second: &mut dyn Scheduler,
    pods: &[Pod],
    rng: &mut Rng,
    case: usize,
) {
    let config = Config::paper_default();
    let mut state = ClusterState::from_config(&config.cluster);
    for pod in pods {
        // Random churn keeps candidate sets diverse (never all-down:
        // flips are individually reverted half the time).
        if rng.chance(0.3) {
            let node = rng.below(state.nodes().len());
            let up = rng.chance(0.5);
            state.set_ready(node, up, 0.0);
        }
        let a = first.schedule(&state, pod);
        let b = second.schedule(&state, pod);
        assert_eq!(
            a.node, b.node,
            "case {case} pod {}: node diverged",
            pod.id
        );
        assert_eq!(
            a.scores.len(),
            b.scores.len(),
            "case {case} pod {}: candidate sets diverged",
            pod.id
        );
        for (&(na, sa), &(nb, sb)) in a.scores.iter().zip(&b.scores) {
            assert_eq!(na, nb, "case {case} pod {}: candidate order", pod.id);
            assert_eq!(
                sa.to_bits(),
                sb.to_bits(),
                "case {case} pod {} node {na}: score {sa} != {sb}",
                pod.id
            );
        }
        if let Some(node) = a.node {
            state.bind(pod, node, 0.0).unwrap();
        }
        // Random releases free capacity so later pods see varied load.
        if rng.chance(0.2) {
            if let Some(&id) =
                pods.iter().map(|p| &p.id).find(|&&id| state.node_of(id).is_some())
            {
                state.release(id, 0.0).unwrap();
            }
        }
    }
}

// The monolith-vs-framework differentials that lived here pinned the
// framework `greenpod`/`default-k8s` profiles bit-identical to the
// retired `GreenPodScheduler`/`DefaultK8sScheduler` monoliths for two
// PRs. With the monoliths deleted, the framework is the only
// formulation left, so those differentials are reborn as framework
// self-consistency checks: alias resolution, seeded tie-break stream
// determinism, and guarded-vs-forced cycle equivalence through the
// delegated engine path.

#[test]
fn prop_legacy_alias_build_bit_identical_to_canonical() {
    // `greenpod-topsis` (the retired monolith's reported name) must
    // resolve to a scheduler bit-identical to a `greenpod` build with
    // the same options, decision-for-decision under churn.
    let mut rng = Rng::seed_from_u64(31);
    let config = Config::paper_default();
    let executor = WorkloadExecutor::analytic();
    for case in 0..prop_cases(25) {
        let scheme = random_scheme(&mut rng);
        let level = random_level(&mut rng);
        let seed = rng.next_u64();
        let pods = generate_pods(level, &config.experiment, seed).pods;
        let registry = ProfileRegistry::new(&config);
        let opts = BuildOptions::new(&config, scheme)
            .with_seed(seed)
            .with_executor(&executor);
        let mut aliased = registry.build("greenpod-topsis", &opts).unwrap();
        let mut canonical = registry.build("greenpod", &opts).unwrap();
        assert_bit_identical_decisions(
            &mut aliased,
            &mut canonical,
            &pods,
            &mut rng,
            case,
        );
    }
}

#[test]
fn prop_default_k8s_tie_break_stream_deterministic() {
    // The seeded-random tie-break: two independent builds with the
    // same seed must consume their RNG streams draw-for-draw, so the
    // decisions stay bitwise equal over an evolving cluster.
    let mut rng = Rng::seed_from_u64(32);
    let config = Config::paper_default();
    let executor = WorkloadExecutor::analytic();
    for case in 0..prop_cases(25) {
        let level = random_level(&mut rng);
        let seed = rng.next_u64();
        let pods = generate_pods(level, &config.experiment, seed).pods;
        let registry = ProfileRegistry::new(&config);
        let opts = BuildOptions::new(&config, WeightingScheme::General)
            .with_seed(seed)
            .with_executor(&executor);
        let mut first = registry.build("default-k8s", &opts).unwrap();
        let mut second = registry.build("default-k8s", &opts).unwrap();
        assert_bit_identical_decisions(
            &mut first,
            &mut second,
            &pods,
            &mut rng,
            case,
        );
    }
}

#[test]
fn prop_forced_full_cycles_bit_identical_through_delegation() {
    // The cycle-guard regression pin, at property scale: with the
    // guard skipping no-change cycles (default) and with every cycle
    // forced (`force_full_cycles`), the delegated engine path must
    // produce bitwise-identical runs — the guard may only elide work
    // that provably cannot change a decision.
    let mut rng = Rng::seed_from_u64(33);
    let config = Config::paper_default();
    let executor = WorkloadExecutor::analytic();
    for case in 0..prop_cases(15) {
        let scheme = random_scheme(&mut rng);
        let level = random_level(&mut rng);
        let seed = rng.next_u64();
        let pods = generate_pods(level, &config.experiment, seed).pods;
        let params = SimulationParams::with_beta_and_seed(
            config.experiment.contention_beta,
            seed,
        );
        let mut forced_params = params.clone();
        forced_params.force_full_cycles = true;

        let registry = ProfileRegistry::new(&config);
        let opts = BuildOptions::new(&config, scheme)
            .with_seed(seed)
            .with_executor(&executor);
        let engine = SimulationEngine::new(&config, params, &executor);
        let mut gt = registry.build("greenpod", &opts).unwrap();
        let mut gd = registry.build("default-k8s", &opts).unwrap();
        let guarded = engine.run(pods.clone(), &mut gt, &mut gd);

        let forced_engine =
            SimulationEngine::new(&config, forced_params, &executor);
        let mut ft = registry.build("greenpod", &opts).unwrap();
        let mut fd = registry.build("default-k8s", &opts).unwrap();
        let forced = forced_engine.run(pods, &mut ft, &mut fd);

        assert_eq!(
            guarded.records.len(),
            forced.records.len(),
            "case {case} (seed {seed})"
        );
        assert_eq!(guarded.unschedulable, forced.unschedulable);
        for (x, y) in guarded.records.iter().zip(&forced.records) {
            assert_eq!(x.pod, y.pod, "case {case} (seed {seed})");
            assert_eq!(x.node, y.node, "case {case} (seed {seed})");
            assert_eq!(x.start_s, y.start_s);
            assert_eq!(x.finish_s, y.finish_s);
            assert_eq!(x.wait_s, y.wait_s);
            assert_eq!(x.attempts, y.attempts);
            assert_eq!(x.joules, y.joules, "case {case} pod {}", x.pod);
        }
        assert_eq!(guarded.events, forced.events);
        assert_eq!(guarded.makespan_s, forced.makespan_s);
        assert_eq!(
            guarded.meter.total_kj(SchedulerKind::Topsis),
            forced.meter.total_kj(SchedulerKind::Topsis)
        );
        assert_eq!(
            guarded.meter.total_kj(SchedulerKind::DefaultK8s),
            forced.meter.total_kj(SchedulerKind::DefaultK8s)
        );
        // Counter conservation: forcing skips nothing, and the two
        // paths agree on how many cycles the run requested.
        assert_eq!(forced.cycles_skipped, 0, "case {case}");
        assert_eq!(
            guarded.cycles_run + guarded.cycles_skipped,
            forced.cycles_run,
            "case {case}"
        );
    }
}

#[test]
fn prop_incremental_scoring_bit_identical_to_full_rescore() {
    // The hot-path pin: a scheduler reusing version-stamped estimator
    // rows across cycles (incremental, the default) must place every
    // pod on the same node with bit-identical published scores as a
    // twin forced to rescore from scratch each decision — across all
    // built-in profiles, churn (readiness flips, autoscaler-style
    // joins, releases), varying pod shapes, and back-to-back decisions
    // with no intervening mutation (the pure cache-hit path).
    let mut rng = Rng::seed_from_u64(41);
    let config = Config::paper_default();
    let profiles =
        ["greenpod", "default-k8s", "carbon-aware", "hybrid-topsis-balanced"];
    for case in 0..prop_cases(12) {
        for profile in profiles {
            let seed = rng.next_u64();
            let registry = ProfileRegistry::new(&config);
            let opts = BuildOptions::new(&config, random_scheme(&mut rng))
                .with_seed(seed);
            let mut inc = registry.build(profile, &opts).unwrap();
            let mut full = registry.build(profile, &opts).unwrap();
            full.set_incremental(false);

            let mut state = ClusterState::from_config(&config.cluster);
            let mut bound: Vec<u64> = Vec::new();
            let mut id = 0u64;
            let mut now = 0.0;
            for _step in 0..60 {
                now += 7.5;
                // Churn between decisions: readiness flips (up-biased
                // so the cluster never drains), joins, releases.
                if rng.chance(0.25) {
                    let node = rng.below(state.nodes().len());
                    state.set_ready(node, rng.chance(0.7), now);
                }
                if rng.chance(0.1) {
                    let n = state.add_node(&config.cluster.pools[0], now);
                    state.set_ready(n, true, now);
                }
                if rng.chance(0.3) && !bound.is_empty() {
                    let idx = rng.below(bound.len());
                    state.release(bound.swap_remove(idx), now).unwrap();
                }
                let class = [
                    WorkloadClass::Light,
                    WorkloadClass::Medium,
                    WorkloadClass::Complex,
                ][rng.below(3)];
                let pod = Pod::new(
                    id,
                    class,
                    SchedulerKind::Topsis,
                    now,
                    1 + rng.below(4) as u32,
                );
                id += 1;
                // Repeat = same pod shape with zero mutations in
                // between: the incremental twin serves the whole row
                // set from cache and must still agree.
                let repeats = if rng.chance(0.3) { 2 } else { 1 };
                let mut choice = None;
                for _ in 0..repeats {
                    let a = inc.schedule_at(&state, &pod, now);
                    let b = full.schedule_at(&state, &pod, now);
                    assert_eq!(
                        a.node, b.node,
                        "case {case} {profile} pod {}: node diverged",
                        pod.id
                    );
                    assert_eq!(
                        a.scores.len(),
                        b.scores.len(),
                        "case {case} {profile} pod {}: candidate sets",
                        pod.id
                    );
                    for (&(na, sa), &(nb, sb)) in
                        a.scores.iter().zip(&b.scores)
                    {
                        assert_eq!(
                            na, nb,
                            "case {case} {profile}: candidate order"
                        );
                        assert_eq!(
                            sa.to_bits(),
                            sb.to_bits(),
                            "case {case} {profile} pod {} node {na}: \
                             {sa} != {sb}",
                            pod.id
                        );
                    }
                    choice = a.node;
                }
                if let Some(node) = choice {
                    state.bind(&pod, node, now).unwrap();
                    bound.push(pod.id);
                }
            }
        }
    }
}

#[test]
fn prop_indexed_feasibility_matches_scan() {
    // The log2-bucket free-capacity indices must answer exactly the
    // same (sorted) feasible set as the reference O(nodes) scan for
    // any request shape — zero, typical, axis-skewed, oversized (a pod
    // bigger than every node: empty set, not a panic) — over
    // arbitrarily churned clusters.
    use greenpod::cluster::ResourceRequests;
    let mut rng = Rng::seed_from_u64(42);
    let config = Config::paper_default();
    for case in 0..prop_cases(80) {
        let mut state = ClusterState::from_config(&config.cluster);
        let mut bound: Vec<u64> = Vec::new();
        let mut id = 0u64;
        for step in 0..80 {
            match rng.below(10) {
                0 => {
                    let node = rng.below(state.nodes().len());
                    state.set_ready(node, rng.chance(0.6), 0.0);
                }
                1 => {
                    let pool = rng.below(config.cluster.pools.len());
                    let n =
                        state.add_node(&config.cluster.pools[pool], 0.0);
                    if rng.chance(0.7) {
                        state.set_ready(n, true, 0.0);
                    }
                }
                2 | 3 => {
                    if !bound.is_empty() {
                        let idx = rng.below(bound.len());
                        state
                            .release(bound.swap_remove(idx), 0.0)
                            .unwrap();
                    }
                }
                _ => {
                    let class = [
                        WorkloadClass::Light,
                        WorkloadClass::Medium,
                        WorkloadClass::Complex,
                    ][rng.below(3)];
                    let pod =
                        Pod::new(id, class, SchedulerKind::Topsis, 0.0, 1);
                    id += 1;
                    let node = rng.below(state.nodes().len());
                    if state.bind(&pod, node, 0.0).is_ok() {
                        bound.push(pod.id);
                    }
                }
            }
            let req = match rng.below(5) {
                0 => ResourceRequests { cpu_millis: 0, memory_mib: 0 },
                1 => ResourceRequests {
                    cpu_millis: 1_000_000,
                    memory_mib: 1_000_000,
                },
                2 => ResourceRequests {
                    cpu_millis: rng.next_u64() % 5_000,
                    memory_mib: 1,
                },
                3 => ResourceRequests {
                    cpu_millis: 1,
                    memory_mib: rng.next_u64() % 20_000,
                },
                _ => ResourceRequests {
                    cpu_millis: rng.next_u64() % 3_000,
                    memory_mib: rng.next_u64() % 10_000,
                },
            };
            assert_eq!(
                state.feasible_nodes(req),
                state.feasible_nodes_scan(req),
                "case {case} step {step}: index diverged from scan \
                 ({req:?})"
            );
        }
    }
}

// ---------------------------------------------------------------------
// Percentile unification (the util::stats nearest-rank helper —
// DESIGN.md §"Federation" bugfix sweep).

#[test]
fn prop_nearest_rank_matches_legacy_percentile_formulas() {
    // Three hand-rolled percentile implementations had drifted into
    // metrics::Summary, energy::CarbonSignal::percentile and the
    // autoscaler's wait-p95 path. The unified util::stats helper must
    // be bit-identical to each retired call-site formula over random
    // samples and quantiles — and the consumers must actually resolve
    // through it.
    let mut rng = Rng::seed_from_u64(23);
    for case in 0..prop_cases(300) {
        let n = 1 + rng.below(200);
        let samples: Vec<f64> =
            (0..n).map(|_| rng.range_f64(0.01, 100.0)).collect();
        let q = match rng.below(4) {
            0 => 0.0,
            1 => 0.5,
            2 => 0.95,
            _ => rng.range_f64(0.0, 1.0),
        };
        let mut sorted = samples.clone();
        sorted.sort_by(total_order);
        // Retired metrics::Summary closure: round() then clamp.
        let legacy_summary = {
            let idx = ((n as f64 - 1.0) * q).round() as usize;
            sorted[idx.min(n - 1)]
        };
        // Retired energy::signal inline indexing: floor(x + 0.5).
        let legacy_signal = {
            let x = (n as f64 - 1.0) * q.clamp(0.0, 1.0);
            let idx = ((x + 0.5).floor() as usize).min(n - 1);
            sorted[idx]
        };
        let unified =
            greenpod::util::stats::nearest_rank(&samples, q).unwrap();
        assert_eq!(
            unified.to_bits(),
            legacy_summary.to_bits(),
            "case {case}: unified {unified} vs Summary formula \
             {legacy_summary} (n {n}, q {q})"
        );
        assert_eq!(
            unified.to_bits(),
            legacy_signal.to_bits(),
            "case {case}: unified {unified} vs signal formula \
             {legacy_signal} (n {n}, q {q})"
        );
        // The live consumers go through the same helper.
        let s = Summary::of(&samples);
        assert_eq!(
            s.p95.to_bits(),
            greenpod::util::stats::nearest_rank(&samples, 0.95)
                .unwrap()
                .to_bits(),
            "case {case}: Summary p95 drifted"
        );
        let signal = CarbonSignal::step(
            samples
                .iter()
                .enumerate()
                .map(|(i, &v)| (i as f64, v))
                .collect(),
        )
        .unwrap();
        assert_eq!(
            signal.percentile(q).to_bits(),
            unified.to_bits(),
            "case {case}: CarbonSignal percentile drifted"
        );
    }
    // The empty window stays a distinct state, never "p95 = 0".
    assert_eq!(greenpod::util::stats::nearest_rank(&[], 0.95), None);
}

// ---------------------------------------------------------------------
// Federation properties (rust/src/federation/ — DESIGN.md
// §"Federation").

fn federation_schedulers(
    _config: &Config,
    seed: u64,
    n: usize,
) -> Vec<RegionSchedulers> {
    (0..n)
        .map(|_| {
            let (topsis, default) =
                framework_pair(WeightingScheme::EnergyCentric, seed);
            RegionSchedulers {
                topsis: Box::new(topsis),
                default: Box::new(default),
            }
        })
        .collect()
}

fn random_dispatch(rng: &mut Rng) -> DispatchKind {
    match rng.below(3) {
        0 => DispatchKind::RoundRobin,
        1 => DispatchKind::LeastPending,
        _ => DispatchKind::CarbonGreedy,
    }
}

fn random_region_signal(rng: &mut Rng) -> CarbonSignal {
    if rng.chance(0.5) {
        CarbonSignal::diurnal(
            rng.range_f64(1e-5, 5e-4),
            rng.range_f64(0.1, 0.9),
            rng.range_f64(60.0, 400.0),
            12,
        )
        .expect("valid diurnal")
    } else {
        CarbonSignal::constant(rng.range_f64(0.0, 5e-4))
    }
}

#[test]
fn prop_federation_single_region_is_bit_identical_to_plain_engine() {
    // The delegation contract: `SimulationEngine::run` is a thin
    // wrapper over a 1-region federation, so a hand-assembled solo
    // region — any dispatch policy, with or without an autoscaler,
    // constant or diurnal signal — must reproduce the wrapper's run
    // record-for-record, bit-for-bit: placements, times, joules,
    // grams, events, scaling, node timeline. This pins the wrapper's
    // SimulationParams→RegionSpec mapping (the merged queue
    // degenerates to the kernel queue; every dispatch resolves to
    // region 0).
    let mut rng = Rng::seed_from_u64(21);
    let config = Config::paper_default();
    let executor = WorkloadExecutor::analytic();
    for case in 0..prop_cases(10) {
        let level = random_level(&mut rng);
        let process = random_process(&mut rng);
        let seed = rng.next_u64();
        let pods =
            generate_pods_with(level, &config.experiment, seed, process).pods;
        let policy = if rng.chance(0.5) {
            Some(AutoscalerPolicy::Threshold(random_threshold_policy(
                &mut rng,
                &config.cluster,
            )))
        } else {
            None
        };
        let signal = random_region_signal(&mut rng);

        let params = SimulationParams {
            contention_beta: config.experiment.contention_beta,
            seed,
            node_events: Vec::new(),
            autoscaler: policy.clone(),
            billing_horizon_s: None,
            carbon: Some(signal.clone()),
            force_full_cycles: false,
        };
        let engine = SimulationEngine::new(&config, params, &executor);
        let (mut topsis, mut default) =
            framework_pair(WeightingScheme::EnergyCentric, seed);
        let plain = engine.run(pods.clone(), &mut topsis, &mut default);

        let mut spec =
            RegionSpec::new("solo", config.clone()).with_carbon(signal);
        if let Some(p) = policy {
            spec = spec.with_autoscaler(p);
        }
        let specs = vec![spec];
        let fed_engine = FederationEngine::new(
            &specs,
            FederationParams::with_beta_and_seed(
                config.experiment.contention_beta,
                seed,
            ),
            &executor,
        );
        let mut scheds = federation_schedulers(&config, seed, 1);
        let mut dispatcher = build_dispatcher(random_dispatch(&mut rng));
        let fed = fed_engine.run(pods, dispatcher.as_mut(), &mut scheds);

        assert_eq!(fed.regions.len(), 1, "case {case}");
        let run = &fed.regions[0].run;
        assert_eq!(
            plain.records.len(),
            run.records.len(),
            "case {case} (seed {seed})"
        );
        for (x, y) in plain.records.iter().zip(&run.records) {
            assert_eq!(x.pod, y.pod, "case {case} (seed {seed})");
            assert_eq!(x.node, y.node, "case {case} (seed {seed})");
            assert_eq!(x.start_s.to_bits(), y.start_s.to_bits());
            assert_eq!(x.finish_s.to_bits(), y.finish_s.to_bits());
            assert_eq!(x.wait_s.to_bits(), y.wait_s.to_bits());
            assert_eq!(x.attempts, y.attempts);
            assert_eq!(
                x.joules.to_bits(),
                y.joules.to_bits(),
                "case {case} pod {}",
                x.pod
            );
        }
        assert_eq!(plain.unschedulable, run.unschedulable, "case {case}");
        assert_eq!(plain.events, run.events, "case {case}");
        assert_eq!(plain.scaling, run.scaling, "case {case}");
        assert_eq!(plain.node_timeline, run.node_timeline, "case {case}");
        assert_eq!(plain.makespan_s.to_bits(), run.makespan_s.to_bits());
        for kind in [SchedulerKind::Topsis, SchedulerKind::DefaultK8s] {
            assert_eq!(
                plain.meter.total_kj(kind).to_bits(),
                run.meter.total_kj(kind).to_bits(),
                "case {case}"
            );
            assert_eq!(
                plain.meter.total_co2_g(kind).to_bits(),
                run.meter.total_co2_g(kind).to_bits(),
                "case {case}"
            );
        }
        assert_eq!(plain.idle_kj().to_bits(), run.idle_kj().to_bits());
        assert_eq!(
            plain.meter.idle_co2_g().to_bits(),
            run.meter.idle_co2_g().to_bits()
        );
    }
}

#[test]
fn prop_federation_dispatcher_conservation() {
    // Across random federations — 1 to 3 regions, every dispatch
    // policy, mixed signals, autoscalers on a coin flip — every
    // admitted pod is routed to exactly one region, every region's
    // outcome covers exactly its assigned pods, and per-region
    // completed/unschedulable counts sum to the trace totals.
    let mut rng = Rng::seed_from_u64(22);
    let config = Config::paper_default();
    let executor = WorkloadExecutor::analytic();
    for case in 0..prop_cases(10) {
        let n_regions = 1 + rng.below(3);
        let dispatch = random_dispatch(&mut rng);
        let level = random_level(&mut rng);
        let process = random_process(&mut rng);
        let seed = rng.next_u64();
        let pods =
            generate_pods_with(level, &config.experiment, seed, process).pods;
        let n_pods = pods.len();
        let specs: Vec<RegionSpec> = (0..n_regions)
            .map(|j| {
                let mut spec = RegionSpec::new(
                    &format!("r{j}"),
                    config.clone(),
                )
                .with_carbon(random_region_signal(&mut rng));
                if rng.chance(0.3) {
                    spec = spec.with_autoscaler(AutoscalerPolicy::Threshold(
                        random_threshold_policy(&mut rng, &config.cluster),
                    ));
                }
                spec
            })
            .collect();
        let engine = FederationEngine::new(
            &specs,
            FederationParams::with_beta_and_seed(
                config.experiment.contention_beta,
                seed,
            ),
            &executor,
        );
        let mut scheds = federation_schedulers(&config, seed, n_regions);
        let mut dispatcher = build_dispatcher(dispatch);
        let fed: FederationResult =
            engine.run(pods, dispatcher.as_mut(), &mut scheds);

        // Every admitted pod dispatched to exactly one region.
        assert_eq!(
            fed.assignments.len(),
            n_pods,
            "case {case} ({dispatch:?}, seed {seed})"
        );
        let mut assigned: Vec<u64> =
            fed.assignments.iter().map(|a| a.pod).collect();
        assigned.sort_unstable();
        assigned.dedup();
        assert_eq!(assigned.len(), n_pods, "case {case}: double dispatch");
        for a in &fed.assignments {
            assert!(a.region < n_regions, "case {case}: {a:?}");
        }

        // Conservation: completed + unschedulable across regions
        // covers the trace exactly once.
        assert_eq!(
            fed.completed() + fed.unschedulable(),
            n_pods,
            "case {case} ({dispatch:?}, seed {seed}): pods lost"
        );
        let mut outcomes: Vec<u64> = fed
            .regions
            .iter()
            .flat_map(|r| {
                r.run
                    .records
                    .iter()
                    .map(|rec| rec.pod)
                    .chain(r.run.unschedulable.iter().copied())
            })
            .collect();
        outcomes.sort_unstable();
        outcomes.dedup();
        assert_eq!(
            outcomes.len(),
            n_pods,
            "case {case}: duplicate pod outcome across regions"
        );

        // Every region's outcome matches its assignments — a pod never
        // completes in a region it was not dispatched to.
        for (ri, reg) in fed.regions.iter().enumerate() {
            let arrivals = reg
                .run
                .events
                .iter()
                .filter(|e| e.kind == "pod-arrival")
                .count();
            let owned = fed
                .assignments
                .iter()
                .filter(|a| a.region == ri)
                .count();
            assert_eq!(
                arrivals, owned,
                "case {case}: region {ri} arrival log vs assignments"
            );
            assert_eq!(
                reg.run.records.len() + reg.run.unschedulable.len(),
                owned,
                "case {case}: region {ri} outcome vs assignments"
            );
            for rec in &reg.run.records {
                let a = fed
                    .assignments
                    .iter()
                    .find(|a| a.pod == rec.pod)
                    .expect("assignment for completed pod");
                assert_eq!(a.region, ri, "case {case}: pod {}", rec.pod);
            }
        }
    }
}

/// The PR-8 float-ordering sweep rerouted every ad-hoc comparator
/// (`partial_cmp().unwrap()`, bare `total_cmp`) through
/// `util::stats::total_order`. This pins the reroute as bit-identical
/// on non-NaN inputs: sorting any NaN-free corpus with the shared
/// helper yields exactly the sequence either ad-hoc comparator
/// produced, so no golden fixture can move.
#[test]
fn prop_total_order_bit_identical_to_ad_hoc_comparators_off_nan() {
    let mut rng = Rng::seed_from_u64(0x70a1_0bde);
    for case in 0..prop_cases(200) {
        let n = 2 + rng.below(64);
        // Mix continuous draws with quantized duplicates so the Equal
        // arm is exercised; no NaN and no -0.0 in this corpus.
        let v: Vec<f64> = (0..n)
            .map(|_| {
                if rng.chance(0.3) {
                    rng.below(8) as f64
                } else {
                    rng.range_f64(-1e9, 1e9)
                }
            })
            .collect();
        let mut by_helper = v.clone();
        by_helper.sort_by(total_order);
        let mut by_partial = v.clone();
        // greenpod-lint: allow(float-cmp-unwrap) reason="differential property: the ad-hoc comparator IS the subject under test"
        by_partial.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mut by_total = v;
        // greenpod-lint: allow(float-cmp-unwrap) reason="differential property: raw total_cmp is the reference being pinned"
        by_total.sort_by(|a, b| a.total_cmp(b));
        for i in 0..n {
            assert_eq!(
                by_helper[i].to_bits(),
                by_partial[i].to_bits(),
                "case {case} idx {i}: diverges from partial_cmp"
            );
            assert_eq!(
                by_helper[i].to_bits(),
                by_total[i].to_bits(),
                "case {case} idx {i}: diverges from total_cmp"
            );
        }
    }

    // And off the non-NaN corpus the helper stays total: sorting with
    // NaN present cannot panic, and NaN sorts after every number.
    let mut v = vec![
        f64::NAN,
        1.0,
        f64::NEG_INFINITY,
        -1.0,
        f64::INFINITY,
        0.0,
    ];
    v.sort_by(total_order);
    assert!(v[..5].iter().all(|x| !x.is_nan()));
    assert!(v[5].is_nan());
    assert_eq!(v[0], f64::NEG_INFINITY);
    assert_eq!(v[4], f64::INFINITY);
}

/// Drain any workload trace into a vector (test helper).
fn drain_trace(t: &mut dyn WorkloadTrace) -> Vec<TraceEntry> {
    let mut out = Vec::new();
    while let Some(e) = t.next_entry().expect("valid trace") {
        out.push(e);
    }
    out
}

#[test]
fn prop_streaming_arrivals_bit_identical_to_eager_run() {
    // The lazy-arrival contract: feeding a federation through
    // `run_source(StreamArrivals)` must reproduce the eager
    // `run(Vec<Pod>)` on the same trace record-for-record,
    // bit-for-bit — placements, times, joules, grams, events, node
    // timeline — across 1-3 regions, every dispatch policy, both
    // ownership modes and mixed carbon signals. Only the memory
    // high-water mark may differ: streaming recycles pod slots, so
    // its peak is at most the eager trace length.
    let mut rng = Rng::seed_from_u64(0x57ea);
    let config = Config::paper_default();
    let executor = WorkloadExecutor::analytic();
    for case in 0..prop_cases(10) {
        let spec = TraceSpec::surf_lisa(
            rng.range_f64(0.2, 3.0),
            rng.range_f64(30.0, 300.0),
        );
        let seed = rng.next_u64();
        let trace = if rng.chance(0.5) {
            ArrivalTrace::poisson(&spec, seed)
        } else {
            ArrivalTrace::bursty(&spec, 1 + rng.below(4), seed)
        };
        let ownership = if rng.chance(0.5) {
            TraceOwnership::RoundRobin
        } else {
            TraceOwnership::Fixed(SchedulerKind::Topsis)
        };
        let pods = match ownership {
            TraceOwnership::RoundRobin => trace.to_pods_round_robin(),
            TraceOwnership::Fixed(kind) => trace.to_pods(kind),
        };
        let n_regions = 1 + rng.below(3);
        let specs: Vec<RegionSpec> = (0..n_regions)
            .map(|i| {
                RegionSpec::new(&format!("r{i}"), config.clone())
                    .with_carbon(random_region_signal(&mut rng))
            })
            .collect();
        let params = FederationParams::with_beta_and_seed(
            config.experiment.contention_beta,
            seed,
        );
        let engine = FederationEngine::new(&specs, params, &executor);
        let dispatch = random_dispatch(&mut rng);

        let mut scheds = federation_schedulers(&config, seed, n_regions);
        let mut dispatcher = build_dispatcher(dispatch);
        let eager = engine.run(pods, dispatcher.as_mut(), &mut scheds);

        let n = trace.entries.len();
        let mut mem = InMemoryTrace::new(trace.entries);
        let mut source = StreamArrivals::new(&mut mem, ownership);
        let mut scheds = federation_schedulers(&config, seed, n_regions);
        let mut dispatcher = build_dispatcher(dispatch);
        let streamed = engine
            .run_source(&mut source, dispatcher.as_mut(), &mut scheds)
            .expect("in-memory traces cannot fail");

        assert_eq!(eager.regions.len(), streamed.regions.len());
        for (ri, (a, b)) in
            eager.regions.iter().zip(&streamed.regions).enumerate()
        {
            let (a, b) = (&a.run, &b.run);
            assert_eq!(
                a.records.len(),
                b.records.len(),
                "case {case} region {ri} (seed {seed})"
            );
            for (x, y) in a.records.iter().zip(&b.records) {
                assert_eq!(x.pod, y.pod, "case {case} (seed {seed})");
                assert_eq!(x.node, y.node, "case {case} pod {}", x.pod);
                assert_eq!(x.start_s.to_bits(), y.start_s.to_bits());
                assert_eq!(x.finish_s.to_bits(), y.finish_s.to_bits());
                assert_eq!(x.wait_s.to_bits(), y.wait_s.to_bits());
                assert_eq!(x.attempts, y.attempts);
                assert_eq!(
                    x.joules.to_bits(),
                    y.joules.to_bits(),
                    "case {case} pod {}",
                    x.pod
                );
            }
            assert_eq!(a.unschedulable, b.unschedulable, "case {case}");
            assert_eq!(a.events, b.events, "case {case}");
            assert_eq!(a.node_timeline, b.node_timeline, "case {case}");
            assert_eq!(a.makespan_s.to_bits(), b.makespan_s.to_bits());
            for kind in [SchedulerKind::Topsis, SchedulerKind::DefaultK8s] {
                assert_eq!(
                    a.meter.total_kj(kind).to_bits(),
                    b.meter.total_kj(kind).to_bits(),
                    "case {case}"
                );
                assert_eq!(
                    a.meter.total_co2_g(kind).to_bits(),
                    b.meter.total_co2_g(kind).to_bits(),
                    "case {case}"
                );
            }
            assert_eq!(
                a.meter.idle_co2_g().to_bits(),
                b.meter.idle_co2_g().to_bits(),
                "case {case}"
            );
        }
        // Streaming recycles slots; eager holds the whole trace.
        assert_eq!(eager.peak_live_pods, n, "case {case}");
        assert!(
            streamed.peak_live_pods <= n,
            "case {case}: streamed peak {} > trace length {n}",
            streamed.peak_live_pods
        );
    }
}

#[test]
fn prop_down_sampler_deterministic_ordered_one_in_k() {
    // Across random traces and keep-rates: the same seed always
    // selects the same slice (bit-identical), the slice is an
    // order-preserving subsequence, and each class keeps its
    // one-in-k share — floor(m/k) or ceil(m/k) of m entries, so no
    // class is ever silently dropped by a sampling phase.
    let mut rng = Rng::seed_from_u64(0xd057);
    for case in 0..prop_cases(30) {
        let spec = TraceSpec::surf_lisa(
            rng.range_f64(0.5, 4.0),
            rng.range_f64(40.0, 250.0),
        );
        let trace = ArrivalTrace::poisson(&spec, rng.next_u64());
        let keep = 1 + rng.below(8);
        let seed = rng.next_u64();

        let mut a = DownSampler::new(
            InMemoryTrace::new(trace.entries.clone()),
            keep,
            seed,
        );
        let mut b = DownSampler::new(
            InMemoryTrace::new(trace.entries.clone()),
            keep,
            seed,
        );
        let (xs, ys) = (drain_trace(&mut a), drain_trace(&mut b));
        assert_eq!(xs.len(), ys.len(), "case {case}");
        for (x, y) in xs.iter().zip(&ys) {
            assert_eq!(x.at_s.to_bits(), y.at_s.to_bits(), "case {case}");
            assert_eq!(x.class, y.class);
            assert_eq!(x.epochs, y.epochs);
        }

        // Order-preserving subsequence of the input.
        let mut it = trace.entries.iter();
        for x in &xs {
            assert!(
                it.any(|e| e.at_s.to_bits() == x.at_s.to_bits()
                    && e.class == x.class
                    && e.epochs == x.epochs),
                "case {case}: kept entry not a subsequence match"
            );
        }

        // Per-class one-in-k share.
        for class in [
            WorkloadClass::Light,
            WorkloadClass::Medium,
            WorkloadClass::Complex,
        ] {
            let m =
                trace.entries.iter().filter(|e| e.class == class).count();
            let kept = xs.iter().filter(|e| e.class == class).count();
            assert!(
                kept >= m / keep && kept <= m.div_ceil(keep),
                "case {case}: class {class:?} kept {kept} of {m} at 1/{keep}"
            );
        }
    }
}

#[test]
fn prop_malformed_traces_rejected_with_line_numbers() {
    // Corrupt one random line of an otherwise-valid JSONL trace in a
    // random way; the chunked reader must fail (at any chunk size)
    // and name the corrupted line, never silently skip or reorder.
    let mut rng = Rng::seed_from_u64(0xbad1);
    for case in 0..prop_cases(40) {
        let spec = TraceSpec::surf_lisa(
            rng.range_f64(0.5, 2.0),
            rng.range_f64(40.0, 120.0),
        );
        let trace = ArrivalTrace::poisson(&spec, rng.next_u64());
        if trace.entries.len() < 2 {
            continue;
        }
        let mut lines: Vec<String> = trace
            .entries
            .iter()
            .map(|e| e.to_json().to_string())
            .collect();
        let victim = rng.below(lines.len() - 1);
        let kind = rng.below(4);
        match kind {
            0 => lines[victim] = "{not json".into(),
            1 => {
                lines[victim] =
                    "{\"at_s\":-1.0,\"class\":\"light\",\"epochs\":2}".into()
            }
            2 => {
                lines[victim] = format!(
                    "{{\"at_s\":{},\"class\":\"light\",\"epochs\":2.5}}",
                    trace.entries[victim].at_s
                )
            }
            // Swap two adjacent arrivals to break the time order; the
            // error lands on whichever line now runs backwards.
            _ => lines.swap(victim, victim + 1),
        }
        let text = lines.join("\n");
        let chunk = 1 + rng.below(64);
        let mut reader =
            ChunkedTraceReader::new(text.as_bytes(), TraceFormat::Jsonl, chunk)
                .expect("construction never parses");
        let mut err = None;
        loop {
            match reader.next_entry() {
                Ok(Some(_)) => {}
                Ok(None) => break,
                Err(e) => {
                    err = Some(e.to_string());
                    break;
                }
            }
        }
        let err = err.unwrap_or_else(|| {
            panic!("case {case}: corruption kind {kind} not rejected")
        });
        // Swapped equal-time lines cannot corrupt; all other kinds
        // must name the victim line.
        let expect_a = format!("trace line {}", victim + 1);
        let expect_b = format!("trace line {}", victim + 2);
        assert!(
            err.contains(&expect_a) || err.contains(&expect_b),
            "case {case} kind {kind}: error '{err}' names neither \
             '{expect_a}' nor '{expect_b}'"
        );
    }
}

/// The `trace replay --full` memory contract: a million-pod synthetic
/// trace streams through the engine end to end while the reader holds
/// at most one burst and the engine's live-pod high-water mark stays
/// a small fraction of the trace (slots are recycled at completion).
/// Heavy (minutes in release); run explicitly via
/// `cargo test --release --test properties full_scale -- --ignored`.
#[test]
#[ignore = "heavy: ~1M pods through the engine; CI runs it in release"]
fn trace_replay_full_scale_streams_bounded() {
    let mut config = Config::paper_default();
    config.cluster = ClusterConfig::scaled(80);
    let seed = config.experiment.seed;
    let ctx = ExperimentContext::new(config);
    let mut synth =
        SynthTrace::poisson(TraceSpec::surf_lisa(100.0, 10_500.0), seed);
    let s = run_trace_replay(
        &ctx,
        &mut synth,
        TraceOwnership::RoundRobin,
        Vec::new(),
    )
    .expect("synthetic traces cannot fail");
    assert!(s.pods >= 1_000_000, "trace too small: {} pods", s.pods);
    assert_eq!(s.completed + s.unschedulable, s.pods);
    assert_eq!(s.peak_buffered, 1, "poisson synth buffers one entry");
    assert!(
        s.peak_live_pods < s.pods / 10,
        "peak live pods {} not bounded well below {} total",
        s.peak_live_pods,
        s.pods
    );
    assert!(s.total_kj.is_finite() && s.total_kj > 0.0);
}
