//! Randomized property tests (seeded, deterministic) over the L3
//! invariants — the in-tree stand-in for proptest (DESIGN.md §1b).
//!
//! Each property runs a few hundred random cases from a fixed seed;
//! shrinkage is traded for printing the failing case's seed so it can
//! be replayed.

use greenpod::cluster::{ClusterState, Pod};
use greenpod::config::{
    ClusterConfig, CompetitionLevel, Config, ExperimentConfig,
    SchedulerKind, WeightingScheme,
};
use greenpod::mcda::{
    self, Criterion, DecisionProblem, Direction, McdaMethod,
};
use greenpod::scheduler::{
    DefaultK8sScheduler, Estimator, GreenPodScheduler, Scheduler,
};
use greenpod::simulation::{RunResult, SimulationEngine, SimulationParams};
use greenpod::util::rng::Rng;
use greenpod::workload::{
    generate_pods, generate_pods_with, ArrivalProcess, WorkloadClass,
    WorkloadExecutor,
};

/// Case-count knob: `GREENPOD_PROP_CASES` scales every property's
/// case count for hardening runs (e.g. `GREENPOD_PROP_CASES=2000
/// cargo test --release -q`); unset/garbage keeps the in-tree default.
fn prop_cases(default_cases: usize) -> usize {
    std::env::var("GREENPOD_PROP_CASES")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .filter(|&n| n > 0)
        .unwrap_or(default_cases)
}

fn random_problem(rng: &mut Rng) -> DecisionProblem {
    let n = 1 + rng.below(40);
    let c = 1 + rng.below(7);
    let matrix: Vec<f64> =
        (0..n * c).map(|_| rng.range_f64(0.01, 100.0)).collect();
    let criteria: Vec<Criterion> = (0..c)
        .map(|_| {
            let w = rng.range_f64(0.01, 2.0);
            if rng.chance(0.5) {
                Criterion::benefit(w)
            } else {
                Criterion::cost(w)
            }
        })
        .collect();
    DecisionProblem::new(matrix, n, criteria)
}

#[test]
fn prop_topsis_closeness_in_unit_interval() {
    let mut rng = Rng::seed_from_u64(1);
    for case in 0..prop_cases(300) {
        let p = random_problem(&mut rng);
        for (i, s) in mcda::topsis_closeness(&p).iter().enumerate() {
            assert!(
                (-1e-9..=1.0 + 1e-9).contains(s),
                "case {case}: row {i} score {s}"
            );
            assert!(s.is_finite());
        }
    }
}

#[test]
fn prop_dominated_alternative_never_first() {
    // Build a problem, then append a row strictly dominated by row 0;
    // the dominated row must never outrank its dominator.
    let mut rng = Rng::seed_from_u64(2);
    for case in 0..prop_cases(200) {
        let mut p = random_problem(&mut rng);
        let c = p.c();
        let mut dominated = Vec::with_capacity(c);
        for col in 0..c {
            let v = p.at(0, col);
            let delta = rng.range_f64(0.1, 1.0);
            dominated.push(match p.criteria[col].direction {
                Direction::Benefit => (v - delta).max(0.001),
                Direction::Cost => v + delta,
            });
        }
        p.matrix.extend_from_slice(&dominated);
        p.n += 1;
        let scores = mcda::topsis_closeness(&p);
        assert!(
            scores[0] >= scores[p.n - 1] - 1e-9,
            "case {case}: dominated row scored {} > dominator {}",
            scores[p.n - 1],
            scores[0]
        );
    }
}

#[test]
fn prop_all_mcda_methods_rank_dominator_over_dominated() {
    let mut rng = Rng::seed_from_u64(3);
    for case in 0..prop_cases(100) {
        let mut p = random_problem(&mut rng);
        let c = p.c();
        let mut dominated = Vec::with_capacity(c);
        for col in 0..c {
            let v = p.at(0, col);
            dominated.push(match p.criteria[col].direction {
                Direction::Benefit => v * 0.5,
                Direction::Cost => v * 2.0 + 0.1,
            });
        }
        p.matrix.extend_from_slice(&dominated);
        p.n += 1;
        for method in McdaMethod::ALL {
            let scores = method.scores(&p);
            assert!(
                scores[0] >= scores[p.n - 1] - 1e-9,
                "case {case} {method:?}: dominated outranked dominator"
            );
        }
    }
}

#[test]
fn prop_topsis_scale_invariance() {
    // Multiplying any column by a positive constant leaves closeness
    // unchanged (vector normalization).
    let mut rng = Rng::seed_from_u64(4);
    for case in 0..prop_cases(200) {
        let p = random_problem(&mut rng);
        let col = rng.below(p.c());
        let k = rng.range_f64(0.1, 50.0);
        let mut scaled = p.clone();
        for row in 0..p.n {
            scaled.matrix[row * p.c() + col] *= k;
        }
        let a = mcda::topsis_closeness(&p);
        let b = mcda::topsis_closeness(&scaled);
        for (x, y) in a.iter().zip(&b) {
            assert!(
                (x - y).abs() < 1e-6,
                "case {case}: column {col} scale {k} changed {x} -> {y}"
            );
        }
    }
}

#[test]
fn prop_cluster_never_overcommits() {
    // Random bind/release sequences keep every node within capacity and
    // release restores the exact previous free amounts.
    let mut rng = Rng::seed_from_u64(5);
    for _case in 0..prop_cases(100) {
        let mut state =
            ClusterState::from_config(&ClusterConfig::paper_default());
        let mut live: Vec<Pod> = Vec::new();
        let mut id = 0u64;
        for _step in 0..200 {
            if rng.chance(0.6) || live.is_empty() {
                let class = match rng.below(3) {
                    0 => WorkloadClass::Light,
                    1 => WorkloadClass::Medium,
                    _ => WorkloadClass::Complex,
                };
                let pod =
                    Pod::new(id, class, SchedulerKind::Topsis, 0.0, 1);
                id += 1;
                let node = rng.below(state.nodes().len());
                let fits = state.fits(node, pod.requests);
                let res = state.bind(&pod, node, 0.0);
                assert_eq!(res.is_ok(), fits);
                if res.is_ok() {
                    live.push(pod);
                }
            } else {
                let idx = rng.below(live.len());
                let pod = live.swap_remove(idx);
                state.release(pod.id, 0.0).unwrap();
            }
            for n in 0..state.nodes().len() {
                assert!(state.free_cpu(n) <= state.node(n).cpu_millis);
                assert!(state.free_memory(n) <= state.node(n).memory_mib);
                let u = state.cpu_utilization(n);
                assert!((0.0..=1.0).contains(&u));
            }
        }
        // Release everything: cluster returns to pristine.
        for pod in live {
            state.release(pod.id, 0.0).unwrap();
        }
        for n in 0..state.nodes().len() {
            assert_eq!(state.free_cpu(n), state.node(n).cpu_millis);
            assert_eq!(state.free_memory(n), state.node(n).memory_mib);
            assert_eq!(state.pods_on(n), 0);
        }
    }
}

#[test]
fn prop_schedulers_always_pick_feasible_nodes() {
    let mut rng = Rng::seed_from_u64(6);
    let energy = greenpod::config::EnergyModelConfig::default();
    for case in 0..prop_cases(60) {
        let mut state =
            ClusterState::from_config(&ClusterConfig::paper_default());
        let mut topsis = GreenPodScheduler::new(
            Estimator::with_defaults(energy.clone()),
            match rng.below(4) {
                0 => WeightingScheme::General,
                1 => WeightingScheme::EnergyCentric,
                2 => WeightingScheme::PerformanceCentric,
                _ => WeightingScheme::ResourceEfficient,
            },
        );
        let mut default = DefaultK8sScheduler::new(case as u64);
        let mut id = 0u64;
        for _ in 0..40 {
            let class = match rng.below(3) {
                0 => WorkloadClass::Light,
                1 => WorkloadClass::Medium,
                _ => WorkloadClass::Complex,
            };
            let kind = if rng.chance(0.5) {
                SchedulerKind::Topsis
            } else {
                SchedulerKind::DefaultK8s
            };
            let pod = Pod::new(id, class, kind, 0.0, 1);
            id += 1;
            let d = match kind {
                SchedulerKind::Topsis => topsis.schedule(&state, &pod),
                SchedulerKind::DefaultK8s => default.schedule(&state, &pod),
            };
            match d.node {
                Some(n) => {
                    // The chosen node must satisfy the filter — bind
                    // must succeed.
                    state.bind(&pod, n, 0.0).unwrap();
                }
                None => {
                    // Unschedulable must mean NO node fits.
                    assert!(
                        state.feasible_nodes(pod.requests).is_empty(),
                        "case {case}: scheduler gave up though nodes fit"
                    );
                }
            }
        }
    }
}

#[test]
fn prop_generator_counts_and_determinism() {
    let mut rng = Rng::seed_from_u64(7);
    let cfg = ExperimentConfig::default();
    for _ in 0..prop_cases(50) {
        let seed = rng.next_u64();
        for level in CompetitionLevel::ALL {
            let a = generate_pods(level, &cfg, seed);
            let b = generate_pods(level, &cfg, seed);
            assert_eq!(a.pods.len(), level.total_pods());
            for (x, y) in a.pods.iter().zip(&b.pods) {
                assert_eq!(x.id, y.id);
                assert_eq!(x.class, y.class);
                assert_eq!(x.scheduler, y.scheduler);
                assert_eq!(x.arrival_s, y.arrival_s);
            }
            // Half/half ownership per Table V.
            let t = a.owned_by(SchedulerKind::Topsis).len();
            let d = a.owned_by(SchedulerKind::DefaultK8s).len();
            assert_eq!(t, d);
        }
    }
}

#[test]
fn prop_simulation_conservation() {
    // Across random seeds: every generated pod either completes with
    // positive energy and start >= arrival, or is reported
    // unschedulable; energy sums are finite and positive.
    let mut rng = Rng::seed_from_u64(8);
    let config = Config::paper_default();
    let executor = greenpod::workload::WorkloadExecutor::analytic();
    for _case in 0..prop_cases(30) {
        let seed = rng.next_u64();
        let level = match rng.below(3) {
            0 => CompetitionLevel::Low,
            1 => CompetitionLevel::Medium,
            _ => CompetitionLevel::High,
        };
        let ctx = greenpod::experiments::ExperimentContext::new(
            config.clone(),
        );
        let result = greenpod::experiments::run_once(
            &ctx,
            level,
            WeightingScheme::EnergyCentric,
            seed,
            &executor,
        );
        assert_eq!(
            result.records.len() + result.unschedulable.len(),
            level.total_pods()
        );
        for r in &result.records {
            assert!(r.joules > 0.0 && r.joules.is_finite());
            assert!(r.start_s >= r.arrival_s - 1e-9);
            assert!(r.finish_s > r.start_s);
            assert!(r.wait_s >= 0.0);
        }
        assert!(result.makespan_s.is_finite());
    }
}

#[test]
fn prop_weights_simplex_under_adaptation() {
    use greenpod::scheduler::AdaptiveWeighting;
    let mut rng = Rng::seed_from_u64(9);
    for _ in 0..prop_cases(100) {
        let a = AdaptiveWeighting {
            lo: rng.range_f64(0.0, 0.9),
            hi: rng.range_f64(0.0, 1.0),
            target: WeightingScheme::ResourceEfficient,
        };
        let mut state =
            ClusterState::from_config(&ClusterConfig::paper_default());
        // Random load.
        let mut id = 0;
        for _ in 0..rng.below(10) {
            let pod = Pod::new(id, WorkloadClass::Medium,
                               SchedulerKind::Topsis, 0.0, 1);
            id += 1;
            let node = rng.below(state.nodes().len());
            let _ = state.bind(&pod, node, 0.0);
        }
        for base in WeightingScheme::ALL {
            let w = a.weights(&state, base);
            let sum: f64 = w.iter().sum();
            assert!((sum - 1.0).abs() < 1e-9, "{w:?}");
            assert!(w.iter().all(|&x| x >= 0.0));
        }
    }
}

// ---------------------------------------------------------------------
// Event-kernel properties (the discrete-event engine's contract).

/// Run one seeded deployment through the event engine under a random
/// arrival process.
fn run_event_case(
    config: &Config,
    executor: &WorkloadExecutor,
    level: CompetitionLevel,
    process: ArrivalProcess,
    seed: u64,
) -> RunResult {
    let pods =
        generate_pods_with(level, &config.experiment, seed, process).pods;
    let engine = SimulationEngine::new(
        config,
        SimulationParams::with_beta_and_seed(
            config.experiment.contention_beta,
            seed,
        ),
        executor,
    );
    let mut topsis = GreenPodScheduler::new(
        Estimator::with_defaults(config.energy.clone()),
        WeightingScheme::EnergyCentric,
    );
    let mut default = DefaultK8sScheduler::new(seed);
    engine.run(pods, &mut topsis, &mut default)
}

fn random_process(rng: &mut Rng) -> ArrivalProcess {
    match rng.below(3) {
        0 => ArrivalProcess::Jittered {
            mean_gap_s: rng.range_f64(0.0, 2.0),
        },
        1 => ArrivalProcess::Poisson {
            rate_per_s: rng.range_f64(0.2, 5.0),
        },
        _ => ArrivalProcess::Bursty {
            burst_size: 1 + rng.below(6),
            burst_gap_s: rng.range_f64(0.5, 30.0),
            intra_gap_s: rng.range_f64(0.0, 0.2),
        },
    }
}

#[test]
fn prop_event_times_monotone() {
    // The kernel's clock contract: the event log is non-decreasing in
    // time for every arrival process and seed.
    let mut rng = Rng::seed_from_u64(10);
    let config = Config::paper_default();
    let executor = WorkloadExecutor::analytic();
    for case in 0..prop_cases(25) {
        let level = match rng.below(3) {
            0 => CompetitionLevel::Low,
            1 => CompetitionLevel::Medium,
            _ => CompetitionLevel::High,
        };
        let process = random_process(&mut rng);
        let seed = rng.next_u64();
        let r = run_event_case(&config, &executor, level, process, seed);
        assert!(!r.events.is_empty());
        for w in r.events.windows(2) {
            assert!(
                w[1].at_s >= w[0].at_s,
                "case {case} ({process:?}, seed {seed}): \
                 event time regressed {} -> {}",
                w[0].at_s,
                w[1].at_s
            );
        }
    }
}

#[test]
fn prop_no_pod_lost_between_arrival_and_completion() {
    // Conservation across the kernel: every generated pod is either
    // completed exactly once or reported unschedulable, under every
    // arrival process.
    let mut rng = Rng::seed_from_u64(11);
    let config = Config::paper_default();
    let executor = WorkloadExecutor::analytic();
    for case in 0..prop_cases(25) {
        let level = match rng.below(3) {
            0 => CompetitionLevel::Low,
            1 => CompetitionLevel::Medium,
            _ => CompetitionLevel::High,
        };
        let process = random_process(&mut rng);
        let seed = rng.next_u64();
        let r = run_event_case(&config, &executor, level, process, seed);
        assert_eq!(
            r.records.len() + r.unschedulable.len(),
            level.total_pods(),
            "case {case} ({process:?}, seed {seed}): pods lost"
        );
        let mut ids: Vec<u64> = r
            .records
            .iter()
            .map(|x| x.pod)
            .chain(r.unschedulable.iter().copied())
            .collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(
            ids.len(),
            level.total_pods(),
            "case {case}: duplicate pod outcome"
        );
        let arrivals =
            r.events.iter().filter(|e| e.kind == "pod-arrival").count();
        let completions =
            r.events.iter().filter(|e| e.kind == "pod-completed").count();
        assert_eq!(arrivals, level.total_pods());
        assert_eq!(completions, r.records.len());
        for rec in &r.records {
            assert!(rec.wait_s >= 0.0);
            assert!(rec.attempts >= 1);
            assert!(rec.start_s >= rec.arrival_s - 1e-9);
            assert!(rec.finish_s > rec.start_s);
            assert!(rec.joules.is_finite() && rec.joules > 0.0);
        }
    }
}

#[test]
fn prop_batch_mode_equals_event_mode_at_t0() {
    // With every arrival at t = 0 the event kernel must reproduce the
    // synchronous batch pass exactly: same placements, same start and
    // finish times, same waits; energy matches to integration rounding.
    let mut rng = Rng::seed_from_u64(12);
    let config = Config::paper_default();
    let executor = WorkloadExecutor::analytic();
    for case in 0..prop_cases(20) {
        let level = match rng.below(3) {
            0 => CompetitionLevel::Low,
            1 => CompetitionLevel::Medium,
            _ => CompetitionLevel::High,
        };
        let seed = rng.next_u64();
        let mut pods =
            generate_pods(level, &config.experiment, seed).pods;
        for p in &mut pods {
            p.arrival_s = 0.0;
        }
        let engine = SimulationEngine::new(
            &config,
            SimulationParams::with_beta_and_seed(
                config.experiment.contention_beta,
                seed,
            ),
            &executor,
        );
        let mk = || {
            (
                GreenPodScheduler::new(
                    Estimator::with_defaults(config.energy.clone()),
                    WeightingScheme::EnergyCentric,
                ),
                DefaultK8sScheduler::new(seed),
            )
        };
        let (mut t1, mut d1) = mk();
        let (mut t2, mut d2) = mk();
        let ev = engine.run(pods.clone(), &mut t1, &mut d1);
        let ba = engine.run_batch(pods, &mut t2, &mut d2);
        assert_eq!(
            ev.records.len(),
            ba.records.len(),
            "case {case} (seed {seed})"
        );
        assert_eq!(ev.unschedulable, ba.unschedulable);
        for (x, y) in ev.records.iter().zip(&ba.records) {
            assert_eq!(x.pod, y.pod, "case {case} (seed {seed})");
            assert_eq!(x.node, y.node, "case {case} (seed {seed})");
            assert_eq!(x.start_s, y.start_s);
            assert_eq!(x.finish_s, y.finish_s);
            assert_eq!(x.wait_s, y.wait_s);
            assert_eq!(x.attempts, y.attempts);
            assert!(
                (x.joules - y.joules).abs() <= 1e-9 * x.joules.max(1.0),
                "case {case}: joules {} vs {}",
                x.joules,
                y.joules
            );
        }
        assert_eq!(ev.makespan_s, ba.makespan_s);
    }
}
