//! Real-world impact arithmetic — paper §V.E/F and Table VII.
//!
//! Extrapolates a measured optimization percentage to SURF-Lisa-scale
//! clusters: energy (MWh), CO₂ (metric tons, eGRID factor), vehicle
//! equivalents (EPA), electricity cost (EIA rate) and carbon-credit
//! value (World Bank range).


use crate::config::EnergyModelConfig;

/// Joules per kWh — the single unit bridge between the config's
/// g/kWh surface and the engine's g/J signal space.
pub const J_PER_KWH: f64 = 3.6e6;

/// Grid carbon intensity as grams CO₂ per joule, derived from the
/// config's eGRID emission factor (lb CO₂ per kWh). The carbon-aware
/// scheduling profile scores candidates with this; Table VII's
/// annual-tonnage arithmetic uses the same factor at MWh scale.
pub fn grams_co2_per_joule(cfg: &EnergyModelConfig) -> f64 {
    // lb → g (453.59237), kWh → J.
    cfg.co2_lb_per_kwh * 453.59237 / J_PER_KWH
}

/// Extrapolation parameters (defaults = the paper's §V.E inputs).
#[derive(Debug, Clone)]
pub struct ImpactParams {
    /// Average jobs per day (SURF Lisa: 6,304 from SLURM logs).
    pub jobs_per_day: f64,
    /// Average energy per job (kWh; paper derives 0.024 from the blade
    /// model at its typical workload parameters).
    pub kwh_per_job: f64,
    /// Measured energy optimization as a fraction (paper: 0.1938, the
    /// all-levels average of Table VI).
    pub optimization: f64,
    /// Clusters in the deployment (1 = single, 10 = medium data center).
    pub clusters: u32,
}

impl ImpactParams {
    /// §V.E single-cluster inputs with a supplied optimization fraction.
    pub fn surf_lisa(optimization: f64) -> Self {
        Self {
            jobs_per_day: 6304.0,
            kwh_per_job: 0.024,
            optimization,
            clusters: 1,
        }
    }

    pub fn with_clusters(mut self, clusters: u32) -> Self {
        self.clusters = clusters;
        self
    }
}

/// The Table VII row set for one deployment size.
#[derive(Debug, Clone)]
pub struct ImpactAssessment {
    pub clusters: u32,
    pub daily_mwh: f64,
    pub monthly_mwh: f64,
    pub annual_mwh: f64,
    pub annual_co2_tons: f64,
    pub vehicles_equivalent: f64,
    pub annual_cost_usd: f64,
    pub annual_credit_usd_min: f64,
    pub annual_credit_usd_max: f64,
    pub total_1yr_usd_min: f64,
    pub total_1yr_usd_max: f64,
    pub total_5yr_usd_min: f64,
    pub total_5yr_usd_max: f64,
}

impl ImpactAssessment {
    /// Compute Table VII from the extrapolation inputs.
    pub fn compute(cfg: &EnergyModelConfig, p: &ImpactParams) -> Self {
        let c = p.clusters as f64;
        // Daily MWh saved: kWh/job × jobs/day × optimization / 1000.
        let daily_mwh = p.kwh_per_job * p.jobs_per_day * p.optimization
            / 1000.0
            * c;
        let monthly_mwh = daily_mwh * 30.0;
        let annual_mwh = daily_mwh * 365.0;
        // eGRID: lb/kWh → kg/MWh → metric tons.
        let kg_per_mwh = cfg.co2_lb_per_kwh * 0.4536 * 1000.0;
        let annual_co2_tons = annual_mwh * kg_per_mwh / 1000.0;
        let vehicles_equivalent = annual_co2_tons / cfg.vehicle_tons_per_year;
        let annual_cost_usd = annual_mwh * 1000.0 * cfg.usd_per_kwh;
        let annual_credit_usd_min =
            annual_co2_tons * cfg.carbon_credit_usd_min;
        let annual_credit_usd_max =
            annual_co2_tons * cfg.carbon_credit_usd_max;
        Self {
            clusters: p.clusters,
            daily_mwh,
            monthly_mwh,
            annual_mwh,
            annual_co2_tons,
            vehicles_equivalent,
            annual_cost_usd,
            annual_credit_usd_min,
            annual_credit_usd_max,
            total_1yr_usd_min: annual_cost_usd + annual_credit_usd_min,
            total_1yr_usd_max: annual_cost_usd + annual_credit_usd_max,
            total_5yr_usd_min: 5.0 * (annual_cost_usd + annual_credit_usd_min),
            total_5yr_usd_max: 5.0 * (annual_cost_usd + annual_credit_usd_max),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// §V.E/F publishes intermediate numbers; we must match them when
    /// fed the same inputs (optimization = 19.38%).
    #[test]
    fn reproduces_paper_single_cluster_numbers() {
        let cfg = EnergyModelConfig::default();
        let a = ImpactAssessment::compute(&cfg,
                                          &ImpactParams::surf_lisa(0.1938));
        assert!((a.daily_mwh - 0.0293).abs() < 0.0005, "{}", a.daily_mwh);
        assert!((a.monthly_mwh - 0.88).abs() < 0.01, "{}", a.monthly_mwh);
        assert!((a.annual_mwh - 10.70).abs() < 0.05, "{}", a.annual_mwh);
        // Paper: 3.99 metric tons CO₂ (10.6872 MWh × 373.2 kg/MWh).
        assert!((a.annual_co2_tons - 3.99).abs() < 0.03, "{}",
                a.annual_co2_tons);
        assert!((a.vehicles_equivalent - 0.87).abs() < 0.01);
        // Paper: ≈ $1,380 annual electricity savings.
        assert!((a.annual_cost_usd - 1380.0).abs() < 10.0, "{}",
                a.annual_cost_usd);
        // Credits: $1.84 – $667.
        assert!((a.annual_credit_usd_min - 1.84).abs() < 0.05);
        assert!((a.annual_credit_usd_max - 667.0).abs() < 5.0);
        // Combined: $1,381 – $2,047; 5 yr: $6,907 – $10,233.
        assert!((a.total_1yr_usd_min - 1381.0).abs() < 12.0);
        assert!((a.total_1yr_usd_max - 2047.0).abs() < 15.0);
        assert!((a.total_5yr_usd_min - 6907.0).abs() < 60.0);
        assert!((a.total_5yr_usd_max - 10233.0).abs() < 75.0);
    }

    /// Medium data center = 10 clusters: everything scales ×10.
    #[test]
    fn reproduces_paper_ten_cluster_numbers() {
        let cfg = EnergyModelConfig::default();
        let p = ImpactParams::surf_lisa(0.1938).with_clusters(10);
        let a = ImpactAssessment::compute(&cfg, &p);
        assert!((a.annual_mwh - 107.02).abs() < 0.5, "{}", a.annual_mwh);
        assert!((a.annual_co2_tons - 39.94).abs() < 0.3);
        assert!((a.vehicles_equivalent - 8.70).abs() < 0.1);
        assert!((a.annual_cost_usd - 13795.0).abs() < 100.0);
        assert!((a.total_5yr_usd_max - 102326.0).abs() < 750.0);
    }

    #[test]
    fn grams_per_joule_consistent_with_table7_arithmetic() {
        // 1 MWh = 3.6e9 J; the per-joule factor must reproduce the
        // kg-per-MWh figure Table VII uses (0.8229 lb/kWh → ~373 kg).
        let cfg = EnergyModelConfig::default();
        let kg_per_mwh = grams_co2_per_joule(&cfg) * 3.6e9 / 1000.0;
        let expect = cfg.co2_lb_per_kwh * 0.4536 * 1000.0;
        assert!(
            (kg_per_mwh - expect).abs() < 0.05,
            "{kg_per_mwh} vs {expect}"
        );
    }

    #[test]
    fn zero_optimization_zero_impact() {
        let cfg = EnergyModelConfig::default();
        let a = ImpactAssessment::compute(&cfg,
                                          &ImpactParams::surf_lisa(0.0));
        assert_eq!(a.annual_mwh, 0.0);
        assert_eq!(a.total_5yr_usd_max, 0.0);
    }
}
