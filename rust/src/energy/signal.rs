//! Time-varying grid carbon intensity — the signal the carbon-aware
//! scheduling profile, the energy meter's CO₂ ledger and the
//! autoscaler's carbon windows all read (DESIGN.md §"Carbon signal").
//!
//! A [`CarbonSignal`] is a sampled intensity series: `(t_s, gCO₂/J)`
//! points over virtual time, interpolated as a step function or
//! piecewise-linearly, and *clamped* at both endpoints (before the
//! first sample and after the last the signal holds the boundary
//! value). A one-sample series is exactly a constant — and constants
//! are algebraically factored out of every integral, so the
//! constant-signal path reproduces the legacy scalar
//! [`grams_co2_per_joule`] arithmetic bit-for-bit (the differential
//! property in `rust/tests/properties.rs` pins this).
//!
//! The synthetic diurnal generator is a piecewise-linear triangle wave
//! (clean at phase 0, dirtiest at half period) rather than a sinusoid:
//! real grid curves are not sinusoids either, and pure arithmetic keeps
//! the Python oracle (`python/tools/make_golden_trace.py`) reproducible
//! bit-for-bit across languages — no libm in the loop.
//!
//! [`grams_co2_per_joule`]: crate::energy::grams_co2_per_joule

use anyhow::{ensure, Result};

/// How a [`CarbonSignal`] interpolates between samples.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SignalShape {
    /// Each sample's intensity holds until the next sample.
    Step,
    /// Linear interpolation between neighboring samples.
    Linear,
}

impl SignalShape {
    pub fn label(self) -> &'static str {
        match self {
            SignalShape::Step => "step",
            SignalShape::Linear => "linear",
        }
    }
}

impl std::str::FromStr for SignalShape {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        match s {
            "step" => Ok(SignalShape::Step),
            "linear" => Ok(SignalShape::Linear),
            other => anyhow::bail!("unknown signal shape `{other}` (step|linear)"),
        }
    }
}

/// Grid carbon intensity over virtual time (gCO₂ per joule).
#[derive(Debug, Clone, PartialEq)]
pub struct CarbonSignal {
    /// `(t_s, g_per_j)` samples, strictly increasing in time, non-empty.
    points: Vec<(f64, f64)>,
    shape: SignalShape,
}

impl Default for CarbonSignal {
    /// A zero-intensity constant — carbon metering off.
    fn default() -> Self {
        Self::constant(0.0)
    }
}

impl CarbonSignal {
    /// A flat signal: `at` returns exactly `g_per_j` everywhere, and
    /// the meter derives grams as `joules * g_per_j` with no integral
    /// in the loop — the legacy scalar path, bit-for-bit.
    pub fn constant(g_per_j: f64) -> Self {
        Self { points: vec![(0.0, g_per_j)], shape: SignalShape::Step }
    }

    /// The energy model's eGRID scalar as a constant signal.
    pub fn from_energy(cfg: &crate::config::EnergyModelConfig) -> Self {
        Self::constant(super::grams_co2_per_joule(cfg))
    }

    fn series(
        points: Vec<(f64, f64)>,
        shape: SignalShape,
    ) -> Result<Self> {
        ensure!(!points.is_empty(), "carbon signal has no samples");
        for (i, &(t, v)) in points.iter().enumerate() {
            ensure!(
                t.is_finite(),
                "carbon signal sample {i}: timestamp {t} is not finite"
            );
            ensure!(
                v.is_finite() && v >= 0.0,
                "carbon signal sample {i}: intensity {v} must be a \
                 finite non-negative number"
            );
            if i > 0 {
                ensure!(
                    t > points[i - 1].0,
                    "carbon signal sample {i}: timestamp {t} does not \
                     increase over {}",
                    points[i - 1].0
                );
            }
        }
        Ok(Self { points, shape })
    }

    /// A step series: each sample's intensity holds until the next.
    pub fn step(points: Vec<(f64, f64)>) -> Result<Self> {
        Self::series(points, SignalShape::Step)
    }

    /// A piecewise-linear series.
    pub fn linear(points: Vec<(f64, f64)>) -> Result<Self> {
        Self::series(points, SignalShape::Linear)
    }

    /// Synthetic diurnal cycle over one period: a piecewise-linear
    /// triangle wave from `base * (1 - swing)` at t = 0 (the clean
    /// phase) up to `base * (1 + swing)` at half period and back.
    /// Outside `[0, period_s]` the signal clamps to the clean endpoint
    /// values. `samples + 1` evenly spaced points are generated;
    /// `samples` must be even so half period is a sample point and the
    /// documented peak is actually reached.
    pub fn diurnal(
        base_g_per_j: f64,
        swing: f64,
        period_s: f64,
        samples: u32,
    ) -> Result<Self> {
        ensure!(
            base_g_per_j.is_finite() && base_g_per_j >= 0.0,
            "diurnal base intensity {base_g_per_j} must be finite and \
             non-negative"
        );
        ensure!(
            (0.0..=1.0).contains(&swing),
            "diurnal swing {swing} must be in [0, 1]"
        );
        ensure!(
            period_s.is_finite() && period_s > 0.0,
            "diurnal period {period_s} must be a finite positive number"
        );
        ensure!(
            samples >= 2 && samples % 2 == 0,
            "diurnal needs an even sample count >= 2 (got {samples}) so \
             the half-period peak is sampled"
        );
        let points = (0..=samples)
            .map(|k| {
                let p = k as f64 / samples as f64;
                let t = period_s * p;
                // Triangle: 0 at p = 0, 1 at p = 0.5, 0 at p = 1.
                let tri = 1.0 - (2.0 * p - 1.0).abs();
                let v = base_g_per_j * (1.0 + swing * (2.0 * tri - 1.0));
                (t, v)
            })
            .collect();
        Self::series(points, SignalShape::Linear)
    }

    /// `Some(g)` when the series is a single sample — the degenerate
    /// case that behaves, and is metered, exactly as a constant.
    pub fn constant_value(&self) -> Option<f64> {
        if self.points.len() == 1 {
            Some(self.points[0].1)
        } else {
            None
        }
    }

    /// The samples, in time order.
    pub fn points(&self) -> &[(f64, f64)] {
        &self.points
    }

    pub fn shape(&self) -> SignalShape {
        self.shape
    }

    /// Intensity at virtual time `t_s` (gCO₂/J). Clamped: before the
    /// first sample it returns the first intensity, after the last the
    /// last.
    pub fn at(&self, t_s: f64) -> f64 {
        let (t0, v0) = self.points[0];
        if t_s <= t0 {
            return v0;
        }
        let &(tn, vn) = self.points.last().expect("non-empty");
        if t_s >= tn {
            return vn;
        }
        for w in self.points.windows(2) {
            let (ts, vs) = w[0];
            let (te, ve) = w[1];
            if t_s < te {
                return match self.shape {
                    SignalShape::Step => vs,
                    SignalShape::Linear => {
                        vs + (ve - vs) * ((t_s - ts) / (te - ts))
                    }
                };
            }
        }
        vn
    }

    /// `∫ intensity dt` over `[a_s, b_s]` (g·s/J — multiply by watts
    /// for grams). Zero when `b_s <= a_s`. Clamped tails integrate at
    /// the boundary intensities. Additive across interval splits to
    /// float rounding (property-tested).
    pub fn integral(&self, a_s: f64, b_s: f64) -> f64 {
        if b_s <= a_s {
            return 0.0;
        }
        let mut total = 0.0;
        let (t0, v0) = self.points[0];
        if a_s < t0 {
            total += v0 * (b_s.min(t0) - a_s);
        }
        for w in self.points.windows(2) {
            let (ts, vs) = w[0];
            let (te, ve) = w[1];
            // greenpod-lint: allow(silent-clamp) reason="interval intersection: lower edge of [a,b] ∩ [ts,te], not a time-ordering repair"
            let lo = a_s.max(ts);
            let hi = b_s.min(te);
            if hi > lo {
                total += match self.shape {
                    SignalShape::Step => vs * (hi - lo),
                    SignalShape::Linear => {
                        let va = vs + (ve - vs) * ((lo - ts) / (te - ts));
                        let vb = vs + (ve - vs) * ((hi - ts) / (te - ts));
                        0.5 * (va + vb) * (hi - lo)
                    }
                };
            }
        }
        let &(tn, vn) = self.points.last().expect("non-empty");
        if b_s > tn {
            // greenpod-lint: allow(silent-clamp) reason="tail integration starts at the later of the window start and the final sample — an intersection, not a repair"
            total += vn * (b_s - a_s.max(tn));
        }
        total
    }

    /// Earliest time strictly after `now_s` at which the signal's
    /// dirtiness with respect to `threshold` (strictly above vs not)
    /// changes, or `None` when it never changes again (constant
    /// signals, and any time past the last crossing — the clamped tail
    /// holds its value forever). The autoscaler's carbon windows wake
    /// at this instant so tightening and deferral release do not wait
    /// for an unrelated kernel event.
    ///
    /// Candidates are the sample timestamps plus, for linear shapes,
    /// the in-segment threshold crossings; the first candidate whose
    /// dirtiness differs from `now_s`'s is returned. A rising linear
    /// segment reports the transition at its end sample (the crossing
    /// point itself sits exactly *at* the threshold, which is clean
    /// under the strict comparison) — conservative by part of one
    /// segment, never early.
    pub fn next_transition(&self, now_s: f64, threshold: f64) -> Option<f64> {
        let dirty_now = self.at(now_s) > threshold;
        let mut candidates: Vec<f64> = Vec::new();
        for w in self.points.windows(2) {
            let (ts, vs) = w[0];
            let (te, ve) = w[1];
            if te > now_s {
                candidates.push(te);
            }
            if self.shape == SignalShape::Linear && ve != vs {
                let cross = ts + (threshold - vs) / (ve - vs) * (te - ts);
                if cross > now_s && cross > ts && cross < te {
                    candidates.push(cross);
                }
            }
        }
        candidates.sort_by(crate::util::stats::total_order);
        candidates
            .into_iter()
            .find(|&t| (self.at(t) > threshold) != dirty_now)
    }

    /// Intensity at quantile `q` of the sample values — the shared
    /// nearest-rank convention of `util::stats`, so this, `metrics::
    /// Summary` and the autoscaler's wait-p95 trigger agree on what a
    /// percentile means by construction. The autoscaler's carbon
    /// windows derive their "dirty" threshold from this.
    pub fn percentile(&self, q: f64) -> f64 {
        let vals: Vec<f64> = self.points.iter().map(|&(_, v)| v).collect();
        crate::util::stats::nearest_rank(&vals, q)
            .expect("carbon signal is non-empty by construction")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn step3() -> CarbonSignal {
        CarbonSignal::step(vec![(0.0, 4.0), (10.0, 2.0), (20.0, 6.0)])
            .unwrap()
    }

    fn linear3() -> CarbonSignal {
        CarbonSignal::linear(vec![(0.0, 4.0), (10.0, 2.0), (20.0, 6.0)])
            .unwrap()
    }

    #[test]
    fn constant_everywhere() {
        let s = CarbonSignal::constant(3.5);
        assert_eq!(s.constant_value(), Some(3.5));
        for t in [-5.0, 0.0, 1e9] {
            assert_eq!(s.at(t), 3.5);
        }
        assert_eq!(s.integral(2.0, 7.0), 3.5 * 5.0);
    }

    #[test]
    fn lookups_clamp_at_endpoints() {
        for s in [step3(), linear3()] {
            assert_eq!(s.at(-100.0), 4.0);
            assert_eq!(s.at(0.0), 4.0);
            assert_eq!(s.at(20.0), 6.0);
            assert_eq!(s.at(1e6), 6.0);
            assert_eq!(s.constant_value(), None);
        }
    }

    #[test]
    fn step_holds_left_sample() {
        let s = step3();
        assert_eq!(s.at(5.0), 4.0);
        assert_eq!(s.at(10.0), 2.0);
        assert_eq!(s.at(19.999), 2.0);
    }

    #[test]
    fn linear_interpolates_between_samples() {
        let s = linear3();
        assert!((s.at(5.0) - 3.0).abs() < 1e-12);
        assert!((s.at(15.0) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn integral_matches_hand_arithmetic() {
        let s = step3();
        // 4·10 + 2·10 = 60 over the sampled span.
        assert!((s.integral(0.0, 20.0) - 60.0).abs() < 1e-12);
        // Clamped tails: 5 s before at 4, 5 s after at 6.
        assert!((s.integral(-5.0, 25.0) - (20.0 + 60.0 + 30.0)).abs()
            < 1e-12);
        let l = linear3();
        // Trapezoids: (4+2)/2·10 + (2+6)/2·10 = 70.
        assert!((l.integral(0.0, 20.0) - 70.0).abs() < 1e-12);
        assert_eq!(l.integral(5.0, 5.0), 0.0);
        assert_eq!(l.integral(9.0, 3.0), 0.0);
    }

    #[test]
    fn diurnal_is_clean_dirty_clean() {
        let s = CarbonSignal::diurnal(100.0, 0.5, 240.0, 12).unwrap();
        assert_eq!(s.points().len(), 13);
        assert!((s.at(0.0) - 50.0).abs() < 1e-9);
        assert!((s.at(120.0) - 150.0).abs() < 1e-9);
        assert!((s.at(240.0) - 50.0).abs() < 1e-9);
        // Clamps to the clean endpoint beyond the period.
        assert!((s.at(1e4) - 50.0).abs() < 1e-9);
        // Monotone rise to the peak, fall after.
        assert!(s.at(60.0) > s.at(30.0));
        assert!(s.at(200.0) < s.at(150.0));
    }

    #[test]
    fn percentile_nearest_rank() {
        let s = step3();
        assert_eq!(s.percentile(0.0), 2.0);
        assert_eq!(s.percentile(0.5), 4.0);
        assert_eq!(s.percentile(1.0), 6.0);
        assert_eq!(CarbonSignal::constant(7.0).percentile(0.9), 7.0);
    }

    #[test]
    fn bad_series_rejected() {
        assert!(CarbonSignal::step(vec![]).is_err());
        assert!(CarbonSignal::step(vec![(0.0, f64::NAN)]).is_err());
        assert!(CarbonSignal::step(vec![(f64::INFINITY, 1.0)]).is_err());
        assert!(CarbonSignal::step(vec![(0.0, -1.0)]).is_err());
        // Non-monotone and duplicate timestamps.
        assert!(
            CarbonSignal::step(vec![(5.0, 1.0), (2.0, 1.0)]).is_err()
        );
        assert!(
            CarbonSignal::linear(vec![(5.0, 1.0), (5.0, 2.0)]).is_err()
        );
        assert!(CarbonSignal::diurnal(1.0, 1.5, 10.0, 4).is_err());
        assert!(CarbonSignal::diurnal(1.0, 0.5, 0.0, 4).is_err());
        assert!(CarbonSignal::diurnal(1.0, 0.5, 10.0, 1).is_err());
        // Odd sample counts would clip the half-period peak.
        assert!(CarbonSignal::diurnal(1.0, 0.5, 10.0, 11).is_err());
        assert!(CarbonSignal::diurnal(f64::NAN, 0.5, 10.0, 4).is_err());
    }

    #[test]
    fn next_transition_finds_step_and_linear_crossings() {
        // Step 4 → 2 → 6 with threshold 3: dirty on [0, 10) and
        // [20, ∞); transitions at 10 (→clean) and 20 (→dirty).
        let s = step3();
        assert_eq!(s.next_transition(0.0, 3.0), Some(10.0));
        assert_eq!(s.next_transition(12.0, 3.0), Some(20.0));
        // Clamped tail: dirty forever, no further transition.
        assert_eq!(s.next_transition(25.0, 3.0), None);
        // Threshold above every sample: never dirty, never transitions.
        assert_eq!(s.next_transition(0.0, 10.0), None);

        // Linear 4 → 2 → 6: falls through 3 at t = 5 (exact crossing),
        // rises through it inside [10, 20] — reported at the segment
        // end (conservative under the strict comparison).
        let l = linear3();
        let down = l.next_transition(0.0, 3.0).unwrap();
        assert!((down - 5.0).abs() < 1e-12, "{down}");
        assert_eq!(l.next_transition(6.0, 3.0), Some(20.0));

        // Constants never transition.
        assert_eq!(
            CarbonSignal::constant(2.0).next_transition(0.0, 1.0),
            None
        );
    }

    #[test]
    fn one_sample_series_is_constant() {
        let s = CarbonSignal::linear(vec![(30.0, 2.5)]).unwrap();
        assert_eq!(s.constant_value(), Some(2.5));
        for t in [0.0, 30.0, 500.0] {
            assert_eq!(s.at(t), 2.5);
        }
        assert_eq!(s.integral(0.0, 4.0), 2.5 * 4.0);
    }
}
