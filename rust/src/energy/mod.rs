//! Energy substrate: the Dayarathna blade-server power model (the
//! paper's own model, §V.E), per-node energy metering, and the carbon /
//! cost arithmetic behind Table VII.

mod carbon;
mod meter;
mod power;

pub use carbon::{grams_co2_per_joule, ImpactAssessment, ImpactParams};
pub use meter::{EnergyMeter, PodEnergy};
pub use power::{
    blade_power_watts, node_idle_watts, node_power_watts,
    pod_idle_claim_watts, pod_power_watts,
};
