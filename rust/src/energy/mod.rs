//! Energy substrate: the Dayarathna blade-server power model (the
//! paper's own model, §V.E), per-node energy metering, the carbon /
//! cost arithmetic behind Table VII, and the time-varying grid
//! carbon-intensity signal (DESIGN.md §"Carbon signal").

mod carbon;
mod meter;
mod power;
mod signal;

pub use carbon::{
    grams_co2_per_joule, ImpactAssessment, ImpactParams, J_PER_KWH,
};
pub use meter::{EnergyMeter, PodEnergy};
pub use signal::{CarbonSignal, SignalShape};
pub use power::{
    blade_power_watts, node_idle_watts, node_power_watts,
    pod_idle_claim_watts, pod_power_watts,
};
