//! Energy metering: integrates pod power over execution time and keeps
//! the per-pod / per-scheduler / per-class ledgers the evaluation
//! (Table VI, §V.D) reads out.

use std::collections::HashMap;


use crate::cluster::{Node, PodId};
use crate::config::{EnergyModelConfig, SchedulerKind};
use crate::energy::pod_power_watts;
use crate::workload::WorkloadClass;

/// Energy record for one completed pod.
#[derive(Debug, Clone)]
pub struct PodEnergy {
    pub pod: PodId,
    pub class: WorkloadClass,
    pub scheduler: SchedulerKind,
    pub node: usize,
    /// Execution duration (simulated seconds).
    pub duration_s: f64,
    /// Attributed energy (joules, at the wall).
    pub joules: f64,
}

/// The run-wide energy ledger.
#[derive(Debug, Clone, Default)]
pub struct EnergyMeter {
    records: Vec<PodEnergy>,
}

impl EnergyMeter {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record a pod execution: `share` is the CPU fraction of `node` the
    /// pod occupied for `duration_s` seconds.
    #[allow(clippy::too_many_arguments)]
    pub fn record(
        &mut self,
        cfg: &EnergyModelConfig,
        pod: PodId,
        class: WorkloadClass,
        scheduler: SchedulerKind,
        node: &Node,
        share: f64,
        duration_s: f64,
    ) -> f64 {
        let joules = pod_power_watts(cfg, node, share) * duration_s;
        self.records.push(PodEnergy {
            pod,
            class,
            scheduler,
            node: node.id,
            duration_s,
            joules,
        });
        joules
    }

    pub fn records(&self) -> &[PodEnergy] {
        &self.records
    }

    /// Total energy (kJ) consumed by pods owned by `kind`.
    pub fn total_kj(&self, kind: SchedulerKind) -> f64 {
        self.records
            .iter()
            .filter(|r| r.scheduler == kind)
            .map(|r| r.joules)
            .sum::<f64>()
            / 1000.0
    }

    /// Mean per-pod energy (kJ) for pods owned by `kind` — the unit the
    /// paper's Table VI reports.
    pub fn mean_kj_per_pod(&self, kind: SchedulerKind) -> f64 {
        let (sum, n) = self
            .records
            .iter()
            .filter(|r| r.scheduler == kind)
            .fold((0.0, 0usize), |(s, n), r| (s + r.joules, n + 1));
        if n == 0 {
            0.0
        } else {
            sum / n as f64 / 1000.0
        }
    }

    /// Per-class mean energy (kJ/pod) for one scheduler — §V.D's
    /// workload analysis.
    pub fn per_class_kj(
        &self,
        kind: SchedulerKind,
    ) -> HashMap<WorkloadClass, f64> {
        let mut sums: HashMap<WorkloadClass, (f64, usize)> = HashMap::new();
        for r in self.records.iter().filter(|r| r.scheduler == kind) {
            let e = sums.entry(r.class).or_insert((0.0, 0));
            e.0 += r.joules;
            e.1 += 1;
        }
        sums.into_iter()
            .map(|(k, (s, n))| (k, s / n as f64 / 1000.0))
            .collect()
    }

    /// Mean execution duration per class for one scheduler (Table IV
    /// "execution performance").
    pub fn per_class_duration(
        &self,
        kind: SchedulerKind,
    ) -> HashMap<WorkloadClass, f64> {
        let mut sums: HashMap<WorkloadClass, (f64, usize)> = HashMap::new();
        for r in self.records.iter().filter(|r| r.scheduler == kind) {
            let e = sums.entry(r.class).or_insert((0.0, 0));
            e.0 += r.duration_s;
            e.1 += 1;
        }
        sums.into_iter()
            .map(|(k, (s, n))| (k, s / n as f64))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::NodeCategory;

    fn node(id: usize, power_scale: f64) -> Node {
        Node {
            id,
            name: format!("n{id}"),
            category: NodeCategory::A,
            machine_type: "e2-medium".into(),
            cpu_millis: 2000,
            memory_mib: 4096,
            speed_factor: 0.7,
            power_scale,
            ready: true,
        }
    }

    #[test]
    fn ledger_accumulates_and_averages() {
        let cfg = EnergyModelConfig::default();
        let mut m = EnergyMeter::new();
        let n = node(0, 0.45);
        let j1 = m.record(&cfg, 1, WorkloadClass::Light,
                          SchedulerKind::Topsis, &n, 0.1, 10.0);
        let j2 = m.record(&cfg, 2, WorkloadClass::Light,
                          SchedulerKind::Topsis, &n, 0.1, 10.0);
        assert!(j1 > 0.0);
        assert!((m.total_kj(SchedulerKind::Topsis)
            - (j1 + j2) / 1000.0).abs() < 1e-12);
        assert!((m.mean_kj_per_pod(SchedulerKind::Topsis)
            - j1 / 1000.0).abs() < 1e-12);
        assert_eq!(m.total_kj(SchedulerKind::DefaultK8s), 0.0);
        assert_eq!(m.mean_kj_per_pod(SchedulerKind::DefaultK8s), 0.0);
    }

    #[test]
    fn efficient_node_uses_less_energy() {
        let cfg = EnergyModelConfig::default();
        let mut m = EnergyMeter::new();
        let a = node(0, 0.45);
        let c = node(1, 1.6);
        let ja = m.record(&cfg, 1, WorkloadClass::Medium,
                          SchedulerKind::Topsis, &a, 0.25, 20.0);
        let jc = m.record(&cfg, 2, WorkloadClass::Medium,
                          SchedulerKind::DefaultK8s, &c, 0.25, 20.0);
        assert!(ja < jc, "A-node energy {ja} !< C-node energy {jc}");
    }

    #[test]
    fn per_class_breakdown() {
        let cfg = EnergyModelConfig::default();
        let mut m = EnergyMeter::new();
        let n = node(0, 1.0);
        m.record(&cfg, 1, WorkloadClass::Light, SchedulerKind::Topsis,
                 &n, 0.1, 5.0);
        m.record(&cfg, 2, WorkloadClass::Complex, SchedulerKind::Topsis,
                 &n, 0.5, 40.0);
        let per = m.per_class_kj(SchedulerKind::Topsis);
        assert!(per[&WorkloadClass::Complex] > per[&WorkloadClass::Light]);
        let dur = m.per_class_duration(SchedulerKind::Topsis);
        assert_eq!(dur[&WorkloadClass::Complex], 40.0);
    }
}
