//! Energy metering: integrates pod power over execution time and keeps
//! the per-pod / per-scheduler / per-class ledgers the evaluation
//! (Table VI, §V.D) reads out.
//!
//! Two accounting modes share one ledger:
//! * **single-shot** ([`EnergyMeter::record`]) — power × duration in
//!   one multiply, for callers that know the full duration up front
//!   (the real-time serve loop);
//! * **interval integration** ([`EnergyMeter::start`] /
//!   [`EnergyMeter::advance`] / [`EnergyMeter::finish`]) — the
//!   discrete-event engine advances the meter at every event boundary
//!   and each running pod's energy accumulates piecewise over the
//!   intervals, which is what lets future work vary power within a
//!   pod's lifetime (DVFS, carbon-intensity curves) without touching
//!   the engine.

use std::collections::BTreeMap;

use crate::cluster::{Node, PodId};
use crate::config::{EnergyModelConfig, SchedulerKind};
use crate::energy::{
    node_idle_watts, pod_idle_claim_watts, pod_power_watts, CarbonSignal,
};
use crate::workload::WorkloadClass;

/// Energy record for one completed pod.
#[derive(Debug, Clone)]
pub struct PodEnergy {
    pub pod: PodId,
    pub class: WorkloadClass,
    pub scheduler: SchedulerKind,
    pub node: usize,
    /// Execution duration (simulated seconds).
    pub duration_s: f64,
    /// Attributed energy (joules, at the wall).
    pub joules: f64,
    /// Grid CO₂ attributed over the execution (grams): power integrated
    /// against the meter's [`CarbonSignal`]. Under a constant signal
    /// this is exactly `joules * g_per_j` — the legacy scalar path.
    pub grams: f64,
}

/// A pod currently accumulating energy (interval-integration mode).
#[derive(Debug, Clone)]
struct RunningEntry {
    class: WorkloadClass,
    scheduler: SchedulerKind,
    node: usize,
    watts: f64,
    /// The idle-floor share of `watts` — handed back to the node's
    /// ledger when the pod finishes.
    idle_claim_watts: f64,
    started_s: f64,
    acc_joules: f64,
    /// Time-varying-signal grams (unused — and left at zero — under a
    /// constant signal, where grams derive from `acc_joules` exactly).
    acc_grams: f64,
}

/// A powered-on node's idle-floor ledger: integrates the node's
/// *unattributed* idle draw — the idle floor minus the shares claimed
/// by running pods — over its online (Ready) intervals. This is the
/// waste an autoscaler's scale-in eliminates.
#[derive(Debug, Clone)]
struct NodeLedger {
    idle_watts: f64,
    /// Σ idle-claims of pods currently running on the node.
    claimed_watts: f64,
    online: bool,
    acc_joules: f64,
    /// Time-varying-signal grams (zero under a constant signal, where
    /// grams derive from `acc_joules` exactly).
    acc_grams: f64,
}

/// The run-wide energy ledger.
#[derive(Debug, Clone, Default)]
pub struct EnergyMeter {
    records: Vec<PodEnergy>,
    /// Pods mid-integration (BTreeMap: `advance` walks every entry, so
    /// the walk order must be deterministic).
    running: BTreeMap<PodId, RunningEntry>,
    /// Per-node idle ledgers (BTreeMap: deterministic iteration).
    nodes: BTreeMap<usize, NodeLedger>,
    /// Grid intensity the CO₂ ledger integrates against (default: a
    /// zero constant — carbon metering off).
    carbon: CarbonSignal,
    /// Virtual time up to which all running pods are integrated.
    last_s: f64,
}

impl EnergyMeter {
    pub fn new() -> Self {
        Self::default()
    }

    /// Attach the grid-intensity signal the CO₂ ledger integrates
    /// against. Set before any accrual (the engine does this at run
    /// start); a constant signal keeps grams exactly `joules × g`.
    pub fn with_carbon(mut self, carbon: CarbonSignal) -> Self {
        self.carbon = carbon;
        self
    }

    pub fn carbon(&self) -> &CarbonSignal {
        &self.carbon
    }

    /// Record a pod execution: `share` is the CPU fraction of `node` the
    /// pod occupied for `duration_s` seconds starting at virtual time
    /// `at_s` (the CO₂ ledger integrates the signal over that window).
    #[allow(clippy::too_many_arguments)]
    pub fn record(
        &mut self,
        cfg: &EnergyModelConfig,
        pod: PodId,
        class: WorkloadClass,
        scheduler: SchedulerKind,
        node: &Node,
        share: f64,
        duration_s: f64,
        at_s: f64,
    ) -> f64 {
        let watts = pod_power_watts(cfg, node, share);
        let joules = watts * duration_s;
        let grams = match self.carbon.constant_value() {
            Some(g) => joules * g,
            None => watts * self.carbon.integral(at_s, at_s + duration_s),
        };
        self.records.push(PodEnergy {
            pod,
            class,
            scheduler,
            node: node.id,
            duration_s,
            joules,
            grams,
        });
        joules
    }

    /// Begin interval-integrated metering for `pod` at virtual time
    /// `at_s`. The pod's draw is sampled once at start (contention is
    /// frozen at bind time — `simulation::contention`).
    #[allow(clippy::too_many_arguments)]
    pub fn start(
        &mut self,
        cfg: &EnergyModelConfig,
        pod: PodId,
        class: WorkloadClass,
        scheduler: SchedulerKind,
        node: &Node,
        share: f64,
        at_s: f64,
    ) {
        self.advance(at_s);
        let watts = pod_power_watts(cfg, node, share);
        let idle_claim_watts = pod_idle_claim_watts(cfg, node, share);
        if let Some(ledger) = self.nodes.get_mut(&node.id) {
            ledger.claimed_watts += idle_claim_watts;
        }
        self.running.insert(
            pod,
            RunningEntry {
                class,
                scheduler,
                node: node.id,
                watts,
                idle_claim_watts,
                started_s: at_s,
                acc_joules: 0.0,
                acc_grams: 0.0,
            },
        );
    }

    /// Integrate every running pod's power — and every online node's
    /// unattributed idle floor — over `[last, now]` and move the
    /// integration frontier to `now`. Idempotent at equal times; never
    /// moves the frontier backwards. Grams integrate alongside joules
    /// against the carbon signal; a constant signal is factored out of
    /// the loop entirely (grams derive from joules at read time, so the
    /// scalar path stays bit-identical).
    pub fn advance(&mut self, now_s: f64) {
        if now_s <= self.last_s {
            return;
        }
        let dt = now_s - self.last_s;
        // ∫ intensity dt over [last, now] (g·s/J), None for constants.
        let gdt = match self.carbon.constant_value() {
            Some(_) => None,
            None => Some(self.carbon.integral(self.last_s, now_s)),
        };
        for entry in self.running.values_mut() {
            entry.acc_joules += entry.watts * dt;
            if let Some(gdt) = gdt {
                entry.acc_grams += entry.watts * gdt;
            }
        }
        for ledger in self.nodes.values_mut() {
            if ledger.online {
                let idle_watts =
                    (ledger.idle_watts - ledger.claimed_watts).max(0.0);
                ledger.acc_joules += idle_watts * dt;
                if let Some(gdt) = gdt {
                    ledger.acc_grams += idle_watts * gdt;
                }
            }
        }
        self.last_s = now_s;
    }

    /// Begin idle-floor metering for a node that powered on (became
    /// Ready) at `at_s`. Idempotent while online; a node that was
    /// offline resumes accrual from `at_s`.
    pub fn node_online(
        &mut self,
        cfg: &EnergyModelConfig,
        node: &Node,
        at_s: f64,
    ) {
        self.advance(at_s);
        let idle_watts = node_idle_watts(cfg, node);
        let ledger = self.nodes.entry(node.id).or_insert(NodeLedger {
            idle_watts,
            claimed_watts: 0.0,
            online: false,
            acc_joules: 0.0,
            acc_grams: 0.0,
        });
        ledger.online = true;
    }

    /// Stop idle-floor metering for a node that powered off (scale-in
    /// or failure) at `at_s`. Unknown or already-offline nodes are a
    /// no-op. Pods still running on the node keep integrating their own
    /// attribution (kube semantics: NotReady gates new bindings, not
    /// executions) — only the node's unattributed idle stops accruing.
    pub fn node_offline(&mut self, node: usize, at_s: f64) {
        self.advance(at_s);
        if let Some(ledger) = self.nodes.get_mut(&node) {
            ledger.online = false;
        }
    }

    /// Close the interval integration for `pod` at `at_s`, emit its
    /// ledger record, and return the accumulated joules.
    ///
    /// Panics if the pod was never [`EnergyMeter::start`]ed — the
    /// engine's bind/complete pairing guarantees it.
    pub fn finish(&mut self, pod: PodId, at_s: f64) -> f64 {
        self.advance(at_s);
        let entry = self
            .running
            .remove(&pod)
            .expect("finish() without matching start()");
        if let Some(ledger) = self.nodes.get_mut(&entry.node) {
            ledger.claimed_watts -= entry.idle_claim_watts;
        }
        let grams = match self.carbon.constant_value() {
            Some(g) => entry.acc_joules * g,
            None => entry.acc_grams,
        };
        self.records.push(PodEnergy {
            pod,
            class: entry.class,
            scheduler: entry.scheduler,
            node: entry.node,
            duration_s: at_s - entry.started_s,
            joules: entry.acc_joules,
            grams,
        });
        entry.acc_joules
    }

    /// Number of pods currently integrating.
    pub fn running_count(&self) -> usize {
        self.running.len()
    }

    /// Total unattributed node-idle energy (kJ) across the run — the
    /// infrastructure cost of keeping nodes powered beyond what running
    /// pods account for. Zero when node metering was never enabled
    /// (single-shot mode, the batch oracle).
    pub fn idle_kj(&self) -> f64 {
        self.nodes.values().map(|l| l.acc_joules).sum::<f64>() / 1000.0
    }

    /// Unattributed idle energy (J) accrued by one node.
    pub fn node_idle_joules(&self, node: usize) -> f64 {
        self.nodes.get(&node).map_or(0.0, |l| l.acc_joules)
    }

    /// Grid CO₂ of one node ledger (grams).
    fn ledger_grams(&self, l: &NodeLedger) -> f64 {
        match self.carbon.constant_value() {
            Some(g) => l.acc_joules * g,
            None => l.acc_grams,
        }
    }

    /// Grid CO₂ of the unattributed node-idle energy (grams) — the
    /// idle floor integrated against the carbon signal.
    pub fn idle_co2_g(&self) -> f64 {
        self.nodes.values().map(|l| self.ledger_grams(l)).sum()
    }

    /// Unattributed idle CO₂ (grams) accrued by one node.
    pub fn node_idle_co2_g(&self, node: usize) -> f64 {
        self.nodes.get(&node).map_or(0.0, |l| self.ledger_grams(l))
    }

    /// Grid CO₂ (grams) attributed to pods owned by `kind`.
    pub fn total_co2_g(&self, kind: SchedulerKind) -> f64 {
        self.records
            .iter()
            .filter(|r| r.scheduler == kind)
            .map(|r| r.grams)
            .sum()
    }

    pub fn records(&self) -> &[PodEnergy] {
        &self.records
    }

    /// Total energy (kJ) consumed by pods owned by `kind`.
    pub fn total_kj(&self, kind: SchedulerKind) -> f64 {
        self.records
            .iter()
            .filter(|r| r.scheduler == kind)
            .map(|r| r.joules)
            .sum::<f64>()
            / 1000.0
    }

    /// Mean per-pod energy (kJ) for pods owned by `kind` — the unit the
    /// paper's Table VI reports.
    pub fn mean_kj_per_pod(&self, kind: SchedulerKind) -> f64 {
        let (sum, n) = self
            .records
            .iter()
            .filter(|r| r.scheduler == kind)
            .fold((0.0, 0usize), |(s, n), r| (s + r.joules, n + 1));
        if n == 0 {
            0.0
        } else {
            sum / n as f64 / 1000.0
        }
    }

    /// Per-class mean energy (kJ/pod) for one scheduler — §V.D's
    /// workload analysis. Ordered map: report rows derived from this
    /// render in class order, identically on every run.
    pub fn per_class_kj(
        &self,
        kind: SchedulerKind,
    ) -> BTreeMap<WorkloadClass, f64> {
        let mut sums: BTreeMap<WorkloadClass, (f64, usize)> = BTreeMap::new();
        for r in self.records.iter().filter(|r| r.scheduler == kind) {
            let e = sums.entry(r.class).or_insert((0.0, 0));
            e.0 += r.joules;
            e.1 += 1;
        }
        sums.into_iter()
            .map(|(k, (s, n))| (k, s / n as f64 / 1000.0))
            .collect()
    }

    /// Mean execution duration per class for one scheduler (Table IV
    /// "execution performance").
    pub fn per_class_duration(
        &self,
        kind: SchedulerKind,
    ) -> BTreeMap<WorkloadClass, f64> {
        let mut sums: BTreeMap<WorkloadClass, (f64, usize)> = BTreeMap::new();
        for r in self.records.iter().filter(|r| r.scheduler == kind) {
            let e = sums.entry(r.class).or_insert((0.0, 0));
            e.0 += r.duration_s;
            e.1 += 1;
        }
        sums.into_iter()
            .map(|(k, (s, n))| (k, s / n as f64))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::NodeCategory;

    fn node(id: usize, power_scale: f64) -> Node {
        Node {
            id,
            name: format!("n{id}"),
            category: NodeCategory::A,
            machine_type: "e2-medium".into(),
            cpu_millis: 2000,
            memory_mib: 4096,
            speed_factor: 0.7,
            power_scale,
            ready: true,
        }
    }

    #[test]
    fn ledger_accumulates_and_averages() {
        let cfg = EnergyModelConfig::default();
        let mut m = EnergyMeter::new();
        let n = node(0, 0.45);
        let j1 = m.record(&cfg, 1, WorkloadClass::Light,
                          SchedulerKind::Topsis, &n, 0.1, 10.0, 0.0);
        let j2 = m.record(&cfg, 2, WorkloadClass::Light,
                          SchedulerKind::Topsis, &n, 0.1, 10.0, 0.0);
        assert!(j1 > 0.0);
        assert!((m.total_kj(SchedulerKind::Topsis)
            - (j1 + j2) / 1000.0).abs() < 1e-12);
        assert!((m.mean_kj_per_pod(SchedulerKind::Topsis)
            - j1 / 1000.0).abs() < 1e-12);
        assert_eq!(m.total_kj(SchedulerKind::DefaultK8s), 0.0);
        assert_eq!(m.mean_kj_per_pod(SchedulerKind::DefaultK8s), 0.0);
    }

    #[test]
    fn efficient_node_uses_less_energy() {
        let cfg = EnergyModelConfig::default();
        let mut m = EnergyMeter::new();
        let a = node(0, 0.45);
        let c = node(1, 1.6);
        let ja = m.record(&cfg, 1, WorkloadClass::Medium,
                          SchedulerKind::Topsis, &a, 0.25, 20.0, 0.0);
        let jc = m.record(&cfg, 2, WorkloadClass::Medium,
                          SchedulerKind::DefaultK8s, &c, 0.25, 20.0, 0.0);
        assert!(ja < jc, "A-node energy {ja} !< C-node energy {jc}");
    }

    #[test]
    fn interval_integration_matches_single_shot() {
        let cfg = EnergyModelConfig::default();
        let n = node(0, 0.45);

        let mut single = EnergyMeter::new();
        let want = single.record(&cfg, 1, WorkloadClass::Medium,
                                 SchedulerKind::Topsis, &n, 0.25, 12.5, 0.0);

        // Same pod integrated across several uneven event intervals.
        let mut meter = EnergyMeter::new();
        meter.start(&cfg, 1, WorkloadClass::Medium, SchedulerKind::Topsis,
                    &n, 0.25, 0.0);
        assert_eq!(meter.running_count(), 1);
        for t in [0.5, 0.5, 3.75, 9.0] {
            meter.advance(t); // includes a deliberate same-time repeat
        }
        let got = meter.finish(1, 12.5);
        assert_eq!(meter.running_count(), 0);
        assert!(
            (got - want).abs() < 1e-9 * want,
            "interval {got} vs single-shot {want}"
        );
        let rec = &meter.records()[0];
        assert_eq!(rec.duration_s, 12.5);
        assert_eq!(rec.joules, got);
    }

    #[test]
    fn advance_never_moves_backwards() {
        let cfg = EnergyModelConfig::default();
        let n = node(0, 1.0);
        let mut meter = EnergyMeter::new();
        meter.start(&cfg, 1, WorkloadClass::Light, SchedulerKind::Topsis,
                    &n, 0.1, 0.0);
        meter.advance(10.0);
        meter.advance(4.0); // ignored: frontier stays at 10
        let j = meter.finish(1, 10.0);
        let mut single = EnergyMeter::new();
        let want = single.record(&cfg, 1, WorkloadClass::Light,
                                 SchedulerKind::Topsis, &n, 0.1, 10.0, 0.0);
        assert!((j - want).abs() < 1e-9 * want);
    }

    #[test]
    fn overlapping_pods_integrate_independently() {
        let cfg = EnergyModelConfig::default();
        let a = node(0, 0.45);
        let c = node(1, 1.6);
        let mut meter = EnergyMeter::new();
        meter.start(&cfg, 1, WorkloadClass::Light, SchedulerKind::Topsis,
                    &a, 0.1, 0.0);
        meter.advance(2.0);
        meter.start(&cfg, 2, WorkloadClass::Light,
                    SchedulerKind::DefaultK8s, &c, 0.1, 2.0);
        meter.advance(5.0);
        let j1 = meter.finish(1, 5.0);
        let j2 = meter.finish(2, 8.0);
        let mut oracle = EnergyMeter::new();
        let w1 = oracle.record(&cfg, 1, WorkloadClass::Light,
                               SchedulerKind::Topsis, &a, 0.1, 5.0, 0.0);
        let w2 = oracle.record(&cfg, 2, WorkloadClass::Light,
                               SchedulerKind::DefaultK8s, &c, 0.1, 6.0, 0.0);
        assert!((j1 - w1).abs() < 1e-9 * w1);
        assert!((j2 - w2).abs() < 1e-9 * w2);
    }

    #[test]
    fn node_idle_accrues_only_while_online() {
        let cfg = EnergyModelConfig::default();
        let n = node(0, 1.0);
        let mut m = EnergyMeter::new();
        m.node_online(&cfg, &n, 0.0);
        m.advance(10.0);
        m.node_offline(0, 10.0);
        m.advance(25.0); // offline: no accrual
        m.node_online(&cfg, &n, 25.0);
        m.advance(30.0);
        let idle_w = crate::energy::node_idle_watts(&cfg, &n);
        let want = idle_w * 15.0; // 10 s + 5 s online
        let got = m.node_idle_joules(0);
        assert!((got - want).abs() < 1e-9 * want, "{got} vs {want}");
        assert!((m.idle_kj() - want / 1000.0).abs() < 1e-12 * want);
    }

    #[test]
    fn running_pod_claims_its_idle_share_from_the_node() {
        // One half-share pod for 10 of 20 online seconds: the node's
        // unattributed idle is full-idle for 10 s + half-idle for 10 s,
        // and pod + node-idle together equal node power integrated at
        // the pod's load — no double counting.
        let cfg = EnergyModelConfig::default();
        let n = node(0, 1.0);
        let mut m = EnergyMeter::new();
        m.node_online(&cfg, &n, 0.0);
        m.start(&cfg, 1, WorkloadClass::Medium, SchedulerKind::Topsis,
                &n, 0.5, 0.0);
        let pod_j = m.finish(1, 10.0);
        m.advance(20.0);
        let idle_w = crate::energy::node_idle_watts(&cfg, &n);
        let claim_w = crate::energy::pod_idle_claim_watts(&cfg, &n, 0.5);
        let want_idle = (idle_w - claim_w) * 10.0 + idle_w * 10.0;
        let got_idle = m.node_idle_joules(0);
        assert!(
            (got_idle - want_idle).abs() < 1e-9 * want_idle,
            "{got_idle} vs {want_idle}"
        );
        let total = pod_j + got_idle;
        let node_at_load =
            crate::energy::node_power_watts(&cfg, &n, 0.5) * 10.0
                + idle_w * 10.0;
        assert!(
            (total - node_at_load).abs() < 1e-9 * node_at_load,
            "attribution {total} != node draw {node_at_load}"
        );
    }

    #[test]
    fn node_online_is_idempotent_and_unknown_offline_is_noop() {
        let cfg = EnergyModelConfig::default();
        let n = node(0, 0.45);
        let mut m = EnergyMeter::new();
        m.node_online(&cfg, &n, 0.0);
        m.node_online(&cfg, &n, 0.0); // repeat: no reset, no double accrual
        m.node_offline(99, 0.0); // never onlined: no-op
        m.advance(8.0);
        let want = crate::energy::node_idle_watts(&cfg, &n) * 8.0;
        assert!((m.node_idle_joules(0) - want).abs() < 1e-9 * want);
        assert_eq!(m.node_idle_joules(99), 0.0);
    }

    #[test]
    fn single_shot_mode_reports_zero_idle() {
        let cfg = EnergyModelConfig::default();
        let mut m = EnergyMeter::new();
        let n = node(0, 1.0);
        m.record(&cfg, 1, WorkloadClass::Light, SchedulerKind::Topsis,
                 &n, 0.1, 10.0, 0.0);
        assert_eq!(m.idle_kj(), 0.0);
    }

    #[test]
    fn per_class_breakdown() {
        let cfg = EnergyModelConfig::default();
        let mut m = EnergyMeter::new();
        let n = node(0, 1.0);
        m.record(&cfg, 1, WorkloadClass::Light, SchedulerKind::Topsis,
                 &n, 0.1, 5.0, 0.0);
        m.record(&cfg, 2, WorkloadClass::Complex, SchedulerKind::Topsis,
                 &n, 0.5, 40.0, 0.0);
        let per = m.per_class_kj(SchedulerKind::Topsis);
        assert!(per[&WorkloadClass::Complex] > per[&WorkloadClass::Light]);
        let dur = m.per_class_duration(SchedulerKind::Topsis);
        assert_eq!(dur[&WorkloadClass::Complex], 40.0);
    }

    #[test]
    fn per_class_tables_are_insertion_order_independent() {
        // Regression for the unordered-iter sweep: the per-class
        // report maps must walk in class order and be byte-identical
        // regardless of the order pods were recorded in.
        let cfg = EnergyModelConfig::default();
        let n = node(0, 1.0);
        let fwd = [
            WorkloadClass::Complex,
            WorkloadClass::Light,
            WorkloadClass::Medium,
        ];
        let mut m1 = EnergyMeter::new();
        for (i, class) in fwd.into_iter().enumerate() {
            m1.record(&cfg, i as u64, class, SchedulerKind::Topsis,
                      &n, 0.1, 10.0, 0.0);
        }
        let mut m2 = EnergyMeter::new();
        for (i, class) in fwd.into_iter().rev().enumerate() {
            m2.record(&cfg, 10 + i as u64, class, SchedulerKind::Topsis,
                      &n, 0.1, 10.0, 0.0);
        }
        let kj = m1.per_class_kj(SchedulerKind::Topsis);
        let keys: Vec<WorkloadClass> = kj.keys().copied().collect();
        let mut sorted = keys.clone();
        sorted.sort();
        assert_eq!(keys, sorted);
        assert_eq!(keys.len(), 3);
        assert_eq!(kj, m2.per_class_kj(SchedulerKind::Topsis));
        assert_eq!(
            m1.per_class_duration(SchedulerKind::Topsis),
            m2.per_class_duration(SchedulerKind::Topsis)
        );
    }

    #[test]
    fn constant_signal_grams_are_exactly_joules_times_scalar() {
        // The scalar-path contract: under a constant signal the grams
        // ledger is bit-identical to multiplying joules by the scalar.
        let cfg = EnergyModelConfig::default();
        let g = crate::energy::grams_co2_per_joule(&cfg);
        let n = node(0, 0.45);
        let mut m =
            EnergyMeter::new().with_carbon(CarbonSignal::constant(g));
        m.node_online(&cfg, &n, 0.0);
        m.start(&cfg, 1, WorkloadClass::Medium, SchedulerKind::Topsis,
                &n, 0.25, 0.0);
        for t in [2.0, 7.5, 11.0] {
            m.advance(t);
        }
        let joules = m.finish(1, 14.0);
        m.advance(20.0);
        let rec = &m.records()[0];
        assert_eq!(rec.grams.to_bits(), (rec.joules * g).to_bits());
        assert_eq!(rec.joules, joules);
        assert_eq!(
            m.node_idle_co2_g(0).to_bits(),
            (m.node_idle_joules(0) * g).to_bits()
        );
        assert_eq!(
            m.total_co2_g(SchedulerKind::Topsis).to_bits(),
            rec.grams.to_bits()
        );
    }

    #[test]
    fn varying_signal_integrates_grams_per_interval() {
        // Step signal: intensity 2 g/J for the first 10 s, 0 after —
        // a pod spanning the step accrues grams only in the dirty half.
        let cfg = EnergyModelConfig::default();
        let n = node(0, 1.0);
        let signal =
            CarbonSignal::step(vec![(0.0, 2.0), (10.0, 0.0)]).unwrap();
        let mut m = EnergyMeter::new().with_carbon(signal);
        m.start(&cfg, 1, WorkloadClass::Light, SchedulerKind::Topsis,
                &n, 0.1, 0.0);
        m.advance(10.0);
        let joules = m.finish(1, 20.0);
        let rec = &m.records()[0];
        let watts = joules / 20.0;
        let want = watts * 2.0 * 10.0;
        assert!(
            (rec.grams - want).abs() < 1e-9 * want,
            "{} vs {want}",
            rec.grams
        );
    }

    #[test]
    fn grams_additive_across_interval_splits() {
        // Integrating through many event boundaries must agree with one
        // whole-interval integration to float rounding.
        let cfg = EnergyModelConfig::default();
        let n = node(0, 1.0);
        let signal = CarbonSignal::linear(vec![
            (0.0, 1.0),
            (6.0, 3.0),
            (15.0, 0.5),
        ])
        .unwrap();
        let run = |splits: &[f64]| {
            let mut m = EnergyMeter::new().with_carbon(signal.clone());
            m.start(&cfg, 1, WorkloadClass::Light, SchedulerKind::Topsis,
                    &n, 0.1, 0.0);
            for &t in splits {
                m.advance(t);
            }
            m.finish(1, 18.0);
            m.records()[0].grams
        };
        let whole = run(&[]);
        let split = run(&[1.0, 2.5, 6.0, 9.9, 15.0, 17.0]);
        assert!(whole > 0.0);
        assert!(
            (whole - split).abs() < 1e-9 * whole,
            "{whole} vs {split}"
        );
    }

    #[test]
    fn single_shot_integrates_signal_over_its_window() {
        let cfg = EnergyModelConfig::default();
        let n = node(0, 1.0);
        let signal =
            CarbonSignal::step(vec![(0.0, 2.0), (10.0, 0.0)]).unwrap();
        let mut m = EnergyMeter::new().with_carbon(signal.clone());
        // Runs 5 s dirty + 5 s clean: half the dirty-rate grams.
        let joules = m.record(&cfg, 1, WorkloadClass::Light,
                              SchedulerKind::Topsis, &n, 0.1, 10.0, 5.0);
        let watts = joules / 10.0;
        let want = watts * signal.integral(5.0, 15.0);
        let got = m.records()[0].grams;
        assert!((got - want).abs() < 1e-9 * want, "{got} vs {want}");
    }
}
