//! Power model — Dayarathna et al. blade-server equation, scaled per
//! node hardware class.
//!
//! `P_blade = 14.45 + 0.236·u_cpu − 4.47e-8·u_mem + 0.00281·u_disk
//!            + 3.1e-8·u_net` watts,
//! `u_cpu` in percent, `u_mem` in accesses/s, `u_disk` in IO ops/s,
//! `u_net` in ops/s. This is exactly the model the paper plugs its
//! "typical workload parameters" into for §V.E (60% CPU, 8M mem acc/s,
//! 350 IOPS, 3M net ops/s → ≈0.024 kWh per 34-min job at PUE 1.45).
//!
//! For the simulated cluster, each node applies its `power_scale` to the
//! blade figure — an e2 shared-core VM draws a fraction of a full blade;
//! an n2-standard-4 draws more (DESIGN.md §1).

use crate::cluster::Node;
use crate::config::EnergyModelConfig;

/// The raw blade-model power at the given utilization parameters (W).
pub fn blade_power_watts(
    cfg: &EnergyModelConfig,
    u_cpu_pct: f64,
    mem_accesses_per_sec: f64,
    disk_iops: f64,
    net_ops_per_sec: f64,
) -> f64 {
    cfg.p_idle
        + cfg.k_cpu * u_cpu_pct
        + cfg.k_mem * mem_accesses_per_sec
        + cfg.k_disk * disk_iops
        + cfg.k_net * net_ops_per_sec
}

/// Blade power with the auxiliary channels (memory/disk/network) scaled
/// proportionally to CPU load — the paper's "typical workload
/// parameters" describe a fully loaded job, so a job at fraction `f`
/// of a node drives `f` of those rates too.
fn blade_power_at_load(cfg: &EnergyModelConfig, load_fraction: f64) -> f64 {
    let f = load_fraction.clamp(0.0, 1.0);
    blade_power_watts(
        cfg,
        100.0 * f,
        cfg.mem_accesses_per_sec * f,
        cfg.disk_iops * f,
        cfg.net_ops_per_sec * f,
    )
}

/// Whole-node power draw (W, at the wall — includes PUE) at CPU-load
/// fraction `u` ∈ [0,1].
pub fn node_power_watts(
    cfg: &EnergyModelConfig,
    node: &Node,
    u: f64,
) -> f64 {
    node.power_scale * blade_power_at_load(cfg, u) * cfg.pue
}

/// A powered-on node's idle floor (W, at the wall) — what the node
/// draws with zero pods. This is the draw the autoscaler eliminates by
/// scaling in: the energy meter integrates it over each node's Ready
/// intervals, minus the idle shares already attributed to running pods
/// (see [`pod_idle_claim_watts`]), so pod accounting and node-idle
/// accounting never double-count a watt.
pub fn node_idle_watts(cfg: &EnergyModelConfig, node: &Node) -> f64 {
    node.power_scale * blade_power_at_load(cfg, 0.0) * cfg.pue
}

/// The idle-floor component of [`pod_power_watts`]: the share of the
/// node's idle draw that "idle cost follows reservation" accounting
/// charges to a pod occupying CPU fraction `share`. Subtracted from the
/// node's unattributed idle accrual while the pod runs.
pub fn pod_idle_claim_watts(
    cfg: &EnergyModelConfig,
    node: &Node,
    share: f64,
) -> f64 {
    let share = share.clamp(0.0, 1.0);
    node.power_scale * blade_power_at_load(cfg, 0.0) * share * cfg.pue
}

/// Power attributed to one pod occupying CPU fraction `share` of `node`
/// (W, at the wall).
///
/// Attribution = the pod's *dynamic* draw plus its proportional share of
/// the node's idle floor — the standard "idle cost follows reservation"
/// accounting, which makes placement on a high-idle node expensive even
/// for small pods (the effect GreenPod's energy criterion exploits).
pub fn pod_power_watts(
    cfg: &EnergyModelConfig,
    node: &Node,
    share: f64,
) -> f64 {
    let share = share.clamp(0.0, 1.0);
    let dynamic =
        blade_power_at_load(cfg, share) - blade_power_at_load(cfg, 0.0);
    let idle_share = blade_power_at_load(cfg, 0.0) * share;
    node.power_scale * (dynamic + idle_share) * cfg.pue
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::NodeCategory;

    fn node(power_scale: f64) -> Node {
        Node {
            id: 0,
            name: "t".into(),
            category: NodeCategory::B,
            machine_type: "n2-standard-2".into(),
            cpu_millis: 2000,
            memory_mib: 8192,
            speed_factor: 1.0,
            power_scale,
            ready: true,
        }
    }

    #[test]
    fn paper_section_5e_job_energy() {
        // §V.E: 60% CPU, 8M mem acc/s, 350 IOPS, 3M net ops/s, 34 min,
        // PUE 1.45 → ≈ 0.024 kWh.
        let cfg = EnergyModelConfig::default();
        let p = blade_power_watts(&cfg, 60.0, 8.0e6, 350.0, 3.0e6);
        let kwh = p * cfg.pue * (34.0 / 60.0) / 1000.0;
        assert!(
            (kwh - 0.024).abs() < 0.001,
            "expected ~0.024 kWh, got {kwh}"
        );
    }

    #[test]
    fn power_monotone_in_load() {
        let cfg = EnergyModelConfig::default();
        let n = node(1.0);
        let p0 = node_power_watts(&cfg, &n, 0.0);
        let p5 = node_power_watts(&cfg, &n, 0.5);
        let p1 = node_power_watts(&cfg, &n, 1.0);
        assert!(p0 > 0.0 && p5 > p0 && p1 > p5);
    }

    #[test]
    fn power_scale_linear() {
        let cfg = EnergyModelConfig::default();
        let lo = node_power_watts(&cfg, &node(0.45), 0.6);
        let hi = node_power_watts(&cfg, &node(1.6), 0.6);
        assert!((hi / lo - 1.6 / 0.45).abs() < 1e-9);
    }

    #[test]
    fn pod_attribution_bounded_by_node_power() {
        let cfg = EnergyModelConfig::default();
        let n = node(1.0);
        let full = pod_power_watts(&cfg, &n, 1.0);
        let whole = node_power_watts(&cfg, &n, 1.0);
        assert!((full - whole).abs() / whole < 1e-9);
        // Half-share pod draws less than half-load node total (which
        // includes the full idle floor).
        assert!(pod_power_watts(&cfg, &n, 0.5) < node_power_watts(&cfg, &n, 0.5));
    }

    #[test]
    fn zero_share_zero_power() {
        let cfg = EnergyModelConfig::default();
        assert_eq!(pod_power_watts(&cfg, &node(1.0), 0.0), 0.0);
    }

    #[test]
    fn idle_watts_is_zero_load_node_power() {
        let cfg = EnergyModelConfig::default();
        let n = node(0.45);
        assert_eq!(node_idle_watts(&cfg, &n), node_power_watts(&cfg, &n, 0.0));
        assert!(node_idle_watts(&cfg, &n) > 0.0);
    }

    #[test]
    fn pod_idle_claims_sum_to_node_idle_at_full_reservation() {
        // Four quarter-share pods claim exactly the node's idle floor —
        // so (idle − Σclaims) is zero on a fully reserved node and no
        // watt is double-counted between pod and node-idle ledgers.
        let cfg = EnergyModelConfig::default();
        let n = node(1.6);
        let claims = 4.0 * pod_idle_claim_watts(&cfg, &n, 0.25);
        let idle = node_idle_watts(&cfg, &n);
        assert!((claims - idle).abs() < 1e-9 * idle);
        // And a full-share pod's claim is its attribution minus the
        // purely dynamic draw.
        let full_claim = pod_idle_claim_watts(&cfg, &n, 1.0);
        let dynamic = pod_power_watts(&cfg, &n, 1.0) - full_claim;
        assert!(dynamic > 0.0);
        assert!((full_claim - idle).abs() < 1e-9 * idle);
    }
}
