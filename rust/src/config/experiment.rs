//! Experimental factorial design — paper Tables III & V.


use crate::workload::WorkloadClass;

/// Which scheduler places a pod (Table V splits each level half/half).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SchedulerKind {
    /// GreenPod's TOPSIS scheduler (the paper's contribution).
    Topsis,
    /// The default kube-scheduler baseline.
    DefaultK8s,
}

/// Resource-contention level — paper Table V.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CompetitionLevel {
    Low,
    Medium,
    High,
}

impl std::str::FromStr for SchedulerKind {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "topsis" => Ok(SchedulerKind::Topsis),
            "default-k8s" | "default" => Ok(SchedulerKind::DefaultK8s),
            other => anyhow::bail!("unknown scheduler `{other}`"),
        }
    }
}

impl std::str::FromStr for CompetitionLevel {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "low" => Ok(CompetitionLevel::Low),
            "medium" => Ok(CompetitionLevel::Medium),
            "high" => Ok(CompetitionLevel::High),
            other => anyhow::bail!(
                "unknown competition level `{other}` (low|medium|high)"
            ),
        }
    }
}

/// Pod counts for one workload class at one competition level.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PodMix {
    pub class: WorkloadClass,
    /// Pods placed by the TOPSIS scheduler.
    pub topsis: usize,
    /// Pods placed by the default scheduler.
    pub default_k8s: usize,
}

impl PodMix {
    pub fn total(&self) -> usize {
        self.topsis + self.default_k8s
    }
}

impl CompetitionLevel {
    pub const ALL: [CompetitionLevel; 3] = [
        CompetitionLevel::Low,
        CompetitionLevel::Medium,
        CompetitionLevel::High,
    ];

    /// Table V, verbatim: (light, medium, complex) pods, half TOPSIS /
    /// half default.
    pub fn pod_mix(self) -> [PodMix; 3] {
        let mix = |class, t, d| PodMix { class, topsis: t, default_k8s: d };
        match self {
            CompetitionLevel::Low => [
                mix(WorkloadClass::Light, 2, 2),
                mix(WorkloadClass::Medium, 1, 1),
                mix(WorkloadClass::Complex, 1, 1),
            ],
            CompetitionLevel::Medium => [
                mix(WorkloadClass::Light, 4, 4),
                mix(WorkloadClass::Medium, 2, 2),
                mix(WorkloadClass::Complex, 1, 1),
            ],
            CompetitionLevel::High => [
                mix(WorkloadClass::Light, 6, 6),
                mix(WorkloadClass::Medium, 3, 3),
                mix(WorkloadClass::Complex, 2, 2),
            ],
        }
    }

    pub fn total_pods(self) -> usize {
        self.pod_mix().iter().map(|m| m.total()).sum()
    }

    pub fn label(self) -> &'static str {
        match self {
            CompetitionLevel::Low => "Low",
            CompetitionLevel::Medium => "Medium",
            CompetitionLevel::High => "High",
        }
    }
}

/// Factorial experiment configuration (Table III) plus run mechanics.
#[derive(Debug, Clone, PartialEq)]
pub struct ExperimentConfig {
    /// Independent seeded replications averaged per cell.
    pub replications: u32,
    /// Base RNG seed; replication r uses `seed + r`.
    pub seed: u64,
    /// Mean pod inter-arrival time (seconds of simulated time). The
    /// paper deploys each level as a burst; small jitter models kubectl
    /// submission spacing.
    pub arrival_jitter_s: f64,
    /// Contention slowdown coefficient (see `simulation::contention`).
    pub contention_beta: f64,
    /// SGD epochs each pod runs (scales Table II task sizes; an epoch is
    /// `artifacts/manifest.json: epoch_steps` kernel steps).
    pub epochs_light: u32,
    pub epochs_medium: u32,
    pub epochs_complex: u32,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        Self {
            replications: 5,
            seed: 20250710,
            arrival_jitter_s: 0.25,
            contention_beta: 0.20,
            // Work ratios follow Table II sample counts (1k/1M/10M) at
            // laptop scale: medium ≈ 8× light work, complex ≈ 32× light
            // (the per-step shapes already differ by 4×/16× FLOPs).
            epochs_light: 2,
            epochs_medium: 4,
            epochs_complex: 8,
        }
    }
}

impl ExperimentConfig {
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.replications >= 1, "need at least 1 replication");
        anyhow::ensure!(
            self.arrival_jitter_s >= 0.0,
            "arrival jitter must be non-negative"
        );
        anyhow::ensure!(
            (0.0..=10.0).contains(&self.contention_beta),
            "contention_beta out of range"
        );
        anyhow::ensure!(
            self.epochs_light >= 1
                && self.epochs_medium >= 1
                && self.epochs_complex >= 1,
            "epoch counts must be >= 1"
        );
        Ok(())
    }

    pub fn epochs_for(&self, class: WorkloadClass) -> u32 {
        match class {
            WorkloadClass::Light => self.epochs_light,
            WorkloadClass::Medium => self.epochs_medium,
            WorkloadClass::Complex => self.epochs_complex,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table5_pod_counts() {
        // Low: 4 light, 2 medium, 2 complex.
        let low = CompetitionLevel::Low.pod_mix();
        assert_eq!(low.iter().map(PodMix::total).collect::<Vec<_>>(),
                   vec![4, 2, 2]);
        // Medium: 8/4/2. High: 12/6/4.
        assert_eq!(CompetitionLevel::Medium.total_pods(), 14);
        assert_eq!(CompetitionLevel::High.total_pods(), 22);
        // Every mix is split half/half between schedulers.
        for level in CompetitionLevel::ALL {
            for m in level.pod_mix() {
                assert_eq!(m.topsis, m.default_k8s, "{level:?} {m:?}");
            }
        }
    }

    #[test]
    fn default_config_validates() {
        ExperimentConfig::default().validate().unwrap();
    }
}
