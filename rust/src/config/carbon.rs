//! Carbon-intensity configuration — the `carbon` section of a config
//! file, materialized as an [`CarbonSignal`] for the meter, the
//! carbon-aware profile and the autoscaler's carbon windows.
//!
//! The config speaks **gCO₂ per kWh** (the unit eGRID publishes, ≈373
//! for the paper's US-average factor); the engine's signal speaks
//! gCO₂ per joule. The default mode is `constant`, which derives the
//! intensity from the energy model's `co2_lb_per_kwh` — exactly the
//! legacy scalar path, so an absent section changes nothing.

use anyhow::{ensure, Result};

use crate::energy::{grams_co2_per_joule, CarbonSignal, SignalShape};

use super::EnergyModelConfig;

/// Joules per kWh (the unit bridge between config and signal space —
/// the same constant `grams_co2_per_joule` converts with).
pub use crate::energy::J_PER_KWH;

/// One sample of a configured intensity trace.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CarbonPoint {
    pub at_s: f64,
    pub g_per_kwh: f64,
}

/// Which intensity signal the run uses.
#[derive(Debug, Clone, PartialEq)]
pub enum CarbonMode {
    /// Flat grid at the energy model's eGRID factor (the default; the
    /// legacy scalar path, bit-for-bit).
    Constant,
    /// Synthetic diurnal cycle (piecewise-linear triangle wave; see
    /// [`CarbonSignal::diurnal`]).
    Diurnal {
        base_g_per_kwh: f64,
        /// Relative swing around the base, in `[0, 1]`.
        swing: f64,
        period_s: f64,
        samples: u32,
    },
    /// Explicit intensity trace.
    Trace { shape: SignalShape, points: Vec<CarbonPoint> },
}

/// The `carbon` config section.
#[derive(Debug, Clone, PartialEq)]
pub struct CarbonConfig {
    pub mode: CarbonMode,
}

impl Default for CarbonConfig {
    fn default() -> Self {
        Self { mode: CarbonMode::Constant }
    }
}

impl CarbonConfig {
    /// Build the runtime signal. Errors surface everything
    /// [`CarbonSignal`]'s constructors reject: non-finite or
    /// non-monotonic timestamps, negative or non-finite intensities,
    /// empty traces, out-of-range diurnal parameters.
    pub fn build_signal(
        &self,
        energy: &EnergyModelConfig,
    ) -> Result<CarbonSignal> {
        match &self.mode {
            CarbonMode::Constant => {
                Ok(CarbonSignal::constant(grams_co2_per_joule(energy)))
            }
            CarbonMode::Diurnal { base_g_per_kwh, swing, period_s, samples } => {
                ensure!(
                    base_g_per_kwh.is_finite(),
                    "carbon: base_g_per_kwh {base_g_per_kwh} is not finite"
                );
                CarbonSignal::diurnal(
                    base_g_per_kwh / J_PER_KWH,
                    *swing,
                    *period_s,
                    *samples,
                )
            }
            CarbonMode::Trace { shape, points } => {
                let points: Vec<(f64, f64)> = points
                    .iter()
                    .map(|p| (p.at_s, p.g_per_kwh / J_PER_KWH))
                    .collect();
                match shape {
                    SignalShape::Step => CarbonSignal::step(points),
                    SignalShape::Linear => CarbonSignal::linear(points),
                }
            }
        }
    }

    /// The runtime signal of a validated config. Panics on an invalid
    /// section — [`CarbonConfig::validate`] (called by
    /// `Config::validate`) is the error path.
    pub fn signal(&self, energy: &EnergyModelConfig) -> CarbonSignal {
        self.build_signal(energy)
            .expect("Config::validate admits only representable carbon signals")
    }

    pub fn validate(&self, energy: &EnergyModelConfig) -> Result<()> {
        self.build_signal(energy).map(|_| ())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_the_scalar_path() {
        let energy = EnergyModelConfig::default();
        let s = CarbonConfig::default().signal(&energy);
        assert_eq!(s.constant_value(), Some(grams_co2_per_joule(&energy)));
    }

    #[test]
    fn diurnal_converts_units() {
        let energy = EnergyModelConfig::default();
        let cfg = CarbonConfig {
            mode: CarbonMode::Diurnal {
                base_g_per_kwh: 360.0,
                swing: 0.5,
                period_s: 86400.0,
                samples: 24,
            },
        };
        cfg.validate(&energy).unwrap();
        let s = cfg.signal(&energy);
        // Peak at half period: 360 * 1.5 g/kWh in g/J.
        let peak = s.at(43200.0);
        assert!(
            (peak - 540.0 / J_PER_KWH).abs() < 1e-12,
            "peak {peak}"
        );
    }

    #[test]
    fn one_sample_trace_is_a_constant() {
        let energy = EnergyModelConfig::default();
        let cfg = CarbonConfig {
            mode: CarbonMode::Trace {
                shape: SignalShape::Linear,
                points: vec![CarbonPoint { at_s: 0.0, g_per_kwh: 400.0 }],
            },
        };
        cfg.validate(&energy).unwrap();
        let s = cfg.signal(&energy);
        assert_eq!(s.constant_value(), Some(400.0 / J_PER_KWH));
        assert_eq!(s.at(0.0), s.at(1e6));
    }

    #[test]
    fn bad_traces_rejected() {
        let energy = EnergyModelConfig::default();
        let mk = |points: Vec<CarbonPoint>| CarbonConfig {
            mode: CarbonMode::Trace { shape: SignalShape::Step, points },
        };
        assert!(mk(vec![]).validate(&energy).is_err());
        assert!(mk(vec![
            CarbonPoint { at_s: f64::NAN, g_per_kwh: 1.0 },
        ])
        .validate(&energy)
        .is_err());
        assert!(mk(vec![
            CarbonPoint { at_s: 10.0, g_per_kwh: 1.0 },
            CarbonPoint { at_s: 5.0, g_per_kwh: 1.0 },
        ])
        .validate(&energy)
        .is_err());
        assert!(mk(vec![
            CarbonPoint { at_s: 0.0, g_per_kwh: -3.0 },
        ])
        .validate(&energy)
        .is_err());
        let bad_diurnal = CarbonConfig {
            mode: CarbonMode::Diurnal {
                base_g_per_kwh: 300.0,
                swing: 2.0,
                period_s: 60.0,
                samples: 8,
            },
        };
        assert!(bad_diurnal.validate(&energy).is_err());
    }
}
