//! JSON (de)serialization for the config types, over `util::json`.
//!
//! Schema mirrors the struct layout:
//! ```json
//! {
//!   "cluster":    {"pools": [{"category": "A", ...}], ...},
//!   "energy":     {"pue": 1.45, ...},
//!   "experiment": {"replications": 5, "seed": 1, ...},
//!   "profiles":   [{"name": "my-hybrid",
//!                   "tie_break": "lowest-index",
//!                   "plugins": [
//!                     {"plugin": "mcda", "weight": 0.7,
//!                      "method": "topsis", "scheme": "energy-centric",
//!                      "percent_scale": true},
//!                     {"plugin": "balanced-allocation", "weight": 0.3}]}]
//! }
//! ```
//! Absent sections/fields fall back to the paper defaults, so a config
//! file only states deviations.

use anyhow::{anyhow, Result};

use crate::cluster::NodeCategory;
use crate::energy::SignalShape;
use crate::util::json::Json;

use super::{
    CarbonConfig, CarbonMode, CarbonPoint, ClusterConfig, Config,
    EnergyModelConfig, ExperimentConfig, NodePoolConfig, ProfileSpec,
    ScorePluginKind, ScorePluginSpec,
};

// ------------------------------------------------------------ helpers

fn get_f64(obj: &Json, key: &str, default: f64) -> Result<f64> {
    match obj.get(key) {
        None => Ok(default),
        Some(v) => v
            .as_f64()
            .ok_or_else(|| anyhow!("field `{key}` is not a number")),
    }
}

fn get_u64(obj: &Json, key: &str, default: u64) -> Result<u64> {
    match obj.get(key) {
        None => Ok(default),
        Some(v) => v
            .as_u64()
            .ok_or_else(|| anyhow!("field `{key}` is not an integer")),
    }
}

fn category_from_str(s: &str) -> Result<NodeCategory> {
    match s {
        "A" => Ok(NodeCategory::A),
        "B" => Ok(NodeCategory::B),
        "C" => Ok(NodeCategory::C),
        "Default" => Ok(NodeCategory::Default),
        other => Err(anyhow!("unknown node category `{other}`")),
    }
}

// ------------------------------------------------------------- loads

pub fn config_from_json(text: &str) -> Result<Config> {
    let v = Json::parse(text)?;
    let mut cfg = Config::paper_default();
    if let Some(c) = v.get("cluster") {
        cfg.cluster = cluster_from_json(c)?;
    }
    if let Some(e) = v.get("energy") {
        cfg.energy = energy_from_json(e)?;
    }
    if let Some(x) = v.get("experiment") {
        cfg.experiment = experiment_from_json(x)?;
    }
    if let Some(c) = v.get("carbon") {
        cfg.carbon = carbon_from_json(c)?;
    }
    if let Some(p) = v.get("profiles") {
        cfg.profiles = profiles_from_json(p)?;
    }
    Ok(cfg)
}

fn carbon_from_json(v: &Json) -> Result<CarbonConfig> {
    let mode = match v.get("mode").and_then(Json::as_str).unwrap_or("constant")
    {
        "constant" => CarbonMode::Constant,
        "diurnal" => CarbonMode::Diurnal {
            base_g_per_kwh: v.req_f64("base_g_per_kwh")?,
            swing: get_f64(v, "swing", 0.5)?,
            period_s: v.req_f64("period_s")?,
            samples: u32::try_from(get_u64(v, "samples", 24)?).map_err(
                |_| anyhow!("carbon `samples` does not fit in 32 bits"),
            )?,
        },
        "trace" => {
            let shape: SignalShape = v
                .get("shape")
                .and_then(Json::as_str)
                .unwrap_or("step")
                .parse()?;
            let points = v
                .req("points")?
                .as_arr()
                .ok_or_else(|| anyhow!("carbon `points` is not an array"))?
                .iter()
                .map(|p| {
                    Ok(CarbonPoint {
                        at_s: p.req_f64("at_s")?,
                        g_per_kwh: p.req_f64("g_per_kwh")?,
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            CarbonMode::Trace { shape, points }
        }
        other => {
            return Err(anyhow!(
                "unknown carbon mode `{other}` (constant|diurnal|trace)"
            ))
        }
    };
    Ok(CarbonConfig { mode })
}

fn profiles_from_json(v: &Json) -> Result<Vec<ProfileSpec>> {
    let arr = v
        .as_arr()
        .ok_or_else(|| anyhow!("`profiles` is not an array"))?;
    arr.iter().map(profile_from_json).collect()
}

fn profile_from_json(p: &Json) -> Result<ProfileSpec> {
    let name = p.req_str("name")?.to_string();
    let tie_break = p
        .get("tie_break")
        .and_then(Json::as_str)
        .unwrap_or("lowest-index")
        .parse()?;
    let plugins = p
        .req("plugins")?
        .as_arr()
        .ok_or_else(|| anyhow!("profile `{name}`: `plugins` is not an array"))?
        .iter()
        .map(|pl| {
            let weight = get_f64(pl, "weight", 1.0)?;
            let kind = match pl.req_str("plugin")? {
                "least-allocated" => ScorePluginKind::LeastAllocated,
                "balanced-allocation" => ScorePluginKind::BalancedAllocation,
                "carbon-aware" => ScorePluginKind::CarbonAware,
                "mcda" => ScorePluginKind::Mcda {
                    method: pl
                        .get("method")
                        .and_then(Json::as_str)
                        .unwrap_or("topsis")
                        .parse()?,
                    scheme: pl
                        .get("scheme")
                        .and_then(Json::as_str)
                        .unwrap_or("energy-centric")
                        .parse()?,
                    percent_scale: pl
                        .get("percent_scale")
                        .and_then(Json::as_bool)
                        .unwrap_or(false),
                },
                other => {
                    return Err(anyhow!(
                        "profile `{name}`: unknown score plugin `{other}` \
                         (least-allocated|balanced-allocation|carbon-aware\
                         |mcda)"
                    ))
                }
            };
            Ok(ScorePluginSpec { kind, weight })
        })
        .collect::<Result<Vec<_>>>()?;
    Ok(ProfileSpec { name, tie_break, plugins })
}

fn cluster_from_json(v: &Json) -> Result<ClusterConfig> {
    let mut cfg = ClusterConfig::paper_default();
    if let Some(pools) = v.get("pools") {
        let arr = pools
            .as_arr()
            .ok_or_else(|| anyhow!("`pools` is not an array"))?;
        cfg.pools = arr
            .iter()
            .map(|p| {
                Ok(NodePoolConfig {
                    category: category_from_str(p.req_str("category")?)?,
                    machine_type: p
                        .get("machine_type")
                        .and_then(Json::as_str)
                        .unwrap_or("custom")
                        .to_string(),
                    count: p.req_usize("count")?,
                    cpu_millis: p.req_f64("cpu_millis")? as u64,
                    memory_mib: p.req_f64("memory_mib")? as u64,
                    speed_factor: p.req_f64("speed_factor")?,
                    power_scale: p.req_f64("power_scale")?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
    }
    if let Some(b) = v.get("schedulable_default_pool") {
        cfg.schedulable_default_pool = b
            .as_bool()
            .ok_or_else(|| anyhow!("schedulable_default_pool not bool"))?;
    }
    Ok(cfg)
}

fn energy_from_json(v: &Json) -> Result<EnergyModelConfig> {
    let d = EnergyModelConfig::default();
    Ok(EnergyModelConfig {
        p_idle: get_f64(v, "p_idle", d.p_idle)?,
        k_cpu: get_f64(v, "k_cpu", d.k_cpu)?,
        k_mem: get_f64(v, "k_mem", d.k_mem)?,
        k_disk: get_f64(v, "k_disk", d.k_disk)?,
        k_net: get_f64(v, "k_net", d.k_net)?,
        pue: get_f64(v, "pue", d.pue)?,
        mem_accesses_per_sec: get_f64(
            v, "mem_accesses_per_sec", d.mem_accesses_per_sec)?,
        disk_iops: get_f64(v, "disk_iops", d.disk_iops)?,
        net_ops_per_sec: get_f64(v, "net_ops_per_sec", d.net_ops_per_sec)?,
        co2_lb_per_kwh: get_f64(v, "co2_lb_per_kwh", d.co2_lb_per_kwh)?,
        usd_per_kwh: get_f64(v, "usd_per_kwh", d.usd_per_kwh)?,
        carbon_credit_usd_min: get_f64(
            v, "carbon_credit_usd_min", d.carbon_credit_usd_min)?,
        carbon_credit_usd_max: get_f64(
            v, "carbon_credit_usd_max", d.carbon_credit_usd_max)?,
        vehicle_tons_per_year: get_f64(
            v, "vehicle_tons_per_year", d.vehicle_tons_per_year)?,
    })
}

fn experiment_from_json(v: &Json) -> Result<ExperimentConfig> {
    let d = ExperimentConfig::default();
    Ok(ExperimentConfig {
        replications: get_u64(v, "replications", d.replications as u64)?
            as u32,
        seed: get_u64(v, "seed", d.seed)?,
        arrival_jitter_s: get_f64(v, "arrival_jitter_s", d.arrival_jitter_s)?,
        contention_beta: get_f64(v, "contention_beta", d.contention_beta)?,
        epochs_light: get_u64(v, "epochs_light", d.epochs_light as u64)?
            as u32,
        epochs_medium: get_u64(v, "epochs_medium", d.epochs_medium as u64)?
            as u32,
        epochs_complex: get_u64(
            v, "epochs_complex", d.epochs_complex as u64)? as u32,
    })
}

// ------------------------------------------------------------- dumps

pub fn config_to_json(cfg: &Config) -> Json {
    Json::obj(vec![
        ("cluster", cluster_to_json(&cfg.cluster)),
        ("energy", energy_to_json(&cfg.energy)),
        ("experiment", experiment_to_json(&cfg.experiment)),
        ("carbon", carbon_to_json(&cfg.carbon)),
        ("profiles", profiles_to_json(&cfg.profiles)),
    ])
}

pub fn carbon_to_json(c: &CarbonConfig) -> Json {
    match &c.mode {
        CarbonMode::Constant => {
            Json::obj(vec![("mode", Json::Str("constant".into()))])
        }
        CarbonMode::Diurnal { base_g_per_kwh, swing, period_s, samples } => {
            Json::obj(vec![
                ("mode", Json::Str("diurnal".into())),
                ("base_g_per_kwh", Json::Num(*base_g_per_kwh)),
                ("swing", Json::Num(*swing)),
                ("period_s", Json::Num(*period_s)),
                ("samples", Json::Num(*samples as f64)),
            ])
        }
        CarbonMode::Trace { shape, points } => Json::obj(vec![
            ("mode", Json::Str("trace".into())),
            ("shape", Json::Str(shape.label().into())),
            (
                "points",
                Json::Arr(
                    points
                        .iter()
                        .map(|p| {
                            Json::obj(vec![
                                ("at_s", Json::Num(p.at_s)),
                                ("g_per_kwh", Json::Num(p.g_per_kwh)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ]),
    }
}

pub fn profiles_to_json(profiles: &[ProfileSpec]) -> Json {
    Json::Arr(
        profiles
            .iter()
            .map(|p| {
                Json::obj(vec![
                    ("name", Json::Str(p.name.clone())),
                    ("tie_break", Json::Str(p.tie_break.label().into())),
                    (
                        "plugins",
                        Json::Arr(
                            p.plugins
                                .iter()
                                .map(plugin_to_json)
                                .collect(),
                        ),
                    ),
                ])
            })
            .collect(),
    )
}

fn plugin_to_json(p: &ScorePluginSpec) -> Json {
    let mut pairs = vec![
        ("plugin", Json::Str(p.kind.label().into())),
        ("weight", Json::Num(p.weight)),
    ];
    if let ScorePluginKind::Mcda { method, scheme, percent_scale } = &p.kind {
        pairs.push((
            "method",
            Json::Str(format!("{method:?}").to_lowercase()),
        ));
        pairs.push(("scheme", Json::Str(scheme_label(*scheme).into())));
        pairs.push(("percent_scale", Json::Bool(*percent_scale)));
    }
    Json::obj(pairs)
}

/// Kebab-case scheme name (the `FromStr` inverse).
fn scheme_label(s: super::WeightingScheme) -> &'static str {
    use super::WeightingScheme::*;
    match s {
        General => "general",
        EnergyCentric => "energy-centric",
        PerformanceCentric => "performance-centric",
        ResourceEfficient => "resource-efficient",
    }
}

pub fn cluster_to_json(c: &ClusterConfig) -> Json {
    Json::obj(vec![
        (
            "pools",
            Json::Arr(
                c.pools
                    .iter()
                    .map(|p| {
                        Json::obj(vec![
                            ("category",
                             Json::Str(p.category.label().into())),
                            ("machine_type",
                             Json::Str(p.machine_type.clone())),
                            ("count", Json::Num(p.count as f64)),
                            ("cpu_millis", Json::Num(p.cpu_millis as f64)),
                            ("memory_mib", Json::Num(p.memory_mib as f64)),
                            ("speed_factor", Json::Num(p.speed_factor)),
                            ("power_scale", Json::Num(p.power_scale)),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "schedulable_default_pool",
            Json::Bool(c.schedulable_default_pool),
        ),
    ])
}

pub fn energy_to_json(e: &EnergyModelConfig) -> Json {
    Json::obj(vec![
        ("p_idle", Json::Num(e.p_idle)),
        ("k_cpu", Json::Num(e.k_cpu)),
        ("k_mem", Json::Num(e.k_mem)),
        ("k_disk", Json::Num(e.k_disk)),
        ("k_net", Json::Num(e.k_net)),
        ("pue", Json::Num(e.pue)),
        ("mem_accesses_per_sec", Json::Num(e.mem_accesses_per_sec)),
        ("disk_iops", Json::Num(e.disk_iops)),
        ("net_ops_per_sec", Json::Num(e.net_ops_per_sec)),
        ("co2_lb_per_kwh", Json::Num(e.co2_lb_per_kwh)),
        ("usd_per_kwh", Json::Num(e.usd_per_kwh)),
        ("carbon_credit_usd_min", Json::Num(e.carbon_credit_usd_min)),
        ("carbon_credit_usd_max", Json::Num(e.carbon_credit_usd_max)),
        ("vehicle_tons_per_year", Json::Num(e.vehicle_tons_per_year)),
    ])
}

pub fn experiment_to_json(x: &ExperimentConfig) -> Json {
    Json::obj(vec![
        ("replications", Json::Num(x.replications as f64)),
        ("seed", Json::Num(x.seed as f64)),
        ("arrival_jitter_s", Json::Num(x.arrival_jitter_s)),
        ("contention_beta", Json::Num(x.contention_beta)),
        ("epochs_light", Json::Num(x.epochs_light as f64)),
        ("epochs_medium", Json::Num(x.epochs_medium as f64)),
        ("epochs_complex", Json::Num(x.epochs_complex as f64)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn custom_pools_parse() {
        let cfg = config_from_json(
            r#"{"cluster": {"pools": [
                {"category": "A", "count": 3, "cpu_millis": 2000,
                 "memory_mib": 4096, "speed_factor": 0.7,
                 "power_scale": 0.45}
            ]}}"#,
        )
        .unwrap();
        assert_eq!(cfg.cluster.pools.len(), 1);
        assert_eq!(cfg.cluster.total_nodes(), 3);
        assert_eq!(cfg.cluster.pools[0].machine_type, "custom");
    }

    #[test]
    fn profiles_parse_and_roundtrip() {
        let text = r#"{"profiles": [
            {"name": "my-hybrid", "tie_break": "seeded-random",
             "plugins": [
                {"plugin": "mcda", "weight": 0.7, "method": "saw",
                 "scheme": "general", "percent_scale": true},
                {"plugin": "carbon-aware", "weight": 0.3},
                {"plugin": "least-allocated"}
             ]}
        ]}"#;
        let cfg = config_from_json(text).unwrap();
        cfg.validate().unwrap();
        assert_eq!(cfg.profiles.len(), 1);
        let p = &cfg.profiles[0];
        assert_eq!(p.name, "my-hybrid");
        assert_eq!(p.plugins.len(), 3);
        // Omitted weight defaults to 1.0.
        assert_eq!(p.plugins[2].weight, 1.0);
        // Dump → parse is the identity on the profile list.
        let back = config_from_json(&config_to_json(&cfg).pretty()).unwrap();
        assert_eq!(cfg.profiles, back.profiles);
    }

    #[test]
    fn unknown_plugin_rejected() {
        assert!(config_from_json(
            r#"{"profiles": [{"name": "x", "plugins":
                [{"plugin": "warp-drive"}]}]}"#,
        )
        .is_err());
    }

    #[test]
    fn carbon_sections_parse_and_roundtrip() {
        for text in [
            r#"{"carbon": {"mode": "constant"}}"#,
            r#"{"carbon": {"mode": "diurnal", "base_g_per_kwh": 373.4,
                 "swing": 0.4, "period_s": 86400, "samples": 48}}"#,
            r#"{"carbon": {"mode": "trace", "shape": "linear", "points":
                 [{"at_s": 0, "g_per_kwh": 450},
                  {"at_s": 3600, "g_per_kwh": 210}]}}"#,
        ] {
            let cfg = config_from_json(text).unwrap();
            cfg.validate().unwrap();
            // Dump → parse is the identity on the carbon section.
            let back =
                config_from_json(&config_to_json(&cfg).pretty()).unwrap();
            assert_eq!(cfg.carbon, back.carbon, "{text}");
        }
        // Absent section keeps the constant (scalar-path) default.
        let cfg = config_from_json("{}").unwrap();
        assert_eq!(cfg.carbon, super::super::CarbonConfig::default());
    }

    #[test]
    fn carbon_bad_sections_rejected() {
        // Unknown mode and missing required fields fail at parse time.
        assert!(config_from_json(
            r#"{"carbon": {"mode": "lunar"}}"#
        )
        .is_err());
        assert!(config_from_json(
            r#"{"carbon": {"mode": "diurnal", "swing": 0.4}}"#
        )
        .is_err());
        assert!(config_from_json(
            r#"{"carbon": {"mode": "trace", "shape": "cubic",
                 "points": [{"at_s": 0, "g_per_kwh": 1}]}}"#
        )
        .is_err());
        // Out-of-range sample counts error instead of wrapping.
        assert!(config_from_json(
            r#"{"carbon": {"mode": "diurnal", "base_g_per_kwh": 300,
                 "period_s": 60, "samples": 4294967320}}"#
        )
        .is_err());
        // Non-monotonic or non-finite timestamps parse but fail
        // validation (the signal constructor is the single gate).
        let bad = config_from_json(
            r#"{"carbon": {"mode": "trace", "points":
                 [{"at_s": 10, "g_per_kwh": 400},
                  {"at_s": 5, "g_per_kwh": 300}]}}"#,
        )
        .unwrap();
        assert!(bad.validate().is_err());
        let inf = config_from_json(
            r#"{"carbon": {"mode": "trace", "points":
                 [{"at_s": 1e999, "g_per_kwh": 400}]}}"#,
        )
        .unwrap();
        assert!(inf.validate().is_err());
    }

    #[test]
    fn carbon_one_sample_trace_validates_as_constant() {
        let cfg = config_from_json(
            r#"{"carbon": {"mode": "trace", "points":
                 [{"at_s": 0, "g_per_kwh": 360}]}}"#,
        )
        .unwrap();
        cfg.validate().unwrap();
        let s = cfg.carbon.signal(&cfg.energy);
        assert_eq!(s.constant_value(), Some(360.0 / super::super::J_PER_KWH));
    }

    #[test]
    fn bad_category_rejected() {
        let err = config_from_json(
            r#"{"cluster": {"pools": [
                {"category": "Z", "count": 1, "cpu_millis": 1000,
                 "memory_mib": 1024, "speed_factor": 1.0,
                 "power_scale": 1.0}
            ]}}"#,
        );
        assert!(err.is_err());
    }
}
