//! JSON (de)serialization for the config types, over `util::json`.
//!
//! Schema mirrors the struct layout:
//! ```json
//! {
//!   "cluster":    {"pools": [{"category": "A", ...}], ...},
//!   "energy":     {"pue": 1.45, ...},
//!   "experiment": {"replications": 5, "seed": 1, ...},
//!   "profiles":   [{"name": "my-hybrid",
//!                   "tie_break": "lowest-index",
//!                   "plugins": [
//!                     {"plugin": "mcda", "weight": 0.7,
//!                      "method": "topsis", "scheme": "energy-centric",
//!                      "percent_scale": true},
//!                     {"plugin": "balanced-allocation", "weight": 0.3}]}]
//! }
//! ```
//! Absent sections/fields fall back to the paper defaults, so a config
//! file only states deviations.

use anyhow::{anyhow, Result};

use crate::cluster::NodeCategory;
use crate::energy::SignalShape;
use crate::util::json::Json;

use super::{
    CarbonConfig, CarbonMode, CarbonPoint, CarbonWindowParams,
    ClusterConfig, Config, DispatchKind, EnergyModelConfig,
    ExperimentConfig, FederationConfig, NodePoolConfig, ProfileSpec,
    RegionAutoscalerConfig, RegionConfig, ScorePluginKind,
    ScorePluginSpec,
};

// ------------------------------------------------------------ helpers

fn get_f64(obj: &Json, key: &str, default: f64) -> Result<f64> {
    match obj.get(key) {
        None => Ok(default),
        Some(v) => v
            .as_f64()
            .ok_or_else(|| anyhow!("field `{key}` is not a number")),
    }
}

fn get_u64(obj: &Json, key: &str, default: u64) -> Result<u64> {
    match obj.get(key) {
        None => Ok(default),
        Some(v) => v
            .as_u64()
            .ok_or_else(|| anyhow!("field `{key}` is not an integer")),
    }
}

/// 32-bit counter fields (replications, epochs). A `get_u64(..)? as
/// u32` here would silently truncate out-of-range values — the same
/// bug the trace parser's `epochs` had — so reject them instead (the
/// `lossy-id-cast` lint now fences the narrowing-cast shape).
fn get_u32(obj: &Json, key: &str, default: u32) -> Result<u32> {
    let raw = get_u64(obj, key, u64::from(default))?;
    u32::try_from(raw)
        .map_err(|_| anyhow!("field `{key}` ({raw}) does not fit in 32 bits"))
}

fn category_from_str(s: &str) -> Result<NodeCategory> {
    match s {
        "A" => Ok(NodeCategory::A),
        "B" => Ok(NodeCategory::B),
        "C" => Ok(NodeCategory::C),
        "Default" => Ok(NodeCategory::Default),
        other => Err(anyhow!("unknown node category `{other}`")),
    }
}

// ------------------------------------------------------------- loads

pub fn config_from_json(text: &str) -> Result<Config> {
    let v = Json::parse(text)?;
    let mut cfg = Config::paper_default();
    if let Some(c) = v.get("cluster") {
        cfg.cluster = cluster_from_json(c)?;
    }
    if let Some(e) = v.get("energy") {
        cfg.energy = energy_from_json(e)?;
    }
    if let Some(x) = v.get("experiment") {
        cfg.experiment = experiment_from_json(x)?;
    }
    if let Some(c) = v.get("carbon") {
        cfg.carbon = carbon_from_json(c)?;
    }
    if let Some(p) = v.get("profiles") {
        cfg.profiles = profiles_from_json(p)?;
    }
    if let Some(f) = v.get("federation") {
        cfg.federation = Some(federation_from_json(f)?);
    }
    Ok(cfg)
}

fn federation_from_json(v: &Json) -> Result<FederationConfig> {
    // Same typo-guard principle as the region sub-sections: a present
    // but wrong-typed `dispatch` must error, not silently fall back
    // to the round-robin default.
    let dispatch: DispatchKind = match v.get("dispatch") {
        None => DispatchKind::RoundRobin,
        Some(d) => d
            .as_str()
            .ok_or_else(|| anyhow!("federation `dispatch` is not a string"))?
            .parse()?,
    };
    let regions = v
        .req("regions")?
        .as_arr()
        .ok_or_else(|| anyhow!("federation `regions` is not an array"))?
        .iter()
        .map(region_from_json)
        .collect::<Result<Vec<_>>>()?;
    Ok(FederationConfig { dispatch, regions })
}

fn region_from_json(v: &Json) -> Result<RegionConfig> {
    let name = v.req_str("name")?.to_string();
    let mut region = RegionConfig::named(&name);
    // Every sub-section must be an object when present: the section
    // parsers default *missing keys*, so a typo like `"carbon":
    // "diurnal"` would otherwise silently yield the paper defaults
    // (constant signal, 7-node cluster) instead of erroring.
    for (key, val) in [
        ("cluster", v.get("cluster")),
        ("carbon", v.get("carbon")),
        ("autoscaler", v.get("autoscaler")),
    ] {
        if let Some(val) = val {
            if val.as_obj().is_none() {
                return Err(anyhow!(
                    "region `{name}`: `{key}` is not an object"
                ));
            }
        }
    }
    if let Some(c) = v.get("cluster") {
        region.cluster = cluster_from_json(c)
            .map_err(|e| anyhow!("region `{name}`: {e}"))?;
    }
    if let Some(c) = v.get("carbon") {
        region.carbon = carbon_from_json(c)
            .map_err(|e| anyhow!("region `{name}`: {e}"))?;
    }
    if let Some(a) = v.get("autoscaler") {
        region.autoscaler = Some(
            region_autoscaler_from_json(a)
                .map_err(|e| anyhow!("region `{name}`: {e}"))?,
        );
    }
    Ok(region)
}

fn region_autoscaler_from_json(v: &Json) -> Result<RegionAutoscalerConfig> {
    // Reject non-object sections outright: `get_f64` falls back to
    // defaults on *missing keys*, so a typo like `"autoscaler": 5` or
    // `"window": "p50"` would otherwise silently enable the feature
    // with default knobs instead of erroring.
    if v.as_obj().is_none() {
        return Err(anyhow!("`autoscaler` is not an object"));
    }
    let d = RegionAutoscalerConfig::default();
    let window = match v.get("window") {
        None => None,
        Some(w) => {
            if w.as_obj().is_none() {
                return Err(anyhow!("autoscaler `window` is not an object"));
            }
            Some(CarbonWindowParams {
                percentile: get_f64(w, "percentile", 0.5)?,
                idle_tighten: get_f64(w, "idle_tighten", 0.25)?,
                defer_scale_out_s: get_f64(w, "defer_scale_out_s", 20.0)?,
            })
        }
    };
    Ok(RegionAutoscalerConfig {
        scale_out_pending: get_u64(
            v,
            "scale_out_pending",
            d.scale_out_pending as u64,
        )? as usize,
        // Absent = the disabled sentinel (`INFINITY` is not JSON).
        scale_out_wait_p95_s: get_f64(
            v,
            "scale_out_wait_p95_s",
            f64::INFINITY,
        )?,
        provision_delay_s: get_f64(
            v, "provision_delay_s", d.provision_delay_s)?,
        cooldown_s: get_f64(v, "cooldown_s", d.cooldown_s)?,
        idle_scale_in_s: get_f64(v, "idle_scale_in_s", d.idle_scale_in_s)?,
        max_extra_nodes: get_u64(
            v,
            "max_extra_nodes",
            d.max_extra_nodes as u64,
        )? as usize,
        window,
    })
}

fn carbon_from_json(v: &Json) -> Result<CarbonConfig> {
    let mode = match v.get("mode").and_then(Json::as_str).unwrap_or("constant")
    {
        "constant" => CarbonMode::Constant,
        "diurnal" => CarbonMode::Diurnal {
            base_g_per_kwh: v.req_f64("base_g_per_kwh")?,
            swing: get_f64(v, "swing", 0.5)?,
            period_s: v.req_f64("period_s")?,
            samples: get_u32(v, "samples", 24).map_err(
                |e| anyhow!("carbon `samples`: {e}"),
            )?,
        },
        "trace" => {
            let shape: SignalShape = v
                .get("shape")
                .and_then(Json::as_str)
                .unwrap_or("step")
                .parse()?;
            let points = v
                .req("points")?
                .as_arr()
                .ok_or_else(|| anyhow!("carbon `points` is not an array"))?
                .iter()
                .map(|p| {
                    Ok(CarbonPoint {
                        at_s: p.req_f64("at_s")?,
                        g_per_kwh: p.req_f64("g_per_kwh")?,
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            CarbonMode::Trace { shape, points }
        }
        other => {
            return Err(anyhow!(
                "unknown carbon mode `{other}` (constant|diurnal|trace)"
            ))
        }
    };
    Ok(CarbonConfig { mode })
}

fn profiles_from_json(v: &Json) -> Result<Vec<ProfileSpec>> {
    let arr = v
        .as_arr()
        .ok_or_else(|| anyhow!("`profiles` is not an array"))?;
    arr.iter().map(profile_from_json).collect()
}

fn profile_from_json(p: &Json) -> Result<ProfileSpec> {
    let name = p.req_str("name")?.to_string();
    let tie_break = p
        .get("tie_break")
        .and_then(Json::as_str)
        .unwrap_or("lowest-index")
        .parse()?;
    let plugins = p
        .req("plugins")?
        .as_arr()
        .ok_or_else(|| anyhow!("profile `{name}`: `plugins` is not an array"))?
        .iter()
        .map(|pl| {
            let weight = get_f64(pl, "weight", 1.0)?;
            let kind = match pl.req_str("plugin")? {
                "least-allocated" => ScorePluginKind::LeastAllocated,
                "balanced-allocation" => ScorePluginKind::BalancedAllocation,
                "carbon-aware" => ScorePluginKind::CarbonAware,
                "mcda" => ScorePluginKind::Mcda {
                    method: pl
                        .get("method")
                        .and_then(Json::as_str)
                        .unwrap_or("topsis")
                        .parse()?,
                    scheme: pl
                        .get("scheme")
                        .and_then(Json::as_str)
                        .unwrap_or("energy-centric")
                        .parse()?,
                    percent_scale: pl
                        .get("percent_scale")
                        .and_then(Json::as_bool)
                        .unwrap_or(false),
                },
                other => {
                    return Err(anyhow!(
                        "profile `{name}`: unknown score plugin `{other}` \
                         (least-allocated|balanced-allocation|carbon-aware\
                         |mcda)"
                    ))
                }
            };
            Ok(ScorePluginSpec { kind, weight })
        })
        .collect::<Result<Vec<_>>>()?;
    Ok(ProfileSpec { name, tie_break, plugins })
}

fn cluster_from_json(v: &Json) -> Result<ClusterConfig> {
    let mut cfg = ClusterConfig::paper_default();
    if let Some(pools) = v.get("pools") {
        let arr = pools
            .as_arr()
            .ok_or_else(|| anyhow!("`pools` is not an array"))?;
        cfg.pools = arr
            .iter()
            .map(|p| {
                Ok(NodePoolConfig {
                    category: category_from_str(p.req_str("category")?)?,
                    machine_type: p
                        .get("machine_type")
                        .and_then(Json::as_str)
                        .unwrap_or("custom")
                        .to_string(),
                    count: p.req_usize("count")?,
                    // Lossless u64 path: capacities are integer fields
                    // (a fractional value is a config error, not
                    // something to truncate silently).
                    cpu_millis: p.req_u64("cpu_millis")?,
                    memory_mib: p.req_u64("memory_mib")?,
                    speed_factor: p.req_f64("speed_factor")?,
                    power_scale: p.req_f64("power_scale")?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
    }
    if let Some(b) = v.get("schedulable_default_pool") {
        cfg.schedulable_default_pool = b
            .as_bool()
            .ok_or_else(|| anyhow!("schedulable_default_pool not bool"))?;
    }
    Ok(cfg)
}

fn energy_from_json(v: &Json) -> Result<EnergyModelConfig> {
    let d = EnergyModelConfig::default();
    Ok(EnergyModelConfig {
        p_idle: get_f64(v, "p_idle", d.p_idle)?,
        k_cpu: get_f64(v, "k_cpu", d.k_cpu)?,
        k_mem: get_f64(v, "k_mem", d.k_mem)?,
        k_disk: get_f64(v, "k_disk", d.k_disk)?,
        k_net: get_f64(v, "k_net", d.k_net)?,
        pue: get_f64(v, "pue", d.pue)?,
        mem_accesses_per_sec: get_f64(
            v, "mem_accesses_per_sec", d.mem_accesses_per_sec)?,
        disk_iops: get_f64(v, "disk_iops", d.disk_iops)?,
        net_ops_per_sec: get_f64(v, "net_ops_per_sec", d.net_ops_per_sec)?,
        co2_lb_per_kwh: get_f64(v, "co2_lb_per_kwh", d.co2_lb_per_kwh)?,
        usd_per_kwh: get_f64(v, "usd_per_kwh", d.usd_per_kwh)?,
        carbon_credit_usd_min: get_f64(
            v, "carbon_credit_usd_min", d.carbon_credit_usd_min)?,
        carbon_credit_usd_max: get_f64(
            v, "carbon_credit_usd_max", d.carbon_credit_usd_max)?,
        vehicle_tons_per_year: get_f64(
            v, "vehicle_tons_per_year", d.vehicle_tons_per_year)?,
    })
}

fn experiment_from_json(v: &Json) -> Result<ExperimentConfig> {
    let d = ExperimentConfig::default();
    Ok(ExperimentConfig {
        replications: get_u32(v, "replications", d.replications)?,
        seed: get_u64(v, "seed", d.seed)?,
        arrival_jitter_s: get_f64(v, "arrival_jitter_s", d.arrival_jitter_s)?,
        contention_beta: get_f64(v, "contention_beta", d.contention_beta)?,
        epochs_light: get_u32(v, "epochs_light", d.epochs_light)?,
        epochs_medium: get_u32(v, "epochs_medium", d.epochs_medium)?,
        epochs_complex: get_u32(v, "epochs_complex", d.epochs_complex)?,
    })
}

// ------------------------------------------------------------- dumps

pub fn config_to_json(cfg: &Config) -> Json {
    let mut pairs = vec![
        ("cluster", cluster_to_json(&cfg.cluster)),
        ("energy", energy_to_json(&cfg.energy)),
        ("experiment", experiment_to_json(&cfg.experiment)),
        ("carbon", carbon_to_json(&cfg.carbon)),
        ("profiles", profiles_to_json(&cfg.profiles)),
    ];
    if let Some(fed) = &cfg.federation {
        pairs.push(("federation", federation_to_json(fed)));
    }
    Json::obj(pairs)
}

pub fn federation_to_json(f: &FederationConfig) -> Json {
    Json::obj(vec![
        ("dispatch", Json::Str(f.dispatch.label().into())),
        (
            "regions",
            Json::Arr(f.regions.iter().map(region_to_json).collect()),
        ),
    ])
}

fn region_to_json(r: &RegionConfig) -> Json {
    let mut pairs = vec![
        ("name", Json::Str(r.name.clone())),
        ("cluster", cluster_to_json(&r.cluster)),
        ("carbon", carbon_to_json(&r.carbon)),
    ];
    if let Some(a) = &r.autoscaler {
        pairs.push(("autoscaler", region_autoscaler_to_json(a)));
    }
    Json::obj(pairs)
}

fn region_autoscaler_to_json(a: &RegionAutoscalerConfig) -> Json {
    let mut pairs = vec![
        ("scale_out_pending", Json::Uint(a.scale_out_pending as u64)),
        ("provision_delay_s", Json::Num(a.provision_delay_s)),
        ("cooldown_s", Json::Num(a.cooldown_s)),
        ("idle_scale_in_s", Json::Num(a.idle_scale_in_s)),
        ("max_extra_nodes", Json::Uint(a.max_extra_nodes as u64)),
    ];
    // JSON has no infinity: the disabled wait trigger is encoded by
    // omission (the parser's default is `INFINITY`).
    if a.scale_out_wait_p95_s.is_finite() {
        pairs.push((
            "scale_out_wait_p95_s",
            Json::Num(a.scale_out_wait_p95_s),
        ));
    }
    if let Some(w) = &a.window {
        pairs.push((
            "window",
            Json::obj(vec![
                ("percentile", Json::Num(w.percentile)),
                ("idle_tighten", Json::Num(w.idle_tighten)),
                ("defer_scale_out_s", Json::Num(w.defer_scale_out_s)),
            ]),
        ));
    }
    Json::obj(pairs)
}

pub fn carbon_to_json(c: &CarbonConfig) -> Json {
    match &c.mode {
        CarbonMode::Constant => {
            Json::obj(vec![("mode", Json::Str("constant".into()))])
        }
        CarbonMode::Diurnal { base_g_per_kwh, swing, period_s, samples } => {
            Json::obj(vec![
                ("mode", Json::Str("diurnal".into())),
                ("base_g_per_kwh", Json::Num(*base_g_per_kwh)),
                ("swing", Json::Num(*swing)),
                ("period_s", Json::Num(*period_s)),
                ("samples", Json::Uint(*samples as u64)),
            ])
        }
        CarbonMode::Trace { shape, points } => Json::obj(vec![
            ("mode", Json::Str("trace".into())),
            ("shape", Json::Str(shape.label().into())),
            (
                "points",
                Json::Arr(
                    points
                        .iter()
                        .map(|p| {
                            Json::obj(vec![
                                ("at_s", Json::Num(p.at_s)),
                                ("g_per_kwh", Json::Num(p.g_per_kwh)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ]),
    }
}

pub fn profiles_to_json(profiles: &[ProfileSpec]) -> Json {
    Json::Arr(
        profiles
            .iter()
            .map(|p| {
                Json::obj(vec![
                    ("name", Json::Str(p.name.clone())),
                    ("tie_break", Json::Str(p.tie_break.label().into())),
                    (
                        "plugins",
                        Json::Arr(
                            p.plugins
                                .iter()
                                .map(plugin_to_json)
                                .collect(),
                        ),
                    ),
                ])
            })
            .collect(),
    )
}

fn plugin_to_json(p: &ScorePluginSpec) -> Json {
    let mut pairs = vec![
        ("plugin", Json::Str(p.kind.label().into())),
        ("weight", Json::Num(p.weight)),
    ];
    if let ScorePluginKind::Mcda { method, scheme, percent_scale } = &p.kind {
        pairs.push((
            "method",
            Json::Str(format!("{method:?}").to_lowercase()),
        ));
        pairs.push(("scheme", Json::Str(scheme_label(*scheme).into())));
        pairs.push(("percent_scale", Json::Bool(*percent_scale)));
    }
    Json::obj(pairs)
}

/// Kebab-case scheme name (the `FromStr` inverse).
fn scheme_label(s: super::WeightingScheme) -> &'static str {
    use super::WeightingScheme::*;
    match s {
        General => "general",
        EnergyCentric => "energy-centric",
        PerformanceCentric => "performance-centric",
        ResourceEfficient => "resource-efficient",
    }
}

pub fn cluster_to_json(c: &ClusterConfig) -> Json {
    Json::obj(vec![
        (
            "pools",
            Json::Arr(
                c.pools
                    .iter()
                    .map(|p| {
                        Json::obj(vec![
                            ("category",
                             Json::Str(p.category.label().into())),
                            ("machine_type",
                             Json::Str(p.machine_type.clone())),
                            ("count", Json::Uint(p.count as u64)),
                            ("cpu_millis", Json::Uint(p.cpu_millis)),
                            ("memory_mib", Json::Uint(p.memory_mib)),
                            ("speed_factor", Json::Num(p.speed_factor)),
                            ("power_scale", Json::Num(p.power_scale)),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "schedulable_default_pool",
            Json::Bool(c.schedulable_default_pool),
        ),
    ])
}

pub fn energy_to_json(e: &EnergyModelConfig) -> Json {
    Json::obj(vec![
        ("p_idle", Json::Num(e.p_idle)),
        ("k_cpu", Json::Num(e.k_cpu)),
        ("k_mem", Json::Num(e.k_mem)),
        ("k_disk", Json::Num(e.k_disk)),
        ("k_net", Json::Num(e.k_net)),
        ("pue", Json::Num(e.pue)),
        ("mem_accesses_per_sec", Json::Num(e.mem_accesses_per_sec)),
        ("disk_iops", Json::Num(e.disk_iops)),
        ("net_ops_per_sec", Json::Num(e.net_ops_per_sec)),
        ("co2_lb_per_kwh", Json::Num(e.co2_lb_per_kwh)),
        ("usd_per_kwh", Json::Num(e.usd_per_kwh)),
        ("carbon_credit_usd_min", Json::Num(e.carbon_credit_usd_min)),
        ("carbon_credit_usd_max", Json::Num(e.carbon_credit_usd_max)),
        ("vehicle_tons_per_year", Json::Num(e.vehicle_tons_per_year)),
    ])
}

pub fn experiment_to_json(x: &ExperimentConfig) -> Json {
    // Every integer field dumps as `Json::Uint` so dump → parse is the
    // identity at the `Json` value level too (the parser produces
    // `Uint` for integer literals). The seed in particular is a full
    // u64: `Json::Num`'s f64 would corrupt seeds >= 2^53 and silently
    // change the reloaded run.
    Json::obj(vec![
        ("replications", Json::Uint(x.replications as u64)),
        ("seed", Json::Uint(x.seed)),
        ("arrival_jitter_s", Json::Num(x.arrival_jitter_s)),
        ("contention_beta", Json::Num(x.contention_beta)),
        ("epochs_light", Json::Uint(x.epochs_light as u64)),
        ("epochs_medium", Json::Uint(x.epochs_medium as u64)),
        ("epochs_complex", Json::Uint(x.epochs_complex as u64)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DispatchKind;

    #[test]
    fn custom_pools_parse() {
        let cfg = config_from_json(
            r#"{"cluster": {"pools": [
                {"category": "A", "count": 3, "cpu_millis": 2000,
                 "memory_mib": 4096, "speed_factor": 0.7,
                 "power_scale": 0.45}
            ]}}"#,
        )
        .unwrap();
        assert_eq!(cfg.cluster.pools.len(), 1);
        assert_eq!(cfg.cluster.total_nodes(), 3);
        assert_eq!(cfg.cluster.pools[0].machine_type, "custom");
    }

    #[test]
    fn profiles_parse_and_roundtrip() {
        let text = r#"{"profiles": [
            {"name": "my-hybrid", "tie_break": "seeded-random",
             "plugins": [
                {"plugin": "mcda", "weight": 0.7, "method": "saw",
                 "scheme": "general", "percent_scale": true},
                {"plugin": "carbon-aware", "weight": 0.3},
                {"plugin": "least-allocated"}
             ]}
        ]}"#;
        let cfg = config_from_json(text).unwrap();
        cfg.validate().unwrap();
        assert_eq!(cfg.profiles.len(), 1);
        let p = &cfg.profiles[0];
        assert_eq!(p.name, "my-hybrid");
        assert_eq!(p.plugins.len(), 3);
        // Omitted weight defaults to 1.0.
        assert_eq!(p.plugins[2].weight, 1.0);
        // Dump → parse is the identity on the profile list.
        let back = config_from_json(&config_to_json(&cfg).pretty()).unwrap();
        assert_eq!(cfg.profiles, back.profiles);
    }

    #[test]
    fn unknown_plugin_rejected() {
        assert!(config_from_json(
            r#"{"profiles": [{"name": "x", "plugins":
                [{"plugin": "warp-drive"}]}]}"#,
        )
        .is_err());
    }

    #[test]
    fn legacy_scheduler_name_resolves_across_config_roundtrip() {
        // Monolith-era back-compat: `greenpod-topsis` is not a config
        // field, but a registry built from any config — including one
        // that went through a dump → parse round-trip — must keep
        // resolving the deprecated name to the `greenpod` profile.
        use crate::config::WeightingScheme;
        use crate::framework::{BuildOptions, ProfileRegistry};
        use crate::scheduler::Scheduler;
        let cfg = config_from_json(
            r#"{"profiles": [
                {"name": "my-hybrid",
                 "plugins": [{"plugin": "least-allocated"}]}
            ]}"#,
        )
        .unwrap();
        cfg.validate().unwrap();
        let back = config_from_json(&config_to_json(&cfg).pretty()).unwrap();
        back.validate().unwrap();
        let registry = ProfileRegistry::new(&back);
        assert!(registry.contains("greenpod-topsis"));
        let opts =
            BuildOptions::new(&back, WeightingScheme::EnergyCentric);
        let sched = registry.build("greenpod-topsis", &opts).unwrap();
        assert_eq!(sched.name(), "greenpod");
        // And a config profile may not shadow the deprecated alias.
        let shadow = config_from_json(
            r#"{"profiles": [{"name": "greenpod-topsis",
                "plugins": [{"plugin": "least-allocated"}]}]}"#,
        )
        .unwrap();
        assert!(shadow.validate().is_err());
    }

    #[test]
    fn carbon_sections_parse_and_roundtrip() {
        for text in [
            r#"{"carbon": {"mode": "constant"}}"#,
            r#"{"carbon": {"mode": "diurnal", "base_g_per_kwh": 373.4,
                 "swing": 0.4, "period_s": 86400, "samples": 48}}"#,
            r#"{"carbon": {"mode": "trace", "shape": "linear", "points":
                 [{"at_s": 0, "g_per_kwh": 450},
                  {"at_s": 3600, "g_per_kwh": 210}]}}"#,
        ] {
            let cfg = config_from_json(text).unwrap();
            cfg.validate().unwrap();
            // Dump → parse is the identity on the carbon section.
            let back =
                config_from_json(&config_to_json(&cfg).pretty()).unwrap();
            assert_eq!(cfg.carbon, back.carbon, "{text}");
        }
        // Absent section keeps the constant (scalar-path) default.
        let cfg = config_from_json("{}").unwrap();
        assert_eq!(cfg.carbon, super::super::CarbonConfig::default());
    }

    #[test]
    fn carbon_bad_sections_rejected() {
        // Unknown mode and missing required fields fail at parse time.
        assert!(config_from_json(
            r#"{"carbon": {"mode": "lunar"}}"#
        )
        .is_err());
        assert!(config_from_json(
            r#"{"carbon": {"mode": "diurnal", "swing": 0.4}}"#
        )
        .is_err());
        assert!(config_from_json(
            r#"{"carbon": {"mode": "trace", "shape": "cubic",
                 "points": [{"at_s": 0, "g_per_kwh": 1}]}}"#
        )
        .is_err());
        // Out-of-range sample counts error instead of wrapping.
        assert!(config_from_json(
            r#"{"carbon": {"mode": "diurnal", "base_g_per_kwh": 300,
                 "period_s": 60, "samples": 4294967320}}"#
        )
        .is_err());
    }

    #[test]
    fn experiment_u32_fields_reject_out_of_range() {
        // (2^32 + 7) used to truncate to 7 through `as u32` — every
        // 32-bit experiment field must reject it with the key named.
        for key in
            ["replications", "epochs_light", "epochs_medium", "epochs_complex"]
        {
            let err = config_from_json(&format!(
                r#"{{"experiment": {{"{key}": 4294967303}}}}"#
            ))
            .unwrap_err()
            .to_string();
            assert!(err.contains(key), "{key}: {err}");
            assert!(err.contains("does not fit in 32 bits"), "{err}");
        }
        // The largest representable value still parses exactly.
        let cfg = config_from_json(
            r#"{"experiment": {"epochs_light": 4294967295}}"#,
        )
        .unwrap();
        assert_eq!(cfg.experiment.epochs_light, u32::MAX);
        // Non-monotonic or non-finite timestamps parse but fail
        // validation (the signal constructor is the single gate).
        let bad = config_from_json(
            r#"{"carbon": {"mode": "trace", "points":
                 [{"at_s": 10, "g_per_kwh": 400},
                  {"at_s": 5, "g_per_kwh": 300}]}}"#,
        )
        .unwrap();
        assert!(bad.validate().is_err());
        let inf = config_from_json(
            r#"{"carbon": {"mode": "trace", "points":
                 [{"at_s": 1e999, "g_per_kwh": 400}]}}"#,
        )
        .unwrap();
        assert!(inf.validate().is_err());
    }

    #[test]
    fn carbon_one_sample_trace_validates_as_constant() {
        let cfg = config_from_json(
            r#"{"carbon": {"mode": "trace", "points":
                 [{"at_s": 0, "g_per_kwh": 360}]}}"#,
        )
        .unwrap();
        cfg.validate().unwrap();
        let s = cfg.carbon.signal(&cfg.energy);
        assert_eq!(s.constant_value(), Some(360.0 / super::super::J_PER_KWH));
    }

    #[test]
    fn federation_section_parses_and_roundtrips() {
        let text = r#"{"federation": {
            "dispatch": "carbon-greedy",
            "regions": [
                {"name": "us-east",
                 "carbon": {"mode": "diurnal", "base_g_per_kwh": 373.4,
                            "period_s": 86400, "samples": 24}},
                {"name": "eu-west",
                 "cluster": {"pools": [
                     {"category": "A", "count": 4, "cpu_millis": 2000,
                      "memory_mib": 4096, "speed_factor": 0.7,
                      "power_scale": 0.45}]},
                 "autoscaler": {"scale_out_pending": 2,
                                "scale_out_wait_p95_s": 12.5,
                                "max_extra_nodes": 2,
                                "window": {"percentile": 0.5,
                                           "idle_tighten": 0.25,
                                           "defer_scale_out_s": 10}}}
            ]}}"#;
        let cfg = config_from_json(text).unwrap();
        cfg.validate().unwrap();
        let fed = cfg.federation.as_ref().unwrap();
        assert_eq!(fed.dispatch, DispatchKind::CarbonGreedy);
        assert_eq!(fed.regions.len(), 2);
        assert_eq!(fed.regions[0].name, "us-east");
        // Absent sections keep the paper defaults.
        assert_eq!(fed.regions[0].cluster.total_nodes(), 7);
        assert!(fed.regions[0].autoscaler.is_none());
        assert_eq!(fed.regions[1].cluster.total_nodes(), 4);
        let a = fed.regions[1].autoscaler.as_ref().unwrap();
        assert_eq!(a.scale_out_pending, 2);
        assert_eq!(a.scale_out_wait_p95_s, 12.5);
        assert_eq!(a.max_extra_nodes, 2);
        assert_eq!(a.window.as_ref().unwrap().percentile, 0.5);
        // Dump → parse is the identity on the federation section.
        let back = config_from_json(&config_to_json(&cfg).pretty()).unwrap();
        assert_eq!(cfg.federation, back.federation);
        // Absent section stays absent (and absent from the dump).
        let plain = config_from_json("{}").unwrap();
        assert!(plain.federation.is_none());
        assert!(!config_to_json(&plain).pretty().contains("federation"));
    }

    #[test]
    fn federation_disabled_wait_trigger_roundtrips_by_omission() {
        // No `scale_out_wait_p95_s` key = the INFINITY sentinel; the
        // dump omits non-finite values, so the identity holds.
        let text = r#"{"federation": {"regions": [
            {"name": "solo", "autoscaler": {}}]}}"#;
        let cfg = config_from_json(text).unwrap();
        cfg.validate().unwrap();
        let a = cfg.federation.as_ref().unwrap().regions[0]
            .autoscaler
            .as_ref()
            .unwrap();
        assert!(a.scale_out_wait_p95_s.is_infinite());
        let dumped = config_to_json(&cfg).pretty();
        assert!(!dumped.contains("scale_out_wait_p95_s"), "{dumped}");
        let back = config_from_json(&dumped).unwrap();
        assert_eq!(cfg.federation, back.federation);
    }

    #[test]
    fn federation_bad_sections_rejected() {
        // Unknown dispatch policy fails at parse time.
        assert!(config_from_json(
            r#"{"federation": {"dispatch": "telepathy",
                 "regions": [{"name": "x"}]}}"#
        )
        .is_err());
        // A wrong-typed dispatch value errors rather than silently
        // falling back to round-robin.
        assert!(config_from_json(
            r#"{"federation": {"dispatch": 5,
                 "regions": [{"name": "x"}]}}"#
        )
        .is_err());
        // Missing regions array fails at parse time.
        assert!(config_from_json(r#"{"federation": {}}"#).is_err());
        // Duplicate names parse but fail validation.
        let dup = config_from_json(
            r#"{"federation": {"regions":
                 [{"name": "a"}, {"name": "a"}]}}"#,
        )
        .unwrap();
        assert!(dup.validate().is_err());
        // Non-object sub-sections error instead of silently falling
        // back to defaults (constant signal, paper cluster, default
        // autoscaler knobs).
        for bad in [
            r#"{"federation": {"regions":
                 [{"name": "a", "autoscaler": 5}]}}"#,
            r#"{"federation": {"regions":
                 [{"name": "a", "autoscaler": {"window": "p50"}}]}}"#,
            r#"{"federation": {"regions":
                 [{"name": "a", "carbon": "diurnal"}]}}"#,
            r#"{"federation": {"regions":
                 [{"name": "a", "cluster": 17}]}}"#,
        ] {
            assert!(config_from_json(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn seeds_above_2_pow_53_roundtrip_losslessly() {
        let mut cfg = crate::config::Config::paper_default();
        cfg.experiment.seed = (1u64 << 53) + 1;
        let back = config_from_json(&config_to_json(&cfg).pretty()).unwrap();
        assert_eq!(back.experiment.seed, cfg.experiment.seed);
    }

    #[test]
    fn bad_category_rejected() {
        let err = config_from_json(
            r#"{"cluster": {"pools": [
                {"category": "Z", "count": 1, "cpu_millis": 1000,
                 "memory_mib": 1024, "speed_factor": 1.0,
                 "power_scale": 1.0}
            ]}}"#,
        );
        assert!(err.is_err());
    }
}
