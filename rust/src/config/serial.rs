//! JSON (de)serialization for the config types, over `util::json`.
//!
//! Schema mirrors the struct layout:
//! ```json
//! {
//!   "cluster":    {"pools": [{"category": "A", ...}], ...},
//!   "energy":     {"pue": 1.45, ...},
//!   "experiment": {"replications": 5, "seed": 1, ...}
//! }
//! ```
//! Absent sections/fields fall back to the paper defaults, so a config
//! file only states deviations.

use anyhow::{anyhow, Result};

use crate::cluster::NodeCategory;
use crate::util::json::Json;

use super::{
    ClusterConfig, Config, EnergyModelConfig, ExperimentConfig,
    NodePoolConfig,
};

// ------------------------------------------------------------ helpers

fn get_f64(obj: &Json, key: &str, default: f64) -> Result<f64> {
    match obj.get(key) {
        None => Ok(default),
        Some(v) => v
            .as_f64()
            .ok_or_else(|| anyhow!("field `{key}` is not a number")),
    }
}

fn get_u64(obj: &Json, key: &str, default: u64) -> Result<u64> {
    match obj.get(key) {
        None => Ok(default),
        Some(v) => v
            .as_u64()
            .ok_or_else(|| anyhow!("field `{key}` is not an integer")),
    }
}

fn category_from_str(s: &str) -> Result<NodeCategory> {
    match s {
        "A" => Ok(NodeCategory::A),
        "B" => Ok(NodeCategory::B),
        "C" => Ok(NodeCategory::C),
        "Default" => Ok(NodeCategory::Default),
        other => Err(anyhow!("unknown node category `{other}`")),
    }
}

// ------------------------------------------------------------- loads

pub fn config_from_json(text: &str) -> Result<Config> {
    let v = Json::parse(text)?;
    let mut cfg = Config::paper_default();
    if let Some(c) = v.get("cluster") {
        cfg.cluster = cluster_from_json(c)?;
    }
    if let Some(e) = v.get("energy") {
        cfg.energy = energy_from_json(e)?;
    }
    if let Some(x) = v.get("experiment") {
        cfg.experiment = experiment_from_json(x)?;
    }
    Ok(cfg)
}

fn cluster_from_json(v: &Json) -> Result<ClusterConfig> {
    let mut cfg = ClusterConfig::paper_default();
    if let Some(pools) = v.get("pools") {
        let arr = pools
            .as_arr()
            .ok_or_else(|| anyhow!("`pools` is not an array"))?;
        cfg.pools = arr
            .iter()
            .map(|p| {
                Ok(NodePoolConfig {
                    category: category_from_str(p.req_str("category")?)?,
                    machine_type: p
                        .get("machine_type")
                        .and_then(Json::as_str)
                        .unwrap_or("custom")
                        .to_string(),
                    count: p.req_usize("count")?,
                    cpu_millis: p.req_f64("cpu_millis")? as u64,
                    memory_mib: p.req_f64("memory_mib")? as u64,
                    speed_factor: p.req_f64("speed_factor")?,
                    power_scale: p.req_f64("power_scale")?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
    }
    if let Some(b) = v.get("schedulable_default_pool") {
        cfg.schedulable_default_pool = b
            .as_bool()
            .ok_or_else(|| anyhow!("schedulable_default_pool not bool"))?;
    }
    Ok(cfg)
}

fn energy_from_json(v: &Json) -> Result<EnergyModelConfig> {
    let d = EnergyModelConfig::default();
    Ok(EnergyModelConfig {
        p_idle: get_f64(v, "p_idle", d.p_idle)?,
        k_cpu: get_f64(v, "k_cpu", d.k_cpu)?,
        k_mem: get_f64(v, "k_mem", d.k_mem)?,
        k_disk: get_f64(v, "k_disk", d.k_disk)?,
        k_net: get_f64(v, "k_net", d.k_net)?,
        pue: get_f64(v, "pue", d.pue)?,
        mem_accesses_per_sec: get_f64(
            v, "mem_accesses_per_sec", d.mem_accesses_per_sec)?,
        disk_iops: get_f64(v, "disk_iops", d.disk_iops)?,
        net_ops_per_sec: get_f64(v, "net_ops_per_sec", d.net_ops_per_sec)?,
        co2_lb_per_kwh: get_f64(v, "co2_lb_per_kwh", d.co2_lb_per_kwh)?,
        usd_per_kwh: get_f64(v, "usd_per_kwh", d.usd_per_kwh)?,
        carbon_credit_usd_min: get_f64(
            v, "carbon_credit_usd_min", d.carbon_credit_usd_min)?,
        carbon_credit_usd_max: get_f64(
            v, "carbon_credit_usd_max", d.carbon_credit_usd_max)?,
        vehicle_tons_per_year: get_f64(
            v, "vehicle_tons_per_year", d.vehicle_tons_per_year)?,
    })
}

fn experiment_from_json(v: &Json) -> Result<ExperimentConfig> {
    let d = ExperimentConfig::default();
    Ok(ExperimentConfig {
        replications: get_u64(v, "replications", d.replications as u64)?
            as u32,
        seed: get_u64(v, "seed", d.seed)?,
        arrival_jitter_s: get_f64(v, "arrival_jitter_s", d.arrival_jitter_s)?,
        contention_beta: get_f64(v, "contention_beta", d.contention_beta)?,
        epochs_light: get_u64(v, "epochs_light", d.epochs_light as u64)?
            as u32,
        epochs_medium: get_u64(v, "epochs_medium", d.epochs_medium as u64)?
            as u32,
        epochs_complex: get_u64(
            v, "epochs_complex", d.epochs_complex as u64)? as u32,
    })
}

// ------------------------------------------------------------- dumps

pub fn config_to_json(cfg: &Config) -> Json {
    Json::obj(vec![
        ("cluster", cluster_to_json(&cfg.cluster)),
        ("energy", energy_to_json(&cfg.energy)),
        ("experiment", experiment_to_json(&cfg.experiment)),
    ])
}

pub fn cluster_to_json(c: &ClusterConfig) -> Json {
    Json::obj(vec![
        (
            "pools",
            Json::Arr(
                c.pools
                    .iter()
                    .map(|p| {
                        Json::obj(vec![
                            ("category",
                             Json::Str(p.category.label().into())),
                            ("machine_type",
                             Json::Str(p.machine_type.clone())),
                            ("count", Json::Num(p.count as f64)),
                            ("cpu_millis", Json::Num(p.cpu_millis as f64)),
                            ("memory_mib", Json::Num(p.memory_mib as f64)),
                            ("speed_factor", Json::Num(p.speed_factor)),
                            ("power_scale", Json::Num(p.power_scale)),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "schedulable_default_pool",
            Json::Bool(c.schedulable_default_pool),
        ),
    ])
}

pub fn energy_to_json(e: &EnergyModelConfig) -> Json {
    Json::obj(vec![
        ("p_idle", Json::Num(e.p_idle)),
        ("k_cpu", Json::Num(e.k_cpu)),
        ("k_mem", Json::Num(e.k_mem)),
        ("k_disk", Json::Num(e.k_disk)),
        ("k_net", Json::Num(e.k_net)),
        ("pue", Json::Num(e.pue)),
        ("mem_accesses_per_sec", Json::Num(e.mem_accesses_per_sec)),
        ("disk_iops", Json::Num(e.disk_iops)),
        ("net_ops_per_sec", Json::Num(e.net_ops_per_sec)),
        ("co2_lb_per_kwh", Json::Num(e.co2_lb_per_kwh)),
        ("usd_per_kwh", Json::Num(e.usd_per_kwh)),
        ("carbon_credit_usd_min", Json::Num(e.carbon_credit_usd_min)),
        ("carbon_credit_usd_max", Json::Num(e.carbon_credit_usd_max)),
        ("vehicle_tons_per_year", Json::Num(e.vehicle_tons_per_year)),
    ])
}

pub fn experiment_to_json(x: &ExperimentConfig) -> Json {
    Json::obj(vec![
        ("replications", Json::Num(x.replications as f64)),
        ("seed", Json::Num(x.seed as f64)),
        ("arrival_jitter_s", Json::Num(x.arrival_jitter_s)),
        ("contention_beta", Json::Num(x.contention_beta)),
        ("epochs_light", Json::Num(x.epochs_light as f64)),
        ("epochs_medium", Json::Num(x.epochs_medium as f64)),
        ("epochs_complex", Json::Num(x.epochs_complex as f64)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn custom_pools_parse() {
        let cfg = config_from_json(
            r#"{"cluster": {"pools": [
                {"category": "A", "count": 3, "cpu_millis": 2000,
                 "memory_mib": 4096, "speed_factor": 0.7,
                 "power_scale": 0.45}
            ]}}"#,
        )
        .unwrap();
        assert_eq!(cfg.cluster.pools.len(), 1);
        assert_eq!(cfg.cluster.total_nodes(), 3);
        assert_eq!(cfg.cluster.pools[0].machine_type, "custom");
    }

    #[test]
    fn bad_category_rejected() {
        let err = config_from_json(
            r#"{"cluster": {"pools": [
                {"category": "Z", "count": 1, "cpu_millis": 1000,
                 "memory_mib": 1024, "speed_factor": 1.0,
                 "power_scale": 1.0}
            ]}}"#,
        );
        assert!(err.is_err());
    }
}
