//! Multi-cluster federation configuration — the `federation` section
//! of a config file: named region entries, each carrying its own
//! cluster topology, carbon-intensity signal and (optional) autoscaler
//! knobs, plus the dispatch policy that routes arriving pods between
//! regions (DESIGN.md §"Federation").
//!
//! This module is pure data + validation; `federation::RegionSpec::
//! from_federation_config` materializes the runtime region specs and
//! `autoscaler::ThresholdConfig::from_region` builds the per-region
//! scaling policy around the region's cluster and signal.

use anyhow::{ensure, Result};

use super::{CarbonConfig, ClusterConfig, EnergyModelConfig};

/// How the federation dispatcher routes each arriving pod to a region
/// (before the region's own scheduling profile places it on a node).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DispatchKind {
    /// Cycle through regions in index order, blind to state.
    RoundRobin,
    /// The region with the fewest pending (dispatched, unplaced) pods.
    LeastPending,
    /// The currently cleanest region (lowest `signal.at(now)`) that
    /// still has headroom for the pod; falls back to least-pending
    /// when every region is full.
    CarbonGreedy,
}

impl DispatchKind {
    pub const ALL: [DispatchKind; 3] = [
        DispatchKind::RoundRobin,
        DispatchKind::LeastPending,
        DispatchKind::CarbonGreedy,
    ];

    pub fn label(self) -> &'static str {
        match self {
            DispatchKind::RoundRobin => "round-robin",
            DispatchKind::LeastPending => "least-pending",
            DispatchKind::CarbonGreedy => "carbon-greedy",
        }
    }
}

impl std::str::FromStr for DispatchKind {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        match s {
            "round-robin" => Ok(DispatchKind::RoundRobin),
            "least-pending" => Ok(DispatchKind::LeastPending),
            "carbon-greedy" => Ok(DispatchKind::CarbonGreedy),
            other => anyhow::bail!(
                "unknown dispatch policy `{other}` \
                 (round-robin|least-pending|carbon-greedy)"
            ),
        }
    }
}

/// Carbon scale-down window knobs of a region autoscaler (the
/// percentile-derived `CarbonWindowConfig` parameters).
#[derive(Debug, Clone, PartialEq)]
pub struct CarbonWindowParams {
    /// Quantile of the region signal's samples that sets the dirty
    /// threshold, in `[0, 1]`.
    pub percentile: f64,
    /// Idle scale-in multiplier while dirty, in `(0, 1]`.
    pub idle_tighten: f64,
    /// Bound (s) on deferring depth-triggered scale-out while dirty.
    pub defer_scale_out_s: f64,
}

/// Serializable per-region autoscaler knobs. Cluster-derived values
/// (node bounds, the edge template) are filled in by
/// `autoscaler::ThresholdConfig::from_region` at build time.
#[derive(Debug, Clone, PartialEq)]
pub struct RegionAutoscalerConfig {
    /// Depth trigger (`0` disables).
    pub scale_out_pending: usize,
    /// Wait trigger (`f64::INFINITY`, the default when absent,
    /// disables; the JSON dump encodes the sentinel by omission).
    pub scale_out_wait_p95_s: f64,
    pub provision_delay_s: f64,
    pub cooldown_s: f64,
    /// Idle scale-in timeout. Must be **finite** (validated): JSON
    /// cannot encode the `INFINITY` sentinel the runtime
    /// `ThresholdConfig` uses, so "no idle scale-in" is expressed
    /// with a horizon-exceeding finite timeout instead.
    pub idle_scale_in_s: f64,
    /// Nodes the policy may add beyond the region's base cluster
    /// (bounds become `[base, base + max_extra_nodes]`).
    pub max_extra_nodes: usize,
    /// Optional carbon scale-down windows over the region's signal.
    pub window: Option<CarbonWindowParams>,
}

impl Default for RegionAutoscalerConfig {
    /// The elastic-experiment threshold policy's knobs.
    fn default() -> Self {
        Self {
            scale_out_pending: 3,
            scale_out_wait_p95_s: f64::INFINITY,
            provision_delay_s: 5.0,
            cooldown_s: 15.0,
            idle_scale_in_s: 20.0,
            max_extra_nodes: 3,
            window: None,
        }
    }
}

impl RegionAutoscalerConfig {
    pub fn validate(&self) -> Result<()> {
        ensure!(
            self.provision_delay_s.is_finite()
                && self.provision_delay_s >= 0.0,
            "autoscaler provision_delay_s {} must be a finite \
             non-negative number",
            self.provision_delay_s
        );
        ensure!(
            self.cooldown_s.is_finite() && self.cooldown_s >= 0.0,
            "autoscaler cooldown_s {} must be a finite non-negative \
             number",
            self.cooldown_s
        );
        ensure!(
            self.scale_out_wait_p95_s >= 0.0,
            "autoscaler scale_out_wait_p95_s {} must be non-negative",
            self.scale_out_wait_p95_s
        );
        // Finite by requirement: JSON cannot encode the `INFINITY`
        // disable sentinel, so a config-file region expresses "no idle
        // scale-in" with a horizon-exceeding finite timeout instead.
        ensure!(
            self.idle_scale_in_s.is_finite() && self.idle_scale_in_s >= 0.0,
            "autoscaler idle_scale_in_s {} must be a finite non-negative \
             number",
            self.idle_scale_in_s
        );
        if let Some(w) = &self.window {
            ensure!(
                (0.0..=1.0).contains(&w.percentile),
                "carbon window percentile {} must be in [0, 1]",
                w.percentile
            );
            ensure!(
                w.idle_tighten > 0.0 && w.idle_tighten <= 1.0,
                "carbon window idle_tighten {} must be in (0, 1]",
                w.idle_tighten
            );
            ensure!(
                w.defer_scale_out_s.is_finite()
                    && w.defer_scale_out_s >= 0.0,
                "carbon window defer_scale_out_s {} must be a finite \
                 non-negative number",
                w.defer_scale_out_s
            );
        }
        Ok(())
    }
}

/// One named region: its own cluster topology and carbon signal, plus
/// optional autoscaling.
#[derive(Debug, Clone, PartialEq)]
pub struct RegionConfig {
    pub name: String,
    pub cluster: ClusterConfig,
    pub carbon: CarbonConfig,
    pub autoscaler: Option<RegionAutoscalerConfig>,
}

impl RegionConfig {
    /// A paper-default cluster under a constant (eGRID-scalar) signal.
    pub fn named(name: &str) -> Self {
        Self {
            name: name.to_string(),
            cluster: ClusterConfig::paper_default(),
            carbon: CarbonConfig::default(),
            autoscaler: None,
        }
    }
}

/// The `federation` config section.
#[derive(Debug, Clone, PartialEq)]
pub struct FederationConfig {
    pub dispatch: DispatchKind,
    pub regions: Vec<RegionConfig>,
}

impl FederationConfig {
    pub fn validate(&self, energy: &EnergyModelConfig) -> Result<()> {
        ensure!(
            !self.regions.is_empty(),
            "federation section has no regions"
        );
        for (i, r) in self.regions.iter().enumerate() {
            ensure!(
                !r.name.is_empty(),
                "federation region {i} has an empty name"
            );
            ensure!(
                !self.regions[..i].iter().any(|p| p.name == r.name),
                "federation region name `{}` is not unique",
                r.name
            );
            r.cluster.validate().map_err(|e| {
                anyhow::anyhow!("federation region `{}`: {e}", r.name)
            })?;
            r.carbon.validate(energy).map_err(|e| {
                anyhow::anyhow!("federation region `{}`: {e}", r.name)
            })?;
            if let Some(a) = &r.autoscaler {
                a.validate().map_err(|e| {
                    anyhow::anyhow!("federation region `{}`: {e}", r.name)
                })?;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dispatch_kinds_roundtrip_labels() {
        for kind in DispatchKind::ALL {
            assert_eq!(kind.label().parse::<DispatchKind>().unwrap(), kind);
        }
        assert!("warp-routing".parse::<DispatchKind>().is_err());
    }

    #[test]
    fn valid_two_region_section() {
        let fc = FederationConfig {
            dispatch: DispatchKind::CarbonGreedy,
            regions: vec![
                RegionConfig::named("us-east"),
                RegionConfig {
                    autoscaler: Some(RegionAutoscalerConfig::default()),
                    ..RegionConfig::named("eu-west")
                },
            ],
        };
        fc.validate(&EnergyModelConfig::default()).unwrap();
    }

    #[test]
    fn empty_duplicate_and_bad_regions_rejected() {
        let energy = EnergyModelConfig::default();
        let empty = FederationConfig {
            dispatch: DispatchKind::RoundRobin,
            regions: vec![],
        };
        assert!(empty.validate(&energy).is_err());

        let dup = FederationConfig {
            dispatch: DispatchKind::RoundRobin,
            regions: vec![
                RegionConfig::named("same"),
                RegionConfig::named("same"),
            ],
        };
        assert!(dup.validate(&energy).is_err());

        let unnamed = FederationConfig {
            dispatch: DispatchKind::RoundRobin,
            regions: vec![RegionConfig::named("")],
        };
        assert!(unnamed.validate(&energy).is_err());

        let mut bad_window = RegionConfig::named("w");
        bad_window.autoscaler = Some(RegionAutoscalerConfig {
            window: Some(CarbonWindowParams {
                percentile: 2.0,
                idle_tighten: 0.5,
                defer_scale_out_s: 1.0,
            }),
            ..RegionAutoscalerConfig::default()
        });
        let fc = FederationConfig {
            dispatch: DispatchKind::CarbonGreedy,
            regions: vec![bad_window],
        };
        assert!(fc.validate(&energy).is_err());
    }

    #[test]
    fn autoscaler_knob_ranges_enforced() {
        let mut a = RegionAutoscalerConfig::default();
        a.validate().unwrap();
        a.provision_delay_s = f64::NAN;
        assert!(a.validate().is_err());
        a.provision_delay_s = 5.0;
        a.cooldown_s = -1.0;
        assert!(a.validate().is_err());
    }
}
