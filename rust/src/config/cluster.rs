//! Cluster configuration — paper Table I.
//!
//! The paper's GKE cluster has four node categories:
//!
//! | Category | Machine type           | vCPUs | Memory | Purpose |
//! |----------|------------------------|-------|--------|---------|
//! | A        | e2-medium              | 2     | 4 GB   | energy-efficient, minimal resources |
//! | B        | n2-standard-2          | 2     | 8 GB   | balanced performance |
//! | C        | n2-standard-4          | 4     | 16 GB  | high-performance, high resource |
//! | Default  | e2-standard-2          | 2     | 8 GB   | system components |
//!
//! Per-category *performance* (relative per-core speed) and *power*
//! (Dayarathna-model scale factor) profiles encode the heterogeneity the
//! paper's results depend on: E2 machines are slower but markedly more
//! energy-efficient than N2 (see `DESIGN.md` §1 substitution table).


use crate::cluster::NodeCategory;

/// One homogeneous node pool (GKE terminology).
#[derive(Debug, Clone, PartialEq)]
pub struct NodePoolConfig {
    pub category: NodeCategory,
    /// GCE machine type name (informational; profiles below are authoritative).
    pub machine_type: String,
    /// Number of identical nodes in the pool.
    pub count: usize,
    /// vCPUs per node, in millicores (2 vCPU = 2000m).
    pub cpu_millis: u64,
    /// Memory per node, MiB.
    pub memory_mib: u64,
    /// Relative per-core execution speed (1.0 = n2-standard baseline).
    pub speed_factor: f64,
    /// Scale applied to the Dayarathna blade power model for this
    /// hardware class (e2 shared-core machines draw far less than a
    /// full blade; n2-standard-4 draws more).
    pub power_scale: f64,
}

/// Cluster-wide configuration: the set of node pools.
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterConfig {
    pub pools: Vec<NodePoolConfig>,
    /// Whether the Default pool accepts user workloads (in the paper it
    /// hosts system components but remains schedulable).
    pub schedulable_default_pool: bool,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        Self::paper_default()
    }
}

impl ClusterConfig {
    /// Table I machine types, with three A nodes and two B nodes so the
    /// scheduler has real placement choice (16 vCPU / 52 GiB total; the
    /// high-competition level requests ~9.4 vCPU, and with executions
    /// overlapping, the cluster transiently approaches full utilization,
    /// matching the paper's description). Speed/power profiles are the
    /// calibrated values of EXPERIMENTS.md §Calibration.
    pub fn paper_default() -> Self {
        Self {
            pools: vec![
                NodePoolConfig {
                    category: NodeCategory::A,
                    machine_type: "e2-medium".into(),
                    count: 3,
                    cpu_millis: 2000,
                    memory_mib: 4096,
                    speed_factor: 0.70,
                    power_scale: 0.30,
                },
                NodePoolConfig {
                    category: NodeCategory::B,
                    machine_type: "n2-standard-2".into(),
                    count: 2,
                    cpu_millis: 2000,
                    memory_mib: 8192,
                    speed_factor: 1.00,
                    power_scale: 0.55,
                },
                NodePoolConfig {
                    category: NodeCategory::C,
                    machine_type: "n2-standard-4".into(),
                    count: 1,
                    cpu_millis: 4000,
                    memory_mib: 16384,
                    speed_factor: 1.10,
                    power_scale: 2.60,
                },
                NodePoolConfig {
                    category: NodeCategory::Default,
                    machine_type: "e2-standard-2".into(),
                    count: 1,
                    cpu_millis: 2000,
                    memory_mib: 8192,
                    speed_factor: 0.85,
                    power_scale: 0.50,
                },
            ],
            schedulable_default_pool: true,
        }
    }

    /// A scaled cluster with `n` copies of each paper pool (benchmarks).
    pub fn scaled(n: usize) -> Self {
        let mut cfg = Self::paper_default();
        for pool in &mut cfg.pools {
            pool.count *= n;
        }
        cfg
    }

    /// This cluster with every pool's node count divided by `k`
    /// (ceiling, so no pool vanishes) — the capacity-side companion of
    /// the trace down-sampler: replaying every k-th pod against 1/k of
    /// the machines keeps the offered load per node comparable.
    pub fn downsampled(&self, k: usize) -> Self {
        assert!(k > 0, "downsampled(0)");
        let mut cfg = self.clone();
        for pool in &mut cfg.pools {
            pool.count = pool.count.div_ceil(k);
        }
        cfg
    }

    pub fn total_nodes(&self) -> usize {
        self.pools.iter().map(|p| p.count).sum()
    }

    pub fn total_cpu_millis(&self) -> u64 {
        self.pools.iter().map(|p| p.count as u64 * p.cpu_millis).sum()
    }

    pub fn total_memory_mib(&self) -> u64 {
        self.pools.iter().map(|p| p.count as u64 * p.memory_mib).sum()
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(!self.pools.is_empty(), "cluster has no node pools");
        for p in &self.pools {
            anyhow::ensure!(p.count > 0, "pool {:?} has zero nodes", p.category);
            anyhow::ensure!(
                p.cpu_millis >= 100,
                "pool {:?}: cpu_millis < 100",
                p.category
            );
            anyhow::ensure!(
                p.memory_mib >= 128,
                "pool {:?}: memory_mib < 128",
                p.category
            );
            anyhow::ensure!(
                p.speed_factor > 0.0 && p.speed_factor <= 10.0,
                "pool {:?}: speed_factor out of range",
                p.category
            );
            anyhow::ensure!(
                p.power_scale > 0.0 && p.power_scale <= 10.0,
                "pool {:?}: power_scale out of range",
                p.category
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_capacities() {
        let cfg = ClusterConfig::paper_default();
        assert_eq!(cfg.total_nodes(), 7);
        assert_eq!(cfg.total_cpu_millis(), 16_000);
        assert_eq!(cfg.total_memory_mib(), 3 * 4096 + 2 * 8192 + 16384 + 8192);
        let a = &cfg.pools[0];
        assert_eq!(a.machine_type, "e2-medium");
        assert_eq!((a.cpu_millis, a.memory_mib), (2000, 4096));
        let c = &cfg.pools[2];
        assert_eq!((c.cpu_millis, c.memory_mib), (4000, 16384));
    }

    #[test]
    fn category_a_is_most_efficient() {
        let cfg = ClusterConfig::paper_default();
        let scale = |cat: NodeCategory| {
            cfg.pools
                .iter()
                .find(|p| p.category == cat)
                .unwrap()
                .power_scale
        };
        assert!(scale(NodeCategory::A) < scale(NodeCategory::B));
        assert!(scale(NodeCategory::B) < scale(NodeCategory::C));
    }

    #[test]
    fn scaled_multiplies_counts() {
        assert_eq!(ClusterConfig::scaled(4).total_nodes(), 28);
    }

    #[test]
    fn downsampled_ceil_divides_and_keeps_every_pool() {
        // Paper pools are 3/2/1/1: k=2 → 2/1/1/1, and even k ≫ counts
        // leaves one node per pool (the cluster never vanishes).
        let cfg = ClusterConfig::paper_default();
        let half = cfg.downsampled(2);
        let counts: Vec<usize> = half.pools.iter().map(|p| p.count).collect();
        assert_eq!(counts, [2, 1, 1, 1]);
        let tiny = cfg.downsampled(100);
        assert!(tiny.pools.iter().all(|p| p.count == 1));
        assert!(tiny.validate().is_ok());
        // Round-trips with scaled for exact multiples.
        assert_eq!(
            ClusterConfig::scaled(6).downsampled(6),
            ClusterConfig::paper_default()
        );
    }

    #[test]
    fn invalid_pool_rejected() {
        let mut cfg = ClusterConfig::paper_default();
        cfg.pools[0].speed_factor = 0.0;
        assert!(cfg.validate().is_err());
    }
}
