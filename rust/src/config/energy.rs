//! Energy-model constants — the Dayarathna et al. blade-server power
//! model the paper itself uses for its impact analysis (§V.E), plus the
//! conversion factors of §V.F (eGRID CO₂, EIA rate, World Bank credits).
//!
//! Blade model:
//! `P = 14.45 + 0.236·u_cpu − 4.47e-8·u_mem + 0.00281·u_disk + 3.1e-8·u_net` W
//! with `u_cpu` in percent, `u_mem` memory accesses/s, `u_disk` IO ops/s,
//! `u_net` network ops/s.


#[derive(Debug, Clone, PartialEq)]
pub struct EnergyModelConfig {
    /// Blade-model constant term (W).
    pub p_idle: f64,
    /// CPU coefficient (W per % utilization).
    pub k_cpu: f64,
    /// Memory coefficient (W per access/s; negative in the paper's model).
    pub k_mem: f64,
    /// Disk coefficient (W per IO op/s).
    pub k_disk: f64,
    /// Network coefficient (W per op/s).
    pub k_net: f64,
    /// Power-usage-effectiveness multiplier (paper: 1.45).
    pub pue: f64,
    /// Typical workload parameters used by §V.E (memory accesses/s,
    /// disk IOPS, network ops/s) — applied proportionally to CPU load.
    pub mem_accesses_per_sec: f64,
    pub disk_iops: f64,
    pub net_ops_per_sec: f64,
    /// eGRID national average emission factor (lb CO₂ / kWh).
    pub co2_lb_per_kwh: f64,
    /// EIA average commercial electricity rate ($ / kWh).
    pub usd_per_kwh: f64,
    /// World Bank carbon-credit price range ($ / metric ton CO₂).
    pub carbon_credit_usd_min: f64,
    pub carbon_credit_usd_max: f64,
    /// EPA average passenger-vehicle emissions (metric tons CO₂ / yr).
    pub vehicle_tons_per_year: f64,
}

impl Default for EnergyModelConfig {
    fn default() -> Self {
        Self {
            p_idle: 14.45,
            k_cpu: 0.236,
            k_mem: -4.47e-8,
            k_disk: 0.00281,
            k_net: 3.1e-8,
            pue: 1.45,
            mem_accesses_per_sec: 8.0e6,
            disk_iops: 350.0,
            net_ops_per_sec: 3.0e6,
            co2_lb_per_kwh: 0.823,
            usd_per_kwh: 0.1289,
            carbon_credit_usd_min: 0.46,
            carbon_credit_usd_max: 167.0,
            vehicle_tons_per_year: 4.6,
        }
    }
}

impl EnergyModelConfig {
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.p_idle > 0.0, "p_idle must be positive");
        anyhow::ensure!(self.k_cpu > 0.0, "k_cpu must be positive");
        anyhow::ensure!(self.pue >= 1.0, "PUE < 1 is unphysical");
        anyhow::ensure!(
            self.carbon_credit_usd_min <= self.carbon_credit_usd_max,
            "carbon credit range inverted"
        );
        anyhow::ensure!(self.usd_per_kwh > 0.0, "electricity rate <= 0");
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper_constants() {
        let c = EnergyModelConfig::default();
        assert_eq!(c.p_idle, 14.45);
        assert_eq!(c.k_cpu, 0.236);
        assert_eq!(c.pue, 1.45);
        assert_eq!(c.co2_lb_per_kwh, 0.823);
        assert_eq!(c.usd_per_kwh, 0.1289);
        assert_eq!((c.carbon_credit_usd_min, c.carbon_credit_usd_max),
                   (0.46, 167.0));
        c.validate().unwrap();
    }

    #[test]
    fn bad_pue_rejected() {
        let mut c = EnergyModelConfig::default();
        c.pue = 0.5;
        assert!(c.validate().is_err());
    }
}
