//! Weighting schemes (scheduling profiles) — paper §IV.D.
//!
//! GreenPod scores nodes on five criteria; each profile reweights them:
//!
//! * **General (balanced)** — equal importance to all metrics.
//! * **Energy-centric** — prioritizes power consumption.
//! * **Performance-centric** — emphasizes execution speed.
//! * **Resource-efficient** — balances utilization and energy.


/// Number of scheduling criteria (paper abstract: execution time, energy
/// consumption, processing core, memory availability, resource balance).
pub const NUM_CRITERIA: usize = 5;

/// Criterion order used everywhere a decision matrix appears.
pub const CRITERIA_NAMES: [&str; NUM_CRITERIA] = [
    "exec_time",
    "energy",
    "free_cores",
    "free_memory",
    "resource_balance",
];

/// Criterion direction: `exec_time` and `energy` are costs, the rest are
/// benefits. 1.0 = benefit, 0.0 = cost (the kernel-side convention).
pub const BENEFIT_MASK: [f64; NUM_CRITERIA] = [0.0, 0.0, 1.0, 1.0, 1.0];

/// A scheduling profile from §IV.D. `Ord` follows declaration order —
/// the paper's Table VI reporting order — so ordered maps keyed by
/// scheme render rows in paper order.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum WeightingScheme {
    General,
    EnergyCentric,
    PerformanceCentric,
    ResourceEfficient,
}

impl WeightingScheme {
    /// All four profiles, in the paper's reporting order (Table VI).
    pub const ALL: [WeightingScheme; 4] = [
        WeightingScheme::General,
        WeightingScheme::EnergyCentric,
        WeightingScheme::PerformanceCentric,
        WeightingScheme::ResourceEfficient,
    ];

    /// Criterion weights `[exec_time, energy, cores, memory, balance]`.
    /// Each sums to 1.0 (validated by tests and proptest).
    pub fn weights(self) -> [f64; NUM_CRITERIA] {
        match self {
            WeightingScheme::General => [0.20, 0.20, 0.20, 0.20, 0.20],
            WeightingScheme::EnergyCentric => [0.15, 0.40, 0.15, 0.15, 0.15],
            WeightingScheme::PerformanceCentric => {
                [0.50, 0.10, 0.15, 0.15, 0.10]
            }
            WeightingScheme::ResourceEfficient => {
                [0.05, 0.35, 0.15, 0.15, 0.30]
            }
        }
    }

    /// Paper display name.
    pub fn label(self) -> &'static str {
        match self {
            WeightingScheme::General => "General (Balanced)",
            WeightingScheme::EnergyCentric => "Energy-centric",
            WeightingScheme::PerformanceCentric => "Performance-centric",
            WeightingScheme::ResourceEfficient => "Resource-efficient",
        }
    }
}

impl std::str::FromStr for WeightingScheme {
    type Err = anyhow::Error;

    /// kebab-case names, as used on the CLI (`--scheme energy-centric`).
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "general" => Ok(WeightingScheme::General),
            "energy-centric" => Ok(WeightingScheme::EnergyCentric),
            "performance-centric" => Ok(WeightingScheme::PerformanceCentric),
            "resource-efficient" => Ok(WeightingScheme::ResourceEfficient),
            other => anyhow::bail!(
                "unknown weighting scheme `{other}` (expected general, \
                 energy-centric, performance-centric, resource-efficient)"
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn weights_sum_to_one() {
        for s in WeightingScheme::ALL {
            let sum: f64 = s.weights().iter().sum();
            assert!((sum - 1.0).abs() < 1e-9, "{s:?} sums to {sum}");
        }
    }

    #[test]
    fn energy_centric_prioritizes_energy() {
        let w = WeightingScheme::EnergyCentric.weights();
        assert!(w[1] > w[0] && w[1] > w[2] && w[1] > w[3] && w[1] > w[4]);
    }

    #[test]
    fn performance_centric_prioritizes_exec_time() {
        let w = WeightingScheme::PerformanceCentric.weights();
        let max = *w
            .iter()
            .max_by(|a, b| crate::util::stats::total_order(a, b))
            .unwrap();
        assert_eq!(w[0], max);
    }

    #[test]
    fn general_is_uniform() {
        let w = WeightingScheme::General.weights();
        assert!(w.iter().all(|&x| (x - 0.2).abs() < 1e-12));
    }

    #[test]
    fn from_str_kebab_case() {
        let s: WeightingScheme = "energy-centric".parse().unwrap();
        assert_eq!(s, WeightingScheme::EnergyCentric);
        assert!("energy".parse::<WeightingScheme>().is_err());
    }
}
