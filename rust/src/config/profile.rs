//! Scheduling-profile configuration: named plugin compositions, as
//! data. The framework's [`ProfileRegistry`] materializes these specs
//! into runnable schedulers; the JSON schema lives in
//! [`super::serial`].
//!
//! [`ProfileRegistry`]: crate::framework::ProfileRegistry

use crate::mcda::McdaMethod;

use super::WeightingScheme;

/// Profile names reserved by the framework's built-ins — config-defined
/// profiles may not shadow them.
pub const BUILTIN_PROFILE_NAMES: [&str; 4] =
    ["greenpod", "default-k8s", "carbon-aware", "hybrid-topsis-balanced"];

/// Deprecated scheduler names from the retired monolith era, mapped to
/// the framework profile that replaced each. The registry resolves
/// these on `build`/`contains` so monolith-era configs and `--profile`
/// flags keep working; they are reserved like built-ins, so
/// config-defined profiles may not shadow them either.
pub const LEGACY_PROFILE_ALIASES: [(&str, &str); 1] =
    [("greenpod-topsis", "greenpod")];

/// Tie-break policy of a configured profile.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ProfileTieBreak {
    LowestIndex,
    SeededRandom,
}

impl ProfileTieBreak {
    pub fn label(self) -> &'static str {
        match self {
            ProfileTieBreak::LowestIndex => "lowest-index",
            ProfileTieBreak::SeededRandom => "seeded-random",
        }
    }
}

impl std::str::FromStr for ProfileTieBreak {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "lowest-index" => Ok(ProfileTieBreak::LowestIndex),
            "seeded-random" => Ok(ProfileTieBreak::SeededRandom),
            other => anyhow::bail!(
                "unknown tie_break `{other}` (lowest-index|seeded-random)"
            ),
        }
    }
}

/// Which score plugin a profile entry names.
#[derive(Debug, Clone, PartialEq)]
pub enum ScorePluginKind {
    LeastAllocated,
    BalancedAllocation,
    CarbonAware,
    Mcda {
        method: McdaMethod,
        scheme: WeightingScheme,
        /// Rescale the MCDA closeness onto the 0–100 convention (for
        /// composition with the kube-style plugins).
        percent_scale: bool,
    },
}

impl ScorePluginKind {
    pub fn label(&self) -> &'static str {
        match self {
            ScorePluginKind::LeastAllocated => "least-allocated",
            ScorePluginKind::BalancedAllocation => "balanced-allocation",
            ScorePluginKind::CarbonAware => "carbon-aware",
            ScorePluginKind::Mcda { .. } => "mcda",
        }
    }
}

/// One weighted score plugin in a profile.
#[derive(Debug, Clone, PartialEq)]
pub struct ScorePluginSpec {
    pub kind: ScorePluginKind,
    pub weight: f64,
}

/// A config-defined scheduling profile (the `profiles` section of a
/// config file). All profiles implicitly filter with NodeResourcesFit.
#[derive(Debug, Clone, PartialEq)]
pub struct ProfileSpec {
    pub name: String,
    pub tie_break: ProfileTieBreak,
    pub plugins: Vec<ScorePluginSpec>,
}

impl ProfileSpec {
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(!self.name.is_empty(), "profile name must not be empty");
        anyhow::ensure!(
            !BUILTIN_PROFILE_NAMES.contains(&self.name.as_str()),
            "profile name `{}` shadows a built-in profile",
            self.name
        );
        anyhow::ensure!(
            !LEGACY_PROFILE_ALIASES
                .iter()
                .any(|(legacy, _)| *legacy == self.name),
            "profile name `{}` shadows a deprecated built-in alias",
            self.name
        );
        anyhow::ensure!(
            !self.plugins.is_empty(),
            "profile `{}` has no score plugins",
            self.name
        );
        for p in &self.plugins {
            anyhow::ensure!(
                p.weight.is_finite() && p.weight > 0.0,
                "profile `{}`: plugin `{}` weight must be a finite \
                 positive number, got {}",
                self.name,
                p.kind.label(),
                p.weight
            );
        }
        Ok(())
    }
}

/// Validate a profile list (individual specs + duplicate names).
pub fn validate_profiles(profiles: &[ProfileSpec]) -> anyhow::Result<()> {
    for (i, p) in profiles.iter().enumerate() {
        p.validate()?;
        anyhow::ensure!(
            !profiles[..i].iter().any(|q| q.name == p.name),
            "duplicate profile name `{}`",
            p.name
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(name: &str) -> ProfileSpec {
        ProfileSpec {
            name: name.into(),
            tie_break: ProfileTieBreak::LowestIndex,
            plugins: vec![ScorePluginSpec {
                kind: ScorePluginKind::LeastAllocated,
                weight: 1.0,
            }],
        }
    }

    #[test]
    fn valid_spec_passes() {
        validate_profiles(&[spec("mine"), spec("yours")]).unwrap();
    }

    #[test]
    fn builtin_shadowing_rejected() {
        assert!(spec("greenpod").validate().is_err());
        assert!(spec("default-k8s").validate().is_err());
    }

    #[test]
    fn legacy_alias_shadowing_rejected() {
        assert!(spec("greenpod-topsis").validate().is_err());
    }

    #[test]
    fn bad_weight_rejected() {
        let mut s = spec("w");
        s.plugins[0].weight = 0.0;
        assert!(s.validate().is_err());
        s.plugins[0].weight = f64::NAN;
        assert!(s.validate().is_err());
        s.plugins[0].weight = -1.0;
        assert!(s.validate().is_err());
    }

    #[test]
    fn empty_plugins_rejected() {
        let mut s = spec("e");
        s.plugins.clear();
        assert!(s.validate().is_err());
    }

    #[test]
    fn duplicate_names_rejected() {
        assert!(validate_profiles(&[spec("a"), spec("a")]).is_err());
    }

    #[test]
    fn tie_break_parses() {
        assert_eq!(
            "seeded-random".parse::<ProfileTieBreak>().unwrap(),
            ProfileTieBreak::SeededRandom
        );
        assert!("coin-flip".parse::<ProfileTieBreak>().is_err());
    }
}
