//! Configuration system: every experimental knob of the paper, as data.
//!
//! The defaults reproduce the paper's setup exactly:
//! * Table I  — cluster node categories ([`ClusterConfig::paper_default`])
//! * Table II — workload classes ([`crate::workload::WorkloadClass`])
//! * Table III/V — factorial design & competition levels
//! * §IV.D — weighting schemes ([`WeightingScheme`])
//!
//! Configs serialize to/from JSON (via the in-tree `util::json`
//! substrate — DESIGN.md §1b) so experiments can be driven from files
//! (`greenpod experiment table6 --config my.json`) and every run can
//! record the exact configuration it used.

mod carbon;
mod cluster;
mod energy;
mod experiment;
mod federation;
mod profile;
mod serial;
mod weights;

pub use carbon::{CarbonConfig, CarbonMode, CarbonPoint, J_PER_KWH};
pub use cluster::{ClusterConfig, NodePoolConfig};
pub use energy::EnergyModelConfig;
pub use experiment::{
    CompetitionLevel, ExperimentConfig, PodMix, SchedulerKind,
};
pub use federation::{
    CarbonWindowParams, DispatchKind, FederationConfig,
    RegionAutoscalerConfig, RegionConfig,
};
pub use profile::{
    ProfileSpec, ProfileTieBreak, ScorePluginKind, ScorePluginSpec,
    BUILTIN_PROFILE_NAMES, LEGACY_PROFILE_ALIASES,
};
pub use weights::{WeightingScheme, BENEFIT_MASK, CRITERIA_NAMES, NUM_CRITERIA};

/// Top-level config bundle (what a JSON config file deserializes into).
#[derive(Debug, Clone, Default)]
pub struct Config {
    pub cluster: ClusterConfig,
    pub energy: EnergyModelConfig,
    pub experiment: ExperimentConfig,
    /// Grid carbon-intensity signal (`constant` by default — the
    /// legacy eGRID scalar path, bit-for-bit).
    pub carbon: CarbonConfig,
    /// User-defined scheduling profiles, registered alongside the
    /// framework built-ins (see `framework::ProfileRegistry`).
    pub profiles: Vec<ProfileSpec>,
    /// Multi-cluster federation: named regions with per-region cluster
    /// / carbon / autoscaler configuration, plus the dispatch policy
    /// (`None` = the single-cluster paper setup).
    pub federation: Option<FederationConfig>,
}

impl Config {
    /// The paper's full experimental configuration.
    pub fn paper_default() -> Self {
        Self::default()
    }

    /// Load from a JSON file; absent sections/fields keep paper
    /// defaults. See `config::serial` for the schema.
    pub fn from_json_file(path: &std::path::Path) -> anyhow::Result<Self> {
        let text = std::fs::read_to_string(path)?;
        let cfg = serial::config_from_json(&text)?;
        cfg.validate()?;
        Ok(cfg)
    }

    /// Serialize to pretty JSON (the same schema `from_json_file` reads).
    pub fn to_json(&self) -> String {
        serial::config_to_json(self).pretty()
    }

    /// Cross-field validation (weights simplex, positive capacities, ...).
    pub fn validate(&self) -> anyhow::Result<()> {
        self.cluster.validate()?;
        self.energy.validate()?;
        self.experiment.validate()?;
        self.carbon.validate(&self.energy)?;
        profile::validate_profiles(&self.profiles)?;
        if let Some(fed) = &self.federation {
            fed.validate(&self.energy)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_default_validates() {
        Config::paper_default().validate().unwrap();
    }

    #[test]
    fn json_roundtrip() {
        let cfg = Config::paper_default();
        let text = cfg.to_json();
        let back = serial::config_from_json(&text).unwrap();
        assert_eq!(cfg.cluster.pools.len(), back.cluster.pools.len());
        assert_eq!(cfg.experiment.seed, back.experiment.seed);
        assert_eq!(cfg.energy.pue, back.energy.pue);
        back.validate().unwrap();
    }

    #[test]
    fn partial_config_keeps_defaults() {
        let cfg = serial::config_from_json(
            r#"{"experiment": {"replications": 2, "seed": 9}}"#,
        )
        .unwrap();
        assert_eq!(cfg.experiment.replications, 2);
        assert_eq!(cfg.experiment.seed, 9);
        // Untouched sections keep paper values.
        assert_eq!(cfg.cluster.total_nodes(), 7);
        assert_eq!(cfg.energy.pue, 1.45);
    }
}
