//! Seeded pseudo-random number generation: xoshiro256** seeded via
//! SplitMix64 (Blackman & Vigna's reference construction).
//!
//! Deterministic across platforms and runs — the property every
//! experiment cell in this repo depends on. Not cryptographic.

/// xoshiro256** generator.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 (handles seed = 0 fine).
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = seed;
        let mut next_sm = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Self { s: [next_sm(), next_sm(), next_sm(), next_sm()] }
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        // 53 mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform usize in [0, n) (n > 0). Lemire-style rejection-free
    /// multiply-shift is overkill here; modulo bias is negligible for
    /// our n ≪ 2^64 but we debias anyway.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "below(0)");
        let n = n as u64;
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return (v % n) as usize;
            }
        }
    }

    /// Uniform u64 in [lo, hi).
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(hi > lo);
        lo + self.below((hi - lo) as usize) as u64
    }

    /// Bernoulli(p).
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Exponential with mean `mean` (inverse-CDF; used for arrival
    /// jitter and Poisson inter-arrivals).
    pub fn exponential(&mut self, mean: f64) -> f64 {
        let u = self.f64().max(1e-15);
        -mean * u.ln()
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-15);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Seeded Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::seed_from_u64(42);
        let mut b = Rng::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::seed_from_u64(43);
        assert_ne!(Rng::seed_from_u64(42).next_u64(), c.next_u64());
    }

    #[test]
    fn xoshiro_reference_vector_seed42() {
        // Cross-language pin: python/tests/test_rng_mirror.py asserts
        // the same constants for python/tools/rng_mirror.py. If either
        // implementation drifts, its side of this pair fails.
        let mut r = Rng::seed_from_u64(42);
        assert_eq!(r.next_u64(), 0x15780B2E0C2EC716);
        assert_eq!(r.next_u64(), 0x6104D9866D113A7E);
        assert_eq!(r.next_u64(), 0xAE17533239E499A1);
        assert_eq!(r.next_u64(), 0xECB8AD4703B360A1);
        assert_eq!(r.f64(), 0.9918039142821028);
        assert_eq!(r.f64(), 0.7697394604342425);
    }

    #[test]
    fn f64_in_unit_interval_and_roughly_uniform() {
        let mut r = Rng::seed_from_u64(1);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn below_covers_all_residues() {
        let mut r = Rng::seed_from_u64(2);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            seen[r.below(7)] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::seed_from_u64(3);
        let n = 50_000;
        let mean: f64 =
            (0..n).map(|_| r.exponential(2.5)).sum::<f64>() / n as f64;
        assert!((mean - 2.5).abs() < 0.05, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seed_from_u64(4);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var =
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seed_from_u64(5);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<u32>>());
        assert_ne!(xs, (0..50).collect::<Vec<u32>>()); // astronomically sure
    }

    #[test]
    #[should_panic(expected = "below(0)")]
    fn below_zero_panics() {
        Rng::seed_from_u64(0).below(0);
    }
}
