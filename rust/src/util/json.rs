//! Minimal JSON: value model, recursive-descent parser, emitter.
//!
//! Covers everything this repo serializes — the AOT manifest, golden
//! vectors, traces (JSON-lines), run reports, config files. Strict
//! enough for our own round-trips; not a general-purpose validator
//! (rejects unknown escapes rather than mapping every edge of the spec).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A JSON value. Objects are ordered (BTreeMap) so emission is
/// deterministic.
///
/// Integers have a dedicated lossless variant: [`Json::Uint`] holds a
/// `u64` exactly, where routing an id through [`Json::Num`]'s `f64`
/// would silently corrupt values at or above 2⁵³ (the JSONL event
/// stream carries `u64` pod ids — regression-tested in `api`). The
/// parser produces `Uint` for any unsigned integer literal without a
/// fraction or exponent, so round-trips preserve every digit.
///
/// Caveat: the derived equality is structural — `Num(7.0) != Uint(7)`
/// even though both emit `7`. Compare parsed trees to parsed trees
/// (or go through the [`Json::as_f64`]/[`Json::as_u64`] accessors,
/// which handle both variants); emitters that want value-level
/// dump → parse identity use `Uint` for integer fields.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    /// A non-negative integer, kept exact (no f64 round-trip).
    Uint(u64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ------------------------------------------------------ accessors

    /// Numeric view. `Uint` converts (rounding above 2⁵³, as any f64
    /// consumer must accept); use [`Json::as_u64`] where exactness
    /// matters.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            Json::Uint(x) => Some(*x as f64),
            _ => None,
        }
    }

    /// Exact integer view: `Uint` verbatim; `Num` only when it holds a
    /// representable non-negative integer.
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Uint(x) => Some(*x),
            Json::Num(x)
                if *x >= 0.0
                    && x.fract() == 0.0
                    && *x <= u64::MAX as f64 =>
            {
                Some(*x as u64)
            }
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// Required typed field helpers (error messages name the key).
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| anyhow!("missing field `{key}`"))
    }

    pub fn req_str(&self, key: &str) -> Result<&str> {
        self.req(key)?
            .as_str()
            .ok_or_else(|| anyhow!("field `{key}` is not a string"))
    }

    pub fn req_f64(&self, key: &str) -> Result<f64> {
        self.req(key)?
            .as_f64()
            .ok_or_else(|| anyhow!("field `{key}` is not a number"))
    }

    /// Exact-integer field access: no f64 round-trip for `Uint`, so
    /// 64-bit ids/counts survive above 2^53 (`lossy-id-cast`'s fix).
    pub fn req_u64(&self, key: &str) -> Result<u64> {
        self.req(key)?
            .as_u64()
            .ok_or_else(|| anyhow!("field `{key}` is not an integer"))
    }

    pub fn req_usize(&self, key: &str) -> Result<usize> {
        self.req(key)?
            .as_usize()
            .ok_or_else(|| anyhow!("field `{key}` is not an integer"))
    }

    // ---------------------------------------------------- constructors

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(
            pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect(),
        )
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    // -------------------------------------------------------- emission

    /// Compact rendering.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty rendering with 2-space indent.
    pub fn pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Uint(x) => {
                let _ = write!(out, "{x}");
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    newline(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !o.is_empty() {
                    newline(out, indent, depth);
                }
                out.push('}');
            }
        }
    }

    // --------------------------------------------------------- parsing

    /// Parse a complete JSON document (trailing whitespace allowed).
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing characters at offset {}", p.i);
        }
        Ok(v)
    }
}

fn newline(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        out.push_str(&" ".repeat(w * depth));
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            bail!(
                "expected `{}` at offset {} (found {:?})",
                c as char,
                self.i,
                self.peek().map(|b| b as char)
            )
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => bail!("unexpected {:?} at offset {}", other, self.i),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at offset {}", self.i)
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(),
            Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])?;
        // Unsigned integer literals stay exact (ids above 2⁵³ would be
        // corrupted by an f64 round-trip); anything fractional,
        // exponential, negative or beyond u64 takes the f64 path.
        if !text.starts_with('-')
            && !text.contains(&['.', 'e', 'E'][..])
        {
            if let Ok(u) = text.parse::<u64>() {
                return Ok(Json::Uint(u));
            }
        }
        Ok(Json::Num(text.parse::<f64>().map_err(|e| {
            anyhow!("bad number `{text}` at offset {start}: {e}")
        })?))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => bail!("unterminated string"),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .b
                                .get(self.i + 1..self.i + 5)
                                .ok_or_else(|| anyhow!("short \\u escape"))?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex)?,
                                16,
                            )?;
                            out.push(
                                char::from_u32(code)
                                    .ok_or_else(|| anyhow!("bad \\u"))?,
                            );
                            self.i += 4;
                        }
                        other => bail!("bad escape {:?}", other),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.b[self.i..])?;
                    let ch = rest.chars().next().unwrap();
                    out.push(ch);
                    self.i += ch.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                other => bail!("expected , or ] (found {:?})", other),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            map.insert(key, self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                other => bail!("expected , or }} (found {:?})", other),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".into())
        );
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(
            r#"{"a": [1, 2, {"b": "x", "c": null}], "d": false}"#,
        )
        .unwrap();
        assert_eq!(v.get("d"), Some(&Json::Bool(false)));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].req_str("b").unwrap(), "x");
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"k":[1,2.5,"s"],"n":{"x":true},"z":[]}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
        assert_eq!(Json::parse(&v.pretty()).unwrap(), v);
    }

    #[test]
    fn unicode_and_escapes() {
        let v = Json::parse(r#""éλ — ok""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "éλ — ok");
        let back = Json::parse(&v.to_string()).unwrap();
        assert_eq!(back, v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn typed_field_errors_name_the_key() {
        let v = Json::parse(r#"{"a": "x"}"#).unwrap();
        let err = v.req_f64("a").unwrap_err().to_string();
        assert!(err.contains("`a`"), "{err}");
        let err = v.req("missing").unwrap_err().to_string();
        assert!(err.contains("`missing`"));
    }

    #[test]
    fn integers_emit_without_decimal_point() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(2.5).to_string(), "2.5");
        assert_eq!(Json::Uint(42).to_string(), "42");
    }

    #[test]
    fn uint_is_lossless_beyond_2_pow_53() {
        // 2⁵³ + 1 is the first integer an f64 cannot represent; the
        // Uint path must carry it (and u64::MAX) digit-for-digit.
        let over = (1u64 << 53) + 1;
        for x in [over, u64::MAX, (1u64 << 60) + 3] {
            let v = Json::Uint(x);
            assert_eq!(v.to_string(), x.to_string());
            let back = Json::parse(&v.to_string()).unwrap();
            assert_eq!(back, Json::Uint(x));
            assert_eq!(back.as_u64(), Some(x));
        }
        // The f64 path really would have corrupted it.
        assert_ne!((over as f64) as u64, over);
        // Exactness also survives nesting and pretty-printing.
        let obj = Json::obj(vec![("pod", Json::Uint(over))]);
        assert!(obj.pretty().contains(&over.to_string()));
        assert_eq!(
            Json::parse(&obj.pretty()).unwrap().req("pod").unwrap(),
            &Json::Uint(over)
        );
    }

    #[test]
    fn parser_keeps_integers_exact_and_floats_floating() {
        assert_eq!(Json::parse("9007199254740993").unwrap().as_u64(),
                   Some(9007199254740993));
        assert_eq!(Json::parse("7").unwrap(), Json::Uint(7));
        // Fractions, exponents and negatives take the f64 path.
        assert_eq!(Json::parse("7.0").unwrap(), Json::Num(7.0));
        assert_eq!(Json::parse("7e0").unwrap(), Json::Num(7.0));
        assert_eq!(Json::parse("-7").unwrap(), Json::Num(-7.0));
        // Beyond u64 falls back to f64 rather than erroring.
        assert_eq!(
            Json::parse("99999999999999999999999").unwrap(),
            Json::Num(1e23)
        );
        // Uint interoperates with the f64 accessors.
        assert_eq!(Json::Uint(3).as_f64(), Some(3.0));
        assert_eq!(Json::Uint(5).as_usize(), Some(5));
    }
}
