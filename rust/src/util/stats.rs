//! Shared numeric helpers.
//!
//! The nearest-rank percentile had drifted into three hand-rolled
//! copies — `metrics::Summary`'s closure, `energy::CarbonSignal::
//! percentile`'s inline indexing, and the autoscaler's wait-p95
//! trigger (via a full `Summary` construction). All three used the
//! same convention by coincidence; this module makes it one function
//! so they agree by construction. A property test
//! (`prop_nearest_rank_matches_legacy_percentile_formulas`) pins the
//! unified helper bit-identical to each retired call-site formula.
//!
//! Convention: **nearest rank, round half up** — the sorted sample at
//! index `floor((n - 1) · q + 0.5)`, clamped to `[0, n - 1]`. For the
//! non-negative indexes that arise here this is exactly `f64::round`
//! (round half away from zero), which is what `Summary` used to apply.
//!
//! [`total_order`] is the same consolidation applied to float
//! comparison: every `sort_by` / `min_by` / `max_by` over f64 routes
//! through this one helper (the `float-cmp-unwrap` lint rule enforces
//! it), so event ordering, score tie-breaks and percentile sorts all
//! agree on a single total order instead of scattering `total_cmp` /
//! `partial_cmp().unwrap()` variants that diverge the day one of them
//! meets a NaN.

/// Nearest-rank index into a sorted sample set of length `n` at
/// quantile `q` (clamped to `[0, 1]`). `n` must be non-zero.
pub fn nearest_rank_index(n: usize, q: f64) -> usize {
    debug_assert!(n > 0, "nearest_rank_index of an empty sample set");
    let x = (n as f64 - 1.0) * q.clamp(0.0, 1.0);
    ((x + 0.5).floor() as usize).min(n - 1)
}

/// The one float comparator for the whole tree: IEEE 754 `totalOrder`
/// (`-NaN < -∞ < … < -0 < +0 < … < +∞ < +NaN`). On non-NaN inputs it
/// agrees bit-for-bit with the `partial_cmp().unwrap()` and bare
/// `total_cmp` call sites it replaced (a property test pins this); on
/// NaN it is still total, so a poisoned sample can never panic a sort
/// or flip comparison transitivity mid-run.
///
/// The reference signature coerces directly into the std adaptors:
/// `v.sort_by(total_order)`, `xs.iter().min_by(|a, b| total_order(a, b))`.
pub fn total_order(a: &f64, b: &f64) -> std::cmp::Ordering {
    a.total_cmp(b)
}

/// Nearest-rank percentile of an unsorted sample set; `None` when the
/// set is empty — callers must decide what an empty window means
/// (the autoscaler's SLO trigger treats it as "no signal", never as
/// "p95 = 0").
pub fn nearest_rank(samples: &[f64], q: f64) -> Option<f64> {
    if samples.is_empty() {
        return None;
    }
    let mut sorted = samples.to_vec();
    sorted.sort_by(total_order);
    Some(sorted[nearest_rank_index(sorted.len(), q)])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn index_convention() {
        // n = 5: q 0 → 0, 0.5 → 2, 0.95 → 4, 1 → 4.
        assert_eq!(nearest_rank_index(5, 0.0), 0);
        assert_eq!(nearest_rank_index(5, 0.5), 2);
        assert_eq!(nearest_rank_index(5, 0.95), 4);
        assert_eq!(nearest_rank_index(5, 1.0), 4);
        // Half-up: (2 - 1) * 0.5 = 0.5 rounds to index 1.
        assert_eq!(nearest_rank_index(2, 0.5), 1);
        // Out-of-range quantiles clamp.
        assert_eq!(nearest_rank_index(3, -1.0), 0);
        assert_eq!(nearest_rank_index(3, 7.0), 2);
        assert_eq!(nearest_rank_index(1, 0.5), 0);
    }

    #[test]
    fn percentile_over_unsorted_samples() {
        let s = [5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(nearest_rank(&s, 0.0), Some(1.0));
        assert_eq!(nearest_rank(&s, 0.5), Some(3.0));
        assert_eq!(nearest_rank(&s, 1.0), Some(5.0));
        assert_eq!(nearest_rank(&[7.5], 0.95), Some(7.5));
    }

    #[test]
    fn empty_is_none_not_zero() {
        assert_eq!(nearest_rank(&[], 0.95), None);
    }

    #[test]
    fn total_order_is_total_and_nan_safe() {
        use std::cmp::Ordering;
        assert_eq!(total_order(&1.0, &2.0), Ordering::Less);
        assert_eq!(total_order(&2.0, &1.0), Ordering::Greater);
        assert_eq!(total_order(&1.5, &1.5), Ordering::Equal);
        // IEEE totalOrder: -0 < +0, NaN sorts to the outside instead
        // of panicking or breaking transitivity.
        assert_eq!(total_order(&-0.0, &0.0), Ordering::Less);
        assert_eq!(total_order(&f64::NAN, &f64::INFINITY), Ordering::Greater);
        assert_eq!(
            total_order(&-f64::NAN, &f64::NEG_INFINITY),
            Ordering::Less
        );
        let mut v = vec![2.0, f64::NAN, -1.0, 0.5];
        v.sort_by(total_order);
        assert_eq!(&v[..3], &[-1.0, 0.5, 2.0]);
        assert!(v[3].is_nan());
    }
}
