//! Shared numeric helpers.
//!
//! The nearest-rank percentile had drifted into three hand-rolled
//! copies — `metrics::Summary`'s closure, `energy::CarbonSignal::
//! percentile`'s inline indexing, and the autoscaler's wait-p95
//! trigger (via a full `Summary` construction). All three used the
//! same convention by coincidence; this module makes it one function
//! so they agree by construction. A property test
//! (`prop_nearest_rank_matches_legacy_percentile_formulas`) pins the
//! unified helper bit-identical to each retired call-site formula.
//!
//! Convention: **nearest rank, round half up** — the sorted sample at
//! index `floor((n - 1) · q + 0.5)`, clamped to `[0, n - 1]`. For the
//! non-negative indexes that arise here this is exactly `f64::round`
//! (round half away from zero), which is what `Summary` used to apply.

/// Nearest-rank index into a sorted sample set of length `n` at
/// quantile `q` (clamped to `[0, 1]`). `n` must be non-zero.
pub fn nearest_rank_index(n: usize, q: f64) -> usize {
    debug_assert!(n > 0, "nearest_rank_index of an empty sample set");
    let x = (n as f64 - 1.0) * q.clamp(0.0, 1.0);
    ((x + 0.5).floor() as usize).min(n - 1)
}

/// Nearest-rank percentile of an unsorted sample set; `None` when the
/// set is empty — callers must decide what an empty window means
/// (the autoscaler's SLO trigger treats it as "no signal", never as
/// "p95 = 0").
pub fn nearest_rank(samples: &[f64], q: f64) -> Option<f64> {
    if samples.is_empty() {
        return None;
    }
    let mut sorted = samples.to_vec();
    sorted.sort_by(f64::total_cmp);
    Some(sorted[nearest_rank_index(sorted.len(), q)])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn index_convention() {
        // n = 5: q 0 → 0, 0.5 → 2, 0.95 → 4, 1 → 4.
        assert_eq!(nearest_rank_index(5, 0.0), 0);
        assert_eq!(nearest_rank_index(5, 0.5), 2);
        assert_eq!(nearest_rank_index(5, 0.95), 4);
        assert_eq!(nearest_rank_index(5, 1.0), 4);
        // Half-up: (2 - 1) * 0.5 = 0.5 rounds to index 1.
        assert_eq!(nearest_rank_index(2, 0.5), 1);
        // Out-of-range quantiles clamp.
        assert_eq!(nearest_rank_index(3, -1.0), 0);
        assert_eq!(nearest_rank_index(3, 7.0), 2);
        assert_eq!(nearest_rank_index(1, 0.5), 0);
    }

    #[test]
    fn percentile_over_unsorted_samples() {
        let s = [5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(nearest_rank(&s, 0.0), Some(1.0));
        assert_eq!(nearest_rank(&s, 0.5), Some(3.0));
        assert_eq!(nearest_rank(&s, 1.0), Some(5.0));
        assert_eq!(nearest_rank(&[7.5], 0.95), Some(7.5));
    }

    #[test]
    fn empty_is_none_not_zero() {
        assert_eq!(nearest_rank(&[], 0.95), None);
    }
}
