//! In-tree infrastructure substrates (DESIGN.md §1b).
//!
//! The build environment is fully offline, so the ecosystem crates a
//! project like this would normally lean on (rand, serde_json, clap,
//! criterion, tokio) are implemented here at the scale this repo needs,
//! each with its own tests.

pub mod bench;
pub mod cli;
pub mod json;
pub mod rng;
pub mod stats;
