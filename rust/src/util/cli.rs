//! Small CLI argument parser: subcommands, `--flag`, `--key value`.
//!
//! Deliberately minimal: positional subcommand chain first, then options.
//! Unknown options are errors (catches typos in experiment scripts).

use std::collections::BTreeMap;

use anyhow::{bail, Result};

/// Parsed arguments: subcommand path + options.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// Leading non-option words, e.g. `["experiment", "table6"]`.
    pub commands: Vec<String>,
    /// `--key value` options.
    opts: BTreeMap<String, String>,
    /// `--flag` booleans.
    flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (without argv[0]).
    /// `flag_names` lists options that take no value.
    pub fn parse<I: IntoIterator<Item = String>>(
        argv: I,
        flag_names: &[&str],
    ) -> Result<Args> {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        let mut seen_opt = false;
        while let Some(arg) = it.next() {
            if let Some(name) = arg.strip_prefix("--") {
                seen_opt = true;
                if name.is_empty() {
                    bail!("bare `--` not supported");
                }
                if let Some((k, v)) = name.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                } else if flag_names.contains(&name) {
                    out.flags.push(name.to_string());
                } else {
                    let v = it.next().ok_or_else(|| {
                        anyhow::anyhow!("option --{name} needs a value")
                    })?;
                    out.opts.insert(name.to_string(), v);
                }
            } else if !seen_opt {
                out.commands.push(arg);
            } else {
                bail!("unexpected positional `{arg}` after options");
            }
        }
        Ok(out)
    }

    /// Parse the process arguments.
    pub fn from_env(flag_names: &[&str]) -> Result<Args> {
        Self::parse(std::env::args().skip(1), flag_names)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    /// Typed option with default.
    pub fn opt_parse<T: std::str::FromStr>(
        &self,
        name: &str,
        default: T,
    ) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v.parse::<T>().map_err(|e| {
                anyhow::anyhow!("bad value for --{name}: {e}")
            }),
        }
    }

    /// Subcommand at position `i`, if present.
    pub fn command(&self, i: usize) -> Option<&str> {
        self.commands.get(i).map(|s| s.as_str())
    }

    /// Error if any option other than those in `known` was given
    /// (flag names are validated at parse time already).
    pub fn reject_unknown_opts(&self, known: &[&str]) -> Result<()> {
        for k in self.opts.keys() {
            if !known.contains(&k.as_str()) {
                bail!("unknown option --{k}");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str], flags: &[&str]) -> Result<Args> {
        Args::parse(args.iter().map(|s| s.to_string()), flags)
    }

    #[test]
    fn subcommands_then_options() {
        let a = parse(
            &["experiment", "table6", "--replications", "3", "--pjrt"],
            &["pjrt"],
        )
        .unwrap();
        assert_eq!(a.command(0), Some("experiment"));
        assert_eq!(a.command(1), Some("table6"));
        assert_eq!(a.opt("replications"), Some("3"));
        assert!(a.flag("pjrt"));
        assert_eq!(a.opt_parse::<u32>("replications", 5).unwrap(), 3);
        assert_eq!(a.opt_parse::<u32>("seed", 7).unwrap(), 7);
    }

    #[test]
    fn equals_form() {
        let a = parse(&["--seed=9"], &[]).unwrap();
        assert_eq!(a.opt("seed"), Some("9"));
    }

    #[test]
    fn missing_value_is_error() {
        assert!(parse(&["--seed"], &[]).is_err());
    }

    #[test]
    fn positional_after_option_is_error() {
        assert!(parse(&["--pjrt", "table6"], &["pjrt"]).is_err());
    }

    #[test]
    fn unknown_opt_rejection() {
        let a = parse(&["--sed", "9"], &[]).unwrap();
        assert!(a.reject_unknown_opts(&["seed"]).is_err());
        let b = parse(&["--seed", "9"], &[]).unwrap();
        assert!(b.reject_unknown_opts(&["seed"]).is_ok());
    }

    #[test]
    fn bad_typed_value() {
        let a = parse(&["--seed", "abc"], &[]).unwrap();
        assert!(a.opt_parse::<u64>("seed", 0).is_err());
    }
}
