//! Bench harness: warmup + timed iterations + summary, criterion-style
//! output. Used by every target under `rust/benches/`.
//!
//! Not statistically fancy (no bootstrap), but reports mean/std/p50/p95
//! over per-iteration timings and guards against dead-code elimination
//! via `std::hint::black_box`.

use std::time::{Duration, Instant};

use crate::metrics::Summary;

/// One benchmark's result.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    /// Per-iteration wall-clock (seconds).
    pub summary: Summary,
    pub iters: u32,
}

impl BenchResult {
    pub fn report(&self) -> String {
        let s = &self.summary;
        format!(
            "{:<44} {:>12} {:>12} {:>12} {:>12}  ({} iters)",
            self.name,
            fmt_time(s.mean),
            fmt_time(s.std),
            fmt_time(s.p50),
            fmt_time(s.p95),
            self.iters,
        )
    }
}

/// Render seconds human-readably (ns/µs/ms/s).
pub fn fmt_time(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1} ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.2} µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.3} ms", secs * 1e3)
    } else {
        format!("{:.3} s", secs)
    }
}

/// A bench suite accumulating results and printing a criterion-like
/// header/footer.
pub struct Bench {
    target_time: Duration,
    min_iters: u32,
    max_iters: u32,
    results: Vec<BenchResult>,
}

impl Default for Bench {
    fn default() -> Self {
        Self::new()
    }
}

impl Bench {
    pub fn new() -> Self {
        println!(
            "{:<44} {:>12} {:>12} {:>12} {:>12}",
            "benchmark", "mean", "std", "p50", "p95"
        );
        Self {
            target_time: Duration::from_secs_f64(
                std::env::var("BENCH_TARGET_SECS")
                    .ok()
                    .and_then(|s| s.parse().ok())
                    .unwrap_or(1.0),
            ),
            min_iters: 10,
            max_iters: 100_000,
            results: Vec::new(),
        }
    }

    /// Time `f`, auto-choosing the iteration count to fill the target
    /// time (after 3 warmup calls). Return values are black-boxed.
    pub fn bench<T>(&mut self, name: &str, mut f: impl FnMut() -> T) {
        // Warmup + per-iteration cost estimate.
        let mut est = f64::INFINITY;
        for _ in 0..3 {
            let t0 = Instant::now();
            std::hint::black_box(f());
            est = est.min(t0.elapsed().as_secs_f64());
        }
        let iters = ((self.target_time.as_secs_f64() / est.max(1e-9)) as u32)
            .clamp(self.min_iters, self.max_iters);

        let mut samples = Vec::with_capacity(iters as usize);
        for _ in 0..iters {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed().as_secs_f64());
        }
        let result = BenchResult {
            name: name.to_string(),
            summary: Summary::of(&samples),
            iters,
        };
        println!("{}", result.report());
        self.results.push(result);
    }

    /// All results so far (e.g. for CSV emission).
    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    pub fn finish(self) {
        println!("-- {} benchmarks done", self.results.len());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_time_scales() {
        assert!(fmt_time(5e-9).contains("ns"));
        assert!(fmt_time(5e-6).contains("µs"));
        assert!(fmt_time(5e-3).contains("ms"));
        assert!(fmt_time(5.0).ends_with("s"));
    }

    #[test]
    fn bench_runs_and_records() {
        std::env::set_var("BENCH_TARGET_SECS", "0.01");
        let mut b = Bench::new();
        let mut x = 0u64;
        b.bench("noop", || {
            x = x.wrapping_add(1);
            x
        });
        assert_eq!(b.results().len(), 1);
        assert!(b.results()[0].summary.mean >= 0.0);
        assert!(b.results()[0].iters >= 10);
    }
}
