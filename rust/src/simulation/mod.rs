//! Deterministic discrete-event simulation of the cluster.
//!
//! Replaces the paper's wall-clock GKE runs (DESIGN.md §1): arrivals,
//! scheduling decisions, execution (base durations from the
//! [`crate::workload::WorkloadExecutor`], contention from
//! [`contention`]), completion, and energy metering, all on a virtual
//! clock with seeded randomness.

mod contention;
pub mod event;
mod engine;
mod results;

pub use contention::contention_factor;
pub use engine::{NodeChange, SimulationEngine, SimulationParams};
pub use event::{
    EventQueue, FedEventQueue, FedScheduledEvent, ScheduledEvent, SimEvent,
    VirtualClock,
};
pub use results::{
    EventRecord, NodeCountSample, PodRecord, RunResult, ScalingRecord,
};
