//! CPU-contention model.
//!
//! Kubernetes CFS shares guarantee a pod its *request*, but co-resident
//! pods still contend for memory bandwidth, LLC, and burst headroom. We
//! model that as a multiplicative slowdown on the pod's base duration:
//!
//! `factor = 1 + β · u_others`
//!
//! where `u_others` is the requested-CPU fraction of the node occupied
//! by *other* pods at the moment this pod starts, and β is
//! `ExperimentConfig::contention_beta` (default 0.35, i.e. a fully
//! contended node runs ~35% slower — in line with public noisy-neighbor
//! measurements on shared-core cloud VMs).
//!
//! The factor is frozen at start time: deterministic, and a reasonable
//! approximation because the paper's workloads are short relative to
//! cluster churn.

/// Contention slowdown for a pod occupying `pod_share` of a node whose
/// post-placement requested-CPU utilization is `util_after`.
pub fn contention_factor(beta: f64, util_after: f64, pod_share: f64) -> f64 {
    let others = (util_after - pod_share).clamp(0.0, 1.0);
    1.0 + beta * others
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alone_on_node_no_slowdown() {
        assert_eq!(contention_factor(0.35, 0.25, 0.25), 1.0);
    }

    #[test]
    fn full_node_max_slowdown() {
        let f = contention_factor(0.35, 1.0, 0.1);
        assert!((f - 1.315).abs() < 1e-12);
    }

    #[test]
    fn monotone_in_coresidents() {
        let a = contention_factor(0.35, 0.4, 0.2);
        let b = contention_factor(0.35, 0.8, 0.2);
        assert!(b > a && a > 1.0);
    }

    #[test]
    fn zero_beta_disables_contention() {
        assert_eq!(contention_factor(0.0, 1.0, 0.1), 1.0);
    }
}
