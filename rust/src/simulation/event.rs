//! The discrete-event kernel: a virtual clock plus a total-ordered
//! event queue (DESIGN.md §"Event kernel").
//!
//! Determinism contract:
//! * events are ordered by `(time, kind-priority, seq)` where `seq` is
//!   the insertion counter — at equal timestamps, state changes land in
//!   a fixed kind order (arrivals, completions, autoscaler decisions,
//!   failures, joins) before the scheduling cycle fires, and events of
//!   the same kind fire in insertion order, so a run is a pure function
//!   of `(pods, params, scheduler seeds)` regardless of *when* an event
//!   was pushed (seeded at init vs. emitted at runtime);
//! * the clock never moves backwards: `VirtualClock::advance_to`
//!   is monotone (and debug-asserts it);
//! * all randomness lives in the workload generator and the schedulers
//!   (seeded xoshiro256**, `util::rng`) — the kernel itself is
//!   deterministic by construction.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::cluster::NodeId;

/// Kernel event types. Pods are addressed by their index into the
/// run's pod vector (dense, stable for the whole run).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SimEvent {
    /// A pod enters the scheduling queue.
    PodArrival { pod: usize },
    /// Drain the pending-pod queue (FIFO) through the schedulers.
    /// Requested by arrivals, completions, and node joins; at most one
    /// is outstanding per timestamp.
    SchedulingCycle,
    /// A running pod finished; its reservation is released.
    PodCompleted { pod: usize },
    /// Node (re)joins: becomes Ready and schedulable.
    NodeJoined { node: NodeId },
    /// Node fails: NotReady. Running pods keep their reservation
    /// (kube semantics: NotReady gates *new* bindings).
    NodeFailed { node: NodeId },
    /// Autoscaler wake-up: re-evaluate the scaling policy even though
    /// no workload event fired (idle-timeout scale-in, cooldown expiry,
    /// scheduled churn replay).
    AutoscaleTick,
}

impl SimEvent {
    /// Stable label for event logs and reports.
    pub fn kind(&self) -> &'static str {
        match self {
            SimEvent::PodArrival { .. } => "pod-arrival",
            SimEvent::SchedulingCycle => "scheduling-cycle",
            SimEvent::PodCompleted { .. } => "pod-completed",
            SimEvent::NodeJoined { .. } => "node-joined",
            SimEvent::NodeFailed { .. } => "node-failed",
            SimEvent::AutoscaleTick => "autoscale-tick",
        }
    }

    /// Same-timestamp tie-break rank (lower fires first). The
    /// documented total order at one instant: pod arrivals land first,
    /// then completions, then autoscaler decisions, then node failures,
    /// then node joins, and the scheduling cycle runs only after every
    /// same-time state change. In particular a `PodArrival` is never
    /// outrun by a same-timestamp `NodeFailed` — scale-in cannot
    /// silently race an arrival (regression-tested below) — no pod is
    /// ever bound to a node whose failure is due at the same instant,
    /// and a same-instant down+up blip on one node nets *Ready*
    /// (failures before joins: recovery wins, as a down-then-up churn
    /// schedule read in order would).
    pub fn priority(&self) -> u8 {
        match self {
            SimEvent::PodArrival { .. } => 0,
            SimEvent::PodCompleted { .. } => 1,
            SimEvent::AutoscaleTick => 2,
            SimEvent::NodeFailed { .. } => 3,
            SimEvent::NodeJoined { .. } => 4,
            SimEvent::SchedulingCycle => 5,
        }
    }
}

/// A queued event: fire time + total-order tie-break.
#[derive(Debug, Clone, PartialEq)]
pub struct ScheduledEvent {
    pub at: f64,
    pub seq: u64,
    pub event: SimEvent,
}

impl Eq for ScheduledEvent {}

impl Ord for ScheduledEvent {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        crate::util::stats::total_order(&self.at, &other.at)
            .then_with(|| self.event.priority().cmp(&other.event.priority()))
            .then_with(|| self.seq.cmp(&other.seq))
    }
}

impl PartialOrd for ScheduledEvent {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

/// Monotone virtual clock (simulated seconds).
#[derive(Debug, Clone, Copy, Default)]
pub struct VirtualClock {
    now: f64,
}

impl VirtualClock {
    pub fn now(&self) -> f64 {
        self.now
    }

    /// Advance to `t` (returns the new now). Time never moves
    /// backwards; the min-heap pop order guarantees `t >= now` up to
    /// total_cmp ties, which this asserts in debug builds.
    pub fn advance_to(&mut self, t: f64) -> f64 {
        debug_assert!(
            t >= self.now,
            "clock moved backwards: {} -> {t}",
            self.now
        );
        if t > self.now {
            self.now = t;
        }
        self.now
    }
}

/// Deterministic min-queue of [`ScheduledEvent`]s.
#[derive(Debug, Default)]
pub struct EventQueue {
    heap: BinaryHeap<Reverse<ScheduledEvent>>,
    seq: u64,
}

impl EventQueue {
    pub fn new() -> Self {
        Self::default()
    }

    /// Enqueue `event` at time `at`; kind priority then insertion order
    /// break ties.
    pub fn push(&mut self, at: f64, event: SimEvent) {
        self.heap.push(Reverse(ScheduledEvent { at, seq: self.seq, event }));
        self.seq += 1;
    }

    /// Pop the earliest event (lowest `(at, priority, seq)`).
    pub fn pop(&mut self) -> Option<ScheduledEvent> {
        self.heap.pop().map(|Reverse(e)| e)
    }

    /// Peek at the earliest event without removing it.
    pub fn peek(&self) -> Option<&ScheduledEvent> {
        self.heap.peek().map(|Reverse(e)| e)
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

/// A region-tagged scheduled event — the merged total order of the
/// multi-cluster federation (`crate::federation`). Ordering is exactly
/// [`ScheduledEvent`]'s `(time, kind-priority, seq)`; the region tag
/// only routes the popped event to its cluster's state and never
/// participates in the comparison, so a 1-region federation pops in
/// bit-identical order to a plain [`EventQueue`] fed the same pushes
/// (the differential property in `rust/tests/properties.rs` pins the
/// whole-engine consequence of this).
#[derive(Debug, Clone, PartialEq)]
pub struct FedScheduledEvent {
    pub at: f64,
    pub seq: u64,
    /// Index of the owning cluster (meaningless for `PodArrival`,
    /// whose region the dispatcher resolves at pop time).
    pub region: usize,
    pub event: SimEvent,
}

impl FedScheduledEvent {
    /// The untagged kernel event — ordering delegates to this, so the
    /// two queues share one comparator by construction.
    fn untagged(&self) -> ScheduledEvent {
        ScheduledEvent { at: self.at, seq: self.seq, event: self.event }
    }
}

impl Eq for FedScheduledEvent {}

impl Ord for FedScheduledEvent {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.untagged().cmp(&other.untagged())
    }
}

impl PartialOrd for FedScheduledEvent {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

/// Deterministic min-queue of [`FedScheduledEvent`]s: one shared
/// virtual-time order interleaving every cluster's kernel events.
#[derive(Debug, Default)]
pub struct FedEventQueue {
    heap: BinaryHeap<Reverse<FedScheduledEvent>>,
    seq: u64,
}

impl FedEventQueue {
    pub fn new() -> Self {
        Self::default()
    }

    /// Enqueue `event` for `region` at time `at`; kind priority then
    /// insertion order break ties, across all regions.
    pub fn push(&mut self, at: f64, region: usize, event: SimEvent) {
        self.heap.push(Reverse(FedScheduledEvent {
            at,
            seq: self.seq,
            region,
            event,
        }));
        self.seq += 1;
    }

    /// Pop the earliest event (lowest `(at, priority, seq)`).
    pub fn pop(&mut self) -> Option<FedScheduledEvent> {
        self.heap.pop().map(|Reverse(e)| e)
    }

    /// Peek at the earliest event without removing it — the streaming
    /// arrival pump compares the next source arrival against this to
    /// decide whether it is due for admission.
    pub fn peek(&self) -> Option<&FedScheduledEvent> {
        self.heap.peek().map(|Reverse(e)| e)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_then_insertion_order() {
        let mut q = EventQueue::new();
        q.push(2.0, SimEvent::SchedulingCycle);
        q.push(1.0, SimEvent::PodArrival { pod: 0 });
        q.push(1.0, SimEvent::PodArrival { pod: 1 });
        q.push(0.5, SimEvent::NodeFailed { node: 3 });
        let order: Vec<(f64, SimEvent)> =
            std::iter::from_fn(|| q.pop().map(|e| (e.at, e.event))).collect();
        assert_eq!(
            order,
            vec![
                (0.5, SimEvent::NodeFailed { node: 3 }),
                (1.0, SimEvent::PodArrival { pod: 0 }),
                (1.0, SimEvent::PodArrival { pod: 1 }),
                (2.0, SimEvent::SchedulingCycle),
            ]
        );
        assert!(q.is_empty());
    }

    #[test]
    fn seq_breaks_exact_ties_fifo() {
        let mut q = EventQueue::new();
        for pod in 0..100 {
            q.push(7.25, SimEvent::PodArrival { pod });
        }
        for pod in 0..100 {
            let e = q.pop().unwrap();
            assert_eq!(e.event, SimEvent::PodArrival { pod });
        }
    }

    #[test]
    fn clock_is_monotone() {
        let mut c = VirtualClock::default();
        assert_eq!(c.now(), 0.0);
        assert_eq!(c.advance_to(1.5), 1.5);
        assert_eq!(c.advance_to(1.5), 1.5); // same-time events are fine
        assert_eq!(c.advance_to(3.0), 3.0);
        assert_eq!(c.now(), 3.0);
    }

    #[test]
    fn event_kinds_are_stable_labels() {
        assert_eq!(SimEvent::PodArrival { pod: 0 }.kind(), "pod-arrival");
        assert_eq!(SimEvent::SchedulingCycle.kind(), "scheduling-cycle");
        assert_eq!(SimEvent::PodCompleted { pod: 0 }.kind(), "pod-completed");
        assert_eq!(SimEvent::NodeJoined { node: 0 }.kind(), "node-joined");
        assert_eq!(SimEvent::NodeFailed { node: 0 }.kind(), "node-failed");
        assert_eq!(SimEvent::AutoscaleTick.kind(), "autoscale-tick");
    }

    #[test]
    fn same_timestamp_arrival_beats_node_failure() {
        // The documented scale-in/arrival race fix: a NodeFailed pushed
        // *before* a PodArrival at the same virtual time still fires
        // after it — kind priority overrides insertion order.
        let mut q = EventQueue::new();
        q.push(3.0, SimEvent::NodeFailed { node: 1 });
        q.push(3.0, SimEvent::PodArrival { pod: 0 });
        assert_eq!(q.pop().unwrap().event, SimEvent::PodArrival { pod: 0 });
        assert_eq!(
            q.pop().unwrap().event,
            SimEvent::NodeFailed { node: 1 }
        );
    }

    #[test]
    fn same_timestamp_total_order_is_documented_kind_order() {
        // Push one event of every kind at one timestamp, in reverse of
        // the documented order; the queue must restore it: arrival,
        // completion, autoscale tick, failure, join, cycle.
        let mut q = EventQueue::new();
        q.push(1.0, SimEvent::SchedulingCycle);
        q.push(1.0, SimEvent::NodeJoined { node: 1 });
        q.push(1.0, SimEvent::NodeFailed { node: 0 });
        q.push(1.0, SimEvent::AutoscaleTick);
        q.push(1.0, SimEvent::PodCompleted { pod: 2 });
        q.push(1.0, SimEvent::PodArrival { pod: 3 });
        let kinds: Vec<&'static str> =
            std::iter::from_fn(|| q.pop().map(|e| e.event.kind())).collect();
        assert_eq!(
            kinds,
            vec![
                "pod-arrival",
                "pod-completed",
                "autoscale-tick",
                "node-failed",
                "node-joined",
                "scheduling-cycle",
            ]
        );
    }

    #[test]
    fn same_instant_down_up_blip_nets_ready() {
        // A down+up blip at one timestamp resolves failure-then-join
        // regardless of push order, so the node ends the instant Ready
        // — recovery wins, matching a down-then-up schedule read in
        // order.
        let mut q = EventQueue::new();
        q.push(9.0, SimEvent::NodeJoined { node: 2 });
        q.push(9.0, SimEvent::NodeFailed { node: 2 });
        assert_eq!(q.pop().unwrap().event, SimEvent::NodeFailed { node: 2 });
        assert_eq!(q.pop().unwrap().event, SimEvent::NodeJoined { node: 2 });
    }

    #[test]
    fn fed_queue_orders_across_regions_like_one_kernel() {
        // Region tags never perturb the (time, priority, seq) order:
        // a same-instant completion in region 1 still precedes a
        // scheduling cycle in region 0, and equal-kind ties stay FIFO
        // across regions.
        let mut q = FedEventQueue::new();
        q.push(1.0, 0, SimEvent::SchedulingCycle);
        q.push(1.0, 1, SimEvent::PodCompleted { pod: 9 });
        q.push(1.0, 2, SimEvent::PodArrival { pod: 0 });
        q.push(1.0, 0, SimEvent::PodArrival { pod: 1 });
        let order: Vec<(usize, &'static str)> =
            std::iter::from_fn(|| q.pop().map(|e| (e.region, e.event.kind())))
                .collect();
        assert_eq!(
            order,
            vec![
                (2, "pod-arrival"),
                (0, "pod-arrival"),
                (1, "pod-completed"),
                (0, "scheduling-cycle"),
            ]
        );
    }

    #[test]
    fn fed_queue_single_region_matches_plain_queue_order() {
        // The degenerate federation: identical pushes into both queues
        // must pop in identical order — the kernel-level half of the
        // 1-region bit-identity differential.
        let pushes = [
            (2.0, SimEvent::PodArrival { pod: 0 }),
            (1.0, SimEvent::SchedulingCycle),
            (1.0, SimEvent::PodCompleted { pod: 3 }),
            (2.0, SimEvent::NodeFailed { node: 1 }),
            (1.0, SimEvent::AutoscaleTick),
        ];
        let mut plain = EventQueue::new();
        let mut fed = FedEventQueue::new();
        for &(at, ev) in &pushes {
            plain.push(at, ev);
            fed.push(at, 0, ev);
        }
        loop {
            match (plain.pop(), fed.pop()) {
                (None, None) => break,
                (Some(p), Some(f)) => {
                    assert_eq!(p.at, f.at);
                    assert_eq!(p.seq, f.seq);
                    assert_eq!(p.event, f.event);
                }
                other => panic!("queue lengths diverged: {other:?}"),
            }
        }
    }

    #[test]
    fn priority_only_breaks_exact_time_ties() {
        // A strictly earlier low-priority event still precedes a later
        // high-priority one: priority is a tie-break, not a reordering.
        let mut q = EventQueue::new();
        q.push(2.0, SimEvent::PodArrival { pod: 0 });
        q.push(1.0, SimEvent::SchedulingCycle);
        assert_eq!(q.pop().unwrap().event, SimEvent::SchedulingCycle);
        assert_eq!(q.pop().unwrap().event, SimEvent::PodArrival { pod: 0 });
    }
}
