//! The discrete-event simulation engine.
//!
//! Built on the kernel in [`super::event`]: a virtual clock and a
//! total-ordered event queue over `PodArrival`, `SchedulingCycle`,
//! `PodCompleted`, `NodeJoined` and `NodeFailed` events. Arriving pods
//! enter a FIFO pending queue; a `SchedulingCycle` (requested by
//! arrivals, completions and node joins, at most one outstanding per
//! timestamp) drains that queue through the owning schedulers — the
//! same retry semantics as kube-scheduler's backoff queue, collapsed to
//! event-driven time. Energy is integrated interval-by-interval as the
//! clock advances (see [`EnergyMeter::advance`]), and per-pod queue
//! wait, scheduling latency and attempt counts are recorded into
//! [`RunResult`].
//!
//! [`SimulationEngine::run_batch`] is an independent re-implementation
//! of the same scheduling semantics without the event queue (whole
//! deployment submitted at t = 0, one synchronous FIFO pass,
//! completion-driven retries with the kernel's same-timestamp
//! coalescing) — a differential-testing oracle: with all arrivals at
//! t = 0 the two modes must produce identical placements
//! (property-tested in `rust/tests/properties.rs`).
//!
//! Event mode can additionally run a cluster autoscaler
//! (`SimulationParams::autoscaler`, DESIGN.md §"Autoscaler"): the
//! policy is consulted after every event except arrivals and grows or
//! shrinks the cluster by emitting `NodeJoined` / `NodeFailed` through
//! the same kernel as churn injection. The energy meter attributes the
//! idle floor of every Ready node (`EnergyMeter::node_online`), so
//! scale-in shows up as measured savings. Batch mode ignores both
//! `node_events` and the autoscaler — it is the fixed-cluster legacy
//! oracle.

use std::collections::{HashMap, VecDeque};

use crate::autoscaler::{Autoscaler, AutoscalerPolicy, Observation, ScalingAction};
use crate::cluster::{ClusterState, NodeId, Pod, PodPhase};
use crate::config::{Config, SchedulerKind};
use crate::energy::{CarbonSignal, EnergyMeter};
use crate::scheduler::Scheduler;
use crate::simulation::event::{EventQueue, SimEvent, VirtualClock};
use crate::simulation::{
    contention_factor, EventRecord, NodeCountSample, PodRecord, RunResult,
    ScalingRecord,
};
use crate::workload::WorkloadExecutor;

/// A scheduled node-membership change (cluster churn injection).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NodeChange {
    pub at_s: f64,
    pub node: NodeId,
    /// `true` = NodeJoined (Ready), `false` = NodeFailed (NotReady).
    pub up: bool,
}

/// Engine-level knobs (beyond what `Config` carries).
#[derive(Debug, Clone)]
pub struct SimulationParams {
    pub contention_beta: f64,
    /// Seed for per-pod dataset generation in real-execution mode.
    pub seed: u64,
    /// Node churn schedule (empty = the fixed paper cluster).
    pub node_events: Vec<NodeChange>,
    /// Cluster-autoscaling policy (`None` = the fixed cluster; the run
    /// is then bit-identical to the pre-autoscaler engine, which the
    /// property suite pins).
    pub autoscaler: Option<AutoscalerPolicy>,
    /// Billing horizon for idle energy (s). By default the meter stops
    /// at the last event, which undercounts a static cluster relative
    /// to an autoscaled one whose trailing scale-ins extend the event
    /// stream; setting a common horizon bills every configuration's
    /// powered-on nodes over the same `[0, horizon]` window, making
    /// totals comparable at equal admitted work (the elasticity
    /// experiments set this; plain experiment cells do not).
    pub billing_horizon_s: Option<f64>,
    /// Grid carbon-intensity signal the meter's CO₂ ledger integrates
    /// against and the scheduling clock exposes (`None` = the config's
    /// eGRID scalar as a constant — bit-identical to the pre-signal
    /// engine, which the differential property pins).
    pub carbon: Option<CarbonSignal>,
    /// Differential-testing knob: run every scheduling cycle even when
    /// no node changed and no pod arrived since the previous cycle,
    /// instead of short-circuiting the provably-futile retry pass.
    /// The skip is placement-neutral by construction (an unchanged
    /// cluster re-fails every pending pod identically); the regression
    /// test pins forced ≡ guarded bitwise.
    pub force_full_cycles: bool,
}

impl Default for SimulationParams {
    fn default() -> Self {
        Self {
            contention_beta: 0.35,
            seed: 0,
            node_events: Vec::new(),
            autoscaler: None,
            billing_horizon_s: None,
            carbon: None,
            force_full_cycles: false,
        }
    }
}

impl SimulationParams {
    /// Explicit contention/seed, no node churn — the common case for
    /// experiments, benches and examples.
    pub fn with_beta_and_seed(contention_beta: f64, seed: u64) -> Self {
        Self { contention_beta, seed, ..Self::default() }
    }

    /// Attach an autoscaling policy.
    pub fn with_autoscaler(mut self, policy: AutoscalerPolicy) -> Self {
        self.autoscaler = Some(policy);
        self
    }

    /// Attach a time-varying carbon-intensity signal.
    pub fn with_carbon(mut self, carbon: CarbonSignal) -> Self {
        self.carbon = Some(carbon);
        self
    }
}

/// Bookkeeping for a bound, executing pod (indexed by pod *index*).
struct RunningPod {
    node: NodeId,
    start_s: f64,
}

/// Mutable per-run state threaded through the event handlers.
struct RunState {
    state: ClusterState,
    meter: EnergyMeter,
    records: Vec<PodRecord>,
    queue: EventQueue,
    pending: VecDeque<usize>,
    running: HashMap<usize, RunningPod>,
    sched_latency_us: Vec<f64>,
    attempts: Vec<u32>,
    events: Vec<EventRecord>,
    scaling: Vec<ScalingRecord>,
    node_timeline: Vec<NodeCountSample>,
    /// Fire time of the earliest pending `AutoscaleTick`, for dedupe.
    next_tick: Option<f64>,
    makespan: f64,
    cycle_queued: bool,
    /// Arena for the autoscaler's pending-wait vector (rebuilt each
    /// consultation into the same allocation).
    waits_buf: Vec<f64>,
    /// `state.mutations()` as of the end of the previous scheduling
    /// cycle (`u64::MAX` = no cycle yet, never matches).
    last_cycle_mutations: u64,
    /// Whether any pod arrived since the previous scheduling cycle.
    arrivals_since_cycle: bool,
}

impl RunState {
    fn new(config: &Config, params: &SimulationParams, n_pods: usize) -> Self {
        // The meter's CO₂ ledger integrates against the run's signal;
        // absent an explicit one, the config's (constant by default —
        // exactly the scalar grams_co2_per_joule path).
        let carbon = params
            .carbon
            .clone()
            .unwrap_or_else(|| config.carbon.signal(&config.energy));
        Self {
            state: ClusterState::from_config(&config.cluster),
            meter: EnergyMeter::new().with_carbon(carbon),
            records: Vec::with_capacity(n_pods),
            queue: EventQueue::new(),
            pending: VecDeque::new(),
            running: HashMap::new(),
            sched_latency_us: vec![0.0; n_pods],
            attempts: vec![0; n_pods],
            events: Vec::new(),
            scaling: Vec::new(),
            node_timeline: Vec::new(),
            next_tick: None,
            makespan: 0.0,
            cycle_queued: false,
            waits_buf: Vec::new(),
            last_cycle_mutations: u64::MAX,
            arrivals_since_cycle: false,
        }
    }

    /// Request a scheduling cycle at `now` unless one is already
    /// outstanding (any outstanding cycle is at the current timestamp
    /// and fires before any strictly later event, so the flag is safe).
    fn request_cycle(&mut self, now: f64) {
        if !self.cycle_queued {
            self.queue.push(now, SimEvent::SchedulingCycle);
            self.cycle_queued = true;
        }
    }

    /// Append a node-count sample (after a membership change).
    fn sample_nodes(&mut self, at_s: f64) {
        self.node_timeline.push(NodeCountSample {
            at_s,
            ready_nodes: self.state.ready_nodes(),
            total_nodes: self.state.nodes().len(),
        });
    }

    fn into_result(
        mut self,
        pods: &mut [Pod],
        pjrt_fallbacks: u64,
    ) -> RunResult {
        let unschedulable = self
            .pending
            .iter()
            .map(|&i| {
                pods[i].phase = PodPhase::Unschedulable;
                pods[i].id
            })
            .collect();
        RunResult {
            records: std::mem::take(&mut self.records),
            meter: self.meter,
            unschedulable,
            makespan_s: self.makespan,
            pjrt_fallbacks,
            events: self.events,
            scaling: self.scaling,
            node_timeline: self.node_timeline,
        }
    }
}

/// The simulation engine. Owns the cluster state and the energy meter
/// for the duration of one run.
pub struct SimulationEngine<'a> {
    config: &'a Config,
    params: SimulationParams,
    executor: &'a WorkloadExecutor,
}

impl<'a> SimulationEngine<'a> {
    pub fn new(
        config: &'a Config,
        params: SimulationParams,
        executor: &'a WorkloadExecutor,
    ) -> Self {
        Self { config, params, executor }
    }

    /// Event mode: pods arrive per their `arrival_s`; pods tagged
    /// `Topsis` are placed by `topsis`, the rest by `default`.
    pub fn run(
        &self,
        mut pods: Vec<Pod>,
        topsis: &mut dyn Scheduler,
        default: &mut dyn Scheduler,
    ) -> RunResult {
        let mut rs = RunState::new(self.config, &self.params, pods.len());
        let mut clock = VirtualClock::default();

        // Idle-floor metering starts with the configured cluster: every
        // Ready node draws its idle power from t = 0 until it fails or
        // is scaled in (`EnergyMeter::node_online`).
        for id in 0..rs.state.nodes().len() {
            if rs.state.node(id).ready {
                let node = rs.state.node(id).clone();
                rs.meter.node_online(&self.config.energy, &node, 0.0);
            }
        }
        rs.sample_nodes(0.0);

        // Seed the queue: arrivals first (insertion order = pod order),
        // then the churn schedule. The kernel's `(time, kind-priority,
        // seq)` order guarantees same-timestamp arrivals precede
        // membership changes however the events were pushed.
        for (i, p) in pods.iter().enumerate() {
            rs.queue.push(p.arrival_s, SimEvent::PodArrival { pod: i });
        }
        for ch in &self.params.node_events {
            let ev = if ch.up {
                SimEvent::NodeJoined { node: ch.node }
            } else {
                SimEvent::NodeFailed { node: ch.node }
            };
            rs.queue.push(ch.at_s, ev);
        }

        // The autoscaler decides once at t = 0 (so schedules and
        // wake-ups that start immediately are honored) and then after
        // every event that leaves no same-instant scheduling cycle
        // outstanding — if a cycle is queued at this timestamp, the
        // pending queue is about to be retried and the cycle's own
        // consultation follows, so the policy only ever reacts to
        // backlog the scheduler actually failed to place. The policy's
        // own wake-up ticks are always honored (the scheduled-churn
        // replay depends on firing exactly on time, before the cycle).
        let mut autoscaler = self
            .params
            .autoscaler
            .as_ref()
            .map(|p| p.build(rs.state.nodes().len()));
        if let Some(policy) = autoscaler.as_deref_mut() {
            self.autoscale(&mut rs, 0.0, &pods, policy);
        }

        while let Some(ev) = rs.queue.pop() {
            let now = clock.advance_to(ev.at);
            rs.meter.advance(now);
            rs.events.push(EventRecord { at_s: now, kind: ev.event.kind() });
            let is_tick = matches!(ev.event, SimEvent::AutoscaleTick);
            match ev.event {
                SimEvent::PodArrival { pod } => {
                    rs.pending.push_back(pod);
                    rs.arrivals_since_cycle = true;
                    rs.request_cycle(now);
                }
                SimEvent::SchedulingCycle => {
                    rs.cycle_queued = false;
                    // Short-circuit a provably-futile retry pass: if no
                    // node changed and nothing arrived since the last
                    // cycle, every pending pod re-fails identically.
                    // (Today every cycle request follows a mutation or
                    // an arrival, so this guard is structural — it
                    // keeps future cycle sources, e.g. periodic
                    // re-syncs, from going quadratic in the backlog.)
                    let unchanged = !rs.arrivals_since_cycle
                        && rs.last_cycle_mutations == rs.state.mutations();
                    if !unchanged || self.params.force_full_cycles {
                        self.drain_pending(
                            &mut rs, now, &mut pods, topsis, default,
                        );
                    }
                    // Record *after* draining: the cycle's own binds
                    // must not look like fresh mutations next time.
                    rs.last_cycle_mutations = rs.state.mutations();
                    rs.arrivals_since_cycle = false;
                }
                SimEvent::PodCompleted { pod } => {
                    self.complete(&mut rs, now, &mut pods, pod);
                    if !rs.pending.is_empty() {
                        rs.request_cycle(now);
                    }
                }
                SimEvent::NodeJoined { node } => {
                    rs.state.set_ready(node, true, now);
                    let joined = rs.state.node(node).clone();
                    rs.meter.node_online(&self.config.energy, &joined, now);
                    rs.sample_nodes(now);
                    if !rs.pending.is_empty() {
                        rs.request_cycle(now);
                    }
                }
                SimEvent::NodeFailed { node } => {
                    rs.state.set_ready(node, false, now);
                    rs.meter.node_offline(node, now);
                    rs.sample_nodes(now);
                }
                SimEvent::AutoscaleTick => {
                    rs.next_tick = None;
                }
            }
            if is_tick || !rs.cycle_queued {
                if let Some(policy) = autoscaler.as_deref_mut() {
                    self.autoscale(&mut rs, now, &pods, policy);
                }
            }
        }

        // Bill still-powered nodes' idle out to the common horizon
        // (no-op when the horizon already passed or none is set).
        if let Some(horizon) = self.params.billing_horizon_s {
            rs.meter.advance(horizon);
        }

        rs.into_result(&mut pods, 0)
    }

    /// One autoscaler consultation: observe, apply the decision's
    /// actions in order, and (de-duplicated) schedule its wake-up.
    fn autoscale(
        &self,
        rs: &mut RunState,
        now: f64,
        pods: &[Pod],
        policy: &mut dyn Autoscaler,
    ) {
        let mut waits = std::mem::take(&mut rs.waits_buf);
        waits.clear();
        waits.extend(rs.pending.iter().map(|&i| now - pods[i].arrival_s));
        let decision = policy.decide(&Observation {
            now_s: now,
            state: &rs.state,
            pending_wait_s: &waits,
        });
        rs.waits_buf = waits;
        for action in decision.actions {
            match action {
                ScalingAction::Provision { template, ready_at_s } => {
                    let node = rs.state.add_node(&template, now);
                    let at = ready_at_s.max(now);
                    rs.queue.push(at, SimEvent::NodeJoined { node });
                    // Sample so the timeline shows the booting node
                    // (total > ready until its NodeJoined fires).
                    rs.sample_nodes(now);
                    rs.scaling.push(ScalingRecord {
                        at_s: now,
                        kind: "scale-out",
                        node,
                        effective_at_s: at,
                    });
                }
                ScalingAction::Activate { node, at_s } => {
                    let at = at_s.max(now);
                    rs.queue.push(at, SimEvent::NodeJoined { node });
                    rs.scaling.push(ScalingRecord {
                        at_s: now,
                        kind: "activate",
                        node,
                        effective_at_s: at,
                    });
                }
                ScalingAction::Deactivate { node, at_s } => {
                    let at = at_s.max(now);
                    rs.queue.push(at, SimEvent::NodeFailed { node });
                    rs.scaling.push(ScalingRecord {
                        at_s: now,
                        kind: "scale-in",
                        node,
                        effective_at_s: at,
                    });
                }
            }
        }
        if let Some(wake) = decision.wake_at_s {
            if wake > now && rs.next_tick.map_or(true, |t| wake < t) {
                rs.queue.push(wake, SimEvent::AutoscaleTick);
                rs.next_tick = Some(wake);
            }
        }
    }

    /// Batch mode (differential oracle, and the paper's burst
    /// deployment without arrival dynamics): every pod is submitted at
    /// t = 0 regardless of `arrival_s`, placed in one synchronous FIFO
    /// pass; completions then release capacity chronologically —
    /// coalescing equal timestamps exactly like the event kernel's
    /// single outstanding cycle — each group retrying the pending
    /// queue once.
    pub fn run_batch(
        &self,
        mut pods: Vec<Pod>,
        topsis: &mut dyn Scheduler,
        default: &mut dyn Scheduler,
    ) -> RunResult {
        for p in &mut pods {
            p.arrival_s = 0.0;
        }
        let mut rs = RunState::new(self.config, &self.params, pods.len());

        // Synchronous placement pass at t = 0.
        rs.events.push(EventRecord { at_s: 0.0, kind: "batch-submit" });
        for i in 0..pods.len() {
            if !self.try_place(&mut rs, i, 0.0, &mut pods, topsis, default) {
                rs.pending.push_back(i);
            }
        }

        // Completion-driven retries (the queue holds only completions).
        // Same-time completions are coalesced before the retry pass —
        // mirroring the event kernel, where one SchedulingCycle fires
        // after every completion at a given timestamp.
        while let Some(first) = rs.queue.pop() {
            let now = first.at;
            rs.meter.advance(now);
            let mut group = vec![first];
            while rs.queue.peek().is_some_and(|e| e.at == now) {
                group.push(rs.queue.pop().expect("peeked"));
            }
            for ev in group {
                rs.events
                    .push(EventRecord { at_s: now, kind: ev.event.kind() });
                let SimEvent::PodCompleted { pod } = ev.event else {
                    unreachable!("batch mode queues only completions");
                };
                self.complete(&mut rs, now, &mut pods, pod);
            }
            self.drain_pending(&mut rs, now, &mut pods, topsis, default);
        }

        rs.into_result(&mut pods, 0)
    }

    /// One scheduling cycle: try every pending pod once, FIFO. A later
    /// small pod may fit where an earlier big one does not, so the
    /// whole queue is scanned; failures keep their queue order.
    fn drain_pending(
        &self,
        rs: &mut RunState,
        now: f64,
        pods: &mut [Pod],
        topsis: &mut dyn Scheduler,
        default: &mut dyn Scheduler,
    ) {
        let n = rs.pending.len();
        for _ in 0..n {
            let i = rs.pending.pop_front().expect("pending non-empty");
            if !self.try_place(rs, i, now, pods, topsis, default) {
                rs.pending.push_back(i);
            }
        }
    }

    /// Attempt to place and start pod `i` at time `now`. Returns false
    /// if it remains pending.
    fn try_place(
        &self,
        rs: &mut RunState,
        i: usize,
        now: f64,
        pods: &mut [Pod],
        topsis: &mut dyn Scheduler,
        default: &mut dyn Scheduler,
    ) -> bool {
        // Time-aware dispatch: the cycle's virtual timestamp reaches
        // clock-consuming profiles (carbon-aware intensity lookups);
        // the default trait impl keeps everything else bit-identical.
        let decision = match pods[i].scheduler {
            SchedulerKind::Topsis => {
                topsis.schedule_at(&rs.state, &pods[i], now)
            }
            SchedulerKind::DefaultK8s => {
                default.schedule_at(&rs.state, &pods[i], now)
            }
        };
        rs.sched_latency_us[i] += decision.latency.as_secs_f64() * 1e6;
        rs.attempts[i] += 1;
        let Some(node_id) = decision.node else {
            return false;
        };

        rs.state.bind(&pods[i], node_id, now).expect("scheduler chose fit");
        pods[i].phase = PodPhase::Running;

        let node = rs.state.node(node_id).clone();
        let outcome = self
            .executor
            .execute(&pods[i], &node, self.params.seed ^ pods[i].id)
            .expect("workload execution");
        let share =
            pods[i].requests.cpu_millis as f64 / node.cpu_millis as f64;
        let factor = contention_factor(
            self.params.contention_beta,
            rs.state.cpu_utilization(node_id),
            share,
        );
        let duration = outcome.base_secs * factor;

        rs.meter.start(
            &self.config.energy,
            pods[i].id,
            pods[i].class,
            pods[i].scheduler,
            &node,
            share,
            now,
        );
        rs.running.insert(i, RunningPod { node: node_id, start_s: now });
        rs.queue.push(now + duration, SimEvent::PodCompleted { pod: i });
        true
    }

    /// Handle a completion: release the reservation, close the energy
    /// interval, and emit the pod's lifecycle record.
    fn complete(
        &self,
        rs: &mut RunState,
        now: f64,
        pods: &mut [Pod],
        i: usize,
    ) {
        rs.makespan = rs.makespan.max(now);
        rs.state
            .release(pods[i].id, now)
            .expect("completion of bound pod");
        pods[i].phase = PodPhase::Succeeded;
        let run = rs.running.remove(&i).expect("completion of running pod");
        let joules = rs.meter.finish(pods[i].id, now);
        rs.records.push(PodRecord {
            pod: pods[i].id,
            class: pods[i].class,
            scheduler: pods[i].scheduler,
            node: run.node,
            node_category: rs.state.node(run.node).category,
            arrival_s: pods[i].arrival_s,
            start_s: run.start_s,
            finish_s: now,
            sched_latency_us: rs.sched_latency_us[i],
            attempts: rs.attempts[i],
            joules,
            wait_s: run.start_s - pods[i].arrival_s,
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{CompetitionLevel, WeightingScheme};
    use crate::scheduler::{
        DefaultK8sScheduler, Estimator, GreenPodScheduler,
    };
    use crate::workload::generate_pods;

    fn run_level(level: CompetitionLevel, seed: u64) -> RunResult {
        let config = Config::paper_default();
        let executor = WorkloadExecutor::analytic();
        let engine = SimulationEngine::new(
            &config,
            SimulationParams::with_beta_and_seed(0.35, seed),
            &executor,
        );
        let pods = generate_pods(level, &config.experiment, seed).pods;
        let mut topsis = GreenPodScheduler::new(
            Estimator::with_defaults(config.energy.clone()),
            WeightingScheme::EnergyCentric,
        );
        let mut default = DefaultK8sScheduler::new(seed);
        engine.run(pods, &mut topsis, &mut default)
    }

    #[test]
    fn all_pods_complete_low_competition() {
        let r = run_level(CompetitionLevel::Low, 1);
        assert_eq!(r.records.len(), 8);
        assert!(r.unschedulable.is_empty());
        assert!(r.makespan_s > 0.0);
        for rec in &r.records {
            assert!(rec.finish_s > rec.start_s);
            assert!(rec.start_s >= rec.arrival_s);
            assert!(rec.joules > 0.0);
            assert!(rec.attempts >= 1);
        }
    }

    #[test]
    fn high_competition_completes_via_retry_queue() {
        let r = run_level(CompetitionLevel::High, 2);
        assert_eq!(r.records.len(), 22);
        assert!(r.unschedulable.is_empty());
        // At least one pod should have waited (the cluster cannot hold
        // all 22 pods' requests at once given complex pods).
        let _waited = r.records.iter().filter(|x| x.wait_s > 0.0).count();
    }

    #[test]
    fn deterministic_runs() {
        let a = run_level(CompetitionLevel::Medium, 7);
        let b = run_level(CompetitionLevel::Medium, 7);
        assert_eq!(a.records.len(), b.records.len());
        for (x, y) in a.records.iter().zip(&b.records) {
            assert_eq!(x.pod, y.pod);
            assert_eq!(x.node, y.node);
            assert_eq!(x.finish_s, y.finish_s);
            assert_eq!(x.joules, y.joules);
        }
        assert_eq!(a.events.len(), b.events.len());
    }

    #[test]
    fn energy_centric_topsis_saves_energy_vs_default() {
        // The paper's headline direction must hold in expectation; we
        // average a few seeds to avoid flakiness.
        let mut topsis_kj = 0.0;
        let mut default_kj = 0.0;
        for seed in 0..5 {
            let r = run_level(CompetitionLevel::Medium, seed);
            topsis_kj += r.mean_kj(SchedulerKind::Topsis);
            default_kj += r.mean_kj(SchedulerKind::DefaultK8s);
        }
        assert!(
            topsis_kj < default_kj,
            "TOPSIS {topsis_kj} !< default {default_kj}"
        );
    }

    #[test]
    fn event_log_is_time_ordered_and_complete() {
        let r = run_level(CompetitionLevel::Medium, 3);
        assert!(!r.events.is_empty());
        for w in r.events.windows(2) {
            assert!(w[1].at_s >= w[0].at_s, "{w:?}");
        }
        let arrivals =
            r.events.iter().filter(|e| e.kind == "pod-arrival").count();
        let completions =
            r.events.iter().filter(|e| e.kind == "pod-completed").count();
        assert_eq!(arrivals, CompetitionLevel::Medium.total_pods());
        assert_eq!(completions, r.records.len());
    }

    #[test]
    fn node_failure_defers_placement_until_rejoin() {
        // Kill every node before the pods arrive; nothing can place
        // until the nodes rejoin, so queue waits must cover the outage.
        let config = Config::paper_default();
        let executor = WorkloadExecutor::analytic();
        let n_nodes = config.cluster.total_nodes();
        let mut node_events: Vec<NodeChange> = (0..n_nodes)
            .map(|node| NodeChange { at_s: 0.0, node, up: false })
            .collect();
        node_events.extend(
            (0..n_nodes).map(|node| NodeChange { at_s: 30.0, node, up: true }),
        );
        let engine = SimulationEngine::new(
            &config,
            SimulationParams {
                contention_beta: 0.35,
                seed: 1,
                node_events,
                ..SimulationParams::default()
            },
            &executor,
        );
        let pods =
            generate_pods(CompetitionLevel::Low, &config.experiment, 1).pods;
        let mut topsis = GreenPodScheduler::new(
            Estimator::with_defaults(config.energy.clone()),
            WeightingScheme::EnergyCentric,
        );
        let mut default = DefaultK8sScheduler::new(1);
        let r = engine.run(pods, &mut topsis, &mut default);
        assert_eq!(r.records.len(), 8);
        assert!(r.unschedulable.is_empty());
        for rec in &r.records {
            assert!(
                rec.start_s >= 30.0,
                "pod {} started at {} during the outage",
                rec.pod,
                rec.start_s
            );
            assert!(rec.wait_s > 0.0);
        }
    }

    #[test]
    fn threshold_autoscaler_scales_out_under_backlog_and_back_in() {
        use crate::autoscaler::{AutoscalerPolicy, ThresholdConfig};
        use crate::workload::WorkloadClass;

        // 18 complex pods against 16 complex slots: 2 overflow at
        // t = 0.5, the depth-2 trigger provisions edge nodes, the
        // overflow lands on them, and idle scale-in returns the cluster
        // to its base size before the run ends.
        let config = Config::paper_default();
        let executor = WorkloadExecutor::analytic();
        let mut pods = Vec::new();
        for i in 0..18u64 {
            let at = 0.25 * (i / 6) as f64;
            pods.push(Pod::new(
                i,
                WorkloadClass::Complex,
                SchedulerKind::Topsis,
                at,
                1,
            ));
        }
        let policy = ThresholdConfig {
            scale_out_pending: 2,
            scale_out_wait_p95_s: f64::INFINITY,
            provision_delay_s: 5.0,
            cooldown_s: 2.0,
            idle_scale_in_s: 10.0,
            min_nodes: 7,
            max_nodes: 10,
            template: ThresholdConfig::edge_template(&config.cluster),
            carbon: None,
        };
        let params = SimulationParams::with_beta_and_seed(0.35, 1)
            .with_autoscaler(AutoscalerPolicy::Threshold(policy));
        let engine = SimulationEngine::new(&config, params, &executor);
        let mut topsis = GreenPodScheduler::new(
            Estimator::with_defaults(config.energy.clone()),
            WeightingScheme::EnergyCentric,
        );
        let mut default = DefaultK8sScheduler::new(1);
        let r = engine.run(pods, &mut topsis, &mut default);

        assert_eq!(r.records.len(), 18);
        assert!(r.unschedulable.is_empty());
        assert!(r.scaling_count("scale-out") >= 1, "{:?}", r.scaling);
        assert!(r.scaling_count("scale-in") >= 1, "{:?}", r.scaling);
        // Provisioned capacity is append-only: autoscaled ids follow
        // the 7 base nodes, and the overflow actually ran on one.
        assert!(r.scaling.iter().all(|s| s.node >= 7));
        assert!(
            r.records.iter().any(|rec| rec.node >= 7),
            "no pod ever used autoscaled capacity"
        );
        // Scale-out takes effect only after the provisioning delay.
        for s in r.scaling.iter().filter(|s| s.kind == "scale-out") {
            assert!((s.effective_at_s - s.at_s - 5.0).abs() < 1e-12);
        }
        assert!(r.peak_ready_nodes() > 7);
        assert_eq!(r.node_timeline.last().unwrap().ready_nodes, 7);
        assert!(r.idle_kj() > 0.0);
        assert!(r.mean_ready_nodes() > 7.0);
        assert!(r.mean_ready_nodes() < 10.0);
    }

    #[test]
    fn disabled_threshold_policy_is_bit_identical_to_none() {
        use crate::autoscaler::{AutoscalerPolicy, ThresholdConfig};

        let config = Config::paper_default();
        let executor = WorkloadExecutor::analytic();
        let pods =
            generate_pods(CompetitionLevel::High, &config.experiment, 9).pods;
        let mk = || {
            (
                GreenPodScheduler::new(
                    Estimator::with_defaults(config.energy.clone()),
                    WeightingScheme::EnergyCentric,
                ),
                DefaultK8sScheduler::new(9),
            )
        };
        let run = |params: SimulationParams| {
            let engine = SimulationEngine::new(&config, params, &executor);
            let (mut t, mut d) = mk();
            engine.run(pods.clone(), &mut t, &mut d)
        };
        let plain = run(SimulationParams::with_beta_and_seed(0.35, 9));
        let noop = run(
            SimulationParams::with_beta_and_seed(0.35, 9).with_autoscaler(
                AutoscalerPolicy::Threshold(ThresholdConfig::disabled(
                    &config.cluster,
                )),
            ),
        );
        assert_eq!(plain.records.len(), noop.records.len());
        for (x, y) in plain.records.iter().zip(&noop.records) {
            assert_eq!(x.pod, y.pod);
            assert_eq!(x.node, y.node);
            assert_eq!(x.start_s, y.start_s);
            assert_eq!(x.finish_s, y.finish_s);
            assert_eq!(x.joules, y.joules);
        }
        assert_eq!(plain.events, noop.events);
        assert_eq!(plain.makespan_s, noop.makespan_s);
        assert!(noop.scaling.is_empty());
        assert_eq!(plain.node_timeline, noop.node_timeline);
    }

    #[test]
    fn forced_full_cycles_are_bit_identical_to_guarded() {
        use crate::autoscaler::{AutoscalerPolicy, ThresholdConfig};
        use crate::workload::WorkloadClass;

        // The no-change short-circuit must be placement-neutral: the
        // same backlog-heavy autoscaled run with every cycle forced
        // must match the guarded run bitwise, record for record.
        let config = Config::paper_default();
        let executor = WorkloadExecutor::analytic();
        let mut pods = Vec::new();
        for i in 0..18u64 {
            let at = 0.25 * (i / 6) as f64;
            pods.push(Pod::new(
                i,
                WorkloadClass::Complex,
                SchedulerKind::Topsis,
                at,
                1,
            ));
        }
        let policy = || ThresholdConfig {
            scale_out_pending: 2,
            scale_out_wait_p95_s: f64::INFINITY,
            provision_delay_s: 5.0,
            cooldown_s: 2.0,
            idle_scale_in_s: 10.0,
            min_nodes: 7,
            max_nodes: 10,
            template: ThresholdConfig::edge_template(&config.cluster),
            carbon: None,
        };
        let run = |force: bool| {
            let mut params = SimulationParams::with_beta_and_seed(0.35, 1)
                .with_autoscaler(AutoscalerPolicy::Threshold(policy()));
            params.force_full_cycles = force;
            let engine = SimulationEngine::new(&config, params, &executor);
            let mut topsis = GreenPodScheduler::new(
                Estimator::with_defaults(config.energy.clone()),
                WeightingScheme::EnergyCentric,
            );
            let mut default = DefaultK8sScheduler::new(1);
            engine.run(pods.clone(), &mut topsis, &mut default)
        };
        let guarded = run(false);
        let forced = run(true);
        assert_eq!(guarded.records.len(), forced.records.len());
        for (x, y) in guarded.records.iter().zip(&forced.records) {
            assert_eq!(x.pod, y.pod);
            assert_eq!(x.node, y.node);
            assert_eq!(x.start_s, y.start_s);
            assert_eq!(x.finish_s, y.finish_s);
            assert_eq!(x.attempts, y.attempts);
            assert_eq!(x.joules.to_bits(), y.joules.to_bits());
        }
        assert_eq!(guarded.events, forced.events);
        assert_eq!(guarded.node_timeline, forced.node_timeline);
        assert_eq!(
            guarded.makespan_s.to_bits(),
            forced.makespan_s.to_bits()
        );
    }

    #[test]
    fn batch_mode_matches_event_mode_at_t0() {
        let config = Config::paper_default();
        let executor = WorkloadExecutor::analytic();
        let engine = SimulationEngine::new(
            &config,
            SimulationParams::with_beta_and_seed(0.35, 5),
            &executor,
        );
        let mut pods =
            generate_pods(CompetitionLevel::High, &config.experiment, 5).pods;
        for p in &mut pods {
            p.arrival_s = 0.0;
        }
        let mk = || {
            (
                GreenPodScheduler::new(
                    Estimator::with_defaults(config.energy.clone()),
                    WeightingScheme::EnergyCentric,
                ),
                DefaultK8sScheduler::new(5),
            )
        };
        let (mut t1, mut d1) = mk();
        let (mut t2, mut d2) = mk();
        let ev = engine.run(pods.clone(), &mut t1, &mut d1);
        let ba = engine.run_batch(pods, &mut t2, &mut d2);
        assert_eq!(ev.records.len(), ba.records.len());
        for (x, y) in ev.records.iter().zip(&ba.records) {
            assert_eq!(x.pod, y.pod);
            assert_eq!(x.node, y.node);
            assert_eq!(x.start_s, y.start_s);
            assert_eq!(x.finish_s, y.finish_s);
            assert!((x.joules - y.joules).abs() <= 1e-9 * x.joules.abs());
        }
    }
}
