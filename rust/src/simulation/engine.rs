//! The discrete-event engine.
//!
//! Events: pod arrival → scheduling attempt → (bind, execute) →
//! completion → retry queue. Unschedulable pods wait in a FIFO retry
//! queue that is re-examined on every completion — the same retry
//! semantics as kube-scheduler's backoff queue, collapsed to
//! event-driven time.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::cluster::{ClusterState, Pod, PodPhase};
use crate::config::{Config, SchedulerKind};
use crate::energy::EnergyMeter;
use crate::scheduler::Scheduler;
use crate::simulation::{contention_factor, PodRecord, RunResult};
use crate::workload::WorkloadExecutor;

/// Engine-level knobs (beyond what `Config` carries).
#[derive(Debug, Clone)]
pub struct SimulationParams {
    pub contention_beta: f64,
    /// Seed for per-pod dataset generation in real-execution mode.
    pub seed: u64,
}

impl Default for SimulationParams {
    fn default() -> Self {
        Self { contention_beta: 0.35, seed: 0 }
    }
}

#[derive(Debug, Clone, PartialEq)]
enum Event {
    Arrival(usize),
    Completion(usize),
}

/// Time-ordered event-queue entry. `seq` makes ordering total and
/// deterministic for simultaneous events.
#[derive(Debug, Clone, PartialEq)]
struct QueuedEvent {
    at: f64,
    seq: u64,
    event: Event,
}

impl Eq for QueuedEvent {}

impl Ord for QueuedEvent {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.at
            .total_cmp(&other.at)
            .then_with(|| self.seq.cmp(&other.seq))
    }
}

impl PartialOrd for QueuedEvent {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

/// The simulation engine. Owns the cluster state and the energy meter
/// for the duration of one run.
pub struct SimulationEngine<'a> {
    config: &'a Config,
    params: SimulationParams,
    executor: &'a WorkloadExecutor,
}

impl<'a> SimulationEngine<'a> {
    pub fn new(
        config: &'a Config,
        params: SimulationParams,
        executor: &'a WorkloadExecutor,
    ) -> Self {
        Self { config, params, executor }
    }

    /// Run one deployment: `pods` arrive per their `arrival_s`; pods
    /// tagged `Topsis` are placed by `topsis`, the rest by `default`.
    pub fn run(
        &self,
        mut pods: Vec<Pod>,
        topsis: &mut dyn Scheduler,
        default: &mut dyn Scheduler,
    ) -> RunResult {
        let mut state = ClusterState::from_config(&self.config.cluster);
        let mut meter = EnergyMeter::new();
        let mut records: Vec<PodRecord> = Vec::with_capacity(pods.len());
        let mut queue: BinaryHeap<Reverse<QueuedEvent>> = BinaryHeap::new();
        let mut seq: u64 = 0;
        // Pods awaiting a schedulable moment (FIFO), by index into pods.
        let mut pending: Vec<usize> = Vec::new();
        // Cumulative scheduling latency per pod (µs), across retries.
        let mut sched_latency_us: Vec<f64> = vec![0.0; pods.len()];
        let mut makespan: f64 = 0.0;

        for (i, p) in pods.iter().enumerate() {
            queue.push(Reverse(QueuedEvent {
                at: p.arrival_s,
                seq,
                event: Event::Arrival(i),
            }));
            seq += 1;
        }

        while let Some(Reverse(QueuedEvent { at: now, event, .. })) =
            queue.pop()
        {
            match event {
                Event::Arrival(i) => {
                    if !self.try_place(
                        i, now, &mut pods, &mut state, &mut meter,
                        &mut records, &mut sched_latency_us, &mut queue,
                        &mut seq, topsis, default,
                    ) {
                        pending.push(i);
                    }
                }
                Event::Completion(i) => {
                    makespan = makespan.max(now);
                    state
                        .release(pods[i].id, now)
                        .expect("completion of bound pod");
                    pods[i].phase = PodPhase::Succeeded;
                    // Retry pending pods in FIFO order; stop early is not
                    // possible (a later small pod may fit where an
                    // earlier big one does not), so scan all.
                    let mut still_pending = Vec::new();
                    for &j in &pending {
                        if !self.try_place(
                            j, now, &mut pods, &mut state, &mut meter,
                            &mut records, &mut sched_latency_us, &mut queue,
                            &mut seq, topsis, default,
                        ) {
                            still_pending.push(j);
                        }
                    }
                    pending = still_pending;
                }
            }
        }

        let unschedulable = pending
            .iter()
            .map(|&i| {
                pods[i].phase = PodPhase::Unschedulable;
                pods[i].id
            })
            .collect();

        RunResult {
            records,
            meter,
            unschedulable,
            makespan_s: makespan,
            pjrt_fallbacks: 0,
        }
    }

    /// Attempt to place and start pod `i` at time `now`. Returns false
    /// if it remains pending.
    #[allow(clippy::too_many_arguments)]
    fn try_place(
        &self,
        i: usize,
        now: f64,
        pods: &mut [Pod],
        state: &mut ClusterState,
        meter: &mut EnergyMeter,
        records: &mut Vec<PodRecord>,
        sched_latency_us: &mut [f64],
        queue: &mut BinaryHeap<Reverse<QueuedEvent>>,
        seq: &mut u64,
        topsis: &mut dyn Scheduler,
        default: &mut dyn Scheduler,
    ) -> bool {
        let decision = match pods[i].scheduler {
            SchedulerKind::Topsis => topsis.schedule(state, &pods[i]),
            SchedulerKind::DefaultK8s => default.schedule(state, &pods[i]),
        };
        sched_latency_us[i] += decision.latency.as_secs_f64() * 1e6;
        let Some(node_id) = decision.node else {
            return false;
        };

        state.bind(&pods[i], node_id, now).expect("scheduler chose fit");
        pods[i].phase = PodPhase::Running;

        let node = state.node(node_id).clone();
        let outcome = self
            .executor
            .execute(&pods[i], &node, self.params.seed ^ pods[i].id)
            .expect("workload execution");
        let share =
            pods[i].requests.cpu_millis as f64 / node.cpu_millis as f64;
        let factor = contention_factor(
            self.params.contention_beta,
            state.cpu_utilization(node_id),
            share,
        );
        let duration = outcome.base_secs * factor;
        let joules = meter.record(
            &self.config.energy,
            pods[i].id,
            pods[i].class,
            pods[i].scheduler,
            &node,
            share,
            duration,
        );

        records.push(PodRecord {
            pod: pods[i].id,
            class: pods[i].class,
            scheduler: pods[i].scheduler,
            node: node_id,
            node_category: node.category,
            arrival_s: pods[i].arrival_s,
            start_s: now,
            finish_s: now + duration,
            sched_latency_us: sched_latency_us[i],
            joules,
            wait_s: now - pods[i].arrival_s,
        });

        queue.push(Reverse(QueuedEvent {
            at: now + duration,
            seq: *seq,
            event: Event::Completion(i),
        }));
        *seq += 1;
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{CompetitionLevel, WeightingScheme};
    use crate::scheduler::{
        DefaultK8sScheduler, Estimator, GreenPodScheduler,
    };
    use crate::workload::generate_pods;

    fn run_level(level: CompetitionLevel, seed: u64) -> RunResult {
        let config = Config::paper_default();
        let executor = WorkloadExecutor::analytic();
        let engine = SimulationEngine::new(
            &config,
            SimulationParams { contention_beta: 0.35, seed },
            &executor,
        );
        let pods = generate_pods(level, &config.experiment, seed).pods;
        let mut topsis = GreenPodScheduler::new(
            Estimator::with_defaults(config.energy.clone()),
            WeightingScheme::EnergyCentric,
        );
        let mut default = DefaultK8sScheduler::new(seed);
        engine.run(pods, &mut topsis, &mut default)
    }

    #[test]
    fn all_pods_complete_low_competition() {
        let r = run_level(CompetitionLevel::Low, 1);
        assert_eq!(r.records.len(), 8);
        assert!(r.unschedulable.is_empty());
        assert!(r.makespan_s > 0.0);
        for rec in &r.records {
            assert!(rec.finish_s > rec.start_s);
            assert!(rec.start_s >= rec.arrival_s);
            assert!(rec.joules > 0.0);
        }
    }

    #[test]
    fn high_competition_completes_via_retry_queue() {
        let r = run_level(CompetitionLevel::High, 2);
        assert_eq!(r.records.len(), 22);
        assert!(r.unschedulable.is_empty());
        // At least one pod should have waited (the cluster cannot hold
        // all 22 pods' requests at once given complex pods).
        let _waited = r.records.iter().filter(|x| x.wait_s > 0.0).count();
    }

    #[test]
    fn deterministic_runs() {
        let a = run_level(CompetitionLevel::Medium, 7);
        let b = run_level(CompetitionLevel::Medium, 7);
        assert_eq!(a.records.len(), b.records.len());
        for (x, y) in a.records.iter().zip(&b.records) {
            assert_eq!(x.pod, y.pod);
            assert_eq!(x.node, y.node);
            assert_eq!(x.finish_s, y.finish_s);
            assert_eq!(x.joules, y.joules);
        }
    }

    #[test]
    fn energy_centric_topsis_saves_energy_vs_default() {
        // The paper's headline direction must hold in expectation; we
        // average a few seeds to avoid flakiness.
        let mut topsis_kj = 0.0;
        let mut default_kj = 0.0;
        for seed in 0..5 {
            let r = run_level(CompetitionLevel::Medium, seed);
            topsis_kj += r.mean_kj(SchedulerKind::Topsis);
            default_kj += r.mean_kj(SchedulerKind::DefaultK8s);
        }
        assert!(
            topsis_kj < default_kj,
            "TOPSIS {topsis_kj} !< default {default_kj}"
        );
    }
}
