//! The simulation engine — a thin front over the one event loop.
//!
//! Since the engine collapse ([`crate::federation::FederationEngine`]
//! is the single discrete-event loop in the tree),
//! [`SimulationEngine::run`] delegates to a **1-region federation**:
//! the merged queue degenerates to the plain kernel queue (identical
//! `(time, kind-priority, seq)` assignments), every dispatch resolves
//! to region 0, and all arithmetic is the same float ops in the same
//! order — so the delegation is record-for-record bit-identical to the
//! retired standalone loop, pinned by the golden-fixture replays and
//! `prop_federation_single_region_is_bit_identical_to_plain_engine`.
//!
//! [`SimulationEngine::run_batch`] — the paper's burst deployment
//! without arrival dynamics — is the same event loop with every
//! arrival forced to t = 0 on a fixed cluster (no churn, no
//! autoscaler, no billing horizon). It is no longer an independent
//! re-implementation: folding it onto the real queue means the
//! kernel's same-timestamp kind-priority ordering (arrivals before
//! completions before the cycle) applies to batch runs too, instead of
//! being hand-mirrored outside the kernel.
//!
//! Event mode can additionally run a cluster autoscaler
//! (`SimulationParams::autoscaler`, DESIGN.md §"Autoscaler") and a
//! node-churn schedule (`SimulationParams::node_events`); both flow
//! into the region spec unchanged.

use crate::autoscaler::AutoscalerPolicy;
use crate::cluster::{NodeId, Pod};
use crate::config::Config;
use crate::energy::CarbonSignal;
use crate::federation::{
    FederationEngine, FederationParams, RegionSpec, RoundRobin,
};
use crate::scheduler::Scheduler;
use crate::simulation::RunResult;
use crate::workload::WorkloadExecutor;

/// A scheduled node-membership change (cluster churn injection).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NodeChange {
    pub at_s: f64,
    pub node: NodeId,
    /// `true` = NodeJoined (Ready), `false` = NodeFailed (NotReady).
    pub up: bool,
}

/// Engine-level knobs (beyond what `Config` carries).
#[derive(Debug, Clone)]
pub struct SimulationParams {
    pub contention_beta: f64,
    /// Seed for per-pod dataset generation in real-execution mode.
    pub seed: u64,
    /// Node churn schedule (empty = the fixed paper cluster).
    pub node_events: Vec<NodeChange>,
    /// Cluster-autoscaling policy (`None` = the fixed cluster; the run
    /// is then bit-identical to the pre-autoscaler engine, which the
    /// property suite pins).
    pub autoscaler: Option<AutoscalerPolicy>,
    /// Billing horizon for idle energy (s). By default the meter stops
    /// at the last event, which undercounts a static cluster relative
    /// to an autoscaled one whose trailing scale-ins extend the event
    /// stream; setting a common horizon bills every configuration's
    /// powered-on nodes over the same `[0, horizon]` window, making
    /// totals comparable at equal admitted work (the elasticity
    /// experiments set this; plain experiment cells do not).
    pub billing_horizon_s: Option<f64>,
    /// Grid carbon-intensity signal the meter's CO₂ ledger integrates
    /// against and the scheduling clock exposes (`None` = the config's
    /// eGRID scalar as a constant — bit-identical to the pre-signal
    /// engine, which the differential property pins).
    pub carbon: Option<CarbonSignal>,
    /// Differential-testing knob: run every scheduling cycle even when
    /// no node changed and no pod arrived since the previous cycle,
    /// instead of short-circuiting the provably-futile retry pass.
    /// The skip is placement-neutral by construction (an unchanged
    /// cluster re-fails every pending pod identically); the regression
    /// test pins forced ≡ guarded bitwise.
    pub force_full_cycles: bool,
}

impl Default for SimulationParams {
    fn default() -> Self {
        Self {
            contention_beta: 0.35,
            seed: 0,
            node_events: Vec::new(),
            autoscaler: None,
            billing_horizon_s: None,
            carbon: None,
            force_full_cycles: false,
        }
    }
}

impl SimulationParams {
    /// Explicit contention/seed, no node churn — the common case for
    /// experiments, benches and examples.
    pub fn with_beta_and_seed(contention_beta: f64, seed: u64) -> Self {
        Self { contention_beta, seed, ..Self::default() }
    }

    /// Attach an autoscaling policy.
    pub fn with_autoscaler(mut self, policy: AutoscalerPolicy) -> Self {
        self.autoscaler = Some(policy);
        self
    }

    /// Attach a time-varying carbon-intensity signal.
    pub fn with_carbon(mut self, carbon: CarbonSignal) -> Self {
        self.carbon = Some(carbon);
        self
    }
}

/// The single-cluster simulation engine: a 1-region view over the
/// federation event loop.
pub struct SimulationEngine<'a> {
    config: &'a Config,
    params: SimulationParams,
    executor: &'a WorkloadExecutor,
}

impl<'a> SimulationEngine<'a> {
    pub fn new(
        config: &'a Config,
        params: SimulationParams,
        executor: &'a WorkloadExecutor,
    ) -> Self {
        Self { config, params, executor }
    }

    /// Event mode: pods arrive per their `arrival_s`; pods tagged
    /// `Topsis` are placed by `topsis`, the rest by `default`.
    /// Delegates to a 1-region federation — the one event loop.
    pub fn run(
        &self,
        pods: Vec<Pod>,
        topsis: &mut dyn Scheduler,
        default: &mut dyn Scheduler,
    ) -> RunResult {
        // The region's CO₂ ledger integrates against the run's signal;
        // absent an explicit one, the config's (constant by default —
        // exactly the scalar grams_co2_per_joule path).
        let mut spec = RegionSpec::new("cluster", self.config.clone())
            .with_node_events(self.params.node_events.clone());
        if let Some(carbon) = &self.params.carbon {
            spec = spec.with_carbon(carbon.clone());
        }
        if let Some(policy) = &self.params.autoscaler {
            spec = spec.with_autoscaler(policy.clone());
        }
        let specs = [spec];
        let engine = FederationEngine::new(
            &specs,
            FederationParams {
                contention_beta: self.params.contention_beta,
                seed: self.params.seed,
                billing_horizon_s: self.params.billing_horizon_s,
                force_full_cycles: self.params.force_full_cycles,
            },
            self.executor,
        );
        // With one region, round-robin dispatch is the identity.
        let mut dispatcher = RoundRobin::new();
        let result =
            engine.run_refs(pods, &mut dispatcher, &mut [(topsis, default)]);
        result
            .regions
            .into_iter()
            .next()
            .expect("1-region federation yields one region")
            .run
    }

    /// Batch mode (the paper's burst deployment without arrival
    /// dynamics): every pod is submitted at t = 0 regardless of
    /// `arrival_s` and the run executes on the fixed configured
    /// cluster — node churn, the autoscaler and the billing horizon do
    /// not apply. Same event loop as [`SimulationEngine::run`], so the
    /// kernel's same-timestamp coalescing and kind-priority ordering
    /// hold here too.
    pub fn run_batch(
        &self,
        mut pods: Vec<Pod>,
        topsis: &mut dyn Scheduler,
        default: &mut dyn Scheduler,
    ) -> RunResult {
        for p in &mut pods {
            p.arrival_s = 0.0;
        }
        let fixed = SimulationEngine::new(
            self.config,
            SimulationParams {
                node_events: Vec::new(),
                autoscaler: None,
                billing_horizon_s: None,
                ..self.params.clone()
            },
            self.executor,
        );
        fixed.run(pods, topsis, default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{CompetitionLevel, SchedulerKind, WeightingScheme};
    use crate::framework::{BuildOptions, FrameworkScheduler, ProfileRegistry};
    use crate::workload::generate_pods;

    /// Registry-built scheduler pair — the framework profiles are the
    /// only scheduler implementations since the monolith retirement.
    fn build_scheds(
        config: &Config,
        seed: u64,
    ) -> (FrameworkScheduler, FrameworkScheduler) {
        let registry = ProfileRegistry::new(config);
        let opts = BuildOptions::new(config, WeightingScheme::EnergyCentric)
            .with_seed(seed);
        (
            registry.build("greenpod", &opts).expect("built-in"),
            registry.build("default-k8s", &opts).expect("built-in"),
        )
    }

    fn run_level(level: CompetitionLevel, seed: u64) -> RunResult {
        let config = Config::paper_default();
        let executor = WorkloadExecutor::analytic();
        let engine = SimulationEngine::new(
            &config,
            SimulationParams::with_beta_and_seed(0.35, seed),
            &executor,
        );
        let pods = generate_pods(level, &config.experiment, seed).pods;
        let (mut topsis, mut default) = build_scheds(&config, seed);
        engine.run(pods, &mut topsis, &mut default)
    }

    #[test]
    fn all_pods_complete_low_competition() {
        let r = run_level(CompetitionLevel::Low, 1);
        assert_eq!(r.records.len(), 8);
        assert!(r.unschedulable.is_empty());
        assert!(r.makespan_s > 0.0);
        for rec in &r.records {
            assert!(rec.finish_s > rec.start_s);
            assert!(rec.start_s >= rec.arrival_s);
            assert!(rec.joules > 0.0);
            assert!(rec.attempts >= 1);
        }
    }

    #[test]
    fn high_competition_completes_via_retry_queue() {
        let r = run_level(CompetitionLevel::High, 2);
        assert_eq!(r.records.len(), 22);
        assert!(r.unschedulable.is_empty());
        // At least one pod should have waited (the cluster cannot hold
        // all 22 pods' requests at once given complex pods).
        let _waited = r.records.iter().filter(|x| x.wait_s > 0.0).count();
    }

    #[test]
    fn deterministic_runs() {
        let a = run_level(CompetitionLevel::Medium, 7);
        let b = run_level(CompetitionLevel::Medium, 7);
        assert_eq!(a.records.len(), b.records.len());
        for (x, y) in a.records.iter().zip(&b.records) {
            assert_eq!(x.pod, y.pod);
            assert_eq!(x.node, y.node);
            assert_eq!(x.finish_s, y.finish_s);
            assert_eq!(x.joules, y.joules);
        }
        assert_eq!(a.events.len(), b.events.len());
    }

    #[test]
    fn energy_centric_topsis_saves_energy_vs_default() {
        // The paper's headline direction must hold in expectation; we
        // average a few seeds to avoid flakiness.
        let mut topsis_kj = 0.0;
        let mut default_kj = 0.0;
        for seed in 0..5 {
            let r = run_level(CompetitionLevel::Medium, seed);
            topsis_kj += r.mean_kj(SchedulerKind::Topsis);
            default_kj += r.mean_kj(SchedulerKind::DefaultK8s);
        }
        assert!(
            topsis_kj < default_kj,
            "TOPSIS {topsis_kj} !< default {default_kj}"
        );
    }

    #[test]
    fn event_log_is_time_ordered_and_complete() {
        let r = run_level(CompetitionLevel::Medium, 3);
        assert!(!r.events.is_empty());
        for w in r.events.windows(2) {
            assert!(w[1].at_s >= w[0].at_s, "{w:?}");
        }
        let arrivals =
            r.events.iter().filter(|e| e.kind == "pod-arrival").count();
        let completions =
            r.events.iter().filter(|e| e.kind == "pod-completed").count();
        assert_eq!(arrivals, CompetitionLevel::Medium.total_pods());
        assert_eq!(completions, r.records.len());
    }

    #[test]
    fn node_failure_defers_placement_until_rejoin() {
        // Kill every node before the pods arrive; nothing can place
        // until the nodes rejoin, so queue waits must cover the outage.
        let config = Config::paper_default();
        let executor = WorkloadExecutor::analytic();
        let n_nodes = config.cluster.total_nodes();
        let mut node_events: Vec<NodeChange> = (0..n_nodes)
            .map(|node| NodeChange { at_s: 0.0, node, up: false })
            .collect();
        node_events.extend(
            (0..n_nodes).map(|node| NodeChange { at_s: 30.0, node, up: true }),
        );
        let engine = SimulationEngine::new(
            &config,
            SimulationParams {
                contention_beta: 0.35,
                seed: 1,
                node_events,
                ..SimulationParams::default()
            },
            &executor,
        );
        let pods =
            generate_pods(CompetitionLevel::Low, &config.experiment, 1).pods;
        let (mut topsis, mut default) = build_scheds(&config, 1);
        let r = engine.run(pods, &mut topsis, &mut default);
        assert_eq!(r.records.len(), 8);
        assert!(r.unschedulable.is_empty());
        for rec in &r.records {
            assert!(
                rec.start_s >= 30.0,
                "pod {} started at {} during the outage",
                rec.pod,
                rec.start_s
            );
            assert!(rec.wait_s > 0.0);
        }
    }

    #[test]
    fn threshold_autoscaler_scales_out_under_backlog_and_back_in() {
        use crate::autoscaler::{AutoscalerPolicy, ThresholdConfig};
        use crate::workload::WorkloadClass;

        // 18 complex pods against 16 complex slots: 2 overflow at
        // t = 0.5, the depth-2 trigger provisions edge nodes, the
        // overflow lands on them, and idle scale-in returns the cluster
        // to its base size before the run ends.
        let config = Config::paper_default();
        let executor = WorkloadExecutor::analytic();
        let mut pods = Vec::new();
        for i in 0..18u64 {
            let at = 0.25 * (i / 6) as f64;
            pods.push(Pod::new(
                i,
                WorkloadClass::Complex,
                SchedulerKind::Topsis,
                at,
                1,
            ));
        }
        let policy = ThresholdConfig {
            scale_out_pending: 2,
            scale_out_wait_p95_s: f64::INFINITY,
            provision_delay_s: 5.0,
            cooldown_s: 2.0,
            idle_scale_in_s: 10.0,
            min_nodes: 7,
            max_nodes: 10,
            template: ThresholdConfig::edge_template(&config.cluster),
            carbon: None,
        };
        let params = SimulationParams::with_beta_and_seed(0.35, 1)
            .with_autoscaler(AutoscalerPolicy::Threshold(policy));
        let engine = SimulationEngine::new(&config, params, &executor);
        let (mut topsis, mut default) = build_scheds(&config, 1);
        let r = engine.run(pods, &mut topsis, &mut default);

        assert_eq!(r.records.len(), 18);
        assert!(r.unschedulable.is_empty());
        assert!(r.scaling_count("scale-out") >= 1, "{:?}", r.scaling);
        assert!(r.scaling_count("scale-in") >= 1, "{:?}", r.scaling);
        // Provisioned capacity is append-only: autoscaled ids follow
        // the 7 base nodes, and the overflow actually ran on one.
        assert!(r.scaling.iter().all(|s| s.node >= 7));
        assert!(
            r.records.iter().any(|rec| rec.node >= 7),
            "no pod ever used autoscaled capacity"
        );
        // Scale-out takes effect only after the provisioning delay.
        for s in r.scaling.iter().filter(|s| s.kind == "scale-out") {
            assert!((s.effective_at_s - s.at_s - 5.0).abs() < 1e-12);
        }
        assert!(r.peak_ready_nodes() > 7);
        assert_eq!(r.node_timeline.last().unwrap().ready_nodes, 7);
        assert!(r.idle_kj() > 0.0);
        assert!(r.mean_ready_nodes() > 7.0);
        assert!(r.mean_ready_nodes() < 10.0);
    }

    #[test]
    fn disabled_threshold_policy_is_bit_identical_to_none() {
        use crate::autoscaler::{AutoscalerPolicy, ThresholdConfig};

        let config = Config::paper_default();
        let executor = WorkloadExecutor::analytic();
        let pods =
            generate_pods(CompetitionLevel::High, &config.experiment, 9).pods;
        let run = |params: SimulationParams| {
            let engine = SimulationEngine::new(&config, params, &executor);
            let (mut t, mut d) = build_scheds(&config, 9);
            engine.run(pods.clone(), &mut t, &mut d)
        };
        let plain = run(SimulationParams::with_beta_and_seed(0.35, 9));
        let noop = run(
            SimulationParams::with_beta_and_seed(0.35, 9).with_autoscaler(
                AutoscalerPolicy::Threshold(ThresholdConfig::disabled(
                    &config.cluster,
                )),
            ),
        );
        assert_eq!(plain.records.len(), noop.records.len());
        for (x, y) in plain.records.iter().zip(&noop.records) {
            assert_eq!(x.pod, y.pod);
            assert_eq!(x.node, y.node);
            assert_eq!(x.start_s, y.start_s);
            assert_eq!(x.finish_s, y.finish_s);
            assert_eq!(x.joules, y.joules);
        }
        assert_eq!(plain.events, noop.events);
        assert_eq!(plain.makespan_s, noop.makespan_s);
        assert!(noop.scaling.is_empty());
        assert_eq!(plain.node_timeline, noop.node_timeline);
    }

    #[test]
    fn forced_full_cycles_are_bit_identical_to_guarded() {
        use crate::autoscaler::{AutoscalerPolicy, ThresholdConfig};
        use crate::workload::WorkloadClass;

        // The no-change short-circuit must be placement-neutral: the
        // same backlog-heavy autoscaled run with every cycle forced
        // must match the guarded run bitwise, record for record —
        // through the delegated path, since the single guard now lives
        // in the federation loop.
        let config = Config::paper_default();
        let executor = WorkloadExecutor::analytic();
        let mut pods = Vec::new();
        for i in 0..18u64 {
            let at = 0.25 * (i / 6) as f64;
            pods.push(Pod::new(
                i,
                WorkloadClass::Complex,
                SchedulerKind::Topsis,
                at,
                1,
            ));
        }
        let policy = || ThresholdConfig {
            scale_out_pending: 2,
            scale_out_wait_p95_s: f64::INFINITY,
            provision_delay_s: 5.0,
            cooldown_s: 2.0,
            idle_scale_in_s: 10.0,
            min_nodes: 7,
            max_nodes: 10,
            template: ThresholdConfig::edge_template(&config.cluster),
            carbon: None,
        };
        let run = |force: bool| {
            let mut params = SimulationParams::with_beta_and_seed(0.35, 1)
                .with_autoscaler(AutoscalerPolicy::Threshold(policy()));
            params.force_full_cycles = force;
            let engine = SimulationEngine::new(&config, params, &executor);
            let (mut topsis, mut default) = build_scheds(&config, 1);
            engine.run(pods.clone(), &mut topsis, &mut default)
        };
        let guarded = run(false);
        let forced = run(true);
        assert_eq!(guarded.records.len(), forced.records.len());
        for (x, y) in guarded.records.iter().zip(&forced.records) {
            assert_eq!(x.pod, y.pod);
            assert_eq!(x.node, y.node);
            assert_eq!(x.start_s, y.start_s);
            assert_eq!(x.finish_s, y.finish_s);
            assert_eq!(x.attempts, y.attempts);
            assert_eq!(x.joules.to_bits(), y.joules.to_bits());
        }
        assert_eq!(guarded.events, forced.events);
        assert_eq!(guarded.node_timeline, forced.node_timeline);
        assert_eq!(
            guarded.makespan_s.to_bits(),
            forced.makespan_s.to_bits()
        );
        // The skip/run counters make the guard observable: forcing
        // disables skipping entirely, and both runs fire the same
        // total number of cycles (their event logs are equal).
        assert_eq!(forced.cycles_skipped, 0);
        assert_eq!(
            guarded.cycles_run + guarded.cycles_skipped,
            forced.cycles_run
        );
        let fired = guarded
            .events
            .iter()
            .filter(|e| e.kind == "scheduling-cycle")
            .count() as u64;
        assert_eq!(guarded.cycles_run + guarded.cycles_skipped, fired);
    }

    #[test]
    fn batch_mode_matches_event_mode_at_t0() {
        let config = Config::paper_default();
        let executor = WorkloadExecutor::analytic();
        let engine = SimulationEngine::new(
            &config,
            SimulationParams::with_beta_and_seed(0.35, 5),
            &executor,
        );
        let mut pods =
            generate_pods(CompetitionLevel::High, &config.experiment, 5).pods;
        for p in &mut pods {
            p.arrival_s = 0.0;
        }
        let (mut t1, mut d1) = build_scheds(&config, 5);
        let (mut t2, mut d2) = build_scheds(&config, 5);
        let ev = engine.run(pods.clone(), &mut t1, &mut d1);
        let ba = engine.run_batch(pods, &mut t2, &mut d2);
        assert_eq!(ev.records.len(), ba.records.len());
        for (x, y) in ev.records.iter().zip(&ba.records) {
            assert_eq!(x.pod, y.pod);
            assert_eq!(x.node, y.node);
            assert_eq!(x.start_s, y.start_s);
            assert_eq!(x.finish_s, y.finish_s);
            assert!((x.joules - y.joules).abs() <= 1e-9 * x.joules.abs());
        }
        // Folded onto the one event loop, batch mode at t = 0 is the
        // event run verbatim — events and all.
        assert_eq!(ev.events, ba.events);
    }
}
