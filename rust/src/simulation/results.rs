//! Per-run results: everything the metrics/report layer and the
//! experiment drivers need from one simulated deployment.

use std::collections::HashMap;

use crate::cluster::{NodeCategory, PodId};
use crate::config::SchedulerKind;
use crate::energy::EnergyMeter;
use crate::metrics::Summary;
use crate::workload::WorkloadClass;

/// Lifecycle record of one pod.
#[derive(Debug, Clone)]
pub struct PodRecord {
    pub pod: PodId,
    pub class: WorkloadClass,
    pub scheduler: SchedulerKind,
    pub node: usize,
    pub node_category: NodeCategory,
    pub arrival_s: f64,
    pub start_s: f64,
    pub finish_s: f64,
    /// Cumulative scheduling decision latency across attempts (µs).
    pub sched_latency_us: f64,
    /// Scheduling attempts until bound (1 = placed on first try).
    pub attempts: u32,
    /// Attributed energy (J).
    pub joules: f64,
    /// Queueing delay between arrival and binding (s).
    pub wait_s: f64,
}

/// One kernel event, for audit/debug and the monotonicity property
/// tests (`at_s` is non-decreasing over the log).
#[derive(Debug, Clone, PartialEq)]
pub struct EventRecord {
    pub at_s: f64,
    pub kind: &'static str,
}

/// The outcome of one simulated run.
#[derive(Debug, Clone)]
pub struct RunResult {
    pub records: Vec<PodRecord>,
    pub meter: EnergyMeter,
    /// Pods that never became schedulable.
    pub unschedulable: Vec<PodId>,
    /// Virtual time at which the last pod finished.
    pub makespan_s: f64,
    /// PJRT scoring fallbacks observed (failure injection).
    pub pjrt_fallbacks: u64,
    /// Time-ordered kernel event log.
    pub events: Vec<EventRecord>,
}

impl RunResult {
    /// Mean per-pod energy (kJ) for one scheduler — Table VI's unit.
    pub fn mean_kj(&self, kind: SchedulerKind) -> f64 {
        self.meter.mean_kj_per_pod(kind)
    }

    /// Mean scheduling latency (ms) for one scheduler — the paper's
    /// "scheduling time" metric.
    pub fn mean_sched_ms(&self, kind: SchedulerKind) -> f64 {
        let l: Vec<f64> = self
            .records
            .iter()
            .filter(|r| r.scheduler == kind)
            .map(|r| r.sched_latency_us / 1000.0)
            .collect();
        if l.is_empty() {
            0.0
        } else {
            l.iter().sum::<f64>() / l.len() as f64
        }
    }

    /// Per-pod queue-wait distribution (s) for one scheduler — the
    /// "slight scheduling latency" cost the paper trades for energy.
    pub fn queue_wait_summary(&self, kind: SchedulerKind) -> Summary {
        let w: Vec<f64> = self
            .records
            .iter()
            .filter(|r| r.scheduler == kind)
            .map(|r| r.wait_s)
            .collect();
        Summary::of(&w)
    }

    /// Per-pod cumulative scheduling-latency distribution (ms).
    pub fn sched_latency_summary_ms(&self, kind: SchedulerKind) -> Summary {
        let l: Vec<f64> = self
            .records
            .iter()
            .filter(|r| r.scheduler == kind)
            .map(|r| r.sched_latency_us / 1000.0)
            .collect();
        Summary::of(&l)
    }

    /// Mean scheduling attempts per placed pod (1.0 = never queued
    /// behind capacity).
    pub fn mean_attempts(&self, kind: SchedulerKind) -> f64 {
        let a: Vec<f64> = self
            .records
            .iter()
            .filter(|r| r.scheduler == kind)
            .map(|r| r.attempts as f64)
            .collect();
        Summary::of(&a).mean
    }

    /// Allocation histogram per node category for one scheduler (§V.D).
    pub fn allocations(
        &self,
        kind: SchedulerKind,
    ) -> HashMap<NodeCategory, u32> {
        let mut out = HashMap::new();
        for r in self.records.iter().filter(|r| r.scheduler == kind) {
            *out.entry(r.node_category).or_insert(0) += 1;
        }
        out
    }

    /// Mean completion time (s) per workload class for one scheduler.
    pub fn completion_by_class(
        &self,
        kind: SchedulerKind,
    ) -> HashMap<WorkloadClass, f64> {
        let mut sums: HashMap<WorkloadClass, (f64, usize)> = HashMap::new();
        for r in self.records.iter().filter(|r| r.scheduler == kind) {
            let e = sums.entry(r.class).or_insert((0.0, 0));
            e.0 += r.finish_s - r.arrival_s;
            e.1 += 1;
        }
        sums.into_iter()
            .map(|(k, (s, n))| (k, s / n as f64))
            .collect()
    }

    /// Node-allocation efficiency (Table IV): fraction of pods placed on
    /// the node category that minimizes their energy (the "optimal"
    /// energy allocation is Category A whenever it fits).
    pub fn allocation_efficiency(&self, kind: SchedulerKind) -> f64 {
        let recs: Vec<_> = self
            .records
            .iter()
            .filter(|r| r.scheduler == kind)
            .collect();
        if recs.is_empty() {
            return 0.0;
        }
        let on_a = recs
            .iter()
            .filter(|r| r.node_category == NodeCategory::A)
            .count();
        on_a as f64 / recs.len() as f64
    }
}
