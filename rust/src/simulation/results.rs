//! Per-run results: everything the metrics/report layer and the
//! experiment drivers need from one simulated deployment.

use std::collections::BTreeMap;

use crate::cluster::{NodeCategory, PodId};
use crate::config::SchedulerKind;
use crate::energy::EnergyMeter;
use crate::metrics::Summary;
use crate::workload::WorkloadClass;

/// Lifecycle record of one pod.
#[derive(Debug, Clone)]
pub struct PodRecord {
    pub pod: PodId,
    pub class: WorkloadClass,
    pub scheduler: SchedulerKind,
    pub node: usize,
    pub node_category: NodeCategory,
    pub arrival_s: f64,
    pub start_s: f64,
    pub finish_s: f64,
    /// Cumulative scheduling decision latency across attempts (µs).
    pub sched_latency_us: f64,
    /// Scheduling attempts until bound (1 = placed on first try).
    pub attempts: u32,
    /// Attributed energy (J).
    pub joules: f64,
    /// Queueing delay between arrival and binding (s).
    pub wait_s: f64,
}

/// One kernel event, for audit/debug and the monotonicity property
/// tests (`at_s` is non-decreasing over the log).
#[derive(Debug, Clone, PartialEq)]
pub struct EventRecord {
    pub at_s: f64,
    pub kind: &'static str,
}

/// One autoscaler action, as applied to the kernel.
#[derive(Debug, Clone, PartialEq)]
pub struct ScalingRecord {
    /// Decision time.
    pub at_s: f64,
    /// `"scale-out"` (provision), `"scale-in"` (deactivate) or
    /// `"activate"` (scheduled rejoin).
    pub kind: &'static str,
    pub node: usize,
    /// When the action takes effect (scale-out: decision time +
    /// provisioning delay; others: the emitted event's time).
    pub effective_at_s: f64,
}

/// One point of the node-count timeline (sampled at t = 0 and after
/// every membership change).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NodeCountSample {
    pub at_s: f64,
    /// Nodes currently Ready (schedulable capacity).
    pub ready_nodes: usize,
    /// Nodes that exist, Ready or not (provisioned but still booting,
    /// failed, scaled in).
    pub total_nodes: usize,
}

/// The outcome of one simulated run.
#[derive(Debug, Clone)]
pub struct RunResult {
    pub records: Vec<PodRecord>,
    pub meter: EnergyMeter,
    /// Pods that never became schedulable.
    pub unschedulable: Vec<PodId>,
    /// Virtual time at which the last pod finished.
    pub makespan_s: f64,
    /// PJRT scoring fallbacks observed (failure injection).
    pub pjrt_fallbacks: u64,
    /// Time-ordered kernel event log.
    pub events: Vec<EventRecord>,
    /// Autoscaler actions, in decision order (empty without a policy).
    pub scaling: Vec<ScalingRecord>,
    /// Ready/total node counts over the run (sampled at t = 0 and at
    /// every membership change; batch mode carries just the t = 0
    /// sample of its fixed cluster).
    pub node_timeline: Vec<NodeCountSample>,
    /// Scheduling cycles that actually drained the pending queue.
    pub cycles_run: u64,
    /// Scheduling cycles short-circuited by the no-change guard
    /// (`cycles_run + cycles_skipped` = cycles fired; the guard is
    /// structural today, so this stays 0 unless a future cycle source
    /// fires without a preceding mutation or arrival).
    pub cycles_skipped: u64,
}

impl RunResult {
    /// Mean per-pod energy (kJ) for one scheduler — Table VI's unit.
    pub fn mean_kj(&self, kind: SchedulerKind) -> f64 {
        self.meter.mean_kj_per_pod(kind)
    }

    /// Unattributed node-idle energy (kJ) — powered-on capacity no pod
    /// accounted for. This is what scale-in saves.
    pub fn idle_kj(&self) -> f64 {
        self.meter.idle_kj()
    }

    /// Fraction of completed pods of `kind` whose queue wait exceeded
    /// `slo_wait_s` (0.0 when none completed).
    pub fn slo_miss_fraction(&self, kind: SchedulerKind, slo_wait_s: f64) -> f64 {
        let (miss, n) = self
            .records
            .iter()
            .filter(|r| r.scheduler == kind)
            .fold((0usize, 0usize), |(m, n), r| {
                (m + usize::from(r.wait_s > slo_wait_s), n + 1)
            });
        if n == 0 {
            0.0
        } else {
            miss as f64 / n as f64
        }
    }

    /// Time-weighted mean Ready-node count over `[0, makespan]` (0.0
    /// when no timeline was sampled — the batch oracle).
    pub fn mean_ready_nodes(&self) -> f64 {
        let end = self.makespan_s;
        if self.node_timeline.is_empty() || end <= 0.0 {
            return self
                .node_timeline
                .first()
                .map_or(0.0, |s| s.ready_nodes as f64);
        }
        let mut area = 0.0;
        for (i, s) in self.node_timeline.iter().enumerate() {
            let from = s.at_s.min(end);
            let to = self
                .node_timeline
                .get(i + 1)
                .map_or(end, |n| n.at_s)
                .min(end);
            if to > from {
                area += s.ready_nodes as f64 * (to - from);
            }
        }
        area / end
    }

    /// Peak Ready-node count over the run.
    pub fn peak_ready_nodes(&self) -> usize {
        self.node_timeline
            .iter()
            .map(|s| s.ready_nodes)
            .max()
            .unwrap_or(0)
    }

    /// Scaling actions of one kind (`"scale-out"` / `"scale-in"` /
    /// `"activate"`).
    pub fn scaling_count(&self, kind: &str) -> usize {
        self.scaling.iter().filter(|s| s.kind == kind).count()
    }

    /// Mean scheduling latency (ms) for one scheduler — the paper's
    /// "scheduling time" metric.
    pub fn mean_sched_ms(&self, kind: SchedulerKind) -> f64 {
        let l: Vec<f64> = self
            .records
            .iter()
            .filter(|r| r.scheduler == kind)
            .map(|r| r.sched_latency_us / 1000.0)
            .collect();
        if l.is_empty() {
            0.0
        } else {
            l.iter().sum::<f64>() / l.len() as f64
        }
    }

    /// Per-pod queue-wait distribution (s) for one scheduler — the
    /// "slight scheduling latency" cost the paper trades for energy.
    pub fn queue_wait_summary(&self, kind: SchedulerKind) -> Summary {
        let w: Vec<f64> = self
            .records
            .iter()
            .filter(|r| r.scheduler == kind)
            .map(|r| r.wait_s)
            .collect();
        Summary::of(&w)
    }

    /// Per-pod cumulative scheduling-latency distribution (ms).
    pub fn sched_latency_summary_ms(&self, kind: SchedulerKind) -> Summary {
        let l: Vec<f64> = self
            .records
            .iter()
            .filter(|r| r.scheduler == kind)
            .map(|r| r.sched_latency_us / 1000.0)
            .collect();
        Summary::of(&l)
    }

    /// Mean scheduling attempts per placed pod (1.0 = never queued
    /// behind capacity).
    pub fn mean_attempts(&self, kind: SchedulerKind) -> f64 {
        let a: Vec<f64> = self
            .records
            .iter()
            .filter(|r| r.scheduler == kind)
            .map(|r| r.attempts as f64)
            .collect();
        Summary::of(&a).mean
    }

    /// Allocation histogram per node category for one scheduler (§V.D).
    /// Ordered map: the derived report rows render in category order.
    pub fn allocations(
        &self,
        kind: SchedulerKind,
    ) -> BTreeMap<NodeCategory, u32> {
        let mut out = BTreeMap::new();
        for r in self.records.iter().filter(|r| r.scheduler == kind) {
            *out.entry(r.node_category).or_insert(0) += 1;
        }
        out
    }

    /// Mean completion time (s) per workload class for one scheduler.
    pub fn completion_by_class(
        &self,
        kind: SchedulerKind,
    ) -> BTreeMap<WorkloadClass, f64> {
        let mut sums: BTreeMap<WorkloadClass, (f64, usize)> = BTreeMap::new();
        for r in self.records.iter().filter(|r| r.scheduler == kind) {
            let e = sums.entry(r.class).or_insert((0.0, 0));
            e.0 += r.finish_s - r.arrival_s;
            e.1 += 1;
        }
        sums.into_iter()
            .map(|(k, (s, n))| (k, s / n as f64))
            .collect()
    }

    /// Node-allocation efficiency (Table IV): fraction of pods placed on
    /// the node category that minimizes their energy (the "optimal"
    /// energy allocation is Category A whenever it fits).
    pub fn allocation_efficiency(&self, kind: SchedulerKind) -> f64 {
        let recs: Vec<_> = self
            .records
            .iter()
            .filter(|r| r.scheduler == kind)
            .collect();
        if recs.is_empty() {
            return 0.0;
        }
        let on_a = recs
            .iter()
            .filter(|r| r.node_category == NodeCategory::A)
            .count();
        on_a as f64 / recs.len() as f64
    }
}
