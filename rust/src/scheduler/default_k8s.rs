//! The baseline: default kube-scheduler scoring, as documented.
//!
//! The default scheduler filters by NodeResourcesFit, then scores with
//! (among others) `NodeResourcesLeastAllocated` and
//! `NodeResourcesBalancedAllocation`, both 0–100, averaged here with
//! equal weight — the heuristic spread-by-least-requested behaviour the
//! paper contrasts against ([14, 15]). Ties are broken uniformly at
//! random, as in kube-scheduler's `selectHost`; the RNG is seeded for
//! replicable experiments.
//!
//! The scoring math lives in `framework::plugins` (the canonical
//! plugin implementations, clamped against over-requests);
//! this monolith delegates to it and is pinned bit-identical to the
//! framework's `default-k8s` profile by the differential property
//! suite.

use std::time::Instant;

use crate::cluster::{ClusterState, Pod};
use crate::framework::{balanced_allocation_score, least_allocated_score};
use crate::util::rng::Rng;

use super::{Scheduler, SchedulingDecision};

pub struct DefaultK8sScheduler {
    rng: Rng,
}

impl DefaultK8sScheduler {
    pub fn new(seed: u64) -> Self {
        Self { rng: Rng::seed_from_u64(seed) }
    }
}

impl Scheduler for DefaultK8sScheduler {
    fn name(&self) -> &str {
        "default-k8s"
    }

    fn schedule(
        &mut self,
        state: &ClusterState,
        pod: &Pod,
    ) -> SchedulingDecision {
        let t0 = Instant::now();
        let feasible = state.feasible_nodes(pod.requests);
        let scores: Vec<(usize, f64)> = feasible
            .iter()
            .map(|&id| {
                let s = (least_allocated_score(state, id, pod)
                    + balanced_allocation_score(state, id, pod))
                    / 2.0;
                (id, s)
            })
            .collect();

        // Highest score wins; ties broken uniformly at random.
        let node = {
            let best = scores
                .iter()
                .map(|&(_, s)| s)
                .fold(f64::NEG_INFINITY, f64::max);
            let top: Vec<usize> = scores
                .iter()
                .filter(|&&(_, s)| (s - best).abs() < 1e-9)
                .map(|&(id, _)| id)
                .collect();
            if top.is_empty() {
                None
            } else {
                Some(top[self.rng.below(top.len())])
            }
        };

        SchedulingDecision { node, latency: t0.elapsed(), scores }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ClusterConfig, SchedulerKind};
    use crate::workload::WorkloadClass;

    fn state() -> ClusterState {
        ClusterState::from_config(&ClusterConfig::paper_default())
    }

    fn pod(id: u64, class: WorkloadClass) -> Pod {
        Pod::new(id, class, SchedulerKind::DefaultK8s, 0.0, 1)
    }

    #[test]
    fn spreads_to_least_allocated() {
        let mut s = state();
        let mut sched = DefaultK8sScheduler::new(0);
        // Load node 3 (B) heavily; the next pod must not land there
        // while emptier same-shape nodes exist.
        s.bind(&pod(1, WorkloadClass::Complex), 3, 0.0).unwrap();
        s.bind(&pod(2, WorkloadClass::Medium), 3, 0.0).unwrap();
        let d = sched.schedule(&s, &pod(3, WorkloadClass::Light));
        assert_ne!(d.node, Some(3));
    }

    #[test]
    fn unschedulable_when_full() {
        let mut s = state();
        let mut sched = DefaultK8sScheduler::new(0);
        // Fill every node's memory with synthetic hog pods.
        for id in 0..s.nodes().len() {
            let mut hog = pod(100 + id as u64, WorkloadClass::Light);
            hog.requests.cpu_millis = s.free_cpu(id);
            hog.requests.memory_mib = s.free_memory(id);
            s.bind(&hog, id, 0.0).unwrap();
        }
        let d = sched.schedule(&s, &pod(1, WorkloadClass::Light));
        assert_eq!(d.node, None);
        assert!(d.scores.is_empty());
    }

    #[test]
    fn deterministic_under_same_seed() {
        let s = state();
        let mut a = DefaultK8sScheduler::new(5);
        let mut b = DefaultK8sScheduler::new(5);
        for i in 0..10 {
            let p = pod(i, WorkloadClass::Light);
            assert_eq!(a.schedule(&s, &p).node, b.schedule(&s, &p).node);
        }
    }

    #[test]
    fn scores_cover_all_feasible_nodes() {
        let s = state();
        let mut sched = DefaultK8sScheduler::new(0);
        let d = sched.schedule(&s, &pod(1, WorkloadClass::Light));
        assert_eq!(d.scores.len(), 7);
        assert!(d.node.is_some());
        for &(_, score) in &d.scores {
            assert!((0.0..=100.0).contains(&score));
        }
    }
}
