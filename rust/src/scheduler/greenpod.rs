//! GreenPodScheduler — the paper's TOPSIS-based multi-criteria
//! scheduler (§III).
//!
//! Pipeline per pod (§III.A "multi-stage decision pipeline"):
//! 1. **filter** — NodeResourcesFit + readiness (candidate set);
//! 2. **decision matrix** — one [`NodeEstimate`] row per candidate
//!    across the paper's five criteria;
//! 3. **scoring** — TOPSIS closeness via the configured backend:
//!    pure-Rust [`crate::mcda`] (default), the AOT Pallas kernel through
//!    PJRT, or an alternate MCDA method (ablations);
//! 4. **select** — highest closeness coefficient wins (deterministic
//!    lowest-index tie-break).
//!
//! If the PJRT backend errors at scoring time (artifact missing, client
//! failure) the scheduler degrades to the pure-Rust path and counts the
//! fallback — the failure-injection tests assert this.

use std::time::Instant;

use crate::cluster::{ClusterState, Pod};
use crate::config::{WeightingScheme, NUM_CRITERIA};
use crate::mcda::{argmax, DecisionProblem, McdaMethod};
use crate::runtime::PjrtTopsisEngine;

use super::{AdaptiveWeighting, Estimator, Scheduler, SchedulingDecision};

/// How GreenPod turns a decision matrix into scores.
pub enum ScoringBackend {
    /// Pure-Rust MCDA (`McdaMethod::Topsis` is the paper's method; other
    /// methods are ablation baselines).
    Rust(McdaMethod),
    /// The AOT-compiled fused Pallas kernel, executed via PJRT.
    Pjrt(Box<PjrtTopsisEngine>),
}

pub struct GreenPodScheduler {
    estimator: Estimator,
    scheme: WeightingScheme,
    backend: ScoringBackend,
    /// Optional adaptive weighting (paper §III.A); replaces the static
    /// scheme's weights when set.
    adaptive: Option<AdaptiveWeighting>,
    /// PJRT failures that fell back to the Rust path.
    pub pjrt_fallbacks: u64,
}

impl GreenPodScheduler {
    pub fn new(estimator: Estimator, scheme: WeightingScheme) -> Self {
        Self {
            estimator,
            scheme,
            backend: ScoringBackend::Rust(McdaMethod::Topsis),
            adaptive: None,
            pjrt_fallbacks: 0,
        }
    }

    pub fn with_backend(mut self, backend: ScoringBackend) -> Self {
        self.backend = backend;
        self
    }

    pub fn with_adaptive(mut self, adaptive: AdaptiveWeighting) -> Self {
        self.adaptive = Some(adaptive);
        self
    }

    pub fn scheme(&self) -> WeightingScheme {
        self.scheme
    }

    pub fn set_scheme(&mut self, scheme: WeightingScheme) {
        self.scheme = scheme;
    }

    pub fn estimator_mut(&mut self) -> &mut Estimator {
        &mut self.estimator
    }

    /// The weights used for this decision (static scheme or adaptive).
    fn effective_weights(&self, state: &ClusterState) -> [f64; NUM_CRITERIA] {
        match &self.adaptive {
            Some(a) => a.weights(state, self.scheme),
            None => self.scheme.weights(),
        }
    }

    /// Build the 5-criteria decision problem over the candidate set
    /// (delegates to the canonical framework builder, shared with
    /// [`crate::framework::McdaScorePlugin`]).
    pub fn decision_problem(
        &self,
        state: &ClusterState,
        pod: &Pod,
        candidates: &[usize],
    ) -> DecisionProblem {
        crate::framework::build_decision_problem(
            &self.estimator,
            self.effective_weights(state),
            state,
            pod,
            candidates,
        )
    }

    fn score(&mut self, problem: &DecisionProblem) -> Vec<f64> {
        match &mut self.backend {
            ScoringBackend::Rust(method) => method.scores(problem),
            ScoringBackend::Pjrt(engine) => {
                match engine.closeness(problem) {
                    Ok(s) => s,
                    Err(_) => {
                        // Degrade gracefully: the artifact math and the
                        // Rust math are the same TOPSIS.
                        self.pjrt_fallbacks += 1;
                        McdaMethod::Topsis.scores(problem)
                    }
                }
            }
        }
    }
}

impl Scheduler for GreenPodScheduler {
    fn name(&self) -> &str {
        "greenpod-topsis"
    }

    fn schedule(
        &mut self,
        state: &ClusterState,
        pod: &Pod,
    ) -> SchedulingDecision {
        let t0 = Instant::now();
        // Stage 1: filter.
        let candidates = state.feasible_nodes(pod.requests);
        if candidates.is_empty() {
            return SchedulingDecision {
                node: None,
                latency: t0.elapsed(),
                scores: Vec::new(),
            };
        }
        // Stage 2+3: decision matrix and MCDA scoring.
        let problem = self.decision_problem(state, pod, &candidates);
        let scores = self.score(&problem);
        // Stage 4: select.
        let node = argmax(&scores).map(|i| candidates[i]);
        SchedulingDecision {
            node,
            latency: t0.elapsed(),
            scores: candidates.into_iter().zip(scores).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::NodeCategory;
    use crate::config::{ClusterConfig, EnergyModelConfig, SchedulerKind};
    use crate::workload::WorkloadClass;

    fn scheduler(scheme: WeightingScheme) -> GreenPodScheduler {
        GreenPodScheduler::new(
            Estimator::with_defaults(EnergyModelConfig::default()),
            scheme,
        )
    }

    fn state() -> ClusterState {
        ClusterState::from_config(&ClusterConfig::paper_default())
    }

    fn pod(id: u64, class: WorkloadClass) -> Pod {
        Pod::new(id, class, SchedulerKind::Topsis, 0.0, 2)
    }

    #[test]
    fn energy_centric_prefers_category_a() {
        let s = state();
        let mut sched = scheduler(WeightingScheme::EnergyCentric);
        let d = sched.schedule(&s, &pod(1, WorkloadClass::Medium));
        let cat = s.node(d.node.unwrap()).category;
        assert_eq!(cat, NodeCategory::A, "scores: {:?}", d.scores);
    }

    #[test]
    fn performance_centric_prefers_fast_nodes() {
        let s = state();
        let mut sched = scheduler(WeightingScheme::PerformanceCentric);
        let d = sched.schedule(&s, &pod(1, WorkloadClass::Medium));
        let node = s.node(d.node.unwrap());
        // B (1.0) or C (1.1) — never the slow A machines.
        assert!(node.speed_factor >= 1.0, "chose {:?}", node.name);
    }

    #[test]
    fn respects_filter() {
        let mut s = state();
        let mut sched = scheduler(WeightingScheme::EnergyCentric);
        // Exhaust all three A nodes' memory so they are infeasible.
        for id in [0usize, 1, 2] {
            let mut hog = pod(50 + id as u64, WorkloadClass::Light);
            hog.requests.cpu_millis = 100;
            hog.requests.memory_mib = s.free_memory(id) - 256;
            s.bind(&hog, id, 0.0).unwrap();
        }
        let d = sched.schedule(&s, &pod(1, WorkloadClass::Complex));
        let cat = s.node(d.node.unwrap()).category;
        assert_ne!(cat, NodeCategory::A);
    }

    #[test]
    fn unschedulable_on_full_cluster() {
        let mut s = state();
        let mut sched = scheduler(WeightingScheme::General);
        for id in 0..s.nodes().len() {
            let mut hog = pod(80 + id as u64, WorkloadClass::Light);
            hog.requests.cpu_millis = s.free_cpu(id);
            hog.requests.memory_mib = s.free_memory(id);
            s.bind(&hog, id, 0.0).unwrap();
        }
        let d = sched.schedule(&s, &pod(1, WorkloadClass::Light));
        assert_eq!(d.node, None);
        assert!(d.scores.is_empty());
    }

    #[test]
    fn scores_one_per_candidate_in_unit_interval() {
        let s = state();
        let mut sched = scheduler(WeightingScheme::General);
        let d = sched.schedule(&s, &pod(1, WorkloadClass::Light));
        assert_eq!(d.scores.len(), 7);
        for &(_, c) in &d.scores {
            assert!((0.0..=1.0 + 1e-9).contains(&c), "{:?}", d.scores);
        }
    }

    #[test]
    fn deterministic_decisions() {
        let s = state();
        let mut a = scheduler(WeightingScheme::EnergyCentric);
        let mut b = scheduler(WeightingScheme::EnergyCentric);
        for i in 0..5 {
            let p = pod(i, WorkloadClass::Light);
            assert_eq!(a.schedule(&s, &p).node, b.schedule(&s, &p).node);
        }
    }

    #[test]
    fn saw_backend_also_picks_efficient_nodes() {
        let s = state();
        let mut sched = scheduler(WeightingScheme::EnergyCentric)
            .with_backend(ScoringBackend::Rust(McdaMethod::Saw));
        let d = sched.schedule(&s, &pod(1, WorkloadClass::Medium));
        assert!(d.node.is_some());
    }
}
