//! Adaptive weighting — the paper's "adaptive weighting module
//! dynamically adjusts criteria weights based on system conditions"
//! (§III.A), realized as load-dependent profile interpolation.
//!
//! The evaluation (§V.C) observes that energy-centric weighting is best
//! at low/medium load while high competition "may require hybrid
//! approaches balancing energy awareness with resource efficiency" —
//! exactly the hybrid this module implements: as cluster requested-CPU
//! utilization crosses `lo..hi`, the active profile's weights are
//! blended toward the resource-efficient profile.


use crate::cluster::ClusterState;
use crate::config::{WeightingScheme, NUM_CRITERIA};

#[derive(Debug, Clone)]
pub struct AdaptiveWeighting {
    /// Utilization below which the base profile applies unchanged.
    pub lo: f64,
    /// Utilization above which the hybrid target applies fully.
    pub hi: f64,
    /// Profile blended toward under load.
    pub target: WeightingScheme,
}

impl Default for AdaptiveWeighting {
    fn default() -> Self {
        Self {
            lo: 0.45,
            hi: 0.80,
            target: WeightingScheme::ResourceEfficient,
        }
    }
}

impl AdaptiveWeighting {
    /// Blend factor in [0,1] for the current cluster load.
    pub fn blend(&self, utilization: f64) -> f64 {
        if self.hi <= self.lo {
            return if utilization >= self.hi { 1.0 } else { 0.0 };
        }
        ((utilization - self.lo) / (self.hi - self.lo)).clamp(0.0, 1.0)
    }

    /// Effective weights for `base` at the current cluster state.
    pub fn weights(
        &self,
        state: &ClusterState,
        base: WeightingScheme,
    ) -> [f64; NUM_CRITERIA] {
        let t = self.blend(state.total_cpu_utilization());
        let a = base.weights();
        let b = self.target.weights();
        let mut out = [0.0; NUM_CRITERIA];
        for i in 0..NUM_CRITERIA {
            out[i] = (1.0 - t) * a[i] + t * b[i];
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{ClusterState, Pod};
    use crate::config::{ClusterConfig, SchedulerKind};
    use crate::workload::WorkloadClass;

    #[test]
    fn blend_saturates() {
        let a = AdaptiveWeighting::default();
        assert_eq!(a.blend(0.0), 0.0);
        assert_eq!(a.blend(0.45), 0.0);
        assert_eq!(a.blend(1.0), 1.0);
        let mid = a.blend(0.625);
        assert!(mid > 0.49 && mid < 0.52);
    }

    #[test]
    fn weights_remain_on_simplex() {
        let a = AdaptiveWeighting::default();
        let mut s = ClusterState::from_config(&ClusterConfig::paper_default());
        // Load the cluster past `lo` (16 vCPU total; 8 complex pods
        // = 8 vCPU requested = 50% utilization).
        for (i, node) in [(0u64, 0usize), (1, 1), (2, 2), (3, 3),
                          (4, 4), (5, 5), (6, 5), (7, 5)] {
            let p = Pod::new(i, WorkloadClass::Complex,
                             SchedulerKind::Topsis, 0.0, 1);
            s.bind(&p, node, 0.0).unwrap();
        }
        let w = a.weights(&s, WeightingScheme::EnergyCentric);
        let sum: f64 = w.iter().sum();
        assert!((sum - 1.0).abs() < 1e-9, "{w:?}");
        // Under load the energy weight moves toward resource-efficient's.
        let pure = WeightingScheme::EnergyCentric.weights();
        assert!(w[1] < pure[1]);
    }

    #[test]
    fn empty_cluster_keeps_base_profile() {
        let a = AdaptiveWeighting::default();
        let s = ClusterState::from_config(&ClusterConfig::paper_default());
        let w = a.weights(&s, WeightingScheme::EnergyCentric);
        assert_eq!(w, WeightingScheme::EnergyCentric.weights());
    }

    #[test]
    fn degenerate_thresholds() {
        let a = AdaptiveWeighting {
            lo: 0.5,
            hi: 0.5,
            target: WeightingScheme::General,
        };
        assert_eq!(a.blend(0.49), 0.0);
        assert_eq!(a.blend(0.51), 1.0);
    }
}
