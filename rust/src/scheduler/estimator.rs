//! Execution-time and energy estimators — the predictive half of
//! GreenPod's "energy profiling module" (§III.A).
//!
//! Estimates feed the decision matrix; the simulation then *realizes*
//! execution with the same physical model plus contention dynamics, so
//! estimates are honest (same units, same power model) but not
//! clairvoyant (contention evolves after placement).

use crate::cluster::{ClusterState, Node, NodeId, Pod};
use crate::config::EnergyModelConfig;
use crate::energy::pod_power_watts;

/// Calibrated cost of one *light-class epoch* on a speed-1.0 node with
/// one full vCPU, in seconds. The default is the PJRT-measured value on
/// the reference machine; `greenpod` recalibrates at startup when the
/// runtime is available (see `LinRegRunner::calibrate`).
pub const DEFAULT_LIGHT_EPOCH_SECS: f64 = 0.35;

/// One candidate node's predicted metrics — a decision-matrix row.
#[derive(Debug, Clone, Copy)]
pub struct NodeEstimate {
    pub node: NodeId,
    /// Predicted execution time (s) — cost criterion 1.
    pub exec_time_s: f64,
    /// Predicted energy (J) — cost criterion 2.
    pub energy_j: f64,
    /// Free-CPU fraction after placement — benefit criterion 3
    /// ("processing core availability"; a fraction so that big nodes do
    /// not dwarf the other criteria by sheer absolute size).
    pub free_cpu_frac: f64,
    /// Free-memory fraction after placement — benefit criterion 4.
    pub free_mem_frac: f64,
    /// Resource balance after placement — benefit criterion 5.
    pub balance: f64,
}

/// Estimator with a calibrated work-unit cost.
#[derive(Debug, Clone)]
pub struct Estimator {
    energy_cfg: EnergyModelConfig,
    /// Seconds per light-epoch on a speed-1 node at 1 vCPU.
    light_epoch_secs: f64,
    /// Contention coefficient β: estimated slowdown = 1 + β·util.
    contention_beta: f64,
}

impl Estimator {
    pub fn new(
        energy_cfg: EnergyModelConfig,
        light_epoch_secs: f64,
        contention_beta: f64,
    ) -> Self {
        Self { energy_cfg, light_epoch_secs, contention_beta }
    }

    pub fn with_defaults(energy_cfg: EnergyModelConfig) -> Self {
        Self::new(energy_cfg, DEFAULT_LIGHT_EPOCH_SECS, 0.35)
    }

    pub fn light_epoch_secs(&self) -> f64 {
        self.light_epoch_secs
    }

    /// Recalibrate the work-unit cost (from a PJRT measurement).
    pub fn set_light_epoch_secs(&mut self, secs: f64) {
        if secs.is_finite() && secs > 0.0 {
            self.light_epoch_secs = secs;
        }
    }

    /// Pure compute time of `pod` on `node` with no contention (s).
    pub fn base_exec_time(&self, node: &Node, pod: &Pod) -> f64 {
        let work = pod.class.work_per_epoch() * pod.epochs as f64;
        let cores = pod.requests.cpu_millis as f64 / 1000.0;
        self.light_epoch_secs * work / (node.speed_factor * cores)
    }

    /// Predicted execution time on `node` given its current utilization.
    pub fn exec_time(
        &self,
        state: &ClusterState,
        node: &Node,
        pod: &Pod,
    ) -> f64 {
        let slowdown = 1.0 + self.contention_beta * state.cpu_utilization(node.id);
        self.base_exec_time(node, pod) * slowdown
    }

    /// Predicted energy (J) for running `pod` on `node`.
    pub fn energy(
        &self,
        state: &ClusterState,
        node: &Node,
        pod: &Pod,
    ) -> f64 {
        let share =
            pod.requests.cpu_millis as f64 / node.cpu_millis as f64;
        pod_power_watts(&self.energy_cfg, node, share)
            * self.exec_time(state, node, pod)
    }

    /// Full decision-matrix row for placing `pod` on `node`.
    pub fn estimate(
        &self,
        state: &ClusterState,
        node: &Node,
        pod: &Pod,
    ) -> NodeEstimate {
        let exec_time_s = self.exec_time(state, node, pod);
        let energy_j = {
            let share =
                pod.requests.cpu_millis as f64 / node.cpu_millis as f64;
            pod_power_watts(&self.energy_cfg, node, share) * exec_time_s
        };
        let free_cpu_after =
            state.free_cpu(node.id).saturating_sub(pod.requests.cpu_millis);
        let free_mem_after = state
            .free_memory(node.id)
            .saturating_sub(pod.requests.memory_mib);
        let cpu_util_after = 1.0
            - free_cpu_after as f64 / node.cpu_millis as f64;
        let mem_util_after = 1.0
            - free_mem_after as f64 / node.memory_mib as f64;
        NodeEstimate {
            node: node.id,
            exec_time_s,
            energy_j,
            free_cpu_frac: 1.0 - cpu_util_after,
            free_mem_frac: 1.0 - mem_util_after,
            balance: 1.0 - (cpu_util_after - mem_util_after).abs(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ClusterConfig, SchedulerKind};
    use crate::workload::WorkloadClass;

    fn setup() -> (ClusterState, Estimator) {
        let state = ClusterState::from_config(&ClusterConfig::paper_default());
        let est = Estimator::with_defaults(EnergyModelConfig::default());
        (state, est)
    }

    fn pod(class: WorkloadClass) -> Pod {
        Pod::new(0, class, SchedulerKind::Topsis, 0.0, 2)
    }

    #[test]
    fn faster_node_lower_exec_time() {
        let (state, est) = setup();
        let p = pod(WorkloadClass::Medium);
        // Node 0 = A (speed 0.7), node 3 = B (speed 1.0).
        let t_a = est.exec_time(&state, state.node(0), &p);
        let t_b = est.exec_time(&state, state.node(3), &p);
        assert!(t_a > t_b);
    }

    #[test]
    fn efficient_node_lower_energy_despite_slower() {
        let (state, est) = setup();
        let p = pod(WorkloadClass::Medium);
        // A (power 0.30, speed 0.7) vs C (power 2.6, speed 1.1): the
        // speed gap (~1.6x) is far smaller than the power gap (~8.7x),
        // so A wins on energy — the heterogeneity driving the paper.
        let e_a = est.energy(&state, state.node(0), &p);
        let e_c = est.energy(&state, state.node(5), &p);
        assert!(e_a < e_c, "A energy {e_a} !< C energy {e_c}");
    }

    #[test]
    fn contention_raises_estimate() {
        let (mut state, est) = setup();
        let p = pod(WorkloadClass::Light);
        let before = est.exec_time(&state, state.node(0), &p);
        let filler = Pod::new(9, WorkloadClass::Complex,
                              SchedulerKind::DefaultK8s, 0.0, 1);
        state.bind(&filler, 0, 0.0).unwrap();
        let after = est.exec_time(&state, state.node(0), &p);
        assert!(after > before);
    }

    #[test]
    fn estimate_row_fields_sane() {
        let (state, est) = setup();
        let p = pod(WorkloadClass::Complex);
        let row = est.estimate(&state, state.node(5), &p); // C node
        assert!(row.exec_time_s > 0.0);
        assert!(row.energy_j > 0.0);
        assert!((row.free_cpu_frac - 0.75).abs() < 1e-9); // 3 of 4 vCPU
        assert!((row.free_mem_frac - 14.0 / 16.0).abs() < 1e-9);
        assert!((0.0..=1.0).contains(&row.balance));
    }

    #[test]
    fn more_epochs_more_time_and_energy() {
        let (state, est) = setup();
        let mut p = pod(WorkloadClass::Light);
        let t1 = est.exec_time(&state, state.node(0), &p);
        let e1 = est.energy(&state, state.node(0), &p);
        p.epochs = 8;
        let t4 = est.exec_time(&state, state.node(0), &p);
        let e4 = est.energy(&state, state.node(0), &p);
        assert!((t4 / t1 - 4.0).abs() < 1e-9);
        assert!((e4 / e1 - 4.0).abs() < 1e-9);
    }
}
