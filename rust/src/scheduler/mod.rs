//! Scheduler abstractions — the trait every profile is driven through,
//! plus the estimator feeding the decision matrix.
//!
//! The concrete scheduler implementations live in [`crate::framework`]:
//! kube-style Filter/Score plugins composed into weighted profiles and
//! driven by `FrameworkScheduler` through the [`Scheduler`] trait. The
//! legacy monolith structs (`GreenPodScheduler`, `DefaultK8sScheduler`)
//! are retired — the framework profiles `greenpod` and `default-k8s`
//! were pinned bit-identical to them for two PRs and are now the
//! canonical (and only) formulations; `ProfileRegistry` still accepts
//! the old `greenpod-topsis` name as a deprecated alias.
//!
//! * [`estimator`] — per-(node, pod) execution-time and energy
//!   predictions feeding the decision matrix.
//! * [`AdaptiveWeighting`] — the paper's "adaptive weighting module"
//!   (§III.A): interpolates between profiles based on cluster load.
//! * [`ScoringBackend`] — how an MCDA scorer turns a decision matrix
//!   into scores: pure-Rust MCDA or the AOT Pallas kernel via PJRT.

mod adaptive;
pub mod estimator;

pub use adaptive::AdaptiveWeighting;
pub use estimator::{Estimator, NodeEstimate, DEFAULT_LIGHT_EPOCH_SECS};

use std::time::Duration;

use crate::cluster::{ClusterState, NodeId, Pod};
use crate::mcda::McdaMethod;
// greenpod-lint: allow(kernel-imports-tool) reason="ScoringBackend::Pjrt wraps the deterministic compiled-TOPSIS engine; scheduling stays bit-reproducible either way"
use crate::runtime::PjrtTopsisEngine;

/// How an MCDA scorer turns a decision matrix into scores.
pub enum ScoringBackend {
    /// Pure-Rust MCDA (`McdaMethod::Topsis` is the paper's method; other
    /// methods are ablation baselines).
    Rust(McdaMethod),
    /// The AOT-compiled fused Pallas kernel, executed via PJRT.
    Pjrt(Box<PjrtTopsisEngine>),
}

/// Outcome of one scheduling decision.
#[derive(Debug, Clone)]
pub struct SchedulingDecision {
    /// Chosen node, or `None` if the pod is unschedulable right now.
    pub node: Option<NodeId>,
    /// Wall-clock the decision took (the paper's "scheduling time" metric).
    pub latency: Duration,
    /// Per-candidate scores (node id, score), for logging/§V.D analysis.
    pub scores: Vec<(NodeId, f64)>,
}

/// A pod scheduler: stateless with respect to the cluster (all cluster
/// knowledge flows in through `state`), stateful for internal RNG /
/// scoring backends.
pub trait Scheduler {
    /// Profile/scheduler name, emitted in `ApiEvent::Bound` JSONL so
    /// traces are attributable when multiple profiles run at once.
    fn name(&self) -> &str;

    /// Pick a node for `pod` given the current cluster state.
    fn schedule(
        &mut self,
        state: &ClusterState,
        pod: &Pod,
    ) -> SchedulingDecision;

    /// Time-aware entry point: drivers with a virtual clock — the
    /// discrete-event engine, the serve loop — pass the scheduling
    /// cycle's timestamp so time-varying policies (the carbon-aware
    /// profile's intensity lookup) can read it. Schedulers that do not
    /// consume time fall through to [`Scheduler::schedule`], so the
    /// default keeps every pre-clock implementation bit-identical.
    fn schedule_at(
        &mut self,
        state: &ClusterState,
        pod: &Pod,
        now_s: f64,
    ) -> SchedulingDecision {
        let _ = now_s;
        self.schedule(state, pod)
    }
}
