//! Schedulers — the paper's contribution lives here.
//!
//! * [`GreenPodScheduler`] — the TOPSIS-based multi-criteria scheduler:
//!   filter → decision matrix (5 criteria) → MCDA scoring → bind target.
//! * [`DefaultK8sScheduler`] — the baseline: the documented default
//!   kube-scheduler scoring path (LeastAllocated + BalancedAllocation).
//! * [`estimator`] — per-(node, pod) execution-time and energy
//!   predictions feeding the decision matrix.
//! * [`AdaptiveWeighting`] — the paper's "adaptive weighting module"
//!   (§III.A): interpolates between profiles based on cluster load.
//!
//! Both schedulers implement [`Scheduler`] and are driven identically by
//! the simulation engine and the serve loop.
//!
//! These two structs are the *legacy monolith* formulations. The
//! drivers now compose the same pipelines from
//! [`crate::framework`] extension-point plugins (profiles `greenpod`
//! and `default-k8s`); the monoliths stay as the executable reference
//! the differential properties pin the framework against, and they
//! delegate their scoring math to the canonical framework
//! implementations so the two paths cannot drift.

mod adaptive;
mod default_k8s;
pub mod estimator;
mod greenpod;

pub use adaptive::AdaptiveWeighting;
pub use default_k8s::DefaultK8sScheduler;
pub use estimator::{Estimator, NodeEstimate, DEFAULT_LIGHT_EPOCH_SECS};
pub use greenpod::{GreenPodScheduler, ScoringBackend};

use std::time::Duration;

use crate::cluster::{ClusterState, NodeId, Pod};

/// Outcome of one scheduling decision.
#[derive(Debug, Clone)]
pub struct SchedulingDecision {
    /// Chosen node, or `None` if the pod is unschedulable right now.
    pub node: Option<NodeId>,
    /// Wall-clock the decision took (the paper's "scheduling time" metric).
    pub latency: Duration,
    /// Per-candidate scores (node id, score), for logging/§V.D analysis.
    pub scores: Vec<(NodeId, f64)>,
}

/// A pod scheduler: stateless with respect to the cluster (all cluster
/// knowledge flows in through `state`), stateful for internal RNG /
/// scoring backends.
pub trait Scheduler {
    /// Profile/scheduler name, emitted in `ApiEvent::Bound` JSONL so
    /// traces are attributable when multiple profiles run at once.
    fn name(&self) -> &str;

    /// Pick a node for `pod` given the current cluster state.
    fn schedule(
        &mut self,
        state: &ClusterState,
        pod: &Pod,
    ) -> SchedulingDecision;

    /// Time-aware entry point: drivers with a virtual clock — the
    /// discrete-event engine, the serve loop — pass the scheduling
    /// cycle's timestamp so time-varying policies (the carbon-aware
    /// profile's intensity lookup) can read it. Schedulers that do not
    /// consume time fall through to [`Scheduler::schedule`], so the
    /// default keeps every pre-clock implementation bit-identical.
    fn schedule_at(
        &mut self,
        state: &ClusterState,
        pod: &Pod,
        now_s: f64,
    ) -> SchedulingDecision {
        let _ = now_s;
        self.schedule(state, pod)
    }
}
