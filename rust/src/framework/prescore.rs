//! PreScore: per-cycle estimator-row computation, cached across
//! cycles by node version (DESIGN.md §"Hot path").
//!
//! `Estimator::estimate` is a pure function of (node spec + allocation
//! + readiness, pod *shape*, estimator params). [`RowKey`] captures
//! the pod shape and [`crate::cluster::ClusterState::node_version`]
//! captures everything node-side, so a (key, version) hit can reuse
//! the last computed row bit-for-bit. TOPSIS normalization couples
//! candidates to each other, so only estimator *rows* are cacheable
//! here — final scores are always recombined per decision.

use crate::cluster::{ClusterState, NodeId, Pod};
use crate::scheduler::{Estimator, NodeEstimate};
use crate::workload::WorkloadClass;

/// The pod-side inputs `Estimator::estimate` reads: two pods with
/// equal keys produce identical rows on the same node state.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RowKey {
    class: WorkloadClass,
    epochs: u32,
    cpu_millis: u64,
    memory_mib: u64,
}

impl RowKey {
    pub fn of(pod: &Pod) -> Self {
        Self {
            class: pod.class,
            epochs: pod.epochs,
            cpu_millis: pod.requests.cpu_millis,
            memory_mib: pod.requests.memory_mib,
        }
    }
}

/// Version-stamped estimator rows for one scoring plugin. One
/// instance lives inside each estimator-backed `ScorePlugin`; across
/// scheduling cycles it recomputes rows only for nodes whose version
/// changed (dirty nodes) while the pod shape stays the same.
#[derive(Debug, Default)]
pub struct RowCache {
    /// Pod shape the cached rows were computed for.
    key: Option<RowKey>,
    /// Per node id: last computed row (valid iff versions[id] matches
    /// the state's current stamp for that node).
    rows: Vec<NodeEstimate>,
    /// Per node id: `state.node_version(id)` at computation time.
    /// 0 never matches a real stamp (stamps start at 1).
    versions: Vec<u64>,
}

impl RowCache {
    /// Fill `out` with one estimator row per candidate (same order).
    /// With `reuse` set, rows for (shape, version)-clean nodes come
    /// from the cache — bit-identical to recomputation because the
    /// estimator is pure; with `reuse` unset every row is recomputed
    /// (the full-rescore reference path the differential property
    /// compares against).
    pub fn fill(
        &mut self,
        estimator: &Estimator,
        state: &ClusterState,
        pod: &Pod,
        candidates: &[NodeId],
        reuse: bool,
        out: &mut Vec<NodeEstimate>,
    ) {
        let key = RowKey::of(pod);
        if !reuse || self.key != Some(key) {
            // Shape change (or reuse disabled): every stamp becomes
            // the never-matches sentinel, forcing recomputation.
            self.versions.clear();
            self.key = Some(key);
        }
        let n = state.nodes().len();
        self.versions.resize(n, 0);
        self.rows.resize(
            n,
            NodeEstimate {
                node: 0,
                exec_time_s: 0.0,
                energy_j: 0.0,
                free_cpu_frac: 0.0,
                free_mem_frac: 0.0,
                balance: 0.0,
            },
        );
        out.clear();
        for &id in candidates {
            if self.versions[id] != state.node_version(id) {
                self.rows[id] = estimator.estimate(state, state.node(id), pod);
                self.versions[id] = state.node_version(id);
            }
            out.push(self.rows[id]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ClusterConfig, EnergyModelConfig, SchedulerKind};
    use crate::scheduler::Estimator;

    fn fixtures() -> (ClusterState, Estimator) {
        let state = ClusterState::from_config(&ClusterConfig::paper_default());
        (state, Estimator::with_defaults(EnergyModelConfig::default()))
    }

    fn pod(id: u64, class: WorkloadClass, epochs: u32) -> Pod {
        Pod::new(id, class, SchedulerKind::Topsis, 0.0, epochs)
    }

    fn rows_eq(a: &[NodeEstimate], b: &[NodeEstimate]) -> bool {
        a.len() == b.len()
            && a.iter().zip(b).all(|(x, y)| {
                x.node == y.node
                    && x.exec_time_s.to_bits() == y.exec_time_s.to_bits()
                    && x.energy_j.to_bits() == y.energy_j.to_bits()
                    && x.free_cpu_frac.to_bits() == y.free_cpu_frac.to_bits()
                    && x.free_mem_frac.to_bits() == y.free_mem_frac.to_bits()
                    && x.balance.to_bits() == y.balance.to_bits()
            })
    }

    #[test]
    fn cached_rows_bit_identical_to_recompute_across_churn() {
        let (mut state, est) = fixtures();
        let p = pod(1, WorkloadClass::Medium, 4);
        let candidates = state.feasible_nodes(p.requests);
        let mut cache = RowCache::default();
        let (mut cached, mut fresh) = (Vec::new(), Vec::new());

        cache.fill(&est, &state, &p, &candidates, true, &mut cached);
        RowCache::default().fill(&est, &state, &p, &candidates, true, &mut fresh);
        assert!(rows_eq(&cached, &fresh));

        // Mutate two nodes; clean nodes must serve cache hits that
        // still match full recomputation bitwise.
        state.bind(&pod(2, WorkloadClass::Complex, 4), 0, 0.0).unwrap();
        state.set_ready(5, false, 0.0);
        let candidates = state.feasible_nodes(p.requests);
        cache.fill(&est, &state, &p, &candidates, true, &mut cached);
        RowCache::default().fill(&est, &state, &p, &candidates, true, &mut fresh);
        assert!(rows_eq(&cached, &fresh));
    }

    #[test]
    fn shape_change_invalidates_rows() {
        let (state, est) = fixtures();
        let candidates = state.feasible_nodes(
            pod(1, WorkloadClass::Light, 2).requests,
        );
        let mut cache = RowCache::default();
        let (mut light, mut complex, mut fresh) =
            (Vec::new(), Vec::new(), Vec::new());
        cache.fill(
            &est,
            &state,
            &pod(1, WorkloadClass::Light, 2),
            &candidates,
            true,
            &mut light,
        );
        // Same cache, different pod shape: rows must be for the new
        // shape, not stale Light rows.
        let p2 = pod(2, WorkloadClass::Complex, 9);
        let cand2 = state.feasible_nodes(p2.requests);
        cache.fill(&est, &state, &p2, &cand2, true, &mut complex);
        RowCache::default().fill(&est, &state, &p2, &cand2, true, &mut fresh);
        assert!(rows_eq(&complex, &fresh));
        assert!(!rows_eq(&light, &complex));
    }
}
