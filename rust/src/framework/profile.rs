//! Profile composition and the framework-driven scheduler.

use std::time::Instant;

use crate::cluster::{ClusterState, NodeId, Pod};
use crate::mcda::argmax;
use crate::scheduler::{Scheduler, SchedulingDecision};
use crate::util::rng::Rng;

use super::{CycleCtx, FilterPlugin, ScorePlugin};

/// How a profile resolves score ties.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TieBreak {
    /// Deterministic lowest candidate index among the maxima — the
    /// GreenPod monolith's `argmax` semantics.
    LowestIndex,
    /// Uniform random among candidates within 1e-9 of the best score,
    /// from the scheduler's seeded RNG — kube-scheduler's `selectHost`
    /// semantics, as the default-k8s monolith implements them.
    SeededRandom,
}

/// A named scheduler composition: filter chain, weighted score plugins,
/// tie-break policy.
pub struct SchedulerProfile {
    pub name: String,
    pub filters: Vec<Box<dyn FilterPlugin>>,
    /// `(plugin, weight)` — combined as the weight-normalized sum of
    /// each plugin's normalized scores.
    pub scorers: Vec<(Box<dyn ScorePlugin>, f64)>,
    pub tie_break: TieBreak,
}

impl SchedulerProfile {
    pub fn new(name: impl Into<String>) -> Self {
        Self {
            name: name.into(),
            filters: Vec::new(),
            scorers: Vec::new(),
            tie_break: TieBreak::LowestIndex,
        }
    }

    pub fn filter(mut self, plugin: Box<dyn FilterPlugin>) -> Self {
        self.filters.push(plugin);
        self
    }

    pub fn score(mut self, plugin: Box<dyn ScorePlugin>, weight: f64) -> Self {
        self.scorers.push((plugin, weight));
        self
    }

    pub fn tie_break(mut self, tie_break: TieBreak) -> Self {
        self.tie_break = tie_break;
        self
    }
}

/// Drives a [`SchedulerProfile`] through the [`Scheduler`] trait:
/// filter → score (+ normalize) → weighted combine → select. The
/// published `SchedulingDecision::scores` are the combined
/// per-candidate scores, exactly as the legacy monoliths published
/// theirs.
///
/// Time-aware drivers bind the scheduling cycle's virtual timestamp
/// via [`Scheduler::schedule_at`]; it is handed to every score plugin
/// as [`CycleCtx::now_s`]. Plain [`Scheduler::schedule`] calls reuse
/// the last bound timestamp (0.0 before any), so clock-less callers
/// and time-invariant plugins behave exactly as before the clock
/// existed.
pub struct FrameworkScheduler {
    profile: SchedulerProfile,
    rng: Rng,
    /// Virtual time of the current scheduling cycle.
    now_s: f64,
    /// Whether score plugins may serve version-clean estimator rows
    /// from their caches ([`CycleCtx::reuse_rows`]). On by default —
    /// cache hits are bit-identical to recomputation; the differential
    /// property runs one scheduler with this off as the full-rescore
    /// reference.
    incremental: bool,
    // Arena buffers reused across decisions so the steady-state cycle
    // allocates nothing (the published `SchedulingDecision::scores`
    // vector is the one remaining per-decision allocation — it
    // escapes into the caller).
    candidates: Vec<NodeId>,
    combined: Vec<f64>,
    raw: Vec<f64>,
    top: Vec<NodeId>,
}

impl FrameworkScheduler {
    /// `seed` feeds the tie-break RNG (used only by
    /// [`TieBreak::SeededRandom`]); the stream matches the retired
    /// `DefaultK8sScheduler::new(seed)` monolith draw-for-draw, so
    /// seeded traces recorded before the retirement still replay.
    pub fn new(profile: SchedulerProfile, seed: u64) -> Self {
        Self {
            profile,
            rng: Rng::seed_from_u64(seed),
            now_s: 0.0,
            incremental: true,
            candidates: Vec::new(),
            combined: Vec::new(),
            raw: Vec::new(),
            top: Vec::new(),
        }
    }

    pub fn profile_name(&self) -> &str {
        &self.profile.name
    }

    /// Toggle row reuse (see [`CycleCtx::reuse_rows`]). `false` forces
    /// a full rescore every decision — the reference path the
    /// incremental≡full differential property compares against.
    pub fn set_incremental(&mut self, on: bool) {
        self.incremental = on;
    }

    /// PJRT → Rust scoring fallbacks across all score plugins.
    pub fn pjrt_fallbacks(&self) -> u64 {
        self.profile.scorers.iter().map(|(p, _)| p.fallbacks()).sum()
    }
}

impl Scheduler for FrameworkScheduler {
    fn name(&self) -> &str {
        &self.profile.name
    }

    fn schedule(
        &mut self,
        state: &ClusterState,
        pod: &Pod,
    ) -> SchedulingDecision {
        // greenpod-lint: allow(wall-clock-in-kernel) reason="bench-only decision-latency metric; the reading feeds latency_us reporting and never reaches placement, virtual time, or energy results"
        let t0 = Instant::now();

        // Filter: a node survives only if every filter admits it.
        // When a filter offers bulk admission (PreFilter — e.g. the
        // index-backed NodeResourcesFit), its output seeds the
        // candidate set and only the *other* filters re-probe per
        // node; otherwise fall back to the full scan.
        let mut candidates = std::mem::take(&mut self.candidates);
        candidates.clear();
        let filters = &self.profile.filters;
        let bulk = filters
            .iter()
            .position(|f| f.prefilter(state, pod, &mut candidates));
        match bulk {
            Some(k) => {
                if filters.len() > 1 {
                    candidates.retain(|&id| {
                        filters
                            .iter()
                            .enumerate()
                            .all(|(j, f)| j == k || f.feasible(state, pod, id))
                    });
                }
            }
            None => {
                candidates.extend((0..state.nodes().len()).filter(|&id| {
                    filters.iter().all(|f| f.feasible(state, pod, id))
                }));
            }
        }
        if candidates.is_empty() {
            self.candidates = candidates;
            return SchedulingDecision {
                node: None,
                latency: t0.elapsed(),
                scores: Vec::new(),
            };
        }

        // Score: each plugin scores + normalizes; combine by weight.
        // `raw` and `combined` are arena buffers — no allocation once
        // their high-water capacity is reached.
        let ctx = CycleCtx { now_s: self.now_s, reuse_rows: self.incremental };
        self.combined.clear();
        self.combined.resize(candidates.len(), 0.0);
        let mut raw = std::mem::take(&mut self.raw);
        let mut total_weight = 0.0;
        for (plugin, weight) in &mut self.profile.scorers {
            plugin.score(&ctx, state, pod, &candidates, &mut raw);
            // Hard contract on the public extension point: a short
            // vector would silently zero-bias the tail candidates.
            assert_eq!(
                raw.len(),
                candidates.len(),
                "plugin {} returned {} scores for {} candidates",
                plugin.name(),
                raw.len(),
                candidates.len()
            );
            plugin.normalize(state, pod, &mut raw);
            for (acc, s) in self.combined.iter_mut().zip(&raw) {
                *acc += *weight * s;
            }
            total_weight += *weight;
        }
        if total_weight > 0.0 {
            for s in &mut self.combined {
                *s /= total_weight;
            }
        }
        self.raw = raw;

        // Select.
        let node = match self.profile.tie_break {
            TieBreak::LowestIndex => {
                argmax(&self.combined).map(|i| candidates[i])
            }
            TieBreak::SeededRandom => {
                let best = self
                    .combined
                    .iter()
                    .copied()
                    .fold(f64::NEG_INFINITY, f64::max);
                self.top.clear();
                self.top.extend(
                    candidates
                        .iter()
                        .zip(&self.combined)
                        .filter(|&(_, &s)| (s - best).abs() < 1e-9)
                        .map(|(&id, _)| id),
                );
                if self.top.is_empty() {
                    None
                } else {
                    Some(self.top[self.rng.below(self.top.len())])
                }
            }
        };

        let scores = candidates
            .iter()
            .copied()
            .zip(self.combined.iter().copied())
            .collect();
        self.candidates = candidates;
        SchedulingDecision { node, latency: t0.elapsed(), scores }
    }

    fn schedule_at(
        &mut self,
        state: &ClusterState,
        pod: &Pod,
        now_s: f64,
    ) -> SchedulingDecision {
        self.now_s = now_s;
        self.schedule(state, pod)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ClusterConfig, SchedulerKind};
    use crate::framework::{
        BalancedAllocation, LeastAllocated, NodeResourcesFit,
    };
    use crate::workload::WorkloadClass;

    fn state() -> ClusterState {
        ClusterState::from_config(&ClusterConfig::paper_default())
    }

    fn pod(id: u64, class: WorkloadClass) -> Pod {
        Pod::new(id, class, SchedulerKind::DefaultK8s, 0.0, 1)
    }

    fn k8s_profile() -> SchedulerProfile {
        SchedulerProfile::new("default-k8s")
            .filter(Box::new(NodeResourcesFit))
            .score(Box::new(LeastAllocated), 1.0)
            .score(Box::new(BalancedAllocation), 1.0)
            .tie_break(TieBreak::SeededRandom)
    }

    #[test]
    fn empty_cluster_unschedulable() {
        let mut s = state();
        for id in 0..s.nodes().len() {
            s.set_ready(id, false, 0.0);
        }
        let mut sched = FrameworkScheduler::new(k8s_profile(), 0);
        let d = sched.schedule(&s, &pod(1, WorkloadClass::Light));
        assert_eq!(d.node, None);
        assert!(d.scores.is_empty());
    }

    #[test]
    fn combined_scores_cover_candidates_in_range() {
        let s = state();
        let mut sched = FrameworkScheduler::new(k8s_profile(), 0);
        let d = sched.schedule(&s, &pod(1, WorkloadClass::Light));
        assert_eq!(d.scores.len(), 7);
        assert!(d.node.is_some());
        for &(_, v) in &d.scores {
            assert!((0.0..=100.0).contains(&v), "{:?}", d.scores);
        }
    }

    #[test]
    fn seeded_tie_break_deterministic() {
        let s = state();
        let mut a = FrameworkScheduler::new(k8s_profile(), 42);
        let mut b = FrameworkScheduler::new(k8s_profile(), 42);
        for i in 0..10 {
            let p = pod(i, WorkloadClass::Light);
            assert_eq!(a.schedule(&s, &p).node, b.schedule(&s, &p).node);
        }
    }

    #[test]
    fn schedule_at_threads_the_cycle_clock_to_plugins() {
        use std::cell::Cell;
        use std::rc::Rc;

        struct Probe(Rc<Cell<f64>>);
        impl ScorePlugin for Probe {
            fn name(&self) -> &'static str {
                "probe"
            }

            fn score(
                &mut self,
                ctx: &CycleCtx,
                _state: &ClusterState,
                _pod: &Pod,
                candidates: &[NodeId],
                out: &mut Vec<f64>,
            ) {
                self.0.set(ctx.now_s);
                out.clear();
                out.resize(candidates.len(), 0.0);
            }
        }

        let seen = Rc::new(Cell::new(f64::NAN));
        let profile = SchedulerProfile::new("probe")
            .filter(Box::new(NodeResourcesFit))
            .score(Box::new(Probe(seen.clone())), 1.0);
        let s = state();
        let mut sched = FrameworkScheduler::new(profile, 0);
        sched.schedule_at(&s, &pod(1, WorkloadClass::Light), 42.5);
        assert_eq!(seen.get(), 42.5);
        // A plain schedule() reuses the last bound timestamp.
        sched.schedule(&s, &pod(2, WorkloadClass::Light));
        assert_eq!(seen.get(), 42.5);
    }

    #[test]
    fn bulk_prefilter_composes_with_other_filters() {
        // The index-backed prefilter seeds the candidate set; every
        // other filter must still get its per-node veto, and the final
        // set must equal the all-filters reference scan, order included.
        struct OddOnly;
        impl FilterPlugin for OddOnly {
            fn name(&self) -> &'static str {
                "odd-only"
            }

            fn feasible(
                &self,
                _state: &ClusterState,
                _pod: &Pod,
                node: NodeId,
            ) -> bool {
                node % 2 == 1
            }
        }

        let mut s = state();
        s.set_ready(3, false, 0.0);
        let p = pod(1, WorkloadClass::Light);
        let profile = SchedulerProfile::new("odd")
            .filter(Box::new(NodeResourcesFit))
            .filter(Box::new(OddOnly))
            .score(Box::new(LeastAllocated), 1.0);
        let mut sched = FrameworkScheduler::new(profile, 0);
        let d = sched.schedule(&s, &p);
        let expect: Vec<NodeId> = s
            .feasible_nodes_scan(p.requests)
            .into_iter()
            .filter(|id| id % 2 == 1)
            .collect();
        let got: Vec<NodeId> = d.scores.iter().map(|&(id, _)| id).collect();
        assert_eq!(got, expect);
        assert!(!expect.is_empty());
        assert!(expect.contains(&d.node.unwrap()));
    }

    #[test]
    fn zero_scorers_falls_back_to_first_candidate() {
        // A filter-only profile still binds (uniform zero scores,
        // lowest-index tie-break) — useful as a "random-fit" baseline.
        let s = state();
        let profile = SchedulerProfile::new("filter-only")
            .filter(Box::new(NodeResourcesFit));
        let mut sched = FrameworkScheduler::new(profile, 0);
        let d = sched.schedule(&s, &pod(1, WorkloadClass::Light));
        assert_eq!(d.node, Some(0));
    }
}
