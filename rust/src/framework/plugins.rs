//! Stock plugins: the default kube-scheduler's documented filter and
//! scoring behaviour, plus a carbon-aware scorer the monolithic API
//! could not express.
//!
//! The free functions here are the *canonical* scoring math — since
//! the retirement of the `DefaultK8sScheduler` monolith (which
//! delegated to them), the framework's `default-k8s` profile is their
//! only consumer and the single formulation in the tree.

use crate::cluster::{ClusterState, NodeId, Pod};
use crate::energy::CarbonSignal;
use crate::scheduler::{Estimator, NodeEstimate};

use super::{CycleCtx, FilterPlugin, RowCache, ScorePlugin};

/// `LeastAllocated` (kube `NodeResourcesLeastAllocated`): mean over
/// cpu/mem of the free fraction after placement, scaled to 0–100.
///
/// Free-after-placement is clamped at zero (`saturating_sub`), so a pod
/// larger than the node scores 0 instead of underflowing — the filter
/// normally removes such nodes, but the scoring math must stay in
/// range for any input.
pub fn least_allocated_score(
    state: &ClusterState,
    node: NodeId,
    pod: &Pod,
) -> f64 {
    let n = state.node(node);
    let cpu_free = state.free_cpu(node).saturating_sub(pod.requests.cpu_millis)
        as f64
        / n.cpu_millis as f64;
    let mem_free = state
        .free_memory(node)
        .saturating_sub(pod.requests.memory_mib) as f64
        / n.memory_mib as f64;
    50.0 * (cpu_free + mem_free)
}

/// `BalancedAllocation` (kube `NodeResourcesBalancedAllocation`):
/// 100 − |cpu_fraction − mem_fraction|·100 after placement.
///
/// Used-after-placement is capped at capacity, so an over-request can
/// never push a utilization fraction past 1 and the score out of the
/// 0–100 range.
pub fn balanced_allocation_score(
    state: &ClusterState,
    node: NodeId,
    pod: &Pod,
) -> f64 {
    let n = state.node(node);
    let cpu_used = (n.cpu_millis - state.free_cpu(node))
        .saturating_add(pod.requests.cpu_millis)
        .min(n.cpu_millis) as f64
        / n.cpu_millis as f64;
    let mem_used = (n.memory_mib - state.free_memory(node))
        .saturating_add(pod.requests.memory_mib)
        .min(n.memory_mib) as f64
        / n.memory_mib as f64;
    100.0 - 100.0 * (cpu_used - mem_used).abs()
}

/// Filter: kube's `NodeResourcesFit` + readiness — exactly
/// [`ClusterState::fits`].
pub struct NodeResourcesFit;

impl FilterPlugin for NodeResourcesFit {
    fn name(&self) -> &'static str {
        "node-resources-fit"
    }

    fn feasible(&self, state: &ClusterState, pod: &Pod, node: NodeId) -> bool {
        state.fits(node, pod.requests)
    }

    /// Bulk admission off the free-capacity indices: a range probe
    /// instead of an O(nodes) scan, pinned to the same membership and
    /// order as per-node [`ClusterState::fits`] probing.
    fn prefilter(
        &self,
        state: &ClusterState,
        pod: &Pod,
        out: &mut Vec<NodeId>,
    ) -> bool {
        state.feasible_nodes_into(pod.requests, out);
        true
    }
}

/// Score: [`least_allocated_score`] as a plugin.
pub struct LeastAllocated;

impl ScorePlugin for LeastAllocated {
    fn name(&self) -> &'static str {
        "least-allocated"
    }

    fn score(
        &mut self,
        _ctx: &CycleCtx,
        state: &ClusterState,
        pod: &Pod,
        candidates: &[NodeId],
        out: &mut Vec<f64>,
    ) {
        out.clear();
        out.extend(
            candidates
                .iter()
                .map(|&id| least_allocated_score(state, id, pod)),
        );
    }
}

/// Score: [`balanced_allocation_score`] as a plugin.
pub struct BalancedAllocation;

impl ScorePlugin for BalancedAllocation {
    fn name(&self) -> &'static str {
        "balanced-allocation"
    }

    fn score(
        &mut self,
        _ctx: &CycleCtx,
        state: &ClusterState,
        pod: &Pod,
        candidates: &[NodeId],
        out: &mut Vec<f64>,
    ) {
        out.clear();
        out.extend(
            candidates
                .iter()
                .map(|&id| balanced_allocation_score(state, id, pod)),
        );
    }
}

/// Score: predicted grams of CO₂ for running the pod on each candidate
/// (estimator energy × the grid intensity *at the scheduling cycle's
/// virtual timestamp*, [`CarbonSignal::at`]), inverted onto 0–100 in
/// the normalize pass — the carbon-aware placement policy the CODECO
/// far-edge study evaluates as a "greenness" profile, not expressible
/// under the old monolithic API. A constant signal reproduces the
/// pre-signal scalar scoring bit-for-bit (differential-tested).
pub struct CarbonAware {
    estimator: Estimator,
    /// Grid intensity over virtual time.
    signal: CarbonSignal,
    /// Version-stamped estimator rows (PreScore; see [`RowCache`]).
    cache: RowCache,
    rows: Vec<NodeEstimate>,
}

impl CarbonAware {
    pub fn new(estimator: Estimator, signal: CarbonSignal) -> Self {
        Self {
            estimator,
            signal,
            cache: RowCache::default(),
            rows: Vec::new(),
        }
    }
}

impl ScorePlugin for CarbonAware {
    fn name(&self) -> &'static str {
        "carbon-aware"
    }

    /// Raw output: estimated grams CO₂ at the cycle's grid intensity
    /// (a cost — lower is better). Rows come through the PreScore
    /// cache; the time-varying intensity multiplies in afterwards, so
    /// row reuse never freezes the clock.
    fn score(
        &mut self,
        ctx: &CycleCtx,
        state: &ClusterState,
        pod: &Pod,
        candidates: &[NodeId],
        out: &mut Vec<f64>,
    ) {
        // One intensity per cycle: all candidates share the clock.
        let g_per_j = self.signal.at(ctx.now_s);
        self.cache.fill(
            &self.estimator,
            state,
            pod,
            candidates,
            ctx.reuse_rows,
            &mut self.rows,
        );
        out.clear();
        out.extend(self.rows.iter().map(|e| e.energy_j * g_per_j));
    }

    /// Inverted min–max onto 0–100: the lowest-carbon candidate scores
    /// 100, the highest 0. A degenerate (all-equal) candidate set
    /// scores a uniform 100.
    fn normalize(
        &self,
        _state: &ClusterState,
        _pod: &Pod,
        scores: &mut [f64],
    ) {
        let min = scores.iter().copied().fold(f64::INFINITY, f64::min);
        let max = scores.iter().copied().fold(f64::NEG_INFINITY, f64::max);
        let range = max - min;
        for s in scores.iter_mut() {
            *s = if range <= f64::EPSILON * max.abs().max(1.0) {
                100.0
            } else {
                100.0 * (max - *s) / range
            };
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ClusterConfig, SchedulerKind};
    use crate::workload::WorkloadClass;

    fn state() -> ClusterState {
        ClusterState::from_config(&ClusterConfig::paper_default())
    }

    fn pod(class: WorkloadClass) -> Pod {
        Pod::new(0, class, SchedulerKind::DefaultK8s, 0.0, 1)
    }

    #[test]
    fn oversized_pod_scores_stay_in_range() {
        // A pod larger than any node: the clamp must keep both kube
        // scores inside 0–100 instead of underflowing/overflowing.
        let s = state();
        let mut hog = pod(WorkloadClass::Light);
        hog.requests.cpu_millis = 1_000_000;
        hog.requests.memory_mib = 1_000_000;
        for id in 0..s.nodes().len() {
            let la = least_allocated_score(&s, id, &hog);
            let ba = balanced_allocation_score(&s, id, &hog);
            assert!((0.0..=100.0).contains(&la), "node {id}: least {la}");
            assert!((0.0..=100.0).contains(&ba), "node {id}: balanced {ba}");
            // Fully over-requested on both axes: no free capacity left.
            assert_eq!(la, 0.0);
            assert_eq!(ba, 100.0); // both fractions cap at 1.0 → balanced
        }
    }

    #[test]
    fn feasible_scores_match_unclamped_math() {
        // For a pod that fits, the clamp is the identity: the scores
        // are the documented kube formulas.
        let s = state();
        let p = pod(WorkloadClass::Light);
        let n = s.node(0);
        let la = least_allocated_score(&s, 0, &p);
        let expect = 50.0
            * ((s.free_cpu(0) - p.requests.cpu_millis) as f64
                / n.cpu_millis as f64
                + (s.free_memory(0) - p.requests.memory_mib) as f64
                    / n.memory_mib as f64);
        assert_eq!(la, expect);
    }

    #[test]
    fn node_resources_fit_matches_cluster_fits() {
        let mut s = state();
        let p = pod(WorkloadClass::Complex);
        let f = NodeResourcesFit;
        for id in 0..s.nodes().len() {
            assert_eq!(f.feasible(&s, &p, id), s.fits(id, p.requests));
        }
        s.set_ready(0, false, 0.0);
        assert!(!f.feasible(&s, &p, 0));
        // Bulk admission agrees with per-node probing, order included.
        let mut bulk = Vec::new();
        assert!(f.prefilter(&s, &p, &mut bulk));
        assert_eq!(bulk, s.feasible_nodes_scan(p.requests));
    }

    #[test]
    fn carbon_aware_prefers_low_power_nodes() {
        use crate::config::EnergyModelConfig;
        let s = state();
        let p = pod(WorkloadClass::Medium);
        let energy = EnergyModelConfig::default();
        let mut plug = CarbonAware::new(
            Estimator::with_defaults(energy.clone()),
            CarbonSignal::from_energy(&energy),
        );
        let candidates: Vec<usize> = (0..s.nodes().len()).collect();
        let mut scores = Vec::new();
        plug.score(&CycleCtx::default(), &s, &p, &candidates, &mut scores);
        plug.normalize(&s, &p, &mut scores);
        for &v in &scores {
            assert!((0.0..=100.0).contains(&v), "{scores:?}");
        }
        // Category-A nodes (0..3) are the energy-efficient ones — one
        // of them must be the 100-scoring minimum-carbon choice.
        let best = scores
            .iter()
            .enumerate()
            .max_by(|a, b| crate::util::stats::total_order(a.1, b.1))
            .unwrap()
            .0;
        assert!(best < 3, "best candidate {best}, scores {scores:?}");
        assert_eq!(scores[best], 100.0);
    }

    #[test]
    fn carbon_aware_raw_scores_track_the_cycle_time() {
        // Raw grams scale with the intensity at the cycle timestamp:
        // dirty-hour estimates are (intensity ratio) × clean-hour ones.
        use crate::config::EnergyModelConfig;
        let s = state();
        let p = pod(WorkloadClass::Medium);
        let energy = EnergyModelConfig::default();
        let signal = CarbonSignal::step(vec![(0.0, 1e-4), (100.0, 3e-4)])
            .unwrap();
        let mut plug = CarbonAware::new(
            Estimator::with_defaults(energy),
            signal,
        );
        let candidates: Vec<usize> = (0..s.nodes().len()).collect();
        let (mut clean, mut dirty) = (Vec::new(), Vec::new());
        plug.score(
            &CycleCtx { now_s: 50.0, ..CycleCtx::default() },
            &s,
            &p,
            &candidates,
            &mut clean,
        );
        plug.score(
            &CycleCtx { now_s: 150.0, ..CycleCtx::default() },
            &s,
            &p,
            &candidates,
            &mut dirty,
        );
        for (c, d) in clean.iter().zip(&dirty) {
            assert!(*c > 0.0);
            assert!((d / c - 3.0).abs() < 1e-9, "{c} vs {d}");
        }
    }
}
