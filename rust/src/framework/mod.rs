//! Scheduling framework — kube-scheduler's extension-point model as a
//! library (DESIGN.md §"Scheduling framework").
//!
//! The original `Scheduler` implementations were sealed monoliths: the
//! whole filter → score → select pipeline hid behind one `schedule()`
//! call, so every new strategy meant a whole new struct. This module
//! decomposes scheduling into the extension points the real
//! kube-scheduler exposes, so strategies become *configuration*:
//!
//! * [`FilterPlugin`] — admits/rejects one candidate node (kube's
//!   Filter point; [`NodeResourcesFit`] is the stock implementation).
//! * [`ScorePlugin`] — scores every surviving candidate. The framework
//!   convention is kube's: **0–100, higher is better**. A plugin whose
//!   natural output lives on another scale maps onto 0–100 in its
//!   [`ScorePlugin::normalize`] pass (kube's NormalizeScore point) —
//!   or deliberately opts out, like [`McdaScorePlugin`] running as a
//!   profile's sole scorer, where the raw TOPSIS closeness in `[0, 1]`
//!   is the published per-candidate score the paper's §V.D analysis
//!   reads.
//! * [`SchedulerProfile`] — a named composition: filter chain, weighted
//!   score plugins, and a tie-break policy. [`FrameworkScheduler`]
//!   drives a profile through the existing [`Scheduler`] trait, so the
//!   event loop and the api loop need no changes to run any profile.
//! * [`ProfileRegistry`] — name → profile. Ships the built-in profiles
//!   (the two ports of the retired monolith schedulers plus
//!   compositions the old API could not express) and materializes
//!   user-defined profiles from `Config::profiles`.
//!
//! The ported pipelines were pinned **bit-identical** to the monolith
//! schedulers (`GreenPodScheduler`, `DefaultK8sScheduler`) by
//! differential properties for two PRs before the monoliths were
//! deleted; the profiles here are now the only formulation, and the
//! properties in `rust/tests/properties.rs` continue as framework
//! self-consistency checks (alias resolution, tie-break stream
//! determinism, incremental-vs-full rescoring).
//!
//! [`Scheduler`]: crate::scheduler::Scheduler

mod mcda_plugin;
mod plugins;
mod prescore;
mod profile;
mod registry;

pub use mcda_plugin::{build_decision_problem, McdaScorePlugin};
pub use plugins::{
    balanced_allocation_score, least_allocated_score, BalancedAllocation,
    CarbonAware, LeastAllocated, NodeResourcesFit,
};
pub use prescore::{RowCache, RowKey};
pub use profile::{FrameworkScheduler, SchedulerProfile, TieBreak};
pub use registry::{BuildOptions, ProfileRegistry};

use crate::cluster::{ClusterState, NodeId, Pod};

/// Per-cycle context handed to score plugins (kube's CycleState,
/// reduced to what the stock plugins consume): the scheduling cycle's
/// virtual timestamp. Drivers with a clock — the event engine, the
/// serve loop — thread it in through [`Scheduler::schedule_at`];
/// clock-less `schedule` calls reuse the scheduler's last bound
/// timestamp (0.0 before any `schedule_at`). Time-varying plugins like
/// [`CarbonAware`] read the grid intensity at `now_s`.
///
/// [`Scheduler::schedule_at`]: crate::scheduler::Scheduler::schedule_at
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct CycleCtx {
    /// Virtual time of the scheduling cycle (seconds).
    pub now_s: f64,
    /// Whether estimator-backed plugins may serve version-clean rows
    /// from their [`RowCache`] instead of recomputing (DESIGN.md
    /// §"Hot path"). Cache hits are bit-identical to recomputation, so
    /// this only trades CPU — never placement bits. Defaults to
    /// `false` (full rescore), the conservative reference path the
    /// incremental≡full differential property compares against.
    pub reuse_rows: bool,
}

/// Filter extension point: one candidate node in, admit/reject out
/// (kube's Filter). A node survives only if *every* filter in the
/// profile admits it.
pub trait FilterPlugin {
    fn name(&self) -> &'static str;

    /// Whether `pod` may be placed on `node` right now.
    fn feasible(&self, state: &ClusterState, pod: &Pod, node: NodeId) -> bool;

    /// Optional bulk admission (kube's PreFilter, inverted): fill
    /// `out` with *exactly* the nodes this filter admits, ascending by
    /// id, and return `true` — or return `false` (the default) to fall
    /// back to per-node [`feasible`] probing. Lets an index-backed
    /// filter like [`NodeResourcesFit`] produce the candidate set as a
    /// range probe instead of an O(nodes) scan. Implementations must
    /// guarantee `out` equals the set `feasible` would admit.
    ///
    /// [`feasible`]: FilterPlugin::feasible
    fn prefilter(
        &self,
        _state: &ClusterState,
        _pod: &Pod,
        _out: &mut Vec<NodeId>,
    ) -> bool {
        false
    }
}

/// Score extension point (kube's Score + NormalizeScore).
///
/// Convention: scores are **0–100, higher is better**. [`score`]
/// returns the plugin's raw output; [`normalize`] then maps it onto the
/// convention where the raw scale differs (min–max inversion for cost
/// quantities, ×100 for unit-interval closeness, ...). The
/// [`FrameworkScheduler`] combines normalized scores across plugins by
/// weight, so commensurability is what makes multi-plugin profiles
/// meaningful.
///
/// [`score`]: ScorePlugin::score
/// [`normalize`]: ScorePlugin::normalize
pub trait ScorePlugin {
    fn name(&self) -> &'static str;

    /// Raw score for every candidate, written into `out` in candidate
    /// order (`out` is cleared first and ends with `candidates.len()`
    /// entries). The out-parameter lets the driver reuse one buffer
    /// across cycles — the steady-state hot path allocates nothing.
    /// `ctx` carries the scheduling cycle's virtual timestamp and the
    /// row-reuse flag.
    fn score(
        &mut self,
        ctx: &CycleCtx,
        state: &ClusterState,
        pod: &Pod,
        candidates: &[NodeId],
        out: &mut Vec<f64>,
    );

    /// Optional NormalizeScore pass: rescale this plugin's raw scores
    /// onto the 0–100 convention. Default: identity.
    fn normalize(
        &self,
        _state: &ClusterState,
        _pod: &Pod,
        _scores: &mut [f64],
    ) {
    }

    /// PJRT → Rust scoring fallbacks this plugin has taken so far
    /// (non-zero only for [`McdaScorePlugin`] on the PJRT backend).
    fn fallbacks(&self) -> u64 {
        0
    }
}
