//! Profile registry: name → [`FrameworkScheduler`].
//!
//! Built-in profiles (always registered):
//!
//! * `greenpod` — NodeResourcesFit + the MCDA plugin (paper pipeline;
//!   honors the build options' weighting scheme, MCDA method and PJRT
//!   registry). Port of the retired `GreenPodScheduler` monolith.
//! * `default-k8s` — NodeResourcesFit + LeastAllocated +
//!   BalancedAllocation, equal weight, seeded-random tie-break. Port of
//!   the retired `DefaultK8sScheduler` monolith.
//! * `carbon-aware` — NodeResourcesFit + the CO₂ scorer. Not
//!   expressible under the old monolithic API.
//! * `hybrid-topsis-balanced` — TOPSIS closeness (percent-scaled)
//!   blended 70/30 with BalancedAllocation. Also new with this API.
//!
//! **Deprecated aliases.** Configs and `--profile` flags written
//! against the monolith era may still name `greenpod-topsis` (the
//! retired `GreenPodScheduler`'s reported name); the registry resolves
//! it to the `greenpod` profile so old invocations keep working. New
//! code should use the profile names above.
//!
//! `Config::profiles` entries are materialized on top; every driver
//! (experiment runner, elastic scenarios, `greenpod serve`) constructs
//! its schedulers exclusively through [`ProfileRegistry::build`].

use std::rc::Rc;

use anyhow::{bail, Result};

use crate::config::{
    Config, ProfileSpec, ProfileTieBreak, ScorePluginKind, WeightingScheme,
    BUILTIN_PROFILE_NAMES, LEGACY_PROFILE_ALIASES,
};
use crate::energy::CarbonSignal;
use crate::mcda::McdaMethod;
// greenpod-lint: allow(kernel-imports-tool) reason="PJRT scoring backend is an opt-in plugin; the engine is a deterministic offline artifact runner, not an ambient tool"
use crate::runtime::{ArtifactRegistry, PjrtTopsisEngine};
use crate::scheduler::{
    Estimator, ScoringBackend, DEFAULT_LIGHT_EPOCH_SECS,
};
use crate::workload::WorkloadExecutor;

use super::{
    BalancedAllocation, CarbonAware, FrameworkScheduler, LeastAllocated,
    McdaScorePlugin, NodeResourcesFit, SchedulerProfile, TieBreak,
};

/// Everything a profile build needs beyond the profile definition:
/// seeds, calibration, the MCDA configuration and the optional PJRT
/// artifact registry.
#[derive(Clone)]
pub struct BuildOptions {
    /// Tie-break RNG seed (stream-compatible with the legacy
    /// `DefaultK8sScheduler::new(seed)`).
    pub seed: u64,
    /// Weighting scheme for the built-in `greenpod` and
    /// `hybrid-topsis-balanced` profiles.
    pub scheme: WeightingScheme,
    /// MCDA method for the built-in `greenpod` profile (ablations).
    pub mcda_method: McdaMethod,
    /// When present (and the method is TOPSIS), MCDA plugins score
    /// through the AOT Pallas kernel via PJRT.
    pub pjrt: Option<Rc<ArtifactRegistry>>,
    /// Estimator calibration: seconds per light-epoch.
    pub light_epoch_secs: f64,
    /// Estimator contention coefficient β.
    pub contention_beta: f64,
    /// Grid carbon-intensity signal for the `carbon-aware` plugin
    /// (default: the config's `carbon` section — a constant at the
    /// eGRID scalar unless configured otherwise).
    pub carbon: CarbonSignal,
}

impl BuildOptions {
    pub fn new(cfg: &Config, scheme: WeightingScheme) -> Self {
        Self {
            seed: cfg.experiment.seed,
            scheme,
            mcda_method: McdaMethod::Topsis,
            pjrt: None,
            light_epoch_secs: DEFAULT_LIGHT_EPOCH_SECS,
            contention_beta: cfg.experiment.contention_beta,
            carbon: cfg.carbon.signal(&cfg.energy),
        }
    }

    /// Override the carbon-intensity signal (the carbon experiment
    /// crosses several signals over one config).
    pub fn with_carbon(mut self, carbon: CarbonSignal) -> Self {
        self.carbon = carbon;
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Calibrate the estimator from an executor's measured epoch cost.
    pub fn with_executor(mut self, executor: &WorkloadExecutor) -> Self {
        self.light_epoch_secs = executor.light_epoch_secs();
        self
    }

    pub fn with_method(mut self, method: McdaMethod) -> Self {
        self.mcda_method = method;
        self
    }

    pub fn with_pjrt(mut self, pjrt: Option<Rc<ArtifactRegistry>>) -> Self {
        self.pjrt = pjrt;
        self
    }

    fn estimator(&self, cfg: &Config) -> Estimator {
        Estimator::new(
            cfg.energy.clone(),
            self.light_epoch_secs,
            self.contention_beta,
        )
    }

    /// Scoring backend for an MCDA plugin using `method` — PJRT when an
    /// artifact registry is attached and the method is the kernel's
    /// TOPSIS, pure Rust otherwise.
    fn backend_for(&self, method: McdaMethod) -> ScoringBackend {
        match (&self.pjrt, method) {
            (Some(reg), McdaMethod::Topsis) => ScoringBackend::Pjrt(
                Box::new(PjrtTopsisEngine::new(reg.clone())),
            ),
            (_, m) => ScoringBackend::Rust(m),
        }
    }
}

/// Resolve a deprecated monolith-era scheduler name to its framework
/// profile (identity for every other name). The alias table lives in
/// [`crate::config::LEGACY_PROFILE_ALIASES`], next to the reserved
/// built-in names, so config validation rejects shadowing it.
fn resolve_alias(name: &str) -> &str {
    LEGACY_PROFILE_ALIASES
        .iter()
        .find(|(legacy, _)| *legacy == name)
        .map_or(name, |(_, canonical)| canonical)
}

/// Name → profile. Holds the config so user-defined profiles and the
/// energy model are available at build time.
pub struct ProfileRegistry {
    config: Config,
}

impl ProfileRegistry {
    pub fn new(config: &Config) -> Self {
        Self { config: config.clone() }
    }

    /// All registered profile names: built-ins first, then
    /// `Config::profiles` in declaration order.
    pub fn names(&self) -> Vec<String> {
        BUILTIN_PROFILE_NAMES
            .iter()
            .map(|s| s.to_string())
            .chain(self.config.profiles.iter().map(|p| p.name.clone()))
            .collect()
    }

    pub fn contains(&self, name: &str) -> bool {
        let name = resolve_alias(name);
        BUILTIN_PROFILE_NAMES.contains(&name)
            || self.config.profiles.iter().any(|p| p.name == name)
    }

    /// Materialize a registered profile as a scheduler. Deprecated
    /// monolith names resolve through [`LEGACY_PROFILE_ALIASES`].
    pub fn build(
        &self,
        name: &str,
        opts: &BuildOptions,
    ) -> Result<FrameworkScheduler> {
        let profile = match resolve_alias(name) {
            "greenpod" => SchedulerProfile::new("greenpod")
                .filter(Box::new(NodeResourcesFit))
                .score(
                    Box::new(
                        McdaScorePlugin::new(
                            opts.estimator(&self.config),
                            opts.scheme,
                        )
                        .with_backend(opts.backend_for(opts.mcda_method)),
                    ),
                    1.0,
                ),
            "default-k8s" => SchedulerProfile::new("default-k8s")
                .filter(Box::new(NodeResourcesFit))
                .score(Box::new(LeastAllocated), 1.0)
                .score(Box::new(BalancedAllocation), 1.0)
                .tie_break(TieBreak::SeededRandom),
            "carbon-aware" => SchedulerProfile::new("carbon-aware")
                .filter(Box::new(NodeResourcesFit))
                .score(
                    Box::new(CarbonAware::new(
                        opts.estimator(&self.config),
                        opts.carbon.clone(),
                    )),
                    1.0,
                ),
            "hybrid-topsis-balanced" => {
                SchedulerProfile::new("hybrid-topsis-balanced")
                    .filter(Box::new(NodeResourcesFit))
                    .score(
                        Box::new(
                            McdaScorePlugin::new(
                                opts.estimator(&self.config),
                                opts.scheme,
                            )
                            .with_backend(
                                opts.backend_for(McdaMethod::Topsis),
                            )
                            .with_percent_scale(),
                        ),
                        0.7,
                    )
                    .score(Box::new(BalancedAllocation), 0.3)
            }
            other => match self
                .config
                .profiles
                .iter()
                .find(|p| p.name == other)
            {
                Some(spec) => self.from_spec(spec, opts),
                None => bail!(
                    "unknown scheduling profile `{other}` (registered: {})",
                    self.names().join(", ")
                ),
            },
        };
        Ok(FrameworkScheduler::new(profile, opts.seed))
    }

    /// Materialize a config-defined profile.
    fn from_spec(
        &self,
        spec: &ProfileSpec,
        opts: &BuildOptions,
    ) -> SchedulerProfile {
        let mut profile = SchedulerProfile::new(spec.name.clone())
            .filter(Box::new(NodeResourcesFit))
            .tie_break(match spec.tie_break {
                ProfileTieBreak::LowestIndex => TieBreak::LowestIndex,
                ProfileTieBreak::SeededRandom => TieBreak::SeededRandom,
            });
        for plugin in &spec.plugins {
            profile = match &plugin.kind {
                ScorePluginKind::LeastAllocated => {
                    profile.score(Box::new(LeastAllocated), plugin.weight)
                }
                ScorePluginKind::BalancedAllocation => profile
                    .score(Box::new(BalancedAllocation), plugin.weight),
                ScorePluginKind::CarbonAware => profile.score(
                    Box::new(CarbonAware::new(
                        opts.estimator(&self.config),
                        opts.carbon.clone(),
                    )),
                    plugin.weight,
                ),
                ScorePluginKind::Mcda {
                    method,
                    scheme,
                    percent_scale,
                } => {
                    let mut mcda = McdaScorePlugin::new(
                        opts.estimator(&self.config),
                        *scheme,
                    )
                    .with_backend(opts.backend_for(*method));
                    if *percent_scale {
                        mcda = mcda.with_percent_scale();
                    }
                    profile.score(Box::new(mcda), plugin.weight)
                }
            };
        }
        profile
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{ClusterState, Pod};
    use crate::config::SchedulerKind;
    use crate::scheduler::Scheduler;
    use crate::workload::WorkloadClass;

    fn registry() -> ProfileRegistry {
        ProfileRegistry::new(&Config::paper_default())
    }

    fn opts() -> BuildOptions {
        BuildOptions::new(
            &Config::paper_default(),
            WeightingScheme::EnergyCentric,
        )
    }

    #[test]
    fn builtins_registered() {
        let r = registry();
        let names = r.names();
        assert!(names.len() >= 4);
        for name in BUILTIN_PROFILE_NAMES {
            assert!(r.contains(name), "{name} missing");
        }
        assert!(!r.contains("nope"));
        assert!(r.build("nope", &opts()).is_err());
    }

    #[test]
    fn every_builtin_schedules_the_paper_cluster() {
        let r = registry();
        let state =
            ClusterState::from_config(&Config::paper_default().cluster);
        for name in BUILTIN_PROFILE_NAMES {
            let mut sched = r.build(name, &opts()).unwrap();
            assert_eq!(sched.name(), name);
            let pod = Pod::new(
                0,
                WorkloadClass::Medium,
                SchedulerKind::Topsis,
                0.0,
                2,
            );
            let d = sched.schedule(&state, &pod);
            assert!(d.node.is_some(), "{name} failed to place");
            assert_eq!(d.scores.len(), 7, "{name}");
        }
    }

    #[test]
    fn config_defined_profile_builds() {
        use crate::config::{ProfileSpec, ScorePluginSpec};
        let mut cfg = Config::paper_default();
        cfg.profiles.push(ProfileSpec {
            name: "my-hybrid".into(),
            tie_break: ProfileTieBreak::LowestIndex,
            plugins: vec![
                ScorePluginSpec {
                    kind: ScorePluginKind::Mcda {
                        method: McdaMethod::Saw,
                        scheme: WeightingScheme::General,
                        percent_scale: true,
                    },
                    weight: 0.5,
                },
                ScorePluginSpec {
                    kind: ScorePluginKind::CarbonAware,
                    weight: 0.5,
                },
            ],
        });
        cfg.validate().unwrap();
        let r = ProfileRegistry::new(&cfg);
        assert!(r.contains("my-hybrid"));
        let mut sched = r
            .build("my-hybrid", &BuildOptions::new(&cfg, WeightingScheme::General))
            .unwrap();
        let state = ClusterState::from_config(&cfg.cluster);
        let pod =
            Pod::new(0, WorkloadClass::Light, SchedulerKind::Topsis, 0.0, 1);
        assert!(sched.schedule(&state, &pod).node.is_some());
    }

    #[test]
    fn carbon_aware_places_on_efficient_category() {
        use crate::cluster::NodeCategory;
        let r = registry();
        let state =
            ClusterState::from_config(&Config::paper_default().cluster);
        let mut sched = r.build("carbon-aware", &opts()).unwrap();
        let pod =
            Pod::new(0, WorkloadClass::Medium, SchedulerKind::Topsis, 0.0, 2);
        let d = sched.schedule(&state, &pod);
        assert_eq!(state.node(d.node.unwrap()).category, NodeCategory::A);
    }

    #[test]
    fn legacy_monolith_name_resolves_to_framework_profile() {
        // Deprecated alias back-compat: the retired GreenPodScheduler
        // reported "greenpod-topsis"; old configs/flags naming it must
        // build the `greenpod` profile, decision-for-decision.
        let r = registry();
        assert!(r.contains("greenpod-topsis"));
        assert!(!r.names().iter().any(|n| n == "greenpod-topsis"));
        let state =
            ClusterState::from_config(&Config::paper_default().cluster);
        let mut legacy = r.build("greenpod-topsis", &opts()).unwrap();
        let mut canonical = r.build("greenpod", &opts()).unwrap();
        assert_eq!(legacy.name(), "greenpod");
        for i in 0..5 {
            let pod = Pod::new(
                i,
                WorkloadClass::Medium,
                SchedulerKind::Topsis,
                0.0,
                2,
            );
            assert_eq!(
                legacy.schedule(&state, &pod).node,
                canonical.schedule(&state, &pod).node
            );
        }
    }

    // Behavior pins relocated from the retired monolith schedulers'
    // unit tests — the framework profiles are now the only
    // implementations of these semantics.

    fn build(name: &str, scheme: WeightingScheme) -> FrameworkScheduler {
        registry()
            .build(
                name,
                &BuildOptions::new(&Config::paper_default(), scheme),
            )
            .unwrap()
    }

    #[test]
    fn energy_centric_greenpod_prefers_category_a() {
        use crate::cluster::NodeCategory;
        let state =
            ClusterState::from_config(&Config::paper_default().cluster);
        let mut sched = build("greenpod", WeightingScheme::EnergyCentric);
        let pod =
            Pod::new(1, WorkloadClass::Medium, SchedulerKind::Topsis, 0.0, 2);
        let d = sched.schedule(&state, &pod);
        assert_eq!(
            state.node(d.node.unwrap()).category,
            NodeCategory::A,
            "scores: {:?}",
            d.scores
        );
    }

    #[test]
    fn performance_centric_greenpod_prefers_fast_nodes() {
        let state =
            ClusterState::from_config(&Config::paper_default().cluster);
        let mut sched =
            build("greenpod", WeightingScheme::PerformanceCentric);
        let pod =
            Pod::new(1, WorkloadClass::Medium, SchedulerKind::Topsis, 0.0, 2);
        let d = sched.schedule(&state, &pod);
        let node = state.node(d.node.unwrap());
        // B (1.0) or C (1.1) — never the slow A machines.
        assert!(node.speed_factor >= 1.0, "chose {:?}", node.name);
    }

    #[test]
    fn greenpod_respects_filter_and_reports_unschedulable_when_full() {
        let mut state =
            ClusterState::from_config(&Config::paper_default().cluster);
        let mut sched = build("greenpod", WeightingScheme::EnergyCentric);
        // Exhaust all three A nodes' memory so they are infeasible.
        for id in [0usize, 1, 2] {
            let mut hog = Pod::new(
                50 + id as u64,
                WorkloadClass::Light,
                SchedulerKind::Topsis,
                0.0,
                2,
            );
            hog.requests.cpu_millis = 100;
            hog.requests.memory_mib = state.free_memory(id) - 256;
            state.bind(&hog, id, 0.0).unwrap();
        }
        let pod = Pod::new(
            1,
            WorkloadClass::Complex,
            SchedulerKind::Topsis,
            0.0,
            2,
        );
        use crate::cluster::NodeCategory;
        let d = sched.schedule(&state, &pod);
        assert_ne!(state.node(d.node.unwrap()).category, NodeCategory::A);
        // Now fill every node entirely: unschedulable, no scores.
        for id in 0..state.nodes().len() {
            let mut hog = Pod::new(
                80 + id as u64,
                WorkloadClass::Light,
                SchedulerKind::Topsis,
                0.0,
                2,
            );
            hog.requests.cpu_millis = state.free_cpu(id);
            hog.requests.memory_mib = state.free_memory(id);
            state.bind(&hog, id, 0.0).unwrap();
        }
        let d = sched.schedule(&state, &pod);
        assert_eq!(d.node, None);
        assert!(d.scores.is_empty());
    }

    #[test]
    fn greenpod_scores_one_per_candidate_in_unit_interval() {
        let state =
            ClusterState::from_config(&Config::paper_default().cluster);
        let mut sched = build("greenpod", WeightingScheme::General);
        let pod =
            Pod::new(1, WorkloadClass::Light, SchedulerKind::Topsis, 0.0, 2);
        let d = sched.schedule(&state, &pod);
        assert_eq!(d.scores.len(), 7);
        for &(_, c) in &d.scores {
            assert!((0.0..=1.0 + 1e-9).contains(&c), "{:?}", d.scores);
        }
    }

    #[test]
    fn saw_method_also_picks_a_node() {
        let cfg = Config::paper_default();
        let mut sched = registry()
            .build(
                "greenpod",
                &BuildOptions::new(&cfg, WeightingScheme::EnergyCentric)
                    .with_method(McdaMethod::Saw),
            )
            .unwrap();
        let state = ClusterState::from_config(&cfg.cluster);
        let pod =
            Pod::new(1, WorkloadClass::Medium, SchedulerKind::Topsis, 0.0, 2);
        assert!(sched.schedule(&state, &pod).node.is_some());
    }

    #[test]
    fn default_k8s_spreads_to_least_allocated() {
        let mut state =
            ClusterState::from_config(&Config::paper_default().cluster);
        let mut sched = build("default-k8s", WeightingScheme::EnergyCentric);
        // Load node 3 (B) heavily; the next pod must not land there
        // while emptier same-shape nodes exist.
        let p = |id, class| {
            Pod::new(id, class, SchedulerKind::DefaultK8s, 0.0, 1)
        };
        state.bind(&p(1, WorkloadClass::Complex), 3, 0.0).unwrap();
        state.bind(&p(2, WorkloadClass::Medium), 3, 0.0).unwrap();
        let d = sched.schedule(&state, &p(3, WorkloadClass::Light));
        assert_ne!(d.node, Some(3));
        // And on the empty cluster, every feasible node is scored on
        // the kube 0–100 convention.
        let fresh =
            ClusterState::from_config(&Config::paper_default().cluster);
        let d = sched.schedule(&fresh, &p(4, WorkloadClass::Light));
        assert_eq!(d.scores.len(), 7);
        assert!(d.node.is_some());
        for &(_, score) in &d.scores {
            assert!((0.0..=100.0).contains(&score));
        }
    }
}
