//! The MCDA pipeline as one Score plugin — GreenPod's estimator /
//! decision-matrix / scoring-backend stage, behind the framework's
//! extension-point API.
//!
//! [`build_decision_problem`] is the canonical (and, since the
//! monolith schedulers' retirement, only) matrix builder — the plugin,
//! the benches and any external caller share one implementation.

use crate::cluster::{ClusterState, NodeId, Pod};
use crate::config::{WeightingScheme, BENEFIT_MASK, NUM_CRITERIA};
use crate::mcda::{Criterion, DecisionProblem, McdaMethod};
use crate::scheduler::{
    AdaptiveWeighting, Estimator, NodeEstimate, ScoringBackend,
};

use super::{CycleCtx, RowCache, ScorePlugin};

/// Build the paper's 5-criteria decision problem over a candidate set:
/// one estimator row per candidate (exec time, energy, free cores,
/// free memory, balance), directions from [`BENEFIT_MASK`].
pub fn build_decision_problem(
    estimator: &Estimator,
    weights: [f64; NUM_CRITERIA],
    state: &ClusterState,
    pod: &Pod,
    candidates: &[NodeId],
) -> DecisionProblem {
    let mut matrix = Vec::with_capacity(candidates.len() * NUM_CRITERIA);
    for &id in candidates {
        let e = estimator.estimate(state, state.node(id), pod);
        matrix.extend_from_slice(&[
            e.exec_time_s,
            e.energy_j,
            e.free_cpu_frac,
            e.free_mem_frac,
            e.balance,
        ]);
    }
    let criteria = (0..NUM_CRITERIA)
        .map(|i| {
            if BENEFIT_MASK[i] > 0.5 {
                Criterion::benefit(weights[i])
            } else {
                Criterion::cost(weights[i])
            }
        })
        .collect();
    DecisionProblem::new(matrix, candidates.len(), criteria)
}

/// GreenPod's scoring stage as a framework plugin: decision matrix over
/// the candidates, then MCDA closeness through the configured backend
/// (pure-Rust method or the AOT Pallas kernel via PJRT, degrading to
/// Rust TOPSIS with a counted fallback on runtime failure — the same
/// contract the failure-injection tests pin on the monolith).
///
/// Raw output is the MCDA score in `[0, 1]` (TOPSIS closeness). As a
/// profile's sole scorer that raw scale is kept — it is the published
/// per-candidate score of `SchedulingDecision` — so this plugin opts
/// out of the 0–100 convention by default; composed profiles enable
/// [`with_percent_scale`] to make it commensurable with the kube-style
/// 0–100 plugins through the normalize pass.
///
/// [`with_percent_scale`]: McdaScorePlugin::with_percent_scale
pub struct McdaScorePlugin {
    estimator: Estimator,
    scheme: WeightingScheme,
    backend: ScoringBackend,
    adaptive: Option<AdaptiveWeighting>,
    percent_scale: bool,
    fallbacks: u64,
    /// Version-stamped estimator rows (PreScore; see [`RowCache`]).
    /// Only the rows are cacheable — TOPSIS normalization couples
    /// candidates, so closeness is recombined every decision.
    cache: RowCache,
    rows: Vec<NodeEstimate>,
    /// Arena buffers threaded through `DecisionProblem` and reclaimed
    /// after scoring, so steady-state cycles reuse their capacity.
    matrix: Vec<f64>,
    criteria: Vec<Criterion>,
}

impl McdaScorePlugin {
    pub fn new(estimator: Estimator, scheme: WeightingScheme) -> Self {
        Self {
            estimator,
            scheme,
            backend: ScoringBackend::Rust(McdaMethod::Topsis),
            adaptive: None,
            percent_scale: false,
            fallbacks: 0,
            cache: RowCache::default(),
            rows: Vec::new(),
            matrix: Vec::new(),
            criteria: Vec::new(),
        }
    }

    pub fn with_backend(mut self, backend: ScoringBackend) -> Self {
        self.backend = backend;
        self
    }

    pub fn with_adaptive(mut self, adaptive: AdaptiveWeighting) -> Self {
        self.adaptive = Some(adaptive);
        self
    }

    /// Rescale closeness onto 0–100 in the normalize pass, for
    /// composition with kube-convention plugins.
    pub fn with_percent_scale(mut self) -> Self {
        self.percent_scale = true;
        self
    }

    /// The weights used for a decision (static scheme or adaptive).
    fn effective_weights(&self, state: &ClusterState) -> [f64; NUM_CRITERIA] {
        match &self.adaptive {
            Some(a) => a.weights(state, self.scheme),
            None => self.scheme.weights(),
        }
    }
}

impl ScorePlugin for McdaScorePlugin {
    fn name(&self) -> &'static str {
        "mcda"
    }

    fn score(
        &mut self,
        ctx: &CycleCtx,
        state: &ClusterState,
        pod: &Pod,
        candidates: &[NodeId],
        out: &mut Vec<f64>,
    ) {
        let weights = self.effective_weights(state);
        // PreScore: estimator rows, served from the version-stamped
        // cache when the cycle allows reuse. The matrix assembly below
        // is the same per-row float sequence as
        // [`build_decision_problem`], so the two paths are
        // bit-identical (the differential property pins this).
        self.cache.fill(
            &self.estimator,
            state,
            pod,
            candidates,
            ctx.reuse_rows,
            &mut self.rows,
        );
        let mut matrix = std::mem::take(&mut self.matrix);
        matrix.clear();
        for e in &self.rows {
            matrix.extend_from_slice(&[
                e.exec_time_s,
                e.energy_j,
                e.free_cpu_frac,
                e.free_mem_frac,
                e.balance,
            ]);
        }
        let mut criteria = std::mem::take(&mut self.criteria);
        criteria.clear();
        criteria.extend((0..NUM_CRITERIA).map(|i| {
            if BENEFIT_MASK[i] > 0.5 {
                Criterion::benefit(weights[i])
            } else {
                Criterion::cost(weights[i])
            }
        }));
        let problem = DecisionProblem::new(matrix, candidates.len(), criteria);
        out.clear();
        match &mut self.backend {
            ScoringBackend::Rust(method) => {
                out.extend(method.scores(&problem));
            }
            ScoringBackend::Pjrt(engine) => match engine.closeness(&problem) {
                Ok(s) => out.extend(s),
                Err(_) => {
                    // Degrade gracefully: the artifact math and the
                    // Rust math are the same TOPSIS.
                    self.fallbacks += 1;
                    out.extend(McdaMethod::Topsis.scores(&problem));
                }
            },
        }
        // Reclaim the arena buffers for the next cycle.
        self.matrix = problem.matrix;
        self.criteria = problem.criteria;
    }

    fn normalize(
        &self,
        _state: &ClusterState,
        _pod: &Pod,
        scores: &mut [f64],
    ) {
        if self.percent_scale {
            for s in scores.iter_mut() {
                *s *= 100.0;
            }
        }
    }

    fn fallbacks(&self) -> u64 {
        self.fallbacks
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ClusterConfig, EnergyModelConfig, SchedulerKind};
    use crate::workload::WorkloadClass;

    fn setup() -> (ClusterState, McdaScorePlugin) {
        let state = ClusterState::from_config(&ClusterConfig::paper_default());
        let plug = McdaScorePlugin::new(
            Estimator::with_defaults(EnergyModelConfig::default()),
            WeightingScheme::EnergyCentric,
        );
        (state, plug)
    }

    fn pod() -> Pod {
        Pod::new(0, WorkloadClass::Medium, SchedulerKind::Topsis, 0.0, 2)
    }

    #[test]
    fn raw_scores_are_closeness_in_unit_interval() {
        let (state, mut plug) = setup();
        let candidates: Vec<usize> = (0..state.nodes().len()).collect();
        let mut scores = Vec::new();
        plug.score(&CycleCtx::default(), &state, &pod(), &candidates, &mut scores);
        assert_eq!(scores.len(), candidates.len());
        for &s in &scores {
            assert!((0.0..=1.0 + 1e-9).contains(&s), "{scores:?}");
        }
        // No percent scale by default: normalize is the identity.
        let mut normed = scores.clone();
        plug.normalize(&state, &pod(), &mut normed);
        assert_eq!(scores, normed);
    }

    #[test]
    fn percent_scale_maps_to_0_100() {
        let (state, plug) = setup();
        let mut plug = plug.with_percent_scale();
        let candidates: Vec<usize> = (0..state.nodes().len()).collect();
        let mut scores = Vec::new();
        plug.score(&CycleCtx::default(), &state, &pod(), &candidates, &mut scores);
        plug.normalize(&state, &pod(), &mut scores);
        for &s in &scores {
            assert!((0.0..=100.0 + 1e-6).contains(&s), "{scores:?}");
        }
        assert!(scores.iter().any(|&s| s > 1.0), "{scores:?}");
    }

    #[test]
    fn plugin_scores_match_direct_matrix_and_method() {
        // Self-consistency of the one remaining pipeline (this test
        // pinned the plugin against the retired monolith's
        // `decision_problem` until the monolith was deleted): scoring
        // through the plugin must equal building the matrix with
        // `build_decision_problem` and running TOPSIS on it directly,
        // bit for bit.
        let (state, mut plug) = setup();
        let candidates = state.feasible_nodes(pod().requests);
        let mut scores = Vec::new();
        plug.score(
            &CycleCtx::default(),
            &state,
            &pod(),
            &candidates,
            &mut scores,
        );
        let problem = build_decision_problem(
            &Estimator::with_defaults(EnergyModelConfig::default()),
            WeightingScheme::EnergyCentric.weights(),
            &state,
            &pod(),
            &candidates,
        );
        let direct = McdaMethod::Topsis.scores(&problem);
        assert_eq!(scores.len(), direct.len());
        for (i, (a, b)) in scores.iter().zip(&direct).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "candidate {i}: {a} vs {b}");
        }
    }
}
