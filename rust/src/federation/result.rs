//! Aggregated federation results: one [`RunResult`] per region plus
//! the dispatch assignment log, with federation-wide roll-ups (total
//! joules/gCO₂, queue-wait stats, scaling counts) the experiment
//! drivers and the JSONL event stream read.

use crate::cluster::PodId;
use crate::config::SchedulerKind;
use crate::metrics::Summary;
use crate::simulation::RunResult;

/// One dispatch decision: pod → region, at the pod's arrival time.
#[derive(Debug, Clone, PartialEq)]
pub struct RegionAssignment {
    pub pod: PodId,
    /// Index into [`FederationResult::regions`].
    pub region: usize,
    pub at_s: f64,
}

/// One region's outcome: its name and its own complete [`RunResult`]
/// (records, meter/CO₂ ledger, events, scaling, node timeline).
#[derive(Debug)]
pub struct RegionResult {
    pub name: String,
    pub run: RunResult,
}

/// The outcome of one federated run.
#[derive(Debug)]
pub struct FederationResult {
    /// Per-region results, in region order.
    pub regions: Vec<RegionResult>,
    /// Dispatch log, in arrival order (every admitted pod exactly
    /// once — the conservation property pins this).
    pub assignments: Vec<RegionAssignment>,
    /// High-water mark of the engine's live pod vector. Eager runs
    /// materialize every pod up front, so this equals the trace
    /// length; streaming runs ([`FederationEngine::run_source`])
    /// recycle completed slots, so it is bounded by the in-flight pod
    /// count — the memory claim the bounded-replay test asserts.
    ///
    /// [`FederationEngine::run_source`]: super::FederationEngine::run_source
    pub peak_live_pods: usize,
}

impl FederationResult {
    /// Look up one region by name (panics if absent).
    pub fn region(&self, name: &str) -> &RegionResult {
        self.regions
            .iter()
            .find(|r| r.name == name)
            .expect("region in federation")
    }

    /// Completed pods across all regions.
    pub fn completed(&self) -> usize {
        self.regions.iter().map(|r| r.run.records.len()).sum()
    }

    /// Unschedulable pods across all regions.
    pub fn unschedulable(&self) -> usize {
        self.regions.iter().map(|r| r.run.unschedulable.len()).sum()
    }

    /// Pod-attributed energy (kJ) for `kind`, summed over regions.
    pub fn total_kj(&self, kind: SchedulerKind) -> f64 {
        self.regions.iter().map(|r| r.run.meter.total_kj(kind)).sum()
    }

    /// Unattributed node-idle energy (kJ), summed over regions.
    pub fn idle_kj(&self) -> f64 {
        self.regions.iter().map(|r| r.run.idle_kj()).sum()
    }

    /// Pod-attributed CO₂ (grams, each region's ledger integrated
    /// against its own signal), summed over regions.
    pub fn pod_co2_g(&self, kind: SchedulerKind) -> f64 {
        self.regions
            .iter()
            .map(|r| r.run.meter.total_co2_g(kind))
            .sum()
    }

    /// Idle-floor CO₂ (grams), summed over regions.
    pub fn idle_co2_g(&self) -> f64 {
        self.regions.iter().map(|r| r.run.meter.idle_co2_g()).sum()
    }

    /// pod + idle grams — the comparable federation-wide CO₂ total.
    pub fn total_co2_g(&self, kind: SchedulerKind) -> f64 {
        self.pod_co2_g(kind) + self.idle_co2_g()
    }

    /// Queue-wait distribution for `kind` across every region's
    /// completed pods.
    pub fn queue_wait_summary(&self, kind: SchedulerKind) -> Summary {
        let waits: Vec<f64> = self
            .regions
            .iter()
            .flat_map(|r| {
                r.run
                    .records
                    .iter()
                    .filter(|rec| rec.scheduler == kind)
                    .map(|rec| rec.wait_s)
            })
            .collect();
        Summary::of(&waits)
    }

    /// Scaling actions of one kind across all regions.
    pub fn scaling_count(&self, kind: &str) -> usize {
        self.regions.iter().map(|r| r.run.scaling_count(kind)).sum()
    }

    /// Latest completion across regions.
    pub fn makespan_s(&self) -> f64 {
        self.regions
            .iter()
            .map(|r| r.run.makespan_s)
            .fold(0.0, f64::max)
    }
}
