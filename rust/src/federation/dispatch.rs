//! The federation's dispatch extension point: route each arriving pod
//! to a region, *before* the region's own scheduling profile places it
//! on a node.
//!
//! A [`Dispatcher`] is consulted exactly once per pod, at the pod's
//! arrival event, with a read-only [`RegionSnapshot`] of every
//! region's live state; the decision is final (no re-dispatch — a pod
//! that cannot be placed waits in its region's pending queue). All
//! three shipped policies are deterministic: ties resolve to the
//! lowest region index, so a run is a pure function of the trace and
//! the seeds.

use crate::cluster::{ClusterState, Pod};
use crate::config::DispatchKind;
use crate::energy::CarbonSignal;

/// Read-only view of one region at a dispatch decision.
pub struct RegionSnapshot<'a> {
    /// Region index (the dispatcher's return vocabulary).
    pub index: usize,
    pub name: &'a str,
    /// Live cluster state (readiness, per-node allocation).
    pub state: &'a ClusterState,
    /// Pods dispatched to the region and not yet bound.
    pub pending_pods: usize,
    /// Σ CPU requests of those pending pods (millicores).
    pub pending_cpu_millis: u64,
    /// Σ memory requests of those pending pods (MiB).
    pub pending_memory_mib: u64,
    /// Pods currently executing in the region.
    pub running_pods: usize,
    /// The region's grid carbon-intensity signal.
    pub carbon: &'a CarbonSignal,
}

impl RegionSnapshot<'_> {
    /// Whether the region still has headroom for `pod`: aggregate free
    /// CPU and memory across Ready nodes, minus what the region's
    /// already-dispatched pending pods will claim, covers the pod's
    /// requests. Aggregate headroom is a deliberate over-approximation
    /// of per-node bin-packing — a dispatch heuristic, not a placement
    /// guarantee (an unplaceable pod simply waits in the region
    /// queue). Integer arithmetic keeps it exactly mirrorable by the
    /// Python oracle.
    pub fn has_capacity(&self, pod: &Pod) -> bool {
        let mut ready = 0usize;
        let mut free_cpu = 0u64;
        let mut free_mem = 0u64;
        for id in 0..self.state.nodes().len() {
            if self.state.node(id).ready {
                ready += 1;
                free_cpu += self.state.free_cpu(id);
                free_mem += self.state.free_memory(id);
            }
        }
        // Zero-capacity guard (the aggregate analogue of the
        // NaN-guarded utilization ratios): with no Ready node the
        // aggregate comparison alone would wave a zero-request pod
        // through (`0 >= 0`), routing it to a region that cannot bind
        // anything.
        ready > 0
            && free_cpu >= self.pending_cpu_millis + pod.requests.cpu_millis
            && free_mem >= self.pending_memory_mib + pod.requests.memory_mib
    }

    /// The region's grid intensity at virtual time `now_s` (gCO₂/J).
    pub fn intensity_at(&self, now_s: f64) -> f64 {
        self.carbon.at(now_s)
    }
}

/// The dispatch extension point.
pub trait Dispatcher {
    /// Policy name, for tables and JSONL attribution.
    fn name(&self) -> &'static str;

    /// Route an arriving pod: returns the index of the chosen region
    /// (must be `< regions.len()`; the engine asserts it).
    fn dispatch(
        &mut self,
        now_s: f64,
        pod: &Pod,
        regions: &[RegionSnapshot],
    ) -> usize;
}

/// Cycle through regions in index order, blind to state — the
/// baseline every smarter policy is measured against.
#[derive(Debug, Default)]
pub struct RoundRobin {
    next: usize,
}

impl RoundRobin {
    pub fn new() -> Self {
        Self::default()
    }
}

impl Dispatcher for RoundRobin {
    fn name(&self) -> &'static str {
        "round-robin"
    }

    fn dispatch(
        &mut self,
        _now_s: f64,
        _pod: &Pod,
        regions: &[RegionSnapshot],
    ) -> usize {
        debug_assert!(!regions.is_empty(), "dispatch with zero regions");
        let r = self.next % regions.len();
        self.next += 1;
        r
    }
}

/// The region with the fewest pending (dispatched, unplaced) pods —
/// join-shortest-queue over dispatch backlog; lowest index on ties.
#[derive(Debug, Default)]
pub struct LeastPending;

impl LeastPending {
    pub fn new() -> Self {
        Self
    }
}

impl Dispatcher for LeastPending {
    fn name(&self) -> &'static str {
        "least-pending"
    }

    fn dispatch(
        &mut self,
        _now_s: f64,
        _pod: &Pod,
        regions: &[RegionSnapshot],
    ) -> usize {
        least_pending_index(regions)
    }
}

/// Lowest-index region with the minimal pending count (strict `<`
/// keeps the first minimum — the tie-break every policy shares).
fn least_pending_index(regions: &[RegionSnapshot]) -> usize {
    let mut best = 0;
    for i in 1..regions.len() {
        if regions[i].pending_pods < regions[best].pending_pods {
            best = i;
        }
    }
    best
}

/// Price each region at `signal.at(now)` and send the pod to the
/// currently **cleanest region with capacity** (strictly lower
/// intensity wins; lowest index on ties). When no region has headroom
/// the pod must queue somewhere — it falls back to the least-pending
/// region, spreading backlog instead of piling it onto the clean
/// region's queue.
#[derive(Debug, Default)]
pub struct CarbonGreedy;

impl CarbonGreedy {
    pub fn new() -> Self {
        Self
    }
}

impl Dispatcher for CarbonGreedy {
    fn name(&self) -> &'static str {
        "carbon-greedy"
    }

    fn dispatch(
        &mut self,
        now_s: f64,
        pod: &Pod,
        regions: &[RegionSnapshot],
    ) -> usize {
        let mut best: Option<(usize, f64)> = None;
        for r in regions {
            if !r.has_capacity(pod) {
                continue;
            }
            let g = r.intensity_at(now_s);
            match best {
                Some((_, bg)) if g >= bg => {}
                _ => best = Some((r.index, g)),
            }
        }
        match best {
            Some((i, _)) => i,
            None => least_pending_index(regions),
        }
    }
}

/// Materialize a config-file dispatch policy.
pub fn build_dispatcher(kind: DispatchKind) -> Box<dyn Dispatcher> {
    match kind {
        DispatchKind::RoundRobin => Box::new(RoundRobin::new()),
        DispatchKind::LeastPending => Box::new(LeastPending::new()),
        DispatchKind::CarbonGreedy => Box::new(CarbonGreedy::new()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ClusterConfig, SchedulerKind};
    use crate::workload::WorkloadClass;

    fn pod(class: WorkloadClass) -> Pod {
        Pod::new(0, class, SchedulerKind::Topsis, 0.0, 1)
    }

    /// Two paper clusters with distinct constant signals and
    /// configurable pending backlog.
    fn states() -> (ClusterState, ClusterState) {
        let cfg = ClusterConfig::paper_default();
        (ClusterState::from_config(&cfg), ClusterState::from_config(&cfg))
    }

    fn snaps<'a>(
        a: &'a ClusterState,
        b: &'a ClusterState,
        pending: [usize; 2],
        pending_cpu: [u64; 2],
        carbon: &'a [CarbonSignal; 2],
    ) -> [RegionSnapshot<'a>; 2] {
        [
            RegionSnapshot {
                index: 0,
                name: "a",
                state: a,
                pending_pods: pending[0],
                pending_cpu_millis: pending_cpu[0],
                pending_memory_mib: 0,
                running_pods: 0,
                carbon: &carbon[0],
            },
            RegionSnapshot {
                index: 1,
                name: "b",
                state: b,
                pending_pods: pending[1],
                pending_cpu_millis: pending_cpu[1],
                pending_memory_mib: 0,
                running_pods: 0,
                carbon: &carbon[1],
            },
        ]
    }

    #[test]
    fn round_robin_cycles_regions() {
        let (a, b) = states();
        let sig = [CarbonSignal::constant(1.0), CarbonSignal::constant(1.0)];
        let s = snaps(&a, &b, [0, 0], [0, 0], &sig);
        let mut rr = RoundRobin::new();
        let order: Vec<usize> = (0..5)
            .map(|_| rr.dispatch(0.0, &pod(WorkloadClass::Light), &s))
            .collect();
        assert_eq!(order, vec![0, 1, 0, 1, 0]);
    }

    #[test]
    fn least_pending_picks_shortest_queue_lowest_index_on_ties() {
        let (a, b) = states();
        let sig = [CarbonSignal::constant(1.0), CarbonSignal::constant(1.0)];
        let mut lp = LeastPending::new();
        let s = snaps(&a, &b, [3, 1], [0, 0], &sig);
        assert_eq!(lp.dispatch(0.0, &pod(WorkloadClass::Light), &s), 1);
        let s = snaps(&a, &b, [2, 2], [0, 0], &sig);
        assert_eq!(lp.dispatch(0.0, &pod(WorkloadClass::Light), &s), 0);
    }

    #[test]
    fn carbon_greedy_prefers_cleanest_region_with_capacity() {
        let (a, b) = states();
        // Region 1 is cleaner.
        let sig = [CarbonSignal::constant(3.0), CarbonSignal::constant(1.0)];
        let mut cg = CarbonGreedy::new();
        let s = snaps(&a, &b, [0, 0], [0, 0], &sig);
        assert_eq!(cg.dispatch(0.0, &pod(WorkloadClass::Complex), &s), 1);
        // Clean region full (pending claims its whole CPU pool):
        // fall through to the dirty one.
        let full = a.nodes().iter().map(|n| n.cpu_millis).sum::<u64>();
        let s = snaps(&a, &b, [0, 16], [0, full], &sig);
        assert_eq!(cg.dispatch(0.0, &pod(WorkloadClass::Complex), &s), 0);
        // Every region full: least-pending fallback.
        let s = snaps(&a, &b, [9, 16], [full, full], &sig);
        assert_eq!(cg.dispatch(0.0, &pod(WorkloadClass::Complex), &s), 0);
        // Equal intensity: lowest index wins.
        let sig = [CarbonSignal::constant(2.0), CarbonSignal::constant(2.0)];
        let s = snaps(&a, &b, [0, 0], [0, 0], &sig);
        assert_eq!(cg.dispatch(0.0, &pod(WorkloadClass::Light), &s), 0);
    }

    #[test]
    fn capacity_heuristic_counts_pending_and_readiness() {
        let cfg = ClusterConfig::paper_default();
        let mut state = ClusterState::from_config(&cfg);
        let sig = CarbonSignal::constant(1.0);
        let complex = pod(WorkloadClass::Complex);
        let mut snap = RegionSnapshot {
            index: 0,
            name: "a",
            state: &state,
            pending_pods: 0,
            pending_cpu_millis: 0,
            pending_memory_mib: 0,
            running_pods: 0,
            carbon: &sig,
        };
        assert!(snap.has_capacity(&complex));
        // Pending claims eat the headroom.
        let total = state.nodes().iter().map(|n| n.cpu_millis).sum::<u64>();
        snap.pending_cpu_millis = total;
        assert!(!snap.has_capacity(&complex));
        snap.pending_cpu_millis = total - complex.requests.cpu_millis;
        assert!(snap.has_capacity(&complex));
        // NotReady nodes do not count toward headroom.
        drop(snap);
        for id in 0..state.nodes().len() {
            state.set_ready(id, false, 0.0);
        }
        let snap = RegionSnapshot {
            index: 0,
            name: "a",
            state: &state,
            pending_pods: 0,
            pending_cpu_millis: 0,
            pending_memory_mib: 0,
            running_pods: 0,
            carbon: &sig,
        };
        assert!(!snap.has_capacity(&complex));
    }

    #[test]
    fn zero_capacity_region_has_no_headroom_even_for_zero_request_pod() {
        let cfg = ClusterConfig::paper_default();
        let mut state = ClusterState::from_config(&cfg);
        for id in 0..state.nodes().len() {
            state.set_ready(id, false, 0.0);
        }
        let sig = CarbonSignal::constant(1.0);
        let snap = RegionSnapshot {
            index: 0,
            name: "a",
            state: &state,
            pending_pods: 0,
            pending_cpu_millis: 0,
            pending_memory_mib: 0,
            running_pods: 0,
            carbon: &sig,
        };
        // A pod with zero requests would pass the aggregate comparison
        // (`0 >= 0`) without the Ready-node guard, and carbon-greedy
        // would route it to a region that cannot bind anything.
        let mut zero = pod(WorkloadClass::Light);
        zero.requests.cpu_millis = 0;
        zero.requests.memory_mib = 0;
        assert!(!snap.has_capacity(&zero));
        // Carbon-greedy therefore falls back to least-pending instead
        // of picking the clean-but-empty region.
        let full = ClusterState::from_config(&cfg);
        let clean = CarbonSignal::constant(0.5);
        let dirty = CarbonSignal::constant(5.0);
        let s = [
            RegionSnapshot {
                index: 0,
                name: "empty-clean",
                state: &state,
                pending_pods: 0,
                pending_cpu_millis: 0,
                pending_memory_mib: 0,
                running_pods: 0,
                carbon: &clean,
            },
            RegionSnapshot {
                index: 1,
                name: "ready-dirty",
                state: &full,
                pending_pods: 0,
                pending_cpu_millis: 0,
                pending_memory_mib: 0,
                running_pods: 0,
                carbon: &dirty,
            },
        ];
        let mut cg = CarbonGreedy::new();
        assert_eq!(cg.dispatch(0.0, &zero, &s), 1);
    }

    #[test]
    fn config_kinds_build_their_dispatchers() {
        for kind in DispatchKind::ALL {
            let d = build_dispatcher(kind);
            assert_eq!(d.name(), kind.label());
        }
    }
}
