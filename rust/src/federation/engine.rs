//! The federation engine: N per-region event kernels under one shared
//! virtual clock and merged event order — **the one event loop in the
//! tree**.
//!
//! `SimulationEngine::run` is a thin wrapper around a 1-region
//! federation: the merged queue degenerates to the plain kernel queue
//! (identical `(time, priority, seq)` assignments), every dispatch
//! resolves to region 0, and all arithmetic is the same float ops in
//! the same order — so the delegation is record-for-record
//! bit-identical to the retired standalone loop, pinned by the
//! golden-fixture replays and
//! `prop_federation_single_region_is_bit_identical_to_plain_engine`.
//!
//! The loop seeds arrivals in pod order, then each region's node-churn
//! schedule in region order; advances the meter at every event; and
//! consults each region's autoscaler at t = 0 and after every event
//! that leaves no same-instant scheduling cycle outstanding in its
//! region. The one federation-specific step is arrival handling: the
//! [`Dispatcher`] resolves the pod's region at the arrival event's pop
//! (seeing every region's live state), after which the pod belongs to
//! that region's pending queue for good.

use std::collections::{BTreeMap, VecDeque};

use crate::autoscaler::{
    Autoscaler, AutoscalerPolicy, Observation, ScalingAction,
};
use crate::cluster::{ClusterState, Pod, PodPhase};
use crate::config::{Config, FederationConfig, SchedulerKind};
use crate::energy::{CarbonSignal, EnergyMeter};
use crate::scheduler::Scheduler;
use crate::simulation::{
    contention_factor, EventRecord, FedEventQueue, NodeChange,
    NodeCountSample, PodRecord, RunResult, ScalingRecord, SimEvent,
    VirtualClock,
};
use crate::workload::WorkloadExecutor;

use super::dispatch::{Dispatcher, RegionSnapshot};
use super::result::{FederationResult, RegionAssignment, RegionResult};
use super::source::ArrivalSource;

/// One federated cluster: its own full config (cluster topology +
/// energy model), regional carbon-intensity signal, and optional
/// autoscaling policy.
#[derive(Debug, Clone)]
pub struct RegionSpec {
    pub name: String,
    pub config: Config,
    pub carbon: CarbonSignal,
    pub autoscaler: Option<AutoscalerPolicy>,
    /// Scheduled node-membership changes for this region (churn
    /// injection; empty = the fixed configured cluster).
    pub node_events: Vec<NodeChange>,
}

impl RegionSpec {
    /// A region around `config`, its signal taken from the config's
    /// `carbon` section, no autoscaler.
    pub fn new(name: &str, config: Config) -> Self {
        let carbon = config.carbon.signal(&config.energy);
        Self {
            name: name.to_string(),
            config,
            carbon,
            autoscaler: None,
            node_events: Vec::new(),
        }
    }

    /// Override the region's carbon signal.
    pub fn with_carbon(mut self, carbon: CarbonSignal) -> Self {
        self.carbon = carbon;
        self
    }

    /// Attach an autoscaling policy.
    pub fn with_autoscaler(mut self, policy: AutoscalerPolicy) -> Self {
        self.autoscaler = Some(policy);
        self
    }

    /// Attach a node-churn schedule.
    pub fn with_node_events(mut self, events: Vec<NodeChange>) -> Self {
        self.node_events = events;
        self
    }

    /// Materialize a validated config-file `federation` section into
    /// runtime region specs: each region inherits `base`'s energy
    /// model, experiment knobs and profiles, with the cluster and
    /// carbon sections replaced by the region entry's own, and the
    /// optional autoscaler built around the region's cluster and
    /// signal.
    pub fn from_federation_config(
        base: &Config,
        fed: &FederationConfig,
    ) -> anyhow::Result<Vec<RegionSpec>> {
        fed.regions
            .iter()
            .map(|rc| {
                let mut config = base.clone();
                config.cluster = rc.cluster.clone();
                config.carbon = rc.carbon.clone();
                config.federation = None;
                let carbon = config.carbon.build_signal(&config.energy)?;
                let autoscaler = match &rc.autoscaler {
                    Some(a) => Some(AutoscalerPolicy::Threshold(
                        crate::autoscaler::ThresholdConfig::from_region(
                            a,
                            &config.cluster,
                            &carbon,
                        )?,
                    )),
                    None => None,
                };
                Ok(RegionSpec {
                    name: rc.name.clone(),
                    config,
                    carbon,
                    autoscaler,
                    node_events: Vec::new(),
                })
            })
            .collect()
    }
}

/// One region's scheduler slots — the same per-pod owner split
/// (`Pod::scheduler`) as `SimulationEngine::run`.
pub struct RegionSchedulers {
    pub topsis: Box<dyn Scheduler>,
    pub default: Box<dyn Scheduler>,
}

/// Engine-level knobs (the federated counterpart of
/// `SimulationParams`; per-region node churn lives on
/// [`RegionSpec::node_events`]).
#[derive(Debug, Clone)]
pub struct FederationParams {
    pub contention_beta: f64,
    /// Seed for per-pod dataset generation in real-execution mode.
    pub seed: u64,
    /// Common idle-billing horizon (s): every region's meter advances
    /// to `max(horizon, last event)`, so per-region idle totals
    /// compare over one window. `None` = each region bills to the
    /// run's final virtual time.
    pub billing_horizon_s: Option<f64>,
    /// Differential-testing knob: run every scheduling cycle even when
    /// no node changed and no pod arrived in the region since its
    /// previous cycle, instead of short-circuiting the provably-futile
    /// retry pass. The skip is placement-neutral by construction (an
    /// unchanged cluster re-fails every pending pod identically); the
    /// regression test pins forced ≡ guarded bitwise.
    pub force_full_cycles: bool,
}

impl Default for FederationParams {
    fn default() -> Self {
        Self {
            contention_beta: 0.35,
            seed: 0,
            billing_horizon_s: None,
            force_full_cycles: false,
        }
    }
}

impl FederationParams {
    pub fn with_beta_and_seed(contention_beta: f64, seed: u64) -> Self {
        Self { contention_beta, seed, ..Self::default() }
    }
}

/// Bookkeeping for a bound, executing pod (indexed by pod *index*).
struct RunningPod {
    node: usize,
    start_s: f64,
}

/// Per-region mutable run state — the federated `RunState`.
struct RegionRun {
    state: ClusterState,
    meter: EnergyMeter,
    records: Vec<PodRecord>,
    pending: VecDeque<usize>,
    /// BTreeMap rather than HashMap: never iterated today, but an
    /// ordered map keeps any future walk deterministic by default.
    running: BTreeMap<usize, RunningPod>,
    events: Vec<EventRecord>,
    scaling: Vec<ScalingRecord>,
    node_timeline: Vec<NodeCountSample>,
    /// Fire time of the region's earliest pending `AutoscaleTick`.
    next_tick: Option<f64>,
    makespan: f64,
    cycle_queued: bool,
    autoscaler: Option<Box<dyn Autoscaler>>,
    /// Σ requests of the pending queue (the dispatcher's headroom
    /// signal).
    pending_cpu_millis: u64,
    pending_memory_mib: u64,
    /// Arena for the region autoscaler's pending-wait vector.
    waits_buf: Vec<f64>,
    /// `state.mutations()` as of the end of the region's previous
    /// scheduling cycle (`u64::MAX` = no cycle yet, never matches).
    last_cycle_mutations: u64,
    /// Whether any pod arrived in this region since its previous cycle.
    arrivals_since_cycle: bool,
    /// Scheduling cycles that drained the pending queue.
    cycles_run: u64,
    /// Scheduling cycles short-circuited by the no-change guard.
    cycles_skipped: u64,
}

impl RegionRun {
    fn new(spec: &RegionSpec) -> Self {
        Self {
            state: ClusterState::from_config(&spec.config.cluster),
            meter: EnergyMeter::new().with_carbon(spec.carbon.clone()),
            records: Vec::new(),
            pending: VecDeque::new(),
            running: BTreeMap::new(),
            events: Vec::new(),
            scaling: Vec::new(),
            node_timeline: Vec::new(),
            next_tick: None,
            makespan: 0.0,
            cycle_queued: false,
            autoscaler: None,
            pending_cpu_millis: 0,
            pending_memory_mib: 0,
            waits_buf: Vec::new(),
            last_cycle_mutations: u64::MAX,
            arrivals_since_cycle: false,
            cycles_run: 0,
            cycles_skipped: 0,
        }
    }

    fn sample_nodes(&mut self, at_s: f64) {
        self.node_timeline.push(NodeCountSample {
            at_s,
            ready_nodes: self.state.ready_nodes(),
            total_nodes: self.state.nodes().len(),
        });
    }
}

/// When a scaling action scheduled for `at_s` actually takes effect:
/// never before `now`. Actions carried over from a past consultation
/// fire immediately rather than rewriting history — the autoscaler
/// contract every policy relies on.
fn effective_at(at_s: f64, now: f64) -> f64 {
    // greenpod-lint: allow(silent-clamp) reason="past-scheduled scaling actions fire now by contract; asserting would reject valid carried-over decisions"
    at_s.max(now)
}

/// The federation engine. Owns every region's state for one run.
pub struct FederationEngine<'a> {
    regions: &'a [RegionSpec],
    params: FederationParams,
    executor: &'a WorkloadExecutor,
}

impl<'a> FederationEngine<'a> {
    pub fn new(
        regions: &'a [RegionSpec],
        params: FederationParams,
        executor: &'a WorkloadExecutor,
    ) -> Self {
        assert!(!regions.is_empty(), "federation needs at least one region");
        Self { regions, params, executor }
    }

    /// Run the federation: pods arrive per their `arrival_s`, the
    /// dispatcher routes each to a region at its arrival event, and
    /// each region's kernel places/completes its own pods under the
    /// shared clock.
    pub fn run(
        &self,
        pods: Vec<Pod>,
        dispatcher: &mut dyn Dispatcher,
        scheds: &mut [RegionSchedulers],
    ) -> FederationResult {
        let mut pairs: Vec<(&mut dyn Scheduler, &mut dyn Scheduler)> = scheds
            .iter_mut()
            .map(|s| {
                (s.topsis.as_mut() as &mut dyn Scheduler, s.default.as_mut())
            })
            .collect();
        self.run_refs(pods, dispatcher, &mut pairs)
    }

    /// Streaming entry point: pods are pulled lazily from `source` in
    /// nondecreasing arrival order, admitted into the merged queue as
    /// virtual time reaches them, and their pod-vector slots recycled
    /// at completion — bit-identical to [`Self::run`] on the same
    /// arrivals (the admission argument lives on
    /// [`crate::federation::ArrivalSource`]; the differential property
    /// pins the whole-engine consequence), with peak live pods bounded
    /// by the in-flight count instead of the trace length. Errors
    /// surface source failures (I/O, malformed or out-of-order
    /// entries); an in-memory run cannot fail.
    pub fn run_source(
        &self,
        source: &mut dyn ArrivalSource,
        dispatcher: &mut dyn Dispatcher,
        scheds: &mut [RegionSchedulers],
    ) -> anyhow::Result<FederationResult> {
        let mut pairs: Vec<(&mut dyn Scheduler, &mut dyn Scheduler)> = scheds
            .iter_mut()
            .map(|s| {
                (s.topsis.as_mut() as &mut dyn Scheduler, s.default.as_mut())
            })
            .collect();
        self.run_loop(Vec::new(), Some(source), dispatcher, &mut pairs)
    }

    /// The eager event loop, over borrowed `(topsis, default)`
    /// scheduler pairs — the entry point `SimulationEngine::run` uses
    /// to delegate a 1-region run without boxing its schedulers.
    pub(crate) fn run_refs(
        &self,
        pods: Vec<Pod>,
        dispatcher: &mut dyn Dispatcher,
        scheds: &mut [(&mut dyn Scheduler, &mut dyn Scheduler)],
    ) -> FederationResult {
        self.run_loop(pods, None, dispatcher, scheds)
            .expect("in-memory arrivals cannot fail")
    }

    /// The event loop proper. `pods` seeds the eager path; `source`,
    /// when present, feeds arrivals lazily through [`SourcePump`]
    /// (then `pods` starts empty and grows/recycles per admission).
    fn run_loop(
        &self,
        mut pods: Vec<Pod>,
        mut source: Option<&mut dyn ArrivalSource>,
        dispatcher: &mut dyn Dispatcher,
        scheds: &mut [(&mut dyn Scheduler, &mut dyn Scheduler)],
    ) -> anyhow::Result<FederationResult> {
        assert_eq!(
            scheds.len(),
            self.regions.len(),
            "one scheduler pair per region"
        );
        let n_regions = self.regions.len();
        let mut fed: Vec<RegionRun> =
            self.regions.iter().map(RegionRun::new).collect();
        let mut clock = VirtualClock::default();
        let mut queue = FedEventQueue::new();
        let mut sched_latency_us = vec![0.0; pods.len()];
        let mut attempts = vec![0u32; pods.len()];
        let mut assignments: Vec<RegionAssignment> =
            Vec::with_capacity(pods.len());

        // Idle-floor metering and the t = 0 timeline sample, per
        // region (mirrors the plain engine's run start).
        for (r, spec) in self.regions.iter().enumerate() {
            for id in 0..fed[r].state.nodes().len() {
                if fed[r].state.node(id).ready {
                    let node = fed[r].state.node(id).clone();
                    fed[r].meter.node_online(&spec.config.energy, &node, 0.0);
                }
            }
            fed[r].sample_nodes(0.0);
        }

        // Seed arrivals in pod order — the kernel's `(time, priority,
        // seq)` assignments. The region tag of an arrival is resolved
        // by the dispatcher at pop time (0 here is a placeholder,
        // never read). Streaming runs skip this: the pump admits each
        // arrival just before it is due instead.
        for (i, p) in pods.iter().enumerate() {
            queue.push(p.arrival_s, 0, SimEvent::PodArrival { pod: i });
        }
        // Then each region's churn schedule, in region order. The
        // total order guarantees same-timestamp arrivals precede
        // membership changes however the events were pushed.
        for (r, spec) in self.regions.iter().enumerate() {
            for ch in &spec.node_events {
                let ev = if ch.up {
                    SimEvent::NodeJoined { node: ch.node }
                } else {
                    SimEvent::NodeFailed { node: ch.node }
                };
                queue.push(ch.at_s, r, ev);
            }
        }

        // Each region's autoscaler decides once at t = 0, in region
        // order (mirrors the plain engine's initial consultation).
        for (r, spec) in self.regions.iter().enumerate() {
            fed[r].autoscaler = spec
                .autoscaler
                .as_ref()
                .map(|p| p.build(fed[r].state.nodes().len()));
            self.autoscale(&mut fed[r], r, 0.0, &pods, &mut queue);
        }

        let streaming = source.is_some();
        let mut pump = SourcePump::new();
        let mut peak_live_pods = pods.len();
        loop {
            if let Some(src) = source.as_deref_mut() {
                pump.admit_due(
                    src,
                    &mut queue,
                    &mut pods,
                    &mut sched_latency_us,
                    &mut attempts,
                )?;
                peak_live_pods =
                    peak_live_pods.max(pods.len() - pump.free_slots.len());
            }
            let Some(ev) = queue.pop() else { break };
            let now = clock.advance_to(ev.at);
            let is_tick = matches!(ev.event, SimEvent::AutoscaleTick);
            let region = match ev.event {
                SimEvent::PodArrival { pod } => {
                    // The dispatch extension point: route the pod with
                    // every region's live state in view. The decision
                    // is final.
                    let r = {
                        let snaps: Vec<RegionSnapshot> = fed
                            .iter()
                            .enumerate()
                            .map(|(i, run)| RegionSnapshot {
                                index: i,
                                name: &self.regions[i].name,
                                state: &run.state,
                                pending_pods: run.pending.len(),
                                pending_cpu_millis: run.pending_cpu_millis,
                                pending_memory_mib: run.pending_memory_mib,
                                running_pods: run.running.len(),
                                carbon: &self.regions[i].carbon,
                            })
                            .collect();
                        dispatcher.dispatch(now, &pods[pod], &snaps)
                    };
                    assert!(
                        r < n_regions,
                        "dispatcher routed to region {r} of {n_regions}"
                    );
                    let kind = ev.event.kind();
                    let run = &mut fed[r];
                    run.meter.advance(now);
                    run.events.push(EventRecord { at_s: now, kind });
                    run.pending.push_back(pod);
                    run.arrivals_since_cycle = true;
                    run.pending_cpu_millis += pods[pod].requests.cpu_millis;
                    run.pending_memory_mib += pods[pod].requests.memory_mib;
                    assignments.push(RegionAssignment {
                        pod: pods[pod].id,
                        region: r,
                        at_s: now,
                    });
                    if !run.cycle_queued {
                        queue.push(now, r, SimEvent::SchedulingCycle);
                        run.cycle_queued = true;
                    }
                    r
                }
                event => {
                    let r = ev.region;
                    fed[r].meter.advance(now);
                    fed[r]
                        .events
                        .push(EventRecord { at_s: now, kind: event.kind() });
                    match event {
                        SimEvent::SchedulingCycle => {
                            fed[r].cycle_queued = false;
                            // Short-circuit a provably-futile retry
                            // pass: if no node changed and nothing
                            // arrived in this region since its last
                            // cycle, every pending pod re-fails
                            // identically. (Today every cycle request
                            // follows a mutation or an arrival, so the
                            // guard is structural — the skip/run
                            // counters on `RunResult` make it
                            // observable.)
                            let unchanged = !fed[r].arrivals_since_cycle
                                && fed[r].last_cycle_mutations
                                    == fed[r].state.mutations();
                            if !unchanged || self.params.force_full_cycles {
                                fed[r].cycles_run += 1;
                                self.drain_pending(
                                    &mut fed[r],
                                    r,
                                    now,
                                    &mut pods,
                                    &mut scheds[r],
                                    &mut queue,
                                    &mut sched_latency_us,
                                    &mut attempts,
                                );
                            } else {
                                fed[r].cycles_skipped += 1;
                            }
                            // Record *after* draining: the cycle's own
                            // binds must not look like fresh mutations
                            // next time.
                            fed[r].last_cycle_mutations =
                                fed[r].state.mutations();
                            fed[r].arrivals_since_cycle = false;
                        }
                        SimEvent::PodCompleted { pod } => {
                            self.complete(
                                &mut fed[r],
                                now,
                                &mut pods,
                                pod,
                                &sched_latency_us,
                                &attempts,
                            );
                            // A completed pod's record is final: its
                            // slot can host the next streamed arrival,
                            // keeping the live vector bounded by
                            // in-flight pods.
                            if streaming {
                                pump.free_slots.push(pod);
                            }
                            if !fed[r].pending.is_empty()
                                && !fed[r].cycle_queued
                            {
                                queue.push(now, r, SimEvent::SchedulingCycle);
                                fed[r].cycle_queued = true;
                            }
                        }
                        SimEvent::NodeJoined { node } => {
                            fed[r].state.set_ready(node, true, now);
                            let joined = fed[r].state.node(node).clone();
                            fed[r].meter.node_online(
                                &self.regions[r].config.energy,
                                &joined,
                                now,
                            );
                            fed[r].sample_nodes(now);
                            if !fed[r].pending.is_empty()
                                && !fed[r].cycle_queued
                            {
                                queue.push(now, r, SimEvent::SchedulingCycle);
                                fed[r].cycle_queued = true;
                            }
                        }
                        SimEvent::NodeFailed { node } => {
                            fed[r].state.set_ready(node, false, now);
                            fed[r].meter.node_offline(node, now);
                            fed[r].sample_nodes(now);
                        }
                        SimEvent::AutoscaleTick => {
                            fed[r].next_tick = None;
                        }
                        SimEvent::PodArrival { .. } => {
                            unreachable!("arrivals matched above")
                        }
                    }
                    r
                }
            };
            // Same consultation rule as the plain engine: the region's
            // policy reacts only to backlog its own imminent cycle
            // will not retry; its wake-up ticks are always honored.
            if is_tick || !fed[region].cycle_queued {
                self.autoscale(
                    &mut fed[region],
                    region,
                    now,
                    &pods,
                    &mut queue,
                );
            }
        }

        // Close out every region's meter over one common window:
        // max(final virtual time, billing horizon). A no-op for the
        // region owning the run's last event — and therefore for any
        // 1-region federation, matching the plain engine exactly.
        let end = match self.params.billing_horizon_s {
            // greenpod-lint: allow(silent-clamp) reason="extending the meter window to the horizon is the feature; runs past the horizon bill to their own end"
            Some(h) => h.max(clock.now()),
            None => clock.now(),
        };
        for run in &mut fed {
            run.meter.advance(end);
        }

        let mut regions_out = Vec::with_capacity(n_regions);
        for (r, run) in fed.into_iter().enumerate() {
            let unschedulable: Vec<u64> = run
                .pending
                .iter()
                .map(|&i| {
                    pods[i].phase = PodPhase::Unschedulable;
                    pods[i].id
                })
                .collect();
            regions_out.push(RegionResult {
                name: self.regions[r].name.clone(),
                run: RunResult {
                    records: run.records,
                    meter: run.meter,
                    unschedulable,
                    makespan_s: run.makespan,
                    pjrt_fallbacks: 0,
                    events: run.events,
                    scaling: run.scaling,
                    node_timeline: run.node_timeline,
                    cycles_run: run.cycles_run,
                    cycles_skipped: run.cycles_skipped,
                },
            });
        }
        Ok(FederationResult {
            regions: regions_out,
            assignments,
            peak_live_pods,
        })
    }

    /// One region autoscaler consultation (mirrors the plain engine's
    /// `autoscale`, with region-tagged event pushes). No-op for
    /// regions without a policy.
    fn autoscale(
        &self,
        run: &mut RegionRun,
        region: usize,
        now: f64,
        pods: &[Pod],
        queue: &mut FedEventQueue,
    ) {
        let Some(mut policy) = run.autoscaler.take() else {
            return;
        };
        let mut waits = std::mem::take(&mut run.waits_buf);
        waits.clear();
        waits.extend(run.pending.iter().map(|&i| now - pods[i].arrival_s));
        let decision = policy.decide(&Observation {
            now_s: now,
            state: &run.state,
            pending_wait_s: &waits,
        });
        run.waits_buf = waits;
        for action in decision.actions {
            match action {
                ScalingAction::Provision { template, ready_at_s } => {
                    let node = run.state.add_node(&template, now);
                    let at = effective_at(ready_at_s, now);
                    queue.push(at, region, SimEvent::NodeJoined { node });
                    run.sample_nodes(now);
                    run.scaling.push(ScalingRecord {
                        at_s: now,
                        kind: "scale-out",
                        node,
                        effective_at_s: at,
                    });
                }
                ScalingAction::Activate { node, at_s } => {
                    let at = effective_at(at_s, now);
                    queue.push(at, region, SimEvent::NodeJoined { node });
                    run.scaling.push(ScalingRecord {
                        at_s: now,
                        kind: "activate",
                        node,
                        effective_at_s: at,
                    });
                }
                ScalingAction::Deactivate { node, at_s } => {
                    let at = effective_at(at_s, now);
                    queue.push(at, region, SimEvent::NodeFailed { node });
                    run.scaling.push(ScalingRecord {
                        at_s: now,
                        kind: "scale-in",
                        node,
                        effective_at_s: at,
                    });
                }
            }
        }
        if let Some(wake) = decision.wake_at_s {
            if wake > now && run.next_tick.map_or(true, |t| wake < t) {
                queue.push(wake, region, SimEvent::AutoscaleTick);
                run.next_tick = Some(wake);
            }
        }
        run.autoscaler = Some(policy);
    }

    /// One region scheduling cycle: try every pending pod once, FIFO
    /// (mirrors the plain engine's `drain_pending`).
    #[allow(clippy::too_many_arguments)]
    fn drain_pending(
        &self,
        run: &mut RegionRun,
        region: usize,
        now: f64,
        pods: &mut [Pod],
        scheds: &mut (&mut dyn Scheduler, &mut dyn Scheduler),
        queue: &mut FedEventQueue,
        sched_latency_us: &mut [f64],
        attempts: &mut [u32],
    ) {
        let n = run.pending.len();
        for _ in 0..n {
            let i = run.pending.pop_front().expect("pending non-empty");
            if self.try_place(
                run,
                region,
                i,
                now,
                pods,
                scheds,
                queue,
                sched_latency_us,
                attempts,
            ) {
                run.pending_cpu_millis -= pods[i].requests.cpu_millis;
                run.pending_memory_mib -= pods[i].requests.memory_mib;
            } else {
                run.pending.push_back(i);
            }
        }
    }

    /// Attempt to place and start pod `i` in `region` at `now`
    /// (mirrors the plain engine's `try_place`: same estimator,
    /// contention and metering arithmetic, the region's own energy
    /// model).
    #[allow(clippy::too_many_arguments)]
    fn try_place(
        &self,
        run: &mut RegionRun,
        region: usize,
        i: usize,
        now: f64,
        pods: &mut [Pod],
        scheds: &mut (&mut dyn Scheduler, &mut dyn Scheduler),
        queue: &mut FedEventQueue,
        sched_latency_us: &mut [f64],
        attempts: &mut [u32],
    ) -> bool {
        let decision = match pods[i].scheduler {
            SchedulerKind::Topsis => {
                scheds.0.schedule_at(&run.state, &pods[i], now)
            }
            SchedulerKind::DefaultK8s => {
                scheds.1.schedule_at(&run.state, &pods[i], now)
            }
        };
        sched_latency_us[i] += decision.latency.as_secs_f64() * 1e6;
        attempts[i] += 1;
        let Some(node_id) = decision.node else {
            return false;
        };

        run.state.bind(&pods[i], node_id, now).expect("scheduler chose fit");
        pods[i].phase = PodPhase::Running;

        let node = run.state.node(node_id).clone();
        let outcome = self
            .executor
            .execute(&pods[i], &node, self.params.seed ^ pods[i].id)
            .expect("workload execution");
        let share =
            pods[i].requests.cpu_millis as f64 / node.cpu_millis as f64;
        let factor = contention_factor(
            self.params.contention_beta,
            run.state.cpu_utilization(node_id),
            share,
        );
        let duration = outcome.base_secs * factor;

        run.meter.start(
            &self.regions[region].config.energy,
            pods[i].id,
            pods[i].class,
            pods[i].scheduler,
            &node,
            share,
            now,
        );
        run.running.insert(i, RunningPod { node: node_id, start_s: now });
        queue.push(now + duration, region, SimEvent::PodCompleted { pod: i });
        true
    }

    /// Handle a completion in one region (mirrors the plain engine's
    /// `complete`).
    fn complete(
        &self,
        run: &mut RegionRun,
        now: f64,
        pods: &mut [Pod],
        i: usize,
        sched_latency_us: &[f64],
        attempts: &[u32],
    ) {
        run.makespan = run.makespan.max(now);
        run.state
            .release(pods[i].id, now)
            .expect("completion of bound pod");
        pods[i].phase = PodPhase::Succeeded;
        let rp = run.running.remove(&i).expect("completion of running pod");
        let joules = run.meter.finish(pods[i].id, now);
        run.records.push(PodRecord {
            pod: pods[i].id,
            class: pods[i].class,
            scheduler: pods[i].scheduler,
            node: rp.node,
            node_category: run.state.node(rp.node).category,
            arrival_s: pods[i].arrival_s,
            start_s: rp.start_s,
            finish_s: now,
            sched_latency_us: sched_latency_us[i],
            attempts: attempts[i],
            joules,
            wait_s: rp.start_s - pods[i].arrival_s,
        });
    }
}

/// Streaming-arrival bookkeeping for `run_loop`: admits source pods
/// into the merged queue as they come due, and recycles the pod-vector
/// slots of completed pods so a replay's live vector stays bounded by
/// the in-flight count instead of the trace length.
struct SourcePump {
    /// Pod-vector slots of completed pods, ready for reuse.
    free_slots: Vec<usize>,
    /// Last admitted arrival time (monotonicity guard).
    last_at: f64,
}

impl SourcePump {
    fn new() -> Self {
        Self { free_slots: Vec::new(), last_at: 0.0 }
    }

    /// Admit every source pod due at or before the queue's head (or
    /// the single next pod when the queue is empty). Pushed before
    /// that pop, an admitted arrival lands in the identical `(time,
    /// kind-priority)` slot the eager seeding would give it, and
    /// same-slot arrivals keep source order because `seq` is monotone
    /// in admission order — so the pop sequence matches the eager run
    /// exactly (the differential property pins this).
    fn admit_due(
        &mut self,
        src: &mut dyn ArrivalSource,
        queue: &mut FedEventQueue,
        pods: &mut Vec<Pod>,
        sched_latency_us: &mut Vec<f64>,
        attempts: &mut Vec<u32>,
    ) -> anyhow::Result<()> {
        loop {
            let Some(at) = src.peek_at()? else { return Ok(()) };
            anyhow::ensure!(
                at.is_finite() && at >= 0.0,
                "arrival source yielded an invalid time {at}"
            );
            anyhow::ensure!(
                at >= self.last_at,
                "arrival source times must be nondecreasing: {at} after {}",
                self.last_at
            );
            let due = match queue.peek() {
                None => true,
                Some(head) => at <= head.at,
            };
            if !due {
                return Ok(());
            }
            self.last_at = at;
            let pod = src.next_pod()?.ok_or_else(|| {
                anyhow::anyhow!("arrival source ended between peek and next")
            })?;
            let slot = match self.free_slots.pop() {
                Some(s) => {
                    pods[s] = pod;
                    sched_latency_us[s] = 0.0;
                    attempts[s] = 0;
                    s
                }
                None => {
                    pods.push(pod);
                    sched_latency_us.push(0.0);
                    attempts.push(0);
                    pods.len() - 1
                }
            };
            queue
                .push(pods[slot].arrival_s, 0, SimEvent::PodArrival { pod: slot });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::WeightingScheme;
    use crate::federation::dispatch::{CarbonGreedy, RoundRobin};
    use crate::framework::{BuildOptions, ProfileRegistry};
    use crate::workload::{ArrivalTrace, TraceSpec};

    fn build_scheds(spec: &RegionSpec, seed: u64) -> RegionSchedulers {
        let registry = ProfileRegistry::new(&spec.config);
        let opts =
            BuildOptions::new(&spec.config, WeightingScheme::EnergyCentric)
                .with_seed(seed)
                .with_carbon(spec.carbon.clone());
        RegionSchedulers {
            topsis: Box::new(
                registry.build("greenpod", &opts).expect("built-in"),
            ),
            default: Box::new(
                registry.build("default-k8s", &opts).expect("built-in"),
            ),
        }
    }

    fn trace_pods(seed: u64) -> Vec<Pod> {
        let spec = TraceSpec {
            rate_per_s: 0.5,
            duration_s: 60.0,
            p_light: 0.3,
            p_medium: 0.3,
            p_complex: 0.4,
            epochs: [2, 2, 1],
        };
        ArrivalTrace::bursty(&spec, 6, seed)
            .to_pods(SchedulerKind::Topsis)
    }

    fn two_region_specs() -> Vec<RegionSpec> {
        let cfg = Config::paper_default();
        vec![
            RegionSpec::new("east", cfg.clone())
                .with_carbon(CarbonSignal::constant(2e-4)),
            RegionSpec::new("west", cfg)
                .with_carbon(CarbonSignal::constant(1e-4)),
        ]
    }

    #[test]
    fn two_region_federation_conserves_pods_and_meters_both_ledgers() {
        let specs = two_region_specs();
        let executor = WorkloadExecutor::analytic();
        let engine = FederationEngine::new(
            &specs,
            FederationParams::with_beta_and_seed(0.35, 7),
            &executor,
        );
        let pods = trace_pods(7);
        let n = pods.len();
        let mut scheds: Vec<RegionSchedulers> =
            specs.iter().map(|s| build_scheds(s, 7)).collect();
        let mut rr = RoundRobin::new();
        let r = engine.run(pods, &mut rr, &mut scheds);
        assert_eq!(r.assignments.len(), n);
        assert_eq!(r.completed() + r.unschedulable(), n);
        assert_eq!(r.unschedulable(), 0);
        // Round-robin over two regions splits the stream in half.
        let east = r.region("east").run.records.len();
        let west = r.region("west").run.records.len();
        assert_eq!(east + west, n);
        assert!(east.abs_diff(west) <= 1, "{east} vs {west}");
        // Both regions metered work and idle, under their own signals.
        for reg in &r.regions {
            assert!(reg.run.meter.total_kj(SchedulerKind::Topsis) > 0.0);
            assert!(reg.run.idle_kj() > 0.0);
            assert!(reg.run.meter.total_co2_g(SchedulerKind::Topsis) > 0.0);
        }
        // Aggregates are the per-region sums.
        let kj: f64 = r
            .regions
            .iter()
            .map(|x| x.run.meter.total_kj(SchedulerKind::Topsis))
            .sum();
        assert_eq!(r.total_kj(SchedulerKind::Topsis), kj);
        assert!(r.makespan_s() > 0.0);
        // Every record's pod was assigned to the region that ran it.
        for (ri, reg) in r.regions.iter().enumerate() {
            for rec in &reg.run.records {
                let a = r
                    .assignments
                    .iter()
                    .find(|a| a.pod == rec.pod)
                    .expect("assignment");
                assert_eq!(a.region, ri, "pod {}", rec.pod);
            }
        }
    }

    #[test]
    fn carbon_greedy_routes_everything_to_the_cleaner_region() {
        // Constant signals, west strictly cleaner, light load: every
        // pod has capacity in west, so carbon-greedy never touches
        // east.
        let specs = two_region_specs();
        let executor = WorkloadExecutor::analytic();
        let engine = FederationEngine::new(
            &specs,
            FederationParams::with_beta_and_seed(0.35, 3),
            &executor,
        );
        let mut pods = trace_pods(3);
        pods.truncate(6);
        let mut scheds: Vec<RegionSchedulers> =
            specs.iter().map(|s| build_scheds(s, 3)).collect();
        let mut cg = CarbonGreedy::new();
        let r = engine.run(pods, &mut cg, &mut scheds);
        assert_eq!(r.unschedulable(), 0);
        assert_eq!(r.region("east").run.records.len(), 0);
        assert_eq!(r.region("west").run.records.len(), 6);
        // The idle floor still accrues in the untouched region.
        assert!(r.region("east").run.idle_kj() > 0.0);
        assert_eq!(
            r.region("east")
                .run
                .meter
                .total_kj(SchedulerKind::Topsis),
            0.0
        );
    }

    #[test]
    fn autoscaled_region_scales_and_returns_to_base() {
        use crate::autoscaler::ThresholdConfig;
        use crate::workload::WorkloadClass;

        // One autoscaled region fed a burst that overflows its base
        // capacity: the federated kernel must carry the region's
        // scale-out/in lifecycle exactly like the plain engine.
        let cfg = Config::paper_default();
        let policy = ThresholdConfig {
            scale_out_pending: 2,
            scale_out_wait_p95_s: f64::INFINITY,
            provision_delay_s: 5.0,
            cooldown_s: 2.0,
            idle_scale_in_s: 10.0,
            min_nodes: 7,
            max_nodes: 10,
            template: ThresholdConfig::edge_template(&cfg.cluster),
            carbon: None,
        };
        let specs = vec![RegionSpec::new("solo", cfg)
            .with_autoscaler(AutoscalerPolicy::Threshold(policy))];
        let executor = WorkloadExecutor::analytic();
        let engine = FederationEngine::new(
            &specs,
            FederationParams::with_beta_and_seed(0.35, 1),
            &executor,
        );
        let mut pods = Vec::new();
        for i in 0..18u64 {
            let at = 0.25 * (i / 6) as f64;
            pods.push(Pod::new(
                i,
                WorkloadClass::Complex,
                SchedulerKind::Topsis,
                at,
                1,
            ));
        }
        let mut scheds = vec![build_scheds(&specs[0], 1)];
        let mut rr = RoundRobin::new();
        let r = engine.run(pods, &mut rr, &mut scheds);
        assert_eq!(r.completed(), 18);
        assert_eq!(r.unschedulable(), 0);
        assert!(r.scaling_count("scale-out") >= 1);
        assert!(r.scaling_count("scale-in") >= 1);
        let run = &r.regions[0].run;
        assert!(run.peak_ready_nodes() > 7);
        assert_eq!(run.node_timeline.last().unwrap().ready_nodes, 7);
    }

    #[test]
    fn billing_horizon_bills_every_region_idle_to_the_same_window() {
        let specs = two_region_specs();
        let executor = WorkloadExecutor::analytic();
        let horizon = 500.0;
        let engine = FederationEngine::new(
            &specs,
            FederationParams {
                contention_beta: 0.35,
                seed: 5,
                billing_horizon_s: Some(horizon),
                ..FederationParams::default()
            },
            &executor,
        );
        let pods = trace_pods(5);
        let mut scheds: Vec<RegionSchedulers> =
            specs.iter().map(|s| build_scheds(s, 5)).collect();
        let mut cg = CarbonGreedy::new();
        let r = engine.run(pods, &mut cg, &mut scheds);
        // Both regions share one cluster topology, so equal idle
        // windows mean near-equal idle energy minus the pod claims —
        // in particular the *untouched* region's idle must cover the
        // whole horizon, not stop at its (empty) event stream.
        let idle_w: f64 = {
            let cfg = Config::paper_default();
            let state = ClusterState::from_config(&cfg.cluster);
            state
                .nodes()
                .iter()
                .map(|n| crate::energy::node_idle_watts(&cfg.energy, n))
                .sum()
        };
        let full_window_kj = idle_w * horizon / 1000.0;
        for reg in &r.regions {
            // Idle is the full window minus running-pod idle claims —
            // never more than the full window, never less than 90% of
            // it on this light trace.
            assert!(reg.run.idle_kj() <= full_window_kj + 1e-9);
            assert!(
                reg.run.idle_kj() > 0.9 * full_window_kj,
                "{}: idle {} vs window {}",
                reg.name,
                reg.run.idle_kj(),
                full_window_kj
            );
        }
    }
}
