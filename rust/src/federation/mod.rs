//! Multi-cluster federation (DESIGN.md §"Federation"): N per-region
//! event kernels — each with its own `ClusterState`, `EnergyMeter`,
//! regional `CarbonSignal` and optional `ThresholdAutoscaler` — run
//! under **one shared virtual clock** with a merged `(time,
//! kind-priority, seq)` event order, plus a [`Dispatcher`] extension
//! point that routes each arriving pod to a region *before* the
//! region's own scheduling profile places it on a node.
//!
//! This is the ROADMAP's "async multi-cluster" item and the paper's
//! §V.E extrapolation made a real simulated federation: the related
//! work's observation (CODECO, arXiv:2606.12136) that greenness-driven
//! scheduling only pays off when the dispatcher can choose *between*
//! sites is exactly what the [`CarbonGreedy`] policy exercises against
//! phase-shifted per-region grid signals.
//!
//! Determinism and differential contracts:
//! * the merged queue is [`crate::simulation::FedEventQueue`] — the
//!   kernel's total order with a region tag that never participates in
//!   the comparison;
//! * this is **the one event loop in the tree**:
//!   [`SimulationEngine::run`] is a thin wrapper that builds a
//!   1-region federation, and the property suite pins the wrapper
//!   record-for-record bit-identical to a hand-assembled solo region
//!   (`prop_federation_single_region_is_bit_identical_to_plain_engine`);
//! * per-region CO₂ ledgers integrate each region's signal exactly as
//!   the single-cluster meter does, so the federation golden fixture
//!   (`golden_trace_federation.expected.json`) cross-validates against
//!   the Python oracle to 1e-9.
//!
//! [`SimulationEngine`]: crate::simulation::SimulationEngine

mod dispatch;
mod engine;
mod result;
mod source;

pub use dispatch::{
    build_dispatcher, CarbonGreedy, Dispatcher, LeastPending,
    RegionSnapshot, RoundRobin,
};
pub use engine::{
    FederationEngine, FederationParams, RegionSchedulers, RegionSpec,
};
pub use result::{FederationResult, RegionAssignment, RegionResult};
pub use source::{ArrivalSource, VecArrivalSource};
