//! The lazy arrival source — the streaming counterpart of the eager
//! `Vec<Pod>` the engine has always taken.
//!
//! [`FederationEngine::run_source`] pulls pods from an
//! [`ArrivalSource`] *as virtual time reaches them* instead of seeding
//! every arrival up front, so a multi-million-pod trace replays
//! without materializing its pod vector. The contract that keeps the
//! two paths bit-identical (pinned by
//! `prop_stream_replay_is_bit_identical_to_eager`):
//!
//! * `peek_at` reports the next pod's arrival time without consuming
//!   it; `next_pod` consumes exactly that pod. Times must be finite,
//!   non-negative and **nondecreasing** — the engine validates both
//!   and errors on violation (never silently clamps).
//! * The engine admits a source pod into the event queue the moment
//!   its arrival time is less than or equal to the queue head's fire
//!   time. Pushed before that pop, the arrival lands in the same
//!   `(time, kind-priority)` slot the eager seeding would give it, and
//!   same-slot arrivals keep source order because the queue's `seq`
//!   tie-break is monotone in admission order — so the pop sequence,
//!   and therefore every downstream float op, is identical.
//!
//! [`FederationEngine::run_source`]: super::FederationEngine::run_source

use crate::cluster::Pod;

/// A pull-based stream of pods in nondecreasing `arrival_s` order.
pub trait ArrivalSource {
    /// Arrival time of the next pod, without consuming it
    /// (`Ok(None)` = the stream is exhausted).
    fn peek_at(&mut self) -> anyhow::Result<Option<f64>>;

    /// Consume the next pod. Returns the pod whose time the last
    /// `peek_at` reported.
    fn next_pod(&mut self) -> anyhow::Result<Option<Pod>>;
}

/// An in-memory arrival source over an already-sorted pod vector —
/// the degenerate stream used by differential tests to pin streaming
/// against eager on identical inputs.
pub struct VecArrivalSource {
    pods: std::vec::IntoIter<Pod>,
    next: Option<Pod>,
}

impl VecArrivalSource {
    /// Wrap `pods` (must already be in nondecreasing `arrival_s`
    /// order; the engine rejects violations).
    pub fn new(pods: Vec<Pod>) -> Self {
        Self { pods: pods.into_iter(), next: None }
    }

    fn fill(&mut self) {
        if self.next.is_none() {
            self.next = self.pods.next();
        }
    }
}

impl ArrivalSource for VecArrivalSource {
    fn peek_at(&mut self) -> anyhow::Result<Option<f64>> {
        self.fill();
        Ok(self.next.as_ref().map(|p| p.arrival_s))
    }

    fn next_pod(&mut self) -> anyhow::Result<Option<Pod>> {
        self.fill();
        Ok(self.next.take())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SchedulerKind;
    use crate::workload::WorkloadClass;

    #[test]
    fn vec_source_peeks_without_consuming() {
        let pods = vec![
            Pod::new(0, WorkloadClass::Light, SchedulerKind::Topsis, 1.0, 2),
            Pod::new(1, WorkloadClass::Medium, SchedulerKind::Topsis, 3.5, 4),
        ];
        let mut src = VecArrivalSource::new(pods);
        assert_eq!(src.peek_at().unwrap(), Some(1.0));
        assert_eq!(src.peek_at().unwrap(), Some(1.0));
        assert_eq!(src.next_pod().unwrap().unwrap().id, 0);
        assert_eq!(src.peek_at().unwrap(), Some(3.5));
        assert_eq!(src.next_pod().unwrap().unwrap().id, 1);
        assert_eq!(src.peek_at().unwrap(), None);
        assert!(src.next_pod().unwrap().is_none());
    }
}
