//! Experiment drivers — one per table/figure of the paper's evaluation
//! (see DESIGN.md §5 for the full index).

mod ablation;
mod alloc;
mod carbon;
mod elastic;
mod federation;
mod fig2;
mod profiles;
mod replay;
mod runner;
mod table6;
mod table7;

pub use ablation::{run_ablation, AblationResult};
pub use alloc::{run_alloc_analysis, AllocAnalysis};
pub use carbon::{
    carbon_window, run_carbon, CarbonCell, CarbonReport, CarbonSignalKind,
    WINDOW_DEFER_S, WINDOW_IDLE_TIGHTEN, WINDOW_PERCENTILE,
};
pub use elastic::{
    churn_schedule, elastic_policy, run_elastic, ClusterMode, ElasticCell,
    ElasticProcess, ElasticityReport, BILLING_HORIZON_S, EXTRA_NODES,
    SLO_WAIT_S,
};
pub use federation::{
    phase_shifted_diurnal, run_federation, FederationCell,
    FederationReport, FED_REGION_NAMES, FED_SAMPLES, FED_SWING,
};
pub use fig2::render_fig2;
pub use profiles::{run_profiles, ProfileCell, ProfilesReport};
pub use replay::{run_trace_replay, ReplaySummary};
pub use runner::{run_cell, run_once, run_uniform, CellResult, ExperimentContext};
pub use table6::{run_table6, Table6, Table6Row};
pub use table7::{run_table7, Table7};
