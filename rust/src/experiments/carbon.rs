//! Carbon scenarios — `greenpod experiment carbon`: the time-of-day
//! experiment class the scalar eGRID factor could not express
//! (ROADMAP: carbon-intensity *time series* driving the carbon plugin,
//! tied into carbon-aware scale-down windows).
//!
//! The grid crosses three intensity signals (the constant eGRID
//! scalar, a synthetic diurnal cycle, an explicit step trace) with the
//! autoscaled elastic cluster in two flavors — the plain threshold
//! policy and the same policy under [`CarbonWindowConfig`] scale-down
//! windows — under two profiles (`greenpod`, `carbon-aware`). Every
//! cell replays the same bursty AIoT trace, so CO₂ totals compare at
//! equal admitted work.
//!
//! Pinned headlines (tests below, cross-validated against the Python
//! oracle mirror): on the diurnal signal the carbon-windowed run emits
//! strictly fewer total gCO₂ than the plain autoscaled run, and on the
//! constant signal the window is provably inert — bit-identical
//! totals.

use anyhow::Result;

use crate::autoscaler::{AutoscalerPolicy, CarbonWindowConfig};
use crate::config::{SchedulerKind, WeightingScheme};
use crate::energy::{grams_co2_per_joule, CarbonSignal};
use crate::framework::ProfileRegistry;
use crate::metrics::{Summary, Table};
use crate::simulation::{RunResult, SimulationEngine, SimulationParams};
use crate::workload::WorkloadExecutor;

use super::{
    elastic_policy, ElasticProcess, ExperimentContext, BILLING_HORIZON_S,
    SLO_WAIT_S,
};

/// Dirty-threshold quantile of the carbon windows.
pub const WINDOW_PERCENTILE: f64 = 0.5;
/// Idle scale-in tightening while dirty.
pub const WINDOW_IDLE_TIGHTEN: f64 = 0.25;
/// Bound (s) on deferring depth-triggered scale-out while dirty.
pub const WINDOW_DEFER_S: f64 = 20.0;

/// The three intensity signals of the grid.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CarbonSignalKind {
    /// The eGRID scalar — the paper's §V.E conversion, as a signal.
    Constant,
    /// Synthetic diurnal cycle over the billing horizon: clean at the
    /// run's start and end, dirtiest mid-run (swing ±50%).
    Diurnal,
    /// Explicit step trace alternating dirty and clean hours.
    Trace,
}

impl CarbonSignalKind {
    pub const ALL: [CarbonSignalKind; 3] = [
        CarbonSignalKind::Constant,
        CarbonSignalKind::Diurnal,
        CarbonSignalKind::Trace,
    ];

    pub fn label(&self) -> &'static str {
        match self {
            CarbonSignalKind::Constant => "constant",
            CarbonSignalKind::Diurnal => "diurnal",
            CarbonSignalKind::Trace => "trace",
        }
    }

    /// Materialize the signal around the config's eGRID base intensity.
    pub fn signal(&self, energy: &crate::config::EnergyModelConfig) -> CarbonSignal {
        let base = grams_co2_per_joule(energy);
        match self {
            CarbonSignalKind::Constant => CarbonSignal::constant(base),
            CarbonSignalKind::Diurnal => CarbonSignal::diurnal(
                base,
                0.5,
                BILLING_HORIZON_S,
                12,
            )
            .expect("valid diurnal parameters"),
            CarbonSignalKind::Trace => CarbonSignal::step(vec![
                (0.0, base * 1.3),
                (60.0, base * 0.6),
                (120.0, base * 1.4),
                (180.0, base * 0.7),
                (240.0, base * 1.0),
            ])
            .expect("valid step trace"),
        }
    }
}

/// One (signal × window × profile) cell.
#[derive(Debug, Clone)]
pub struct CarbonCell {
    pub signal: CarbonSignalKind,
    /// Whether the autoscaler ran under carbon scale-down windows.
    pub windowed: bool,
    pub profile: String,
    pub pods: usize,
    pub unschedulable: usize,
    /// Pod-attributed energy (kJ).
    pub pod_kj: f64,
    /// Unattributed node-idle energy (kJ).
    pub idle_kj: f64,
    pub total_kj: f64,
    /// Pod-attributed CO₂ (grams, signal-integrated).
    pub pod_co2_g: f64,
    /// Idle-floor CO₂ (grams, signal-integrated).
    pub idle_co2_g: f64,
    /// pod + idle — the comparable CO₂ total.
    pub total_co2_g: f64,
    pub wait_p95_s: f64,
    pub slo_miss: f64,
    pub makespan_s: f64,
    pub scale_outs: usize,
    pub scale_ins: usize,
}

/// The full carbon scenario grid.
#[derive(Debug, Clone)]
pub struct CarbonReport {
    pub cells: Vec<CarbonCell>,
}

impl CarbonReport {
    /// Look up one cell (panics if the grid does not contain it).
    pub fn cell(
        &self,
        signal: CarbonSignalKind,
        windowed: bool,
        profile: &str,
    ) -> &CarbonCell {
        self.cells
            .iter()
            .find(|c| {
                c.signal == signal
                    && c.windowed == windowed
                    && c.profile == profile
            })
            .expect("cell in grid")
    }

    pub fn to_table(&self) -> Table {
        let mut t = Table::new(
            &format!(
                "Carbon scenarios (autoscaled bursty trace; CO2 \
                 integrated over the intensity signal; SLO: wait <= \
                 {SLO_WAIT_S:.0} s)"
            ),
            &[
                "signal", "autoscaler", "profile", "pods", "total CO2 g",
                "pod CO2 g", "idle CO2 g", "total kJ", "wait p95 s",
                "SLO miss %", "scale out/in", "makespan s",
            ],
        );
        for c in &self.cells {
            t.row(vec![
                c.signal.label().to_string(),
                if c.windowed { "carbon-windowed" } else { "plain" }
                    .to_string(),
                c.profile.clone(),
                format!("{}", c.pods),
                format!("{:.2}", c.total_co2_g),
                format!("{:.2}", c.pod_co2_g),
                format!("{:.2}", c.idle_co2_g),
                format!("{:.3}", c.total_kj),
                format!("{:.2}", c.wait_p95_s),
                format!("{:.1}", 100.0 * c.slo_miss),
                format!("{}/{}", c.scale_outs, c.scale_ins),
                format!("{:.1}", c.makespan_s),
            ]);
        }
        t
    }
}

/// The window policy of the carbon-windowed cells: the elastic
/// threshold policy, with scale-down windows derived from `signal`.
pub fn carbon_window(signal: CarbonSignal) -> CarbonWindowConfig {
    CarbonWindowConfig::at_percentile(
        signal,
        WINDOW_PERCENTILE,
        WINDOW_IDLE_TIGHTEN,
        WINDOW_DEFER_S,
    )
    .expect("valid window parameters")
}

/// Run the grid: {constant, diurnal, trace} × {plain, carbon-windowed}
/// × {greenpod, carbon-aware}, one shared bursty trace.
pub fn run_carbon(ctx: &ExperimentContext) -> Result<CarbonReport> {
    let base = &ctx.config;
    let registry = ProfileRegistry::new(base);
    let executor = WorkloadExecutor::analytic();
    let trace = ElasticProcess::Bursty.trace(base.experiment.seed);

    let mut cells = Vec::new();
    for kind in CarbonSignalKind::ALL {
        let signal = kind.signal(&base.energy);
        for windowed in [false, true] {
            for profile in ["greenpod", "carbon-aware"] {
                let mut policy = elastic_policy(&base.cluster);
                if windowed {
                    policy = policy
                        .with_carbon_window(carbon_window(signal.clone()));
                }
                let mut params = SimulationParams::with_beta_and_seed(
                    base.experiment.contention_beta,
                    base.experiment.seed,
                )
                .with_autoscaler(AutoscalerPolicy::Threshold(policy))
                .with_carbon(signal.clone());
                params.billing_horizon_s = Some(BILLING_HORIZON_S);

                let opts = ctx
                    .build_options(
                        WeightingScheme::EnergyCentric,
                        base.experiment.seed,
                        &executor,
                    )
                    .with_carbon(signal.clone());
                let mut under_test = registry.build(profile, &opts)?;
                let mut unused = registry.build("default-k8s", &opts)?;
                let engine = SimulationEngine::new(base, params, &executor);
                let pods = trace.to_pods(SchedulerKind::Topsis);
                let n_pods = pods.len();
                let result: RunResult =
                    engine.run(pods, &mut under_test, &mut unused);

                let waits: Summary =
                    result.queue_wait_summary(SchedulerKind::Topsis);
                let pod_kj = result.meter.total_kj(SchedulerKind::Topsis);
                let idle_kj = result.idle_kj();
                let pod_co2_g =
                    result.meter.total_co2_g(SchedulerKind::Topsis);
                let idle_co2_g = result.meter.idle_co2_g();
                cells.push(CarbonCell {
                    signal: kind,
                    windowed,
                    profile: profile.to_string(),
                    pods: n_pods,
                    unschedulable: result.unschedulable.len(),
                    pod_kj,
                    idle_kj,
                    total_kj: pod_kj + idle_kj,
                    pod_co2_g,
                    idle_co2_g,
                    total_co2_g: pod_co2_g + idle_co2_g,
                    wait_p95_s: waits.p95,
                    slo_miss: result
                        .slo_miss_fraction(SchedulerKind::Topsis, SLO_WAIT_S),
                    makespan_s: result.makespan_s,
                    scale_outs: result.scaling_count("scale-out")
                        + result.scaling_count("activate"),
                    scale_ins: result.scaling_count("scale-in"),
                });
            }
        }
    }
    Ok(CarbonReport { cells })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;

    fn report() -> &'static CarbonReport {
        static REPORT: std::sync::OnceLock<CarbonReport> =
            std::sync::OnceLock::new();
        REPORT.get_or_init(|| {
            run_carbon(&ExperimentContext::new(Config::paper_default()))
                .unwrap()
        })
    }

    #[test]
    fn grid_is_complete_and_co2_accounted() {
        let r = report();
        assert_eq!(r.cells.len(), 12);
        let pods = r.cells[0].pods;
        assert!(pods > 0);
        for c in &r.cells {
            assert_eq!(c.pods, pods, "{:?}", c);
            assert_eq!(
                c.unschedulable, 0,
                "{}/{}/{} dropped pods",
                c.signal.label(),
                c.windowed,
                c.profile
            );
            assert!(c.total_co2_g.is_finite() && c.total_co2_g > 0.0);
            assert!(c.pod_co2_g > 0.0);
            assert!(c.idle_co2_g > 0.0);
            assert!(
                (c.total_co2_g - c.pod_co2_g - c.idle_co2_g).abs()
                    < 1e-9 * c.total_co2_g
            );
            assert!(c.total_kj > 0.0);
            assert!((0.0..=1.0).contains(&c.slo_miss));
            assert!(
                c.makespan_s <= BILLING_HORIZON_S,
                "{}/{}/{} drained at {:.1} s past the billing horizon",
                c.signal.label(),
                c.windowed,
                c.profile,
                c.makespan_s
            );
        }
        // The burst workload actually elasticizes in every cell.
        for c in r.cells.iter().filter(|c| !c.windowed) {
            assert!(c.scale_outs >= 1, "{:?}", c);
        }
    }

    #[test]
    fn constant_signal_grams_match_scalar_arithmetic() {
        // On the constant signal the ledger must reproduce the legacy
        // total_kj × g conversion to rounding: same integral, factored.
        let r = report();
        let cfg = Config::paper_default();
        let g = grams_co2_per_joule(&cfg.energy);
        for c in r.cells.iter().filter(|c| c.signal == CarbonSignalKind::Constant)
        {
            let want = c.total_kj * 1000.0 * g;
            assert!(
                (c.total_co2_g - want).abs() < 1e-6 * want,
                "{}: ledger {} vs scalar {}",
                c.profile,
                c.total_co2_g,
                want
            );
        }
    }

    #[test]
    fn constant_signal_window_is_bit_identical_to_plain() {
        // A window over a constant signal can never observe a dirty
        // grid, so the windowed cells are the plain cells, bit-for-bit.
        let r = report();
        for profile in ["greenpod", "carbon-aware"] {
            let plain =
                r.cell(CarbonSignalKind::Constant, false, profile);
            let windowed =
                r.cell(CarbonSignalKind::Constant, true, profile);
            assert_eq!(plain.total_kj, windowed.total_kj, "{profile}");
            assert_eq!(plain.total_co2_g, windowed.total_co2_g);
            assert_eq!(plain.wait_p95_s, windowed.wait_p95_s);
            assert_eq!(plain.makespan_s, windowed.makespan_s);
            assert_eq!(plain.scale_outs, windowed.scale_outs);
            assert_eq!(plain.scale_ins, windowed.scale_ins);
        }
    }

    #[test]
    fn diurnal_carbon_windows_cut_co2_at_equal_work() {
        // The acceptance headline: on the diurnal signal, at equal
        // admitted work, the carbon-windowed autoscaled run emits
        // strictly fewer total gCO₂ than the plain autoscaled run.
        let r = report();
        for profile in ["greenpod", "carbon-aware"] {
            let plain = r.cell(CarbonSignalKind::Diurnal, false, profile);
            let windowed =
                r.cell(CarbonSignalKind::Diurnal, true, profile);
            assert_eq!(plain.pods, windowed.pods);
            assert_eq!(plain.unschedulable + windowed.unschedulable, 0);
            assert!(
                windowed.total_co2_g < plain.total_co2_g,
                "{profile}: windowed {:.3} g !< plain {:.3} g",
                windowed.total_co2_g,
                plain.total_co2_g
            );
        }
    }

    #[test]
    fn table_has_co2_columns() {
        let text = crate::metrics::format_table(&report().to_table());
        assert!(text.contains("total CO2 g"), "{text}");
        assert!(text.contains("diurnal"));
        assert!(text.contains("carbon-windowed"));
        assert!(text.contains("carbon-aware"));
    }
}
