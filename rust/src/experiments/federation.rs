//! Federation scenarios — `greenpod experiment federation`: the
//! multi-cluster grid the ROADMAP's "async multi-cluster" item and the
//! paper's §V.E extrapolation call for.
//!
//! The grid crosses {1, 2, 3 regions} × {round-robin, least-pending,
//! carbon-greedy} × {greenpod, carbon-aware}. Every region is a paper
//! Table I cluster under its own **phase-shifted diurnal** carbon
//! signal (with n regions, region j's diurnal cycle is shifted by
//! j/n of the period — when one grid is dirty another is clean), and
//! every cell replays the same bursty AIoT trace, so CO₂ totals
//! compare at equal admitted work.
//!
//! Pinned headline (tests below, cross-validated by the Python mirror
//! `python/tools/validate_federation_experiment.py` in CI): with ≥ 2
//! regions, carbon-greedy dispatch emits **no more total gCO₂ than
//! round-robin** at equal admitted work — choosing *between* sites is
//! where region-aware carbon signals pay off. With 1 region every
//! dispatch policy degenerates to the same run, bit-for-bit.
//!
//! A config file with a `federation` section overrides the built-in
//! region set: the grid's region axis then runs over prefixes of the
//! configured regions (1 region, 2 regions, ... all of them), keeping
//! each entry's own cluster / carbon / autoscaler configuration.

use anyhow::Result;

use crate::config::{DispatchKind, SchedulerKind, WeightingScheme};
use crate::energy::{grams_co2_per_joule, CarbonSignal};
use crate::federation::{
    build_dispatcher, FederationEngine, FederationParams, FederationResult,
    RegionSchedulers, RegionSpec,
};
use crate::framework::ProfileRegistry;
use crate::metrics::{Summary, Table};
use crate::workload::WorkloadExecutor;

use super::{ElasticProcess, ExperimentContext, BILLING_HORIZON_S, SLO_WAIT_S};

/// Region names of the built-in grid.
pub const FED_REGION_NAMES: [&str; 3] = ["region-a", "region-b", "region-c"];
/// Diurnal swing of the built-in per-region signals. 0.8 (clean
/// phase at 20% of base, dirty at 180%) makes the intensity ratio
/// dominate the contention cost of concentrating load, so the
/// carbon-greedy ≤ round-robin headline holds with margin for both
/// profiles (swept in the Python mirror; at 0.5 the 2-region
/// carbon-aware cell is a coin flip).
pub const FED_SWING: f64 = 0.8;
/// Sample count of the built-in diurnal signals (divisible by 2 and 3,
/// so every phase shift j/n keeps the peak on a sample point).
pub const FED_SAMPLES: u32 = 12;

/// A diurnal triangle wave shifted by `phase` of its period: the same
/// pure arithmetic as [`CarbonSignal::diurnal`] evaluated at
/// `(p + phase) mod 1`, so region signals stay bit-mirrorable by the
/// Python oracle. `phase` must be in `[0, 1)`; phase 0 reproduces the
/// unshifted generator's samples exactly.
pub fn phase_shifted_diurnal(
    base_g_per_j: f64,
    swing: f64,
    period_s: f64,
    samples: u32,
    phase: f64,
) -> CarbonSignal {
    assert!((0.0..1.0).contains(&phase), "phase {phase} not in [0, 1)");
    let points = (0..=samples)
        .map(|k| {
            let p = k as f64 / samples as f64;
            let t = period_s * p;
            let mut pe = p + phase;
            if pe >= 1.0 {
                pe -= 1.0;
            }
            let tri = 1.0 - (2.0 * pe - 1.0).abs();
            let v = base_g_per_j * (1.0 + swing * (2.0 * tri - 1.0));
            (t, v)
        })
        .collect();
    CarbonSignal::linear(points).expect("valid phase-shifted diurnal")
}

/// One (region-count × dispatch × profile) cell.
#[derive(Debug, Clone)]
pub struct FederationCell {
    pub regions: usize,
    pub dispatch: DispatchKind,
    pub profile: String,
    pub pods: usize,
    pub completed: usize,
    pub unschedulable: usize,
    /// Pod + idle energy, summed over regions (kJ).
    pub total_kj: f64,
    /// Pod + idle CO₂, each region integrated against its own signal
    /// (grams) — the comparable federation-wide total.
    pub total_co2_g: f64,
    /// Per-region (name, pod + idle grams), in region order.
    pub region_co2_g: Vec<(String, f64)>,
    /// Per-region completed-pod counts, in region order.
    pub region_pods: Vec<usize>,
    pub wait_p95_s: f64,
    pub slo_miss: f64,
    pub makespan_s: f64,
    /// Scale-outs + activations and scale-ins, summed over regions.
    pub scale_outs: usize,
    pub scale_ins: usize,
}

/// The full federation grid.
#[derive(Debug, Clone)]
pub struct FederationReport {
    pub cells: Vec<FederationCell>,
    /// Dispatch log of the headline cell (max regions, the headline
    /// dispatch policy, greenpod) — `--events` streams it as JSONL.
    pub headline_dispatches: Vec<crate::api::ApiEvent>,
    /// The policy of the headline cell: the config `federation`
    /// section's `dispatch` when present, carbon-greedy otherwise.
    pub headline_dispatch: DispatchKind,
    pub max_regions: usize,
}

impl FederationReport {
    /// Look up one cell (panics if the grid does not contain it).
    pub fn cell(
        &self,
        regions: usize,
        dispatch: DispatchKind,
        profile: &str,
    ) -> &FederationCell {
        self.cells
            .iter()
            .find(|c| {
                c.regions == regions
                    && c.dispatch == dispatch
                    && c.profile == profile
            })
            .expect("cell in grid")
    }

    pub fn to_table(&self) -> Table {
        let mut t = Table::new(
            &format!(
                "Federation scenarios (bursty trace; per-region diurnal \
                 signals phase-shifted by 1/n period; CO2 = pod + idle, \
                 per-region ledgers; SLO: wait <= {SLO_WAIT_S:.0} s)"
            ),
            &[
                "regions",
                "dispatch",
                "profile",
                "pods",
                "unsched",
                "total kJ",
                "total CO2 g",
                "per-region CO2 g",
                "per-region pods",
                "wait p95 s",
                "SLO miss %",
                "scale out/in",
                "makespan s",
            ],
        );
        for c in &self.cells {
            t.row(vec![
                format!("{}", c.regions),
                c.dispatch.label().to_string(),
                c.profile.clone(),
                format!("{}", c.pods),
                format!("{}", c.unschedulable),
                format!("{:.3}", c.total_kj),
                format!("{:.2}", c.total_co2_g),
                c.region_co2_g
                    .iter()
                    .map(|(_, g)| format!("{g:.2}"))
                    .collect::<Vec<_>>()
                    .join("/"),
                c.region_pods
                    .iter()
                    .map(|n| n.to_string())
                    .collect::<Vec<_>>()
                    .join("/"),
                format!("{:.2}", c.wait_p95_s),
                format!("{:.1}", 100.0 * c.slo_miss),
                format!("{}/{}", c.scale_outs, c.scale_ins),
                format!("{:.1}", c.makespan_s),
            ]);
        }
        t
    }
}

/// The built-in region set for an `n`-region cell: paper clusters
/// named after [`FED_REGION_NAMES`], region j's diurnal signal
/// phase-shifted by j/n of the period around the config's eGRID base.
fn builtin_specs(ctx: &ExperimentContext, n: usize) -> Vec<RegionSpec> {
    let base_g = grams_co2_per_joule(&ctx.config.energy);
    (0..n)
        .map(|j| {
            let mut config = ctx.config.clone();
            config.federation = None;
            let signal = phase_shifted_diurnal(
                base_g,
                FED_SWING,
                BILLING_HORIZON_S,
                FED_SAMPLES,
                j as f64 / n as f64,
            );
            RegionSpec::new(FED_REGION_NAMES[j], config).with_carbon(signal)
        })
        .collect()
}

/// Run one cell and roll it up.
fn run_cell(
    ctx: &ExperimentContext,
    specs: &[RegionSpec],
    dispatch: DispatchKind,
    profile: &str,
    executor: &WorkloadExecutor,
    pods: Vec<crate::cluster::Pod>,
) -> Result<(FederationCell, FederationResult)> {
    let seed = ctx.config.experiment.seed;
    let mut params = FederationParams::with_beta_and_seed(
        ctx.config.experiment.contention_beta,
        seed,
    );
    params.billing_horizon_s = Some(BILLING_HORIZON_S);
    let engine = FederationEngine::new(specs, params, executor);
    let mut scheds = Vec::with_capacity(specs.len());
    for spec in specs {
        let registry = ProfileRegistry::new(&spec.config);
        let opts = ctx
            .build_options(WeightingScheme::EnergyCentric, seed, executor)
            .with_carbon(spec.carbon.clone());
        scheds.push(RegionSchedulers {
            topsis: Box::new(registry.build(profile, &opts)?),
            default: Box::new(registry.build("default-k8s", &opts)?),
        });
    }
    let mut dispatcher = build_dispatcher(dispatch);
    let n_pods = pods.len();
    let result = engine.run(pods, dispatcher.as_mut(), &mut scheds);

    let waits: Summary = result.queue_wait_summary(SchedulerKind::Topsis);
    let slo_miss = {
        let (mut miss, mut n) = (0usize, 0usize);
        for reg in &result.regions {
            for rec in &reg.run.records {
                if rec.scheduler == SchedulerKind::Topsis {
                    n += 1;
                    miss += usize::from(rec.wait_s > SLO_WAIT_S);
                }
            }
        }
        if n == 0 {
            0.0
        } else {
            miss as f64 / n as f64
        }
    };
    let cell = FederationCell {
        regions: specs.len(),
        dispatch,
        profile: profile.to_string(),
        pods: n_pods,
        completed: result.completed(),
        unschedulable: result.unschedulable(),
        total_kj: result.total_kj(SchedulerKind::Topsis) + result.idle_kj(),
        total_co2_g: result.total_co2_g(SchedulerKind::Topsis),
        region_co2_g: result
            .regions
            .iter()
            .map(|r| {
                (
                    r.name.clone(),
                    r.run.meter.total_co2_g(SchedulerKind::Topsis)
                        + r.run.meter.idle_co2_g(),
                )
            })
            .collect(),
        region_pods: result
            .regions
            .iter()
            .map(|r| r.run.records.len())
            .collect(),
        wait_p95_s: waits.p95,
        slo_miss,
        makespan_s: result.makespan_s(),
        scale_outs: result.scaling_count("scale-out")
            + result.scaling_count("activate"),
        scale_ins: result.scaling_count("scale-in"),
    };
    Ok((cell, result))
}

/// Run the grid: {1..=max regions} × {round-robin, least-pending,
/// carbon-greedy} × {greenpod, carbon-aware}, one shared bursty trace.
pub fn run_federation(ctx: &ExperimentContext) -> Result<FederationReport> {
    let executor = WorkloadExecutor::analytic();
    let trace =
        ElasticProcess::Bursty.trace(ctx.config.experiment.seed);
    let configured = match &ctx.config.federation {
        Some(fed) => {
            Some(RegionSpec::from_federation_config(&ctx.config, fed)?)
        }
        None => None,
    };
    let max_regions = configured
        .as_ref()
        .map_or(FED_REGION_NAMES.len(), |s| s.len());
    // The grid always sweeps every dispatch policy (that comparison is
    // the experiment); the config section's `dispatch` field picks
    // which cell's per-pod dispatch log is the headline `--events`
    // JSONL stream.
    let headline_dispatch = ctx
        .config
        .federation
        .as_ref()
        .map_or(DispatchKind::CarbonGreedy, |f| f.dispatch);

    let mut cells = Vec::new();
    let mut headline_dispatches = Vec::new();
    for n in 1..=max_regions {
        let specs = match &configured {
            Some(all) => all[..n].to_vec(),
            None => builtin_specs(ctx, n),
        };
        for dispatch in DispatchKind::ALL {
            for profile in ["greenpod", "carbon-aware"] {
                let pods = trace.to_pods(SchedulerKind::Topsis);
                let (cell, result) = run_cell(
                    ctx, &specs, dispatch, profile, &executor, pods,
                )?;
                if n == max_regions
                    && dispatch == headline_dispatch
                    && profile == "greenpod"
                {
                    headline_dispatches =
                        crate::api::dispatched_events(&result);
                }
                cells.push(cell);
            }
        }
    }
    Ok(FederationReport {
        cells,
        headline_dispatches,
        headline_dispatch,
        max_regions,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;

    fn report() -> &'static FederationReport {
        static REPORT: std::sync::OnceLock<FederationReport> =
            std::sync::OnceLock::new();
        REPORT.get_or_init(|| {
            run_federation(&ExperimentContext::new(Config::paper_default()))
                .unwrap()
        })
    }

    #[test]
    fn grid_is_complete_and_conserves_work() {
        let r = report();
        assert_eq!(r.max_regions, 3);
        assert_eq!(r.cells.len(), 3 * 3 * 2);
        let pods = r.cells[0].pods;
        assert!(pods > 0);
        for c in &r.cells {
            assert_eq!(c.pods, pods, "{c:?}");
            assert_eq!(
                c.completed + c.unschedulable,
                c.pods,
                "{}r/{}/{} lost pods",
                c.regions,
                c.dispatch.label(),
                c.profile
            );
            assert_eq!(
                c.unschedulable, 0,
                "{}r/{}/{} dropped pods",
                c.regions,
                c.dispatch.label(),
                c.profile
            );
            assert!(c.total_kj.is_finite() && c.total_kj > 0.0);
            assert!(c.total_co2_g.is_finite() && c.total_co2_g > 0.0);
            assert_eq!(c.region_co2_g.len(), c.regions);
            assert_eq!(c.region_pods.len(), c.regions);
            assert_eq!(c.region_pods.iter().sum::<usize>(), c.completed);
            // The roll-up equals the per-region sum.
            let sum: f64 = c.region_co2_g.iter().map(|(_, g)| g).sum();
            assert!(
                (sum - c.total_co2_g).abs() <= 1e-9 * c.total_co2_g,
                "{sum} vs {}",
                c.total_co2_g
            );
            assert!((0.0..=1.0).contains(&c.slo_miss));
            assert!(
                c.makespan_s <= BILLING_HORIZON_S,
                "{}r/{}/{} drained at {:.1} s past the billing horizon",
                c.regions,
                c.dispatch.label(),
                c.profile,
                c.makespan_s
            );
        }
        // The headline cell's dispatch log covers every pod.
        assert_eq!(r.headline_dispatches.len(), pods);
    }

    #[test]
    fn single_region_cells_are_identical_across_dispatch_policies() {
        // With one region every dispatcher routes every pod to region
        // 0, so the three policies must produce bit-identical cells.
        let r = report();
        for profile in ["greenpod", "carbon-aware"] {
            let rr = r.cell(1, DispatchKind::RoundRobin, profile);
            for kind in
                [DispatchKind::LeastPending, DispatchKind::CarbonGreedy]
            {
                let other = r.cell(1, kind, profile);
                assert_eq!(rr.total_kj, other.total_kj, "{profile}");
                assert_eq!(rr.total_co2_g, other.total_co2_g);
                assert_eq!(rr.wait_p95_s, other.wait_p95_s);
                assert_eq!(rr.makespan_s, other.makespan_s);
                assert_eq!(rr.region_pods, other.region_pods);
            }
        }
    }

    #[test]
    fn carbon_greedy_beats_round_robin_on_phase_shifted_signals() {
        // The acceptance headline: with >= 2 phase-shifted regions, at
        // equal admitted work, carbon-greedy dispatch emits no more
        // total gCO2 than round-robin.
        let r = report();
        for n in 2..=r.max_regions {
            for profile in ["greenpod", "carbon-aware"] {
                let rr = r.cell(n, DispatchKind::RoundRobin, profile);
                let cg = r.cell(n, DispatchKind::CarbonGreedy, profile);
                assert_eq!(rr.pods, cg.pods);
                assert_eq!(rr.unschedulable + cg.unschedulable, 0);
                assert!(
                    cg.total_co2_g <= rr.total_co2_g * (1.0 + 1e-9),
                    "{n}r/{profile}: carbon-greedy {:.3} g !<= \
                     round-robin {:.3} g",
                    cg.total_co2_g,
                    rr.total_co2_g
                );
            }
        }
    }

    #[test]
    fn phase_shift_zero_reproduces_the_diurnal_generator() {
        let base = 1.5e-4;
        let shifted =
            phase_shifted_diurnal(base, 0.5, 300.0, 12, 0.0);
        let plain = CarbonSignal::diurnal(base, 0.5, 300.0, 12).unwrap();
        assert_eq!(shifted.points(), plain.points());
        // A half-period shift starts dirty and is cleanest mid-period.
        let half = phase_shifted_diurnal(base, 0.5, 300.0, 12, 0.5);
        assert!((half.at(0.0) - base * 1.5).abs() < 1e-15);
        assert!((half.at(150.0) - base * 0.5).abs() < 1e-15);
    }

    #[test]
    fn grid_headline_defaults_to_carbon_greedy() {
        let r = report();
        assert_eq!(r.headline_dispatch, DispatchKind::CarbonGreedy);
    }

    #[test]
    fn config_federation_section_drives_regions_and_headline() {
        use crate::config::{FederationConfig, RegionConfig};
        // A config section overrides the built-in region set and picks
        // the headline `--events` cell's dispatch policy.
        let mut cfg = Config::paper_default();
        cfg.federation = Some(FederationConfig {
            dispatch: DispatchKind::LeastPending,
            regions: vec![
                RegionConfig::named("north"),
                RegionConfig::named("south"),
            ],
        });
        cfg.validate().unwrap();
        let r = run_federation(&ExperimentContext::new(cfg)).unwrap();
        assert_eq!(r.max_regions, 2);
        assert_eq!(r.cells.len(), 2 * 3 * 2);
        assert_eq!(r.headline_dispatch, DispatchKind::LeastPending);
        assert_eq!(r.headline_dispatches.len(), r.cells[0].pods);
        // Configured region names reach the cells.
        let two = r.cell(2, DispatchKind::LeastPending, "greenpod");
        assert_eq!(two.region_co2_g[0].0, "north");
        assert_eq!(two.region_co2_g[1].0, "south");
    }

    #[test]
    fn table_has_per_region_co2_columns() {
        let text = crate::metrics::format_table(&report().to_table());
        assert!(text.contains("per-region CO2 g"), "{text}");
        assert!(text.contains("carbon-greedy"), "{text}");
        assert!(text.contains("round-robin"));
        assert!(text.contains("least-pending"));
    }
}
