//! §V.D — node-allocation and per-workload analysis: where does each
//! strategy place pods, and which workload class saves the most energy?

// Ordered maps end to end: the allocation tables iterate these when
// rendering, so the row contents must not depend on hash order.
use std::collections::BTreeMap;

use crate::cluster::NodeCategory;
use crate::config::{
    CompetitionLevel, SchedulerKind, WeightingScheme,
};
use crate::metrics::Table;
use crate::workload::{WorkloadClass, WorkloadExecutor};

use super::{runner::run_once, ExperimentContext};

/// Allocation + per-class-savings analysis for one competition level.
#[derive(Debug, Clone)]
pub struct AllocAnalysis {
    pub level: CompetitionLevel,
    /// profile → category → pods placed there by TOPSIS.
    pub topsis_alloc:
        BTreeMap<WeightingScheme, BTreeMap<NodeCategory, u32>>,
    /// Default-scheduler allocation histogram (profile-independent in
    /// expectation; measured from the same runs).
    pub default_alloc: BTreeMap<NodeCategory, u32>,
    /// Energy-centric per-class optimization % (savings by workload).
    pub per_class_optimization: BTreeMap<WorkloadClass, f64>,
}

/// Run §V.D's analysis at one level (replications from config).
pub fn run_alloc_analysis(
    ctx: &ExperimentContext,
    level: CompetitionLevel,
) -> AllocAnalysis {
    let executor = WorkloadExecutor::analytic();
    let reps = ctx.config.experiment.replications;
    let mut topsis_alloc: BTreeMap<_, BTreeMap<NodeCategory, u32>> =
        BTreeMap::new();
    let mut default_alloc: BTreeMap<NodeCategory, u32> = BTreeMap::new();
    let mut class_sum: BTreeMap<WorkloadClass, (f64, f64)> = BTreeMap::new();

    for scheme in WeightingScheme::ALL {
        let entry = topsis_alloc.entry(scheme).or_default();
        for r in 0..reps {
            let seed = ctx.config.experiment.seed.wrapping_add(r as u64);
            let result = run_once(ctx, level, scheme, seed, &executor);
            for (cat, n) in result.allocations(SchedulerKind::Topsis) {
                *entry.entry(cat).or_insert(0) += n;
            }
            for (cat, n) in result.allocations(SchedulerKind::DefaultK8s) {
                *default_alloc.entry(cat).or_insert(0) += n;
            }
            if scheme == WeightingScheme::EnergyCentric {
                let t = result.meter.per_class_kj(SchedulerKind::Topsis);
                let d =
                    result.meter.per_class_kj(SchedulerKind::DefaultK8s);
                for class in WorkloadClass::ALL {
                    let e = class_sum.entry(class).or_insert((0.0, 0.0));
                    e.0 += *t.get(&class).unwrap_or(&0.0);
                    e.1 += *d.get(&class).unwrap_or(&0.0);
                }
            }
        }
    }

    let per_class_optimization = class_sum
        .into_iter()
        .map(|(class, (t, d))| {
            (class, if d > 0.0 { 100.0 * (d - t) / d } else { 0.0 })
        })
        .collect();

    AllocAnalysis {
        level,
        topsis_alloc,
        default_alloc,
        per_class_optimization,
    }
}

impl AllocAnalysis {
    pub fn to_table(&self) -> Table {
        let mut t = Table::new(
            &format!(
                "§V.D — Node allocation by profile ({} competition, \
                 pods over all replications)",
                self.level.label()
            ),
            &["Profile", "Cat A", "Cat B", "Cat C", "Cat Default"],
        );
        for scheme in WeightingScheme::ALL {
            let hist = &self.topsis_alloc[&scheme];
            let mut row = vec![scheme.label().to_string()];
            for cat in NodeCategory::ALL {
                row.push(format!("{}", hist.get(&cat).unwrap_or(&0)));
            }
            t.row(row);
        }
        let mut row = vec!["Default K8s (baseline)".to_string()];
        for cat in NodeCategory::ALL {
            row.push(format!(
                "{}",
                self.default_alloc.get(&cat).unwrap_or(&0)
            ));
        }
        t.row(row);
        t
    }

    pub fn per_class_table(&self) -> Table {
        let mut t = Table::new(
            "§V.D — Energy-centric optimization by workload class",
            &["Workload", "Optimization (%)"],
        );
        for class in WorkloadClass::ALL {
            t.row(vec![
                class.label().to_string(),
                format!(
                    "{:.2}",
                    self.per_class_optimization.get(&class).unwrap_or(&0.0)
                ),
            ]);
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;

    #[test]
    fn energy_centric_prefers_category_a_nodes() {
        let mut cfg = Config::paper_default();
        cfg.experiment.replications = 2;
        let ctx = ExperimentContext::new(cfg);
        let a = run_alloc_analysis(&ctx, CompetitionLevel::Low);

        let energy = &a.topsis_alloc[&WeightingScheme::EnergyCentric];
        let on_a = *energy.get(&NodeCategory::A).unwrap_or(&0);
        let on_c = *energy.get(&NodeCategory::C).unwrap_or(&0);
        assert!(
            on_a > on_c,
            "energy-centric put {on_a} pods on A vs {on_c} on C"
        );

        // Performance-centric must spread away from A relative to
        // energy-centric.
        let perf = &a.topsis_alloc[&WeightingScheme::PerformanceCentric];
        let perf_on_a = *perf.get(&NodeCategory::A).unwrap_or(&0);
        assert!(perf_on_a < on_a);

        // Tables render.
        assert!(crate::metrics::format_table(&a.to_table())
            .contains("Energy-centric"));
        assert!(crate::metrics::format_table(&a.per_class_table())
            .contains("Medium"));
    }

    #[test]
    fn tables_are_insertion_order_independent() {
        // Regression for the unordered-iter sweep: two analyses with
        // identical content built in opposite insertion orders must
        // render byte-identical tables — report rows may not depend
        // on map iteration order.
        let empty = AllocAnalysis {
            level: CompetitionLevel::Low,
            topsis_alloc: BTreeMap::new(),
            default_alloc: BTreeMap::new(),
            per_class_optimization: BTreeMap::new(),
        };
        let (mut fwd, mut rev) = (empty.clone(), empty);
        let cats = [NodeCategory::A, NodeCategory::B, NodeCategory::C];
        for scheme in WeightingScheme::ALL {
            let e = fwd.topsis_alloc.entry(scheme).or_default();
            for (i, c) in cats.iter().enumerate() {
                e.insert(*c, i as u32);
            }
        }
        for scheme in WeightingScheme::ALL.into_iter().rev() {
            let e = rev.topsis_alloc.entry(scheme).or_default();
            for (i, c) in cats.iter().enumerate().rev() {
                e.insert(*c, i as u32);
            }
        }
        for (i, c) in cats.iter().enumerate() {
            fwd.default_alloc.insert(*c, 7 + i as u32);
            fwd.per_class_optimization
                .insert(WorkloadClass::ALL[i], i as f64);
        }
        for (i, c) in cats.iter().enumerate().rev() {
            rev.default_alloc.insert(*c, 7 + i as u32);
            rev.per_class_optimization
                .insert(WorkloadClass::ALL[i], i as f64);
        }
        assert_eq!(
            crate::metrics::format_table(&fwd.to_table()),
            crate::metrics::format_table(&rev.to_table())
        );
        assert_eq!(
            crate::metrics::format_table(&fwd.per_class_table()),
            crate::metrics::format_table(&rev.per_class_table())
        );
    }
}
