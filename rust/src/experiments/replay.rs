//! Trace replay driver — `greenpod trace replay`: push any
//! [`WorkloadTrace`] through the federation engine's lazy arrival
//! source and roll the run up into one summary.
//!
//! The driver is a 1-region federation around the context's config
//! (optionally with a machine-event churn schedule attached), with
//! per-pod scheduler ownership chosen by [`TraceOwnership`]. Because
//! arrivals stream through [`StreamArrivals`], a million-pod synthetic
//! trace replays with peak live pods bounded by the in-flight count —
//! [`ReplaySummary::peak_live_pods`] and
//! [`ReplaySummary::peak_buffered`] report the two memory high-water
//! marks the bounded-replay test asserts on.

use anyhow::Result;

use crate::config::SchedulerKind;
use crate::federation::{
    FederationEngine, FederationParams, RegionSchedulers, RegionSpec,
    RoundRobin,
};
use crate::framework::ProfileRegistry;
use crate::metrics::Summary;
use crate::simulation::NodeChange;
use crate::trace::{StreamArrivals, TraceOwnership, WorkloadTrace};
use crate::workload::WorkloadExecutor;

use super::ExperimentContext;

/// Roll-up of one trace replay.
#[derive(Debug, Clone)]
pub struct ReplaySummary {
    /// Pods admitted from the trace.
    pub pods: usize,
    pub completed: usize,
    pub unschedulable: usize,
    /// Engine-side memory high-water mark: most pod slots live at
    /// once (streaming keeps this near the in-flight count; an eager
    /// run would hold the whole trace).
    pub peak_live_pods: usize,
    /// Reader-side high-water mark: most trace entries buffered at
    /// once (bounded by the reader's chunk size).
    pub peak_buffered: usize,
    pub makespan_s: f64,
    /// Pod energy of both scheduler halves plus idle (kJ).
    pub total_kj: f64,
    /// Pod CO₂ of both scheduler halves plus idle (grams).
    pub total_co2_g: f64,
    /// Queue-wait distribution over every completed pod.
    pub wait_mean_s: f64,
    pub wait_p95_s: f64,
}

/// Replay `trace` through a 1-region federation built from `ctx`'s
/// config, streaming arrivals. `node_events` attaches a machine-event
/// churn schedule (e.g. from
/// [`crate::trace::machine_events_to_node_changes`]); empty = the
/// fixed configured cluster.
pub fn run_trace_replay(
    ctx: &ExperimentContext,
    trace: &mut dyn WorkloadTrace,
    ownership: TraceOwnership,
    node_events: Vec<NodeChange>,
) -> Result<ReplaySummary> {
    let executor = WorkloadExecutor::analytic();
    let seed = ctx.config.experiment.seed;
    let mut config = ctx.config.clone();
    config.federation = None;
    let spec =
        RegionSpec::new("replay", config).with_node_events(node_events);
    let specs = [spec];

    let params = FederationParams::with_beta_and_seed(
        ctx.config.experiment.contention_beta,
        seed,
    );
    let engine = FederationEngine::new(&specs, params, &executor);
    let registry = ProfileRegistry::new(&specs[0].config);
    let opts = ctx
        .build_options(
            crate::config::WeightingScheme::EnergyCentric,
            seed,
            &executor,
        )
        .with_carbon(specs[0].carbon.clone());
    let mut scheds = [RegionSchedulers {
        topsis: Box::new(registry.build("greenpod", &opts)?),
        default: Box::new(registry.build("default-k8s", &opts)?),
    }];

    let mut source = StreamArrivals::new(trace, ownership);
    let mut dispatcher = RoundRobin::new();
    let result =
        engine.run_source(&mut source, &mut dispatcher, &mut scheds)?;

    let waits: Vec<f64> = result
        .regions
        .iter()
        .flat_map(|r| r.run.records.iter().map(|rec| rec.wait_s))
        .collect();
    let wait = Summary::of(&waits);
    Ok(ReplaySummary {
        pods: result.completed() + result.unschedulable(),
        completed: result.completed(),
        unschedulable: result.unschedulable(),
        peak_live_pods: result.peak_live_pods,
        peak_buffered: source.peak_buffered(),
        makespan_s: result.makespan_s(),
        total_kj: result.total_kj(SchedulerKind::Topsis)
            + result.total_kj(SchedulerKind::DefaultK8s)
            + result.idle_kj(),
        total_co2_g: result.pod_co2_g(SchedulerKind::Topsis)
            + result.pod_co2_g(SchedulerKind::DefaultK8s)
            + result.idle_co2_g(),
        wait_mean_s: wait.mean,
        wait_p95_s: wait.p95,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::trace::InMemoryTrace;
    use crate::workload::{ArrivalTrace, TraceSpec};

    fn ctx() -> ExperimentContext {
        ExperimentContext::new(Config::paper_default())
    }

    #[test]
    fn replay_conserves_pods_and_reports_finite_totals() {
        let spec = TraceSpec::surf_lisa(0.5, 300.0);
        let trace = ArrivalTrace::poisson(&spec, 42);
        let n = trace.entries.len();
        let mut mem = InMemoryTrace::new(trace.entries);
        let s = run_trace_replay(
            &ctx(),
            &mut mem,
            TraceOwnership::RoundRobin,
            Vec::new(),
        )
        .unwrap();
        assert_eq!(s.pods, n);
        assert_eq!(s.completed + s.unschedulable, n);
        assert!(s.completed > 0);
        assert!(s.total_kj.is_finite() && s.total_kj > 0.0);
        assert!(s.total_co2_g.is_finite() && s.total_co2_g > 0.0);
        assert!(s.makespan_s.is_finite() && s.makespan_s > 0.0);
        assert!(s.wait_mean_s.is_finite() && s.wait_mean_s >= 0.0);
        assert!(s.wait_p95_s.is_finite() && s.wait_p95_s >= 0.0);
        // Streaming never held the whole trace as live pods.
        assert!(s.peak_live_pods <= n);
        assert_eq!(s.peak_buffered, n); // in-memory trace: full length
    }

    #[test]
    fn replay_with_churn_still_conserves_pods() {
        let spec = TraceSpec::surf_lisa(0.5, 200.0);
        let trace = ArrivalTrace::poisson(&spec, 7);
        let n = trace.entries.len();
        let mut mem = InMemoryTrace::new(trace.entries);
        // Take node 0 down mid-trace and bring it back.
        let events = vec![
            NodeChange { at_s: 50.0, node: 0, up: false },
            NodeChange { at_s: 120.0, node: 0, up: true },
        ];
        let s = run_trace_replay(
            &ctx(),
            &mut mem,
            TraceOwnership::Fixed(SchedulerKind::Topsis),
            events,
        )
        .unwrap();
        assert_eq!(s.completed + s.unschedulable, n);
        assert!(s.completed > 0);
    }

    #[test]
    fn replay_surfaces_malformed_traces_as_errors() {
        use crate::trace::{ChunkedTraceReader, TraceFormat};
        let text = "{\"at_s\":2.0,\"class\":\"light\"}\n\
                    {\"at_s\":1.0,\"class\":\"light\"}\n";
        let mut r = ChunkedTraceReader::new(
            text.as_bytes(),
            TraceFormat::Jsonl,
            1,
        )
        .unwrap();
        let err = run_trace_replay(
            &ctx(),
            &mut r,
            TraceOwnership::RoundRobin,
            Vec::new(),
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("out of order"), "{err}");
    }
}
