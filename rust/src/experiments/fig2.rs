//! Fig. 2: heatmap of energy optimization % across competition levels
//! (columns) and scheduling profiles (rows).

use crate::config::{CompetitionLevel, WeightingScheme};
use crate::metrics::format_heatmap;

use super::Table6;

/// Render the Fig-2 heatmap from Table VI data.
pub fn render_fig2(t6: &Table6) -> String {
    let row_labels: Vec<String> = WeightingScheme::ALL
        .iter()
        .map(|s| s.label().to_string())
        .collect();
    let col_labels: Vec<String> = CompetitionLevel::ALL
        .iter()
        .map(|l| l.label().to_string())
        .collect();
    let values: Vec<Vec<f64>> = WeightingScheme::ALL
        .iter()
        .map(|&scheme| {
            CompetitionLevel::ALL
                .iter()
                .map(|&level| t6.cell(level, scheme).optimization_pct())
                .collect()
        })
        .collect();
    format_heatmap(
        "Fig. 2 — Energy Savings (Optimization %) across Competition \
         Levels and Profiles",
        &row_labels,
        &col_labels,
        &values,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::experiments::{run_table6, ExperimentContext};

    #[test]
    fn fig2_renders_every_cell() {
        let mut cfg = Config::paper_default();
        cfg.experiment.replications = 1;
        let t6 = run_table6(&ExperimentContext::new(cfg));
        let fig = render_fig2(&t6);
        for s in WeightingScheme::ALL {
            assert!(fig.contains(s.label()), "missing row {s:?}");
        }
        for l in CompetitionLevel::ALL {
            assert!(fig.contains(l.label()), "missing col {l:?}");
        }
        // 12 data cells rendered as percentages.
        assert_eq!(fig.matches('%').count() >= 12, true);
    }
}
