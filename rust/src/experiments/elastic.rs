//! Elasticity & churn scenarios: the ROADMAP's "node churn is wired
//! but only exercised by tests" item, promoted to a first-class
//! experiment driver.
//!
//! The grid crosses two arrival shapes (synchronized AIoT bursts,
//! open-loop Poisson) with four cluster modes:
//!
//! * **static** — the paper's fixed Table I cluster;
//! * **static-max** — the fixed cluster plus the autoscaler's full
//!   extra capacity, powered on for the whole run (the elasticity
//!   baseline: same peak capacity, no scaling);
//! * **churn** — the fixed cluster with an injected outage (two nodes
//!   fail mid-run and later rejoin) via `SimulationParams::node_events`;
//! * **autoscaled** — the fixed cluster driven by the queue-driven
//!   [`ThresholdAutoscaler`](crate::autoscaler::ThresholdAutoscaler).
//!
//! Each cell is run once per scheduler (all pods GreenPod, all pods
//! default kube-scheduler — the paired-run methodology of Table VI)
//! and reports *total* energy (pod attribution + unattributed node
//! idle), queue-wait p50/p95, SLO misses, and the node-count timeline.
//! The headline the e2e test pins: at equal admitted work, the
//! autoscaled cluster spends strictly less total energy than the
//! static-max cluster that holds the same peak capacity all along.

use crate::api::ApiEvent;
use crate::autoscaler::{AutoscalerPolicy, ThresholdConfig};
use crate::config::{ClusterConfig, Config, SchedulerKind, WeightingScheme};
use crate::framework::{BuildOptions, ProfileRegistry};
use crate::metrics::{Summary, Table};
use crate::simulation::{
    NodeChange, NodeCountSample, RunResult, ScalingRecord, SimulationEngine,
    SimulationParams,
};
use crate::workload::{ArrivalTrace, TraceSpec, WorkloadExecutor};

use super::ExperimentContext;

/// Queue wait beyond which a pod counts as an SLO miss (s).
pub const SLO_WAIT_S: f64 = 10.0;

/// Extra nodes the elastic scenarios may add beyond the base cluster.
pub const EXTRA_NODES: usize = 3;

/// Common idle-billing horizon (s): every cell's powered-on nodes are
/// billed over the same `[0, horizon]` window, so totals compare
/// configurations rather than event-stream lengths (the trace spans
/// 240 s; 300 s covers every cell's drain with margin).
pub const BILLING_HORIZON_S: f64 = 300.0;

/// Cluster elasticity modes of the scenario grid.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ClusterMode {
    Static,
    StaticMax,
    Churn,
    Autoscaled,
}

impl ClusterMode {
    pub const ALL: [ClusterMode; 4] = [
        ClusterMode::Static,
        ClusterMode::StaticMax,
        ClusterMode::Churn,
        ClusterMode::Autoscaled,
    ];

    pub fn label(&self) -> &'static str {
        match self {
            ClusterMode::Static => "static",
            ClusterMode::StaticMax => "static-max",
            ClusterMode::Churn => "churn",
            ClusterMode::Autoscaled => "autoscaled",
        }
    }
}

/// The two arrival shapes of the grid.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElasticProcess {
    Bursty,
    Poisson,
}

impl ElasticProcess {
    pub const ALL: [ElasticProcess; 2] =
        [ElasticProcess::Bursty, ElasticProcess::Poisson];

    pub fn label(&self) -> &'static str {
        match self {
            ElasticProcess::Bursty => "bursty",
            ElasticProcess::Poisson => "poisson",
        }
    }

    /// Complex-heavy AIoT mix: bursts of synchronized sensor uploads
    /// that overflow the base cluster, separated by gaps long enough
    /// for idle scale-in to pay off.
    pub(crate) fn trace(&self, seed: u64) -> ArrivalTrace {
        let spec = TraceSpec {
            rate_per_s: 0.3,
            duration_s: 240.0,
            p_light: 0.1,
            p_medium: 0.2,
            p_complex: 0.7,
            epochs: [2, 2, 1],
        };
        match self {
            ElasticProcess::Bursty => ArrivalTrace::bursty(&spec, 28, seed),
            ElasticProcess::Poisson => ArrivalTrace::poisson(&spec, seed),
        }
    }
}

/// The threshold policy every autoscaled cell runs (edge template —
/// scale-out adds energy-efficient e2 capacity).
pub fn elastic_policy(cluster: &ClusterConfig) -> ThresholdConfig {
    let base = cluster.total_nodes();
    ThresholdConfig {
        scale_out_pending: 3,
        scale_out_wait_p95_s: 15.0,
        provision_delay_s: 5.0,
        cooldown_s: 15.0,
        idle_scale_in_s: 20.0,
        min_nodes: base,
        max_nodes: base + EXTRA_NODES,
        template: ThresholdConfig::edge_template(cluster),
        carbon: None,
    }
}

/// The injected outage of the churn mode: one A node and one B node
/// fail mid-run and rejoin 90 s later.
pub fn churn_schedule() -> Vec<NodeChange> {
    vec![
        NodeChange { at_s: 60.0, node: 1, up: false },
        NodeChange { at_s: 60.0, node: 4, up: false },
        NodeChange { at_s: 150.0, node: 1, up: true },
        NodeChange { at_s: 150.0, node: 4, up: true },
    ]
}

/// One (process × mode × scheduler) cell.
#[derive(Debug, Clone)]
pub struct ElasticCell {
    pub process: ElasticProcess,
    pub mode: ClusterMode,
    pub scheduler: SchedulerKind,
    pub pods: usize,
    pub unschedulable: usize,
    /// Pod-attributed energy (kJ).
    pub pod_kj: f64,
    /// Unattributed node-idle energy (kJ).
    pub idle_kj: f64,
    /// pod_kj + idle_kj — the comparable total.
    pub total_kj: f64,
    pub wait_p50_s: f64,
    pub wait_p95_s: f64,
    /// Fraction of pods whose queue wait exceeded [`SLO_WAIT_S`].
    pub slo_miss: f64,
    pub makespan_s: f64,
    pub mean_nodes: f64,
    pub peak_nodes: usize,
    /// Capacity-adding actions: fresh provisions plus reactivations of
    /// previously scaled-in nodes.
    pub scale_outs: usize,
    pub scale_ins: usize,
    /// Ready/total node counts over the run.
    pub node_timeline: Vec<NodeCountSample>,
    /// Autoscaler actions, in decision order.
    pub scaling: Vec<ScalingRecord>,
}

impl ElasticCell {
    /// The cell's scaling actions in the serve loop's JSON-lines event
    /// vocabulary ([`ApiEvent::Scaled`]) — `greenpod experiment elastic
    /// --events` and `examples/elastic_burst.rs` stream these.
    pub fn scaling_events(&self) -> Vec<ApiEvent> {
        self.scaling
            .iter()
            .map(|s| {
                // Ready count once the action takes effect, read off the
                // (time-ordered) timeline — decision order can differ
                // from effect order when provisioning delays overlap a
                // scale-in, so cumulative arithmetic would be wrong.
                let ready_nodes = self
                    .node_timeline
                    .iter()
                    .take_while(|t| t.at_s <= s.effective_at_s)
                    .last()
                    .map_or(0, |t| t.ready_nodes);
                ApiEvent::Scaled {
                    at_s: s.at_s,
                    action: s.kind.to_string(),
                    node: s.node,
                    ready_nodes,
                }
            })
            .collect()
    }
}

/// The full scenario grid.
#[derive(Debug, Clone)]
pub struct ElasticityReport {
    pub cells: Vec<ElasticCell>,
    pub slo_wait_s: f64,
}

impl ElasticityReport {
    /// Look up one cell (panics if the grid does not contain it).
    pub fn cell(
        &self,
        process: ElasticProcess,
        mode: ClusterMode,
        scheduler: SchedulerKind,
    ) -> &ElasticCell {
        self.cells
            .iter()
            .find(|c| {
                c.process == process
                    && c.mode == mode
                    && c.scheduler == scheduler
            })
            .expect("cell in grid")
    }

    pub fn to_table(&self) -> Table {
        let mut t = Table::new(
            &format!(
                "Elasticity scenarios (total = pod + idle energy; \
                 SLO: wait <= {:.0} s)",
                self.slo_wait_s
            ),
            &[
                "arrivals", "cluster", "scheduler", "pods", "total kJ",
                "pod kJ", "idle kJ", "wait p50 s", "wait p95 s", "SLO miss %",
                "nodes mean/peak", "scale out/in", "makespan s",
            ],
        );
        for c in &self.cells {
            t.row(vec![
                c.process.label().to_string(),
                c.mode.label().to_string(),
                match c.scheduler {
                    SchedulerKind::Topsis => "greenpod".to_string(),
                    SchedulerKind::DefaultK8s => "default-k8s".to_string(),
                },
                format!("{}", c.pods),
                format!("{:.3}", c.total_kj),
                format!("{:.3}", c.pod_kj),
                format!("{:.3}", c.idle_kj),
                format!("{:.2}", c.wait_p50_s),
                format!("{:.2}", c.wait_p95_s),
                format!("{:.1}", 100.0 * c.slo_miss),
                format!("{:.2}/{}", c.mean_nodes, c.peak_nodes),
                format!("{}/{}", c.scale_outs, c.scale_ins),
                format!("{:.1}", c.makespan_s),
            ]);
        }
        t
    }
}

/// Run one cell: the given trace, all pods owned by `kind`, under one
/// cluster mode. (Named distinctly from `runner::run_cell`, the
/// factorial-cell driver re-exported by this module.)
fn run_scenario_cell(
    ctx: &ExperimentContext,
    process: ElasticProcess,
    mode: ClusterMode,
    kind: SchedulerKind,
    trace: &ArrivalTrace,
) -> ElasticCell {
    let base = &ctx.config;
    let mut cluster = base.cluster.clone();
    let mut params = SimulationParams::with_beta_and_seed(
        base.experiment.contention_beta,
        base.experiment.seed,
    );
    params.billing_horizon_s = Some(BILLING_HORIZON_S);
    match mode {
        ClusterMode::Static => {}
        ClusterMode::StaticMax => {
            let mut pool = ThresholdConfig::edge_template(&cluster);
            pool.count = EXTRA_NODES;
            cluster.pools.push(pool);
        }
        ClusterMode::Churn => params.node_events = churn_schedule(),
        ClusterMode::Autoscaled => {
            params.autoscaler = Some(AutoscalerPolicy::Threshold(
                elastic_policy(&cluster),
            ));
        }
    }
    let config = Config { cluster, ..base.clone() };

    let executor = WorkloadExecutor::analytic();
    let engine = SimulationEngine::new(&config, params, &executor);
    let registry = ProfileRegistry::new(&config);
    let opts = BuildOptions::new(&config, WeightingScheme::EnergyCentric)
        .with_executor(&executor);
    let mut topsis =
        registry.build("greenpod", &opts).expect("built-in profile");
    let mut default =
        registry.build("default-k8s", &opts).expect("built-in profile");
    let pods = trace.to_pods(kind);
    let n_pods = pods.len();
    let result: RunResult = engine.run(pods, &mut topsis, &mut default);

    let waits: Summary = result.queue_wait_summary(kind);
    ElasticCell {
        process,
        mode,
        scheduler: kind,
        pods: n_pods,
        unschedulable: result.unschedulable.len(),
        pod_kj: result.meter.total_kj(kind),
        idle_kj: result.idle_kj(),
        total_kj: result.meter.total_kj(kind) + result.idle_kj(),
        wait_p50_s: waits.p50,
        wait_p95_s: waits.p95,
        slo_miss: result.slo_miss_fraction(kind, SLO_WAIT_S),
        makespan_s: result.makespan_s,
        mean_nodes: result.mean_ready_nodes(),
        peak_nodes: result.peak_ready_nodes(),
        scale_outs: result.scaling_count("scale-out")
            + result.scaling_count("activate"),
        scale_ins: result.scaling_count("scale-in"),
        node_timeline: result.node_timeline,
        scaling: result.scaling,
    }
}

/// Run the full grid: {bursty, poisson} × {static, static-max, churn,
/// autoscaled} × {GreenPod, default kube-scheduler}, one seeded trace
/// per arrival shape shared by every cell in its row block.
pub fn run_elastic(ctx: &ExperimentContext) -> ElasticityReport {
    let mut cells = Vec::new();
    for process in ElasticProcess::ALL {
        let trace = process.trace(ctx.config.experiment.seed);
        for mode in ClusterMode::ALL {
            for kind in [SchedulerKind::Topsis, SchedulerKind::DefaultK8s] {
                cells.push(run_scenario_cell(ctx, process, mode, kind, &trace));
            }
        }
    }
    ElasticityReport { cells, slo_wait_s: SLO_WAIT_S }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report() -> &'static ElasticityReport {
        static REPORT: std::sync::OnceLock<ElasticityReport> =
            std::sync::OnceLock::new();
        REPORT.get_or_init(|| {
            run_elastic(&ExperimentContext::new(Config::paper_default()))
        })
    }

    #[test]
    fn grid_is_complete_and_all_work_is_admitted() {
        let r = report();
        assert_eq!(r.cells.len(), 16);
        for c in &r.cells {
            assert!(c.pods > 0);
            assert_eq!(
                c.unschedulable, 0,
                "{}/{}/{:?} dropped pods",
                c.process.label(),
                c.mode.label(),
                c.scheduler
            );
            assert!(c.total_kj.is_finite() && c.total_kj > 0.0);
            assert!(c.idle_kj > 0.0);
            // The common billing window must actually cover the drain,
            // or the equal-window energy comparison silently breaks.
            assert!(
                c.makespan_s <= BILLING_HORIZON_S,
                "{}/{}/{:?} drained at {:.1} s, past the {} s billing \
                 horizon",
                c.process.label(),
                c.mode.label(),
                c.scheduler,
                c.makespan_s,
                BILLING_HORIZON_S
            );
            assert!(c.wait_p95_s >= c.wait_p50_s);
            assert!((0.0..=1.0).contains(&c.slo_miss));
            assert!(c.peak_nodes >= 7);
        }
        // Equal admitted work within each arrival shape.
        for p in ElasticProcess::ALL {
            let counts: Vec<usize> = r
                .cells
                .iter()
                .filter(|c| c.process == p)
                .map(|c| c.pods)
                .collect();
            assert!(counts.windows(2).all(|w| w[0] == w[1]));
        }
    }

    #[test]
    fn autoscaled_burst_beats_static_max_on_total_energy() {
        // The acceptance headline: at equal admitted work, scaling the
        // extra capacity in and out costs strictly less total energy
        // than keeping it powered all along.
        let r = report();
        let auto = r.cell(
            ElasticProcess::Bursty,
            ClusterMode::Autoscaled,
            SchedulerKind::Topsis,
        );
        let maxed = r.cell(
            ElasticProcess::Bursty,
            ClusterMode::StaticMax,
            SchedulerKind::Topsis,
        );
        assert_eq!(auto.pods, maxed.pods);
        assert_eq!(auto.unschedulable + maxed.unschedulable, 0);
        assert!(
            auto.total_kj < maxed.total_kj,
            "autoscaled {:.3} kJ !< static-max {:.3} kJ",
            auto.total_kj,
            maxed.total_kj
        );
        // The autoscaler actually scaled, and returned to base size.
        assert!(auto.scale_outs >= 1);
        assert!(auto.scale_ins >= 1);
        assert!(auto.peak_nodes > 7);
        assert_eq!(auto.node_timeline.last().unwrap().ready_nodes, 7);
        assert!(auto.mean_nodes < maxed.mean_nodes);
    }

    #[test]
    fn autoscaling_relieves_static_queueing() {
        // Against the *base* static cluster, added elastic capacity
        // must not make waits worse.
        let r = report();
        let auto = r.cell(
            ElasticProcess::Bursty,
            ClusterMode::Autoscaled,
            SchedulerKind::Topsis,
        );
        let fixed = r.cell(
            ElasticProcess::Bursty,
            ClusterMode::Static,
            SchedulerKind::Topsis,
        );
        assert!(auto.wait_p95_s <= fixed.wait_p95_s + 1e-9);
        assert!(auto.slo_miss <= fixed.slo_miss + 1e-12);
    }

    #[test]
    fn churn_outage_raises_waits_over_static() {
        let r = report();
        let churn = r.cell(
            ElasticProcess::Poisson,
            ClusterMode::Churn,
            SchedulerKind::Topsis,
        );
        let fixed = r.cell(
            ElasticProcess::Poisson,
            ClusterMode::Static,
            SchedulerKind::Topsis,
        );
        assert_eq!(churn.pods, fixed.pods);
        // Losing two nodes for 90 s cannot *improve* the wait tail.
        assert!(churn.wait_p95_s >= fixed.wait_p95_s - 1e-9);
    }

    #[test]
    fn table_and_event_stream_render() {
        let r = report();
        let text = crate::metrics::format_table(&r.to_table());
        assert!(text.contains("autoscaled"));
        assert!(text.contains("static-max"));
        let auto = r.cell(
            ElasticProcess::Bursty,
            ClusterMode::Autoscaled,
            SchedulerKind::Topsis,
        );
        let events = auto.scaling_events();
        assert_eq!(events.len(), auto.scaling.len());
        assert_eq!(auto.scaling.len(), auto.scale_outs + auto.scale_ins);
        let json = events[0].to_json().to_string();
        assert!(json.contains("\"event\":\"scaled\""), "{json}");
    }
}
