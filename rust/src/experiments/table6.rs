//! Table VI: energy consumption per (competition level × profile),
//! TOPSIS vs default, with savings and optimization %, plus the
//! per-level and all-levels averages the paper reports.


use crate::config::{CompetitionLevel, WeightingScheme};
use crate::metrics::Table;

use super::{run_cell, CellResult, ExperimentContext};

/// One printable Table VI row.
#[derive(Debug, Clone)]
pub struct Table6Row {
    pub level: String,
    pub profile: String,
    pub default_kj: f64,
    pub topsis_kj: f64,
    pub savings_kj: f64,
    pub optimization_pct: f64,
}

/// The full Table VI result set.
#[derive(Debug, Clone)]
pub struct Table6 {
    pub cells: Vec<CellResult>,
    pub rows: Vec<Table6Row>,
    /// All-levels average optimization % (feeds §V.E extrapolation).
    pub average_optimization_pct: f64,
    /// Per-level average optimization % keyed in `CompetitionLevel::ALL`
    /// order (feeds §V.C's analysis).
    pub per_level_avg_pct: [f64; 3],
}

/// Run the full factorial and assemble Table VI.
pub fn run_table6(ctx: &ExperimentContext) -> Table6 {
    let mut cells = Vec::new();
    let mut rows = Vec::new();
    let mut per_level_avg = [0.0f64; 3];
    let mut grand_default = 0.0;
    let mut grand_topsis = 0.0;

    for (li, level) in CompetitionLevel::ALL.into_iter().enumerate() {
        let mut lvl_default = 0.0;
        let mut lvl_topsis = 0.0;
        for scheme in WeightingScheme::ALL {
            let cell = run_cell(ctx, level, scheme);
            rows.push(Table6Row {
                level: level.label().to_string(),
                profile: scheme.label().to_string(),
                default_kj: cell.default_kj,
                topsis_kj: cell.topsis_kj,
                savings_kj: cell.savings_kj(),
                optimization_pct: cell.optimization_pct(),
            });
            lvl_default += cell.default_kj;
            lvl_topsis += cell.topsis_kj;
            cells.push(cell);
        }
        let n = WeightingScheme::ALL.len() as f64;
        let (d, t) = (lvl_default / n, lvl_topsis / n);
        per_level_avg[li] = 100.0 * (d - t) / d;
        rows.push(Table6Row {
            level: level.label().to_string(),
            profile: format!("Average ({})", level.label()),
            default_kj: d,
            topsis_kj: t,
            savings_kj: d - t,
            optimization_pct: per_level_avg[li],
        });
        grand_default += d;
        grand_topsis += t;
    }

    let gd = grand_default / 3.0;
    let gt = grand_topsis / 3.0;
    let average_optimization_pct = 100.0 * (gd - gt) / gd;
    rows.push(Table6Row {
        level: "All".into(),
        profile: "Average (All)".into(),
        default_kj: gd,
        topsis_kj: gt,
        savings_kj: gd - gt,
        optimization_pct: average_optimization_pct,
    });

    Table6 { cells, rows, average_optimization_pct, per_level_avg_pct: per_level_avg }
}

impl Table6 {
    /// Render in the paper's format.
    pub fn to_table(&self) -> Table {
        let mut t = Table::new(
            "TABLE VI — ENERGY CONSUMPTION (default K8s vs GreenPod TOPSIS)",
            &["Level", "Profile", "Default K8s (kJ)", "TOPSIS (kJ)",
              "Savings (kJ)", "Optimization (%)"],
        );
        for r in &self.rows {
            t.row(vec![
                r.level.clone(),
                r.profile.clone(),
                format!("{:.4}", r.default_kj),
                format!("{:.4}", r.topsis_kj),
                format!("{:.4}", r.savings_kj),
                format!("{:.2} ▼", r.optimization_pct),
            ]);
        }
        t
    }

    /// The cell for a given (level, scheme).
    pub fn cell(
        &self,
        level: CompetitionLevel,
        scheme: WeightingScheme,
    ) -> &CellResult {
        self.cells
            .iter()
            .find(|c| c.level == level && c.scheme == scheme)
            .expect("cell present")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;

    /// The paper's qualitative shape must hold (DESIGN.md §5's
    /// reproduction criterion). Uses reduced replications for speed.
    #[test]
    fn table6_shape_matches_paper() {
        let mut cfg = Config::paper_default();
        cfg.experiment.replications = 3;
        let ctx = ExperimentContext::new(cfg);
        let t6 = run_table6(&ctx);

        for level in CompetitionLevel::ALL {
            let e = t6
                .cell(level, WeightingScheme::EnergyCentric)
                .optimization_pct();
            let p = t6
                .cell(level, WeightingScheme::PerformanceCentric)
                .optimization_pct();
            // Energy-centric always beats performance-centric.
            assert!(e > p, "{level:?}: energy {e:.1}% !> perf {p:.1}%");
            // Energy-centric achieves substantial savings everywhere.
            assert!(e > 15.0, "{level:?}: energy-centric only {e:.1}%");
        }
        // Resource-efficient is strong at low/medium competition.
        for level in [CompetitionLevel::Low, CompetitionLevel::Medium] {
            let r = t6
                .cell(level, WeightingScheme::ResourceEfficient)
                .optimization_pct();
            let p = t6
                .cell(level, WeightingScheme::PerformanceCentric)
                .optimization_pct();
            assert!(r > p, "{level:?}: resource {r:.1}% !> perf {p:.1}%");
        }
        // 13 + 3 + 1 → 12 cells + 3 level averages + grand average.
        assert_eq!(t6.rows.len(), 16);
        assert!(t6.average_optimization_pct > 0.0);
    }
}
