//! Table VII + §V.E: real-world energy / CO₂ / cost extrapolation from
//! a measured optimization percentage.


use crate::config::EnergyModelConfig;
use crate::energy::{ImpactAssessment, ImpactParams};
use crate::metrics::Table;

/// Table VII: single cluster + 10-cluster data center columns.
#[derive(Debug, Clone)]
pub struct Table7 {
    pub optimization_pct: f64,
    pub single: ImpactAssessment,
    pub ten: ImpactAssessment,
}

/// Compute Table VII for a measured optimization percentage (the paper
/// plugs in its all-levels average, 19.38%).
pub fn run_table7(cfg: &EnergyModelConfig, optimization_pct: f64) -> Table7 {
    let frac = optimization_pct / 100.0;
    let single =
        ImpactAssessment::compute(cfg, &ImpactParams::surf_lisa(frac));
    let ten = ImpactAssessment::compute(
        cfg,
        &ImpactParams::surf_lisa(frac).with_clusters(10),
    );
    Table7 { optimization_pct, single, ten }
}

impl Table7 {
    pub fn to_table(&self) -> Table {
        let mut t = Table::new(
            &format!(
                "TABLE VII — ENERGY AND COST SAVINGS ASSESSMENT \
                 (measured optimization: {:.2}%)",
                self.optimization_pct
            ),
            &["Metric", "Single Cluster (SURF Lisa)",
              "Medium-Sized D.C. (10 Clusters)"],
        );
        let rows: Vec<(&str, String, String)> = vec![
            (
                "Daily Energy Savings",
                format!("{:.4} MWh", self.single.daily_mwh),
                format!("{:.2} MWh", self.ten.daily_mwh),
            ),
            (
                "Monthly Energy Savings",
                format!("{:.2} MWh", self.single.monthly_mwh),
                format!("{:.2} MWh", self.ten.monthly_mwh),
            ),
            (
                "Annual Energy Savings",
                format!("{:.2} MWh", self.single.annual_mwh),
                format!("{:.2} MWh", self.ten.annual_mwh),
            ),
            (
                "Annual CO2 Reduction",
                format!("{:.2} metric tons", self.single.annual_co2_tons),
                format!("{:.2} metric tons", self.ten.annual_co2_tons),
            ),
            (
                "Vehicles Removed",
                format!("{:.2} vehicles", self.single.vehicles_equivalent),
                format!("{:.2} vehicles", self.ten.vehicles_equivalent),
            ),
            (
                "Annual Cost Savings",
                format!("${:.0}", self.single.annual_cost_usd),
                format!("${:.0}", self.ten.annual_cost_usd),
            ),
            (
                "Total Savings (1 Yr, Min)",
                format!("${:.0}", self.single.total_1yr_usd_min),
                format!("${:.0}", self.ten.total_1yr_usd_min),
            ),
            (
                "Total Savings (1 Yr, Max)",
                format!("${:.0}", self.single.total_1yr_usd_max),
                format!("${:.0}", self.ten.total_1yr_usd_max),
            ),
            (
                "Total Savings (5 Yrs, Min)",
                format!("${:.0}", self.single.total_5yr_usd_min),
                format!("${:.0}", self.ten.total_5yr_usd_min),
            ),
            (
                "Total Savings (5 Yrs, Max)",
                format!("${:.0}", self.single.total_5yr_usd_max),
                format!("${:.0}", self.ten.total_5yr_usd_max),
            ),
        ];
        for (m, a, b) in rows {
            t.row(vec![m.to_string(), a, b]);
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_inputs_reproduce_table7() {
        let t7 = run_table7(&EnergyModelConfig::default(), 19.38);
        assert!((t7.single.annual_mwh - 10.70).abs() < 0.05);
        assert!((t7.ten.annual_cost_usd - 13795.0).abs() < 100.0);
        let rendered = crate::metrics::format_table(&t7.to_table());
        assert!(rendered.contains("Annual CO2 Reduction"));
        assert!(rendered.contains("10 Clusters"));
    }
}
