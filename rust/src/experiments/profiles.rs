//! Profile comparison on the elastic grid: every registered scheduling
//! profile (framework built-ins + `Config::profiles`) drives the same
//! bursty AIoT trace on the static and the autoscaled cluster, so
//! profiles are compared at equal admitted work — `greenpod experiment
//! profiles`.
//!
//! This is the experiment the old monolithic API could not express:
//! `carbon-aware` and `hybrid-topsis-balanced` (and any config-defined
//! composition) run beside the two ported legacy pipelines with no new
//! scheduler structs, only profile definitions.

use anyhow::Result;

use crate::config::{SchedulerKind, WeightingScheme};
use crate::framework::ProfileRegistry;
use crate::metrics::{Summary, Table};
use crate::simulation::{RunResult, SimulationEngine, SimulationParams};
use crate::workload::WorkloadExecutor;

use super::{
    elastic_policy, ClusterMode, ElasticProcess, ExperimentContext,
    BILLING_HORIZON_S, SLO_WAIT_S,
};
use crate::autoscaler::AutoscalerPolicy;

/// One (profile × cluster mode) cell.
#[derive(Debug, Clone)]
pub struct ProfileCell {
    pub profile: String,
    pub mode: ClusterMode,
    pub pods: usize,
    pub unschedulable: usize,
    /// Pod-attributed energy (kJ).
    pub pod_kj: f64,
    /// Unattributed node-idle energy (kJ).
    pub idle_kj: f64,
    /// pod_kj + idle_kj — the comparable total.
    pub total_kj: f64,
    /// Grid CO₂ of the total (grams), from the meter's signal-integrated
    /// ledger (pod attribution + idle floor). Under the default
    /// constant signal this is exactly the legacy `total × eGRID`
    /// conversion.
    pub co2_g: f64,
    pub wait_p50_s: f64,
    pub wait_p95_s: f64,
    pub slo_miss: f64,
    pub makespan_s: f64,
}

/// The full profile comparison.
#[derive(Debug, Clone)]
pub struct ProfilesReport {
    pub cells: Vec<ProfileCell>,
}

impl ProfilesReport {
    /// Profile names covered (in run order, deduplicated).
    pub fn profile_names(&self) -> Vec<String> {
        let mut names: Vec<String> = Vec::new();
        for c in &self.cells {
            if !names.contains(&c.profile) {
                names.push(c.profile.clone());
            }
        }
        names
    }

    pub fn cell(&self, profile: &str, mode: ClusterMode) -> &ProfileCell {
        self.cells
            .iter()
            .find(|c| c.profile == profile && c.mode == mode)
            .expect("cell in grid")
    }

    pub fn to_table(&self) -> Table {
        let mut t = Table::new(
            &format!(
                "Scheduling profiles on the elastic grid (bursty \
                 arrivals; total = pod + idle energy; SLO: wait <= \
                 {SLO_WAIT_S:.0} s)"
            ),
            &[
                "profile", "cluster", "pods", "total kJ", "pod kJ",
                "idle kJ", "CO2 g", "wait p50 s", "wait p95 s",
                "SLO miss %", "makespan s",
            ],
        );
        for c in &self.cells {
            t.row(vec![
                c.profile.clone(),
                c.mode.label().to_string(),
                format!("{}", c.pods),
                format!("{:.3}", c.total_kj),
                format!("{:.3}", c.pod_kj),
                format!("{:.3}", c.idle_kj),
                format!("{:.1}", c.co2_g),
                format!("{:.2}", c.wait_p50_s),
                format!("{:.2}", c.wait_p95_s),
                format!("{:.1}", 100.0 * c.slo_miss),
                format!("{:.1}", c.makespan_s),
            ]);
        }
        t
    }
}

/// Run every registered profile over the bursty elastic trace on the
/// static and autoscaled clusters. All pods are owned by the profile
/// under test (uniform deployment — the paired-run methodology).
pub fn run_profiles(ctx: &ExperimentContext) -> Result<ProfilesReport> {
    let base = &ctx.config;
    let registry = ProfileRegistry::new(base);
    let executor = WorkloadExecutor::analytic();
    let trace = ElasticProcess::Bursty.trace(base.experiment.seed);

    let mut cells = Vec::new();
    for name in registry.names() {
        for mode in [ClusterMode::Static, ClusterMode::Autoscaled] {
            let mut params = SimulationParams::with_beta_and_seed(
                base.experiment.contention_beta,
                base.experiment.seed,
            );
            params.billing_horizon_s = Some(BILLING_HORIZON_S);
            if mode == ClusterMode::Autoscaled {
                params.autoscaler = Some(AutoscalerPolicy::Threshold(
                    elastic_policy(&base.cluster),
                ));
            }
            let opts = ctx.build_options(
                WeightingScheme::EnergyCentric,
                base.experiment.seed,
                &executor,
            );
            // The profile under test drives *all* pods (they are tagged
            // Topsis, the engine's "first scheduler" slot); the second
            // slot never schedules.
            let mut under_test = registry.build(&name, &opts)?;
            let mut unused = registry.build("default-k8s", &opts)?;
            let engine = SimulationEngine::new(base, params, &executor);
            let pods = trace.to_pods(SchedulerKind::Topsis);
            let n_pods = pods.len();
            let result: RunResult =
                engine.run(pods, &mut under_test, &mut unused);

            let waits: Summary =
                result.queue_wait_summary(SchedulerKind::Topsis);
            let pod_kj = result.meter.total_kj(SchedulerKind::Topsis);
            let idle_kj = result.idle_kj();
            let total_kj = pod_kj + idle_kj;
            cells.push(ProfileCell {
                profile: name.clone(),
                mode,
                pods: n_pods,
                unschedulable: result.unschedulable.len(),
                pod_kj,
                idle_kj,
                total_kj,
                co2_g: result.meter.total_co2_g(SchedulerKind::Topsis)
                    + result.meter.idle_co2_g(),
                wait_p50_s: waits.p50,
                wait_p95_s: waits.p95,
                slo_miss: result
                    .slo_miss_fraction(SchedulerKind::Topsis, SLO_WAIT_S),
                makespan_s: result.makespan_s,
            });
        }
    }
    Ok(ProfilesReport { cells })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Config, BUILTIN_PROFILE_NAMES};

    fn report() -> &'static ProfilesReport {
        static REPORT: std::sync::OnceLock<ProfilesReport> =
            std::sync::OnceLock::new();
        REPORT.get_or_init(|| {
            run_profiles(&ExperimentContext::new(Config::paper_default()))
                .unwrap()
        })
    }

    #[test]
    fn grid_covers_all_registered_profiles() {
        let r = report();
        let names = r.profile_names();
        // The acceptance floor: >= 4 profiles, at least two of which
        // the old monolithic API could not express.
        assert!(names.len() >= 4, "{names:?}");
        for name in BUILTIN_PROFILE_NAMES {
            assert!(names.iter().any(|n| n == name), "{name} missing");
        }
        assert!(names.iter().any(|n| n == "carbon-aware"));
        assert!(names.iter().any(|n| n == "hybrid-topsis-balanced"));
        assert_eq!(r.cells.len(), 2 * names.len());
    }

    #[test]
    fn equal_admitted_work_and_sane_metrics() {
        let r = report();
        let pods = r.cells[0].pods;
        assert!(pods > 0);
        for c in &r.cells {
            assert_eq!(c.pods, pods, "{}/{}", c.profile, c.mode.label());
            assert_eq!(
                c.unschedulable,
                0,
                "{}/{} dropped pods",
                c.profile,
                c.mode.label()
            );
            assert!(c.total_kj.is_finite() && c.total_kj > 0.0);
            assert!(c.co2_g > 0.0);
            assert!(c.wait_p95_s >= c.wait_p50_s);
            assert!((0.0..=1.0).contains(&c.slo_miss));
            assert!(c.makespan_s <= BILLING_HORIZON_S);
        }
    }

    #[test]
    fn greenpod_profile_matches_elastic_grid_cell() {
        // The framework `greenpod` profile on the autoscaled bursty
        // cell must reproduce the elastic experiment's GreenPod cell —
        // same trace, same policy, schedulers now built via the
        // registry in both drivers.
        let r = report();
        let ctx = ExperimentContext::new(Config::paper_default());
        let elastic = super::super::run_elastic(&ctx);
        let mine = r.cell("greenpod", ClusterMode::Autoscaled);
        let theirs = elastic.cell(
            ElasticProcess::Bursty,
            ClusterMode::Autoscaled,
            SchedulerKind::Topsis,
        );
        assert_eq!(mine.pods, theirs.pods);
        assert_eq!(mine.total_kj, theirs.total_kj);
        assert_eq!(mine.wait_p95_s, theirs.wait_p95_s);
    }

    #[test]
    fn table_renders_every_profile() {
        let r = report();
        let text = crate::metrics::format_table(&r.to_table());
        for name in BUILTIN_PROFILE_NAMES {
            assert!(text.contains(name), "{name} not in table");
        }
    }
}
