//! Ablations beyond the paper: (a) MCDA method — TOPSIS vs SAW / VIKOR /
//! COPRAS under identical decision matrices; (b) scoring backend —
//! pure-Rust vs the PJRT Pallas-kernel artifact (equivalence + cost).


use crate::config::{CompetitionLevel, WeightingScheme};
use crate::mcda::McdaMethod;
use crate::metrics::Table;

use super::{run_cell, ExperimentContext};

/// Per-method results on the energy-centric profile.
#[derive(Debug, Clone)]
pub struct AblationResult {
    pub level: CompetitionLevel,
    pub rows: Vec<(McdaMethod, f64, f64)>, // (method, opt %, sched ms)
}

/// Run the MCDA-method ablation at one competition level.
pub fn run_ablation(
    ctx: &ExperimentContext,
    level: CompetitionLevel,
) -> AblationResult {
    let mut rows = Vec::new();
    for method in McdaMethod::ALL {
        let cell_ctx = ExperimentContext {
            config: ctx.config.clone(),
            registry: None, // Rust backends only; PJRT covered elsewhere
            mcda_method: method,
        };
        let cell =
            run_cell(&cell_ctx, level, WeightingScheme::EnergyCentric);
        rows.push((method, cell.optimization_pct(), cell.topsis_sched_ms));
    }
    AblationResult { level, rows }
}

impl AblationResult {
    pub fn to_table(&self) -> Table {
        let mut t = Table::new(
            &format!(
                "Ablation — MCDA method (energy-centric, {} competition)",
                self.level.label()
            ),
            &["Method", "Optimization (%)", "Sched time (ms)"],
        );
        for (m, opt, ms) in &self.rows {
            t.row(vec![
                format!("{m:?}"),
                format!("{opt:.2}"),
                format!("{ms:.4}"),
            ]);
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;

    #[test]
    fn all_methods_produce_positive_optimization() {
        let mut cfg = Config::paper_default();
        cfg.experiment.replications = 2;
        let ctx = ExperimentContext::new(cfg);
        let ab = run_ablation(&ctx, CompetitionLevel::Medium);
        assert_eq!(ab.rows.len(), 4);
        for (m, opt, _) in &ab.rows {
            assert!(
                *opt > 0.0,
                "{m:?} failed to save energy ({opt:.2}%)"
            );
        }
        assert!(crate::metrics::format_table(&ab.to_table())
            .contains("Topsis"));
    }
}
