//! The factorial cell runner: one (competition level × weighting
//! scheme) cell, replicated over seeds, exactly as Table III prescribes.
//!
//! Comparison methodology: *paired runs*. Each replication generates
//! one Table V pod set and deploys it twice — once entirely under the
//! default scheduler (baseline) and once entirely under GreenPod with
//! the cell's profile (treatment) — so the energy delta isolates the
//! scheduling decision, and the "Default K8s (kJ)" column is constant
//! across profiles within a level, exactly as the paper's Table VI
//! shows. (The half/half mixed deployment of Table V is exercised by
//! `run_once`, the §V.D analysis, and the e2e example.)

use std::rc::Rc;


use crate::config::{
    CompetitionLevel, Config, SchedulerKind, WeightingScheme,
};
use crate::framework::{BuildOptions, ProfileRegistry};
use crate::mcda::McdaMethod;
use crate::runtime::ArtifactRegistry;
use crate::simulation::{RunResult, SimulationEngine, SimulationParams};
use crate::workload::{generate_pods, WorkloadExecutor};

/// Shared context for experiment drivers: config + optional PJRT
/// registry (when present, GreenPod scores through the Pallas-kernel
/// artifact; otherwise through the pure-Rust TOPSIS — same math).
pub struct ExperimentContext {
    pub config: Config,
    pub registry: Option<Rc<ArtifactRegistry>>,
    pub mcda_method: McdaMethod,
}

impl ExperimentContext {
    pub fn new(config: Config) -> Self {
        Self { config, registry: None, mcda_method: McdaMethod::Topsis }
    }

    pub fn with_registry(mut self, registry: Rc<ArtifactRegistry>) -> Self {
        self.registry = Some(registry);
        self
    }

    pub fn with_method(mut self, method: McdaMethod) -> Self {
        self.mcda_method = method;
        self
    }

    /// Profile build options carrying this context's scheme, seed,
    /// MCDA method, PJRT registry and executor calibration.
    pub fn build_options(
        &self,
        scheme: WeightingScheme,
        seed: u64,
        executor: &WorkloadExecutor,
    ) -> BuildOptions {
        BuildOptions::new(&self.config, scheme)
            .with_seed(seed)
            .with_executor(executor)
            .with_method(self.mcda_method)
            .with_pjrt(self.registry.clone())
    }
}

/// Aggregated result of one factorial cell.
#[derive(Debug, Clone)]
pub struct CellResult {
    pub level: CompetitionLevel,
    pub scheme: WeightingScheme,
    /// Mean per-pod energy (kJ), default-scheduler half (Table VI col 1).
    pub default_kj: f64,
    /// Mean per-pod energy (kJ), TOPSIS half (Table VI col 2).
    pub topsis_kj: f64,
    /// Mean scheduling latency (ms) per scheduler.
    pub default_sched_ms: f64,
    pub topsis_sched_ms: f64,
    /// Mean per-pod queue wait (s) per scheduler — the latency cost of
    /// energy-aware placement the event engine surfaces.
    pub topsis_wait_s: f64,
    pub default_wait_s: f64,
    /// p95 per-pod queue wait (s), averaged over replications.
    pub topsis_wait_p95_s: f64,
    pub default_wait_p95_s: f64,
    /// Fraction of TOPSIS pods placed on Category-A nodes.
    pub topsis_alloc_efficiency: f64,
    pub default_alloc_efficiency: f64,
    pub replications: u32,
    pub unschedulable: usize,
}

impl CellResult {
    /// kJ saved per pod (Table VI col 3).
    pub fn savings_kj(&self) -> f64 {
        self.default_kj - self.topsis_kj
    }

    /// Optimization percentage (Table VI col 4).
    pub fn optimization_pct(&self) -> f64 {
        if self.default_kj <= 0.0 {
            0.0
        } else {
            100.0 * self.savings_kj() / self.default_kj
        }
    }
}

/// Run one factorial cell: `replications` seeded runs, averaged.
pub fn run_cell(
    ctx: &ExperimentContext,
    level: CompetitionLevel,
    scheme: WeightingScheme,
) -> CellResult {
    let cfg = &ctx.config;
    let executor = WorkloadExecutor::analytic();
    let mut acc = CellResult {
        level,
        scheme,
        default_kj: 0.0,
        topsis_kj: 0.0,
        default_sched_ms: 0.0,
        topsis_sched_ms: 0.0,
        topsis_wait_s: 0.0,
        default_wait_s: 0.0,
        topsis_wait_p95_s: 0.0,
        default_wait_p95_s: 0.0,
        topsis_alloc_efficiency: 0.0,
        default_alloc_efficiency: 0.0,
        replications: cfg.experiment.replications,
        unschedulable: 0,
    };
    let reps = cfg.experiment.replications;
    for r in 0..reps {
        let seed = cfg.experiment.seed.wrapping_add(r as u64);
        let baseline =
            run_uniform(ctx, level, scheme, seed, &executor,
                        SchedulerKind::DefaultK8s);
        let treatment =
            run_uniform(ctx, level, scheme, seed, &executor,
                        SchedulerKind::Topsis);
        acc.default_kj += baseline.mean_kj(SchedulerKind::DefaultK8s);
        acc.topsis_kj += treatment.mean_kj(SchedulerKind::Topsis);
        acc.default_sched_ms +=
            baseline.mean_sched_ms(SchedulerKind::DefaultK8s);
        acc.topsis_sched_ms +=
            treatment.mean_sched_ms(SchedulerKind::Topsis);
        let t_wait = treatment.queue_wait_summary(SchedulerKind::Topsis);
        let d_wait = baseline.queue_wait_summary(SchedulerKind::DefaultK8s);
        acc.topsis_wait_s += t_wait.mean;
        acc.default_wait_s += d_wait.mean;
        acc.topsis_wait_p95_s += t_wait.p95;
        acc.default_wait_p95_s += d_wait.p95;
        acc.topsis_alloc_efficiency +=
            treatment.allocation_efficiency(SchedulerKind::Topsis);
        acc.default_alloc_efficiency +=
            baseline.allocation_efficiency(SchedulerKind::DefaultK8s);
        acc.unschedulable +=
            baseline.unschedulable.len() + treatment.unschedulable.len();
    }
    let n = reps as f64;
    acc.default_kj /= n;
    acc.topsis_kj /= n;
    acc.default_sched_ms /= n;
    acc.topsis_sched_ms /= n;
    acc.topsis_wait_s /= n;
    acc.default_wait_s /= n;
    acc.topsis_wait_p95_s /= n;
    acc.default_wait_p95_s /= n;
    acc.topsis_alloc_efficiency /= n;
    acc.default_alloc_efficiency /= n;
    acc
}

/// One paired-run half: the Table V pod set with every pod owned by
/// `kind` (baseline = all default, treatment = all TOPSIS).
pub fn run_uniform(
    ctx: &ExperimentContext,
    level: CompetitionLevel,
    scheme: WeightingScheme,
    seed: u64,
    executor: &WorkloadExecutor,
    kind: SchedulerKind,
) -> RunResult {
    let cfg = &ctx.config;
    let mut pods = generate_pods(level, &cfg.experiment, seed).pods;
    for p in &mut pods {
        p.scheduler = kind;
    }
    run_pods(ctx, pods, scheme, seed, executor)
}

/// One seeded *mixed* (Table V half/half) run of one cell — the live
/// deployment shape; used by the §V.D analysis and the e2e example.
pub fn run_once(
    ctx: &ExperimentContext,
    level: CompetitionLevel,
    scheme: WeightingScheme,
    seed: u64,
    executor: &WorkloadExecutor,
) -> RunResult {
    let cfg = &ctx.config;
    let pods = generate_pods(level, &cfg.experiment, seed).pods;
    run_pods(ctx, pods, scheme, seed, executor)
}

/// Shared run mechanics for uniform and mixed deployments. Schedulers
/// are composed through the profile registry — the framework profiles
/// were pinned bit-identical to the legacy monoliths before those were
/// retired, so every pinned table/figure is unchanged.
fn run_pods(
    ctx: &ExperimentContext,
    pods: Vec<crate::cluster::Pod>,
    scheme: WeightingScheme,
    seed: u64,
    executor: &WorkloadExecutor,
) -> RunResult {
    let cfg = &ctx.config;
    let registry = ProfileRegistry::new(cfg);
    let opts = ctx.build_options(scheme, seed, executor);
    let mut topsis =
        registry.build("greenpod", &opts).expect("built-in profile");
    let mut default =
        registry.build("default-k8s", &opts).expect("built-in profile");
    let engine = SimulationEngine::new(
        cfg,
        SimulationParams::with_beta_and_seed(
            cfg.experiment.contention_beta,
            seed,
        ),
        executor,
    );
    let mut result = engine.run(pods, &mut topsis, &mut default);
    result.pjrt_fallbacks = topsis.pjrt_fallbacks();
    result
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_ctx() -> ExperimentContext {
        let mut cfg = Config::paper_default();
        cfg.experiment.replications = 2;
        ExperimentContext::new(cfg)
    }

    #[test]
    fn energy_centric_cell_saves_energy() {
        let cell = run_cell(
            &quick_ctx(),
            CompetitionLevel::Medium,
            WeightingScheme::EnergyCentric,
        );
        assert!(cell.topsis_kj > 0.0 && cell.default_kj > 0.0);
        assert!(
            cell.optimization_pct() > 10.0,
            "energy-centric optimization only {:.2}%",
            cell.optimization_pct()
        );
        assert_eq!(cell.unschedulable, 0);
        // The event engine reports queue-wait distributions.
        assert!(cell.topsis_wait_s >= 0.0 && cell.topsis_wait_s.is_finite());
        assert!(cell.default_wait_s >= 0.0 && cell.default_wait_s.is_finite());
        assert!(cell.topsis_wait_p95_s >= 0.0);
        assert!(cell.default_wait_p95_s >= 0.0);
    }

    #[test]
    fn performance_centric_saves_less_than_energy_centric() {
        let ctx = quick_ctx();
        let perf = run_cell(
            &ctx,
            CompetitionLevel::Low,
            WeightingScheme::PerformanceCentric,
        );
        let energy = run_cell(
            &ctx,
            CompetitionLevel::Low,
            WeightingScheme::EnergyCentric,
        );
        assert!(
            energy.optimization_pct() > perf.optimization_pct(),
            "energy {:.2}% !> perf {:.2}%",
            energy.optimization_pct(),
            perf.optimization_pct()
        );
    }

    #[test]
    fn cell_deterministic() {
        let ctx = quick_ctx();
        let a = run_cell(&ctx, CompetitionLevel::Low,
                         WeightingScheme::General);
        let b = run_cell(&ctx, CompetitionLevel::Low,
                         WeightingScheme::General);
        assert_eq!(a.topsis_kj, b.topsis_kj);
        assert_eq!(a.default_kj, b.default_kj);
    }
}
