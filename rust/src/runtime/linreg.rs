//! LinReg workload execution: runs the paper's Table II training jobs
//! for real through the PJRT artifacts.
//!
//! A pod's "containerized workload" is one of the `linreg_epoch_*`
//! artifacts executed `epochs` times; the runner returns measured
//! wall-clock per epoch and the loss trace, which the e2e example logs
//! and the simulation uses to calibrate its analytic execution model.

use std::time::Instant;

use crate::runtime::ArtifactRegistry;
use crate::util::rng::Rng;
use crate::workload::WorkloadClass;

/// A synthetic regression dataset generated Rust-side (mirrors
/// `python/compile/model.py::make_dataset`'s distribution, not its exact
/// streams — correctness is judged by loss decrease, and exact python
/// parity is covered by golden.json replay).
#[derive(Debug, Clone)]
pub struct RustDataset {
    pub x: Vec<f32>,
    pub y: Vec<f32>,
    pub n: usize,
    pub d: usize,
}

impl RustDataset {
    /// Sample x ~ N(0,1)/sqrt(d) (Box–Muller), w_true ~ N(0,1),
    /// y = x·w_true + noise.
    pub fn generate(seed: u64, n: usize, d: usize, noise: f32) -> Self {
        let mut rng = Rng::seed_from_u64(seed);
        let mut normal = move || rng.normal() as f32;
        let scale = 1.0 / (d as f32).sqrt();
        let x: Vec<f32> = (0..n * d).map(|_| normal() * scale).collect();
        let w_true: Vec<f32> = (0..d).map(|_| normal()).collect();
        let y: Vec<f32> = (0..n)
            .map(|i| {
                let dot: f32 = (0..d)
                    .map(|j| x[i * d + j] * w_true[j])
                    .sum();
                dot + noise * normal()
            })
            .collect();
        Self { x, y, n, d }
    }
}

/// Result of running one pod's training job.
#[derive(Debug, Clone)]
pub struct EpochResult {
    /// Loss at the start of each executed step (concatenated epochs).
    pub losses: Vec<f32>,
    /// Final weight vector.
    pub weights: Vec<f32>,
    /// Measured wall-clock per epoch artifact call (seconds).
    pub epoch_secs: Vec<f64>,
}

/// Executes linreg workloads via PJRT.
pub struct LinRegRunner<'a> {
    registry: &'a ArtifactRegistry,
}

impl<'a> LinRegRunner<'a> {
    pub fn new(registry: &'a ArtifactRegistry) -> Self {
        Self { registry }
    }

    /// Run `epochs` epoch-artifact calls for `class`, threading the
    /// weights through. `seed` fixes the dataset.
    pub fn run(
        &self,
        class: WorkloadClass,
        epochs: u32,
        seed: u64,
        lr: f32,
    ) -> anyhow::Result<EpochResult> {
        let name = class.epoch_artifact();
        let exe = self.registry.load(name)?;
        let entry = self.registry.entry(name)?;
        let (n, d) = (
            entry.samples.unwrap_or(0),
            entry.features.unwrap_or(0),
        );
        anyhow::ensure!(n > 0 && d > 0, "artifact {name} missing shape info");
        let steps = entry.steps.unwrap_or(1);

        let ds = RustDataset::generate(seed, n, d, 0.01);
        let x = xla::Literal::vec1(&ds.x)
            .reshape(&[n as i64, d as i64])
            .map_err(|e| anyhow::anyhow!("reshape x: {e:?}"))?;
        let y = xla::Literal::vec1(&ds.y);
        let lr_lit = xla::Literal::from(lr);

        let mut w = vec![0.0f32; d];
        let mut losses = Vec::with_capacity(epochs as usize * steps);
        let mut epoch_secs = Vec::with_capacity(epochs as usize);
        for _ in 0..epochs {
            let w_lit = xla::Literal::vec1(&w);
            let t0 = Instant::now();
            let result = exe
                .execute::<xla::Literal>(&[
                    w_lit,
                    x.reshape(&[n as i64, d as i64])
                        .map_err(|e| anyhow::anyhow!("reshape: {e:?}"))?,
                    y.reshape(&[n as i64])
                        .map_err(|e| anyhow::anyhow!("reshape: {e:?}"))?,
                    lr_lit.reshape(&[]).map_err(|e| anyhow::anyhow!("{e:?}"))?,
                ])
                .map_err(|e| anyhow::anyhow!("execute {name}: {e:?}"))?[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow::anyhow!("fetch: {e:?}"))?;
            epoch_secs.push(t0.elapsed().as_secs_f64());
            let (w_out, loss_out) = result
                .to_tuple2()
                .map_err(|e| anyhow::anyhow!("untuple: {e:?}"))?;
            w = w_out.to_vec().map_err(|e| anyhow::anyhow!("{e:?}"))?;
            let step_losses: Vec<f32> =
                loss_out.to_vec().map_err(|e| anyhow::anyhow!("{e:?}"))?;
            losses.extend_from_slice(&step_losses);
        }
        Ok(EpochResult { losses, weights: w, epoch_secs })
    }

    /// Measure the mean epoch wall-clock for a class (used once at
    /// startup to calibrate the simulation's analytic execution model).
    pub fn calibrate(
        &self,
        class: WorkloadClass,
        reps: u32,
    ) -> anyhow::Result<f64> {
        let res = self.run(class, reps.max(1), 1234, 0.5)?;
        // Skip the first call (compile/warmup noise) when possible.
        let times = if res.epoch_secs.len() > 1 {
            &res.epoch_secs[1..]
        } else {
            &res.epoch_secs[..]
        };
        Ok(times.iter().sum::<f64>() / times.len() as f64)
    }
}
