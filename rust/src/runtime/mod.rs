//! PJRT runtime: loads the AOT artifacts (`make artifacts`) and executes
//! them from the Rust hot path. Python is never involved at runtime.
//!
//! Interchange format is HLO *text* (see `python/compile/aot.py` and
//! /opt/xla-example/README.md): `HloModuleProto::from_text_file` →
//! `XlaComputation::from_proto` → `PjRtClient::compile` → `execute`.
//!
//! `PjRtLoadedExecutable` is not `Send`; the runtime is designed for
//! single-threaded use (the simulation engine is synchronous, and the
//! `serve` loop keeps execution on its own thread).

mod artifacts;
mod linreg;
mod topsis_exec;

pub use artifacts::{ArtifactRegistry, Manifest, ManifestEntry};
pub use linreg::{EpochResult, LinRegRunner, RustDataset};
pub use topsis_exec::PjrtTopsisEngine;

/// Locate the artifacts directory: `$GREENPOD_ARTIFACTS`, else the
/// nearest `artifacts/` with a manifest walking up from the current
/// directory (so examples, tests and benches work from anywhere in the
/// repo).
pub fn default_artifacts_dir() -> std::path::PathBuf {
    if let Ok(p) = std::env::var("GREENPOD_ARTIFACTS") {
        return p.into();
    }
    let mut dir = std::env::current_dir().unwrap_or_else(|_| ".".into());
    loop {
        let cand = dir.join("artifacts");
        if cand.join("manifest.json").exists() {
            return cand;
        }
        if !dir.pop() {
            return "artifacts".into();
        }
    }
}
