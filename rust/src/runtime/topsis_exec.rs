//! PJRT-backed TOPSIS scoring: executes the fused Pallas kernel
//! artifact (`topsis_score_n*`) as the scheduler's scoring backend.
//!
//! The engine pads the live `n × 5` decision matrix into the smallest
//! artifact tier (rows → invalid, columns → zero-weight slots) and
//! returns the closeness coefficients for the real rows. Numerically
//! identical to `mcda::topsis_closeness` — verified by integration
//! tests and usable interchangeably via
//! `scheduler::ScoringBackend`.

use std::rc::Rc;

use crate::mcda::{DecisionProblem, Direction};
use crate::runtime::ArtifactRegistry;

/// Device-resident inputs that rarely change between scoring calls:
/// weights / benefit masks are fixed per scheduling profile and the
/// valid mask only depends on (tier, n). Caching them as `PjRtBuffer`s
/// and using `execute_b` removes 3 of the 4 host→device uploads per
/// decision (§Perf in EXPERIMENTS.md).
struct CachedStatics {
    tier: usize,
    n_valid: usize,
    weights: Vec<f32>,
    benefit: Vec<f32>,
    w_buf: xla::PjRtBuffer,
    b_buf: xla::PjRtBuffer,
    v_buf: xla::PjRtBuffer,
}

/// Reusable scoring engine over the artifact registry.
pub struct PjrtTopsisEngine {
    registry: Rc<ArtifactRegistry>,
    criteria_slots: usize,
    /// Reused padding buffers (hot path: one scoring call per pod).
    matrix_buf: Vec<f32>,
    weights_buf: Vec<f32>,
    benefit_buf: Vec<f32>,
    valid_buf: Vec<f32>,
    statics: Option<CachedStatics>,
}

impl PjrtTopsisEngine {
    pub fn new(registry: Rc<ArtifactRegistry>) -> Self {
        let criteria_slots = registry.manifest().criteria_slots;
        Self {
            registry,
            criteria_slots,
            matrix_buf: Vec::new(),
            weights_buf: Vec::new(),
            benefit_buf: Vec::new(),
            valid_buf: Vec::new(),
            statics: None,
        }
    }

    /// Score a decision problem through the PJRT artifact. Returns
    /// closeness coefficients for the `p.n` real alternatives.
    pub fn closeness(&mut self, p: &DecisionProblem) -> anyhow::Result<Vec<f64>> {
        let (name, tier) = self.registry.topsis_tier(p.n)?;
        let exe = self.registry.load(&name)?;
        let c_slots = self.criteria_slots;
        let c = p.c();
        anyhow::ensure!(
            c <= c_slots,
            "{c} criteria exceed artifact slots {c_slots}"
        );

        // Pad matrix: rows beyond n get valid=0, columns beyond c get
        // weight 0 (both provably inert — see python tests).
        self.matrix_buf.clear();
        self.matrix_buf.resize(tier * c_slots, 0.0);
        for row in 0..p.n {
            for col in 0..c {
                self.matrix_buf[row * c_slots + col] = p.at(row, col) as f32;
            }
        }
        self.weights_buf.clear();
        self.weights_buf.resize(c_slots, 0.0);
        self.benefit_buf.clear();
        self.benefit_buf.resize(c_slots, 0.0);
        for (col, cr) in p.criteria.iter().enumerate() {
            self.weights_buf[col] = cr.weight as f32;
            self.benefit_buf[col] = match cr.direction {
                Direction::Benefit => 1.0,
                Direction::Cost => 0.0,
            };
        }
        self.valid_buf.clear();
        self.valid_buf.resize(tier, 0.0);
        for v in self.valid_buf.iter_mut().take(p.n) {
            *v = 1.0;
        }

        // Refresh the cached device-resident statics if the profile or
        // tier changed since the last call.
        let stale = match &self.statics {
            Some(s) => {
                s.tier != tier
                    || s.n_valid != p.n
                    || s.weights != self.weights_buf
                    || s.benefit != self.benefit_buf
            }
            None => true,
        };
        if stale {
            let client = self.registry.client();
            let mk = |data: &[f32], dims: &[usize]| {
                client
                    .buffer_from_host_buffer::<f32>(data, dims, None)
                    .map_err(|e| anyhow::anyhow!("upload: {e:?}"))
            };
            self.statics = Some(CachedStatics {
                tier,
                n_valid: p.n,
                weights: self.weights_buf.clone(),
                benefit: self.benefit_buf.clone(),
                w_buf: mk(&self.weights_buf, &[c_slots])?,
                b_buf: mk(&self.benefit_buf, &[c_slots])?,
                v_buf: mk(&self.valid_buf, &[tier])?,
            });
        }
        let statics = self.statics.as_ref().expect("just set");

        // Only the matrix changes per decision: one upload + execute_b.
        let matrix = self
            .registry
            .client()
            .buffer_from_host_buffer::<f32>(
                &self.matrix_buf,
                &[tier, c_slots],
                None,
            )
            .map_err(|e| anyhow::anyhow!("upload matrix: {e:?}"))?;
        let args = [&matrix, &statics.w_buf, &statics.b_buf, &statics.v_buf];
        let result = exe
            .execute_b::<&xla::PjRtBuffer>(&args)
            .map_err(|e| anyhow::anyhow!("execute {name}: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetch result: {e:?}"))?;
        // aot.py lowers with return_tuple=True → 1-tuple.
        let out = result
            .to_tuple1()
            .map_err(|e| anyhow::anyhow!("untuple: {e:?}"))?;
        let scores: Vec<f32> =
            out.to_vec().map_err(|e| anyhow::anyhow!("to_vec: {e:?}"))?;
        Ok(scores.iter().take(p.n).map(|&x| x as f64).collect())
    }
}

// Tests that exercise the artifact live in rust/tests/pjrt_integration.rs
// (they need `make artifacts` to have run).
