//! Manifest-driven artifact registry with a compile cache.
//!
//! `artifacts/manifest.json` (written by `python/compile/aot.py`) maps
//! artifact names to HLO-text files and I/O shapes. The registry
//! compiles each artifact at most once per process (compilation is the
//! expensive step — see EXPERIMENTS.md §Perf) and hands out references
//! to the cached `PjRtLoadedExecutable`.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

use crate::util::json::Json;

/// One tensor description in the manifest.
#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

/// One artifact entry in the manifest.
#[derive(Debug, Clone)]
pub struct ManifestEntry {
    pub kind: String,
    pub path: String,
    pub nodes: Option<usize>,
    pub criteria: Option<usize>,
    pub workload: Option<String>,
    pub samples: Option<usize>,
    pub features: Option<usize>,
    pub steps: Option<usize>,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// The whole manifest file.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub criteria_slots: usize,
    pub epoch_steps: usize,
    pub entries: HashMap<String, ManifestEntry>,
}

fn tensor_specs(v: &Json, key: &str) -> anyhow::Result<Vec<TensorSpec>> {
    let arr = v
        .req(key)?
        .as_arr()
        .ok_or_else(|| anyhow::anyhow!("`{key}` is not an array"))?;
    arr.iter()
        .map(|t| {
            Ok(TensorSpec {
                name: t.req_str("name")?.to_string(),
                shape: t
                    .req("shape")?
                    .as_arr()
                    .ok_or_else(|| anyhow::anyhow!("shape not array"))?
                    .iter()
                    .map(|d| {
                        d.as_usize().ok_or_else(|| {
                            anyhow::anyhow!("shape dim not integer")
                        })
                    })
                    .collect::<anyhow::Result<Vec<_>>>()?,
            })
        })
        .collect()
}

impl Manifest {
    /// Parse from the JSON text `python/compile/aot.py` writes.
    pub fn parse(text: &str) -> anyhow::Result<Self> {
        let v = Json::parse(text)?;
        let mut entries = HashMap::new();
        let obj = v
            .req("entries")?
            .as_obj()
            .ok_or_else(|| anyhow::anyhow!("`entries` is not an object"))?;
        for (name, e) in obj {
            entries.insert(
                name.clone(),
                ManifestEntry {
                    kind: e.req_str("kind")?.to_string(),
                    path: e.req_str("path")?.to_string(),
                    nodes: e.get("nodes").and_then(Json::as_usize),
                    criteria: e.get("criteria").and_then(Json::as_usize),
                    workload: e
                        .get("workload")
                        .and_then(Json::as_str)
                        .map(String::from),
                    samples: e.get("samples").and_then(Json::as_usize),
                    features: e.get("features").and_then(Json::as_usize),
                    steps: e.get("steps").and_then(Json::as_usize),
                    inputs: tensor_specs(e, "inputs")?,
                    outputs: tensor_specs(e, "outputs")?,
                },
            );
        }
        Ok(Manifest {
            criteria_slots: v.req_usize("criteria_slots")?,
            epoch_steps: v.req_usize("epoch_steps")?,
            entries,
        })
    }
}

/// Loads HLO-text artifacts and caches compiled executables.
pub struct ArtifactRegistry {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
}

impl ArtifactRegistry {
    /// Open the registry over an artifacts directory.
    pub fn open(dir: impl AsRef<Path>) -> anyhow::Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path).map_err(|e| {
            anyhow::anyhow!(
                "cannot read {} (run `make artifacts` first): {e}",
                manifest_path.display()
            )
        })?;
        let manifest = Manifest::parse(&text)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("PJRT CPU client: {e:?}"))?;
        Ok(Self { client, dir, manifest, cache: RefCell::new(HashMap::new()) })
    }

    /// Open at the default location (env var / repo walk-up).
    pub fn open_default() -> anyhow::Result<Self> {
        Self::open(super::default_artifacts_dir())
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Entry metadata for `name`.
    pub fn entry(&self, name: &str) -> anyhow::Result<&ManifestEntry> {
        self.manifest
            .entries
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("artifact `{name}` not in manifest"))
    }

    /// Compile (or fetch from cache) the executable for `name`.
    pub fn load(&self, name: &str) -> anyhow::Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.borrow().get(name) {
            return Ok(exe.clone());
        }
        let entry = self.entry(name)?;
        let path = self.dir.join(&entry.path);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| {
                anyhow::anyhow!("parse HLO text {}: {e:?}", path.display())
            })?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compile `{name}`: {e:?}"))?;
        let exe = Rc::new(exe);
        self.cache.borrow_mut().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Warm the compile cache for a set of artifacts (startup-time cost
    /// instead of first-request latency — the vLLM-router pattern).
    pub fn warmup<'a>(
        &self,
        names: impl IntoIterator<Item = &'a str>,
    ) -> anyhow::Result<()> {
        for n in names {
            self.load(n)?;
        }
        Ok(())
    }

    /// Number of compiled executables currently cached.
    pub fn cached_count(&self) -> usize {
        self.cache.borrow().len()
    }

    /// Smallest TOPSIS artifact tier that fits `n` candidate nodes.
    pub fn topsis_tier(&self, n: usize) -> anyhow::Result<(String, usize)> {
        let mut tiers: Vec<usize> = self
            .manifest
            .entries
            .values()
            .filter(|e| e.kind == "topsis")
            .filter_map(|e| e.nodes)
            .collect();
        tiers.sort_unstable();
        for t in tiers {
            if t >= n {
                return Ok((format!("topsis_score_n{t}"), t));
            }
        }
        anyhow::bail!("no TOPSIS artifact tier fits {n} nodes (max is 64)")
    }
}

#[cfg(test)]
mod tests {
    // Registry tests that require built artifacts live in
    // rust/tests/pjrt_integration.rs; here we only test pure logic.
    use super::*;

    #[test]
    fn manifest_parses_minimal() {
        let json = r#"{
            "criteria_slots": 8, "epoch_steps": 8,
            "entries": {
                "topsis_score_n4": {
                    "kind": "topsis", "nodes": 4, "criteria": 8,
                    "path": "topsis_score_n4.hlo.txt",
                    "inputs": [{"name": "matrix", "shape": [4, 8]}],
                    "outputs": [{"name": "closeness", "shape": [4]}]
                }
            }
        }"#;
        let m = Manifest::parse(json).unwrap();
        assert_eq!(m.criteria_slots, 8);
        assert_eq!(m.entries["topsis_score_n4"].nodes, Some(4));
    }
}
