//! In-process kube-like API loop: live pod submission, binding, and
//! completion events — the "serving" counterpart of the discrete-event
//! simulation.
//!
//! Pods arrive on an `std::sync::mpsc` channel (from a trace replayer,
//! stdin, or a test thread); the loop schedules each with its owner
//! scheduler, models execution as a deadline on a monotonic timer wheel
//! (a `BinaryHeap` of `Instant`s, compressed by `time_scale`), and
//! emits lifecycle events through a callback. Everything runs on one
//! thread: schedulers and PJRT executables are not `Send`, and
//! kube-scheduler's own scheduling cycle is sequential per profile too.

use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::time::{Duration, Instant};

use crate::cluster::{ClusterState, Pod, PodId};
use crate::config::{Config, SchedulerKind, J_PER_KWH};
use crate::energy::{CarbonSignal, EnergyMeter};
use crate::federation::FederationResult;
use crate::scheduler::Scheduler;
use crate::simulation::contention_factor;
use crate::util::json::Json;
use crate::workload::{TraceEntry, WorkloadExecutor};

/// A pod submission (what `kubectl apply` would carry).
#[derive(Debug, Clone)]
pub struct PodSubmission {
    pub entry: TraceEntry,
    pub scheduler: SchedulerKind,
}

/// Lifecycle events emitted by the loop.
#[derive(Debug, Clone, PartialEq)]
pub enum ApiEvent {
    Bound {
        pod: PodId,
        name: String,
        node: String,
        /// Name of the scheduling profile (or legacy scheduler) that
        /// placed the pod — attributes each binding when multiple
        /// profiles serve one trace.
        profile: String,
        sched_latency_us: f64,
        /// Virtual seconds the pod queued before binding (wall wait
        /// scaled by `time_scale` — the serve-loop counterpart of the
        /// event engine's `wait_s`).
        queue_wait_s: f64,
        /// Grid carbon intensity at bind time (gCO₂/kWh), read off the
        /// config's carbon signal at the loop's virtual clock — lets
        /// downstream consumers attribute each placement to a clean or
        /// dirty grid window.
        grid_g_per_kwh: f64,
    },
    Unschedulable {
        pod: PodId,
        name: String,
    },
    Completed {
        pod: PodId,
        name: String,
        duration_s: f64,
        joules: f64,
    },
    /// A federation dispatch decision: an arriving pod routed to a
    /// named region by the federation dispatcher *before* in-cluster
    /// placement — emitted when replaying federation results
    /// (`greenpod experiment federation --events`). The JSONL `region`
    /// field attributes every line to its cluster.
    Dispatched {
        pod: PodId,
        region: String,
        at_s: f64,
    },
    /// A cluster-scaling action (autoscaler scale-out/scale-in or a
    /// scheduled churn change), in the same JSONL vocabulary as the
    /// pod lifecycle — emitted when replaying simulation results that
    /// carried scaling records (`experiments::ElasticCell::
    /// scaling_events`).
    Scaled {
        at_s: f64,
        /// `"scale-out"`, `"scale-in"` or `"activate"`.
        action: String,
        node: usize,
        /// Ready-node count after the action takes effect.
        ready_nodes: usize,
    },
    Drained {
        completed: u64,
        unschedulable: u64,
        total_kj: f64,
    },
}

impl ApiEvent {
    /// JSON-lines rendering (the `serve` subcommand's output format).
    /// Every id/count field goes through the lossless [`Json::Uint`]
    /// variant: `u64` pod ids routed through `Json::Num`'s f64 were
    /// silently corrupted at and above 2⁵³ (regression-tested below).
    pub fn to_json(&self) -> Json {
        match self {
            ApiEvent::Bound {
                pod,
                name,
                node,
                profile,
                sched_latency_us,
                queue_wait_s,
                grid_g_per_kwh,
            } => Json::obj(vec![
                ("event", Json::Str("bound".into())),
                ("pod", Json::Uint(*pod)),
                ("name", Json::Str(name.clone())),
                ("node", Json::Str(node.clone())),
                ("profile", Json::Str(profile.clone())),
                ("sched_latency_us", Json::Num(*sched_latency_us)),
                ("queue_wait_s", Json::Num(*queue_wait_s)),
                ("grid_g_per_kwh", Json::Num(*grid_g_per_kwh)),
            ]),
            ApiEvent::Unschedulable { pod, name } => Json::obj(vec![
                ("event", Json::Str("unschedulable".into())),
                ("pod", Json::Uint(*pod)),
                ("name", Json::Str(name.clone())),
            ]),
            ApiEvent::Completed { pod, name, duration_s, joules } => {
                Json::obj(vec![
                    ("event", Json::Str("completed".into())),
                    ("pod", Json::Uint(*pod)),
                    ("name", Json::Str(name.clone())),
                    ("duration_s", Json::Num(*duration_s)),
                    ("joules", Json::Num(*joules)),
                ])
            }
            ApiEvent::Dispatched { pod, region, at_s } => Json::obj(vec![
                ("event", Json::Str("dispatched".into())),
                ("pod", Json::Uint(*pod)),
                ("region", Json::Str(region.clone())),
                ("at_s", Json::Num(*at_s)),
            ]),
            ApiEvent::Scaled { at_s, action, node, ready_nodes } => {
                Json::obj(vec![
                    ("event", Json::Str("scaled".into())),
                    ("at_s", Json::Num(*at_s)),
                    ("action", Json::Str(action.clone())),
                    ("node", Json::Uint(*node as u64)),
                    ("ready_nodes", Json::Uint(*ready_nodes as u64)),
                ])
            }
            ApiEvent::Drained { completed, unschedulable, total_kj } => {
                Json::obj(vec![
                    ("event", Json::Str("drained".into())),
                    ("completed", Json::Uint(*completed)),
                    ("unschedulable", Json::Uint(*unschedulable)),
                    ("total_kj", Json::Num(*total_kj)),
                ])
            }
        }
    }
}

/// A federation dispatch log as JSONL-ready [`ApiEvent::Dispatched`]
/// events (region indexes resolved to names) — what `greenpod
/// experiment federation --events` streams. Lives here rather than on
/// [`FederationResult`] so the simulation kernel never depends on the
/// serving/event layer.
pub fn dispatched_events(fed: &FederationResult) -> Vec<ApiEvent> {
    fed.assignments
        .iter()
        .map(|a| ApiEvent::Dispatched {
            pod: a.pod,
            region: fed.regions[a.region].name.clone(),
            at_s: a.at_s,
        })
        .collect()
}

/// Timer-wheel entry: a running pod's completion deadline.
struct Running {
    due: Instant,
    seq: u64,
    pod: Pod,
    duration_s: f64,
    joules: f64,
}

impl PartialEq for Running {
    fn eq(&self, other: &Self) -> bool {
        self.due == other.due && self.seq == other.seq
    }
}
impl Eq for Running {}
impl Ord for Running {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.due.cmp(&other.due).then(self.seq.cmp(&other.seq))
    }
}
impl PartialOrd for Running {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

/// The serve loop.
pub struct ApiLoop {
    config: Config,
    executor: WorkloadExecutor,
    /// Virtual-seconds-per-real-second compression for executions
    /// (e.g. 100.0 replays a 50 s workload in 0.5 s of wall time).
    /// Private: validated once at [`ApiLoop::set_time_scale`], so every
    /// use site can divide/multiply by it without re-guarding.
    time_scale: f64,
    /// Grid carbon intensity over the loop's virtual clock (wall time
    /// since `run()` × `time_scale`), from the config's `carbon`
    /// section.
    carbon: CarbonSignal,
}

impl ApiLoop {
    pub fn new(config: Config, executor: WorkloadExecutor) -> Self {
        let carbon = config.carbon.signal(&config.energy);
        Self { config, executor, time_scale: 100.0, carbon }
    }

    /// Set the time compression. Rejects non-finite or non-positive
    /// values — the single validation point for every `time_scale` use.
    pub fn set_time_scale(&mut self, time_scale: f64) -> anyhow::Result<()> {
        anyhow::ensure!(
            time_scale.is_finite() && time_scale > 0.0,
            "time_scale must be a finite positive number, got {time_scale}"
        );
        self.time_scale = time_scale;
        Ok(())
    }

    pub fn time_scale(&self) -> f64 {
        self.time_scale
    }

    /// Drain `rx`, scheduling each submission with its owner scheduler;
    /// deliver events through `on_event`. Returns when `rx` disconnects
    /// and all running pods have completed.
    pub fn run(
        &self,
        rx: Receiver<PodSubmission>,
        on_event: &mut dyn FnMut(ApiEvent),
        topsis: &mut dyn Scheduler,
        default: &mut dyn Scheduler,
    ) -> anyhow::Result<()> {
        let run_started = Instant::now();
        let mut state = ClusterState::from_config(&self.config.cluster);
        let mut meter =
            EnergyMeter::new().with_carbon(self.carbon.clone());
        let mut timers: BinaryHeap<Reverse<Running>> = BinaryHeap::new();
        // Pending pods carry their submission instant so Bound events
        // can report queue wait.
        let mut pending: Vec<(Pod, Instant)> = Vec::new();
        let mut next_id: PodId = 0;
        let mut seq: u64 = 0;
        let mut completed = 0u64;
        let mut input_open = true;

        loop {
            // 1. Fire due completions.
            let now = Instant::now();
            while timers.peek().is_some_and(|Reverse(r)| r.due <= now) {
                let Reverse(run) = timers.pop().unwrap();
                state.release(run.pod.id, 0.0)?;
                completed += 1;
                on_event(ApiEvent::Completed {
                    pod: run.pod.id,
                    name: run.pod.name.clone(),
                    duration_s: run.duration_s,
                    joules: run.joules,
                });
                // Retry pending pods in FIFO order.
                let mut still = Vec::new();
                for (pod, submitted) in pending.drain(..) {
                    if let Some(pod) = self.try_start(
                        pod, submitted, run_started, &mut state, &mut meter,
                        &mut timers, &mut seq, on_event, topsis, default,
                    )? {
                        still.push((pod, submitted));
                    }
                }
                pending = still;
            }

            // 2. Exit when drained.
            if !input_open && timers.is_empty() {
                break;
            }

            // 3. Wait for the next submission or the next deadline.
            let timeout = timers
                .peek()
                .map(|Reverse(r)| {
                    r.due.saturating_duration_since(Instant::now())
                })
                .unwrap_or(Duration::from_millis(50));
            if !input_open {
                std::thread::sleep(timeout);
                continue;
            }
            match rx.recv_timeout(timeout) {
                Ok(sub) => {
                    let pod = Pod::new(
                        next_id,
                        sub.entry.class,
                        sub.scheduler,
                        0.0,
                        sub.entry.epochs,
                    );
                    next_id += 1;
                    let submitted = Instant::now();
                    if let Some(pod) = self.try_start(
                        pod, submitted, run_started, &mut state, &mut meter,
                        &mut timers, &mut seq, on_event, topsis, default,
                    )? {
                        pending.push((pod, submitted));
                    }
                }
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => input_open = false,
            }
        }

        let unschedulable = pending.len() as u64;
        for (pod, _) in pending {
            on_event(ApiEvent::Unschedulable { pod: pod.id, name: pod.name });
        }
        let total_kj = meter.total_kj(SchedulerKind::Topsis)
            + meter.total_kj(SchedulerKind::DefaultK8s);
        on_event(ApiEvent::Drained { completed, unschedulable, total_kj });
        Ok(())
    }

    /// Schedule + start one pod. Returns `Ok(Some(pod))` if it has to
    /// stay pending, `Ok(None)` if it started.
    #[allow(clippy::too_many_arguments)]
    fn try_start(
        &self,
        pod: Pod,
        submitted: Instant,
        run_started: Instant,
        state: &mut ClusterState,
        meter: &mut EnergyMeter,
        timers: &mut BinaryHeap<Reverse<Running>>,
        seq: &mut u64,
        on_event: &mut dyn FnMut(ApiEvent),
        topsis: &mut dyn Scheduler,
        default: &mut dyn Scheduler,
    ) -> anyhow::Result<Option<Pod>> {
        // The loop's virtual clock: wall time since run() start,
        // compressed by time_scale — the serve-side "what time is it"
        // that time-varying profiles and the carbon ledger read.
        let now_s = run_started.elapsed().as_secs_f64() * self.time_scale;
        let (decision, profile) = match pod.scheduler {
            SchedulerKind::Topsis => (
                topsis.schedule_at(state, &pod, now_s),
                topsis.name().to_string(),
            ),
            SchedulerKind::DefaultK8s => (
                default.schedule_at(state, &pod, now_s),
                default.name().to_string(),
            ),
        };
        let Some(node_id) = decision.node else {
            return Ok(Some(pod));
        };
        state.bind(&pod, node_id, 0.0)?;

        let node = state.node(node_id).clone();
        let outcome = self.executor.execute(&pod, &node, pod.id)?;
        let share = pod.requests.cpu_millis as f64 / node.cpu_millis as f64;
        let duration = outcome.base_secs
            * contention_factor(
                self.config.experiment.contention_beta,
                state.cpu_utilization(node_id),
                share,
            );
        let joules = meter.record(
            &self.config.energy,
            pod.id,
            pod.class,
            pod.scheduler,
            &node,
            share,
            duration,
            now_s,
        );

        on_event(ApiEvent::Bound {
            pod: pod.id,
            name: pod.name.clone(),
            node: node.name.clone(),
            profile,
            sched_latency_us: decision.latency.as_secs_f64() * 1e6,
            queue_wait_s: submitted.elapsed().as_secs_f64()
                * self.time_scale,
            grid_g_per_kwh: self.carbon.at(now_s) * J_PER_KWH,
        });

        let due = Instant::now()
            + Duration::from_secs_f64(duration / self.time_scale);
        timers.push(Reverse(Running {
            due,
            seq: *seq,
            pod,
            duration_s: duration,
            joules,
        }));
        *seq += 1;
        Ok(None)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::WeightingScheme;
    use crate::framework::{
        BuildOptions, FrameworkScheduler, ProfileRegistry,
    };
    use crate::workload::WorkloadClass;

    /// Registry-built scheduler pair (seed 1, matching the retired
    /// monolith constructions these tests used).
    fn scheds(
        config: &Config,
        scheme: WeightingScheme,
    ) -> (FrameworkScheduler, FrameworkScheduler) {
        let registry = ProfileRegistry::new(config);
        let opts = BuildOptions::new(config, scheme).with_seed(1);
        (
            registry.build("greenpod", &opts).expect("built-in"),
            registry.build("default-k8s", &opts).expect("built-in"),
        )
    }

    #[test]
    fn serve_loop_processes_submissions() {
        let config = Config::paper_default();
        let mut api =
            ApiLoop::new(config.clone(), WorkloadExecutor::analytic());
        api.set_time_scale(100_000.0).unwrap(); // fast test

        let (sub_tx, sub_rx) = std::sync::mpsc::channel();
        for i in 0..6u64 {
            let class = match i % 3 {
                0 => WorkloadClass::Light,
                1 => WorkloadClass::Medium,
                _ => WorkloadClass::Complex,
            };
            sub_tx
                .send(PodSubmission {
                    entry: TraceEntry { at_s: 0.0, class, epochs: 1 },
                    scheduler: if i % 2 == 0 {
                        SchedulerKind::Topsis
                    } else {
                        SchedulerKind::DefaultK8s
                    },
                })
                .unwrap();
        }
        drop(sub_tx);

        let (mut topsis, mut default) =
            scheds(&config, WeightingScheme::EnergyCentric);
        let mut events = Vec::new();
        api.run(sub_rx, &mut |e| events.push(e), &mut topsis, &mut default)
            .unwrap();

        let bound = events
            .iter()
            .filter(|e| matches!(e, ApiEvent::Bound { .. }))
            .count();
        let done = events
            .iter()
            .filter(|e| matches!(e, ApiEvent::Completed { .. }))
            .count();
        assert_eq!(bound, 6);
        assert_eq!(done, 6);
        match events.last().unwrap() {
            ApiEvent::Drained { completed, unschedulable, total_kj } => {
                assert_eq!(*completed, 6);
                assert_eq!(*unschedulable, 0);
                assert!(*total_kj > 0.0);
            }
            other => panic!("last event {other:?}"),
        }
    }

    #[test]
    fn overload_goes_pending_then_completes() {
        // More complex pods than the cluster can hold at once: the
        // pending queue must drain as completions free capacity.
        let config = Config::paper_default();
        let mut api =
            ApiLoop::new(config.clone(), WorkloadExecutor::analytic());
        api.set_time_scale(100_000.0).unwrap();
        let (sub_tx, sub_rx) = std::sync::mpsc::channel();
        for _ in 0..12 {
            sub_tx
                .send(PodSubmission {
                    entry: TraceEntry {
                        at_s: 0.0,
                        class: WorkloadClass::Complex,
                        epochs: 1,
                    },
                    scheduler: SchedulerKind::Topsis,
                })
                .unwrap();
        }
        drop(sub_tx);
        let (mut topsis, mut default) =
            scheds(&config, WeightingScheme::General);
        let mut completed = 0;
        api.run(
            sub_rx,
            &mut |e| {
                if matches!(e, ApiEvent::Completed { .. }) {
                    completed += 1;
                }
            },
            &mut topsis,
            &mut default,
        )
        .unwrap();
        assert_eq!(completed, 12);
    }

    #[test]
    fn scaled_event_json_shape() {
        let e = ApiEvent::Scaled {
            at_s: 12.5,
            action: "scale-out".into(),
            node: 7,
            ready_nodes: 8,
        };
        let j = e.to_json().to_string();
        assert!(j.contains("\"event\":\"scaled\""), "{j}");
        assert!(j.contains("\"action\":\"scale-out\""), "{j}");
        assert!(j.contains("\"node\":7"), "{j}");
        assert!(j.contains("\"ready_nodes\":8"), "{j}");
    }

    #[test]
    fn event_json_shape() {
        let e = ApiEvent::Bound {
            pod: 3,
            name: "p".into(),
            node: "n".into(),
            profile: "greenpod".into(),
            sched_latency_us: 12.5,
            queue_wait_s: 0.25,
            grid_g_per_kwh: 373.5,
        };
        let j = e.to_json().to_string();
        assert!(j.contains("\"event\":\"bound\""), "{j}");
        assert!(j.contains("\"pod\":3"));
        assert!(j.contains("\"profile\":\"greenpod\""), "{j}");
        assert!(j.contains("\"queue_wait_s\":0.25"), "{j}");
        assert!(j.contains("\"grid_g_per_kwh\":373.5"), "{j}");
    }

    #[test]
    fn pod_ids_above_2_pow_53_serialize_losslessly() {
        // The f64 path corrupted ids >= 2^53; the Uint path must carry
        // every digit through emission *and* a parse round-trip.
        let id: PodId = (1u64 << 53) + 1;
        // greenpod-lint: allow(lossy-id-cast) reason="deliberate corruption proof: the assert documents exactly the f64 round-trip loss the Uint path prevents"
        assert_ne!((id as f64) as u64, id, "id must exceed f64 precision");
        for e in [
            ApiEvent::Completed {
                pod: id,
                name: "p".into(),
                duration_s: 1.0,
                joules: 2.0,
            },
            ApiEvent::Unschedulable { pod: id, name: "p".into() },
            ApiEvent::Dispatched {
                pod: id,
                region: "eu-west".into(),
                at_s: 0.5,
            },
        ] {
            let line = e.to_json().to_string();
            assert!(
                line.contains(&format!("\"pod\":{id}")),
                "{line}"
            );
            let back = Json::parse(&line).unwrap();
            assert_eq!(back.get("pod").and_then(Json::as_u64), Some(id));
        }
    }

    #[test]
    fn dispatched_event_json_shape() {
        let e = ApiEvent::Dispatched {
            pod: 4,
            region: "region-b".into(),
            at_s: 12.25,
        };
        let j = e.to_json().to_string();
        assert!(j.contains("\"event\":\"dispatched\""), "{j}");
        assert!(j.contains("\"pod\":4"), "{j}");
        assert!(j.contains("\"region\":\"region-b\""), "{j}");
        assert!(j.contains("\"at_s\":12.25"), "{j}");
    }

    #[test]
    fn bound_events_carry_the_grid_intensity() {
        // Default config: constant signal at the eGRID scalar, so every
        // binding reports the same ≈373 g/kWh regardless of wall time.
        let config = Config::paper_default();
        let want = config.carbon.signal(&config.energy).at(0.0)
            * crate::config::J_PER_KWH;
        let mut api =
            ApiLoop::new(config.clone(), WorkloadExecutor::analytic());
        api.set_time_scale(100_000.0).unwrap();
        let (sub_tx, sub_rx) = std::sync::mpsc::channel();
        for _ in 0..3 {
            sub_tx
                .send(PodSubmission {
                    entry: TraceEntry {
                        at_s: 0.0,
                        class: WorkloadClass::Light,
                        epochs: 1,
                    },
                    scheduler: SchedulerKind::Topsis,
                })
                .unwrap();
        }
        drop(sub_tx);
        let (mut topsis, mut default) =
            scheds(&config, WeightingScheme::EnergyCentric);
        let mut grids = Vec::new();
        api.run(
            sub_rx,
            &mut |e| {
                if let ApiEvent::Bound { grid_g_per_kwh, .. } = e {
                    grids.push(grid_g_per_kwh);
                }
            },
            &mut topsis,
            &mut default,
        )
        .unwrap();
        assert_eq!(grids.len(), 3);
        for g in grids {
            assert!((g - want).abs() < 1e-9, "{g} vs {want}");
            assert!((g - 373.4).abs() < 1.0, "≈eGRID scalar, got {g}");
        }
    }

    #[test]
    fn bad_time_scale_rejected() {
        let config = Config::paper_default();
        let mut api =
            ApiLoop::new(config, WorkloadExecutor::analytic());
        assert!(api.set_time_scale(0.0).is_err());
        assert!(api.set_time_scale(-3.0).is_err());
        assert!(api.set_time_scale(f64::NAN).is_err());
        assert!(api.set_time_scale(f64::INFINITY).is_err());
        // The default survives every rejected set.
        assert_eq!(api.time_scale(), 100.0);
        api.set_time_scale(42.0).unwrap();
        assert_eq!(api.time_scale(), 42.0);
    }

    #[test]
    fn overload_reports_queue_waits() {
        // 20 complex pods against 16 complex-sized slots: at least four
        // must queue behind capacity and report a (virtual-time) wait.
        let config = Config::paper_default();
        let mut api =
            ApiLoop::new(config.clone(), WorkloadExecutor::analytic());
        api.set_time_scale(100_000.0).unwrap();
        let (sub_tx, sub_rx) = std::sync::mpsc::channel();
        for _ in 0..20 {
            sub_tx
                .send(PodSubmission {
                    entry: TraceEntry {
                        at_s: 0.0,
                        class: WorkloadClass::Complex,
                        epochs: 1,
                    },
                    scheduler: SchedulerKind::Topsis,
                })
                .unwrap();
        }
        drop(sub_tx);
        let (mut topsis, mut default) =
            scheds(&config, WeightingScheme::General);
        let mut waits = Vec::new();
        api.run(
            sub_rx,
            &mut |e| {
                if let ApiEvent::Bound { queue_wait_s, .. } = e {
                    waits.push(queue_wait_s);
                }
            },
            &mut topsis,
            &mut default,
        )
        .unwrap();
        assert_eq!(waits.len(), 20);
        assert!(waits.iter().all(|w| w.is_finite() && *w >= 0.0));
        // Queued pods wait for a completion (≥ ~0.1 ms wall at this
        // time scale, i.e. ≥ ~10 virtual seconds); 1 s is a safe floor.
        assert!(
            waits.iter().any(|&w| w > 1.0),
            "no pod reported a real queue wait: {waits:?}"
        );
    }
}
