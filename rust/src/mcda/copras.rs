//! COPRAS — COmplex PRoportional ASsessment (ablation baseline, §II.B
//! [21]).
//!
//! Sum-normalizes the matrix, splits weighted sums into benefit (S⁺)
//! and cost (S⁻) parts, and scores `Q_i = S⁺_i + min(S⁻)·ΣS⁻ /
//! (S⁻_i · Σ(min(S⁻)/S⁻_j))`, normalized to the best alternative.

use super::normalize::sum_normalize;
use super::types::{DecisionProblem, Direction};

const EPS: f64 = 1e-12;

/// COPRAS utility degrees, normalized so the best alternative gets 1.0.
pub fn copras_scores(p: &DecisionProblem) -> Vec<f64> {
    let (n, c) = (p.n, p.c());
    if n == 0 {
        return Vec::new();
    }
    let w = p.norm_weights();
    let nm = sum_normalize(&p.matrix, n, c);

    let mut s_plus = vec![0.0f64; n];
    let mut s_minus = vec![0.0f64; n];
    for row in 0..n {
        for col in 0..c {
            let v = w[col] * nm[row * c + col];
            match p.criteria[col].direction {
                Direction::Benefit => s_plus[row] += v,
                Direction::Cost => s_minus[row] += v,
            }
        }
    }

    let any_cost =
        p.criteria.iter().any(|cr| cr.direction == Direction::Cost);
    let q: Vec<f64> = if !any_cost {
        s_plus.clone()
    } else {
        let s_minus_min =
            s_minus.iter().cloned().fold(f64::INFINITY, f64::min).max(EPS);
        let sum_s_minus: f64 = s_minus.iter().sum();
        let denom: f64 =
            s_minus.iter().map(|&s| s_minus_min / s.max(EPS)).sum();
        (0..n)
            .map(|i| {
                s_plus[i]
                    + s_minus_min * sum_s_minus
                        / (s_minus[i].max(EPS) * denom.max(EPS))
            })
            .collect()
    };

    let q_max = q.iter().cloned().fold(f64::NEG_INFINITY, f64::max).max(EPS);
    q.iter().map(|&x| x / q_max).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mcda::Criterion;

    #[test]
    fn dominant_row_scores_one() {
        let p = DecisionProblem::new(
            vec![
                0.1, 9.0, //
                0.9, 1.0, //
                0.5, 5.0,
            ],
            3,
            vec![Criterion::cost(1.0), Criterion::benefit(1.0)],
        );
        let s = copras_scores(&p);
        assert!((s[0] - 1.0).abs() < 1e-12, "{s:?}");
        assert!(s[0] > s[2] && s[2] > s[1]);
    }

    #[test]
    fn benefit_only_problem() {
        let p = DecisionProblem::new(
            vec![2.0, 1.0, 4.0],
            3,
            vec![Criterion::benefit(1.0)],
        );
        let s = copras_scores(&p);
        assert!((s[2] - 1.0).abs() < 1e-12);
        assert!(s[2] > s[0] && s[0] > s[1]);
    }

    #[test]
    fn zero_cost_column_finite() {
        // An all-zero cost column makes every S⁻ zero; the EPS guards
        // must keep the utility degrees finite (no 0/0).
        let p = DecisionProblem::new(
            vec![2.0, 0.0, 1.0, 0.0, 4.0, 0.0],
            3,
            vec![Criterion::benefit(1.0), Criterion::cost(1.0)],
        );
        let s = copras_scores(&p);
        assert!(s.iter().all(|x| x.is_finite()), "{s:?}");
        // Benefit ordering still decides.
        assert!(s[2] >= s[0] && s[0] >= s[1]);
    }

    #[test]
    fn all_equal_matrix_finite_and_tied() {
        let p = DecisionProblem::new(
            vec![5.0; 8],
            4,
            vec![Criterion::benefit(1.0), Criterion::cost(3.0)],
        );
        let s = copras_scores(&p);
        assert!(s.iter().all(|x| x.is_finite()), "{s:?}");
        for w in s.windows(2) {
            assert!((w[0] - w[1]).abs() < 1e-12, "{s:?}");
        }
        assert!((s[0] - 1.0).abs() < 1e-9, "best normalizes to 1: {s:?}");
    }

    #[test]
    fn scores_positive_and_bounded() {
        let p = DecisionProblem::new(
            vec![3.0, 7.0, 2.0, 4.0, 9.0, 5.0],
            3,
            vec![Criterion::benefit(1.0), Criterion::cost(2.0)],
        );
        for s in copras_scores(&p) {
            assert!(s > 0.0 && s <= 1.0 + 1e-12);
        }
    }
}
