//! Decision-matrix normalization schemes.

const EPS: f64 = 1e-12;

/// Vector (Euclidean) normalization per column — what TOPSIS uses.
/// Returns a new row-major matrix of the same shape.
pub fn vector_normalize(matrix: &[f64], n: usize, c: usize) -> Vec<f64> {
    let mut norms = vec![0.0f64; c];
    for row in 0..n {
        for col in 0..c {
            let v = matrix[row * c + col];
            norms[col] += v * v;
        }
    }
    for norm in &mut norms {
        *norm = norm.sqrt().max(EPS);
    }
    let mut out = vec![0.0; n * c];
    for row in 0..n {
        for col in 0..c {
            out[row * c + col] = matrix[row * c + col] / norms[col];
        }
    }
    out
}

/// Min-max normalization per column into [0, 1] (SAW/VIKOR style).
///
/// Zero-range (all-equal) columns carry no preference information, so
/// they normalize to the *neutral* value 0.5 — direction-independent,
/// never NaN. (Normalizing them to 0 would silently bias cost criteria,
/// whose scores invert to `1 − v`.)
pub fn minmax_normalize(matrix: &[f64], n: usize, c: usize) -> Vec<f64> {
    let mut mins = vec![f64::INFINITY; c];
    let mut maxs = vec![f64::NEG_INFINITY; c];
    for row in 0..n {
        for col in 0..c {
            let v = matrix[row * c + col];
            mins[col] = mins[col].min(v);
            maxs[col] = maxs[col].max(v);
        }
    }
    let mut out = vec![0.0; n * c];
    for row in 0..n {
        for col in 0..c {
            let span = maxs[col] - mins[col];
            out[row * c + col] = if span <= EPS {
                0.5
            } else {
                (matrix[row * c + col] - mins[col]) / span
            };
        }
    }
    out
}

/// Sum normalization per column (COPRAS style): each column sums to 1.
pub fn sum_normalize(matrix: &[f64], n: usize, c: usize) -> Vec<f64> {
    let mut sums = vec![0.0f64; c];
    for row in 0..n {
        for col in 0..c {
            sums[col] += matrix[row * c + col];
        }
    }
    let mut out = vec![0.0; n * c];
    for row in 0..n {
        for col in 0..c {
            let s = if sums[col].abs() <= EPS { 1.0 } else { sums[col] };
            out[row * c + col] = matrix[row * c + col] / s;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vector_norm_unit_columns() {
        let m = vec![3.0, 1.0, 4.0, 2.0]; // 2x2
        let r = vector_normalize(&m, 2, 2);
        // col 0: 3,4 -> /5; col 1: 1,2 -> /sqrt(5)
        assert!((r[0] - 0.6).abs() < 1e-12);
        assert!((r[2] - 0.8).abs() < 1e-12);
        let c1: f64 = (r[1] * r[1] + r[3] * r[3]).sqrt();
        assert!((c1 - 1.0).abs() < 1e-12);
    }

    #[test]
    fn vector_norm_zero_column_safe() {
        let m = vec![0.0, 1.0, 0.0, 2.0];
        let r = vector_normalize(&m, 2, 2);
        assert_eq!(r[0], 0.0);
        assert_eq!(r[2], 0.0);
        assert!(r.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn minmax_hits_bounds() {
        let m = vec![1.0, 10.0, 5.0, 20.0, 9.0, 30.0]; // 3x2
        let r = minmax_normalize(&m, 3, 2);
        assert_eq!(r[0], 0.0);
        assert_eq!(r[4], 1.0);
        assert!((r[2] - 0.5).abs() < 1e-12);
    }

    #[test]
    fn minmax_constant_column_is_neutral() {
        let m = vec![5.0, 5.0, 5.0];
        let r = minmax_normalize(&m, 3, 1);
        assert!(r.iter().all(|&v| v == 0.5), "{r:?}");
    }

    #[test]
    fn sum_norm_zero_sum_column_finite() {
        // Entries cancel to a zero column sum; the guard divides by 1
        // instead of 0, so outputs stay finite.
        let m = vec![1.0, -1.0, 0.0];
        let r = sum_normalize(&m, 3, 1);
        assert!(r.iter().all(|v| v.is_finite()), "{r:?}");
        assert_eq!(r, m);
    }

    #[test]
    fn all_normalizers_finite_on_degenerate_matrices() {
        // Zero-range, all-zero and identical-row matrices must never
        // produce NaN/inf from any normalizer.
        let cases: Vec<(Vec<f64>, usize, usize)> = vec![
            (vec![3.0; 8], 4, 2),            // all-equal everywhere
            (vec![0.0; 6], 3, 2),            // all-zero
            (vec![1.0, 2.0, 1.0, 2.0], 2, 2), // identical rows
        ];
        for (m, n, c) in cases {
            for r in [
                vector_normalize(&m, n, c),
                minmax_normalize(&m, n, c),
                sum_normalize(&m, n, c),
            ] {
                assert!(
                    r.iter().all(|v| v.is_finite()),
                    "non-finite normalization of {m:?}: {r:?}"
                );
            }
        }
    }

    #[test]
    fn sum_norm_columns_sum_to_one() {
        let m = vec![1.0, 2.0, 3.0, 4.0, 6.0, 4.0]; // 3x2
        let r = sum_normalize(&m, 3, 2);
        let s0 = r[0] + r[2] + r[4];
        let s1 = r[1] + r[3] + r[5];
        assert!((s0 - 1.0).abs() < 1e-12);
        assert!((s1 - 1.0).abs() < 1e-12);
    }
}
