//! SAW — Simple Additive Weighting (ablation baseline, paper §II.B).
//!
//! Min-max normalize each criterion (cost criteria inverted), then take
//! the weighted sum. The simplest MCDA method; GreenPod's ablation runs
//! it against TOPSIS under identical decision matrices.

use super::normalize::minmax_normalize;
use super::types::{DecisionProblem, Direction};

/// SAW scores in [0, 1]; higher is better.
pub fn saw_scores(p: &DecisionProblem) -> Vec<f64> {
    let (n, c) = (p.n, p.c());
    if n == 0 {
        return Vec::new();
    }
    let w = p.norm_weights();
    let nm = minmax_normalize(&p.matrix, n, c);
    (0..n)
        .map(|row| {
            (0..c)
                .map(|col| {
                    let v = nm[row * c + col];
                    let v = match p.criteria[col].direction {
                        Direction::Benefit => v,
                        Direction::Cost => 1.0 - v,
                    };
                    w[col] * v
                })
                .sum()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mcda::Criterion;

    #[test]
    fn dominant_row_scores_highest() {
        let p = DecisionProblem::new(
            vec![
                0.1, 9.0, //
                0.9, 1.0, //
                0.5, 5.0,
            ],
            3,
            vec![Criterion::cost(1.0), Criterion::benefit(1.0)],
        );
        let s = saw_scores(&p);
        assert!((s[0] - 1.0).abs() < 1e-12); // best on both criteria
        assert!(s[0] > s[2] && s[2] > s[1]);
    }

    #[test]
    fn zero_range_column_is_neutral() {
        // A constant column contributes the neutral 0.5 to every row
        // (for both directions), so it cannot flip a ranking or
        // produce NaN.
        let base = DecisionProblem::new(
            vec![0.1, 9.0, 0.9, 1.0, 0.5, 5.0],
            3,
            vec![Criterion::cost(1.0), Criterion::benefit(1.0)],
        );
        let with_const = DecisionProblem::new(
            vec![0.1, 9.0, 3.0, 0.9, 1.0, 3.0, 0.5, 5.0, 3.0],
            3,
            vec![
                Criterion::cost(1.0),
                Criterion::benefit(1.0),
                Criterion::cost(1.0),
            ],
        );
        let a = saw_scores(&base);
        let b = saw_scores(&with_const);
        assert!(b.iter().all(|s| s.is_finite()));
        // Same ranking in both.
        let rank = |s: &[f64]| {
            let mut idx: Vec<usize> = (0..s.len()).collect();
            idx.sort_by(|&x, &y| crate::util::stats::total_order(&s[y], &s[x]));
            idx
        };
        assert_eq!(rank(&a), rank(&b));
    }

    #[test]
    fn all_equal_matrix_finite_and_tied() {
        let p = DecisionProblem::new(
            vec![2.0; 6],
            3,
            vec![Criterion::benefit(1.0), Criterion::cost(1.0)],
        );
        let s = saw_scores(&p);
        assert!(s.iter().all(|x| x.is_finite()), "{s:?}");
        assert!((s[0] - s[1]).abs() < 1e-12 && (s[1] - s[2]).abs() < 1e-12);
    }

    #[test]
    fn scores_bounded() {
        let p = DecisionProblem::new(
            vec![3.0, 7.0, 2.0, 4.0, 9.0, 5.0],
            3,
            vec![Criterion::benefit(2.0), Criterion::cost(3.0)],
        );
        for s in saw_scores(&p) {
            assert!((0.0..=1.0 + 1e-12).contains(&s));
        }
    }
}
