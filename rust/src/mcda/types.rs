//! Shared MCDA input types.


/// Criterion direction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Direction {
    /// Higher is better (free cores, free memory, balance).
    Benefit,
    /// Lower is better (execution time, energy).
    Cost,
}

/// One criterion: weight + direction.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Criterion {
    pub weight: f64,
    pub direction: Direction,
}

impl Criterion {
    pub fn benefit(weight: f64) -> Self {
        Self { weight, direction: Direction::Benefit }
    }

    pub fn cost(weight: f64) -> Self {
        Self { weight, direction: Direction::Cost }
    }
}

/// An `n`-alternative × `c`-criterion decision problem (row-major).
#[derive(Debug, Clone, PartialEq)]
pub struct DecisionProblem {
    pub matrix: Vec<f64>,
    pub n: usize,
    pub criteria: Vec<Criterion>,
}

impl DecisionProblem {
    pub fn new(matrix: Vec<f64>, n: usize, criteria: Vec<Criterion>) -> Self {
        assert_eq!(
            matrix.len(),
            n * criteria.len(),
            "matrix size {} != n {} x c {}",
            matrix.len(),
            n,
            criteria.len()
        );
        Self { matrix, n, criteria }
    }

    pub fn c(&self) -> usize {
        self.criteria.len()
    }

    #[inline]
    pub fn at(&self, row: usize, col: usize) -> f64 {
        self.matrix[row * self.criteria.len() + col]
    }

    /// Normalized weights (unit simplex).
    pub fn norm_weights(&self) -> Vec<f64> {
        let sum: f64 = self.criteria.iter().map(|c| c.weight).sum();
        let sum = if sum <= 0.0 { 1.0 } else { sum };
        self.criteria.iter().map(|c| c.weight / sum).collect()
    }
}

/// Index of the best (highest-score) alternative; ties broken by lowest
/// index for determinism.
pub fn argmax(scores: &[f64]) -> Option<usize> {
    let mut best: Option<(usize, f64)> = None;
    for (i, &s) in scores.iter().enumerate() {
        match best {
            None => best = Some((i, s)),
            Some((_, bs)) if s > bs => best = Some((i, s)),
            _ => {}
        }
    }
    best.map(|(i, _)| i)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    #[should_panic(expected = "matrix size")]
    fn size_mismatch_panics() {
        DecisionProblem::new(vec![1.0; 5], 2, vec![Criterion::benefit(1.0); 3]);
    }

    #[test]
    fn weights_normalize() {
        let p = DecisionProblem::new(
            vec![1.0; 4],
            2,
            vec![Criterion::benefit(2.0), Criterion::cost(6.0)],
        );
        assert_eq!(p.norm_weights(), vec![0.25, 0.75]);
    }

    #[test]
    fn argmax_tie_breaks_low_index() {
        assert_eq!(argmax(&[1.0, 3.0, 3.0]), Some(1));
        assert_eq!(argmax(&[]), None);
    }
}
