//! VIKOR — VIseKriterijumska Optimizacija I Kompromisno Resenje
//! (ablation baseline, paper §II.B [20, 21]).
//!
//! Computes the group utility S, individual regret R, and compromise
//! index Q (lower Q is better). `McdaMethod::scores` inverts Q so all
//! methods share the higher-is-better convention.

use super::types::{DecisionProblem, Direction};

const EPS: f64 = 1e-12;

/// VIKOR outputs for each alternative.
#[derive(Debug, Clone)]
pub struct VikorResult {
    /// Group utility (weighted Manhattan distance to the ideal).
    pub s: Vec<f64>,
    /// Individual regret (weighted Chebyshev distance to the ideal).
    pub r: Vec<f64>,
    /// Compromise index in [0, 1]; LOWER is better.
    pub q: Vec<f64>,
}

/// Compute VIKOR with strategy weight `v` (0.5 = consensus).
pub fn vikor_scores(p: &DecisionProblem, v: f64) -> VikorResult {
    let (n, c) = (p.n, p.c());
    if n == 0 {
        return VikorResult { s: vec![], r: vec![], q: vec![] };
    }
    let w = p.norm_weights();

    // Best (f*) and worst (f-) per criterion, direction-aware.
    let mut f_star = vec![0.0f64; c];
    let mut f_minus = vec![0.0f64; c];
    for col in 0..c {
        let mut lo = f64::INFINITY;
        let mut hi = f64::NEG_INFINITY;
        for row in 0..n {
            let x = p.at(row, col);
            lo = lo.min(x);
            hi = hi.max(x);
        }
        match p.criteria[col].direction {
            Direction::Benefit => {
                f_star[col] = hi;
                f_minus[col] = lo;
            }
            Direction::Cost => {
                f_star[col] = lo;
                f_minus[col] = hi;
            }
        }
    }

    let mut s = vec![0.0f64; n];
    let mut r = vec![0.0f64; n];
    for row in 0..n {
        for col in 0..c {
            let span = (f_star[col] - f_minus[col]).abs().max(EPS);
            let d = w[col] * (f_star[col] - p.at(row, col)).abs() / span;
            s[row] += d;
            r[row] = r[row].max(d);
        }
    }

    let (s_min, s_max) = min_max(&s);
    let (r_min, r_max) = min_max(&r);
    let q = (0..n)
        .map(|i| {
            let su = (s[i] - s_min) / (s_max - s_min).max(EPS);
            let ru = (r[i] - r_min) / (r_max - r_min).max(EPS);
            v * su + (1.0 - v) * ru
        })
        .collect();

    VikorResult { s, r, q }
}

fn min_max(xs: &[f64]) -> (f64, f64) {
    xs.iter().fold((f64::INFINITY, f64::NEG_INFINITY), |(lo, hi), &x| {
        (lo.min(x), hi.max(x))
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mcda::Criterion;

    fn problem() -> DecisionProblem {
        DecisionProblem::new(
            vec![
                0.1, 9.0, //
                0.9, 1.0, //
                0.5, 5.0,
            ],
            3,
            vec![Criterion::cost(1.0), Criterion::benefit(1.0)],
        )
    }

    #[test]
    fn dominant_row_has_lowest_q() {
        let res = vikor_scores(&problem(), 0.5);
        assert!(res.q[0] <= res.q[1] && res.q[0] <= res.q[2]);
        assert!((res.q[0] - 0.0).abs() < 1e-12);
    }

    #[test]
    fn s_bounded_by_one_r_by_max_weight() {
        let res = vikor_scores(&problem(), 0.5);
        for i in 0..3 {
            assert!(res.s[i] <= 1.0 + 1e-12);
            assert!(res.r[i] <= 0.5 + 1e-12); // max normalized weight
        }
    }

    #[test]
    fn zero_range_column_finite() {
        // A constant column has f* == f⁻; the span guard keeps its
        // regret contribution at 0 instead of NaN.
        let p = DecisionProblem::new(
            vec![0.1, 9.0, 4.0, 0.9, 1.0, 4.0, 0.5, 5.0, 4.0],
            3,
            vec![
                Criterion::cost(1.0),
                Criterion::benefit(1.0),
                Criterion::benefit(1.0),
            ],
        );
        let res = vikor_scores(&p, 0.5);
        for i in 0..3 {
            assert!(res.s[i].is_finite());
            assert!(res.r[i].is_finite());
            assert!(res.q[i].is_finite());
        }
        // Dominator still wins.
        assert!(res.q[0] <= res.q[1] && res.q[0] <= res.q[2]);
    }

    #[test]
    fn all_equal_matrix_finite_and_tied() {
        // Identical alternatives: S/R spans are zero; the Q guard must
        // yield finite, equal scores rather than 0/0.
        let p = DecisionProblem::new(
            vec![3.0; 9],
            3,
            vec![
                Criterion::cost(1.0),
                Criterion::benefit(1.0),
                Criterion::benefit(2.0),
            ],
        );
        let res = vikor_scores(&p, 0.5);
        for q in &res.q {
            assert!(q.is_finite(), "{:?}", res.q);
        }
        assert!((res.q[0] - res.q[1]).abs() < 1e-12);
        assert!((res.q[1] - res.q[2]).abs() < 1e-12);
    }

    #[test]
    fn q_in_unit_interval() {
        let res = vikor_scores(&problem(), 0.25);
        for q in res.q {
            assert!((0.0..=1.0 + 1e-12).contains(&q));
        }
    }
}
