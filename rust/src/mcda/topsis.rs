//! TOPSIS — Technique for Order Preference by Similarity to Ideal
//! Solution. The reference Rust implementation of GreenPod's ranking
//! method; mathematically identical to the Pallas kernel
//! (`python/compile/kernels/topsis.py`), which the integration tests
//! verify numerically through the PJRT artifact.


use super::types::{argmax, DecisionProblem, Direction};

const EPS: f64 = 1e-12;

/// Closeness coefficients `C_i = d⁻ / (d⁺ + d⁻) ∈ [0, 1]`; higher is
/// better.
///
/// Two passes over the matrix, no `n × c` intermediate: the weighted
/// normalized value is `vm = m · s` with a per-column scale
/// `s = w / ‖col‖`, and since `s ≥ 0` the per-column extremes of `vm`
/// are the extremes of `m` scaled — so ideal/anti-ideal points fall out
/// of the same pass that accumulates the column norms (§Perf in
/// EXPERIMENTS.md: ~2.3× over the textbook staged version).
pub fn topsis_closeness(p: &DecisionProblem) -> Vec<f64> {
    let mut out = Vec::new();
    topsis_closeness_into(p, &mut out);
    out
}

/// Allocation-reusing variant: clears and fills `out` (scratch buffers
/// for the per-column stats are stack-allocated up to 8 criteria, the
/// scheduler's case).
pub fn topsis_closeness_into(p: &DecisionProblem, out: &mut Vec<f64>) {
    let (n, c) = (p.n, p.c());
    out.clear();
    if n == 0 {
        return;
    }

    // Per-column stats: sum of squares, min, max (SmallVec-style: a
    // fixed stack array covers the scheduler's 5 criteria).
    const STACK_C: usize = 8;
    let mut stats_stack = [(0.0f64, f64::INFINITY, f64::NEG_INFINITY); STACK_C];
    let mut stats_heap;
    let stats: &mut [(f64, f64, f64)] = if c <= STACK_C {
        &mut stats_stack[..c]
    } else {
        stats_heap = vec![(0.0, f64::INFINITY, f64::NEG_INFINITY); c];
        &mut stats_heap
    };

    // Pass 1: column norms and extremes.
    for row in 0..n {
        let base = row * c;
        for (col, s) in stats.iter_mut().enumerate() {
            let v = p.matrix[base + col];
            s.0 += v * v;
            s.1 = s.1.min(v);
            s.2 = s.2.max(v);
        }
    }

    // Per-column scale s = w/‖col‖ and ideal/anti-ideal points.
    let w_sum: f64 = p.criteria.iter().map(|cr| cr.weight).sum();
    let w_sum = if w_sum <= 0.0 { 1.0 } else { w_sum };
    let mut cols_stack = [(0.0f64, 0.0f64, 0.0f64); STACK_C];
    let mut cols_heap;
    let cols: &mut [(f64, f64, f64)] = if c <= STACK_C {
        &mut cols_stack[..c]
    } else {
        cols_heap = vec![(0.0, 0.0, 0.0); c];
        &mut cols_heap
    };
    for col in 0..c {
        let (sumsq, lo, hi) = stats[col];
        let scale = (p.criteria[col].weight / w_sum) / sumsq.sqrt().max(EPS);
        let (vm_lo, vm_hi) = (lo * scale, hi * scale);
        let (v_plus, v_minus) = match p.criteria[col].direction {
            Direction::Benefit => (vm_hi, vm_lo),
            Direction::Cost => (vm_lo, vm_hi),
        };
        cols[col] = (scale, v_plus, v_minus);
    }

    // Pass 2: separation distances and closeness.
    out.reserve(n);
    for row in 0..n {
        let base = row * c;
        let mut dp = 0.0;
        let mut dm = 0.0;
        for (col, &(scale, v_plus, v_minus)) in cols.iter().enumerate() {
            let v = p.matrix[base + col] * scale;
            dp += (v - v_plus) * (v - v_plus);
            dm += (v - v_minus) * (v - v_minus);
        }
        let (dp, dm) = (dp.sqrt(), dm.sqrt());
        out.push(dm / (dp + dm).max(EPS));
    }
}

/// Rank alternatives: indices sorted by descending closeness (stable;
/// equal scores keep input order for determinism).
pub fn topsis_rank(p: &DecisionProblem) -> Vec<usize> {
    let scores = topsis_closeness(p);
    let mut idx: Vec<usize> = (0..p.n).collect();
    idx.sort_by(|&a, &b| crate::util::stats::total_order(&scores[b], &scores[a]));
    idx
}

/// Convenience: the single best alternative.
pub fn topsis_best(p: &DecisionProblem) -> Option<usize> {
    argmax(&topsis_closeness(p))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mcda::Criterion;

    fn problem() -> DecisionProblem {
        // 3 alternatives x 4 criteria (2 cost, 2 benefit); row 0 dominates.
        DecisionProblem::new(
            vec![
                0.1, 0.1, 9.0, 9.0, //
                0.5, 0.8, 4.0, 2.0, //
                0.9, 0.5, 1.0, 5.0,
            ],
            3,
            vec![
                Criterion::cost(1.0),
                Criterion::cost(1.0),
                Criterion::benefit(1.0),
                Criterion::benefit(1.0),
            ],
        )
    }

    #[test]
    fn dominant_alternative_scores_one() {
        let c = topsis_closeness(&problem());
        assert!((c[0] - 1.0).abs() < 1e-9, "{c:?}");
        assert!(c.iter().all(|&x| (0.0..=1.0 + 1e-9).contains(&x)));
        assert_eq!(topsis_best(&problem()), Some(0));
    }

    #[test]
    fn rank_is_descending() {
        let p = problem();
        let rank = topsis_rank(&p);
        let scores = topsis_closeness(&p);
        for w in rank.windows(2) {
            assert!(scores[w[0]] >= scores[w[1]]);
        }
    }

    #[test]
    fn identical_alternatives_tie() {
        let p = DecisionProblem::new(
            vec![1.0, 2.0, 1.0, 2.0, 1.0, 2.0],
            3,
            vec![Criterion::benefit(1.0), Criterion::cost(1.0)],
        );
        let c = topsis_closeness(&p);
        assert!((c[0] - c[1]).abs() < 1e-12);
        assert!((c[1] - c[2]).abs() < 1e-12);
    }

    #[test]
    fn zero_range_column_is_inert() {
        // A constant (all-equal) criterion column carries no preference
        // information: closeness must stay finite and match the same
        // problem without the column (zero-range guard).
        let base = DecisionProblem::new(
            vec![
                0.2, 5.0, //
                0.8, 2.0, //
                0.5, 9.0,
            ],
            3,
            vec![Criterion::cost(1.0), Criterion::benefit(1.0)],
        );
        let with_const = DecisionProblem::new(
            vec![
                0.2, 5.0, 7.5, //
                0.8, 2.0, 7.5, //
                0.5, 9.0, 7.5,
            ],
            3,
            vec![
                Criterion::cost(1.0),
                Criterion::benefit(1.0),
                Criterion::cost(1.0),
            ],
        );
        let a = topsis_closeness(&base);
        let b = topsis_closeness(&with_const);
        for (x, y) in a.iter().zip(&b) {
            assert!(y.is_finite());
            assert!((x - y).abs() < 1e-12, "{a:?} vs {b:?}");
        }
    }

    #[test]
    fn all_equal_matrix_finite_and_tied() {
        // Every criterion zero-range: scores must be finite and equal
        // (NaN here would silently corrupt rankings downstream).
        let p = DecisionProblem::new(
            vec![4.0; 12],
            3,
            vec![
                Criterion::cost(0.4),
                Criterion::benefit(0.3),
                Criterion::benefit(0.2),
                Criterion::cost(0.1),
            ],
        );
        let c = topsis_closeness(&p);
        assert!(c.iter().all(|x| x.is_finite()), "{c:?}");
        assert!((c[0] - c[1]).abs() < 1e-12 && (c[1] - c[2]).abs() < 1e-12);
    }

    #[test]
    fn empty_problem_empty_scores() {
        let p = DecisionProblem::new(vec![], 0, vec![Criterion::benefit(1.0)]);
        assert!(topsis_closeness(&p).is_empty());
        assert_eq!(topsis_best(&p), None);
    }

    #[test]
    fn matches_python_golden_vector() {
        // Same fixture as artifacts/golden.json topsis_n4 (5 real
        // criteria; padding columns omitted — zero-weight columns don't
        // affect closeness).
        let p = DecisionProblem::new(
            vec![
                0.9, 0.8, 2.0, 4.0, 0.7, //
                0.5, 0.6, 2.0, 8.0, 0.8, //
                0.3, 1.0, 4.0, 16.0, 0.6, //
                0.6, 0.7, 2.0, 8.0, 0.9,
            ],
            4,
            vec![
                Criterion::cost(0.2),
                Criterion::cost(0.2),
                Criterion::benefit(0.2),
                Criterion::benefit(0.2),
                Criterion::benefit(0.2),
            ],
        );
        let c = topsis_closeness(&p);
        // Values checked against the python oracle at artifact-build
        // time; the integration test re-verifies via golden.json.
        assert_eq!(c.len(), 4);
        assert!(c.iter().all(|&x| x.is_finite()));
    }
}
