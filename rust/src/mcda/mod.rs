//! Standalone multi-criteria decision analysis (MCDA) library.
//!
//! [`topsis`] is the pure-Rust reference implementation of the method
//! GreenPod schedules with (bit-for-bit the same math as the Pallas
//! kernel — cross-checked in `rust/tests/pjrt_integration.rs`). The
//! related work the paper positions against combines SAW, VIKOR and
//! COPRAS ([21]); those are implemented here as ablation baselines
//! (`greenpod experiment ablation`).
//!
//! All methods share the [`DecisionProblem`] input type: an `n × c`
//! row-major matrix, per-criterion weights, and per-criterion
//! directions.

mod copras;
mod normalize;
mod saw;
mod topsis;
mod types;
mod vikor;

pub use copras::copras_scores;
pub use normalize::{minmax_normalize, sum_normalize, vector_normalize};
pub use saw::saw_scores;
pub use topsis::{topsis_best, topsis_closeness, topsis_closeness_into, topsis_rank};
pub use types::{argmax, Criterion, DecisionProblem, Direction};
pub use vikor::{vikor_scores, VikorResult};

/// Which MCDA method ranks the candidates (ablation axis).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum McdaMethod {
    Topsis,
    Saw,
    Vikor,
    Copras,
}

impl McdaMethod {
    pub const ALL: [McdaMethod; 4] = [
        McdaMethod::Topsis,
        McdaMethod::Saw,
        McdaMethod::Vikor,
        McdaMethod::Copras,
    ];

    /// Score all alternatives; higher is always better (VIKOR's Q is
    /// inverted to fit the convention).
    pub fn scores(self, p: &DecisionProblem) -> Vec<f64> {
        match self {
            McdaMethod::Topsis => topsis_closeness(p),
            McdaMethod::Saw => saw_scores(p),
            McdaMethod::Vikor => {
                vikor_scores(p, 0.5).q.iter().map(|q| 1.0 - q).collect()
            }
            McdaMethod::Copras => copras_scores(p),
        }
    }
}

impl std::str::FromStr for McdaMethod {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "topsis" => Ok(McdaMethod::Topsis),
            "saw" => Ok(McdaMethod::Saw),
            "vikor" => Ok(McdaMethod::Vikor),
            "copras" => Ok(McdaMethod::Copras),
            other => anyhow::bail!(
                "unknown MCDA method `{other}` (topsis|saw|vikor|copras)"
            ),
        }
    }
}
