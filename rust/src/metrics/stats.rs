//! Summary statistics over metric samples.

use crate::util::stats::{nearest_rank_index, total_order};

/// Mean / spread / percentiles of a sample set.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p95: f64,
    pub max: f64,
}

impl Summary {
    /// Compute a summary; empty input yields all zeros.
    pub fn of(samples: &[f64]) -> Self {
        if samples.is_empty() {
            return Self { n: 0, mean: 0.0, std: 0.0, min: 0.0,
                          p50: 0.0, p95: 0.0, max: 0.0 };
        }
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean).powi(2)).sum::<f64>()
            / n as f64;
        let mut sorted = samples.to_vec();
        sorted.sort_by(total_order);
        // Percentiles resolve through the one shared nearest-rank
        // helper (util::stats) — the autoscaler's wait-p95 trigger and
        // the carbon signal's quantile use the same function, so
        // "p95" means one thing everywhere.
        let pct = |p: f64| sorted[nearest_rank_index(n, p)];
        Self {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            p50: pct(0.50),
            p95: pct(0.95),
            max: sorted[n - 1],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_stats() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert_eq!(s.mean, 3.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.p50, 3.0);
        assert!((s.std - 2.0_f64.sqrt()).abs() < 1e-12);
    }

    #[test]
    fn empty_is_zero() {
        let s = Summary::of(&[]);
        assert_eq!(s.n, 0);
        assert_eq!(s.mean, 0.0);
    }

    #[test]
    fn single_sample() {
        let s = Summary::of(&[7.5]);
        assert_eq!((s.mean, s.min, s.max, s.p50, s.p95), (7.5, 7.5, 7.5, 7.5, 7.5));
        assert_eq!(s.std, 0.0);
    }
}
