//! Metrics (paper Table IV) and paper-style report formatting.

mod report;
mod stats;

pub use report::{format_heatmap, format_table, format_timeline, Table};
pub use stats::Summary;
