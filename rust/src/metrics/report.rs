//! Paper-style table and heatmap rendering (terminal + CSV).

/// A simple column-aligned table.
#[derive(Debug, Clone, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells);
    }

    /// CSV rendering (for EXPERIMENTS.md ingestion / plotting).
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        out.push_str(&self.headers.join(","));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&r.join(","));
            out.push('\n');
        }
        out
    }
}

/// Align and render a `Table` for the terminal.
pub fn format_table(t: &Table) -> String {
    let ncol = t.headers.len();
    let mut widths: Vec<usize> =
        t.headers.iter().map(|h| h.len()).collect();
    for r in &t.rows {
        for (i, c) in r.iter().enumerate() {
            widths[i] = widths[i].max(c.len());
        }
    }
    let sep = |ch: char| {
        let mut s = String::from("+");
        for w in &widths {
            s.push_str(&ch.to_string().repeat(w + 2));
            s.push('+');
        }
        s
    };
    let render_row = |cells: &[String]| {
        let mut s = String::from("|");
        for i in 0..ncol {
            s.push_str(&format!(" {:<w$} |", cells[i], w = widths[i]));
        }
        s
    };
    let mut out = String::new();
    if !t.title.is_empty() {
        out.push_str(&format!("{}\n", t.title));
    }
    out.push_str(&sep('-'));
    out.push('\n');
    out.push_str(&render_row(&t.headers));
    out.push('\n');
    out.push_str(&sep('='));
    out.push('\n');
    for r in &t.rows {
        out.push_str(&render_row(r));
        out.push('\n');
    }
    out.push_str(&sep('-'));
    out
}

/// Render a Fig-2-style heatmap: rows × cols of percentages with a
/// coarse shade legend (terminal-safe ASCII shading).
pub fn format_heatmap(
    title: &str,
    row_labels: &[String],
    col_labels: &[String],
    values: &[Vec<f64>],
) -> String {
    let shade = |v: f64, lo: f64, hi: f64| {
        let t = if hi > lo { (v - lo) / (hi - lo) } else { 0.5 };
        match (t * 4.0) as i64 {
            0 => "░░",
            1 => "▒▒",
            2 => "▓▓",
            _ => "██",
        }
    };
    let (lo, hi) = values
        .iter()
        .flatten()
        .fold((f64::INFINITY, f64::NEG_INFINITY), |(l, h), &v| {
            (l.min(v), h.max(v))
        });
    let label_w = row_labels.iter().map(|l| l.len()).max().unwrap_or(0);
    let col_w = 12;
    let mut out = format!("{title}\n");
    out.push_str(&" ".repeat(label_w + 2));
    for c in col_labels {
        out.push_str(&format!("{c:>col_w$}"));
    }
    out.push('\n');
    for (i, r) in row_labels.iter().enumerate() {
        out.push_str(&format!("{r:<label_w$}  "));
        for v in &values[i] {
            out.push_str(&format!(
                "{:>w$}",
                format!("{} {:5.2}%", shade(*v, lo, hi), v),
                w = col_w
            ));
        }
        out.push('\n');
    }
    out.push_str(&format!(
        "shade: ░░ low … ██ high  (range {lo:.2}% – {hi:.2}%)\n"
    ));
    out
}

/// Render a step-function timeline (e.g. Ready-node count over a run)
/// as a fixed-width block sparkline with its value range. `samples`
/// are `(time, value)` change points; the value holds until the next
/// sample (and to `end_s` after the last).
pub fn format_timeline(
    title: &str,
    samples: &[(f64, usize)],
    end_s: f64,
    width: usize,
) -> String {
    if samples.is_empty() || width == 0 {
        return format!("{title}\n(no samples)\n");
    }
    let lo = samples.iter().map(|&(_, v)| v).min().unwrap_or(0);
    let hi = samples.iter().map(|&(_, v)| v).max().unwrap_or(0);
    // greenpod-lint: allow(silent-clamp) reason="chart x-range must reach the last sample even when it lands past the nominal end"
    let end = end_s.max(samples.last().unwrap().0);
    let value_at = |t: f64| {
        let mut v = samples[0].1;
        for &(at, val) in samples {
            if at <= t {
                v = val;
            } else {
                break;
            }
        }
        v
    };
    const BLOCKS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
    let mut line = String::new();
    for i in 0..width {
        // Sample at the midpoint of each column's time slice.
        let t = end * (i as f64 + 0.5) / width as f64;
        let v = value_at(t);
        let idx = if hi > lo {
            (((v - lo) as f64 / (hi - lo) as f64) * 7.0).round() as usize
        } else {
            3
        };
        line.push(BLOCKS[idx.min(7)]);
    }
    format!("{title}\n{line}\nnodes {lo}–{hi} over {end:.1} s\n")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_roundtrip() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        let text = format_table(&t);
        assert!(text.contains("| a |"));
        assert!(text.contains("| 1 |"));
        assert_eq!(t.to_csv(), "a,b\n1,2\n");
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn row_width_checked() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row(vec!["1".into()]);
    }

    #[test]
    fn timeline_steps_between_levels() {
        // 7 nodes for the first half, 9 for the second: the sparkline's
        // first half is the low block, the second the high block.
        let text = format_timeline(
            "nodes",
            &[(0.0, 7), (50.0, 9)],
            100.0,
            10,
        );
        let line = text.lines().nth(1).unwrap();
        let chars: Vec<char> = line.chars().collect();
        assert_eq!(chars.len(), 10);
        assert_eq!(chars[0], '▁');
        assert_eq!(chars[9], '█');
        assert!(text.contains("nodes 7–9"));
    }

    #[test]
    fn timeline_flat_and_empty_are_safe() {
        let flat = format_timeline("n", &[(0.0, 7)], 10.0, 5);
        assert_eq!(flat.lines().nth(1).unwrap().chars().count(), 5);
        let empty = format_timeline("n", &[], 10.0, 5);
        assert!(empty.contains("no samples"));
    }

    #[test]
    fn heatmap_renders_all_cells() {
        let text = format_heatmap(
            "H",
            &["r1".into(), "r2".into()],
            &["c1".into(), "c2".into()],
            &[vec![1.0, 2.0], vec![3.0, 4.0]],
        );
        assert!(text.contains("1.00%"));
        assert!(text.contains("4.00%"));
        assert!(text.contains("██"));
    }
}
