//! Cluster nodes: the heterogeneous machines of paper Table I.


/// Node category from Table I. Ordering is the paper's reporting order.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum NodeCategory {
    /// e2-medium — energy-efficient, minimal resources.
    A,
    /// n2-standard-2 — balanced performance.
    B,
    /// n2-standard-4 — high-performance, high resource.
    C,
    /// e2-standard-2 — system components pool.
    Default,
}

impl NodeCategory {
    pub const ALL: [NodeCategory; 4] = [
        NodeCategory::A,
        NodeCategory::B,
        NodeCategory::C,
        NodeCategory::Default,
    ];

    pub fn label(self) -> &'static str {
        match self {
            NodeCategory::A => "A",
            NodeCategory::B => "B",
            NodeCategory::C => "C",
            NodeCategory::Default => "Default",
        }
    }
}

impl std::fmt::Display for NodeCategory {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.label())
    }
}

/// Dense node index within a [`crate::cluster::ClusterState`].
pub type NodeId = usize;

/// One cluster node. Capacity is fixed; live allocation is tracked by
/// [`crate::cluster::ClusterState`], not here, so `Node` stays cheap to
/// share with estimators and scorers.
#[derive(Debug, Clone)]
pub struct Node {
    pub id: NodeId,
    pub name: String,
    pub category: NodeCategory,
    /// GCE machine type (informational).
    pub machine_type: String,
    /// Capacity in millicores (kubelet "allocatable").
    pub cpu_millis: u64,
    /// Capacity in MiB.
    pub memory_mib: u64,
    /// Relative per-core execution speed (1.0 = n2 baseline).
    pub speed_factor: f64,
    /// Dayarathna blade-model scale for this hardware class.
    pub power_scale: f64,
    /// NotReady nodes are excluded from scheduling (failure injection).
    pub ready: bool,
}

impl Node {
    /// vCPU count (capacity / 1000m).
    pub fn vcpus(&self) -> f64 {
        self.cpu_millis as f64 / 1000.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn category_labels_roundtrip_display() {
        for c in NodeCategory::ALL {
            assert_eq!(format!("{c}"), c.label());
        }
    }

    #[test]
    fn vcpu_conversion() {
        let n = Node {
            id: 0,
            name: "n".into(),
            category: NodeCategory::C,
            machine_type: "n2-standard-4".into(),
            cpu_millis: 4000,
            memory_mib: 16384,
            speed_factor: 1.1,
            power_scale: 1.6,
            ready: true,
        };
        assert_eq!(n.vcpus(), 4.0);
    }
}
