//! Pods: containerized workload instances (paper Table II rows).


use crate::config::SchedulerKind;
use crate::workload::WorkloadClass;

/// Unique pod identifier within a run.
pub type PodId = u64;

/// CPU/memory requests — what the scheduler reserves (kube semantics:
/// requests gate placement; we do not model limits separately).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ResourceRequests {
    pub cpu_millis: u64,
    pub memory_mib: u64,
}

/// Kube-style pod lifecycle, reduced to what the simulation needs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PodPhase {
    Pending,
    Running,
    Succeeded,
    /// Could not be placed on any node (stays in queue or fails the run,
    /// depending on engine policy).
    Unschedulable,
}

/// One pod to place and execute.
#[derive(Debug, Clone)]
pub struct Pod {
    pub id: PodId,
    pub name: String,
    /// Workload class — determines requests, artifact, and work size.
    pub class: WorkloadClass,
    /// Which scheduler owns this pod (Table V half/half split). Mirrors
    /// the `schedulerName` field of a real pod spec.
    pub scheduler: SchedulerKind,
    pub requests: ResourceRequests,
    /// Submission time (simulated seconds).
    pub arrival_s: f64,
    /// SGD epochs to run (work size; see `ExperimentConfig::epochs_for`).
    pub epochs: u32,
    pub phase: PodPhase,
}

impl Pod {
    pub fn new(
        id: PodId,
        class: WorkloadClass,
        scheduler: SchedulerKind,
        arrival_s: f64,
        epochs: u32,
    ) -> Self {
        Self {
            id,
            name: format!(
                "{}-{}-{id}",
                class.label_lower(),
                match scheduler {
                    SchedulerKind::Topsis => "topsis",
                    SchedulerKind::DefaultK8s => "default",
                }
            ),
            class,
            scheduler,
            requests: class.requests(),
            arrival_s,
            epochs,
            phase: PodPhase::Pending,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pod_names_encode_class_and_scheduler() {
        let p = Pod::new(7, WorkloadClass::Medium, SchedulerKind::Topsis,
                         0.0, 4);
        assert_eq!(p.name, "medium-topsis-7");
        assert_eq!(p.phase, PodPhase::Pending);
        // Table II: medium requests 0.5 CPU / 1 GB.
        assert_eq!(p.requests.cpu_millis, 500);
        assert_eq!(p.requests.memory_mib, 1024);
    }
}
