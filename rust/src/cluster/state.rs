//! Live cluster state: allocatable accounting + bind/release, the
//! invariant-bearing core the schedulers and the simulation share.
//!
//! Million-pod hot path (DESIGN.md §"Hot path"): every mutation stamps
//! the touched node with a globally fresh version
//! ([`ClusterState::node_version`]), so score plugins can reuse
//! last-cycle per-node work for clean nodes; feasibility is served from
//! log2-bucketed free-capacity indices (a range probe, not an O(nodes)
//! scan), pinned bit-identical to the reference linear scan
//! ([`ClusterState::feasible_nodes_scan`]) by the property suite.

use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};

use super::{Node, NodeCategory, NodeId, Pod, PodId, ResourceRequests};
use crate::config::ClusterConfig;

/// Events the state emits (consumed by metrics & the api watch loop).
#[derive(Debug, Clone, PartialEq)]
pub enum ClusterEvent {
    Bound { pod: PodId, node: NodeId, at_s: f64 },
    Released { pod: PodId, node: NodeId, at_s: f64 },
    NodeReady { node: NodeId, ready: bool, at_s: f64 },
    /// A node was provisioned into the cluster (autoscaler scale-out);
    /// it starts NotReady and becomes schedulable via `NodeReady`.
    NodeAdded { node: NodeId, at_s: f64 },
}

/// Most events retained for [`ClusterState::drain_events`]. Consumers
/// that want the stream drain it as they go; an undrained state keeps
/// only the newest `EVENT_RETENTION_CAP` events instead of growing
/// O(pods) over a trace-scale run.
pub const EVENT_RETENTION_CAP: usize = 4096;

/// Monotone global version source. Every node mutation — in any
/// `ClusterState` instance — draws a fresh value, so two nodes (or one
/// node at two times, or a state and its clone after divergence) never
/// share a version unless their content is byte-identical. That makes
/// version equality a sound cache key across instances.
static NODE_VERSION_COUNTER: AtomicU64 = AtomicU64::new(0);

/// Next globally unique version (always ≥ 1, so 0 is a safe
/// never-matches sentinel for caches).
fn fresh_version() -> u64 {
    NODE_VERSION_COUNTER.fetch_add(1, Ordering::Relaxed) + 1
}

/// Per-node live allocation.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
struct Alloc {
    cpu_millis: u64,
    memory_mib: u64,
    pods: u32,
}

/// Buckets for the free-capacity indices: bucket `b` holds nodes whose
/// free amount `v` has `bucket_of(v) == b` (i.e. `v`'s bit length;
/// bucket 0 is exactly `v == 0`). 64-bit values need 65 buckets.
const FREE_BUCKETS: usize = 65;

fn bucket_of(v: u64) -> usize {
    (64 - v.leading_zeros()) as usize
}

/// A log2-bucketed index over one free-resource axis, maintained O(1)
/// per bind/release. `feasible_nodes` probes only the buckets that can
/// hold a satisfying amount: every node with `free >= req` lives in a
/// bucket `>= bucket_of(req)` (values below `req` have strictly fewer
/// or equal bits), so the probe's superset is exact on bucket
/// boundaries and cheap to enumerate.
#[derive(Debug, Clone)]
struct FreeIndex {
    buckets: Vec<Vec<NodeId>>,
    /// Per node: (bucket, position within the bucket) for O(1)
    /// swap-remove maintenance.
    slot: Vec<(u32, u32)>,
}

impl FreeIndex {
    fn new() -> Self {
        Self { buckets: vec![Vec::new(); FREE_BUCKETS], slot: Vec::new() }
    }

    /// Register node `id` (ids are dense and append-only).
    fn insert(&mut self, id: NodeId, free: u64) {
        debug_assert_eq!(self.slot.len(), id);
        let b = bucket_of(free);
        self.slot.push((b as u32, self.buckets[b].len() as u32));
        self.buckets[b].push(id);
    }

    /// Move node `id` to the bucket of its new free amount.
    fn update(&mut self, id: NodeId, free: u64) {
        let b = bucket_of(free) as u32;
        let (old_b, pos) = self.slot[id];
        if old_b == b {
            return;
        }
        let removed = self.buckets[old_b as usize].swap_remove(pos as usize);
        debug_assert_eq!(removed, id);
        // The entry swapped into the vacated position (if any) moved.
        if let Some(&moved) = self.buckets[old_b as usize].get(pos as usize) {
            self.slot[moved] = (old_b, pos);
        }
        self.slot[id] = (b, self.buckets[b as usize].len() as u32);
        self.buckets[b as usize].push(id);
    }

    /// Size of the probe superset for `min` (every node with
    /// `free >= min` is counted; some counted nodes may still fall
    /// short within the boundary bucket).
    fn superset_len(&self, min: u64) -> usize {
        self.buckets[bucket_of(min)..].iter().map(Vec::len).sum()
    }
}

/// The cluster: fixed node set + mutable allocation state.
///
/// Invariants (enforced here, property-tested in `rust/tests/`):
/// * allocated ≤ capacity on every node, always;
/// * a pod is bound to at most one node;
/// * release exactly undoes the matching bind.
#[derive(Debug, Clone)]
pub struct ClusterState {
    nodes: Vec<Node>,
    alloc: Vec<Alloc>,
    /// Bound-pod ledger. BTreeMap so `pods_per_category` (and any
    /// future walk) iterates in pod-id order, never hash order.
    bound: BTreeMap<PodId, (NodeId, ResourceRequests)>,
    events: VecDeque<ClusterEvent>,
    /// Events ever emitted (retained + dropped + drained) — the cursor
    /// consumers compare against to detect drops.
    events_emitted: u64,
    /// Per-node cache-invalidation stamp (globally unique per
    /// mutation; see [`NODE_VERSION_COUNTER`]).
    node_version: Vec<u64>,
    /// Count of mutations applied to this instance (bind / release /
    /// set_ready / add_node) — the engines' "did anything change since
    /// the last cycle" signal.
    mutations: u64,
    ready_count: usize,
    total_alloc_cpu: u64,
    total_cap_cpu: u64,
    free_cpu_index: FreeIndex,
    free_mem_index: FreeIndex,
}

impl ClusterState {
    /// Materialize the Table I cluster from config.
    pub fn from_config(cfg: &ClusterConfig) -> Self {
        let mut nodes = Vec::with_capacity(cfg.total_nodes());
        for pool in &cfg.pools {
            for i in 0..pool.count {
                let id = nodes.len();
                nodes.push(Node {
                    id,
                    name: format!(
                        "{}-{}-{i}",
                        pool.machine_type,
                        pool.category.label().to_lowercase()
                    ),
                    category: pool.category,
                    machine_type: pool.machine_type.clone(),
                    cpu_millis: pool.cpu_millis,
                    memory_mib: pool.memory_mib,
                    speed_factor: pool.speed_factor,
                    power_scale: pool.power_scale,
                    ready: true,
                });
            }
        }
        let alloc = vec![Alloc::default(); nodes.len()];
        let mut state = Self {
            nodes,
            alloc,
            bound: BTreeMap::new(),
            events: VecDeque::new(),
            events_emitted: 0,
            node_version: Vec::new(),
            mutations: 0,
            ready_count: 0,
            total_alloc_cpu: 0,
            total_cap_cpu: 0,
            free_cpu_index: FreeIndex::new(),
            free_mem_index: FreeIndex::new(),
        };
        for id in 0..state.nodes.len() {
            let node = &state.nodes[id];
            state.node_version.push(fresh_version());
            state.ready_count += node.ready as usize;
            state.total_cap_cpu += node.cpu_millis;
            let (cpu, mem) = (node.cpu_millis, node.memory_mib);
            state.free_cpu_index.insert(id, cpu);
            state.free_mem_index.insert(id, mem);
        }
        state
    }

    pub fn nodes(&self) -> &[Node] {
        &self.nodes
    }

    pub fn node(&self, id: NodeId) -> &Node {
        &self.nodes[id]
    }

    /// Remove and return the retained event backlog (oldest first).
    /// Consumers that need the full stream drain after every batch of
    /// mutations; at most [`EVENT_RETENTION_CAP`] events are retained
    /// between drains (oldest dropped first).
    pub fn drain_events(&mut self) -> Vec<ClusterEvent> {
        self.events.drain(..).collect()
    }

    /// Events currently retained (≤ [`EVENT_RETENTION_CAP`]).
    pub fn retained_events(&self) -> usize {
        self.events.len()
    }

    /// Total events ever emitted by this instance. A consumer whose
    /// drained count falls behind this cursor by more than the
    /// retention cap has missed (dropped) events.
    pub fn events_emitted(&self) -> u64 {
        self.events_emitted
    }

    fn push_event(&mut self, ev: ClusterEvent) {
        if self.events.len() == EVENT_RETENTION_CAP {
            self.events.pop_front();
        }
        self.events.push_back(ev);
        self.events_emitted += 1;
    }

    /// Record a mutation of node `id`: stamp a globally fresh version
    /// (invalidating every cache holding the old one) and count it.
    fn touch(&mut self, id: NodeId) {
        self.node_version[id] = fresh_version();
        self.mutations += 1;
    }

    /// Cache-invalidation stamp for node `id`. Equal stamps — across
    /// clones, times and instances — guarantee identical node content
    /// (spec, readiness and allocation); any mutation draws a new,
    /// never-reused stamp.
    pub fn node_version(&self, id: NodeId) -> u64 {
        self.node_version[id]
    }

    /// Mutations applied to this instance so far. Unchanged between
    /// two observations ⇒ no node changed in between.
    pub fn mutations(&self) -> u64 {
        self.mutations
    }

    /// Free CPU on a node (millicores).
    pub fn free_cpu(&self, id: NodeId) -> u64 {
        self.nodes[id].cpu_millis - self.alloc[id].cpu_millis
    }

    /// Free memory on a node (MiB).
    pub fn free_memory(&self, id: NodeId) -> u64 {
        self.nodes[id].memory_mib - self.alloc[id].memory_mib
    }

    /// Requested-CPU utilization fraction of a node, in `[0, 1]`.
    /// A zero-capacity node reads as 0 utilization, not NaN.
    pub fn cpu_utilization(&self, id: NodeId) -> f64 {
        let cap = self.nodes[id].cpu_millis;
        if cap == 0 {
            return 0.0;
        }
        self.alloc[id].cpu_millis as f64 / cap as f64
    }

    /// Requested-memory utilization fraction of a node, in `[0, 1]`.
    /// A zero-capacity node reads as 0 utilization, not NaN.
    pub fn memory_utilization(&self, id: NodeId) -> f64 {
        let cap = self.nodes[id].memory_mib;
        if cap == 0 {
            return 0.0;
        }
        self.alloc[id].memory_mib as f64 / cap as f64
    }

    /// Number of pods currently bound to `id`.
    pub fn pods_on(&self, id: NodeId) -> u32 {
        self.alloc[id].pods
    }

    /// Node the pod is currently bound to, if any.
    pub fn node_of(&self, pod: PodId) -> Option<NodeId> {
        self.bound.get(&pod).map(|(n, _)| *n)
    }

    /// Whether `requests` fit on node `id` right now (kube
    /// NodeResourcesFit filter semantics, plus readiness).
    pub fn fits(&self, id: NodeId, requests: ResourceRequests) -> bool {
        self.nodes[id].ready
            && self.free_cpu(id) >= requests.cpu_millis
            && self.free_memory(id) >= requests.memory_mib
    }

    /// Ready nodes where `requests` fit — the scheduler's candidate
    /// set, ascending node ids. Served from the free-capacity indices;
    /// membership and order are pinned bit-identical to
    /// [`Self::feasible_nodes_scan`] by the property suite.
    pub fn feasible_nodes(&self, requests: ResourceRequests) -> Vec<NodeId> {
        let mut out = Vec::new();
        self.feasible_nodes_into(requests, &mut out);
        out
    }

    /// [`Self::feasible_nodes`] into a caller-owned buffer (cleared
    /// first), so the steady-state scheduling cycle allocates nothing.
    pub fn feasible_nodes_into(
        &self,
        requests: ResourceRequests,
        out: &mut Vec<NodeId>,
    ) {
        out.clear();
        let n = self.nodes.len();
        let by_cpu = self.free_cpu_index.superset_len(requests.cpu_millis);
        let by_mem = self.free_mem_index.superset_len(requests.memory_mib);
        // Probe the more selective axis; the full `fits` re-check
        // covers the other axis, readiness and the boundary bucket.
        let (index, min_free, superset) = if by_cpu <= by_mem {
            (&self.free_cpu_index, requests.cpu_millis, by_cpu)
        } else {
            (&self.free_mem_index, requests.memory_mib, by_mem)
        };
        // A probe visiting most of the cluster gains nothing over the
        // scan and would still pay the sort; cross over at half.
        if superset * 2 > n {
            out.extend((0..n).filter(|&id| self.fits(id, requests)));
            return;
        }
        for bucket in &index.buckets[bucket_of(min_free)..] {
            for &id in bucket {
                if self.fits(id, requests) {
                    out.push(id);
                }
            }
        }
        // Buckets are maintenance-ordered; ascending ids are part of
        // the scheduling contract (ties break toward low ids).
        out.sort_unstable();
    }

    /// Reference implementation: the pre-index linear scan (kept for
    /// the differential property and as the crossover fallback's
    /// definition of truth).
    pub fn feasible_nodes_scan(
        &self,
        requests: ResourceRequests,
    ) -> Vec<NodeId> {
        (0..self.nodes.len())
            .filter(|&id| self.fits(id, requests))
            .collect()
    }

    /// Bind a pod (reserve its requests). Errors if it does not fit or
    /// the pod is already bound — the invariants the API server enforces.
    pub fn bind(
        &mut self,
        pod: &Pod,
        node: NodeId,
        at_s: f64,
    ) -> anyhow::Result<()> {
        anyhow::ensure!(
            !self.bound.contains_key(&pod.id),
            "pod {} already bound",
            pod.name
        );
        anyhow::ensure!(
            self.fits(node, pod.requests),
            "pod {} does not fit on node {}",
            pod.name,
            self.nodes[node].name
        );
        let a = &mut self.alloc[node];
        a.cpu_millis += pod.requests.cpu_millis;
        a.memory_mib += pod.requests.memory_mib;
        a.pods += 1;
        self.total_alloc_cpu += pod.requests.cpu_millis;
        self.bound.insert(pod.id, (node, pod.requests));
        self.touch(node);
        let (free_cpu, free_mem) = (self.free_cpu(node), self.free_memory(node));
        self.free_cpu_index.update(node, free_cpu);
        self.free_mem_index.update(node, free_mem);
        self.push_event(ClusterEvent::Bound { pod: pod.id, node, at_s });
        Ok(())
    }

    /// Release a pod's reservation (completion or failure).
    pub fn release(&mut self, pod: PodId, at_s: f64) -> anyhow::Result<NodeId> {
        let (node, req) = self
            .bound
            .remove(&pod)
            .ok_or_else(|| anyhow::anyhow!("pod {pod} not bound"))?;
        let a = &mut self.alloc[node];
        a.cpu_millis -= req.cpu_millis;
        a.memory_mib -= req.memory_mib;
        a.pods -= 1;
        self.total_alloc_cpu -= req.cpu_millis;
        self.touch(node);
        let (free_cpu, free_mem) = (self.free_cpu(node), self.free_memory(node));
        self.free_cpu_index.update(node, free_cpu);
        self.free_mem_index.update(node, free_mem);
        self.push_event(ClusterEvent::Released { pod, node, at_s });
        Ok(node)
    }

    /// Failure injection: flip a node's readiness. Running pods keep
    /// their reservation (kube semantics: NotReady gates *new* bindings).
    /// Readiness does not move index entries — `fits` re-checks it.
    pub fn set_ready(&mut self, node: NodeId, ready: bool, at_s: f64) {
        if self.nodes[node].ready != ready {
            if ready {
                self.ready_count += 1;
            } else {
                self.ready_count -= 1;
            }
        }
        self.nodes[node].ready = ready;
        self.touch(node);
        self.push_event(ClusterEvent::NodeReady { node, ready, at_s });
    }

    /// Provision a new node from a pool template (autoscaler
    /// scale-out). The node starts NotReady — it becomes schedulable
    /// only when its `NodeJoined` event fires after the provisioning
    /// delay. Returns the new node's id (ids are dense and append-only,
    /// so a run's node ids are deterministic).
    pub fn add_node(
        &mut self,
        pool: &crate::config::NodePoolConfig,
        at_s: f64,
    ) -> NodeId {
        let id = self.nodes.len();
        self.nodes.push(Node {
            id,
            name: format!(
                "{}-{}-as{id}",
                pool.machine_type,
                pool.category.label().to_lowercase()
            ),
            category: pool.category,
            machine_type: pool.machine_type.clone(),
            cpu_millis: pool.cpu_millis,
            memory_mib: pool.memory_mib,
            speed_factor: pool.speed_factor,
            power_scale: pool.power_scale,
            ready: false,
        });
        self.alloc.push(Alloc::default());
        self.node_version.push(fresh_version());
        self.mutations += 1;
        self.total_cap_cpu += pool.cpu_millis;
        self.free_cpu_index.insert(id, pool.cpu_millis);
        self.free_mem_index.insert(id, pool.memory_mib);
        self.push_event(ClusterEvent::NodeAdded { node: id, at_s });
        id
    }

    /// Number of Ready nodes right now (O(1), maintained on flips).
    pub fn ready_nodes(&self) -> usize {
        self.ready_count
    }

    /// Pods bound per category — §V.D's allocation analysis. Ordered
    /// map: derived report rows render in category order, every run.
    pub fn pods_per_category(&self) -> BTreeMap<NodeCategory, u32> {
        let mut out = BTreeMap::new();
        for (&_pod, &(node, _)) in &self.bound {
            *out.entry(self.nodes[node].category).or_insert(0) += 1;
        }
        out
    }

    /// Cluster-wide requested-CPU utilization in `[0, 1]` (O(1),
    /// maintained on bind/release/add). An empty or zero-capacity
    /// cluster reads as 0, not NaN.
    pub fn total_cpu_utilization(&self) -> f64 {
        if self.total_cap_cpu == 0 {
            return 0.0;
        }
        self.total_alloc_cpu as f64 / self.total_cap_cpu as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{NodePoolConfig, SchedulerKind};
    use crate::workload::WorkloadClass;

    fn state() -> ClusterState {
        ClusterState::from_config(&ClusterConfig::paper_default())
    }

    fn pod(id: PodId, class: WorkloadClass) -> Pod {
        Pod::new(id, class, SchedulerKind::Topsis, 0.0, 1)
    }

    #[test]
    fn from_config_materializes_table1() {
        let s = state();
        assert_eq!(s.nodes().len(), 7);
        assert_eq!(s.nodes()[0].category, NodeCategory::A);
        assert_eq!(s.free_cpu(0), 2000);
        assert_eq!(s.free_memory(3), 8192); // first B node
    }

    #[test]
    fn bind_release_roundtrip() {
        let mut s = state();
        let p = pod(1, WorkloadClass::Complex);
        s.bind(&p, 5, 0.0).unwrap(); // node 5 = the C node
        assert_eq!(s.free_cpu(5), 3000);
        assert_eq!(s.node_of(1), Some(5));
        assert_eq!(s.pods_on(5), 1);
        let n = s.release(1, 1.0).unwrap();
        assert_eq!(n, 5);
        assert_eq!(s.free_cpu(5), 4000);
        assert_eq!(s.node_of(1), None);
        assert_eq!(s.retained_events(), 2);
        assert_eq!(s.events_emitted(), 2);
        let evs = s.drain_events();
        assert!(matches!(evs[0], ClusterEvent::Bound { pod: 1, node: 5, .. }));
        assert!(
            matches!(evs[1], ClusterEvent::Released { pod: 1, node: 5, .. })
        );
        assert_eq!(s.retained_events(), 0);
        assert_eq!(s.events_emitted(), 2);
    }

    #[test]
    fn overcommit_rejected() {
        let mut s = state();
        // Node 0 (A, 2000m): two complex pods (1000m each) fit; a third
        // complex does not.
        s.bind(&pod(1, WorkloadClass::Complex), 0, 0.0).unwrap();
        s.bind(&pod(2, WorkloadClass::Complex), 0, 0.0).unwrap();
        assert!(s.bind(&pod(3, WorkloadClass::Complex), 0, 0.0).is_err());
        // Memory can also be the binding constraint: node 0 has 4096 MiB;
        // after 2x2048 MiB nothing fits.
        assert!(!s.fits(0, ResourceRequests { cpu_millis: 0, memory_mib: 1 }));
    }

    #[test]
    fn double_bind_rejected() {
        let mut s = state();
        let p = pod(1, WorkloadClass::Light);
        s.bind(&p, 0, 0.0).unwrap();
        assert!(s.bind(&p, 1, 0.0).is_err());
    }

    #[test]
    fn not_ready_node_filtered() {
        let mut s = state();
        s.set_ready(0, false, 0.0);
        let feas = s.feasible_nodes(WorkloadClass::Light.requests());
        assert!(!feas.contains(&0));
        assert!(s.bind(&pod(1, WorkloadClass::Light), 0, 0.0).is_err());
        s.set_ready(0, true, 1.0);
        assert!(s.fits(0, WorkloadClass::Light.requests()));
    }

    #[test]
    fn release_unknown_pod_errors() {
        let mut s = state();
        assert!(s.release(99, 0.0).is_err());
    }

    #[test]
    fn add_node_appends_not_ready_then_joins() {
        let mut s = state();
        let pool = ClusterConfig::paper_default().pools[0].clone();
        let id = s.add_node(&pool, 5.0);
        assert_eq!(id, 7);
        assert_eq!(s.nodes().len(), 8);
        assert!(!s.node(id).ready);
        assert_eq!(s.ready_nodes(), 7);
        // NotReady: not schedulable yet.
        assert!(!s.fits(id, WorkloadClass::Light.requests()));
        s.set_ready(id, true, 10.0);
        assert_eq!(s.ready_nodes(), 8);
        assert!(s.fits(id, WorkloadClass::Light.requests()));
        assert_eq!(s.free_cpu(id), pool.cpu_millis);
        assert_eq!(s.free_memory(id), pool.memory_mib);
        let evs = s.drain_events();
        assert!(matches!(
            evs[0],
            ClusterEvent::NodeAdded { node: 7, at_s: _ }
        ));
    }

    #[test]
    fn category_histogram() {
        let mut s = state();
        s.bind(&pod(1, WorkloadClass::Light), 0, 0.0).unwrap();
        s.bind(&pod(2, WorkloadClass::Light), 1, 0.0).unwrap();
        s.bind(&pod(3, WorkloadClass::Light), 5, 0.0).unwrap();
        let h = s.pods_per_category();
        assert_eq!(h[&NodeCategory::A], 2);
        assert_eq!(h[&NodeCategory::C], 1);
    }

    #[test]
    fn event_buffer_stays_bounded_over_long_runs() {
        // Regression: the retained buffer used to grow by one entry per
        // bind/release for the whole run — O(pods) memory at trace
        // scale. It must now stay capped, with the cursor still
        // counting everything ever emitted.
        let mut s = state();
        let rounds = EVENT_RETENTION_CAP as u64 * 3;
        for i in 0..rounds {
            let p = pod(i, WorkloadClass::Light);
            s.bind(&p, 0, 0.0).unwrap();
            s.release(i, 0.0).unwrap();
        }
        assert_eq!(s.retained_events(), EVENT_RETENTION_CAP);
        assert_eq!(s.events_emitted(), rounds * 2);
        let drained = s.drain_events();
        assert_eq!(drained.len(), EVENT_RETENTION_CAP);
        assert_eq!(s.retained_events(), 0);
        // The retained tail is the *newest* events.
        assert!(matches!(
            drained.last(),
            Some(ClusterEvent::Released { pod, .. }) if *pod == rounds - 1
        ));
        // Draining as you go loses nothing.
        let mut seen = 0usize;
        let mut t = state();
        for i in 0..rounds {
            let p = pod(i, WorkloadClass::Light);
            t.bind(&p, 0, 0.0).unwrap();
            t.release(i, 0.0).unwrap();
            seen += t.drain_events().len();
        }
        assert_eq!(seen as u64, t.events_emitted());
    }

    #[test]
    fn zero_capacity_utilization_is_zero_not_nan() {
        // Regression: a zero-capacity node (constructible from a raw
        // pool template, e.g. a federation region scaled to nothing)
        // used to divide by zero into NaN and poison every downstream
        // mean/score.
        let cfg = ClusterConfig {
            pools: vec![NodePoolConfig {
                category: NodeCategory::A,
                machine_type: "null".into(),
                count: 1,
                cpu_millis: 0,
                memory_mib: 0,
                speed_factor: 1.0,
                power_scale: 1.0,
            }],
            schedulable_default_pool: true,
        };
        let s = ClusterState::from_config(&cfg);
        assert_eq!(s.cpu_utilization(0), 0.0);
        assert_eq!(s.memory_utilization(0), 0.0);
        assert_eq!(s.total_cpu_utilization(), 0.0);

        // Empty node set: the cluster-wide mean must also be 0.
        let empty = ClusterState::from_config(&ClusterConfig {
            pools: Vec::new(),
            schedulable_default_pool: true,
        });
        assert_eq!(empty.total_cpu_utilization(), 0.0);

        // The guarded paths leave nonzero capacity untouched.
        let mut s = state();
        s.bind(&pod(1, WorkloadClass::Complex), 0, 0.0).unwrap();
        assert_eq!(s.cpu_utilization(0), 1000.0 / 2000.0);
        assert!(s.total_cpu_utilization() > 0.0);
    }

    #[test]
    fn feasible_index_matches_scan_under_churn() {
        let mut s = state();
        let reqs = [
            ResourceRequests { cpu_millis: 250, memory_mib: 512 },
            ResourceRequests { cpu_millis: 1000, memory_mib: 2048 },
            ResourceRequests { cpu_millis: 0, memory_mib: 0 },
            // Oversized on each axis, and on both: always empty.
            ResourceRequests { cpu_millis: 1_000_000, memory_mib: 1 },
            ResourceRequests { cpu_millis: 1, memory_mib: 1_000_000 },
            ResourceRequests { cpu_millis: u64::MAX, memory_mib: u64::MAX },
        ];
        let check = |s: &ClusterState| {
            for req in reqs {
                assert_eq!(
                    s.feasible_nodes(req),
                    s.feasible_nodes_scan(req),
                    "req {req:?}"
                );
            }
        };
        check(&s);
        s.bind(&pod(1, WorkloadClass::Complex), 0, 0.0).unwrap();
        s.bind(&pod(2, WorkloadClass::Medium), 5, 0.0).unwrap();
        check(&s);
        s.set_ready(3, false, 0.0);
        check(&s);
        let pool = ClusterConfig::paper_default().pools[2].clone();
        let id = s.add_node(&pool, 1.0);
        check(&s);
        s.set_ready(id, true, 2.0);
        check(&s);
        s.release(1, 3.0).unwrap();
        check(&s);
        assert!(s
            .feasible_nodes(ResourceRequests {
                cpu_millis: u64::MAX,
                memory_mib: u64::MAX
            })
            .is_empty());
    }

    #[test]
    fn node_versions_stamp_every_mutation() {
        let mut s = state();
        let v0 = s.node_version(0);
        let m0 = s.mutations();
        s.bind(&pod(1, WorkloadClass::Light), 0, 0.0).unwrap();
        assert_ne!(s.node_version(0), v0);
        assert_eq!(s.mutations(), m0 + 1);
        let v1 = s.node_version(0);
        s.set_ready(0, false, 0.0);
        assert_ne!(s.node_version(0), v1);
        // Untouched nodes keep their stamp.
        let v5 = s.node_version(5);
        s.release(1, 0.0).unwrap();
        assert_eq!(s.node_version(5), v5);

        // Clone divergence: after the original mutates, the two
        // instances never share a stamp for the mutated node — the
        // global counter makes stale cross-instance cache hits
        // impossible.
        let clone = s.clone();
        assert_eq!(clone.node_version(0), s.node_version(0));
        s.set_ready(0, true, 1.0);
        assert_ne!(clone.node_version(0), s.node_version(0));
    }
}
