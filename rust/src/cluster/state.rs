//! Live cluster state: allocatable accounting + bind/release, the
//! invariant-bearing core the schedulers and the simulation share.

use std::collections::HashMap;


use super::{Node, NodeCategory, NodeId, Pod, PodId, ResourceRequests};
use crate::config::ClusterConfig;

/// Events the state emits (consumed by metrics & the api watch loop).
#[derive(Debug, Clone, PartialEq)]
pub enum ClusterEvent {
    Bound { pod: PodId, node: NodeId, at_s: f64 },
    Released { pod: PodId, node: NodeId, at_s: f64 },
    NodeReady { node: NodeId, ready: bool, at_s: f64 },
    /// A node was provisioned into the cluster (autoscaler scale-out);
    /// it starts NotReady and becomes schedulable via `NodeReady`.
    NodeAdded { node: NodeId, at_s: f64 },
}

/// Per-node live allocation.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
struct Alloc {
    cpu_millis: u64,
    memory_mib: u64,
    pods: u32,
}

/// The cluster: fixed node set + mutable allocation state.
///
/// Invariants (enforced here, property-tested in `rust/tests/`):
/// * allocated ≤ capacity on every node, always;
/// * a pod is bound to at most one node;
/// * release exactly undoes the matching bind.
#[derive(Debug, Clone)]
pub struct ClusterState {
    nodes: Vec<Node>,
    alloc: Vec<Alloc>,
    bound: HashMap<PodId, (NodeId, ResourceRequests)>,
    events: Vec<ClusterEvent>,
}

impl ClusterState {
    /// Materialize the Table I cluster from config.
    pub fn from_config(cfg: &ClusterConfig) -> Self {
        let mut nodes = Vec::with_capacity(cfg.total_nodes());
        for pool in &cfg.pools {
            for i in 0..pool.count {
                let id = nodes.len();
                nodes.push(Node {
                    id,
                    name: format!(
                        "{}-{}-{i}",
                        pool.machine_type,
                        pool.category.label().to_lowercase()
                    ),
                    category: pool.category,
                    machine_type: pool.machine_type.clone(),
                    cpu_millis: pool.cpu_millis,
                    memory_mib: pool.memory_mib,
                    speed_factor: pool.speed_factor,
                    power_scale: pool.power_scale,
                    ready: true,
                });
            }
        }
        let alloc = vec![Alloc::default(); nodes.len()];
        Self { nodes, alloc, bound: HashMap::new(), events: Vec::new() }
    }

    pub fn nodes(&self) -> &[Node] {
        &self.nodes
    }

    pub fn node(&self, id: NodeId) -> &Node {
        &self.nodes[id]
    }

    pub fn events(&self) -> &[ClusterEvent] {
        &self.events
    }

    /// Free CPU on a node (millicores).
    pub fn free_cpu(&self, id: NodeId) -> u64 {
        self.nodes[id].cpu_millis - self.alloc[id].cpu_millis
    }

    /// Free memory on a node (MiB).
    pub fn free_memory(&self, id: NodeId) -> u64 {
        self.nodes[id].memory_mib - self.alloc[id].memory_mib
    }

    /// Requested-CPU utilization fraction of a node, in `[0, 1]`.
    pub fn cpu_utilization(&self, id: NodeId) -> f64 {
        self.alloc[id].cpu_millis as f64 / self.nodes[id].cpu_millis as f64
    }

    /// Requested-memory utilization fraction of a node, in `[0, 1]`.
    pub fn memory_utilization(&self, id: NodeId) -> f64 {
        self.alloc[id].memory_mib as f64 / self.nodes[id].memory_mib as f64
    }

    /// Number of pods currently bound to `id`.
    pub fn pods_on(&self, id: NodeId) -> u32 {
        self.alloc[id].pods
    }

    /// Node the pod is currently bound to, if any.
    pub fn node_of(&self, pod: PodId) -> Option<NodeId> {
        self.bound.get(&pod).map(|(n, _)| *n)
    }

    /// Whether `requests` fit on node `id` right now (kube
    /// NodeResourcesFit filter semantics, plus readiness).
    pub fn fits(&self, id: NodeId, requests: ResourceRequests) -> bool {
        self.nodes[id].ready
            && self.free_cpu(id) >= requests.cpu_millis
            && self.free_memory(id) >= requests.memory_mib
    }

    /// Ready nodes where `requests` fit — the scheduler's candidate set.
    pub fn feasible_nodes(&self, requests: ResourceRequests) -> Vec<NodeId> {
        (0..self.nodes.len())
            .filter(|&id| self.fits(id, requests))
            .collect()
    }

    /// Bind a pod (reserve its requests). Errors if it does not fit or
    /// the pod is already bound — the invariants the API server enforces.
    pub fn bind(
        &mut self,
        pod: &Pod,
        node: NodeId,
        at_s: f64,
    ) -> anyhow::Result<()> {
        anyhow::ensure!(
            !self.bound.contains_key(&pod.id),
            "pod {} already bound",
            pod.name
        );
        anyhow::ensure!(
            self.fits(node, pod.requests),
            "pod {} does not fit on node {}",
            pod.name,
            self.nodes[node].name
        );
        let a = &mut self.alloc[node];
        a.cpu_millis += pod.requests.cpu_millis;
        a.memory_mib += pod.requests.memory_mib;
        a.pods += 1;
        self.bound.insert(pod.id, (node, pod.requests));
        self.events.push(ClusterEvent::Bound { pod: pod.id, node, at_s });
        Ok(())
    }

    /// Release a pod's reservation (completion or failure).
    pub fn release(&mut self, pod: PodId, at_s: f64) -> anyhow::Result<NodeId> {
        let (node, req) = self
            .bound
            .remove(&pod)
            .ok_or_else(|| anyhow::anyhow!("pod {pod} not bound"))?;
        let a = &mut self.alloc[node];
        a.cpu_millis -= req.cpu_millis;
        a.memory_mib -= req.memory_mib;
        a.pods -= 1;
        self.events.push(ClusterEvent::Released { pod, node, at_s });
        Ok(node)
    }

    /// Failure injection: flip a node's readiness. Running pods keep
    /// their reservation (kube semantics: NotReady gates *new* bindings).
    pub fn set_ready(&mut self, node: NodeId, ready: bool, at_s: f64) {
        self.nodes[node].ready = ready;
        self.events.push(ClusterEvent::NodeReady { node, ready, at_s });
    }

    /// Provision a new node from a pool template (autoscaler
    /// scale-out). The node starts NotReady — it becomes schedulable
    /// only when its `NodeJoined` event fires after the provisioning
    /// delay. Returns the new node's id (ids are dense and append-only,
    /// so a run's node ids are deterministic).
    pub fn add_node(
        &mut self,
        pool: &crate::config::NodePoolConfig,
        at_s: f64,
    ) -> NodeId {
        let id = self.nodes.len();
        self.nodes.push(Node {
            id,
            name: format!(
                "{}-{}-as{id}",
                pool.machine_type,
                pool.category.label().to_lowercase()
            ),
            category: pool.category,
            machine_type: pool.machine_type.clone(),
            cpu_millis: pool.cpu_millis,
            memory_mib: pool.memory_mib,
            speed_factor: pool.speed_factor,
            power_scale: pool.power_scale,
            ready: false,
        });
        self.alloc.push(Alloc::default());
        self.events.push(ClusterEvent::NodeAdded { node: id, at_s });
        id
    }

    /// Number of Ready nodes right now.
    pub fn ready_nodes(&self) -> usize {
        self.nodes.iter().filter(|n| n.ready).count()
    }

    /// Pods bound per category — §V.D's allocation analysis.
    pub fn pods_per_category(&self) -> HashMap<NodeCategory, u32> {
        let mut out = HashMap::new();
        for (&_pod, &(node, _)) in &self.bound {
            *out.entry(self.nodes[node].category).or_insert(0) += 1;
        }
        out
    }

    /// Cluster-wide requested-CPU utilization in `[0, 1]`.
    pub fn total_cpu_utilization(&self) -> f64 {
        let used: u64 = self.alloc.iter().map(|a| a.cpu_millis).sum();
        let cap: u64 = self.nodes.iter().map(|n| n.cpu_millis).sum();
        used as f64 / cap as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SchedulerKind;
    use crate::workload::WorkloadClass;

    fn state() -> ClusterState {
        ClusterState::from_config(&ClusterConfig::paper_default())
    }

    fn pod(id: PodId, class: WorkloadClass) -> Pod {
        Pod::new(id, class, SchedulerKind::Topsis, 0.0, 1)
    }

    #[test]
    fn from_config_materializes_table1() {
        let s = state();
        assert_eq!(s.nodes().len(), 7);
        assert_eq!(s.nodes()[0].category, NodeCategory::A);
        assert_eq!(s.free_cpu(0), 2000);
        assert_eq!(s.free_memory(3), 8192); // first B node
    }

    #[test]
    fn bind_release_roundtrip() {
        let mut s = state();
        let p = pod(1, WorkloadClass::Complex);
        s.bind(&p, 5, 0.0).unwrap(); // node 5 = the C node
        assert_eq!(s.free_cpu(5), 3000);
        assert_eq!(s.node_of(1), Some(5));
        assert_eq!(s.pods_on(5), 1);
        let n = s.release(1, 1.0).unwrap();
        assert_eq!(n, 5);
        assert_eq!(s.free_cpu(5), 4000);
        assert_eq!(s.node_of(1), None);
        assert_eq!(s.events().len(), 2);
    }

    #[test]
    fn overcommit_rejected() {
        let mut s = state();
        // Node 0 (A, 2000m): two complex pods (1000m each) fit; a third
        // complex does not.
        s.bind(&pod(1, WorkloadClass::Complex), 0, 0.0).unwrap();
        s.bind(&pod(2, WorkloadClass::Complex), 0, 0.0).unwrap();
        assert!(s.bind(&pod(3, WorkloadClass::Complex), 0, 0.0).is_err());
        // Memory can also be the binding constraint: node 0 has 4096 MiB;
        // after 2x2048 MiB nothing fits.
        assert!(!s.fits(0, ResourceRequests { cpu_millis: 0, memory_mib: 1 }));
    }

    #[test]
    fn double_bind_rejected() {
        let mut s = state();
        let p = pod(1, WorkloadClass::Light);
        s.bind(&p, 0, 0.0).unwrap();
        assert!(s.bind(&p, 1, 0.0).is_err());
    }

    #[test]
    fn not_ready_node_filtered() {
        let mut s = state();
        s.set_ready(0, false, 0.0);
        let feas = s.feasible_nodes(WorkloadClass::Light.requests());
        assert!(!feas.contains(&0));
        assert!(s.bind(&pod(1, WorkloadClass::Light), 0, 0.0).is_err());
        s.set_ready(0, true, 1.0);
        assert!(s.fits(0, WorkloadClass::Light.requests()));
    }

    #[test]
    fn release_unknown_pod_errors() {
        let mut s = state();
        assert!(s.release(99, 0.0).is_err());
    }

    #[test]
    fn add_node_appends_not_ready_then_joins() {
        let mut s = state();
        let pool = ClusterConfig::paper_default().pools[0].clone();
        let id = s.add_node(&pool, 5.0);
        assert_eq!(id, 7);
        assert_eq!(s.nodes().len(), 8);
        assert!(!s.node(id).ready);
        assert_eq!(s.ready_nodes(), 7);
        // NotReady: not schedulable yet.
        assert!(!s.fits(id, WorkloadClass::Light.requests()));
        s.set_ready(id, true, 10.0);
        assert_eq!(s.ready_nodes(), 8);
        assert!(s.fits(id, WorkloadClass::Light.requests()));
        assert_eq!(s.free_cpu(id), pool.cpu_millis);
        assert_eq!(s.free_memory(id), pool.memory_mib);
        assert!(matches!(
            s.events()[0],
            ClusterEvent::NodeAdded { node: 7, at_s: _ }
        ));
    }

    #[test]
    fn category_histogram() {
        let mut s = state();
        s.bind(&pod(1, WorkloadClass::Light), 0, 0.0).unwrap();
        s.bind(&pod(2, WorkloadClass::Light), 1, 0.0).unwrap();
        s.bind(&pod(3, WorkloadClass::Light), 5, 0.0).unwrap();
        let h = s.pods_per_category();
        assert_eq!(h[&NodeCategory::A], 2);
        assert_eq!(h[&NodeCategory::C], 1);
    }
}
