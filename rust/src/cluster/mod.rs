//! Kubernetes-like cluster-state substrate.
//!
//! The paper evaluates on a live GKE cluster; this module is the
//! substituted substrate (DESIGN.md §1): nodes with capacity/allocatable
//! accounting, pods with resource requests and a lifecycle, and a
//! cluster state that enforces the same invariants a kubelet +
//! API-server pair would (no overcommit of requests, bind/release
//! symmetry, NotReady exclusion).

mod node;
mod pod;
mod state;

pub use node::{Node, NodeCategory, NodeId};
pub use pod::{Pod, PodId, PodPhase, ResourceRequests};
pub use state::{ClusterEvent, ClusterState};
