//! Trace synthesis: fit generator parameters to a trace's marginals,
//! then stream an arbitrarily long synthetic trace from them.
//!
//! [`fit_marginals`] makes one streaming pass over any
//! [`WorkloadTrace`] and recovers a [`TraceSpec`] — arrival rate from
//! count over span, class mix from class frequencies, per-class
//! epochs from the per-class mode — plus the burst shape (mean
//! same-timestamp group size). [`SynthTrace`] then generates from a
//! spec entry by entry, replicating `ArrivalTrace::poisson` /
//! `bursty`'s RNG sequence *exactly*, so the synthetic stream is
//! bit-identical to the eager generator on the same spec and seed
//! while holding at most one burst in memory — this is what lets
//! `greenpod trace replay --full` push a million-pod trace through
//! the engine without materializing it.

use std::collections::BTreeMap;

use super::interface::WorkloadTrace;
use super::sample::class_index;
use crate::util::rng::Rng;
use crate::workload::{TraceEntry, TraceSpec};

/// Generator parameters recovered from a trace by [`fit_marginals`].
#[derive(Debug, Clone)]
pub struct TraceFit {
    /// Rate, duration, class mix and per-class epochs.
    pub spec: TraceSpec,
    /// Mean same-timestamp group size, rounded (1 = no bursts).
    pub burst_size: usize,
    /// Entries in the fitted trace.
    pub entries: usize,
}

/// Fit a [`TraceFit`] to a trace's marginals in one streaming pass.
///
/// Per-class epochs use the mode, smallest value winning ties
/// (BTreeMap iteration order + strictly-greater replacement), so the
/// fit is deterministic. Classes absent from the trace keep the
/// paper's default epochs and get probability zero.
pub fn fit_marginals(
    trace: &mut dyn WorkloadTrace,
) -> anyhow::Result<TraceFit> {
    let mut counts = [0usize; 3];
    let mut epoch_counts: [BTreeMap<u32, usize>; 3] = Default::default();
    let mut groups = 0usize;
    let mut last_at = -1.0;
    while let Some(e) = trace.next_entry()? {
        let i = class_index(e.class);
        counts[i] += 1;
        *epoch_counts[i].entry(e.epochs).or_insert(0) += 1;
        if e.at_s != last_at {
            groups += 1;
            last_at = e.at_s;
        }
    }
    let n = counts.iter().sum::<usize>();
    anyhow::ensure!(n > 0, "cannot fit an empty trace");
    anyhow::ensure!(
        last_at > 0.0,
        "cannot fit a rate: the trace spans zero seconds"
    );
    let mut epochs = [2u32, 4, 8];
    for (slot, modes) in epochs.iter_mut().zip(&epoch_counts) {
        let mut best: Option<(u32, usize)> = None;
        for (&value, &count) in modes {
            if best.is_none_or(|(_, c)| count > c) {
                best = Some((value, count));
            }
        }
        if let Some((value, _)) = best {
            *slot = value;
        }
    }
    Ok(TraceFit {
        spec: TraceSpec {
            rate_per_s: n as f64 / last_at,
            duration_s: last_at,
            p_light: counts[0] as f64 / n as f64,
            p_medium: counts[1] as f64 / n as f64,
            p_complex: counts[2] as f64 / n as f64,
            epochs,
        },
        // Round half up: a trace of b-sized bursts has n/groups = b
        // exactly, and mixed traces land on the nearest integer.
        burst_size: (n + groups / 2) / groups,
        entries: n,
    })
}

/// A streaming generator over a [`TraceSpec`]: the same entries as
/// `ArrivalTrace::poisson` / `bursty` (bit-identical — pinned by the
/// differential tests below), produced one at a time with at most one
/// burst buffered.
pub struct SynthTrace {
    spec: TraceSpec,
    burst: usize,
    rng: Rng,
    t: f64,
    pending: std::collections::VecDeque<TraceEntry>,
    peak: usize,
    done: bool,
}

impl SynthTrace {
    /// Streaming counterpart of `ArrivalTrace::poisson`.
    pub fn poisson(spec: TraceSpec, seed: u64) -> Self {
        // A 1-burst bursty stream *is* a Poisson stream: the gap mean
        // `1/rate` and the single class draw per arrival consume the
        // RNG identically.
        Self::bursty(spec, 1, seed)
    }

    /// Streaming counterpart of `ArrivalTrace::bursty`.
    pub fn bursty(spec: TraceSpec, burst_size: usize, seed: u64) -> Self {
        spec.assert_valid();
        Self {
            burst: burst_size.max(1),
            rng: Rng::seed_from_u64(seed),
            t: 0.0,
            pending: std::collections::VecDeque::new(),
            peak: 0,
            done: false,
            spec,
        }
    }

    /// Generate from a fitted trace's parameters.
    pub fn from_fit(fit: &TraceFit, seed: u64) -> Self {
        Self::bursty(fit.spec.clone(), fit.burst_size, seed)
    }
}

impl WorkloadTrace for SynthTrace {
    fn next_entry(&mut self) -> anyhow::Result<Option<TraceEntry>> {
        if self.pending.is_empty() && !self.done {
            self.t += self
                .rng
                .exponential(self.burst as f64 / self.spec.rate_per_s);
            if self.t > self.spec.duration_s {
                self.done = true;
            } else {
                for _ in 0..self.burst {
                    let (class, epochs) =
                        self.spec.sample_class(&mut self.rng);
                    self.pending.push_back(TraceEntry {
                        at_s: self.t,
                        class,
                        epochs,
                    });
                }
                self.peak = self.peak.max(self.pending.len());
            }
        }
        Ok(self.pending.pop_front())
    }

    fn peak_buffered(&self) -> usize {
        self.peak
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::InMemoryTrace;
    use crate::workload::{ArrivalTrace, WorkloadClass};

    fn drain(t: &mut dyn WorkloadTrace) -> Vec<TraceEntry> {
        let mut out = Vec::new();
        while let Some(e) = t.next_entry().unwrap() {
            out.push(e);
        }
        out
    }

    fn assert_bitwise_eq(a: &[TraceEntry], b: &[TraceEntry]) {
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(b) {
            assert_eq!(x.at_s.to_bits(), y.at_s.to_bits());
            assert_eq!(x.class, y.class);
            assert_eq!(x.epochs, y.epochs);
        }
    }

    #[test]
    fn synth_poisson_bit_identical_to_eager() {
        let spec = TraceSpec::surf_lisa(3.0, 300.0);
        let eager = ArrivalTrace::poisson(&spec, 42);
        let mut synth = SynthTrace::poisson(spec, 42);
        let streamed = drain(&mut synth);
        assert_bitwise_eq(&streamed, &eager.entries);
        assert_eq!(synth.peak_buffered(), 1);
    }

    #[test]
    fn synth_bursty_bit_identical_to_eager() {
        let spec = TraceSpec::surf_lisa(3.0, 300.0);
        let eager = ArrivalTrace::bursty(&spec, 5, 11);
        let mut synth = SynthTrace::bursty(spec, 5, 11);
        let streamed = drain(&mut synth);
        assert_bitwise_eq(&streamed, &eager.entries);
        // At most one burst resident at a time.
        assert_eq!(synth.peak_buffered(), 5);
    }

    #[test]
    fn fit_recovers_bursty_marginals() {
        let spec = TraceSpec::surf_lisa(4.0, 500.0);
        let trace = ArrivalTrace::bursty(&spec, 4, 13);
        let n = trace.entries.len();
        let fit = fit_marginals(&mut InMemoryTrace::new(trace.entries))
            .unwrap();
        assert_eq!(fit.entries, n);
        assert_eq!(fit.burst_size, 4);
        assert_eq!(fit.spec.epochs, [2, 4, 8]);
        assert!(
            (fit.spec.rate_per_s - 4.0).abs() < 0.8,
            "rate {}",
            fit.spec.rate_per_s
        );
        assert!(
            (fit.spec.p_light - 0.8668).abs() < 0.05,
            "p_light {}",
            fit.spec.p_light
        );
        // The fitted spec generates a valid stream of similar size.
        let resynth = drain(&mut SynthTrace::from_fit(&fit, 99));
        let m = resynth.len() as f64;
        assert!((m - n as f64).abs() < 0.35 * n as f64, "resynth {m} vs {n}");
    }

    #[test]
    fn fit_on_poisson_finds_no_bursts() {
        let spec = TraceSpec::surf_lisa(2.0, 400.0);
        let trace = ArrivalTrace::poisson(&spec, 3);
        let fit = fit_marginals(&mut InMemoryTrace::new(trace.entries))
            .unwrap();
        assert_eq!(fit.burst_size, 1);
    }

    #[test]
    fn fit_rejects_degenerate_traces() {
        let err = fit_marginals(&mut InMemoryTrace::new(Vec::new()))
            .unwrap_err()
            .to_string();
        assert!(err.contains("empty"), "{err}");
        // Every entry at t = 0 → no rate is recoverable.
        let flat = vec![
            TraceEntry { at_s: 0.0, class: WorkloadClass::Light, epochs: 2 };
            5
        ];
        let err = fit_marginals(&mut InMemoryTrace::new(flat))
            .unwrap_err()
            .to_string();
        assert!(err.contains("zero seconds"), "{err}");
    }

    #[test]
    fn fit_epochs_mode_prefers_majority_then_smallest() {
        let e = |at_s: f64, epochs: u32| TraceEntry {
            at_s,
            class: WorkloadClass::Light,
            epochs,
        };
        // 6 is the mode; 3 and 9 tie at two occurrences each.
        let trace =
            vec![e(1.0, 9), e(2.0, 6), e(3.0, 3), e(4.0, 6), e(5.0, 6)];
        let fit =
            fit_marginals(&mut InMemoryTrace::new(trace)).unwrap();
        assert_eq!(fit.spec.epochs[0], 6);
        // On a tie the smallest value wins (deterministic fit).
        let tied = vec![e(1.0, 9), e(2.0, 3), e(3.0, 9), e(4.0, 3)];
        let fit = fit_marginals(&mut InMemoryTrace::new(tied)).unwrap();
        assert_eq!(fit.spec.epochs[0], 3);
        // Absent classes keep defaults and probability zero.
        assert_eq!(fit.spec.epochs[1], 4);
        assert_eq!(fit.spec.p_medium, 0.0);
    }

    #[test]
    #[should_panic(expected = "rate must be positive")]
    fn synth_rejects_degenerate_spec() {
        let spec =
            TraceSpec { rate_per_s: 0.0, ..TraceSpec::surf_lisa(1.0, 10.0) };
        let _ = SynthTrace::poisson(spec, 1);
    }
}
