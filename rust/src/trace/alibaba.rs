//! Alibaba-cluster-trace-v2017-shaped column adapters.
//!
//! The 2017 trace ships workload and machine membership as separate
//! headerless CSV tables; these readers map each onto the repo's
//! trace interfaces:
//!
//! * `batch_task.csv` → [`AlibabaTaskReader`] ([`WorkloadTrace`]).
//!   Columns: `start_ts,end_ts,job_id,task_id,instance_num,status,
//!   plan_cpu,plan_mem`. `plan_cpu` is percent-of-one-core (50 = half
//!   a core), i.e. `plan_cpu × 10` millicores; `plan_mem` is
//!   normalized GB, i.e. `plan_mem × 1024` MiB. Rows with both plans
//!   snap to the paper class (Table II: Light 200 m/512 MiB, Medium
//!   500 m/1024 MiB, Complex 1000 m/2048 MiB) with the smallest
//!   summed relative distance across both dimensions; rows with an
//!   empty or absent `plan_mem` fall back to the cpu-only snap. Ties
//!   go to the smaller class either way. Work size is `end_ts -
//!   start_ts` rebased into epochs at 100 s per epoch; `instance_num`
//!   expands a task row into that many identical submissions.
//!   Timestamps are rebased to the first task's `start_ts`.
//! * `machine_events.csv` → [`AlibabaMachineReader`] ([`ClusterTrace`]).
//!   Columns: `timestamp,machine_id,event_type` with `add` = up and
//!   `remove`/`softerror`/`harderror` = down, rebased to the table's
//!   own first timestamp. Feed the result through
//!   [`machine_events_to_node_changes`] to target a simulated cluster.
//!
//! Rows with empty essential fields (the public trace has gaps) are
//! skipped and counted — check [`AlibabaTaskReader::skipped`] after
//! draining rather than treating the trace as complete.
//!
//! [`machine_events_to_node_changes`]: super::machine_events_to_node_changes

use std::collections::VecDeque;
use std::io::BufRead;

use super::interface::{ClusterTrace, MachineEvent, WorkloadTrace};
use crate::workload::{TraceEntry, WorkloadClass};

/// Seconds of traced runtime mapped to one simulated epoch.
const SECS_PER_EPOCH: f64 = 100.0;

/// Snap a millicore request to the nearest paper class (ties to the
/// smaller class — the energy-conservative choice). The cpu-only
/// fallback for rows whose `plan_mem` column is empty.
fn class_for_millis(millis: f64) -> WorkloadClass {
    let mut best = WorkloadClass::Light;
    let mut best_d = (millis - 200.0).abs();
    for (class, m) in
        [(WorkloadClass::Medium, 500.0), (WorkloadClass::Complex, 1000.0)]
    {
        let d = (millis - m).abs();
        if d < best_d {
            best = class;
            best_d = d;
        }
    }
    best
}

/// Snap a (millicore, MiB) request pair to the paper class with the
/// smallest summed relative distance across both dimensions (ties to
/// the smaller class). Relative — not absolute — distance keeps the
/// two axes comparable: 2048 MiB of Complex-shaped memory outweighs
/// 250 m of Light-shaped cpu instead of drowning in MiB magnitudes.
fn class_for_shape(millis: f64, mem_mib: f64) -> WorkloadClass {
    let mut best = WorkloadClass::Light;
    let mut best_d = f64::INFINITY;
    for class in [
        WorkloadClass::Light,
        WorkloadClass::Medium,
        WorkloadClass::Complex,
    ] {
        let r = class.requests();
        let cpu = r.cpu_millis as f64;
        let mem = r.memory_mib as f64;
        let d = (millis - cpu).abs() / cpu + (mem_mib - mem).abs() / mem;
        if d < best_d {
            best = class;
            best_d = d;
        }
    }
    best
}

fn field<'a>(
    fields: &[&'a str],
    idx: usize,
    name: &str,
) -> anyhow::Result<&'a str> {
    fields.get(idx).copied().ok_or_else(|| {
        anyhow::anyhow!("missing column {idx} ({name})")
    })
}

/// Streaming reader over an Alibaba `batch_task` table.
pub struct AlibabaTaskReader<R: BufRead> {
    reader: R,
    line: String,
    line_no: usize,
    /// Trace epoch: the first task's `start_ts`.
    base_ts: Option<f64>,
    last_at: f64,
    /// Expanded instances of the current task row.
    pending: VecDeque<TraceEntry>,
    peak: usize,
    skipped: usize,
    done: bool,
}

impl<R: BufRead> AlibabaTaskReader<R> {
    pub fn new(reader: R) -> Self {
        Self {
            reader,
            line: String::new(),
            line_no: 0,
            base_ts: None,
            last_at: 0.0,
            pending: VecDeque::new(),
            peak: 0,
            skipped: 0,
            done: false,
        }
    }

    /// Rows dropped for empty essential fields so far.
    pub fn skipped(&self) -> usize {
        self.skipped
    }

    /// Parse one task row into its expanded instances, or `None` if
    /// the row has gaps and should be skipped.
    fn parse_row(&mut self, row: &str) -> anyhow::Result<Option<()>> {
        let fields: Vec<&str> = row.split(',').map(str::trim).collect();
        let start = field(&fields, 0, "start_ts")?;
        let end = field(&fields, 1, "end_ts")?;
        let instances = field(&fields, 4, "instance_num")?;
        let plan_cpu = field(&fields, 6, "plan_cpu")?;
        if start.is_empty()
            || end.is_empty()
            || instances.is_empty()
            || plan_cpu.is_empty()
        {
            self.skipped += 1;
            return Ok(None);
        }
        let start: f64 = start
            .parse()
            .map_err(|e| anyhow::anyhow!("bad start_ts `{start}`: {e}"))?;
        let end: f64 = end
            .parse()
            .map_err(|e| anyhow::anyhow!("bad end_ts `{end}`: {e}"))?;
        let instances: usize = instances.parse().map_err(|e| {
            anyhow::anyhow!("bad instance_num `{instances}`: {e}")
        })?;
        let plan_cpu: f64 = plan_cpu.parse().map_err(|e| {
            anyhow::anyhow!("bad plan_cpu `{plan_cpu}`: {e}")
        })?;
        anyhow::ensure!(
            start.is_finite() && end.is_finite() && end >= start,
            "task runs backwards: start_ts {start}, end_ts {end}"
        );
        anyhow::ensure!(
            plan_cpu.is_finite() && plan_cpu >= 0.0,
            "`plan_cpu` must be finite and non-negative, got {plan_cpu}"
        );
        let base = *self.base_ts.get_or_insert(start);
        let at_s = start - base;
        anyhow::ensure!(
            at_s >= 0.0 && at_s >= self.last_at,
            "start_ts {start} is out of order — sort the task table by \
             start_ts first"
        );
        let epochs_f = ((end - start) / SECS_PER_EPOCH).round().max(1.0);
        anyhow::ensure!(
            epochs_f <= f64::from(u32::MAX),
            "task duration {} s does not fit the epoch budget",
            end - start
        );
        // Lossless by the bound just checked.
        let epochs = epochs_f as u32;
        // `plan_mem` (normalized GB) refines the class when present;
        // the public trace leaves it empty on many rows.
        let plan_mem = fields.get(7).copied().unwrap_or("");
        let class = if plan_mem.is_empty() {
            class_for_millis(plan_cpu * 10.0)
        } else {
            let plan_mem: f64 = plan_mem.parse().map_err(|e| {
                anyhow::anyhow!("bad plan_mem `{plan_mem}`: {e}")
            })?;
            anyhow::ensure!(
                plan_mem.is_finite() && plan_mem >= 0.0,
                "`plan_mem` must be finite and non-negative, got \
                 {plan_mem}"
            );
            class_for_shape(plan_cpu * 10.0, plan_mem * 1024.0)
        };
        self.last_at = at_s;
        for _ in 0..instances {
            self.pending.push_back(TraceEntry { at_s, class, epochs });
        }
        self.peak = self.peak.max(self.pending.len());
        Ok(Some(()))
    }
}

impl<R: BufRead> WorkloadTrace for AlibabaTaskReader<R> {
    fn next_entry(&mut self) -> anyhow::Result<Option<TraceEntry>> {
        while self.pending.is_empty() && !self.done {
            self.line.clear();
            let n = self.reader.read_line(&mut self.line).map_err(|e| {
                anyhow::anyhow!(
                    "task table line {}: read error: {e}",
                    self.line_no + 1
                )
            })?;
            if n == 0 {
                self.done = true;
                break;
            }
            self.line_no += 1;
            let row = self.line.trim().to_string();
            if row.is_empty() || row.starts_with('#') {
                continue;
            }
            self.parse_row(&row).map_err(|e| {
                anyhow::anyhow!("task table line {}: {e}", self.line_no)
            })?;
        }
        Ok(self.pending.pop_front())
    }

    fn peak_buffered(&self) -> usize {
        self.peak
    }
}

/// Streaming reader over an Alibaba `machine_events` table.
pub struct AlibabaMachineReader<R: BufRead> {
    reader: R,
    line: String,
    line_no: usize,
    base_ts: Option<f64>,
    done: bool,
}

impl<R: BufRead> AlibabaMachineReader<R> {
    pub fn new(reader: R) -> Self {
        Self {
            reader,
            line: String::new(),
            line_no: 0,
            base_ts: None,
            done: false,
        }
    }

    fn parse_row(&mut self, row: &str) -> anyhow::Result<MachineEvent> {
        let fields: Vec<&str> = row.split(',').map(str::trim).collect();
        let ts = field(&fields, 0, "timestamp")?;
        let machine = field(&fields, 1, "machine_id")?;
        let event = field(&fields, 2, "event_type")?;
        let ts: f64 = ts
            .parse()
            .map_err(|e| anyhow::anyhow!("bad timestamp `{ts}`: {e}"))?;
        anyhow::ensure!(ts.is_finite(), "non-finite timestamp {ts}");
        anyhow::ensure!(!machine.is_empty(), "empty machine_id");
        let up = match event.to_ascii_lowercase().as_str() {
            "add" => true,
            "remove" | "softerror" | "harderror" => false,
            other => anyhow::bail!("unknown event_type `{other}`"),
        };
        let base = *self.base_ts.get_or_insert(ts);
        let at_s = ts - base;
        anyhow::ensure!(
            at_s >= 0.0,
            "timestamp {ts} is out of order — sort the event table first"
        );
        Ok(MachineEvent { at_s, machine: machine.to_string(), up })
    }
}

impl<R: BufRead> ClusterTrace for AlibabaMachineReader<R> {
    fn next_event(&mut self) -> anyhow::Result<Option<MachineEvent>> {
        while !self.done {
            self.line.clear();
            let n = self.reader.read_line(&mut self.line).map_err(|e| {
                anyhow::anyhow!(
                    "machine table line {}: read error: {e}",
                    self.line_no + 1
                )
            })?;
            if n == 0 {
                self.done = true;
                break;
            }
            self.line_no += 1;
            let row = self.line.trim().to_string();
            if row.is_empty() || row.starts_with('#') {
                continue;
            }
            return self
                .parse_row(&row)
                .map(Some)
                .map_err(|e| {
                    anyhow::anyhow!(
                        "machine table line {}: {e}",
                        self.line_no
                    )
                });
        }
        Ok(None)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simulation::NodeChange;
    use crate::trace::machine_events_to_node_changes;

    fn tasks(text: &str) -> AlibabaTaskReader<&[u8]> {
        AlibabaTaskReader::new(text.as_bytes())
    }

    fn drain(r: &mut dyn WorkloadTrace) -> Vec<TraceEntry> {
        let mut out = Vec::new();
        while let Some(e) = r.next_entry().unwrap() {
            out.push(e);
        }
        out
    }

    #[test]
    fn task_rows_map_to_classes_epochs_and_rebased_times() {
        // start,end,job,task,instances,status,plan_cpu,plan_mem
        let text = "\
100,300,j1,t1,1,Terminated,25,0.5
160,1260,j1,t2,2,Terminated,55,1.0
200,250,j2,t1,1,Terminated,100,2.0
";
        let entries = drain(&mut tasks(text));
        // Row 2 expands to two instances.
        assert_eq!(entries.len(), 4);
        // Rebased to the first start_ts (100).
        assert_eq!(entries[0].at_s, 0.0);
        assert_eq!(entries[1].at_s, 60.0);
        assert_eq!(entries[2].at_s, 60.0);
        assert_eq!(entries[3].at_s, 100.0);
        // 25 → 250 m → Light; 55 → 550 m → Medium; 100 → 1000 m → Complex.
        assert_eq!(entries[0].class, WorkloadClass::Light);
        assert_eq!(entries[1].class, WorkloadClass::Medium);
        assert_eq!(entries[3].class, WorkloadClass::Complex);
        // 200 s → 2 epochs; 1100 s → 11; 50 s rounds to 1 (floor at 1).
        assert_eq!(entries[0].epochs, 2);
        assert_eq!(entries[1].epochs, 11);
        assert_eq!(entries[3].epochs, 1);
    }

    #[test]
    fn class_snap_ties_go_to_the_smaller_class() {
        // 350 m is equidistant from 200 and 500; 750 m from 500 and 1000.
        assert_eq!(class_for_millis(350.0), WorkloadClass::Light);
        assert_eq!(class_for_millis(750.0), WorkloadClass::Medium);
        assert_eq!(class_for_millis(0.0), WorkloadClass::Light);
        assert_eq!(class_for_millis(5000.0), WorkloadClass::Complex);
    }

    #[test]
    fn mixed_shape_rows_weigh_memory_too() {
        // plan_cpu 25 (250 m) looks Light on cpu alone, but plan_mem
        // 2.0 (2048 MiB) is Complex-shaped memory: the joint relative
        // distance picks Complex (0.75) over Medium (1.5) and Light
        // (3.25).
        let text = "100,300,j1,t1,1,Terminated,25,2.0\n";
        let entries = drain(&mut tasks(text));
        assert_eq!(entries[0].class, WorkloadClass::Complex);
        // An empty plan_mem column falls back to the cpu-only snap…
        let text = "100,300,j1,t1,1,Terminated,25,\n";
        let entries = drain(&mut tasks(text));
        assert_eq!(entries[0].class, WorkloadClass::Light);
        // …and so does a short row with no plan_mem column at all.
        let text = "100,300,j1,t1,1,Terminated,25\n";
        let entries = drain(&mut tasks(text));
        assert_eq!(entries[0].class, WorkloadClass::Light);
        // On-spec shapes land exactly; the degenerate all-tie point
        // resolves to the smallest class.
        assert_eq!(class_for_shape(550.0, 1024.0), WorkloadClass::Medium);
        assert_eq!(class_for_shape(0.0, 0.0), WorkloadClass::Light);
        // A malformed plan_mem is an error, not a silent fallback.
        let err = tasks("100,300,j1,t1,1,T,25,lots\n")
            .next_entry()
            .unwrap_err()
            .to_string();
        assert!(err.contains("bad plan_mem"), "{err}");
    }

    #[test]
    fn gappy_rows_are_skipped_and_counted() {
        let text = "\
100,300,j1,t1,1,Terminated,25,0.5
110,,j1,t2,1,Waiting,,0.5
120,280,j1,t3,1,Terminated,30,0.5
";
        let mut r = tasks(text);
        let entries = drain(&mut r);
        assert_eq!(entries.len(), 2);
        assert_eq!(r.skipped(), 1);
    }

    #[test]
    fn malformed_task_rows_carry_line_numbers() {
        let err = tasks("100,300,j1,t1,1,T,abc,0.5\n")
            .next_entry()
            .unwrap_err()
            .to_string();
        assert!(err.contains("task table line 1"), "{err}");
        assert!(err.contains("bad plan_cpu"), "{err}");
        // Out of order after rebase.
        let text = "200,300,j1,t1,1,T,25,0.5\n100,300,j1,t2,1,T,25,0.5\n";
        let mut r = tasks(text);
        assert!(r.next_entry().is_ok());
        let err = r.next_entry().unwrap_err().to_string();
        assert!(err.contains("line 2"), "{err}");
        assert!(err.contains("out of order"), "{err}");
        // Backwards task.
        let err = tasks("100,50,j1,t1,1,T,25,0.5\n")
            .next_entry()
            .unwrap_err()
            .to_string();
        assert!(err.contains("runs backwards"), "{err}");
    }

    #[test]
    fn machine_events_parse_rebase_and_feed_node_changes() {
        let text = "\
5000,m_1,add
5000,m_2,add
5100,m_1,softerror
5200,m_1,add
5300,m_2,remove
5400,m_3,harderror
";
        let mut r = AlibabaMachineReader::new(text.as_bytes());
        let changes = machine_events_to_node_changes(&mut r, 2).unwrap();
        // m_1/m_2 baseline adds emit nothing; m_3 is beyond node_count.
        assert_eq!(
            changes,
            vec![
                NodeChange { at_s: 100.0, node: 0, up: false },
                NodeChange { at_s: 200.0, node: 0, up: true },
                NodeChange { at_s: 300.0, node: 1, up: false },
            ]
        );
    }

    #[test]
    fn unknown_machine_event_rejected() {
        let mut r = AlibabaMachineReader::new("5000,m_1,explode\n".as_bytes());
        let err = r.next_event().unwrap_err().to_string();
        assert!(err.contains("machine table line 1"), "{err}");
        assert!(err.contains("unknown event_type"), "{err}");
    }
}
