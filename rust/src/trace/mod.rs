//! Trace replay (DESIGN.md §"Trace replay"): a generic trace
//! interface decoupling *workload* traces (pod submissions) from
//! *cluster* traces (machine-membership events), with streaming
//! ingestion so multi-million-pod traces replay through the federation
//! engine without ever materializing a pod vector.
//!
//! The pieces, bottom-up:
//!
//! * [`WorkloadTrace`] / [`ClusterTrace`] — the pull-based interfaces.
//!   A workload trace yields [`TraceEntry`]s in nondecreasing `at_s`
//!   order; a cluster trace yields [`MachineEvent`]s. Every
//!   implementation reports its buffering high-water mark
//!   ([`WorkloadTrace::peak_buffered`]) — what the bounded-memory
//!   property asserts against the chunk size.
//! * [`ChunkedTraceReader`] — the streaming ingester: JSONL or CSV,
//!   pulled through a bounded chunk buffer, every malformed /
//!   non-finite / out-of-order line rejected with its line number
//!   (the same contract as `ArrivalTrace::from_jsonl`, minus the
//!   materialized vector).
//! * [`StreamArrivals`] — adapts any workload trace into the engine's
//!   [`ArrivalSource`], assigning sequential pod ids and per-index
//!   scheduler ownership exactly like `ArrivalTrace::to_pods` /
//!   `to_pods_round_robin`, so streaming replay is bit-identical to
//!   the eager path on the same entries.
//! * [`AlibabaTaskReader`] / [`AlibabaMachineReader`] — a column
//!   adapter for Alibaba-cluster-trace-v2017-shaped CSVs (batch task
//!   table + machine event table).
//! * [`DownSampler`] — seeded deterministic per-class k-slicing, with
//!   [`crate::config::ClusterConfig::downsampled`] as the
//!   capacity-side companion.
//! * [`fit_marginals`] / [`SynthTrace`] — trace synthesis: fit a
//!   [`TraceSpec`] (rate, class mix, burst shape) to a trace's
//!   marginals in one streaming pass, then generate an arbitrarily
//!   long synthetic trace bit-identical to `ArrivalTrace::poisson` /
//!   `bursty` on the same spec and seed — but streamed, entry by
//!   entry.
//!
//! [`ArrivalSource`]: crate::federation::ArrivalSource
//! [`TraceEntry`]: crate::workload::TraceEntry
//! [`TraceSpec`]: crate::workload::TraceSpec

mod alibaba;
mod interface;
mod sample;
mod stream;
mod synth;

pub use alibaba::{AlibabaMachineReader, AlibabaTaskReader};
pub use interface::{
    machine_events_to_node_changes, ClusterTrace, InMemoryTrace,
    MachineEvent, WorkloadTrace,
};
pub use sample::DownSampler;
pub use stream::{ChunkedTraceReader, StreamArrivals, TraceFormat, TraceOwnership};
pub use synth::{fit_marginals, SynthTrace, TraceFit};
