//! Streaming chunked ingestion: JSONL/CSV → entry stream → engine
//! events, without ever materializing the trace.
//!
//! [`ChunkedTraceReader`] pulls lines through a bounded chunk buffer
//! (the bounded-memory property asserts `peak_buffered() <= chunk`),
//! applying the exact validation contract of
//! `ArrivalTrace::from_jsonl`: malformed, non-finite, negative and
//! out-of-order timestamps are rejected with their line number at the
//! entry they occur on. [`StreamArrivals`] then adapts any
//! [`WorkloadTrace`] into the engine's [`ArrivalSource`], assigning
//! sequential pod ids and per-index ownership exactly like
//! `ArrivalTrace::to_pods` / `to_pods_round_robin` — which is what
//! makes streaming replay bit-identical to the eager path.
//!
//! [`ArrivalSource`]: crate::federation::ArrivalSource

use std::collections::VecDeque;
use std::io::BufRead;
use std::str::FromStr;

use super::interface::WorkloadTrace;
use crate::cluster::Pod;
use crate::config::SchedulerKind;
use crate::federation::ArrivalSource;
use crate::util::json::Json;
use crate::workload::TraceEntry;

/// On-disk trace encodings the chunked reader understands.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TraceFormat {
    /// One `TraceEntry` JSON object per line (`ArrivalTrace::to_jsonl`).
    Jsonl,
    /// Comma-separated with a `at_s,class[,epochs]` header line.
    Csv,
}

impl TraceFormat {
    /// Infer the format from a file extension (`.jsonl` / `.csv`).
    pub fn from_path(path: &str) -> anyhow::Result<Self> {
        match path.rsplit('.').next() {
            Some("jsonl") => Ok(Self::Jsonl),
            Some("csv") => Ok(Self::Csv),
            _ => anyhow::bail!(
                "cannot infer trace format from `{path}` — expected a \
                 .jsonl or .csv extension (or pass --format)"
            ),
        }
    }
}

impl FromStr for TraceFormat {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> anyhow::Result<Self> {
        match s {
            "jsonl" => Ok(Self::Jsonl),
            "csv" => Ok(Self::Csv),
            other => {
                anyhow::bail!("unknown trace format `{other}` (jsonl|csv)")
            }
        }
    }
}

/// A streaming trace reader: pulls `chunk` lines at a time from any
/// [`BufRead`], so a multi-million-entry trace replays with at most
/// `chunk` entries resident.
pub struct ChunkedTraceReader<R: BufRead> {
    reader: R,
    format: TraceFormat,
    chunk: usize,
    buf: VecDeque<TraceEntry>,
    line: String,
    line_no: usize,
    last_at: f64,
    peak: usize,
    header_seen: bool,
    done: bool,
}

impl ChunkedTraceReader<std::io::BufReader<std::fs::File>> {
    /// Open `path`, inferring the format from its extension.
    pub fn open(path: &str, chunk: usize) -> anyhow::Result<Self> {
        let format = TraceFormat::from_path(path)?;
        let file = std::fs::File::open(path)
            .map_err(|e| anyhow::anyhow!("open trace `{path}`: {e}"))?;
        Self::new(std::io::BufReader::new(file), format, chunk)
    }
}

impl<R: BufRead> ChunkedTraceReader<R> {
    pub fn new(reader: R, format: TraceFormat, chunk: usize) -> anyhow::Result<Self> {
        anyhow::ensure!(chunk > 0, "trace chunk size must be positive");
        Ok(Self {
            reader,
            format,
            chunk,
            buf: VecDeque::new(),
            line: String::new(),
            line_no: 0,
            last_at: 0.0,
            peak: 0,
            header_seen: false,
            done: false,
        })
    }

    /// Pull lines until the chunk buffer is full or the input ends.
    fn refill(&mut self) -> anyhow::Result<()> {
        while self.buf.len() < self.chunk && !self.done {
            self.line.clear();
            let n = self.reader.read_line(&mut self.line).map_err(|e| {
                anyhow::anyhow!("trace line {}: read error: {e}", self.line_no + 1)
            })?;
            if n == 0 {
                self.done = true;
                break;
            }
            self.line_no += 1;
            let line = self.line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            if self.format == TraceFormat::Csv && !self.header_seen {
                self.header_seen = true;
                Self::check_csv_header(line)
                    .map_err(|e| anyhow::anyhow!("trace line {}: {e}", self.line_no))?;
                continue;
            }
            let entry = match self.format {
                TraceFormat::Jsonl => {
                    Json::parse(line).and_then(|v| TraceEntry::from_json(&v))
                }
                TraceFormat::Csv => Self::parse_csv_row(line),
            }
            .map_err(|e| anyhow::anyhow!("trace line {}: {e}", self.line_no))?;
            anyhow::ensure!(
                entry.at_s >= self.last_at,
                "trace line {}: at_s {} is out of order (previous entry \
                 at {}) — sort the trace by at_s first",
                self.line_no,
                entry.at_s,
                self.last_at
            );
            self.last_at = entry.at_s;
            self.buf.push_back(entry);
            self.peak = self.peak.max(self.buf.len());
        }
        Ok(())
    }

    fn check_csv_header(line: &str) -> anyhow::Result<()> {
        let cols: Vec<&str> = line.split(',').map(str::trim).collect();
        anyhow::ensure!(
            cols == ["at_s", "class"] || cols == ["at_s", "class", "epochs"],
            "bad CSV header `{line}` — expected `at_s,class[,epochs]`"
        );
        Ok(())
    }

    fn parse_csv_row(line: &str) -> anyhow::Result<TraceEntry> {
        let fields: Vec<&str> = line.split(',').map(str::trim).collect();
        anyhow::ensure!(
            fields.len() == 2 || fields.len() == 3,
            "expected 2 or 3 CSV fields, got {}",
            fields.len()
        );
        let at_s: f64 = fields[0]
            .parse()
            .map_err(|e| anyhow::anyhow!("bad at_s `{}`: {e}", fields[0]))?;
        anyhow::ensure!(
            at_s.is_finite() && at_s >= 0.0,
            "`at_s` must be finite and non-negative, got {at_s}"
        );
        let epochs = match fields.get(2) {
            None => 2,
            Some(f) => f
                .parse::<u32>()
                .map_err(|e| anyhow::anyhow!("bad epochs `{f}`: {e}"))?,
        };
        Ok(TraceEntry { at_s, class: fields[1].parse()?, epochs })
    }
}

impl<R: BufRead> WorkloadTrace for ChunkedTraceReader<R> {
    fn next_entry(&mut self) -> anyhow::Result<Option<TraceEntry>> {
        if self.buf.is_empty() {
            self.refill()?;
        }
        Ok(self.buf.pop_front())
    }

    fn peak_buffered(&self) -> usize {
        self.peak
    }
}

/// How streamed pods are assigned to schedulers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TraceOwnership {
    /// Every pod owned by one scheduler (`ArrivalTrace::to_pods`).
    Fixed(SchedulerKind),
    /// Even index → TOPSIS, odd → default
    /// (`ArrivalTrace::to_pods_round_robin` and the `serve` split).
    RoundRobin,
}

/// Adapts a [`WorkloadTrace`] into the engine's [`ArrivalSource`],
/// assigning sequential ids from 0 — the exact pods the eager
/// materializers build, one at a time.
///
/// [`ArrivalSource`]: crate::federation::ArrivalSource
pub struct StreamArrivals<W: WorkloadTrace> {
    trace: W,
    ownership: TraceOwnership,
    next_id: u64,
    pending: Option<Pod>,
}

impl<W: WorkloadTrace> StreamArrivals<W> {
    pub fn new(trace: W, ownership: TraceOwnership) -> Self {
        Self { trace, ownership, next_id: 0, pending: None }
    }

    /// Buffering high-water mark of the underlying trace.
    pub fn peak_buffered(&self) -> usize {
        self.trace.peak_buffered()
    }

    fn fill(&mut self) -> anyhow::Result<()> {
        if self.pending.is_none() {
            if let Some(e) = self.trace.next_entry()? {
                let kind = match self.ownership {
                    TraceOwnership::Fixed(k) => k,
                    TraceOwnership::RoundRobin => {
                        if self.next_id % 2 == 0 {
                            SchedulerKind::Topsis
                        } else {
                            SchedulerKind::DefaultK8s
                        }
                    }
                };
                self.pending = Some(Pod::new(
                    self.next_id,
                    e.class,
                    kind,
                    e.at_s,
                    e.epochs,
                ));
                self.next_id += 1;
            }
        }
        Ok(())
    }
}

impl<W: WorkloadTrace> ArrivalSource for StreamArrivals<W> {
    fn peek_at(&mut self) -> anyhow::Result<Option<f64>> {
        self.fill()?;
        Ok(self.pending.as_ref().map(|p| p.arrival_s))
    }

    fn next_pod(&mut self) -> anyhow::Result<Option<Pod>> {
        self.fill()?;
        Ok(self.pending.take())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{ArrivalTrace, TraceSpec};

    fn reader(
        text: &str,
        format: TraceFormat,
        chunk: usize,
    ) -> ChunkedTraceReader<&[u8]> {
        ChunkedTraceReader::new(text.as_bytes(), format, chunk).unwrap()
    }

    fn drain(
        r: &mut dyn WorkloadTrace,
    ) -> anyhow::Result<Vec<TraceEntry>> {
        let mut out = Vec::new();
        while let Some(e) = r.next_entry()? {
            out.push(e);
        }
        Ok(out)
    }

    #[test]
    fn jsonl_stream_matches_eager_parse_with_bounded_buffer() {
        let spec = TraceSpec::surf_lisa(4.0, 200.0);
        let trace = ArrivalTrace::poisson(&spec, 17);
        let text = trace.to_jsonl();
        let mut r = reader(&text, TraceFormat::Jsonl, 64);
        let streamed = drain(&mut r).unwrap();
        assert_eq!(streamed.len(), trace.entries.len());
        for (s, e) in streamed.iter().zip(&trace.entries) {
            assert_eq!(s.at_s, e.at_s);
            assert_eq!(s.class, e.class);
            assert_eq!(s.epochs, e.epochs);
        }
        assert!(r.peak_buffered() <= 64, "peak {}", r.peak_buffered());
        assert!(trace.entries.len() > 64, "fixture too small to exercise chunking");
    }

    #[test]
    fn csv_parses_with_and_without_epochs() {
        let text = "at_s,class,epochs\n0.5,light,3\n1.0,complex,8\n";
        let mut r = reader(text, TraceFormat::Csv, 16);
        let entries = drain(&mut r).unwrap();
        assert_eq!(entries.len(), 2);
        assert_eq!(entries[0].epochs, 3);
        let text = "at_s,class\n# comment\n0.5,medium\n";
        let mut r = reader(text, TraceFormat::Csv, 16);
        let entries = drain(&mut r).unwrap();
        assert_eq!(entries.len(), 1);
        assert_eq!(entries[0].epochs, 2); // default
    }

    #[test]
    fn malformed_lines_carry_line_numbers() {
        // Bad JSON on line 2.
        let text = "{\"at_s\":0.5,\"class\":\"light\"}\nnot json\n";
        let mut r = reader(text, TraceFormat::Jsonl, 8);
        assert!(r.next_entry().is_ok());
        let err = r.next_entry().unwrap_err().to_string();
        assert!(err.contains("trace line 2"), "{err}");
        // Out-of-order across a chunk boundary (chunk = 1).
        let text = "{\"at_s\":2.0,\"class\":\"light\"}\n\
                    {\"at_s\":1.0,\"class\":\"light\"}\n";
        let mut r = reader(text, TraceFormat::Jsonl, 1);
        assert!(r.next_entry().is_ok());
        let err = r.next_entry().unwrap_err().to_string();
        assert!(err.contains("out of order"), "{err}");
        // Bad CSV header.
        let mut r = reader("time,kind\n0.5,light\n", TraceFormat::Csv, 8);
        let err = r.next_entry().unwrap_err().to_string();
        assert!(err.contains("bad CSV header"), "{err}");
        // Negative / non-finite CSV timestamps.
        let mut r = reader("at_s,class\n-1.0,light\n", TraceFormat::Csv, 8);
        assert!(r.next_entry().is_err());
        let mut r = reader("at_s,class\ninf,light\n", TraceFormat::Csv, 8);
        assert!(r.next_entry().is_err());
        // CSV epochs overflow is a parse error, not a truncation.
        let big = format!("at_s,class,epochs\n0.5,light,{}\n", (1u64 << 32) + 7);
        let mut r = reader(&big, TraceFormat::Csv, 8);
        let err = r.next_entry().unwrap_err().to_string();
        assert!(err.contains("bad epochs"), "{err}");
    }

    #[test]
    fn stream_arrivals_matches_eager_materializers() {
        let spec = TraceSpec::surf_lisa(2.0, 50.0);
        let trace = ArrivalTrace::poisson(&spec, 9);
        let eager = trace.to_pods_round_robin();
        let mut src = StreamArrivals::new(
            super::super::InMemoryTrace::new(trace.entries.clone()),
            TraceOwnership::RoundRobin,
        );
        for want in &eager {
            assert_eq!(src.peek_at().unwrap(), Some(want.arrival_s));
            let got = src.next_pod().unwrap().unwrap();
            assert_eq!(got.id, want.id);
            assert_eq!(got.class, want.class);
            assert_eq!(got.scheduler, want.scheduler);
            assert_eq!(got.arrival_s, want.arrival_s);
            assert_eq!(got.epochs, want.epochs);
        }
        assert!(src.next_pod().unwrap().is_none());
        // Fixed ownership mirrors to_pods.
        let eager = trace.to_pods(SchedulerKind::Topsis);
        let mut src = StreamArrivals::new(
            super::super::InMemoryTrace::new(trace.entries.clone()),
            TraceOwnership::Fixed(SchedulerKind::Topsis),
        );
        for want in &eager {
            let got = src.next_pod().unwrap().unwrap();
            assert_eq!((got.id, got.scheduler), (want.id, want.scheduler));
        }
    }

    #[test]
    fn crlf_traces_parse_identically_to_lf() {
        // Windows-edited traces reach the reader with `\r\n` line
        // endings; `refill`'s trim must make them byte-identical to
        // their LF twins in both formats.
        let cases = [
            (
                TraceFormat::Csv,
                "at_s,class,epochs\n0.5,light,3\n1.0,complex,8\n",
            ),
            (
                TraceFormat::Jsonl,
                "{\"at_s\":0.5,\"class\":\"light\"}\n\
                 {\"at_s\":1.5,\"class\":\"medium\"}\n",
            ),
        ];
        for (format, lf) in cases {
            let crlf = lf.replace('\n', "\r\n");
            let mut a = reader(lf, format, 1);
            let mut b = reader(&crlf, format, 1);
            let ea = drain(&mut a).unwrap();
            let eb = drain(&mut b).unwrap();
            assert_eq!(ea.len(), 2, "{format:?}");
            assert_eq!(ea.len(), eb.len(), "{format:?}");
            for (x, y) in ea.iter().zip(&eb) {
                assert_eq!(
                    (x.at_s, x.class, x.epochs),
                    (y.at_s, y.class, y.epochs),
                    "{format:?}"
                );
            }
        }
    }

    #[test]
    fn format_inference() {
        assert_eq!(TraceFormat::from_path("a/b.jsonl").unwrap(), TraceFormat::Jsonl);
        assert_eq!(TraceFormat::from_path("t.csv").unwrap(), TraceFormat::Csv);
        assert!(TraceFormat::from_path("t.txt").is_err());
        assert_eq!("csv".parse::<TraceFormat>().unwrap(), TraceFormat::Csv);
        assert!("tsv".parse::<TraceFormat>().is_err());
    }
}
