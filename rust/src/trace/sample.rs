//! Seeded deterministic down-sampling: keep every k-th pod *per
//! class*, so the class mix of the slice matches the full trace even
//! when one class is rare (a global every-k-th slice of an 86/9/4 mix
//! can easily miss the 4% class entirely on short traces).
//!
//! The phase each class's k-cycle starts at is drawn from a seeded
//! [`Rng`], so different seeds select different (but internally
//! consistent) slices and the same seed always selects the same one.
//! [`crate::config::ClusterConfig::downsampled`] is the capacity-side
//! companion: replaying every k-th pod against 1/k of the machines
//! keeps the offered load per node comparable.

use super::interface::WorkloadTrace;
use crate::util::rng::Rng;
use crate::workload::{TraceEntry, WorkloadClass};

pub(super) fn class_index(class: WorkloadClass) -> usize {
    match class {
        WorkloadClass::Light => 0,
        WorkloadClass::Medium => 1,
        WorkloadClass::Complex => 2,
    }
}

/// A filtering adapter over any [`WorkloadTrace`]: passes through the
/// entries whose per-class sequence number falls on the seeded phase
/// of a `keep_every` cycle.
pub struct DownSampler<W: WorkloadTrace> {
    inner: W,
    keep_every: usize,
    /// Per-class phase in `0..keep_every`, drawn in Light/Medium/
    /// Complex order from the seed.
    offsets: [usize; 3],
    /// Per-class entries seen so far (kept or not).
    counts: [usize; 3],
}

impl<W: WorkloadTrace> DownSampler<W> {
    pub fn new(inner: W, keep_every: usize, seed: u64) -> Self {
        assert!(keep_every > 0, "keep_every must be at least 1");
        let mut rng = Rng::seed_from_u64(seed);
        let offsets = [
            rng.below(keep_every),
            rng.below(keep_every),
            rng.below(keep_every),
        ];
        Self { inner, keep_every, offsets, counts: [0; 3] }
    }
}

impl<W: WorkloadTrace> WorkloadTrace for DownSampler<W> {
    fn next_entry(&mut self) -> anyhow::Result<Option<TraceEntry>> {
        while let Some(e) = self.inner.next_entry()? {
            let i = class_index(e.class);
            let keep = self.counts[i] % self.keep_every == self.offsets[i];
            self.counts[i] += 1;
            if keep {
                return Ok(Some(e));
            }
        }
        Ok(None)
    }

    fn peak_buffered(&self) -> usize {
        self.inner.peak_buffered()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::InMemoryTrace;
    use crate::workload::{ArrivalTrace, TraceSpec};

    fn sampled(keep_every: usize, seed: u64) -> Vec<TraceEntry> {
        let spec = TraceSpec::surf_lisa(5.0, 400.0);
        let trace = ArrivalTrace::poisson(&spec, 23);
        let mut s = DownSampler::new(
            InMemoryTrace::new(trace.entries),
            keep_every,
            seed,
        );
        let mut out = Vec::new();
        while let Some(e) = s.next_entry().unwrap() {
            out.push(e);
        }
        out
    }

    #[test]
    fn keeps_one_in_k_per_class() {
        let spec = TraceSpec::surf_lisa(5.0, 400.0);
        let full = ArrivalTrace::poisson(&spec, 23);
        let slice = sampled(10, 7);
        for class in [
            WorkloadClass::Light,
            WorkloadClass::Medium,
            WorkloadClass::Complex,
        ] {
            let n = full.entries.iter().filter(|e| e.class == class).count();
            let k = slice.iter().filter(|e| e.class == class).count();
            // Exactly ceil/floor of n/10 depending on the phase.
            assert!(
                k == n / 10 || k == n.div_ceil(10),
                "class {class:?}: {k} kept of {n}"
            );
            assert!(k > 0, "class {class:?} vanished from the slice");
        }
        // Order is preserved.
        for w in slice.windows(2) {
            assert!(w[0].at_s <= w[1].at_s);
        }
    }

    #[test]
    fn deterministic_per_seed_and_seed_sensitive() {
        let a = sampled(10, 7);
        let b = sampled(10, 7);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.at_s, y.at_s);
            assert_eq!(x.class, y.class);
            assert_eq!(x.epochs, y.epochs);
        }
        // A different seed picks a different phase (almost surely a
        // different first-kept entry for k=10).
        let c = sampled(10, 8);
        assert!(
            a.first().map(|e| e.at_s) != c.first().map(|e| e.at_s)
                || a.len() != c.len(),
            "seeds 7 and 8 selected an identical slice"
        );
    }

    #[test]
    fn keep_every_one_is_identity() {
        // keep_every = 1 → offsets are all 0 → everything kept.
        let full = ArrivalTrace::poisson(&TraceSpec::surf_lisa(5.0, 400.0), 23);
        assert_eq!(sampled(1, 99).len(), full.entries.len());
    }

    #[test]
    #[should_panic(expected = "keep_every")]
    fn zero_k_panics() {
        let _ = DownSampler::new(InMemoryTrace::new(Vec::new()), 0, 1);
    }
}
