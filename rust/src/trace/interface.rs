//! The trace interfaces: workload traces (pod submissions) and
//! cluster traces (machine-membership events) are separate streams —
//! the Alibaba trace ships them as separate tables, and the engine
//! consumes them through separate channels (an [`ArrivalSource`] vs
//! `RegionSpec::with_node_events`).
//!
//! Both interfaces are pull-based and fallible: a streaming reader
//! surfaces I/O and parse errors on the entry they occur at, not at
//! open time. Workload implementations also report their buffering
//! high-water mark so the bounded-memory property can assert that a
//! chunked reader never held more than its chunk.
//!
//! [`ArrivalSource`]: crate::federation::ArrivalSource

use std::collections::BTreeMap;

use crate::cluster::NodeId;
use crate::simulation::NodeChange;
use crate::workload::TraceEntry;

/// A pull-based stream of [`TraceEntry`]s in nondecreasing `at_s`
/// order. The ordering contract is the producer's: readers validate
/// it line by line, and the engine re-validates at admission.
pub trait WorkloadTrace {
    /// The next entry, or `Ok(None)` once the trace is exhausted.
    fn next_entry(&mut self) -> anyhow::Result<Option<TraceEntry>>;

    /// High-water mark of entries this trace has held in memory at
    /// once. A streaming reader reports its chunk occupancy; an
    /// in-memory trace reports its full length.
    fn peak_buffered(&self) -> usize;
}

/// A `&mut` to a workload trace is itself a workload trace, so
/// adapters like [`DownSampler`] can borrow or own interchangeably.
///
/// [`DownSampler`]: super::DownSampler
impl<W: WorkloadTrace + ?Sized> WorkloadTrace for &mut W {
    fn next_entry(&mut self) -> anyhow::Result<Option<TraceEntry>> {
        (**self).next_entry()
    }

    fn peak_buffered(&self) -> usize {
        (**self).peak_buffered()
    }
}

/// One machine-membership transition in a cluster trace.
#[derive(Debug, Clone, PartialEq)]
pub struct MachineEvent {
    /// Seconds since the trace epoch.
    pub at_s: f64,
    /// Trace-native machine identifier (opaque string).
    pub machine: String,
    /// `true` = the machine (re)joined, `false` = it left or failed.
    pub up: bool,
}

/// A pull-based stream of [`MachineEvent`]s in nondecreasing `at_s`
/// order.
pub trait ClusterTrace {
    /// The next event, or `Ok(None)` once the trace is exhausted.
    fn next_event(&mut self) -> anyhow::Result<Option<MachineEvent>>;
}

/// An already-materialized workload trace — the degenerate
/// implementation differential tests pin streaming against.
pub struct InMemoryTrace {
    entries: std::vec::IntoIter<TraceEntry>,
    len: usize,
}

impl InMemoryTrace {
    /// Wrap `entries` (must already be in nondecreasing `at_s` order,
    /// as `ArrivalTrace` guarantees for its own constructors).
    pub fn new(entries: Vec<TraceEntry>) -> Self {
        let len = entries.len();
        Self { entries: entries.into_iter(), len }
    }
}

impl WorkloadTrace for InMemoryTrace {
    fn next_entry(&mut self) -> anyhow::Result<Option<TraceEntry>> {
        Ok(self.entries.next())
    }

    fn peak_buffered(&self) -> usize {
        self.len
    }
}

/// Map a cluster trace's machine events onto the simulated cluster's
/// node indices: the first `node_count` distinct machine ids seen are
/// assigned node ids in first-seen order, events for later machines
/// are dropped (the replayed cluster is smaller than the traced one),
/// and only *transitions* are emitted — a machine's initial `add` is
/// its baseline (the simulated node already exists), and repeated
/// same-direction events are collapsed.
pub fn machine_events_to_node_changes(
    trace: &mut dyn ClusterTrace,
    node_count: usize,
) -> anyhow::Result<Vec<NodeChange>> {
    let mut index: BTreeMap<String, (NodeId, bool)> = BTreeMap::new();
    let mut changes = Vec::new();
    while let Some(ev) = trace.next_event()? {
        anyhow::ensure!(
            ev.at_s.is_finite() && ev.at_s >= 0.0,
            "machine event for {} has invalid time {}",
            ev.machine,
            ev.at_s
        );
        if !index.contains_key(&ev.machine) {
            if index.len() >= node_count {
                continue;
            }
            // First sighting: the simulated node starts up, so an
            // initial `add` is a no-op baseline and an initial
            // `remove` is a real transition.
            let id = index.len();
            index.insert(ev.machine.clone(), (id, true));
        }
        let (node, state) =
            index.get_mut(&ev.machine).expect("machine indexed above");
        if ev.up != *state {
            *state = ev.up;
            changes.push(NodeChange { at_s: ev.at_s, node: *node, up: ev.up });
        }
    }
    Ok(changes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::WorkloadClass;

    struct VecClusterTrace(std::vec::IntoIter<MachineEvent>);

    impl ClusterTrace for VecClusterTrace {
        fn next_event(&mut self) -> anyhow::Result<Option<MachineEvent>> {
            Ok(self.0.next())
        }
    }

    fn ev(at_s: f64, machine: &str, up: bool) -> MachineEvent {
        MachineEvent { at_s, machine: machine.into(), up }
    }

    #[test]
    fn in_memory_trace_streams_and_reports_len() {
        let entries = vec![
            TraceEntry { at_s: 0.5, class: WorkloadClass::Light, epochs: 2 },
            TraceEntry { at_s: 1.5, class: WorkloadClass::Medium, epochs: 4 },
        ];
        let mut t = InMemoryTrace::new(entries);
        assert_eq!(t.peak_buffered(), 2);
        assert_eq!(t.next_entry().unwrap().unwrap().at_s, 0.5);
        assert_eq!(t.next_entry().unwrap().unwrap().at_s, 1.5);
        assert!(t.next_entry().unwrap().is_none());
        // Exhaustion does not change the high-water mark.
        assert_eq!(t.peak_buffered(), 2);
    }

    #[test]
    fn machine_events_index_transition_and_truncate() {
        let events = vec![
            ev(0.0, "m_a", true),  // baseline add: no change emitted
            ev(1.0, "m_b", true),  // baseline add
            ev(2.0, "m_a", false), // real transition: node 0 down
            ev(2.0, "m_a", false), // repeat collapsed
            ev(3.0, "m_c", false), // first sighting as down: transition
            ev(4.0, "m_d", true),  // beyond node_count: dropped
            ev(5.0, "m_a", true),  // node 0 back up
        ];
        let mut trace = VecClusterTrace(events.into_iter());
        let changes = machine_events_to_node_changes(&mut trace, 3).unwrap();
        assert_eq!(
            changes,
            vec![
                NodeChange { at_s: 2.0, node: 0, up: false },
                NodeChange { at_s: 3.0, node: 2, up: false },
                NodeChange { at_s: 5.0, node: 0, up: true },
            ]
        );
    }

    #[test]
    fn machine_events_reject_invalid_time() {
        let mut trace =
            VecClusterTrace(vec![ev(f64::NAN, "m", true)].into_iter());
        let err = machine_events_to_node_changes(&mut trace, 1)
            .unwrap_err()
            .to_string();
        assert!(err.contains("invalid time"), "{err}");
    }
}
